"""Crash recovery walkthrough: WAL-backed training that survives a kill.

Three acts (§14 of DESIGN.md):

  1. train with every published version appended to a durable `DeltaWAL`
     (wire-format frames + crc32, periodic full checkpoints) — then
     "crash" by throwing the trainer and its store away;
  2. `recover_wal` rebuilds the store from disk (newest checkpoint image
     + at most one interval of delta replay), `OCCEngine.restore` resumes
     from the published watermark, and the finished run is BIT-IDENTICAL
     to one that never crashed;
  3. the same machinery at cluster scale: `run_ha_cluster` SIGKILLs the
     master mid-pass, promotes the highest-watermark follower with a
     fenced term, and audits every epoch digest against an uninterrupted
     reference.  (Act 3 spawns processes; pass --ha to include it.)

  PYTHONPATH=src python examples/crash_recovery.py [--ha]
"""
import sys
import tempfile

import jax.numpy as jnp
import numpy as np

from repro.checkpoint import DeltaWAL, recover_wal
from repro.core import DPMeansTransaction, OCCEngine
from repro.data import dp_stick_breaking_data
from repro.distributed.transport import store_digest
from repro.serving.snapshot import SnapshotStore


def main():
    x = jnp.asarray(dp_stick_breaking_data(2048, seed=0, dim=8)[0])
    lam, k_max, pb = 4.0, 128, 128

    # --- the run that never fails: our bit-identity oracle ---------------
    ref = OCCEngine(DPMeansTransaction(lam, k_max=k_max), pb=pb)
    ref.partial_fit(x[:1024])
    ref.partial_fit(x[1024:])
    ref.flush()
    print(f"reference (uninterrupted): K={int(ref.pool.count)}")

    wal_dir = tempfile.mkdtemp(prefix="occ-wal-")

    # --- act 1: durable training, then a crash ---------------------------
    # The WAL rides the store's `wire` seam — the same seam socket
    # replication uses — so durability is just one more subscriber.
    wal = DeltaWAL(wal_dir, model="demo", checkpoint_every=4)
    store = SnapshotStore(capacity=16, delta=True, model="demo", wire=wal)
    trainer = OCCEngine(DPMeansTransaction(lam, k_max=k_max), pb=pb,
                        publish=store.publish_pass)
    for lo in range(0, 1024, 256):    # publish per chunk: versions 1..4,
        trainer.partial_fit(x[lo:lo + 256])   # checkpoint at version 4...
    wal.close()                               # ...then the process dies
    del trainer, store                # the crash: only disk remains
    print(f"crashed after 1024/2048 points; WAL dir keeps "
          f"{wal.n_appended} delta records + {wal.n_checkpoints} checkpoints")

    # --- act 2: recover, resume, verify bit-identity ----------------------
    recovered, info = recover_wal(wal_dir, model="demo", capacity=16)
    snap = recovered.latest().materialize()
    print(f"recovered: checkpoint@v{info['ckpt_version']} + "
          f"{info['n_replayed']} deltas replayed -> version "
          f"{snap.version}, watermark n_seen={snap.n_seen}")

    resumed = OCCEngine(DPMeansTransaction(lam, k_max=k_max), pb=pb)
    resumed.restore(snap, k_max=k_max)
    resumed.partial_fit(x[snap.n_seen:])   # only the unseen suffix
    resumed.flush()
    identical = (int(resumed.pool.count) == int(ref.pool.count)
                 and np.array_equal(np.asarray(resumed.pool.centers),
                                    np.asarray(ref.pool.centers)))
    print(f"resumed:   K={int(resumed.pool.count)}  "
          f"bit-identical to the uninterrupted run: {identical}")
    assert identical

    # --- act 3 (--ha): kill the MASTER of a live cluster ------------------
    if "--ha" in sys.argv[1:]:
        from repro.launch.ha_cluster import HAConfig, run_ha_cluster
        rec = run_ha_cluster(HAConfig(
            n=1024, dim=8, pb=64, k_max=128, lam=3.0, n_workers=2,
            n_nodes=3, kill_master_after_version=6, quiet=True))
        print(f"HA cluster: master killed after acked version "
              f"{rec['kill_version']}; node {rec['master_node_final']} "
              f"promoted (terms {rec['terms']}), resumed at epoch "
              f"{rec['resume_epoch']}; every epoch digest + final store "
              f"bit-identical: "
              f"{rec['epoch_digests_match'] and rec['final_digest_match']}")


if __name__ == "__main__":
    main()

"""End-to-end training driver example: train a ~100M-param granite-family
model for a few hundred steps (CPU-scaled by default; pass --full-100m on
real hardware).

  PYTHONPATH=src python examples/train_lm.py                  # CPU-sized
  PYTHONPATH=src python examples/train_lm.py --full-100m      # ~100M params
"""
import argparse
import sys

from repro.launch.train import main as train_main


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--full-100m", action="store_true")
    ap.add_argument("--steps", type=int, default=None)
    args, _ = ap.parse_known_args()

    if args.full_100m:
        # ~100M params: 12L x 768d qwen3-family, few hundred steps
        argv = ["--arch", "qwen3-4b", "--steps", str(args.steps or 300),
                "--batch", "8", "--seq", "512", "--ckpt-dir", "/tmp/repro_100m",
                "--ckpt-every", "100"]
        import repro.configs.registry as reg
        cfg = reg.ARCHS["qwen3-4b"].replace(
            n_layers=12, d_model=768, n_heads=12, n_kv_heads=4, head_dim=64,
            d_ff=2048, vocab=32000)
        reg.ARCHS["qwen3-100m"] = cfg
        reg._ALIASES["qwen3-100m"] = "qwen3-100m"
        argv[1] = "qwen3-100m"
    else:
        argv = ["--arch", "granite-3-2b", "--reduced",
                "--steps", str(args.steps or 60), "--batch", "8",
                "--seq", "64", "--ckpt-dir", "/tmp/repro_quick",
                "--ckpt-every", "30", "--lr", "3e-3"]
    loss = train_main(argv)
    print(f"example finished; final loss {loss:.4f}")


if __name__ == "__main__":
    main()

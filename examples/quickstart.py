"""Quickstart: the OCC engine and its transactions in 40 lines.

The primary API is `OCCEngine` + an `OCCTransaction` (DP-means, OFL,
BP-means, or your own): the engine runs a whole pass — padding, optional
serial bootstrap, bounded-master validation, mesh sharding, stats — as one
compiled epoch scan.  The legacy `occ_dp_means` / `occ_ofl` / `occ_bp_means`
wrappers remain as one-call conveniences over the same engine.

  PYTHONPATH=src python examples/quickstart.py
"""
import jax
import jax.numpy as jnp

from repro.core import (
    DPMeansTransaction, OCCEngine, occ_bp_means, occ_ofl, serial_dp_means,
)
from repro.data import bp_stick_breaking_data, dp_stick_breaking_data


def main():
    # --- DP-means through the engine (primary API) -----------------------
    x, z_true, _ = dp_stick_breaking_data(2048, seed=0)
    x = jnp.asarray(x)
    txn = DPMeansTransaction(lam=4.0, k_max=256)
    eng = OCCEngine(txn, pb=256)
    res = eng.run(x)                          # ONE compiled call: all epochs
    pool = eng.refine(res.pool, x, res.assign)
    stats = res.stats
    for _ in range(2):                        # Lloyd-style passes, as serial
        res = eng.run(x, pool=pool)
        pool = eng.refine(res.pool, x, res.assign)
    print(f"OCC DP-means:  K={int(res.pool.count)} (true {z_true.max() + 1}), "
          f"J={float(txn.objective(x, res.assign, pool)):.1f}, "
          f"proposed={int(stats.proposed.sum())}, "
          f"rejected={int(stats.proposed.sum() - stats.accepted.sum())}"
          f" (bound Pb=256), dispatches={eng.n_dispatches} (1 per pass)")
    ser = serial_dp_means(x, 4.0, k_max=256, max_iters=3)
    print(f"serial DP-means: K={int(ser.pool.count)}, J={float(ser.objective):.1f}"
          f"  <- OCC matches the serial algorithm (Thm 3.1)")

    # --- OFL / BP-means via the convenience wrappers ----------------------
    ofl = occ_ofl(x, lam=4.0, pb=256, key=jax.random.key(0), k_max=512)
    print(f"OCC OFL:       K={int(ofl.pool.count)}, J={float(ofl.objective):.1f}"
          f"  (constant-factor approx of DP-means objective, Lemma 3.2)")

    xb, zb, _ = bp_stick_breaking_data(1024, seed=0)
    bp = occ_bp_means(jnp.asarray(xb), lam=4.0, pb=256, k_max=128, max_iters=2)
    print(f"OCC BP-means:  K={int(bp.pool.count)} features "
          f"(true {zb.shape[1]}), cost={float(bp.objective):.1f}")

    # --- train/serve split: publish snapshots, serve queries --------------
    # Training publishes immutable model versions into a SnapshotStore; a
    # read-only ClusterService answers typed queries against the newest
    # version (pad-to-bucket microbatching, one jitted dispatch per
    # microbatch, atomic hot-swap).  DESIGN.md §10; the typed surface —
    # `submit(Query(...))` + every knob in one `ServeConfig` — is §17
    # (`assign`/`score`/`topk` remain as shims over `submit`).
    from repro.serving import ClusterService, Query, ServeConfig, SnapshotStore
    store = SnapshotStore()
    eng = OCCEngine(txn, pb=256, publish=store.publish_pass)
    for xs in jnp.split(x, [700, 1500]):      # ragged stream, carry engaged
        eng.partial_fit(xs)
    eng.flush()
    svc = ClusterService(store, ServeConfig(max_bucket=1024))
    resp = svc.submit(Query(x[:100]))         # one microbatch, one dispatch
    top = svc.submit(Query(x[:5], kind="topk", k=3))
    scan = svc.submit(Query(x[:32], kind="topk", k=3, priority="analytics",
                            max_staleness=2))  # sheddable background scan
    print(f"serving:       v{resp.version} answered 100 queries in bucket "
          f"{resp.bucket}, K={store.latest().count}, "
          f"topk[0]={top.labels[0].tolist()}, "
          f"analytics scan degraded={scan.degraded}")
    print("streaming: examples/streaming_clusters.py; full train-while-serve"
          " demo: python -m repro.launch.serve_clusters")


if __name__ == "__main__":
    main()

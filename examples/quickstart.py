"""Quickstart: the paper's OCC algorithms in 30 lines.

  PYTHONPATH=src python examples/quickstart.py
"""
import jax
import jax.numpy as jnp

from repro.core import occ_dp_means, occ_ofl, occ_bp_means, serial_dp_means
from repro.data import dp_stick_breaking_data, bp_stick_breaking_data


def main():
    # --- DP-means (clustering) ------------------------------------------
    x, z_true, _ = dp_stick_breaking_data(2048, seed=0)
    x = jnp.asarray(x)
    res = occ_dp_means(x, lam=4.0, pb=256, k_max=256, max_iters=3)
    print(f"OCC DP-means:  K={int(res.pool.count)} (true {z_true.max() + 1}), "
          f"J={float(res.objective):.1f}, "
          f"proposed={int(res.stats.proposed.sum())}, "
          f"rejected={int(res.stats.proposed.sum() - res.stats.accepted.sum())}"
          f" (bound Pb=256)")
    ser = serial_dp_means(x, 4.0, k_max=256, max_iters=3)
    print(f"serial DP-means: K={int(ser.pool.count)}, J={float(ser.objective):.1f}"
          f"  <- OCC matches the serial algorithm (Thm 3.1)")

    # --- OFL (stochastic facility location) ------------------------------
    ofl = occ_ofl(x, lam=4.0, pb=256, key=jax.random.key(0), k_max=512)
    print(f"OCC OFL:       K={int(ofl.pool.count)}, J={float(ofl.objective):.1f}"
          f"  (constant-factor approx of DP-means objective, Lemma 3.2)")

    # --- BP-means (latent features) --------------------------------------
    xb, zb, _ = bp_stick_breaking_data(1024, seed=0)
    bp = occ_bp_means(jnp.asarray(xb), lam=4.0, pb=256, k_max=128, max_iters=2)
    print(f"OCC BP-means:  K={int(bp.pool.count)} features "
          f"(true {zb.shape[1]}), cost={float(bp.objective):.1f}")


if __name__ == "__main__":
    main()

"""Online clustering of an arriving stream: `OCCEngine.partial_fit`.

The engine's streaming surface reuses the same OCC transactions for
incremental epochs over arriving data — the online / heavy-traffic serving
mode.  The pool, the global point counter, and the epoch statistics carry
over between batches, so the stream is exactly the batch run chunked in
time: with pb-aligned batches (as here) even the epoch boundaries agree,
and for OFL the counter-based uniforms make the stream draw-for-draw
identical to the one-shot run.

  PYTHONPATH=src python examples/streaming_clusters.py
"""
import jax
import jax.numpy as jnp
import numpy as np

from repro.core import DPMeansTransaction, OFLTransaction, OCCEngine, occ_ofl
from repro.data import dp_stick_breaking_data


def main():
    # --- a stream of arriving batches ------------------------------------
    x, z_true, _ = dp_stick_breaking_data(4096, seed=0)
    x = jnp.asarray(x)
    batches = [x[i:i + 512] for i in range(0, 4096, 512)]

    # --- DP-means over the stream ----------------------------------------
    eng = OCCEngine(DPMeansTransaction(lam=4.0, k_max=256), pb=128)
    print("DP-means stream:")
    for i, xb in enumerate(batches):
        res = eng.partial_fit(xb)
        print(f"  batch {i}: n_seen={eng.n_seen:5d}  K={int(res.pool.count):3d}"
              f"  sent={int(res.stats.proposed.sum()):4d}"
              f"  accepted={int(res.stats.accepted.sum()):3d}")
    print(f"  true K = {z_true.max() + 1}; master load stays ~Pb per batch "
          f"after warmup (Thm 3.3)")

    # --- OFL: the stream is bit-identical to the one-shot run -------------
    key = jax.random.key(0)
    eng = OCCEngine(OFLTransaction(lam=8.0, k_max=512, key=key), pb=128)
    zs = [eng.partial_fit(xb).assign for xb in batches]
    one_shot = occ_ofl(x, 8.0, pb=128, key=key, k_max=512)
    same = np.array_equal(np.concatenate([np.asarray(z) for z in zs]),
                          np.asarray(one_shot.z))
    print(f"OFL stream:      K={int(eng.pool.count)}  "
          f"bit-identical to one-shot run: {same}")


if __name__ == "__main__":
    main()

"""Online clustering of an arriving stream: `OCCEngine.partial_fit`.

The engine's streaming surface reuses the same OCC transactions for
incremental epochs over arriving data — the online / heavy-traffic serving
mode.  The pool, the global point counter, and the epoch statistics carry
over between batches, and the trailing `n mod pb` points of each call ride
in an explicit partial-epoch carry, so the stream is *bit-identical* to the
one-shot run for ANY batch lengths — even the deliberately ragged ones
below.  `flush()` commits the stream's final short epoch.

  PYTHONPATH=src python examples/streaming_clusters.py
"""
import jax
import jax.numpy as jnp
import numpy as np

from repro.core import DPMeansTransaction, OFLTransaction, OCCEngine, occ_ofl
from repro.data import dp_stick_breaking_data


def main():
    # --- a stream of RAGGED arriving batches ------------------------------
    x, z_true, _ = dp_stick_breaking_data(4096, seed=0)
    x = jnp.asarray(x)
    cuts = [353, 1000, 1024, 2500, 4070]          # nothing aligned to pb
    batches = jnp.split(x, cuts)

    # --- DP-means over the stream ----------------------------------------
    eng = OCCEngine(DPMeansTransaction(lam=4.0, k_max=256), pb=128)
    print("DP-means stream (ragged batches, pb=128):")
    for i, xb in enumerate(batches):
        res = eng.partial_fit(xb)
        print(f"  batch {i}: len={xb.shape[0]:4d}  n_seen={eng.n_seen:5d}"
              f"  carried={eng.n_pending:3d}  K={int(eng.pool.count):3d}"
              f"  sent={int(res.stats.proposed.sum()):4d}")
    eng.flush()                                   # final short epoch
    print(f"  true K = {z_true.max() + 1}; master load stays ~Pb per batch "
          f"after warmup (Thm 3.3)")

    # --- OFL: ragged stream is bit-identical to the one-shot run ----------
    key = jax.random.key(0)
    eng = OCCEngine(OFLTransaction(lam=8.0, k_max=512, key=key), pb=128)
    zs = [eng.partial_fit(xb).assign for xb in batches]
    fl = eng.flush()
    if fl is not None:
        zs.append(fl.assign)
    one_shot = occ_ofl(x, 8.0, pb=128, key=key, k_max=512)
    same = np.array_equal(np.concatenate([np.asarray(z) for z in zs]),
                          np.asarray(one_shot.z))
    print(f"OFL stream:      K={int(eng.pool.count)}  "
          f"bit-identical to one-shot run (ANY batching): {same}")
    print("train/serve split: see launch/serve_clusters.py "
          "(publish snapshots + serve while training)")


if __name__ == "__main__":
    main()

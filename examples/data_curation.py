"""OCC data curation inside the LM framework (DESIGN.md §4): cluster
sequence embeddings with distributed DP-means, down-weight near-duplicate
clusters, feed the weights back into sampling.

  PYTHONPATH=src python examples/data_curation.py
"""
import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import ARCHS, reduced
from repro.data.curation import curate, embed_sequences
from repro.data.tokens import TokenPipeline
from repro.models import build_model


def main():
    cfg = reduced(ARCHS["granite-3-2b"]).replace(dtype="float32")
    model = build_model(cfg)
    params = model.init(jax.random.key(0))

    # Build a corpus with injected near-duplicates (the realistic failure
    # mode curation exists for).
    pipe = TokenPipeline(cfg.vocab, global_batch=16, seq_len=32, seed=0)
    batches = [
        {k: jnp.asarray(v) for k, v in pipe.batch_at(s).items()}
        for s in range(6)
    ]
    dup = batches[0]["tokens"][:1]
    batches[1] = dict(batches[1])
    batches[1]["tokens"] = jnp.concatenate(
        [jnp.tile(dup, (16, 1))], 0)   # one batch of near-duplicates

    embeds = embed_sequences(model, params, batches)
    print(f"embedded {embeds.shape[0]} sequences into R^{embeds.shape[1]}")

    lam = 0.5 * float(jnp.median(jnp.linalg.norm(
        embeds - embeds.mean(0), axis=1)))
    rep = curate(embeds, lam=lam, pb=32, k_max=64)
    print(f"OCC DP-means curation: {rep.n_clusters} clusters over "
          f"{rep.n_points} sequences; dup_fraction={rep.dup_fraction:.2%}")
    w = rep.keep_weight
    print(f"sampling weights: min={w.min():.3f} mean={w.mean():.3f} "
          f"(duplicate cluster down-weighted: {np.sum(w < 1.0)} seqs)")
    assert rep.dup_fraction > 0.0, "expected the injected duplicates to cluster"


if __name__ == "__main__":
    main()

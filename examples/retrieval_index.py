"""RETRO-style retrieval serving at K >= 10^5 centers (DESIGN.md §16).

The serving-plane scale proof for the streaming top-k path: train a
DP-means clustering of synthetic chunk embeddings up to ~10^5 centers
with the existing OCC engine (tiny lambda — nearly every chunk becomes a
center, exactly the regime a retrieval index lives in), publish it into a
hierarchical `SnapshotStore`, and serve top-k nearest-neighbor lookups
through `ClusterService` as the index:

  * flat serving — the streaming-kernel dispatch over the full center
    buffer (on TPU: tile-skipped DMA past the active prefix);
  * multi-probe serving — route each query to its p nearest coarse cells
    and stream only those fine shards, sweeping the exactness knob p:
    p = all is AUDITED bit-identical to flat (the §16 contract), smaller
    p reports measured recall@k from the service's own audit gauge.

p50/p99 latency + recall rows merge into BENCH_cluster_service.json under
the "retrieval" key (read-modify-write: the train-while-serve demo owns
the rest of the file).

  PYTHONPATH=src python examples/retrieval_index.py [--quick] [--out F]
"""
from __future__ import annotations

import argparse
import json
import os
import time

import jax.numpy as jnp
import numpy as np

from repro.core import DPMeansTransaction, OCCEngine
from repro.serving import ClusterService, Query, ServeConfig, SnapshotStore

N_CHUNKS = 110_000          # K >= 1e5 after conflict rejections
DIM = 16
LAM = 0.05                  # << chunk spacing: every chunk a center
K_MAX = 131_072             # 2^17 capacity bucket
BUCKET = 64                 # latency-regime microbatches (probing prunes)
TOPK = 8


def _chunk_embeddings(n: int, dim: int, seed: int) -> np.ndarray:
    """Unit-normalized Gaussian 'chunk embeddings' — uniform on the
    sphere, the shape retrieval corpora actually have (no mixture
    structure: the index IS the dataset)."""
    rng = np.random.default_rng(seed)
    x = rng.normal(size=(n, dim)).astype(np.float32)
    return x / np.linalg.norm(x, axis=1, keepdims=True)


def build_index(n_chunks: int = N_CHUNKS, quiet: bool = False):
    x = _chunk_embeddings(n_chunks, DIM, seed=0)
    store = SnapshotStore(hier=True)
    eng = OCCEngine(DPMeansTransaction(LAM, k_max=K_MAX), pb=256,
                    validate_cap="adaptive", publish=store.publish_pass)
    t0 = time.time()
    eng.partial_fit(jnp.asarray(x))
    eng.flush()
    t_train = time.time() - t0
    k = int(eng.pool.count)
    assert k >= 100_000, f"index too small: K={k}"
    snap = store.latest()
    h = snap.hier
    if not quiet:
        print(f"index: K={k} centers of {n_chunks} chunks in "
              f"{t_train:.0f}s  (capacity {snap.capacity}, "
              f"{h.n_cells} cells x {h.shard_cap} shard rows)")
    return x, store, t_train


def _serve_sweep(x, store, n_queries: int, ps, quiet: bool = False):
    """One service per probe setting; identical query trace; p50/p99 from
    each service's own request histogram, recall from its audit gauge."""
    rng = np.random.default_rng(42)
    # queries = perturbed chunks: the retrieval access pattern (a query
    # lands NEAR its source chunk, not on it)
    base = x[rng.integers(0, x.shape[0], size=n_queries)]
    q = base + 0.02 * rng.normal(size=base.shape).astype(np.float32)
    h = store.latest().hier
    rows = {}
    flat_resp = None
    for p in ps:
        probes = h.n_cells if p == "all" else p
        svc = ClusterService(store, ServeConfig(
            max_bucket=BUCKET, probes=probes, recall_audit_every=1))
        resps = [svc.submit(Query(q[lo:lo + BUCKET], kind="topk", k=TOPK))
                 for lo in range(0, n_queries, BUCKET)]
        met = svc.metrics()
        labels = np.concatenate([r.labels for r in resps])
        scores = np.concatenate([r.scores for r in resps])
        row = {
            "p": probes,
            "p50_ms": met["request_p50_ms"],
            "p99_ms": met["request_p99_ms"],
            f"recall@{TOPK}": (1.0 if p == "all"
                               else met["topk_recall"]),
            "shards_probed": met["topk_shards_probed"],
            "tiles_skipped": met["topk_tiles_skipped"],
        }
        if p == "all":
            # the exactness contract, audited: p = all responses must be
            # BIT-identical to a probes=None flat service on every row
            flat = ClusterService(store, ServeConfig(max_bucket=BUCKET))
            fq = [flat.submit(Query(q[lo:lo + BUCKET], kind="topk", k=TOPK))
                  for lo in range(0, n_queries, BUCKET)]
            fl = np.concatenate([r.labels for r in fq])
            fs = np.concatenate([r.scores for r in fq])
            row["exact_vs_flat"] = bool(np.array_equal(labels, fl)
                                        and np.array_equal(scores, fs))
            assert row["exact_vs_flat"], "p=all must be bit-identical"
            flat_resp = labels
        rows[f"p{probes}" if p != "all" else "p_all"] = row
        if not quiet:
            tag = "all" if p == "all" else f"{probes:3d}"
            print(f"  p={tag}: p50={row['p50_ms']:7.2f}ms "
                  f"p99={row['p99_ms']:7.2f}ms "
                  f"recall@{TOPK}={row[f'recall@{TOPK}']:.3f}"
                  + (";exact=True" if p == "all" else ""))
    assert flat_resp is not None
    return rows


def main(quick: bool = False, out: str | None = None,
         quiet: bool = False) -> dict:
    x, store, t_train = build_index(quiet=quiet)
    n_queries = 256 if quick else 1024
    ps = (4, "all") if quick else (1, 4, 16, "all")
    if not quiet:
        print(f"serving {n_queries} queries, k={TOPK}, "
              f"bucket={BUCKET}, probe sweep {ps}:")
    rows = _serve_sweep(x, store, n_queries, ps, quiet=quiet)
    snap = store.latest()
    record = {
        "bench": "retrieval_index",
        "n_chunks": int(x.shape[0]),
        "k_centers": int(snap.count),
        "capacity": int(snap.capacity),
        "n_cells": int(snap.hier.n_cells),
        "shard_cap": int(snap.hier.shard_cap),
        "dim": DIM,
        "k": TOPK,
        "train_s": t_train,
        "n_queries": n_queries,
        "sweep": rows,
    }
    if out:
        # read-modify-write: the train-while-serve demo owns the rest of
        # BENCH_cluster_service.json; this example owns the one key
        merged = {}
        if os.path.exists(out):
            try:
                with open(out) as f:
                    merged = json.load(f)
            except ValueError:
                merged = {}
        if not isinstance(merged, dict):
            merged = {"demo": merged}
        merged["retrieval"] = record
        with open(out, "w") as f:
            json.dump(merged, f, indent=2)
        if not quiet:
            print(f"merged retrieval rows into {out}")
    return record


if __name__ == "__main__":
    ap = argparse.ArgumentParser(description=__doc__.split("\n")[0])
    ap.add_argument("--quick", action="store_true",
                    help="CI smoke: fewer queries + a 2-point probe sweep "
                         "(the index still trains to K >= 1e5)")
    ap.add_argument("--out", default=None,
                    help="merge rows into this BENCH json (retrieval key)")
    args = ap.parse_args()
    main(quick=args.quick, out=args.out)

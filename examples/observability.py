"""Observability walkthrough: one registry + one trace for a whole run.

Three acts (§15 of DESIGN.md):

  1. a single shared `Obs` threaded through engine → store → WAL: every
     layer's counters land in ONE registry, read back via `dump()` /
     Prometheus-style `exposition()` — the same text the HA coordinator
     serves over its CTRL channel;
  2. the same run traced: spans and instants from every subsystem land
     in one Chrome-trace JSON — open it at https://ui.perfetto.dev;
  3. the flagship: a 3-node HA cluster with the master SIGKILLed
     mid-pass, `trace_out` merging every process's timeline (the victim
     flushes its trace before `os._exit`) into one file whose span
     categories cover engine, transport, WAL, fault, and the HA control
     plane.  (Act 3 spawns processes; pass --ha to include it.)

  PYTHONPATH=src python examples/observability.py [--ha]
"""
import os
import sys
import tempfile

import jax.numpy as jnp

from repro.checkpoint import DeltaWAL
from repro.core import DPMeansTransaction, OCCEngine
from repro.data import dp_stick_breaking_data
from repro.obs import Obs, Tracer, load_trace, trace_categories, \
    validate_trace
from repro.serving.snapshot import SnapshotStore


def main():
    x = jnp.asarray(dp_stick_breaking_data(2048, seed=0, dim=8)[0])
    lam, k_max, pb = 4.0, 128, 128
    out_dir = tempfile.mkdtemp(prefix="occ-obs-")
    trace_path = os.path.join(out_dir, "trace.json")

    # --- acts 1+2: one Obs, every layer, one registry + one trace --------
    # Components create a private Obs() when none is given (counters still
    # work standalone); passing ONE bundle is what unifies the run.
    obs = Obs(tracer=Tracer("observability-demo"), trace_path=trace_path)
    wal = DeltaWAL(os.path.join(out_dir, "wal"), model="demo",
                   checkpoint_every=4, obs=obs)
    store = SnapshotStore(capacity=16, delta=True, model="demo", wire=wal)
    engine = OCCEngine(DPMeansTransaction(lam, k_max=k_max), pb=pb,
                       publish=store.publish_pass, obs=obs)
    for lo in range(0, 2048, 512):
        engine.partial_fit(x[lo:lo + 512])
    engine.flush()
    wal.close()
    obs.flush()

    print("--- registry (Prometheus text exposition, excerpt) ---")
    for line in obs.metrics.exposition().splitlines():
        if line.startswith(("engine_p", "engine_accepted", "wal_appends",
                            "wal_checkpoints", "engine_pass_s_")):
            print(f"  {line}")
    h = obs.metrics.get_histogram("engine_pass_s")
    print(f"engine passes: {h.count}, pass p50 {h.percentile(50) * 1e3:.1f}ms"
          f" (K={int(engine.pool.count)}, "
          f"conflict_rate={obs.metrics.value('engine_conflict_rate'):.3f})")

    trace = load_trace(trace_path)
    assert validate_trace(trace) == []
    print(f"trace: {len(trace['traceEvents'])} events, categories "
          f"{sorted(trace_categories(trace))}\n"
          f"  -> open {trace_path} at https://ui.perfetto.dev")

    # --- act 3 (--ha): the merged multi-process chaos timeline -----------
    if "--ha" in sys.argv[1:]:
        from repro.launch.ha_cluster import HAConfig, run_ha_cluster
        ha_trace = os.path.join(out_dir, "trace_ha.json")
        rec = run_ha_cluster(HAConfig(
            n=1024, dim=8, pb=64, k_max=128, lam=3.0, n_workers=2,
            n_nodes=3, kill_master_after_version=6, trace_out=ha_trace,
            quiet=True))
        merged = load_trace(ha_trace)
        assert validate_trace(merged) == []
        pids = {e["pid"] for e in merged["traceEvents"]}
        print(f"HA chaos: {rec['promotions']} promotion, "
              f"{len(merged['traceEvents'])} events from {len(pids)} "
              f"processes (killed master included), categories "
              f"{sorted(trace_categories(merged))}\n"
              f"  -> open {ha_trace} at https://ui.perfetto.dev")


if __name__ == "__main__":
    main()

"""Batched serving example: slot-based engine with recycling.

  PYTHONPATH=src python examples/serve_lm.py
"""
from repro.launch.serve import main as serve_main


def main():
    serve_main(["--arch", "qwen3-4b", "--reduced", "--requests", "6",
                "--slots", "3", "--prompt-len", "8", "--max-new", "8",
                "--cache-len", "64"])


if __name__ == "__main__":
    main()

#!/usr/bin/env python
"""Ban raw clocks in the instrumented trees (DESIGN.md §15).

All timing inside ``src/repro/{distributed,serving,checkpoint}`` must go
through the observability layer — `repro.obs.metrics.now()` (monotonic,
system-wide on Linux, so per-process traces merge into one timeline) or a
registry `timer(...)`.  Raw ``time.time()`` drifts under NTP steps and
raw ``time.perf_counter()`` is process-local, so either one silently
breaks cross-process trace merging and the HeartbeatTracker's liveness
math.  This grep-level gate keeps them from creeping back in.

Deliberate exceptions (e.g. a WALL-clock stamp in a checkpoint manifest,
where calendar time is the point) go in ``tools/lint_timing_allow.txt``:
one ``<repo-relative-path>: <substring>`` entry per line; an offending
source line is allowed iff an entry's path matches its file and the
entry's substring occurs in the line.

  python tools/lint_timing.py          # exit 0 clean / 1 with findings
"""
from __future__ import annotations

import os
import re
import sys

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
TREES = ("src/repro/distributed", "src/repro/serving",
         "src/repro/checkpoint")
ALLOWLIST = os.path.join(REPO, "tools", "lint_timing_allow.txt")
BANNED = re.compile(r"\btime\.(?:time|perf_counter)\s*\(")


def load_allowlist() -> list[tuple[str, str]]:
    entries = []
    if os.path.exists(ALLOWLIST):
        with open(ALLOWLIST) as f:
            for raw in f:
                raw = raw.strip()
                if not raw or raw.startswith("#"):
                    continue
                path, _, frag = raw.partition(":")
                entries.append((path.strip(), frag.strip()))
    return entries


def allowed(relpath: str, line: str, entries) -> bool:
    return any(relpath == p and frag and frag in line
               for p, frag in entries)


def main() -> int:
    entries = load_allowlist()
    findings = []
    for tree in TREES:
        root = os.path.join(REPO, tree)
        for dirpath, dirnames, filenames in os.walk(root):
            dirnames[:] = [d for d in dirnames
                           if d not in ("__pycache__", "obs")]
            for fn in sorted(filenames):
                if not fn.endswith(".py"):
                    continue
                path = os.path.join(dirpath, fn)
                rel = os.path.relpath(path, REPO)
                with open(path) as f:
                    for i, line in enumerate(f, start=1):
                        if BANNED.search(line) and not allowed(
                                rel, line, entries):
                            findings.append(
                                f"{rel}:{i}: {line.strip()}")
    if findings:
        print("raw time.time()/time.perf_counter() in instrumented "
              "trees — use repro.obs.metrics.now() or a registry timer "
              "(or add a deliberate exception to "
              "tools/lint_timing_allow.txt):", file=sys.stderr)
        for f in findings:
            print(f"  {f}", file=sys.stderr)
        return 1
    print(f"lint_timing: clean ({', '.join(TREES)})")
    return 0


if __name__ == "__main__":
    sys.exit(main())

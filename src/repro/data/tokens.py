"""Synthetic LM token pipeline.

Deterministic, shardable, restartable: batch t is a pure function of
(seed, step), so a restarted job regenerates exactly the stream it would
have seen — the data-side half of fault tolerance.  Each host materializes
only its shard of the global batch (host_slice), which is what a 1000-node
run needs; on this single-host container host_slice covers everything.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Iterator

import numpy as np

__all__ = ["TokenPipeline", "synthetic_token_batches"]


@dataclass(frozen=True)
class TokenPipeline:
    vocab_size: int
    global_batch: int
    seq_len: int
    seed: int = 0
    host_index: int = 0
    host_count: int = 1

    @property
    def host_batch(self) -> int:
        assert self.global_batch % self.host_count == 0
        return self.global_batch // self.host_count

    def batch_at(self, step: int) -> dict[str, np.ndarray]:
        """Pure function of (seed, step, host): tokens + next-token labels.

        Tokens follow a cheap power-law-ish distribution so losses are not
        uniform-random (gives optimizers something to fit in examples).
        """
        rng = np.random.default_rng(
            np.random.SeedSequence([self.seed, step, self.host_index]))
        shape = (self.host_batch, self.seq_len + 1)
        u = rng.uniform(size=shape)
        toks = np.minimum(
            (self.vocab_size * u ** 3.0).astype(np.int32), self.vocab_size - 1)
        return {"tokens": toks[:, :-1], "labels": toks[:, 1:]}

    def __iter__(self) -> Iterator[dict[str, np.ndarray]]:
        step = 0
        while True:
            yield self.batch_at(step)
            step += 1


def synthetic_token_batches(vocab_size: int, batch: int, seq_len: int,
                            steps: int, seed: int = 0):
    pipe = TokenPipeline(vocab_size, batch, seq_len, seed)
    for s in range(steps):
        yield pipe.batch_at(s)

"""OCC data curation: the paper's algorithm as a first-class framework
feature (DESIGN.md §4).

Distributed DP-means (OCC) clusters sequence embeddings on the same `data`
mesh axis training uses; the resulting clusters drive near-duplicate
down-weighting and topic balancing of the token pipeline.  The embeddings
come from mean-pooled hidden states of the (possibly mid-training) model —
so this runs *inside* the training framework, not as an offline job.
"""
from __future__ import annotations

from dataclasses import dataclass

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.dp_means import DPMeansResult, occ_dp_means

__all__ = ["embed_sequences", "curate", "CurationReport"]


@dataclass(frozen=True)
class CurationReport:
    n_clusters: int
    n_points: int
    dup_fraction: float      # points in overfull clusters
    keep_weight: np.ndarray  # (N,) sampling weight per example
    result: DPMeansResult


def embed_sequences(model, params, batches) -> jnp.ndarray:
    """Mean-pooled final hidden states as sequence embeddings (B_total, D)."""
    outs = []
    for batch in batches:
        x, n_prefix = model._embed(params, batch)
        positions = jnp.arange(x.shape[1], dtype=jnp.float32)
        enc_out = model._encode(params, batch) if model.cfg.is_encdec else None
        h, _ = model._body_train(params, x, positions, enc_out)
        outs.append(jnp.mean(h[:, n_prefix:].astype(jnp.float32), axis=1))
    return jnp.concatenate(outs, axis=0)


def curate(embeds: jnp.ndarray, lam: float, pb: int, k_max: int = 512,
           max_per_cluster: int | None = None, mesh=None) -> CurationReport:
    """OCC DP-means over embeddings -> per-example sampling weights.

    Clusters with more than `max_per_cluster` members are down-weighted to
    that size (near-duplicate suppression); default is mean cluster size.
    """
    res = occ_dp_means(embeds, lam, pb=pb, k_max=k_max, max_iters=2, mesh=mesh)
    z = np.asarray(res.z)
    n = z.shape[0]
    k = int(res.pool.count)
    counts = np.bincount(z[z >= 0], minlength=max(k, 1))
    cap = max_per_cluster or max(1, int(np.ceil(n / max(k, 1))))
    w = np.ones(n, np.float64)
    over = counts > cap
    for c in np.nonzero(over)[0]:
        w[z == c] = cap / counts[c]
    dup_frac = float(np.sum(counts[over] - cap) / max(n, 1))
    return CurationReport(k, n, dup_frac, w, res)

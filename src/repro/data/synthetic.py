"""Synthetic data generators from the paper's §4 and Appendix C.1.

Clustering: stick-breaking for the Dirichlet process (theta = 1), cluster
means mu_k ~ N(0, I_16), points x_i ~ N(mu_{z_i}, 1/4 I_16).

Feature modeling: Paisley et al. stick-breaking for the Beta process,
truncated so remaining weights are negligible (< 1e-4 w.p. > 0.9999);
f_k ~ N(0, I_16), x_i ~ N(sum_k z_ik f_k, 1/4 I_16).

Appendix C.1: separable clusters — DP stick-breaking proportions, centers
mu_k = (2k, 0, ..., 0), points uniform in a ball of radius 1/2 (within-
cluster diameter <= 1 < between-cluster distance), matching Thm 3.3's
assumptions with lambda = 1.
"""
from __future__ import annotations

import numpy as np

__all__ = ["dp_stick_breaking_data", "bp_stick_breaking_data",
           "separable_cluster_data"]


def _dp_sticks_assign(rng: np.random.Generator, n: int, theta: float):
    """On-the-fly DP stick-breaking: break sticks as new clusters are needed."""
    weights: list[float] = []
    remaining = 1.0
    z = np.zeros(n, np.int64)
    u = rng.uniform(size=n)
    for i in range(n):
        # extend sticks until cumulative weight covers u[i]
        while u[i] > 1.0 - remaining:
            beta = rng.beta(1.0, theta)
            weights.append(remaining * beta)
            remaining *= 1.0 - beta
        c = np.searchsorted(np.cumsum(weights), u[i])
        z[i] = min(c, len(weights) - 1)
    return z, np.asarray(weights)


def dp_stick_breaking_data(n: int, dim: int = 16, theta: float = 1.0,
                           noise: float = 0.5, seed: int = 0):
    """Paper §4 clustering data.  noise=0.5 -> covariance (1/4) I."""
    rng = np.random.default_rng(seed)
    z, _ = _dp_sticks_assign(rng, n, theta)
    k = int(z.max()) + 1
    mus = rng.normal(size=(k, dim))
    x = mus[z] + noise * rng.normal(size=(n, dim))
    return x.astype(np.float32), z, mus.astype(np.float32)


def bp_stick_breaking_data(n: int, dim: int = 16, theta: float = 1.0,
                           noise: float = 0.5, seed: int = 0,
                           w_min: float = 1e-4, tail_prob: float = 1e-4):
    """Paper §4 feature data via Beta-process stick-breaking [20].

    Rounds of sticks: in round r, weights are products of r Beta(theta, 1)
    variables; truncate after enough rounds that remaining weights are
    < w_min with high probability (E[w_round_r] = (theta/(theta+1))^r).
    """
    rng = np.random.default_rng(seed)
    weights: list[float] = []
    v_prod = 1.0
    r = 0
    # (theta/(theta+1))^r < w_min * tail_prob  gives a conservative truncation
    while v_prod > w_min * tail_prob and r < 200:
        r += 1
        n_r = rng.poisson(theta)
        v = rng.beta(theta, 1.0, size=max(n_r, 0))
        v_prod *= (theta / (theta + 1.0))
        for vv in v:
            weights.append(float(np.prod(rng.beta(theta, 1.0, size=r))))
    w = np.clip(np.asarray(weights), 0.0, 1.0)
    w = w[w > w_min]
    if w.size == 0:
        w = np.asarray([0.5])
    k = w.size
    zmat = rng.uniform(size=(n, k)) < w[None, :]
    # every point should have at least one active feature for realism
    empty = ~zmat.any(axis=1)
    zmat[empty, rng.integers(0, k, size=int(empty.sum()))] = True
    feats = rng.normal(size=(k, dim))
    x = zmat.astype(np.float64) @ feats + noise * rng.normal(size=(n, dim))
    return x.astype(np.float32), zmat, feats.astype(np.float32)


def separable_cluster_data(n: int, dim: int = 16, theta: float = 1.0, seed: int = 0):
    """Appendix C.1 separable data: within-cluster diameter <= 1, between-
    cluster distance > 1; use with lambda = 1 for Thm 3.3's regime."""
    rng = np.random.default_rng(seed)
    z, _ = _dp_sticks_assign(rng, n, theta)
    k = int(z.max()) + 1
    mus = np.zeros((k, dim))
    mus[:, 0] = 2.0 * np.arange(k)
    # uniform in the ball of radius 1/2
    g = rng.normal(size=(n, dim))
    g /= np.linalg.norm(g, axis=1, keepdims=True)
    radii = 0.5 * rng.uniform(size=(n, 1)) ** (1.0 / dim)
    x = mus[z] + g * radii
    return x.astype(np.float32), z, mus.astype(np.float32)

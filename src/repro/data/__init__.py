from repro.data.synthetic import (
    dp_stick_breaking_data, bp_stick_breaking_data, separable_cluster_data,
)
from repro.data.tokens import TokenPipeline, synthetic_token_batches

"""The jitted train step: loss -> grads -> clip -> AdamW, with optional
microbatch gradient accumulation (lax.scan) and int8 cross-pod compression.

Microbatching serves two purposes: memory (activations live one microbatch
at a time) and overlap (XLA can schedule microbatch i+1's compute against
microbatch i's gradient reduce-scatter — we keep the loop collective-free
and let GSPMD place the reduction once, outside the scan).
"""
from __future__ import annotations

from functools import partial
from typing import Any, NamedTuple

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig, TrainConfig
from repro.distributed.shardings import current_ctx
from repro.optim.adamw import (
    AdamWState, adamw_init, adamw_update, clip_by_global_norm, cosine_lr,
)
from repro.optim.compression import EFState, ef_init, apply_error_feedback

__all__ = ["TrainState", "train_state_init", "make_train_step"]


class TrainState(NamedTuple):
    params: Any
    opt: AdamWState
    ef: Any          # EFState | () — error-feedback memory when compressing


def train_state_init(params, tcfg: TrainConfig) -> TrainState:
    ef = ef_init(params) if tcfg.compress_cross_pod else ()
    return TrainState(params=params, opt=adamw_init(params), ef=ef)


def make_train_step(model, tcfg: TrainConfig):
    """-> train_step(state, batch) -> (state, metrics).

    `batch` leaves have leading dim global_batch; with microbatches > 1 the
    batch splits into (n_micro, micro_batch, ...) and grads accumulate in a
    scan before the (single) optimizer update.
    """
    n_micro = max(1, tcfg.microbatches)

    def loss_fn(params, mb):
        return model.loss(params, mb)

    def grads_of(params, batch):
        if n_micro == 1:
            return jax.value_and_grad(loss_fn)(params, batch)
        split = jax.tree.map(
            lambda a: a.reshape((n_micro, a.shape[0] // n_micro) + a.shape[1:]),
            batch)

        def acc_step(carry, mb):
            tot_loss, acc = carry
            l, g = jax.value_and_grad(loss_fn)(params, mb)
            acc = jax.tree.map(lambda a, b: a + b.astype(jnp.float32), acc, g)
            return (tot_loss + l, acc), None

        zeros = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
        (tot, acc), _ = jax.lax.scan(acc_step, (jnp.zeros(()), zeros), split)
        return tot / n_micro, jax.tree.map(lambda g: g / n_micro, acc)

    def train_step(state: TrainState, batch):
        loss, grads = grads_of(state.params, batch)
        ef = state.ef
        if tcfg.compress_cross_pod:
            # quantize/dequantize with error feedback (the psum itself is
            # GSPMD-placed; the compressed-collective shard_map variant is in
            # optim.compression for explicit-pod-axis deployments)
            grads, ef = apply_error_feedback(grads, ef)
        grads, gnorm = clip_by_global_norm(grads, tcfg.grad_clip)
        lr = cosine_lr(state.opt.step, tcfg.learning_rate, tcfg.warmup_steps,
                       tcfg.total_steps)
        params, opt = adamw_update(
            state.params, grads, state.opt, lr,
            b1=tcfg.beta1, b2=tcfg.beta2, eps=tcfg.eps,
            weight_decay=tcfg.weight_decay)
        metrics = {"loss": loss, "grad_norm": gnorm, "lr": lr,
                   "step": opt.step}
        return TrainState(params, opt, ef), metrics

    return train_step

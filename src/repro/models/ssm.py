"""Mamba2-style SSD (state-space duality) block.

Simplified but faithful Mamba2: single B/C group shared across heads,
scalar A per head, depthwise causal conv on the x branch, gated RMSNorm
before the output projection.

Training/prefill uses the *chunked* SSD form: within a chunk of Q tokens the
recurrence is evaluated as a masked (Q x Q) matmul (MXU work, like
attention with a decay mask); across chunks a lax.scan carries the
(B, H, hd, N) state.  Decode is the O(1) recurrent update.

State cache for decode: {"conv": (B, w-1, d_inner), "ssm": (B, H, hd, N)}.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.distributed.shardings import constrain, res_constrain
from repro.models.layers import dense_init

__all__ = ["init_mamba", "mamba_train", "mamba_decode", "init_ssm_cache"]


def _dims(cfg):
    d_inner = cfg.ssm_expand * cfg.d_model
    n_heads = d_inner // cfg.ssm_head_dim
    return d_inner, n_heads, cfg.ssm_state


def init_mamba(key, cfg):
    d = cfg.d_model
    d_inner, h, n = _dims(cfg)
    dt = jnp.dtype(cfg.dtype)
    ks = jax.random.split(key, 5)
    return {
        # z (gate), x, B, C, dt  packed in one input projection
        "in_w": dense_init(ks[0], d, 2 * d_inner + 2 * n + h, dt),
        "conv_w": (jax.random.normal(ks[1], (cfg.conv_width, d_inner), jnp.float32)
                   * cfg.conv_width ** -0.5).astype(dt),
        "a_log": jnp.zeros((h,), jnp.float32),        # A = -exp(a_log)
        "dt_bias": jnp.zeros((h,), jnp.float32),
        "d_skip": jnp.ones((h,), jnp.float32),
        "gn": jnp.ones((d_inner,), dt),               # gated RMSNorm weight
        "out_w": dense_init(ks[4], d_inner, d, dt),
    }


def _split_in(p, x, cfg):
    d_inner, h, n = _dims(cfg)
    proj = x @ p["in_w"]
    z, xs, bb, cc, dt = jnp.split(
        proj, [d_inner, 2 * d_inner, 2 * d_inner + n, 2 * d_inner + 2 * n], -1)
    dt = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"])
    return z, xs, bb.astype(jnp.float32), cc.astype(jnp.float32), dt


def _conv_causal(xs, w, state=None):
    """Depthwise causal conv, width w.shape[0]; state (B, w-1, d_inner)."""
    width = w.shape[0]
    if state is None:
        pad = jnp.zeros(xs.shape[:1] + (width - 1,) + xs.shape[2:], xs.dtype)
    else:
        pad = state.astype(xs.dtype)
    xp = jnp.concatenate([pad, xs], axis=1)
    out = sum(xp[:, i:i + xs.shape[1]] * w[i][None, None, :].astype(xs.dtype)
              for i in range(width))
    new_state = xp[:, xs.shape[1]:]     # last width-1 inputs
    return jax.nn.silu(out.astype(jnp.float32)).astype(xs.dtype), new_state


def _gated_norm(y, z, gn, eps):
    yf = y.astype(jnp.float32) * jax.nn.silu(z.astype(jnp.float32))
    ms = jnp.mean(yf * yf, axis=-1, keepdims=True)
    return yf * jax.lax.rsqrt(ms + eps) * gn.astype(jnp.float32)


def mamba_train(p, x, cfg, batch_axes):
    """x (B,S,D) -> (B,S,D); chunked SSD scan.  Returns (out, final_cache)."""
    b, s, d = x.shape
    d_inner, h, n = _dims(cfg)
    hd = cfg.ssm_head_dim
    z, xs, bb, cc, dt = _split_in(p, x, cfg)
    xs = constrain(xs, batch_axes, None, "model")
    xs, conv_state = _conv_causal(xs, p["conv_w"])
    a = -jnp.exp(p["a_log"])                          # (H,) negative

    q = min(cfg.ssm_chunk, s)
    if s % q:
        q = s
    nc = s // q
    # Keep the big chunk operands in the compute dtype (bf16 on TPU) with
    # f32 accumulation inside the einsums — halves HBM traffic and the bytes
    # crossing TP collectives for their gradients (§Perf hillclimb C2).
    cdt = xs.dtype
    xh = xs.reshape(b, nc, q, h, hd)
    bbc = bb.reshape(b, nc, q, n).astype(cdt)
    ccc = cc.reshape(b, nc, q, n).astype(cdt)
    dtc = dt.reshape(b, nc, q, h)

    def chunk_fwd(state, xck, bk, ck, dk):
        # dk (dt) stays f32: it feeds exponentials
        la = dk * a[None, None, :]                     # (B,q,H) log-decay
        cum = jnp.cumsum(la, axis=1)                   # inclusive
        # intra-chunk: M[t,s] = exp(cum_t - cum_s) for s <= t
        mdiff = cum[:, :, None, :] - cum[:, None, :, :]        # (B,q,q,H)
        tri = jnp.tril(jnp.ones((q, q), bool))
        m = jnp.where(tri[None, :, :, None], jnp.exp(mdiff), 0.0)
        g = jnp.einsum("btn,bsn->bts", ck, bk,
                       preferred_element_type=jnp.float32)     # (B,q,q)
        w = g[..., None] * m * dk[:, None, :, :]               # (B,t,s,H) f32
        y_intra = jnp.einsum("btsh,bshd->bthd", w.astype(cdt), xck,
                             preferred_element_type=jnp.float32)
        # inter-chunk: y_inter[t] = exp(cum_t) * C_t . state
        dec_t = jnp.exp(cum)                                   # (B,q,H)
        y_inter = jnp.einsum("btn,bhdn,bth->bthd",
                             ck.astype(jnp.float32), state, dec_t)
        # state update: S' = exp(cum_end) S + sum_s exp(cum_end - cum_s) dt_s x_s B_s^T
        dec_end = jnp.exp(cum[:, -1:, :] - cum)                # (B,q,H)
        upd = jnp.einsum("bshd,bsn,bsh,bsh->bhdn",
                         xck.astype(jnp.float32), bk.astype(jnp.float32),
                         dk, dec_end)
        state = state * jnp.exp(cum[:, -1])[:, :, None, None] + upd
        return state, y_intra + y_inter

    if cfg.remat != "none":
        # flash-style: each chunk's backward recomputes its own (q,q,H)
        # decay/score tensors instead of keeping nc of them alive (C1).
        chunk_fwd = jax.checkpoint(chunk_fwd)

    def chunk(state, inp):
        xck, bk, ck, dk = inp                          # (B,q,H,hd),(B,q,N),(B,q,H)
        return chunk_fwd(state, xck, bk, ck, dk)

    state0 = jnp.zeros((b, h, hd, n), jnp.float32)
    state, ys = jax.lax.scan(
        chunk, state0,
        (xh.swapaxes(0, 1), bbc.swapaxes(0, 1), ccc.swapaxes(0, 1), dtc.swapaxes(0, 1)),
        unroll=True if cfg.unroll else 1)
    y = ys.swapaxes(0, 1).reshape(b, s, h, hd)
    y = y + xh.astype(jnp.float32).reshape(b, s, h, hd) \
        * p["d_skip"][None, None, :, None]
    y = _gated_norm(y.reshape(b, s, d_inner), z, p["gn"], cfg.norm_eps)
    out = y.astype(x.dtype) @ p["out_w"]
    cache = {"conv": conv_state, "ssm": state}
    return res_constrain(out, batch_axes), cache


def init_ssm_cache(cfg, batch: int):
    d_inner, h, n = _dims(cfg)
    return {"conv": jnp.zeros((batch, cfg.conv_width - 1, d_inner), jnp.dtype(cfg.dtype)),
            "ssm": jnp.zeros((batch, h, cfg.ssm_head_dim, n), jnp.float32)}


def mamba_decode(p, x, cfg, cache, batch_axes):
    """One-token recurrent update.  x (B,1,D)."""
    b = x.shape[0]
    d_inner, h, n = _dims(cfg)
    hd = cfg.ssm_head_dim
    z, xs, bb, cc, dt = _split_in(p, x, cfg)
    xs, conv_state = _conv_causal(xs, p["conv_w"], cache["conv"])
    a = -jnp.exp(p["a_log"])
    xh = xs.reshape(b, h, hd).astype(jnp.float32)
    dt1 = dt.reshape(b, h)
    da = jnp.exp(dt1 * a[None, :])                               # (B,H)
    upd = jnp.einsum("bhd,bn,bh->bhdn", xh, bb.reshape(b, n), dt1)
    state = cache["ssm"] * da[:, :, None, None] + upd
    y = jnp.einsum("bn,bhdn->bhd", cc.reshape(b, n), state)
    y = y + xh * p["d_skip"][None, :, None]
    y = _gated_norm(y.reshape(b, 1, d_inner), z, p["gn"], cfg.norm_eps)
    out = y.astype(x.dtype) @ p["out_w"]
    return res_constrain(out, batch_axes), {"conv": conv_state, "ssm": state}

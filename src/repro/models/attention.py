"""GQA attention: training/prefill (chunked or flash), decode (head-TP or
context-parallel), and cross-attention for encoder–decoder models.

Decode modes (DESIGN.md §5):
  tp — KV cache sharded on the kv-head dim when divisible by the model axis,
       replicated otherwise; each device attends over the full sequence.
  cp — context-parallel: KV cache sharded on the *sequence* dim over the
       model axis (shard_map); each device computes a partial softmax over
       its shard and the results psum-combine (distributed flash-decoding).
       This is the long-context path: cache memory and per-token bandwidth
       scale 1/|model| and only O(B*H*hd) bytes cross the ICI per step.
"""
from __future__ import annotations

import math
from functools import partial

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.distributed.shardings import (
    constrain, current_ctx, batch_spec, axes_that_divide, res_constrain)
from repro.kernels import ops
from repro.models.layers import apply_rope, dense_init, rope_freqs

__all__ = ["init_attention", "attention_train", "attention_decode",
           "init_kv_cache", "cross_attention", "encode_kv"]

NEG_INF = -1e30


def init_attention(key, cfg, cross: bool = False):
    d, h, hkv, hd = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.hd
    ks = jax.random.split(key, 4)
    dt = jnp.dtype(cfg.dtype)
    pre = "cross_" if cross else ""
    p = {
        pre + "wq": dense_init(ks[0], d, h * hd, dt),
        pre + "wk": dense_init(ks[1], d, hkv * hd, dt),
        pre + "wv": dense_init(ks[2], d, hkv * hd, dt),
        pre + "wo": dense_init(ks[3], h * hd, d, dt, scale=(h * hd) ** -0.5),
    }
    if cfg.qk_norm and not cross:
        p["qn"] = jnp.ones((hd,), dt)
        p["kn"] = jnp.ones((hd,), dt)
    return p


def _qk_norm(x, w, eps):
    xf = x.astype(jnp.float32)
    ms = jnp.mean(xf * xf, axis=-1, keepdims=True)
    return (xf * jax.lax.rsqrt(ms + eps) * w.astype(jnp.float32)).astype(x.dtype)


def _project_qkv(p, x, cfg, positions, pre=""):
    """x (B,S,D) -> q (B,S,H,hd), k,v (B,S,Hkv,hd), roped + qk-normed."""
    b, s, _ = x.shape
    h, hkv, hd = cfg.n_heads, cfg.n_kv_heads, cfg.hd
    ba = batch_spec(b)
    q = (x @ p[pre + "wq"]).reshape(b, s, h, hd)
    k = (x @ p[pre + "wk"]).reshape(b, s, hkv, hd)
    v = (x @ p[pre + "wv"]).reshape(b, s, hkv, hd)
    q = constrain(q, ba, None, "model", None)
    k = constrain(k, ba, None, "model", None)
    v = constrain(v, ba, None, "model", None)
    if cfg.qk_norm and not pre:
        q = _qk_norm(q, p["qn"], cfg.norm_eps)
        k = _qk_norm(k, p["kn"], cfg.norm_eps)
    if not pre:   # self-attention: RoPE
        cos, sin = rope_freqs(positions, hd, cfg.rope_theta)
        q = apply_rope(q, cos, sin)
        k = apply_rope(k, cos, sin)
    return q, k, v


def _gqa_logits(q, k, scale):
    """q (B,c,H,hd), k (B,S,Hkv,hd) -> logits (B,Hkv,g,c,S) in f32."""
    b, c, h, hd = q.shape
    hkv = k.shape[2]
    g = h // hkv
    qg = q.reshape(b, c, hkv, g, hd)
    return jnp.einsum("bchgd,bshd->bhgcs", qg.astype(jnp.float32),
                      k.astype(jnp.float32)) * scale


def _gqa_out(w, v):
    """w (B,Hkv,g,c,S), v (B,S,Hkv,hd) -> (B,c,H,hd)."""
    b, hkv, g, c, s = w.shape
    out = jnp.einsum("bhgcs,bshd->bchgd", w, v.astype(jnp.float32))
    return out.reshape(b, c, hkv * g, -1)


def _chunked_causal_attention(q, k, v, cfg, q_offset=0):
    """Memory-bounded causal attention: scan over query chunks.

    Peak logits memory is (B, Hkv, g, chunk, S) f32 instead of (.., S, S).
    On TPU, cfg.attn_impl == "flash" routes to the Pallas kernel instead.
    """
    b, s, h, hd = q.shape
    scale = hd ** -0.5
    c = min(cfg.attn_chunk, s)
    if s % c:
        c = s
    n = s // c
    k_pos = jnp.arange(k.shape[1])

    qs = q.reshape(b, n, c, h, hd).swapaxes(0, 1)   # (n, B, c, H, hd)

    def chunk_fwd(i, qc):
        logits = _gqa_logits(qc, k, scale)          # (B,Hkv,g,c,S)
        q_pos = q_offset + i * c + jnp.arange(c)
        mask = k_pos[None, :] <= q_pos[:, None]
        logits = jnp.where(mask[None, None, None], logits, NEG_INF)
        w = jax.nn.softmax(logits, axis=-1)
        return _gqa_out(w, v)

    if cfg.remat != "none":
        # flash-style backward: never keep (c, S) softmax weights across
        # chunks — each chunk's backward recomputes its own logits.
        chunk_fwd = jax.checkpoint(chunk_fwd)

    def chunk(carry, inp):
        i, qc = inp
        return carry, chunk_fwd(i, qc)

    _, outs = jax.lax.scan(chunk, 0, (jnp.arange(n), qs),
                           unroll=True if cfg.unroll else 1)
    return outs.swapaxes(0, 1).reshape(b, s, h, hd).astype(q.dtype)


def attention_train(p, x, cfg, positions):
    """Full-sequence causal self-attention (train / prefill).

    Returns (out (B,S,D), kv) — kv is the prefill cache contribution.
    """
    b, s, _ = x.shape
    ba = batch_spec(b)
    q, k, v = _project_qkv(p, x, cfg, positions)
    if cfg.attn_impl == "flash" and ops.on_tpu():
        o = ops.flash_attention(q.swapaxes(1, 2), k.swapaxes(1, 2),
                                v.swapaxes(1, 2), causal=True)
        o = o.swapaxes(1, 2)
    else:
        o = _chunked_causal_attention(q, k, v, cfg)
    o = constrain(o, ba, None, "model", None)
    out = o.reshape(b, s, -1) @ p["wo"]
    return res_constrain(out, ba), (k, v)


# ---------------------------------------------------------------------------
# Decode
# ---------------------------------------------------------------------------

def init_kv_cache(cfg, batch: int, cache_len: int, dtype=None):
    """One layer's KV cache buffers (B, S, Hkv, hd)."""
    dt = jnp.dtype(dtype or cfg.dtype)
    shape = (batch, cache_len, cfg.n_kv_heads, cfg.hd)
    return {"k": jnp.zeros(shape, dt), "v": jnp.zeros(shape, dt)}


def _update_cache(cache_arr, new, pos):
    """Write new (B,1,Hkv,hd) at per-example positions pos (B,)."""
    def upd1(c, n, p):
        return jax.lax.dynamic_update_slice(c, n.astype(c.dtype), (p, 0, 0))
    return jax.vmap(upd1)(cache_arr, new, pos)


def _decode_attend(q, ck, cv, pos, scale):
    """q (B,1,H,hd); ck/cv (B,S,Hkv,hd); mask k_pos <= pos[b]."""
    logits = _gqa_logits(q, ck, scale)                     # (B,Hkv,g,1,S)
    k_pos = jnp.arange(ck.shape[1])
    mask = k_pos[None, :] <= pos[:, None]                  # (B,S)
    logits = jnp.where(mask[:, None, None, None, :], logits, NEG_INF)
    w = jax.nn.softmax(logits, axis=-1)
    return _gqa_out(w, cv)                                 # (B,1,H,hd) f32


def attention_decode(p, x, cfg, cache, pos, mode: str = "tp"):
    """One-token decode step.  x (B,1,D), pos (B,) current positions.

    Returns (out (B,1,D), updated cache).
    """
    b = x.shape[0]
    ba = batch_spec(b)
    q, k_new, v_new = _project_qkv(p, x, cfg, pos[:, None].astype(jnp.float32))
    scale = cfg.hd ** -0.5
    ctx = current_ctx()
    use_cp = (mode == "cp" and ctx.mesh is not None
              and ctx.axis_size(ctx.model_axis) > 1
              and cache["k"].shape[1] % ctx.axis_size(ctx.model_axis) == 0)
    if use_cp:
        o, cache = _cp_decode(q, k_new, v_new, cache, pos, cfg, scale)
    else:
        ck = _update_cache(cache["k"], k_new, pos)
        cv = _update_cache(cache["v"], v_new, pos)
        cache = {"k": ck, "v": cv}
        o = _decode_attend(q, ck, cv, pos, scale).astype(x.dtype)
    o = constrain(o, ba, None, "model", None)
    out = o.reshape(b, 1, -1) @ p["wo"]
    return res_constrain(out, ba), cache


def _cp_decode(q, k_new, v_new, cache, pos, cfg, scale):
    """Context-parallel decode: cache seq-sharded over the model axis.

    Each shard holds S/m cache slots; the owning shard writes the new KV;
    all shards compute partial (max, sum, weighted-V) statistics over their
    slots and combine with three psums — distributed flash-decoding.
    """
    ctx = current_ctx()
    mesh = ctx.mesh
    ax = ctx.model_axis
    bs = batch_spec(q.shape[0], ctx)   # tuple of axes or None

    def local(q, kn, vn, ck, cv, pos):
        i = jax.lax.axis_index(ax)
        s_loc = ck.shape[1]
        start = i * s_loc
        loc = pos - start
        in_rng = jnp.logical_and(loc >= 0, loc < s_loc)
        loc_c = jnp.clip(loc, 0, s_loc - 1)

        def upd1(c, n, p_, ok):
            upd = jax.lax.dynamic_update_slice(c, n.astype(c.dtype), (p_, 0, 0))
            return jnp.where(ok, upd, c)
        ck = jax.vmap(upd1)(ck, kn, loc_c, in_rng)
        cv = jax.vmap(upd1)(cv, vn, loc_c, in_rng)

        logits = _gqa_logits(q, ck, scale)                 # (B,Hkv,g,1,Sl)
        k_pos = start + jnp.arange(s_loc)
        mask = k_pos[None, :] <= pos[:, None]
        logits = jnp.where(mask[:, None, None, None, :], logits, NEG_INF)
        m_loc = jnp.max(logits, axis=-1)                   # (B,Hkv,g,1)
        m_glob = jax.lax.pmax(m_loc, ax)
        p_ = jnp.exp(logits - m_glob[..., None])
        l_loc = jnp.sum(p_, axis=-1)
        acc_loc = jnp.einsum("bhgcs,bshd->bhgcd", p_, cv.astype(jnp.float32))
        l_glob = jax.lax.psum(l_loc, ax)
        acc = jax.lax.psum(acc_loc, ax)
        o = acc / jnp.maximum(l_glob, 1e-30)[..., None]    # (B,Hkv,g,1,hd)
        b, hkv, g, c, hd = o.shape
        o = o.transpose(0, 3, 1, 2, 4).reshape(b, c, hkv * g, hd)
        return o, ck, cv

    from repro.distributed.shardings import compat_shard_map
    o, ck, cv = compat_shard_map(
        local, mesh=mesh,
        in_specs=(P(bs, None, None, None), P(bs, None, None, None),
                  P(bs, None, None, None), P(bs, ax, None, None),
                  P(bs, ax, None, None), P(bs)),
        out_specs=(P(bs, None, None, None), P(bs, ax, None, None),
                   P(bs, ax, None, None)),
    )(q, k_new, v_new, cache["k"], cache["v"], pos)
    return o.astype(q.dtype), {"k": ck, "v": cv}


# ---------------------------------------------------------------------------
# Cross attention (encoder-decoder)
# ---------------------------------------------------------------------------

def encode_kv(p, enc_out, cfg):
    """Project encoder output once into cross-attention KV (static cache)."""
    b, s, _ = enc_out.shape
    hkv, hd = cfg.n_kv_heads, cfg.hd
    k = (enc_out @ p["cross_wk"]).reshape(b, s, hkv, hd)
    v = (enc_out @ p["cross_wv"]).reshape(b, s, hkv, hd)
    ba = batch_spec(b)
    return {"k": constrain(k, ba, None, "model", None),
            "v": constrain(v, ba, None, "model", None)}


def cross_attention(p, x, cfg, cross_kv, enc_valid_len=None):
    """x (B,S,D) attends over encoder KV (no causal mask)."""
    b, s, _ = x.shape
    h, hkv, hd = cfg.n_heads, cfg.n_kv_heads, cfg.hd
    ba = batch_spec(b)
    q = (x @ p["cross_wq"]).reshape(b, s, h, hd)
    q = constrain(q, ba, None, "model", None)
    logits = _gqa_logits(q, cross_kv["k"], hd ** -0.5)
    if enc_valid_len is not None:
        k_pos = jnp.arange(cross_kv["k"].shape[1])
        logits = jnp.where((k_pos[None, :] < enc_valid_len[:, None])
                           [:, None, None, None, :], logits, NEG_INF)
    w = jax.nn.softmax(logits, axis=-1)
    o = _gqa_out(w, cross_kv["v"]).astype(x.dtype)
    o = constrain(o, ba, None, "model", None)
    return constrain(o.reshape(b, s, -1) @ p["cross_wo"], ba, None, None)

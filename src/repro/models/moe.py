"""Mixture-of-Experts FFN: top-k router + capacity-based dispatch (EP).

Implementations (cfg.moe.impl):
  capacity — MaxText/Mesh-TF-style dispatch/combine einsums with per-sequence
             groups and capacity C = ceil(S*k/E * cf); experts sharded over
             the model axis (EP), tokens over data.  GSPMD lowers the
             dispatch einsums to the EP collectives visible in the dry-run.
  dense    — every expert runs on every token, weighted by router probs
             (E/k x extra FLOPs; used as the drop-free oracle in tests).
  ragged   — sort-by-expert + lax.ragged_dot, drop-free and FLOP-minimal
             (the §Perf hillclimb lever for MoE cells).
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp

from repro.distributed.shardings import constrain, batch_spec, res_constrain
from repro.kernels import ops
from repro.models.layers import dense_init

__all__ = ["init_moe", "moe_apply"]


def init_moe(key, cfg):
    d, f = cfg.d_model, cfg.d_ff
    e = cfg.moe.n_experts
    dt = jnp.dtype(cfg.dtype)
    kr, kg, ku, kd = jax.random.split(key, 4)
    return {
        "router": dense_init(kr, d, e, jnp.float32),  # router kept f32
        "we_g": (jax.random.normal(kg, (e, d, f), jnp.float32) * d ** -0.5).astype(dt),
        "we_u": (jax.random.normal(ku, (e, d, f), jnp.float32) * d ** -0.5).astype(dt),
        "we_d": (jax.random.normal(kd, (e, f, d), jnp.float32) * f ** -0.5).astype(dt),
    }


def _router(p, x, cfg):
    """-> (topk_probs (B,S,k), topk_idx (B,S,k)) with renormalized gates."""
    logits = x.astype(jnp.float32) @ p["router"]
    probs = jax.nn.softmax(logits, axis=-1)
    top_p, top_i = jax.lax.top_k(probs, cfg.moe.top_k)
    top_p = top_p / jnp.maximum(jnp.sum(top_p, -1, keepdims=True), 1e-9)
    return top_p, top_i


def _expert_ffn(xe, p):
    """xe (..., E, C, D) grouped tokens -> SwiGLU expert FFN."""
    g = jnp.einsum("becd,edf->becf", xe, p["we_g"])
    u = jnp.einsum("becd,edf->becf", xe, p["we_u"])
    h = ops.swiglu(g, u, backend="ref")
    return jnp.einsum("becf,efd->becd", h, p["we_d"])


def moe_apply(p, x, cfg, batch_axes):
    impl = cfg.moe.impl
    if impl == "dense":
        return _moe_dense(p, x, cfg, batch_axes)
    if impl == "ragged":
        return _moe_ragged(p, x, cfg, batch_axes)
    if impl == "gather":
        return _moe_gather(p, x, cfg, batch_axes)
    if impl == "hybrid":
        return _moe_hybrid(p, x, cfg, batch_axes)
    return _moe_capacity(p, x, cfg, batch_axes)


def _moe_hybrid(p, x, cfg, batch_axes):
    """Gather dispatch (zero-FLOP) + einsum combine (§Perf B6).

    The scatter-add combine forces an f32 model-axis all-reduce of the
    (B,S,D) output; the einsum combine lets GSPMD all-gather the (much
    smaller) expert outputs instead, at the cost of re-introducing half of
    the dispatch-einsum FLOPs (2 T E C D)."""
    b, s, d = x.shape
    e, k = cfg.moe.n_experts, cfg.moe.top_k
    cap = min(int(math.ceil(s * k / e * cfg.moe.capacity_factor)), s)
    top_p, top_i = _router(p, x, cfg)
    src, hit, wslot = _capacity_slots(top_p, top_i, e, cap)

    xe = jnp.take_along_axis(x[:, None, :, :], src[..., None], axis=2)
    xe = xe * hit[..., None].astype(x.dtype)
    xe = constrain(xe, batch_axes, "model", None, None)
    ye = _expert_ffn(xe, p)
    ye = constrain(ye, batch_axes, "model", None, None)

    combine = (jax.nn.one_hot(src, s, dtype=jnp.float32)
               * (wslot * hit)[..., None]).astype(x.dtype)   # (B,E,C,S)
    out = jnp.einsum("becs,becd->bsd", combine,
                     ye.astype(x.dtype), preferred_element_type=jnp.float32)
    return res_constrain(out.astype(x.dtype), batch_axes)


def _capacity_slots(top_p, top_i, e: int, cap: int):
    """Shared slot assignment: for each (batch, expert, cap-slot) compute the
    source token index, validity, and gate weight.  Same drop semantics as
    the einsum dispatch (token order priority)."""
    b, s, k = top_i.shape
    src = jnp.zeros((b, e, cap), jnp.int32)
    hit = jnp.zeros((b, e, cap), bool)
    wslot = jnp.zeros((b, e, cap), jnp.float32)
    counts = jnp.zeros((b, e), jnp.int32)
    bidx = jnp.arange(b)[:, None, None]
    tok = jnp.broadcast_to(jnp.arange(s)[None, :, None], (b, s, 1))
    for j in range(k):
        m_j = jax.nn.one_hot(top_i[..., j], e, dtype=jnp.int32)       # (B,S,E)
        pos_j = jnp.cumsum(m_j, axis=1) - 1 + counts[:, None, :]
        keep = jnp.logical_and(m_j > 0, pos_j < cap)                  # (B,S,E)
        pos_c = jnp.where(keep, pos_j, cap)     # out-of-range -> dropped
        eidx = jnp.broadcast_to(jnp.arange(e)[None, None, :], keep.shape)
        src = src.at[bidx, eidx, pos_c].set(
            jnp.broadcast_to(tok, keep.shape), mode="drop")
        hit = hit.at[bidx, eidx, pos_c].set(True, mode="drop")
        wslot = wslot.at[bidx, eidx, pos_c].set(
            jnp.broadcast_to(top_p[..., j:j + 1], keep.shape), mode="drop")
        counts = counts + jnp.sum(m_j, axis=1)
    return src, hit, wslot


def _moe_gather(p, x, cfg, batch_axes):
    """Capacity-layout MoE with gather/scatter dispatch instead of the
    one-hot einsums (§Perf hillclimb: the dispatch/combine einsums cost
    2 x (2 T E C D) FLOPs — ~28% of this MoE block; a gather moves the same
    bytes with no MXU work)."""
    b, s, d = x.shape
    e, k = cfg.moe.n_experts, cfg.moe.top_k
    cap = min(int(math.ceil(s * k / e * cfg.moe.capacity_factor)), s)
    top_p, top_i = _router(p, x, cfg)
    src, hit, wslot = _capacity_slots(top_p, top_i, e, cap)

    xe = jnp.take_along_axis(x[:, None, :, :], src[..., None], axis=2)  # (B,E,C,D)
    xe = xe * hit[..., None].astype(x.dtype)
    xe = constrain(xe, batch_axes, "model", None, None)
    ye = _expert_ffn(xe, p)
    ye = constrain(ye, batch_axes, "model", None, None)

    # combine in the compute dtype: the scatter-add's model-axis psum then
    # moves bf16, not f32 (§Perf B5) — gate weights are <= 1 so bf16 is safe
    yw = (ye.astype(jnp.float32) * (wslot * hit)[..., None]).astype(x.dtype)
    out = jnp.zeros((b, s, d), x.dtype)
    bidx = jnp.arange(b)[:, None, None]
    out = out.at[bidx, src, :].add(yw, mode="drop")
    return res_constrain(out, batch_axes)


def _moe_dense(p, x, cfg, batch_axes):
    b, s, d = x.shape
    e, k = cfg.moe.n_experts, cfg.moe.top_k
    top_p, top_i = _router(p, x, cfg)
    gates = jnp.zeros((b, s, e), jnp.float32)
    gates = jax.vmap(lambda g, i, v: g.at[i].add(v), in_axes=(0, 0, 0))(
        gates.reshape(-1, e), top_i.reshape(-1, k), top_p.reshape(-1, k)
    ).reshape(b, s, e)
    g = jnp.einsum("bsd,edf->bsef", x, p["we_g"])
    u = jnp.einsum("bsd,edf->bsef", x, p["we_u"])
    h = ops.swiglu(g, u, backend="ref")
    y = jnp.einsum("bsef,efd->bsed", h, p["we_d"])
    out = jnp.einsum("bsed,bse->bsd", y.astype(jnp.float32), gates)
    return res_constrain(out.astype(x.dtype), batch_axes)


def _moe_capacity(p, x, cfg, batch_axes):
    """Dispatch/combine einsum MoE.  Each sequence is a routing group."""
    b, s, d = x.shape
    e, k = cfg.moe.n_experts, cfg.moe.top_k
    cap = int(math.ceil(s * k / e * cfg.moe.capacity_factor))
    cap = min(cap, s)
    top_p, top_i = _router(p, x, cfg)

    # Position of each (token, choice) within its expert's capacity buffer.
    combine = jnp.zeros((b, s, e, cap), jnp.float32)
    counts = jnp.zeros((b, e), jnp.int32)
    for j in range(k):
        m_j = jax.nn.one_hot(top_i[..., j], e, dtype=jnp.int32)        # (B,S,E)
        pos_j = jnp.cumsum(m_j, axis=1) - 1 + counts[:, None, :]       # (B,S,E)
        keep = jnp.logical_and(m_j > 0, pos_j < cap)
        pos_c = jnp.clip(pos_j, 0, cap - 1)
        oh = jax.nn.one_hot(pos_c, cap, dtype=jnp.float32) * keep[..., None]
        combine = combine + oh * top_p[..., j][..., None, None] * m_j[..., None]
        counts = counts + jnp.sum(m_j, axis=1)

    dispatch = (combine > 0).astype(x.dtype)                           # (B,S,E,C)
    combine = combine.astype(jnp.float32)
    dispatch = constrain(dispatch, batch_axes, None, "model", None)
    xe = jnp.einsum("bsd,bsec->becd", x, dispatch)                     # (B,E,C,D)
    xe = constrain(xe, batch_axes, "model", None, None)
    ye = _expert_ffn(xe, p)
    ye = constrain(ye, batch_axes, "model", None, None)
    out = jnp.einsum("becd,bsec->bsd", ye.astype(jnp.float32), combine)
    return res_constrain(out.astype(x.dtype), batch_axes)


def _moe_ragged(p, x, cfg, batch_axes):
    """Sort-by-expert + ragged_dot: drop-free, FLOP-minimal dispatch."""
    b, s, d = x.shape
    e, k = cfg.moe.n_experts, cfg.moe.top_k
    top_p, top_i = _router(p, x, cfg)
    t = b * s
    xf = x.reshape(t, d)
    flat_e = top_i.reshape(t * k)                       # expert of each slot
    flat_w = top_p.reshape(t * k)
    flat_tok = jnp.repeat(jnp.arange(t), k)

    order = jnp.argsort(flat_e, stable=True)
    xe = xf[flat_tok[order]]                            # (T*k, D) sorted
    group_sizes = jnp.bincount(flat_e, length=e).astype(jnp.int32)

    g = jax.lax.ragged_dot(xe, p["we_g"], group_sizes)
    u = jax.lax.ragged_dot(xe, p["we_u"], group_sizes)
    h = ops.swiglu(g, u, backend="ref")
    y = jax.lax.ragged_dot(h, p["we_d"], group_sizes)   # (T*k, D)

    out = jnp.zeros((t, d), jnp.float32)
    out = out.at[flat_tok[order]].add(
        y.astype(jnp.float32) * flat_w[order][:, None])
    return constrain(out.reshape(b, s, d).astype(x.dtype), batch_axes, None, None)

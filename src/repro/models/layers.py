"""Shared neural-net layers (functional, pure-dict params)."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels import ops
from repro.distributed.shardings import constrain, res_constrain

__all__ = ["dense_init", "rmsnorm", "rope_freqs", "apply_rope", "mlp_init",
           "mlp_apply", "embed_init", "cross_entropy_chunked"]


def dense_init(key, d_in: int, d_out: int, dtype, scale: float | None = None):
    scale = scale if scale is not None else d_in ** -0.5
    return (jax.random.normal(key, (d_in, d_out), jnp.float32) * scale).astype(dtype)


def rmsnorm(x: jnp.ndarray, w: jnp.ndarray, eps: float = 1e-6) -> jnp.ndarray:
    # "ref" backend: differentiable everywhere (the fused Pallas kernel is
    # the inference-path option; see kernels/ops.py).
    return ops.rmsnorm(x, w, eps=eps, backend="ref")


def rope_freqs(positions: jnp.ndarray, head_dim: int, theta: float):
    """positions (...,) -> cos, sin of shape (..., head_dim//2), f32."""
    half = head_dim // 2
    inv = 1.0 / (theta ** (jnp.arange(half, dtype=jnp.float32) / half))
    ang = positions.astype(jnp.float32)[..., None] * inv
    return jnp.cos(ang), jnp.sin(ang)


def apply_rope(x: jnp.ndarray, cos: jnp.ndarray, sin: jnp.ndarray):
    """x (..., S, H, hd) with cos/sin (..., S, hd//2) — rotate-half convention."""
    half = x.shape[-1] // 2
    x1, x2 = x[..., :half], x[..., half:]
    c = cos[..., None, :]   # broadcast over head dim
    s = sin[..., None, :]
    xf1, xf2 = x1.astype(jnp.float32), x2.astype(jnp.float32)
    return jnp.concatenate([xf1 * c - xf2 * s, xf2 * c + xf1 * s], -1).astype(x.dtype)


def embed_init(key, vocab: int, d_model: int, dtype):
    return (jax.random.normal(key, (vocab, d_model), jnp.float32)
            * d_model ** -0.5).astype(dtype)


# ---------------------------------------------------------------------------
# SwiGLU MLP
# ---------------------------------------------------------------------------

def mlp_init(key, d_model: int, d_ff: int, dtype):
    kg, ku, kd = jax.random.split(key, 3)
    return {
        "wg": dense_init(kg, d_model, d_ff, dtype),
        "wu": dense_init(ku, d_model, d_ff, dtype),
        "wd": dense_init(kd, d_ff, d_model, dtype),
    }


def mlp_apply(p, x: jnp.ndarray, batch_axes) -> jnp.ndarray:
    g = x @ p["wg"]
    u = x @ p["wu"]
    g = constrain(g, batch_axes, None, "model")
    u = constrain(u, batch_axes, None, "model")
    h = ops.swiglu(g, u, backend="ref")
    out = h @ p["wd"]
    return res_constrain(out, batch_axes)


# ---------------------------------------------------------------------------
# Vocab-chunked cross entropy: never materializes (B, S, V) logits.
# ---------------------------------------------------------------------------

def cross_entropy_chunked(h: jnp.ndarray, lm_head: jnp.ndarray,
                          labels: jnp.ndarray, batch_axes,
                          seq_chunk: int = 512, unroll: bool = False) -> jnp.ndarray:
    """Mean next-token CE.  h (B,S,D), lm_head (D,V), labels (B,S).

    Scans over sequence chunks so peak logits memory is (B, chunk, V_shard);
    the vocab dim is model-sharded, so the logsumexp reduction carries one
    small all-reduce per chunk instead of an all-gather of full logits.
    """
    b, s, d = h.shape
    v = lm_head.shape[1]
    c = min(seq_chunk, s)
    n_chunks = s // c if s % c == 0 else 1
    if s % c != 0:
        c = s
        n_chunks = 1
    hc = h.reshape(b, n_chunks, c, d).swapaxes(0, 1)        # (n, B, c, D)
    lc = labels.reshape(b, n_chunks, c).swapaxes(0, 1)      # (n, B, c)

    @jax.checkpoint   # backward recomputes the (B,c,V) logits per chunk
    def chunk_ce(hx, lx):
        logits = (hx.astype(jnp.float32) @ lm_head.astype(jnp.float32))
        logits = constrain(logits, batch_axes, None, "model")
        lse = jax.nn.logsumexp(logits, axis=-1)
        gold = jnp.take_along_axis(logits, lx[..., None], axis=-1)[..., 0]
        return jnp.sum(lse - gold)

    def chunk_loss(carry, inp):
        hx, lx = inp
        return carry + chunk_ce(hx, lx), None

    total, _ = jax.lax.scan(chunk_loss, jnp.zeros((), jnp.float32), (hc, lc),
                            unroll=True if unroll else 1)
    return total / (b * s)

"""xLSTM blocks: mLSTM (matrix memory, chunkwise-parallel) and sLSTM
(scalar memory, sequential scan).

mLSTM training uses the chunkwise form: within a chunk the recurrence is a
decay-masked (q x q) matmul (like attention); across chunks a scan carries
the matrix state C (B, H, hd, hd) and normalizer n (B, H, hd).  Row-local
max stabilization keeps the exponentials in f32 range; the stabilizer
cancels between numerator and normalizer, so the math is exact.

sLSTM has a true hidden-to-hidden nonlinear recurrence (block-diagonal per
head) and cannot be parallelized over time; it runs as a lax.scan over
steps.  This is an architectural property, not an implementation choice —
see DESIGN.md.

Decode for both is the O(1) recurrent update.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.distributed.shardings import constrain, res_constrain
from repro.models.layers import dense_init

__all__ = ["init_mlstm", "mlstm_train", "mlstm_decode", "init_mlstm_cache",
           "init_slstm", "slstm_train", "slstm_decode", "init_slstm_cache"]


# ---------------------------------------------------------------------------
# mLSTM
# ---------------------------------------------------------------------------

def init_mlstm(key, cfg):
    d, h = cfg.d_model, cfg.n_heads
    hd = d // h
    dt = jnp.dtype(cfg.dtype)
    ks = jax.random.split(key, 7)
    return {
        "wq": dense_init(ks[0], d, d, dt),
        "wk": dense_init(ks[1], d, d, dt),
        "wv": dense_init(ks[2], d, d, dt),
        "ig_w": dense_init(ks[3], d, h, dt, scale=0.01),
        "fg_w": dense_init(ks[4], d, h, dt, scale=0.01),
        "og_w": dense_init(ks[5], d, d, dt),
        "wo": dense_init(ks[6], d, d, dt),
    }


def _mlstm_qkv(p, x, cfg):
    b, s, d = x.shape
    h = cfg.n_heads
    hd = d // h
    q = (x @ p["wq"]).reshape(b, s, h, hd)
    k = (x @ p["wk"]).reshape(b, s, h, hd) * hd ** -0.5
    v = (x @ p["wv"]).reshape(b, s, h, hd)
    it = (x @ p["ig_w"]).astype(jnp.float32)                  # (B,S,H) input gate
    ft = jax.nn.log_sigmoid((x @ p["fg_w"]).astype(jnp.float32) + 3.0)  # log f
    o = jax.nn.sigmoid((x @ p["og_w"]).astype(jnp.float32))   # (B,S,D)
    return q, k, v, it, ft, o


def mlstm_train(p, x, cfg, batch_axes):
    b, s, d = x.shape
    h = cfg.n_heads
    hd = d // h
    q, k, v, it, ft, o = _mlstm_qkv(p, x, cfg)
    qf = q.astype(jnp.float32)
    kf = k.astype(jnp.float32)
    vf = v.astype(jnp.float32)

    cl = min(cfg.ssm_chunk, s)
    if s % cl:
        cl = s
    nc = s // cl

    def rs(a):
        return a.reshape((b, nc, cl) + a.shape[2:]).swapaxes(0, 1)

    def chunk(carry, inp):
        c_st, n_st = carry                        # (B,H,hd,hd), (B,H,hd)
        qc, kc, vc, ic, fc = inp                  # (B,cl,H,*)
        cf = jnp.cumsum(fc, axis=1)               # (B,cl,H) inclusive log decay
        # l[t,s] = cf_t - cf_s + i_s  for s <= t ; inter exponent = cf_t
        lmat = cf[:, :, None, :] - cf[:, None, :, :] + ic[:, None, :, :]
        tri = jnp.tril(jnp.ones((cl, cl), bool))
        lmat = jnp.where(tri[None, :, :, None], lmat, -jnp.inf)
        m_row = jnp.maximum(jnp.max(lmat, axis=2), cf)        # (B,cl,H)
        dmat = jnp.exp(lmat - m_row[:, :, None, :])
        g = jnp.einsum("bthd,bshd->bhts", qc, kc)             # (B,H,t,s)
        w = g * dmat.transpose(0, 3, 1, 2)                    # (B,H,t,s)
        y_num = jnp.einsum("bhts,bshd->bthd", w, vc)
        n_num = jnp.einsum("bshd,btsh->bthd", kc, dmat)       # sum_s exp(l) k_s
        inter_scale = jnp.exp(cf - m_row)                     # (B,cl,H)
        y_num = y_num + jnp.einsum("bthd,bhde,bth->bthe", qc, c_st, inter_scale)
        n_num = n_num + n_st[:, None] * inter_scale[..., None]
        denom = jnp.abs(jnp.einsum("bthd,bthd->bth", n_num, qc))
        denom = jnp.maximum(denom, jnp.exp(-m_row))
        y = y_num / denom[..., None]
        # state update (scaled back to absolute units)
        dec_end = jnp.exp(cf[:, -1:, :] - cf + ic)            # (B,cl,H)
        c_st = c_st * jnp.exp(cf[:, -1])[:, :, None, None] \
            + jnp.einsum("bshd,bshe,bsh->bhde", kc, vc, dec_end)
        n_st = n_st * jnp.exp(cf[:, -1])[..., None] + \
            jnp.einsum("bshd,bsh->bhd", kc, dec_end)
        return (c_st, n_st), y

    c0 = jnp.zeros((b, h, hd, hd), jnp.float32)
    n0 = jnp.zeros((b, h, hd), jnp.float32)
    (c_st, n_st), ys = jax.lax.scan(
        chunk, (c0, n0), (rs(qf), rs(kf), rs(vf), rs(it), rs(ft)),
        unroll=True if cfg.unroll else 1)
    y = ys.swapaxes(0, 1).reshape(b, s, d)
    y = (y * o).astype(x.dtype) @ p["wo"]
    cache = {"c": c_st, "n": n_st}
    return res_constrain(y, batch_axes), cache


def init_mlstm_cache(cfg, batch: int):
    h = cfg.n_heads
    hd = cfg.d_model // h
    return {"c": jnp.zeros((batch, h, hd, hd), jnp.float32),
            "n": jnp.zeros((batch, h, hd), jnp.float32)}


def mlstm_decode(p, x, cfg, cache, batch_axes):
    b = x.shape[0]
    h = cfg.n_heads
    hd = cfg.d_model // h
    q, k, v, it, ft, o = _mlstm_qkv(p, x, cfg)
    qf, kf, vf = (a[:, 0].astype(jnp.float32) for a in (q, k, v))
    i1, f1 = it[:, 0], ft[:, 0]                   # (B,H)
    fdec = jnp.exp(f1)[:, :, None, None]
    iexp = jnp.exp(i1)[:, :, None, None]
    c = cache["c"] * fdec + iexp * jnp.einsum("bhd,bhe->bhde", kf, vf)
    n = cache["n"] * jnp.exp(f1)[..., None] + jnp.exp(i1)[..., None] * kf
    y = jnp.einsum("bhd,bhde->bhe", qf, c)
    denom = jnp.maximum(jnp.abs(jnp.einsum("bhd,bhd->bh", n, qf)), 1.0)
    y = (y / denom[..., None]).reshape(b, 1, -1)
    y = (y * o).astype(x.dtype) @ p["wo"]
    return res_constrain(y, batch_axes), {"c": c, "n": n}


# ---------------------------------------------------------------------------
# sLSTM
# ---------------------------------------------------------------------------

def init_slstm(key, cfg):
    d, h = cfg.d_model, cfg.n_heads
    hd = d // h
    dt = jnp.dtype(cfg.dtype)
    ks = jax.random.split(key, 9)
    p = {
        "zg_w": dense_init(ks[0], d, d, dt),
        "ig_w": dense_init(ks[1], d, h, dt, scale=0.01),
        "fg_w": dense_init(ks[2], d, h, dt, scale=0.01),
        "og_w": dense_init(ks[3], d, d, dt),
        "wo": dense_init(ks[8], d, d, dt),
    }
    for i, nm in enumerate(["zg_r", "ig_r", "fg_r", "og_r"]):
        out_d = hd if nm in ("zg_r", "og_r") else 1
        p[nm] = (jax.random.normal(ks[4 + i], (h, hd, out_d), jnp.float32)
                 * hd ** -0.5).astype(dt)
    return p


def init_slstm_cache(cfg, batch: int):
    h = cfg.n_heads
    hd = cfg.d_model // h
    z = lambda *s: jnp.zeros(s, jnp.float32)
    return {"c": z(batch, h, hd), "n": z(batch, h, hd),
            "h": z(batch, h, hd), "m": z(batch, h)}


def _slstm_proj(p, x, cfg):
    """Hoisted input projections: one batched matmul per gate for the whole
    sequence (the recurrence itself is inherently sequential, the input
    side is not)."""
    b = x.shape[0]
    h = cfg.n_heads
    hd = cfg.d_model // h
    xf = x.astype(jnp.float32)
    xz = (xf @ p["zg_w"].astype(jnp.float32)).reshape(*x.shape[:-1], h, hd)
    xo = (xf @ p["og_w"].astype(jnp.float32)).reshape(*x.shape[:-1], h, hd)
    xi = xf @ p["ig_w"].astype(jnp.float32)           # (..., H)
    xft = xf @ p["fg_w"].astype(jnp.float32)
    return xz, xo, xi, xft


def _slstm_recur(p, cfg, proj_t, st):
    """One recurrent step; proj_t = per-step projected inputs."""
    xz, xo, xi, xft = proj_t
    hprev = st["h"].astype(jnp.float32)            # (B,H,hd)
    rz = jnp.einsum("bhd,hde->bhe", hprev, p["zg_r"].astype(jnp.float32))
    ro = jnp.einsum("bhd,hde->bhe", hprev, p["og_r"].astype(jnp.float32))
    ri = jnp.einsum("bhd,hd->bh", hprev, p["ig_r"].astype(jnp.float32)[..., 0])
    rf = jnp.einsum("bhd,hd->bh", hprev, p["fg_r"].astype(jnp.float32)[..., 0])
    z = jnp.tanh(xz + rz)
    og = jax.nn.sigmoid(xo + ro)
    it = xi + ri                                    # (B,H)
    ft = jax.nn.log_sigmoid(xft + rf + 3.0)
    m_new = jnp.maximum(ft + st["m"], it)
    i_s = jnp.exp(it - m_new)[..., None]
    f_s = jnp.exp(ft + st["m"] - m_new)[..., None]
    c = f_s * st["c"] + i_s * z
    n = f_s * st["n"] + i_s
    hy = og * (c / jnp.maximum(n, 1e-6))
    return {"c": c, "n": n, "h": hy, "m": m_new}, hy


def slstm_train(p, x, cfg, batch_axes):
    b, s, d = x.shape
    st0 = init_slstm_cache(cfg, b)
    xz, xo, xi, xft = _slstm_proj(p, x, cfg)

    def step(st, proj_t):
        return _slstm_recur(p, cfg, proj_t, st)

    st, hs = jax.lax.scan(
        step, st0,
        (xz.swapaxes(0, 1), xo.swapaxes(0, 1),
         xi.swapaxes(0, 1), xft.swapaxes(0, 1)))
    y = hs.swapaxes(0, 1).reshape(b, s, d).astype(x.dtype) @ p["wo"]
    return res_constrain(y, batch_axes), st


def slstm_decode(p, x, cfg, cache, batch_axes):
    xz, xo, xi, xft = _slstm_proj(p, x[:, 0], cfg)
    st, hy = _slstm_recur(p, cfg, (xz, xo, xi, xft), cache)
    y = hy.reshape(x.shape[0], 1, -1).astype(x.dtype) @ p["wo"]
    return res_constrain(y, batch_axes), st

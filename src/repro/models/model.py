"""Model assembly: init / loss / prefill / decode_step for every family.

Parameter tree:
  {"tok_embed": (V,D), "final_norm": (D,), "lm_head": (D,V),
   "segments": {"seg_00": stacked-params, ...},     # scan stacks
   "shared": {...} | absent,                        # zamba2 shared attn block
   "frontend": {...} | absent,                      # vlm / audio projector stub
   "encoder": {"segments": {...}, "norm": (D,)} | absent}

Caches for decode are pytrees mirroring the segment structure:
  {"seg_00": stacked cache, ..., "cross": {...} for enc-dec}
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.distributed.shardings import constrain, batch_spec, res_constrain
from repro.models import attention as attn_mod
from repro.models.frontend import init_frontend, frontend_project
from repro.models.layers import cross_entropy_chunked, embed_init, rmsnorm
from repro.models.transformer import (
    init_block, init_block_cache, run_stack_decode, run_stack_train,
    segments_for,
)

__all__ = ["Model", "build_model"]


def _seg_key(i: int) -> str:
    return f"seg_{i:02d}"


@dataclass(frozen=True)
class Model:
    cfg: ArchConfig

    # ------------------------------------------------------------------ init
    def init(self, key: jax.Array) -> dict:
        cfg = self.cfg
        dt = jnp.dtype(cfg.dtype)
        keys = jax.random.split(key, 8)
        params: dict[str, Any] = {
            "tok_embed": embed_init(keys[0], cfg.vocab, cfg.d_model, dt),
            "final_norm": jnp.ones((cfg.d_model,), dt),
        }
        if not cfg.tie_embeddings:
            params["lm_head"] = embed_init(keys[1], cfg.vocab, cfg.d_model, dt).T

        segs = segments_for(cfg)
        seg_params: dict[str, Any] = {}
        shared_params = None
        skey = jax.random.split(keys[2], len(segs) + 1)
        for i, (kind, count, shared) in enumerate(segs):
            if shared:
                if shared_params is None:
                    shared_params = init_block(skey[i], cfg, kind)
                continue
            if count == 1:
                seg_params[_seg_key(i)] = init_block(skey[i], cfg, kind)
            else:
                lkeys = jax.random.split(skey[i], count)
                seg_params[_seg_key(i)] = jax.vmap(
                    lambda k: init_block(k, cfg, kind))(lkeys)
        params["segments"] = seg_params
        if shared_params is not None:
            params["shared"] = shared_params
        if cfg.frontend:
            params["frontend"] = init_frontend(keys[3], cfg)
        if cfg.is_encdec:
            ekeys = jax.random.split(keys[4], cfg.enc_layers)
            params["encoder"] = {
                "segments": jax.vmap(
                    lambda k: init_block(k, cfg, "enc_attn_mlp"))(ekeys),
                "norm": jnp.ones((cfg.d_model,), dt),
            }
        return params

    # --------------------------------------------------------------- helpers
    def _embed(self, params, batch):
        """-> (x (B,S,D), n_prefix) with modality prefix if present."""
        cfg = self.cfg
        toks = batch["tokens"]
        x = jnp.take(params["tok_embed"], toks, axis=0)
        n_prefix = 0
        if cfg.frontend and not cfg.is_encdec:     # vlm: prefix tokens
            pre = frontend_project(params["frontend"], batch["frontend"], cfg)
            pre = rmsnorm(pre, params["frontend"]["fe_norm"], cfg.norm_eps)
            x = jnp.concatenate([pre.astype(x.dtype), x], axis=1)
            n_prefix = pre.shape[1]
        b = x.shape[0]
        return res_constrain(x, batch_spec(b)), n_prefix

    def _encode(self, params, batch):
        """Audio enc-dec: run the (stub-fed) encoder -> enc_out (B,F,D)."""
        cfg = self.cfg
        enc_x = frontend_project(params["frontend"], batch["frontend"], cfg)
        enc_x = rmsnorm(enc_x, params["frontend"]["fe_norm"], cfg.norm_eps)
        positions = jnp.arange(enc_x.shape[1], dtype=jnp.float32)
        enc_x, _ = run_stack_train(params["encoder"]["segments"], enc_x, cfg,
                                   "enc_attn_mlp", positions,
                                   cfg.enc_layers, shared=False)
        return rmsnorm(enc_x, params["encoder"]["norm"], cfg.norm_eps)

    def _body_train(self, params, x, positions, enc_out=None,
                    want_cache: bool = False):
        cfg = self.cfg
        segs = segments_for(cfg)
        caches = {}
        for i, (kind, count, shared) in enumerate(segs):
            p_seg = params["shared"] if shared else params["segments"][_seg_key(i)]
            x, cache = run_stack_train(p_seg, x, cfg, kind, positions, count,
                                       shared, cross_kv=enc_out,
                                       want_cache=want_cache)
            if want_cache:
                caches[_seg_key(i)] = cache
        return x, caches

    def _lm_head(self, params):
        if self.cfg.tie_embeddings:
            return params["tok_embed"].T
        return params["lm_head"]

    # ------------------------------------------------------------------ loss
    def loss(self, params, batch) -> jnp.ndarray:
        cfg = self.cfg
        enc_out = self._encode(params, batch) if cfg.is_encdec else None
        x, n_prefix = self._embed(params, batch)
        positions = jnp.arange(x.shape[1], dtype=jnp.float32)
        x, _ = self._body_train(params, x, positions, enc_out)
        h = rmsnorm(x, params["final_norm"], cfg.norm_eps)
        if n_prefix:
            h = h[:, n_prefix:]
        b = h.shape[0]
        return cross_entropy_chunked(h, self._lm_head(params), batch["labels"],
                                     batch_spec(b), seq_chunk=cfg.attn_chunk,
                                     unroll=cfg.unroll)

    # --------------------------------------------------------------- prefill
    def prefill(self, params, batch):
        """Forward + caches; returns (last-token logits (B,V), caches)."""
        cfg = self.cfg
        enc_out = self._encode(params, batch) if cfg.is_encdec else None
        x, _ = self._embed(params, batch)
        positions = jnp.arange(x.shape[1], dtype=jnp.float32)
        x, caches = self._body_train(params, x, positions, enc_out,
                                     want_cache=True)
        h = rmsnorm(x[:, -1:], params["final_norm"], cfg.norm_eps)
        logits = (h @ self._lm_head(params))[:, 0]
        return logits.astype(jnp.float32), caches

    # ----------------------------------------------------------------- cache
    def init_cache(self, batch: int, cache_len: int):
        cfg = self.cfg
        segs = segments_for(cfg)
        caches: dict[str, Any] = {}
        for i, (kind, count, shared) in enumerate(segs):
            one = init_block_cache(cfg, kind, batch, cache_len)
            caches[_seg_key(i)] = jax.tree.map(
                lambda a: jnp.zeros((count,) + a.shape, a.dtype), one)
        return caches

    # ----------------------------------------------------------------- decode
    def decode_step(self, params, caches, tokens, pos, decode_mode: str = "tp"):
        """tokens (B,1) int32, pos (B,) int32 -> (logits (B,V) f32, caches)."""
        cfg = self.cfg
        x = jnp.take(params["tok_embed"], tokens, axis=0)
        b = x.shape[0]
        x = constrain(x, batch_spec(b), None, None)
        segs = segments_for(cfg)
        new_caches = dict(caches)
        for i, (kind, count, shared) in enumerate(segs):
            p_seg = params["shared"] if shared else params["segments"][_seg_key(i)]
            x, c_new = run_stack_decode(
                p_seg, x, cfg, kind, caches[_seg_key(i)], pos, count, shared,
                decode_mode=decode_mode)
            new_caches[_seg_key(i)] = c_new
        h = rmsnorm(x, params["final_norm"], cfg.norm_eps)
        logits = (h @ self._lm_head(params))[:, 0]
        return logits.astype(jnp.float32), new_caches

    # ------------------------------------------------------------- param count
    def param_count(self, params=None) -> int:
        import math
        if params is None:
            params = jax.eval_shape(self.init, jax.random.key(0))
        return sum(math.prod(a.shape) for a in jax.tree.leaves(params))


def build_model(cfg: ArchConfig) -> Model:
    return Model(cfg)

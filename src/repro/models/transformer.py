"""Transformer blocks and scan-over-layers stacks.

A model body is a list of *segments*; each segment is a homogeneous stack of
blocks whose parameters are stacked along a leading layer dim and executed
with lax.scan (keeps HLO size and compile time O(1) in depth — the MaxText
pattern).  Hybrid architectures (zamba2: Mamba2 + shared attention, xLSTM:
mLSTM + sLSTM) interleave segments; "shared" segments reuse one parameter
set at several depths (weights shared, per-application KV caches distinct).
"""
from __future__ import annotations

from functools import partial
from typing import Any, Callable

import jax
import jax.numpy as jnp

from repro.distributed.shardings import constrain, batch_spec, res_constrain
from repro.models import attention as attn
from repro.models import moe as moe_mod
from repro.models import ssm as ssm_mod
from repro.models import xlstm as xlstm_mod
from repro.models.layers import mlp_init, mlp_apply, rmsnorm

__all__ = ["SEGMENT_KINDS", "init_block", "block_train", "block_decode",
           "init_block_cache", "run_stack_train", "run_stack_decode",
           "segments_for"]


# ---------------------------------------------------------------------------
# Segment layout per architecture family
# ---------------------------------------------------------------------------

def segments_for(cfg) -> list[tuple[str, int, bool]]:
    """-> [(kind, count, shared_params)] executed in order."""
    fam = cfg.family
    if fam in ("dense", "vlm"):
        return [("attn_mlp", cfg.n_layers, False)]
    if fam in ("moe",):
        return [("attn_moe", cfg.n_layers, False)]
    if fam == "hybrid":
        segs: list[tuple[str, int, bool]] = []
        k = cfg.attn_every
        full, rem = divmod(cfg.n_layers, k)
        for _ in range(full):
            segs.append(("mamba", k, False))
            segs.append(("shared_attn", 1, True))
        if rem:
            segs.append(("mamba", rem, False))
        return segs
    if fam == "ssm" and cfg.slstm_every:
        segs = []
        k = cfg.slstm_every
        full, rem = divmod(cfg.n_layers, k)
        for _ in range(full):
            if k > 1:
                segs.append(("mlstm", k - 1, False))
            segs.append(("slstm", 1, False))
        if rem:
            segs.append(("mlstm", rem, False))
        return segs
    if fam == "ssm":
        return [("mamba", cfg.n_layers, False)]
    if fam == "audio":  # encoder-decoder handled by model.py with two bodies
        return [("dec_attn_mlp", cfg.n_layers, False)]
    raise ValueError(f"unknown family {fam}")


# ---------------------------------------------------------------------------
# Blocks
# ---------------------------------------------------------------------------

def init_block(key, cfg, kind: str):
    ks = jax.random.split(key, 4)
    dt = jnp.dtype(cfg.dtype)
    ones = lambda: jnp.ones((cfg.d_model,), dt)
    if kind in ("attn_mlp", "shared_attn", "enc_attn_mlp"):
        p = {"norm1": ones(), **attn.init_attention(ks[0], cfg)}
        if cfg.d_ff:
            p["norm2"] = ones()
            p.update(mlp_init(ks[1], cfg.d_model, cfg.d_ff, dt))
        return p
    if kind == "attn_moe":
        p = {"norm1": ones(), **attn.init_attention(ks[0], cfg),
             "norm2": ones(), **moe_mod.init_moe(ks[1], cfg)}
        return p
    if kind == "dec_attn_mlp":
        p = {"norm1": ones(), **attn.init_attention(ks[0], cfg),
             "norm_x": ones(), **attn.init_attention(ks[1], cfg, cross=True),
             "norm2": ones(), **mlp_init(ks[2], cfg.d_model, cfg.d_ff, dt)}
        return p
    if kind == "mamba":
        return {"norm1": ones(), **ssm_mod.init_mamba(ks[0], cfg)}
    if kind == "mlstm":
        return {"norm1": ones(), **xlstm_mod.init_mlstm(ks[0], cfg)}
    if kind == "slstm":
        return {"norm1": ones(), **xlstm_mod.init_slstm(ks[0], cfg)}
    raise ValueError(kind)


def block_train(p, x, cfg, kind: str, positions, cross_kv=None, causal=True):
    """-> (x, cache_contrib) — cache_contrib feeds prefill caches.

    For dec_attn_mlp, `cross_kv` is the *encoder output* (B,F,D); the block
    projects it with its own cross-attention weights and the projected KV
    joins the cache (static during decode).
    """
    ba = batch_spec(x.shape[0])
    eps = cfg.norm_eps
    if kind in ("attn_mlp", "attn_moe", "enc_attn_mlp", "dec_attn_mlp", "shared_attn"):
        h = rmsnorm(x, p["norm1"], eps)
        if kind == "enc_attn_mlp":
            # bidirectional encoder: full attention, no causal mask
            a, kv = _bidir_attention(p, h, cfg, positions)
        else:
            a, kv = attn.attention_train(p, h, cfg, positions)
        x = x + a
        cache: dict[str, Any] = {"k": kv[0], "v": kv[1]}
        if kind == "dec_attn_mlp":
            ckv = attn.encode_kv(p, cross_kv, cfg)
            hx = rmsnorm(x, p["norm_x"], eps)
            x = x + attn.cross_attention(p, hx, cfg, ckv)
            cache["ck"] = ckv["k"]
            cache["cv"] = ckv["v"]
        if "wg" in p:
            h2 = rmsnorm(x, p["norm2"], eps)
            x = x + mlp_apply(p, h2, ba)
        elif "router" in p:
            h2 = rmsnorm(x, p["norm2"], eps)
            x = x + moe_mod.moe_apply(p, h2, cfg, ba)
        return x, cache
    if kind == "mamba":
        h = rmsnorm(x, p["norm1"], eps)
        out, cache = ssm_mod.mamba_train(p, h, cfg, ba)
        return x + out, cache
    if kind == "mlstm":
        h = rmsnorm(x, p["norm1"], eps)
        out, cache = xlstm_mod.mlstm_train(p, h, cfg, ba)
        return x + out, cache
    if kind == "slstm":
        h = rmsnorm(x, p["norm1"], eps)
        out, cache = xlstm_mod.slstm_train(p, h, cfg, ba)
        return x + out, cache
    raise ValueError(kind)


def _bidir_attention(p, h, cfg, positions):
    """Encoder self-attention without the causal mask (chunk-free ref)."""
    b, s, _ = h.shape
    q, k, v = attn._project_qkv(p, h, cfg, positions)
    logits = attn._gqa_logits(q, k, cfg.hd ** -0.5)
    w = jax.nn.softmax(logits, axis=-1)
    o = attn._gqa_out(w, v).astype(h.dtype)
    ba = batch_spec(b)
    o = constrain(o, ba, None, "model", None)
    out = o.reshape(b, s, -1) @ p["wo"]
    return res_constrain(out, ba), (k, v)


def init_block_cache(cfg, kind: str, batch: int, cache_len: int,
                     enc_len: int = 0):
    if kind == "dec_attn_mlp":
        c = attn.init_kv_cache(cfg, batch, cache_len)
        cc = attn.init_kv_cache(cfg, batch, enc_len or cfg.frontend_len)
        c["ck"], c["cv"] = cc["k"], cc["v"]
        return c
    if kind in ("attn_mlp", "attn_moe", "shared_attn"):
        return attn.init_kv_cache(cfg, batch, cache_len)
    if kind == "mamba":
        return ssm_mod.init_ssm_cache(cfg, batch)
    if kind == "mlstm":
        return xlstm_mod.init_mlstm_cache(cfg, batch)
    if kind == "slstm":
        return xlstm_mod.init_slstm_cache(cfg, batch)
    raise ValueError(kind)


def block_decode(p, x, cfg, kind: str, cache, pos, cross_kv=None,
                 decode_mode: str = "tp"):
    ba = batch_spec(x.shape[0])
    eps = cfg.norm_eps
    if kind in ("attn_mlp", "attn_moe", "shared_attn", "dec_attn_mlp"):
        h = rmsnorm(x, p["norm1"], eps)
        self_cache = {"k": cache["k"], "v": cache["v"]}
        a, self_cache = attn.attention_decode(p, h, cfg, self_cache, pos,
                                              mode=decode_mode)
        cache = {**cache, **self_cache}
        x = x + a
        if kind == "dec_attn_mlp":
            hx = rmsnorm(x, p["norm_x"], eps)
            x = x + attn.cross_attention(p, hx, cfg,
                                         {"k": cache["ck"], "v": cache["cv"]})
        if "wg" in p:
            h2 = rmsnorm(x, p["norm2"], eps)
            x = x + mlp_apply(p, h2, ba)
        elif "router" in p:
            h2 = rmsnorm(x, p["norm2"], eps)
            x = x + moe_mod.moe_apply(p, h2, cfg, ba)
        return x, cache
    if kind == "mamba":
        h = rmsnorm(x, p["norm1"], eps)
        out, cache = ssm_mod.mamba_decode(p, h, cfg, cache, ba)
        return x + out, cache
    if kind == "mlstm":
        h = rmsnorm(x, p["norm1"], eps)
        out, cache = xlstm_mod.mlstm_decode(p, h, cfg, cache, ba)
        return x + out, cache
    if kind == "slstm":
        h = rmsnorm(x, p["norm1"], eps)
        out, cache = xlstm_mod.slstm_decode(p, h, cfg, cache, ba)
        return x + out, cache
    raise ValueError(kind)


# ---------------------------------------------------------------------------
# Stacks (scan over stacked layer params)
# ---------------------------------------------------------------------------

def _remat_wrap(fn, cfg):
    if cfg.remat == "none":
        return fn
    if cfg.remat == "dots":
        pol = jax.checkpoint_policies.dots_with_no_batch_dims_saveable
        return jax.checkpoint(fn, policy=pol)
    return jax.checkpoint(fn)


def run_stack_train(stack_p, x, cfg, kind: str, positions, count: int,
                    shared: bool, cross_kv=None, want_cache: bool = False):
    """Scan `count` blocks.  shared=True reuses one param set per step."""
    if shared or count == 1:
        p = stack_p
        fn = _remat_wrap(
            lambda xx: block_train(p, xx, cfg, kind, positions, cross_kv), cfg)
        outs = []
        for _ in range(count):
            x, cache = fn(x)
            outs.append(cache)
        cache = jax.tree.map(lambda *cs: jnp.stack(cs), *outs) if want_cache else None
        return x, cache

    def body(xx, p_l):
        out, cache = block_train(p_l, xx, cfg, kind, positions, cross_kv)
        return out, (cache if want_cache else 0)

    body = _remat_wrap(body, cfg)
    if cfg.unroll:
        outs = []
        for i in range(count):
            x, cache = body(x, jax.tree.map(lambda a: a[i], stack_p))
            outs.append(cache)
        caches = jax.tree.map(lambda *cs: jnp.stack(cs), *outs) \
            if want_cache else None
        return x, caches
    x, caches = jax.lax.scan(body, x, stack_p)
    return x, (caches if want_cache else None)


def run_stack_decode(stack_p, x, cfg, kind: str, cache, pos, count: int,
                     shared: bool, cross_kv=None, decode_mode: str = "tp"):
    if shared or count == 1:
        outs = []
        for i in range(count):
            c_i = jax.tree.map(lambda a: a[i], cache)
            x, c_new = block_decode(stack_p, x, cfg, kind, c_i, pos,
                                    cross_kv, decode_mode)
            outs.append(c_new)
        cache = jax.tree.map(lambda *cs: jnp.stack(cs), *outs)
        return x, cache

    def body(xx, inp):
        p_l, c_l = inp
        out, c_new = block_decode(p_l, xx, cfg, kind, c_l, pos, cross_kv,
                                  decode_mode)
        return out, c_new

    x, caches = jax.lax.scan(body, x, (stack_p, cache))
    return x, caches

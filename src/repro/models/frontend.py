"""Modality frontend STUBS (per assignment: `input_specs()` provides
precomputed frame/patch embeddings; the ViT / audio encoder itself is out
of scope).  The projector maps stub embeddings into the backbone width.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.layers import dense_init

__all__ = ["init_frontend", "frontend_project"]


def init_frontend(key, cfg):
    if not cfg.frontend:
        return {}
    dt = jnp.dtype(cfg.dtype)
    k1, k2 = jax.random.split(key)
    return {
        "fe_w1": dense_init(k1, cfg.frontend_dim, cfg.d_model, dt),
        "fe_w2": dense_init(k2, cfg.d_model, cfg.d_model, dt),
        "fe_norm": jnp.ones((cfg.d_model,), dt),
    }


def frontend_project(p, embeds, cfg):
    """embeds (B, F, frontend_dim) -> (B, F, d_model)."""
    h = embeds.astype(jnp.dtype(cfg.dtype)) @ p["fe_w1"]
    h = jax.nn.gelu(h.astype(jnp.float32)).astype(h.dtype)
    return h @ p["fe_w2"]

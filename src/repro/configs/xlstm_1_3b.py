"""xlstm-1.3b [ssm] — sLSTM + mLSTM blocks (7:1 interleave), d_ff=0
(cells carry their own expansion).  [arXiv:2405.04517; unverified]

Sub-quadratic: recurrent state decode, runs long_500k.
"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="xlstm-1.3b", family="ssm",
    n_layers=48, d_model=2048, n_heads=4, n_kv_heads=4,
    d_ff=0, vocab=50304, head_dim=512,
    slstm_every=8, subquadratic=True,
    source="arXiv:2405.04517",
)

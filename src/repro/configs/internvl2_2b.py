"""internvl2-2b [vlm] — InternViT (STUB patch embeddings) + InternLM2
backbone.  [arXiv:2404.16821; hf]
"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="internvl2-2b", family="vlm",
    n_layers=24, d_model=2048, n_heads=16, n_kv_heads=8,
    d_ff=8192, vocab=92553, head_dim=128,
    frontend="vision", frontend_dim=1024, frontend_len=256,
    rope_theta=1_000_000.0,
    source="arXiv:2404.16821",
)

from repro.configs.base import (
    ArchConfig, MoEConfig, ShapeConfig, SHAPES, TrainConfig, reduced,
    supports_shape,
)
from repro.configs.registry import ARCHS, get_arch

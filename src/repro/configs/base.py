"""Architecture / shape / run configuration dataclasses.

Every assigned architecture is a frozen `ArchConfig`; the four canonical
input shapes are `ShapeConfig`s.  `reduced()` produces the same-family
small config used by CPU smoke tests; full configs are only ever lowered
via ShapeDtypeStructs in the dry-run (no allocation).
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field

__all__ = ["MoEConfig", "ArchConfig", "ShapeConfig", "SHAPES", "reduced",
           "supports_shape", "TrainConfig"]


@dataclass(frozen=True)
class MoEConfig:
    n_experts: int
    top_k: int
    capacity_factor: float = 1.25
    impl: str = "capacity"           # "capacity" | "dense" | "ragged"


@dataclass(frozen=True)
class ArchConfig:
    name: str
    family: str                      # dense | moe | hybrid | ssm | encdec | vlm | audio
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab: int
    head_dim: int = 0                # 0 -> d_model // n_heads
    qk_norm: bool = False
    rope_theta: float = 1_000_000.0
    norm_eps: float = 1e-6
    tie_embeddings: bool = False
    moe: MoEConfig | None = None
    # SSM / hybrid
    ssm_state: int = 0
    ssm_head_dim: int = 64
    ssm_expand: int = 2
    conv_width: int = 4
    attn_every: int = 0              # hybrid: shared attn applied after every k ssm layers
    # xLSTM
    slstm_every: int = 0             # sLSTM block at layers (i+1) % slstm_every == 0
    # encoder-decoder
    enc_layers: int = 0
    # modality frontend stub
    frontend: str | None = None      # "audio" | "vision"
    frontend_dim: int = 0            # stub embedding dim
    frontend_len: int = 256          # stub frames / patches per example
    # capabilities
    subquadratic: bool = False       # may run long_500k
    dtype: str = "bfloat16"
    remat: str = "full"              # "none" | "full" | "dots"
    attn_impl: str = "chunked"       # "chunked" | "ref" | "flash"
    attn_chunk: int = 512
    ssm_chunk: int = 256
    unroll: bool = False             # unroll all scans (analytic-model validation)
    source: str = ""                 # provenance note

    @property
    def hd(self) -> int:
        return self.head_dim or self.d_model // self.n_heads

    @property
    def is_encdec(self) -> bool:
        return self.enc_layers > 0

    def replace(self, **kw) -> "ArchConfig":
        return dataclasses.replace(self, **kw)


@dataclass(frozen=True)
class ShapeConfig:
    name: str
    seq_len: int
    global_batch: int
    kind: str                        # "train" | "prefill" | "decode"


SHAPES: dict[str, ShapeConfig] = {
    "train_4k": ShapeConfig("train_4k", 4_096, 256, "train"),
    "prefill_32k": ShapeConfig("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": ShapeConfig("decode_32k", 32_768, 128, "decode"),
    "long_500k": ShapeConfig("long_500k", 524_288, 1, "decode"),
}


def supports_shape(arch: ArchConfig, shape: ShapeConfig) -> tuple[bool, str]:
    """Which (arch x shape) cells are defined.  long_500k needs sub-quadratic
    attention (decode cost O(S) per token for dense-attention models is a
    0.5 TB KV read per token per example — skipped per assignment)."""
    if shape.name == "long_500k" and not arch.subquadratic:
        return False, "long_500k skipped: pure full-attention arch (see DESIGN.md §4)"
    return True, ""


def reduced(arch: ArchConfig) -> ArchConfig:
    """Same-family tiny config for CPU smoke tests."""
    kw: dict = dict(
        n_layers=min(arch.n_layers, 4 if (arch.attn_every or arch.slstm_every) else 2),
        d_model=64,
        n_heads=4,
        n_kv_heads=min(arch.n_kv_heads, 2),
        d_ff=0 if arch.d_ff == 0 else 128,
        vocab=128,
        head_dim=16,
        frontend_dim=32 if arch.frontend else 0,
        frontend_len=8 if arch.frontend else arch.frontend_len,
        enc_layers=min(arch.enc_layers, 2),
        attn_chunk=32,
        ssm_chunk=16,
        remat="none",
    )
    if arch.moe is not None:
        kw["moe"] = MoEConfig(n_experts=4, top_k=min(arch.moe.top_k, 2),
                              capacity_factor=2.0, impl=arch.moe.impl)
    if arch.ssm_state:
        kw["ssm_state"] = 16
        kw["ssm_head_dim"] = 16
    if arch.attn_every:
        kw["attn_every"] = 2
    if arch.slstm_every:
        kw["slstm_every"] = 2
    return arch.replace(**kw)


@dataclass(frozen=True)
class TrainConfig:
    learning_rate: float = 3e-4
    warmup_steps: int = 100
    total_steps: int = 1000
    weight_decay: float = 0.1
    beta1: float = 0.9
    beta2: float = 0.95
    eps: float = 1e-8
    grad_clip: float = 1.0
    microbatches: int = 1            # gradient accumulation steps
    compress_cross_pod: bool = False # int8 error-feedback on cross-pod reduce
    seed: int = 0

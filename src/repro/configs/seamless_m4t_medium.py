"""seamless-m4t-medium [audio] — enc-dec, multimodal.  [arXiv:2308.11596; hf]

Backbone only: 12L encoder over precomputed audio-frame embeddings (STUB)
+ 12L causal decoder with cross-attention.  kv=16 means full MHA.
"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="seamless-m4t-medium", family="audio",
    n_layers=12, d_model=1024, n_heads=16, n_kv_heads=16,
    d_ff=4096, vocab=256206, head_dim=64,
    enc_layers=12, frontend="audio", frontend_dim=160, frontend_len=1024,
    rope_theta=10_000.0,
    source="arXiv:2308.11596",
)

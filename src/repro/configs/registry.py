"""Architecture registry: --arch <id> resolution."""
from __future__ import annotations

from repro.configs.base import ArchConfig

from repro.configs.granite_3_2b import CONFIG as _granite
from repro.configs.qwen3_4b import CONFIG as _qwen3_4b
from repro.configs.phi4_mini_3_8b import CONFIG as _phi4
from repro.configs.qwen3_8b import CONFIG as _qwen3_8b
from repro.configs.seamless_m4t_medium import CONFIG as _seamless
from repro.configs.zamba2_7b import CONFIG as _zamba2
from repro.configs.internvl2_2b import CONFIG as _internvl
from repro.configs.phi3_5_moe_42b import CONFIG as _phi35moe
from repro.configs.olmoe_1b_7b import CONFIG as _olmoe
from repro.configs.xlstm_1_3b import CONFIG as _xlstm

ARCHS: dict[str, ArchConfig] = {c.name: c for c in [
    _granite, _qwen3_4b, _phi4, _qwen3_8b, _seamless,
    _zamba2, _internvl, _phi35moe, _olmoe, _xlstm,
]}

_ALIASES = {
    "granite-3-2b": "granite-3-2b",
    "qwen3-4b": "qwen3-4b",
    "phi4-mini-3.8b": "phi4-mini-3.8b",
    "qwen3-8b": "qwen3-8b",
    "seamless-m4t-medium": "seamless-m4t-medium",
    "zamba2-7b": "zamba2-7b",
    "internvl2-2b": "internvl2-2b",
    "phi3.5-moe-42b-a6.6b": "phi3.5-moe-42b-a6.6b",
    "phi3.5-moe": "phi3.5-moe-42b-a6.6b",
    "olmoe-1b-7b": "olmoe-1b-7b",
    "xlstm-1.3b": "xlstm-1.3b",
}


def get_arch(name: str) -> ArchConfig:
    key = _ALIASES.get(name, name)
    if key not in ARCHS:
        raise KeyError(f"unknown arch {name!r}; known: {sorted(ARCHS)}")
    return ARCHS[key]

"""zamba2-7b [hybrid] — Mamba2 backbone + shared attention block applied
every 6 layers (weights shared, per-application KV caches distinct).
[arXiv:2411.15242; unverified]

Sub-quadratic: runs long_500k (attention KV context-parallel-sharded).
"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="zamba2-7b", family="hybrid",
    n_layers=81, d_model=3584, n_heads=32, n_kv_heads=32,
    d_ff=14336, vocab=32000, head_dim=112,
    ssm_state=64, ssm_head_dim=64, ssm_expand=2, conv_width=4,
    attn_every=6, subquadratic=True,
    rope_theta=10_000.0,
    source="arXiv:2411.15242",
)

"""Int8 gradient compression with error feedback for the cross-pod reduce.

At 1000+ node scale the pod-to-pod gradient all-reduce crosses the slowest
links; int8 quantization cuts those bytes 4x (vs f32).  Error feedback
(residual accumulation) makes the quantization bias telescope to zero, so
SGD/Adam convergence is preserved (Karimireddy et al., 2019).

`compressed_psum_with_feedback` is shard_map-compatible: quantize locally,
psum the int8-as-int32 payload (exact integer addition), dequantize with the
psum'd scale bound.
"""
from __future__ import annotations

from typing import Any, NamedTuple

import jax
import jax.numpy as jnp

__all__ = ["compress_int8", "decompress_int8", "EFState", "ef_init",
           "compressed_psum_with_feedback", "apply_error_feedback"]


def compress_int8(x: jnp.ndarray):
    """-> (q int8, scale f32 ()) with symmetric per-tensor scaling."""
    xf = x.astype(jnp.float32)
    amax = jnp.maximum(jnp.max(jnp.abs(xf)), 1e-12)
    scale = amax / 127.0
    q = jnp.clip(jnp.round(xf / scale), -127, 127).astype(jnp.int8)
    return q, scale


def decompress_int8(q: jnp.ndarray, scale: jnp.ndarray) -> jnp.ndarray:
    return q.astype(jnp.float32) * scale


class EFState(NamedTuple):
    residual: Any    # error-feedback memory, same tree as grads (f32)


def ef_init(grads) -> EFState:
    return EFState(jax.tree.map(
        lambda g: jnp.zeros(g.shape, jnp.float32), grads))


def apply_error_feedback(grads, ef: EFState):
    """Add residual, quantize/dequantize, store the new residual.

    Single-process form (the collective variant below fuses the psum).
    Returns (decompressed grads, new EFState).
    """
    def one(g, r):
        corrected = g.astype(jnp.float32) + r
        q, s = compress_int8(corrected)
        deq = decompress_int8(q, s)
        return deq, corrected - deq

    flat_g, td = jax.tree.flatten(grads)
    flat_r = jax.tree.leaves(ef.residual)
    outs = [one(g, r) for g, r in zip(flat_g, flat_r)]
    return td.unflatten([o[0] for o in outs]), \
        EFState(td.unflatten([o[1] for o in outs]))


def compressed_psum_with_feedback(grads, ef: EFState, axis: str):
    """shard_map body: int8-compressed psum over `axis` with error feedback.

    Integer psum is exact, so every participant dequantizes identically; the
    local quantization error goes into the residual for the next step.
    """
    def one(g, r):
        corrected = g.astype(jnp.float32) + r
        # shared scale across the axis so integer psum dequantizes exactly
        amax = jax.lax.pmax(jnp.maximum(jnp.max(jnp.abs(corrected)), 1e-12), axis)
        scale = amax / 127.0
        q = jnp.clip(jnp.round(corrected / scale), -127, 127).astype(jnp.int8)
        new_r = corrected - q.astype(jnp.float32) * scale
        qsum = jax.lax.psum(q.astype(jnp.int32), axis)
        return qsum.astype(jnp.float32) * scale, new_r

    flat_g, td = jax.tree.flatten(grads)
    flat_r = jax.tree.leaves(ef.residual)
    outs = [one(g, r) for g, r in zip(flat_g, flat_r)]
    return td.unflatten([o[0] for o in outs]), \
        EFState(td.unflatten([o[1] for o in outs]))

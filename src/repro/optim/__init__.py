from repro.optim.adamw import AdamWState, adamw_init, adamw_update, cosine_lr
from repro.optim.compression import (
    compress_int8, decompress_int8, compressed_psum_with_feedback, EFState,
    ef_init,
)

"""AdamW with sharded states (moments inherit parameter shardings) and a
cosine LR schedule with linear warmup.  Pure-pytree, no optax dependency.
"""
from __future__ import annotations

from typing import Any, NamedTuple

import jax
import jax.numpy as jnp

__all__ = ["AdamWState", "adamw_init", "adamw_update", "cosine_lr",
           "global_norm", "clip_by_global_norm"]


class AdamWState(NamedTuple):
    step: jnp.ndarray     # () int32
    mu: Any               # first moments  (f32, same tree as params)
    nu: Any               # second moments (f32)


def adamw_init(params) -> AdamWState:
    zeros = lambda p: jnp.zeros(p.shape, jnp.float32)
    return AdamWState(
        step=jnp.zeros((), jnp.int32),
        mu=jax.tree.map(zeros, params),
        nu=jax.tree.map(zeros, params),
    )


def cosine_lr(step, base_lr: float, warmup: int, total: int,
              min_frac: float = 0.1):
    warm = base_lr * (step + 1) / max(warmup, 1)
    prog = jnp.clip((step - warmup) / max(total - warmup, 1), 0.0, 1.0)
    cos = base_lr * (min_frac + (1 - min_frac) * 0.5 * (1 + jnp.cos(jnp.pi * prog)))
    return jnp.where(step < warmup, warm, cos).astype(jnp.float32)


def global_norm(tree) -> jnp.ndarray:
    sq = sum(jnp.sum(jnp.square(g.astype(jnp.float32)))
             for g in jax.tree.leaves(tree))
    return jnp.sqrt(sq)


def clip_by_global_norm(grads, max_norm: float):
    gn = global_norm(grads)
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(gn, 1e-9))
    return jax.tree.map(lambda g: (g.astype(jnp.float32) * scale), grads), gn


def adamw_update(params, grads, state: AdamWState, lr,
                 b1: float = 0.9, b2: float = 0.95, eps: float = 1e-8,
                 weight_decay: float = 0.1):
    """One AdamW step.  Returns (new_params, new_state).

    Moments are kept in f32; params updated in their own dtype (bf16-safe:
    the f32 update is computed first, then cast).  Weight decay is decoupled
    and skipped for 1-D params (norms, biases) as is conventional.
    """
    step = state.step + 1
    b1t = 1 - b1 ** step.astype(jnp.float32)
    b2t = 1 - b2 ** step.astype(jnp.float32)

    def upd(p, g, m, v):
        gf = g.astype(jnp.float32)
        m = b1 * m + (1 - b1) * gf
        v = b2 * v + (1 - b2) * gf * gf
        mhat = m / b1t
        vhat = v / b2t
        delta = mhat / (jnp.sqrt(vhat) + eps)
        if p.ndim > 1 and weight_decay:
            delta = delta + weight_decay * p.astype(jnp.float32)
        newp = (p.astype(jnp.float32) - lr * delta).astype(p.dtype)
        return newp, m, v

    flat_p, treedef = jax.tree.flatten(params)
    flat_g = jax.tree.leaves(grads)
    flat_m = jax.tree.leaves(state.mu)
    flat_v = jax.tree.leaves(state.nu)
    out = [upd(p, g, m, v) for p, g, m, v in zip(flat_p, flat_g, flat_m, flat_v)]
    new_p = treedef.unflatten([o[0] for o in out])
    new_m = treedef.unflatten([o[1] for o in out])
    new_v = treedef.unflatten([o[2] for o in out])
    return new_p, AdamWState(step=step, mu=new_m, nu=new_v)

"""Follower process entrypoint: tail a primary's delta stream (§13).

Connects a `ReplicationClient` to a `ReplicationServer`, applies
SNAPSHOT/DELTA frames into a local delta-mode `SnapshotStore` (ACKing each
version), and on FIN writes a JSON report — versions held, latest count /
capacity, a sha256 content digest of the latest snapshot, and whether the
stream began with a snapshot bootstrap.  The cluster driver compares the
digest against the primary to prove cross-process bit-identity; a follower
spawned mid-run must report `bootstrapped: true` with the same digest.

  PYTHONPATH=src python -m repro.launch.occ_follower \
      --connect 127.0.0.1:5432 --model occ --out follower.json
"""
from __future__ import annotations

import argparse
import json

from repro.distributed.transport import ReplicationClient, store_digest

__all__ = ["follower_main"]


def follower_main(host: str, port: int, model: str | None,
                  result_path: str | None = None,
                  capacity: int = 128, reconnect: bool = False,
                  max_retries: int = 6, backoff_s: float = 0.05,
                  backoff_max_s: float = 2.0) -> dict:
    """Run the follower loop to FIN/EOF; return (and optionally write) the
    state report.  Spawnable as a `multiprocessing` target.

    With `reconnect=True` a broken stream is retried with exponential
    backoff + jitter (§14) up to `max_retries` consecutive failures; the
    re-HELLO carries the follower's watermark, so a retry resumes with the
    missing suffix (or a SNAPSHOT resync) rather than the full history."""
    client = ReplicationClient((host, port), model=model, capacity=capacity,
                               reconnect=reconnect, max_retries=max_retries,
                               backoff_s=backoff_s,
                               backoff_max_s=backoff_max_s)
    client.connect()
    client.run()
    store = client.store
    meta = store.latest_meta()
    report = dict(
        model=model,
        versions=store.versions(),
        latest_version=None if meta is None else meta.version,
        count=None if meta is None else meta.count,
        capacity=None if meta is None else meta.capacity,
        digest=store_digest(store),
        bootstrapped=client.bootstrapped,
        n_applied=client.n_applied,
        n_reconnects=client.n_reconnects,
        fin_reason=client.fin_reason,
    )
    if result_path is not None:
        with open(result_path, "w") as f:
            json.dump(report, f, indent=2)
    return report


def main(argv=None):
    ap = argparse.ArgumentParser(description=__doc__.split("\n")[0])
    ap.add_argument("--connect", required=True, metavar="HOST:PORT")
    ap.add_argument("--model", default=None)
    ap.add_argument("--out", default=None, help="write the JSON report here")
    ap.add_argument("--capacity", type=int, default=128,
                    help="follower snapshot-ring capacity")
    ap.add_argument("--reconnect", action="store_true",
                    help="retry a broken stream with backoff + jitter")
    ap.add_argument("--max-retries", type=int, default=6,
                    help="consecutive failures before giving up")
    ap.add_argument("--backoff", type=float, default=0.05,
                    help="initial reconnect backoff (seconds)")
    ap.add_argument("--backoff-max", type=float, default=2.0,
                    help="backoff ceiling (seconds)")
    args = ap.parse_args(argv)
    host, port = args.connect.rsplit(":", 1)
    report = follower_main(host, int(port), args.model, args.out,
                           args.capacity, reconnect=args.reconnect,
                           max_retries=args.max_retries,
                           backoff_s=args.backoff,
                           backoff_max_s=args.backoff_max)
    print(json.dumps(report))


if __name__ == "__main__":
    main()

"""Follower process entrypoint: tail a primary's delta stream (§13).

Connects a `ReplicationClient` to a `ReplicationServer`, applies
SNAPSHOT/DELTA frames into a local delta-mode `SnapshotStore` (ACKing each
version), and on FIN writes a JSON report — versions held, latest count /
capacity, a sha256 content digest of the latest snapshot, and whether the
stream began with a snapshot bootstrap.  The cluster driver compares the
digest against the primary to prove cross-process bit-identity; a follower
spawned mid-run must report `bootstrapped: true` with the same digest.

  PYTHONPATH=src python -m repro.launch.occ_follower \
      --connect 127.0.0.1:5432 --model occ --out follower.json
"""
from __future__ import annotations

import argparse
import json

from repro.distributed.transport import ReplicationClient, store_digest

__all__ = ["follower_main"]


def follower_main(host: str, port: int, model: str | None,
                  result_path: str | None = None,
                  capacity: int = 128) -> dict:
    """Run the follower loop to FIN/EOF; return (and optionally write) the
    state report.  Spawnable as a `multiprocessing` target."""
    client = ReplicationClient((host, port), model=model, capacity=capacity)
    client.connect()
    client.run()
    store = client.store
    meta = store.latest_meta()
    report = dict(
        model=model,
        versions=store.versions(),
        latest_version=None if meta is None else meta.version,
        count=None if meta is None else meta.count,
        capacity=None if meta is None else meta.capacity,
        digest=store_digest(store),
        bootstrapped=client.bootstrapped,
        n_applied=client.n_applied,
        fin_reason=client.fin_reason,
    )
    if result_path is not None:
        with open(result_path, "w") as f:
            json.dump(report, f, indent=2)
    return report


def main(argv=None):
    ap = argparse.ArgumentParser(description=__doc__.split("\n")[0])
    ap.add_argument("--connect", required=True, metavar="HOST:PORT")
    ap.add_argument("--model", default=None)
    ap.add_argument("--out", default=None, help="write the JSON report here")
    ap.add_argument("--capacity", type=int, default=128,
                    help="follower snapshot-ring capacity")
    args = ap.parse_args(argv)
    host, port = args.connect.rsplit(":", 1)
    report = follower_main(host, int(port), args.model, args.out,
                           args.capacity)
    print(json.dumps(report))


if __name__ == "__main__":
    main()

"""Batched serving driver.

  PYTHONPATH=src python -m repro.launch.serve --arch granite-3-2b --reduced \
      --requests 8 --prompt-len 16 --max-new 16

Runs the slot-based ServeEngine (prefill + decode loop + slot recycling)
and reports per-token latency and throughput.
"""
from __future__ import annotations

import argparse
import time

import jax
import numpy as np

from repro.configs import get_arch, reduced
from repro.distributed.shardings import shard_ctx
from repro.launch.mesh import make_production_mesh
from repro.models import build_model
from repro.serving.engine import Request, ServeEngine


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--max-new", type=int, default=16)
    ap.add_argument("--cache-len", type=int, default=128)
    ap.add_argument("--mesh", choices=["none", "single", "multi"], default="none")
    ap.add_argument("--decode-mode", choices=["tp", "cp"], default="tp")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    arch = get_arch(args.arch)
    if args.reduced:
        arch = reduced(arch)
    if jax.default_backend() == "cpu":
        arch = arch.replace(dtype="float32")
    mesh = None
    if args.mesh != "none":
        mesh = make_production_mesh(multi_pod=args.mesh == "multi")

    rng = np.random.default_rng(args.seed)
    with shard_ctx(mesh):
        model = build_model(arch)
        params = model.init(jax.random.key(args.seed))
        engine = ServeEngine(model, params, n_slots=args.slots,
                             cache_len=args.cache_len,
                             decode_mode=args.decode_mode)
        reqs = [Request(uid=i,
                        prompt=rng.integers(0, arch.vocab, args.prompt_len),
                        max_new=args.max_new)
                for i in range(args.requests)]
        t0 = time.time()
        done = engine.run(reqs)
        dt = time.time() - t0
        total_new = sum(len(r.out) for r in done)
        print(f"served {len(done)} requests, {total_new} new tokens "
              f"in {dt:.2f}s ({total_new / max(dt, 1e-9):.1f} tok/s, "
              f"{args.slots} slots)")
        for r in done[:4]:
            print(f"  req {r.uid}: out[:8]={r.out[:8]}")
        return done


if __name__ == "__main__":
    main()

"""Production mesh construction.

A function, not a module-level constant: importing this module never touches
jax device state (device count locks on first backend init).
"""
from __future__ import annotations

import jax

__all__ = ["compat_mesh", "make_production_mesh", "make_test_mesh"]


def compat_mesh(shape, axes):
    """jax.make_mesh across jax versions.

    Newer jax wants explicit ``axis_types=(AxisType.Auto, ...)`` to keep the
    GSPMD auto-partitioning behaviour; older releases (<= 0.4.x) don't have
    `jax.sharding.AxisType` at all and Auto is the only behaviour.
    """
    axis_type = getattr(jax.sharding, "AxisType", None)
    if axis_type is None:
        return jax.make_mesh(tuple(shape), tuple(axes))
    return jax.make_mesh(tuple(shape), tuple(axes),
                         axis_types=(axis_type.Auto,) * len(axes))


def make_production_mesh(*, multi_pod: bool = False):
    """Single pod: (data=16, model=16) = 256 chips (v5e pod).
    Multi-pod: (pod=2, data=16, model=16) = 512 chips; `pod` carries the
    cross-pod data parallelism (DCN/ICI-X gradient all-reduce)."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return compat_mesh(shape, axes)


def make_test_mesh(shape=(2, 2), axes=("data", "model")):
    """Small mesh for CPU tests (requires enough host devices)."""
    return compat_mesh(shape, axes)

"""ShapeDtypeStruct stand-ins and sharding specs for every dry-run cell.

`input_specs(arch, shape)` follows the shannon/kernels pattern: weak-type-
correct, shardable, zero allocation.  `cell_functions` builds the jitted
train_step / serve_step with in/out shardings for a given mesh.
"""
from __future__ import annotations

import math
from functools import partial
from typing import Any

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs.base import ArchConfig, ShapeConfig, TrainConfig
from repro.distributed.shardings import (
    ShardCtx, batch_spec, current_ctx, param_specs, spec_for)
from repro.models.model import Model
from repro.training.step import TrainState, make_train_step, train_state_init

__all__ = ["input_specs", "state_specs", "cache_specs", "pick_decode_mode",
           "CellPlan", "plan_cell"]


def _sds(shape, dtype):
    return jax.ShapeDtypeStruct(shape, dtype)


def input_specs(arch: ArchConfig, shape: ShapeConfig) -> dict[str, Any]:
    """Model-input stand-ins for one cell (no device allocation)."""
    b, s = shape.global_batch, shape.seq_len
    if shape.kind in ("train", "prefill"):
        specs = {
            "tokens": _sds((b, s), jnp.int32),
            "labels": _sds((b, s), jnp.int32),
        }
        if arch.frontend:
            specs["frontend"] = _sds((b, arch.frontend_len, arch.frontend_dim),
                                     jnp.float32)
        if shape.kind == "prefill":
            specs.pop("labels")
        return specs
    # decode: one new token against a cache of seq_len
    return {
        "tokens": _sds((b, 1), jnp.int32),
        "pos": _sds((b,), jnp.int32),
    }


def input_spec_shardings(arch, shape, mesh, ctx: ShardCtx):
    bspec = batch_spec(shape.global_batch, ctx)
    sh = lambda *elems: NamedSharding(mesh, P(*elems))
    out = {"tokens": sh(bspec, None)}
    if shape.kind in ("train", "prefill"):
        if shape.kind == "train":
            out["labels"] = sh(bspec, None)
        if arch.frontend:
            out["frontend"] = sh(bspec, None, None)
    else:
        out["pos"] = sh(bspec)
    return out


# ---------------------------------------------------------------------------
# State / cache sharding specs
# ---------------------------------------------------------------------------

def state_specs(model: Model, tcfg: TrainConfig, ctx: ShardCtx):
    """PartitionSpec tree for TrainState (params + moments + ef)."""
    state_sds = jax.eval_shape(
        lambda: train_state_init(model.init(jax.random.key(0)), tcfg))
    specs = param_specs(state_sds, ctx)   # regex rules see full paths
    return state_sds, specs


def pick_decode_mode(arch: ArchConfig, shape: ShapeConfig, ctx: ShardCtx) -> str:
    """cp when head-TP can't shard the cache (kv % model != 0) or the cache
    is long enough that seq-sharding wins on memory; else tp."""
    if ctx.force_decode_mode:
        return ctx.force_decode_mode
    m = ctx.axis_size(ctx.model_axis)
    if m <= 1:
        return "tp"
    if shape.seq_len >= 262_144:
        return "cp"
    if arch.n_kv_heads % m != 0:
        return "cp"
    return "tp"


def cache_specs(model: Model, shape: ShapeConfig, ctx: ShardCtx, mode: str):
    """Spec tree mirroring model.init_cache output."""
    cfg = model.cfg
    b = shape.global_batch
    cache_sds = jax.eval_shape(lambda: model.init_cache(b, shape.seq_len))
    bsp = batch_spec(b, ctx)
    mdl = ctx.model_axis

    def leaf_spec(path, leaf):
        name = str(getattr(path[-1], "key", path[-1]))
        shp = leaf.shape
        if name in ("k", "v", "ck", "cv"):      # (L,B,S,H,hd)
            if mode == "cp" and name in ("k", "v"):
                return spec_for(shp, (None, bsp, mdl, None, None), ctx)
            return spec_for(shp, (None, bsp, None, mdl, None), ctx)
        if name == "conv":                       # (L,B,w-1,d_inner)
            return spec_for(shp, (None, bsp, None, mdl), ctx)
        if name == "ssm":                        # (L,B,H,hd,N)
            return spec_for(shp, (None, bsp, mdl, None, None), ctx)
        if name == "c" and len(shp) == 5:        # mLSTM (L,B,H,hd,hd)
            return spec_for(shp, (None, bsp, None, mdl, None), ctx)
        if name in ("c", "n", "h", "m"):         # (L,B,H,hd) / (L,B,H)
            return spec_for(shp, ((None, bsp) + (None,) * (len(shp) - 2)), ctx)
        return P(*([None] * len(shp)))

    specs = jax.tree_util.tree_map_with_path(leaf_spec, cache_sds)
    return cache_sds, specs


# ---------------------------------------------------------------------------
# Cell assembly
# ---------------------------------------------------------------------------

class CellPlan:
    """Everything needed to lower one (arch x shape x mesh) cell."""
    def __init__(self, fn, args_sds, in_shardings, out_shardings, meta):
        self.fn = fn
        self.args_sds = args_sds
        self.in_shardings = in_shardings
        self.out_shardings = out_shardings
        self.meta = meta

    def lower(self):
        jitted = jax.jit(self.fn, in_shardings=self.in_shardings,
                         out_shardings=self.out_shardings)
        return jitted.lower(*self.args_sds)


def plan_cell(arch: ArchConfig, shape: ShapeConfig, mesh,
              tcfg: TrainConfig | None = None) -> CellPlan:
    ctx = current_ctx()
    assert ctx.mesh is mesh, "wrap plan_cell in shard_ctx(mesh)"
    model = Model(arch)
    tcfg = tcfg or TrainConfig()
    meta: dict[str, Any] = {
        "arch": arch.name, "shape": shape.name, "kind": shape.kind,
        "mesh": dict(mesh.shape), "batch_spec": str(batch_spec(shape.global_batch, ctx)),
    }

    if shape.kind == "train":
        state_sds, sspecs = state_specs(model, tcfg, ctx)
        train_step = make_train_step(model, tcfg)
        batch_sds = input_specs(arch, shape)
        in_sh = (jax.tree.map(lambda s: NamedSharding(mesh, s), sspecs,
                              is_leaf=lambda s: isinstance(s, P)),
                 input_spec_shardings(arch, shape, mesh, ctx))
        out_sh = (in_sh[0], None)

        def step_fn(state, batch):
            return train_step(state, batch)

        return CellPlan(step_fn, (state_sds, batch_sds), in_sh, out_sh, meta)

    if shape.kind == "prefill":
        params_sds = jax.eval_shape(lambda: model.init(jax.random.key(0)))
        pspecs = param_specs(params_sds, ctx)
        mode = pick_decode_mode(arch, shape, ctx)
        cache_sds, cspecs = cache_specs(model, shape, ctx, mode)
        batch_sds = input_specs(arch, shape)
        meta["decode_mode"] = mode

        def prefill_fn(params, batch):
            return model.prefill(params, batch)

        in_sh = (jax.tree.map(lambda s: NamedSharding(mesh, s), pspecs,
                              is_leaf=lambda s: isinstance(s, P)),
                 input_spec_shardings(arch, shape, mesh, ctx))
        return CellPlan(prefill_fn, (params_sds, batch_sds), in_sh, None, meta)

    # decode
    params_sds = jax.eval_shape(lambda: model.init(jax.random.key(0)))
    pspecs = param_specs(params_sds, ctx)
    mode = pick_decode_mode(arch, shape, ctx)
    cache_sds, cspecs = cache_specs(model, shape, ctx, mode)
    io = input_specs(arch, shape)
    meta["decode_mode"] = mode

    def serve_step(params, caches, tokens, pos):
        return model.decode_step(params, caches, tokens, pos, decode_mode=mode)

    nsh = lambda tree: jax.tree.map(lambda s: NamedSharding(mesh, s), tree,
                                    is_leaf=lambda s: isinstance(s, P))
    bsp = batch_spec(shape.global_batch, ctx)
    in_sh = (nsh(pspecs), nsh(cspecs),
             NamedSharding(mesh, P(bsp, None)), NamedSharding(mesh, P(bsp)))
    out_sh = (NamedSharding(mesh, P(bsp, None)), nsh(cspecs))
    return CellPlan(serve_step, (params_sds, cache_sds, io["tokens"], io["pos"]),
                    in_sh, out_sh, meta)

"""Train-on-stream, serve-while-training: the full train→publish→serve
pipeline, scaled out to many tenants (DESIGN.md §10/§12).

Per model, a trainer thread streams batches through `OCCEngine.partial_fit`
(arbitrary batch lengths — the partial-epoch carry keeps the stream
bit-identical to a one-shot run) and publishes one immutable version per
committed pass through the DELTA log (O(ΔK·D) per publish), mirrored into
an eager shadow store so the audit can prove delta-materialize ==
eager-copy bit-identity on the live stream.  Concurrently, a pool of
client threads runs a load generator against a `ModelRouter` fronting all
tenants with admission-queue coalescing enabled: ragged request sizes,
concurrent small requests merged into fuller microbatches under the
deadline-or-full policy, one jitted dispatch per microbatch, atomic
hot-swap per model between requests.

After the run, every response is audited:
  * zero stale reads — every coalesced dispatch is replayed from its
    tagged (model, version) snapshot through the service's own jitted
    step (`DispatchRecord` holds the exact padded inputs) and must
    reproduce each member response bit-exactly; versions observed by any
    single client are monotone per model;
  * multi-model isolation + serve == train — response labels are
    bit-identical to engine labels (`core.occ.nearest_center` on the
    tagged model's snapshot pool), per (model, version);
  * delta publication — every published version materializes
    bit-identically from the delta log and from the eager shadow copy;
  * coalescing pays — the same request trace replayed solo (no admission
    queue) must show a WORSE bucket-fill ratio than the coalesced run;
  * ≥ 2 models, ≥ `min_versions` hot-swapped through per model,
    ≥ `min_queries` total rows (full mode: 10k).

A second ADVERSARIAL MIXED-TRAFFIC phase (§17) then runs the QoS A/B:
the same offered load — interactive clients (small `score` queries,
tight deadlines, `max_staleness=0`) deliberately mixed against
analytics clients (wide `topk` scans, long deadlines, staleness
tolerance) — is replayed against a priority-lane service and against
the legacy FIFO baseline (`priority_lanes=False`), each with a live
trainer republishing versions underneath.  Audited:
  * interactive p99 with priority lanes STRICTLY better than FIFO under
    the same offered load;
  * overload shedding fired (priority run), and every degraded response
    replays bit-exactly from its `DispatchRecord` tagged with the stale
    pinned version + `degraded` flag;
  * `max_staleness=0` traffic is NEVER degraded and always replays
    bit-exactly from its tagged version (zero stale reads), with
    per-client monotone versions on the non-degraded path.

p50/p99 latency, QPS, fill ratios, and the QoS A/B land in
BENCH_cluster_service.json.

  PYTHONPATH=src python -m repro.launch.serve_clusters [--quick]
"""
from __future__ import annotations

import argparse
import dataclasses
import json
import threading
import time
from dataclasses import dataclass, field

import jax.numpy as jnp
import numpy as np

from repro.core import DPMeansTransaction, OCCEngine
from repro.core.occ import nearest_center
from repro.data import dp_stick_breaking_data
from repro.obs import Obs, Tracer
from repro.serving import (
    ClusterService, ModelRouter, Query, ServeConfig, SnapshotStore,
)
from repro.serving.cluster_service import _assign_step, _topk_step

__all__ = ["ServeDemoConfig", "run_demo"]


@dataclass
class ServeDemoConfig:
    n: int = 8192              # stream length PER MODEL
    dim: int = 16
    n_models: int = 2
    lam: float = 4.0
    k_max: int = 512
    pb: int = 128              # points per OCC epoch
    train_batch: int = 384     # NOT a multiple of pb: exercises the carry
    min_queries: int = 10_000  # load-generator floor (rows, all models)
    max_request: int = 32      # ragged request sizes in [1, max_request]
    # Closed-loop load: the queue depth per model is ~ n_clients/n_models
    # blocked requests, so the coalesce bucket is sized to that row supply
    # (a bucket far above it turns every flush into a half-empty deadline
    # flush and coalescing stops paying).
    n_clients: int = 16        # concurrent load-generator threads
    coalesce_bucket: int = 64
    coalesce_delay_ms: float = 10.0
    backend: str = "auto"      # service kernel backend
    min_versions: int = 3      # hot-swap floor per model under load
    # --- adversarial mixed-traffic QoS A/B (§17) ---
    # Deadlines are sized so the FIFO head-of-line penalty (an analytics
    # group parked at the head for its WHOLE deadline — 2 clients x 24
    # rows can never fill the 64-row bucket) dwarfs scheduler/GIL noise
    # on a small box; the lane scheduler flushes interactive on its own
    # 10ms timer regardless.
    qos_n: int = 4096          # stream length for the QoS tenant
    qos_interactive_clients: int = 6
    qos_analytics_clients: int = 2
    qos_interactive_requests: int = 120   # per client, fixed offered trace
    qos_analytics_requests: int = 25
    qos_analytics_rows: int = 24          # rows per analytics topk scan
    qos_interactive_deadline_ms: float = 10.0
    qos_analytics_deadline_ms: float = 250.0
    qos_shed_depth: int = 48   # queued rows at which shedding starts
    seed: int = 0
    out_path: str | None = None
    trace_out: str | None = None   # Perfetto JSON of the whole run
    quiet: bool = False


@dataclass
class _Trace:
    """One served request, as recorded by a load-generator client."""
    model: str
    version: int
    q_lo: int
    q_hi: int
    labels: np.ndarray
    scores: np.ndarray
    bucket: int
    group: int
    offset: int
    latency_s: float = 0.0
    client: int = 0


@dataclass
class _Tenant:
    name: str
    x: jnp.ndarray
    engine: OCCEngine
    store: SnapshotStore          # the router's delta store
    shadow: SnapshotStore         # eager shadow for the delta audit
    batches: list = field(default_factory=list)


def _trainer(tn: _Tenant, svc: ClusterService,
             pace_microbatches: int = 2, timeout_s: float = 5.0):
    """Stream batches through partial_fit; between publishes, wait until the
    service has answered a couple more microbatches so every version is
    actually *observed* under load (deterministic interleaving, no sleeps
    tuned to machine speed)."""
    for xb in tn.batches:
        seen = svc.n_microbatches
        tn.engine.partial_fit(xb)
        deadline = time.perf_counter() + timeout_s
        while (svc.n_microbatches < seen + pace_microbatches
               and time.perf_counter() < deadline):
            time.sleep(0.001)
    tn.engine.flush()


def _make_tenant(name: str, i: int, cfg: ServeDemoConfig,
                 router: ModelRouter, obs: Obs) -> _Tenant:
    x, _, _ = dp_stick_breaking_data(cfg.n, seed=cfg.seed + 17 * i,
                                     dim=cfg.dim)
    x = jnp.asarray(x)
    store = router.add_model(name, snapshot_capacity=256, delta=True)
    shadow = SnapshotStore(capacity=256)

    def publish(res, **kw):
        store.publish_pass(res, **kw)
        shadow.publish_pass(res, **kw)

    eng = OCCEngine(
        DPMeansTransaction(cfg.lam * (1.0 + 0.25 * i), k_max=cfg.k_max),
        pb=cfg.pb, validate_cap="adaptive", publish=publish, obs=obs)
    batches = [x[j:j + cfg.train_batch]
               for j in range(0, cfg.n, cfg.train_batch)]
    return _Tenant(name, x, eng, store, shadow, batches)


@dataclass
class _QosTrace:
    """One served request of the QoS A/B phase."""
    lane: str
    version: int
    q_lo: int
    q_hi: int
    labels: np.ndarray
    scores: np.ndarray
    bucket: int
    group: int
    offset: int
    degraded: bool
    latency_s: float = 0.0


def _qos_schedule(cfg: ServeDemoConfig) -> list[tuple[str, list]]:
    """The offered load, fixed ahead of time: one request list per client,
    identical for both A/B modes (same sizes, same rows, same order) —
    'same offered load' is by construction, not by matched RNG draws."""
    rng = np.random.default_rng(cfg.seed + 4242)
    sched = []
    for _ in range(cfg.qos_interactive_clients):
        sched.append(("interactive",
                      [(int(rng.integers(1, 9)),
                        int(rng.integers(0, cfg.qos_n - 8)))
                       for _ in range(cfg.qos_interactive_requests)]))
    for _ in range(cfg.qos_analytics_clients):
        sched.append(("analytics",
                      [(cfg.qos_analytics_rows,
                        int(rng.integers(0, cfg.qos_n
                                         - cfg.qos_analytics_rows)))
                       for _ in range(cfg.qos_analytics_requests)]))
    return sched


def _replay_step(rec, snap, backend):
    """Replay one DispatchRecord through the service's own jitted step."""
    if rec.kind == "topk":
        d2, idx = _topk_step(snap.centers, snap.mask, np.int32(snap.count),
                             jnp.asarray(rec.x), np.int32(rec.n_valid),
                             k=rec.k, backend=backend)
    else:
        d2, idx = _assign_step(snap.centers, snap.mask, np.int32(snap.count),
                               jnp.asarray(rec.x), np.int32(rec.n_valid),
                               backend=backend)
    return np.asarray(d2), np.asarray(idx)


def _qos_mode(cfg: ServeDemoConfig, obs: Obs, sched,
              priority_lanes: bool, tag: str | None = None) -> dict:
    """One arm of the A/B: train-while-serving a single tenant under the
    fixed adversarial schedule, with (QoS) or without (legacy FIFO) the
    lane scheduler, then audit every response."""
    x, _, _ = dp_stick_breaking_data(cfg.qos_n, seed=cfg.seed + 999,
                                     dim=cfg.dim)
    x = jnp.asarray(x)
    store = SnapshotStore(capacity=256)
    eng = OCCEngine(DPMeansTransaction(cfg.lam, k_max=cfg.k_max),
                    pb=cfg.pb, validate_cap="adaptive",
                    publish=store.publish_pass, obs=obs)
    batches = [x[j:j + cfg.train_batch]
               for j in range(0, cfg.qos_n, cfg.train_batch)]
    # Warm the capacity bucket before measuring: publish all but a tail
    # of batches up front; the tail streams DURING the phase so latest
    # keeps moving and the shed pin genuinely lags it.
    tail = max(2, len(batches) // 4)
    for xb in batches[:-tail]:
        eng.partial_fit(xb)
    mode = tag or ("qos" if priority_lanes else "fifo")
    svc = ClusterService(
        store,
        ServeConfig(backend=cfg.backend, min_bucket=8,
                    max_bucket=max(128, cfg.coalesce_bucket),
                    coalesce=True, coalesce_bucket=cfg.coalesce_bucket,
                    coalesce_delay_ms=cfg.qos_interactive_deadline_ms,
                    audit_log=True, priority_lanes=priority_lanes,
                    shed_depth=cfg.qos_shed_depth),
        name=mode, obs=obs)
    # Warm the jit cache over the request buckets both modes hit, so
    # first-dispatch compiles land in neither mode's percentiles.
    for b in (8, 32, 64):
        svc.score(x[:b])
        svc.topk(x[:b], k=8)
    warm_gid = svc._next_group

    traces: list[list[_QosTrace]] = [[] for _ in sched]

    def client(ci: int, lane: str, reqs):
        mine = traces[ci]
        for size, lo in reqs:
            if lane == "interactive":
                q = Query(x[lo:lo + size], priority="interactive",
                          deadline_ms=cfg.qos_interactive_deadline_ms,
                          max_staleness=0)
            else:
                q = Query(x[lo:lo + size], kind="topk", k=8,
                          priority="analytics",
                          deadline_ms=cfg.qos_analytics_deadline_ms,
                          max_staleness=3)
            t0 = time.perf_counter()
            resp = svc.submit(q)
            dt = time.perf_counter() - t0
            mine.append(_QosTrace(lane, resp.version, lo, lo + size,
                                  resp.labels, resp.scores, resp.bucket,
                                  resp.group, resp.offset, resp.degraded,
                                  dt))

    def trainer():
        for xb in batches[-tail:]:
            seen = svc.n_microbatches
            eng.partial_fit(xb)
            deadline = time.perf_counter() + 5.0
            while (svc.n_microbatches < seen + 2
                   and time.perf_counter() < deadline):
                time.sleep(0.001)
        eng.flush()

    threads = [threading.Thread(target=client, args=(ci, lane, reqs),
                                daemon=True)
               for ci, (lane, reqs) in enumerate(sched)]
    threads.append(threading.Thread(target=trainer, daemon=True))
    t0 = time.perf_counter()
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    wall = time.perf_counter() - t0
    svc.close()

    # ------------------------------------------------------------- audits
    all_t = [t for ts in traces for t in ts]
    ints = [t for t in all_t if t.lane == "interactive"]
    assert all(not t.degraded for t in ints), \
        "max_staleness=0 interactive traffic must never be degraded"
    for ts in traces:
        last = -1       # per-client monotone versions, non-degraded path
        for t in ts:    # (a shed pin may legitimately lag latest)
            if t.degraded:
                continue
            assert t.version >= last, \
                "stale read: version went backwards for a client"
            last = t.version
    # Zero stale reads: every coalesced response replays bit-exactly from
    # its tagged version through the service's own jitted step.
    by_group: dict[int, list[_QosTrace]] = {}
    for t in all_t:
        if not t.degraded:
            assert t.group >= warm_gid, "measured request missed the queue"
            by_group.setdefault(t.group, []).append(t)
    n_replayed = 0
    for rec in svc.audit:
        if rec.degraded:
            continue
        members = by_group.get(rec.group, [])
        if not members:
            continue        # warm-up groups carry no measured traces
        snap = store.get(rec.version)
        assert snap is not None, "audited version evicted — grow the ring"
        d2, idx = _replay_step(rec, snap, cfg.backend)
        for t in members:
            sl = slice(t.offset, t.offset + (t.q_hi - t.q_lo))
            assert (np.array_equal(t.labels, idx[sl])
                    and np.array_equal(t.scores, d2[sl])), \
                f"{mode}: response not reproducible from its tag"
            n_replayed += 1
    assert n_replayed == len([t for t in all_t if not t.degraded]), \
        "audit log lost a dispatch"
    # Degraded replay: every shed response must reproduce bit-exactly
    # from a degraded-tagged DispatchRecord at its tagged stale version.
    deg_by_key: dict[tuple, list] = {}
    for rec in svc.audit:
        if rec.degraded:
            deg_by_key.setdefault((rec.version, rec.n_valid), []).append(rec)
    n_degraded = 0
    for t in (t for t in all_t if t.degraded):
        assert t.lane == "analytics", "only sheddable lanes may degrade"
        n = t.q_hi - t.q_lo
        ok = False
        for rec in deg_by_key.get((t.version, n), []):
            if not np.array_equal(rec.x[:n], np.asarray(x[t.q_lo:t.q_hi])):
                continue
            d2, idx = _replay_step(rec, store.get(rec.version), cfg.backend)
            if (np.array_equal(t.labels, idx[:n])
                    and np.array_equal(t.scores, d2[:n])):
                ok = True
                break
        assert ok, "degraded response not reproducible from its tagged record"
        n_degraded += 1
    m = svc.metrics()
    n_shed = sum(m["n_shed"].values())
    assert n_shed == n_degraded, "shed counter / degraded responses diverge"
    int_lat = np.asarray([t.latency_s for t in ints])
    return {
        "interactive_p50_ms": float(np.percentile(int_lat, 50) * 1e3),
        "interactive_p99_ms": float(np.percentile(int_lat, 99) * 1e3),
        "n_interactive": len(ints),
        "n_analytics": len(all_t) - len(ints),
        "n_shed": n_shed,
        "n_degraded_replayed": n_degraded,
        "lane_flushes": m["lane_flushes"],
        "deadline_miss_rate": m["deadline_miss_rate"],
        "overload_score_last": m["overload_score"],
        "versions_published": len(store),
        "wall_s": wall,
    }


def _qos_warm_jit(cfg: ServeDemoConfig, obs: Obs) -> None:
    """Warm the module-level jit cache over every (request bucket,
    capacity) pair the A/B will hit — including capacities only reached
    by the MID-PHASE tail publishes.  The arms share one process-wide
    cache, so whichever ran first would otherwise pay every compile and
    the p99 comparison would measure compile order, not scheduling.
    Training is deterministic, so a throwaway run discovers the exact
    capacity sequence both arms will publish."""
    x, _, _ = dp_stick_breaking_data(cfg.qos_n, seed=cfg.seed + 999,
                                     dim=cfg.dim)
    x = jnp.asarray(x)
    store = SnapshotStore(capacity=256)
    eng = OCCEngine(DPMeansTransaction(cfg.lam, k_max=cfg.k_max),
                    pb=cfg.pb, validate_cap="adaptive",
                    publish=store.publish_pass, obs=obs)
    for j in range(0, cfg.qos_n, cfg.train_batch):
        eng.partial_fit(x[j:j + cfg.train_batch])
    eng.flush()
    snaps = {}
    for v in store.versions():
        snap = store.get(v)
        snaps[snap.capacity] = snap
    for snap in snaps.values():
        kk = min(8, snap.capacity)
        for b in (8, 16, 32, 64, 128):
            xq = jnp.zeros((b, x.shape[1]), x.dtype)
            _assign_step(snap.centers, snap.mask, np.int32(snap.count), xq,
                         np.int32(b), backend=cfg.backend)
            _topk_step(snap.centers, snap.mask, np.int32(snap.count), xq,
                       np.int32(b), k=kk, backend=cfg.backend)


def _qos_mix(cfg: ServeDemoConfig, obs: Obs) -> dict:
    """The §17 A/B: identical offered load against priority lanes vs the
    legacy FIFO baseline; priority lanes must win interactive p99
    STRICTLY, shedding must have fired (and only in the QoS arm — FIFO
    is the faithful legacy policy, which never sheds)."""
    _qos_warm_jit(cfg, obs)
    # A discarded warm arm absorbs every first-run cost the jit prewarm
    # can't (thread ramp, first flush/shed paths, allocator warmth) so
    # neither MEASURED arm pays for running first.
    warm_cfg = dataclasses.replace(cfg, qos_interactive_requests=10,
                                   qos_analytics_requests=3)
    _qos_mode(warm_cfg, obs, _qos_schedule(warm_cfg), priority_lanes=True,
              tag="qos-warm")
    sched = _qos_schedule(cfg)
    qos = _qos_mode(cfg, obs, sched, priority_lanes=True)
    fifo = _qos_mode(cfg, obs, sched, priority_lanes=False)
    assert qos["interactive_p99_ms"] < fifo["interactive_p99_ms"], (
        f"priority lanes did not beat FIFO: "
        f"{qos['interactive_p99_ms']:.2f}ms vs "
        f"{fifo['interactive_p99_ms']:.2f}ms")
    assert qos["n_shed"] > 0, "overload shedding never fired in the QoS arm"
    assert fifo["n_shed"] == 0, "the FIFO baseline must never shed"
    return {"qos": qos, "fifo": fifo,
            "interactive_p99_speedup":
                fifo["interactive_p99_ms"] / qos["interactive_p99_ms"]}


def run_demo(cfg: ServeDemoConfig) -> dict:
    assert cfg.n_models >= 2, "the scale-out audit needs >= 2 tenants"
    assert cfg.max_request <= cfg.coalesce_bucket
    # ONE shared Obs: trainer engines and every tenant's service land in a
    # single registry / trace file (tracer only when --trace-out asked).
    obs = Obs(tracer=Tracer("serve_clusters") if cfg.trace_out else None,
              trace_path=cfg.trace_out)
    serve_cfg = ServeConfig(backend=cfg.backend, coalesce=True,
                            coalesce_bucket=cfg.coalesce_bucket,
                            coalesce_delay_ms=cfg.coalesce_delay_ms,
                            audit_log=True,
                            max_bucket=max(128, cfg.coalesce_bucket))
    router = ModelRouter(serve_cfg, obs=obs)
    names = [chr(ord("a") + i) for i in range(cfg.n_models)]
    tenants = {nm: _make_tenant(nm, i, cfg, router, obs)
               for i, nm in enumerate(names)}

    # First batch per tenant before any client starts, so every model has a
    # version (and the jit caches warm under measurement, as in production).
    for tn in tenants.values():
        tn.engine.partial_fit(tn.batches[0])
        tn.batches = tn.batches[1:]

    trainers = [threading.Thread(target=_trainer,
                                 args=(tn, router.service(tn.name)),
                                 daemon=True)
                for tn in tenants.values()]

    # ---------------------------------------------------------------- serve
    traces: list[list[_Trace]] = [[] for _ in range(cfg.n_clients)]
    stop = threading.Event()

    def client(ci: int):
        rng = np.random.default_rng(cfg.seed + 1000 + ci)
        mine = traces[ci]
        while not stop.is_set():
            nm = names[int(rng.integers(0, cfg.n_models))]
            tn = tenants[nm]
            size = int(rng.integers(1, cfg.max_request + 1))
            lo = int(rng.integers(0, cfg.n - size))
            t0 = time.perf_counter()
            resp = router.score(nm, tn.x[lo:lo + size])
            dt = time.perf_counter() - t0
            mine.append(_Trace(nm, resp.version, lo, lo + size, resp.labels,
                               resp.scores, resp.bucket, resp.group,
                               resp.offset, dt, ci))

    t_serve0 = time.perf_counter()
    for t in trainers:
        t.start()
    clients = [threading.Thread(target=client, args=(ci,), daemon=True)
               for ci in range(cfg.n_clients)]
    for c in clients:
        c.start()

    def floors_met() -> bool:
        rows = sum(t.q_hi - t.q_lo for ts in traces for t in ts)
        if rows < cfg.min_queries:
            return False
        for nm in names:
            seen = {t.version for ts in traces for t in ts if t.model == nm}
            if len(seen) < cfg.min_versions:
                return False
        return True

    while any(t.is_alive() for t in trainers) or not floors_met():
        time.sleep(0.005)
        if time.perf_counter() - t_serve0 > 180:
            break    # safety valve; the audit below still decides pass/fail
    for t in trainers:
        t.join()
    stop.set()
    for c in clients:
        c.join()
    serve_wall = time.perf_counter() - t_serve0
    all_traces = [t for ts in traces for t in ts]
    n_rows = sum(t.q_hi - t.q_lo for t in all_traces)

    # ---------------------------------------------------------------- audit
    # Versions monotone per (client, model) — each client's requests are
    # sequential, so the hot-swap point can only move forward for it.
    for ts in traces:
        last: dict[str, int] = {}
        for t in ts:
            assert t.version >= last.get(t.model, -1), \
                "stale read: version went backwards for a client"
            last[t.model] = t.version
    versions_observed = {nm: sorted({t.version for t in all_traces
                                     if t.model == nm}) for nm in names}
    for nm, vs in versions_observed.items():
        assert len(vs) >= cfg.min_versions, (
            f"model {nm}: only {len(vs)} versions observed under load")

    # Zero stale reads: replay every coalesced dispatch from its tagged
    # (model, version) snapshot through the service's own jitted step —
    # exact padded inputs from the audit log, bit-exact member slices.
    by_group: dict[tuple[str, int], list[_Trace]] = {}
    for t in all_traces:
        by_group.setdefault((t.model, t.group), []).append(t)
    stale = parity = 0
    n_replayed = 0
    for nm in names:
        tn = tenants[nm]
        svc = router.service(nm)
        for rec in svc.audit:
            members = by_group.get((nm, rec.group), [])
            if not members:
                continue
            snap = tn.store.get(rec.version)
            assert snap is not None, "audited version evicted — grow the ring"
            d2, idx = _assign_step(snap.centers, snap.mask,
                                   np.int32(snap.count), jnp.asarray(rec.x),
                                   np.int32(rec.n_valid), backend=cfg.backend)
            d2, idx = np.asarray(d2), np.asarray(idx)
            for t in members:
                sl = slice(t.offset, t.offset + (t.q_hi - t.q_lo))
                if not (np.array_equal(t.labels, idx[sl])
                        and np.array_equal(t.scores, d2[sl])):
                    stale += 1
                n_replayed += 1
        # serve == train + isolation: labels bit-identical to engine labels
        # on the tagged MODEL's snapshot (nearest_center on its pool).
        for t in (t for t in all_traces if t.model == nm):
            snap = tn.store.get(t.version)
            _, ide = nearest_center(snap.as_pool(), tn.x[t.q_lo:t.q_hi],
                                    backend="ref")
            if not np.array_equal(t.labels, np.asarray(ide)):
                parity += 1
    assert n_replayed == len(all_traces), "audit log lost a dispatch"
    assert stale == 0, f"{stale} responses not reproducible from their tag"
    assert parity == 0, f"{parity} responses diverge from engine labels"

    # Delta publication: every version materializes bit-identically from
    # the delta log and from the eager shadow copy of the same pass.
    for nm in names:
        tn = tenants[nm]
        assert tn.store.versions() == tn.shadow.versions()
        for v in tn.store.versions():
            sd, se = tn.store.get(v), tn.shadow.get(v)
            assert sd.count == se.count and sd.capacity == se.capacity
            np.testing.assert_array_equal(np.asarray(sd.centers),
                                          np.asarray(se.centers))

    # stream == one-shot (the carry satellite, end to end; tenant 0)
    tn0 = tenants[names[0]]
    one = OCCEngine(DPMeansTransaction(cfg.lam, k_max=cfg.k_max),
                    pb=cfg.pb).run(tn0.x)
    assert int(one.pool.count) == int(tn0.engine.pool.count)
    np.testing.assert_array_equal(np.asarray(one.pool.centers),
                                  np.asarray(tn0.engine.pool.centers))

    # Coalescing pays: replay the same request trace solo (no admission
    # queue) against the same stores and compare bucket-fill ratios.
    fill_coalesced = router.metrics()["bucket_fill_ratio"]
    solo = {nm: ClusterService(
                tenants[nm].store,
                serve_cfg.replace(coalesce=False, audit_log=False))
            for nm in names}
    for t in all_traces:
        solo[t.model].score(tenants[t.model].x[t.q_lo:t.q_hi])
    solo_rows = sum(s.n_queries for s in solo.values())
    solo_padded = sum(s.n_padded_rows for s in solo.values())
    fill_solo = solo_rows / max(1, solo_padded)
    assert fill_coalesced > fill_solo, (
        f"coalescing did not improve bucket fill: "
        f"{fill_coalesced:.3f} vs solo {fill_solo:.3f}")

    # Adversarial mixed-traffic QoS A/B (§17): same offered load, lanes
    # vs legacy FIFO, with shed + degraded-replay audits inside.
    qos_ab = _qos_mix(cfg, obs)

    lat = np.asarray([t.latency_s for t in all_traces])
    m = router.metrics()
    record = {
        "bench": "cluster_service",
        "n_models": cfg.n_models,
        "n_train_per_model": cfg.n, "pb": cfg.pb,
        "train_batch": cfg.train_batch,
        "k_final": {nm: int(tenants[nm].engine.pool.count) for nm in names},
        "n_queries": m["n_queries"],
        "n_requests": m["n_requests"],
        "n_microbatches": m["n_microbatches"],
        "query_step_compiles": m["query_step_compiles"],
        "n_versions_published": {nm: len(tenants[nm].store) for nm in names},
        "n_versions_observed": {nm: len(versions_observed[nm])
                                for nm in names},
        "delta_rows_published": {nm: tenants[nm].store.delta_rows_published
                                 for nm in names},
        "zero_stale_reads": stale == 0,
        "serve_train_parity": parity == 0,
        "bucket_fill_coalesced": fill_coalesced,
        "bucket_fill_solo": fill_solo,
        "requests_per_group": {
            nm: m["models"][nm]["requests_per_group"] for nm in names},
        "n_deadline_flushes": {
            nm: m["models"][nm]["n_deadline_flushes"] for nm in names},
        "cap_trace_latest": {
            nm: m["models"][nm]["cap_trace"] for nm in names},
        "qps": n_rows / serve_wall,
        "p50_latency_ms": float(np.percentile(lat, 50) * 1e3),
        "p99_latency_ms": float(np.percentile(lat, 99) * 1e3),
        "qos_ab": qos_ab,
    }
    router.close()
    obs.flush()
    if cfg.out_path is not None:
        with open(cfg.out_path, "w") as f:
            json.dump(record, f, indent=2)
    if not cfg.quiet:
        ks = ", ".join(f"{nm}:K={record['k_final'][nm]}" for nm in names)
        print(f"trained {cfg.n_models} models ({ks}) over {cfg.n} streamed "
              f"points each; versions published: "
              f"{record['n_versions_published']}")
        print(f"served {record['n_queries']} rows / {record['n_requests']} "
              f"requests in {record['n_microbatches']} microbatches across "
              f"{ {nm: len(v) for nm, v in versions_observed.items()} } "
              f"hot-swapped versions")
        print(f"bucket fill: coalesced={fill_coalesced:.3f} vs "
              f"solo={fill_solo:.3f}  "
              f"(requests/group: {record['requests_per_group']})")
        print(f"QPS={record['qps']:.0f}  p50={record['p50_latency_ms']:.2f}ms"
              f"  p99={record['p99_latency_ms']:.2f}ms")
        print("zero stale reads: True   serve==train bit-parity: True   "
              "delta==eager bit-identity: True")
        q, f = qos_ab["qos"], qos_ab["fifo"]
        print(f"QoS A/B: interactive p99 lanes="
              f"{q['interactive_p99_ms']:.2f}ms vs fifo="
              f"{f['interactive_p99_ms']:.2f}ms "
              f"({qos_ab['interactive_p99_speedup']:.1f}x); "
              f"shed={q['n_shed']} (all degraded replay bit-exact), "
              f"fifo shed={f['n_shed']}")
    return record


def main(argv=None):
    ap = argparse.ArgumentParser(description=__doc__.split("\n")[0])
    ap.add_argument("--n", type=int, default=8192)
    ap.add_argument("--models", type=int, default=2)
    ap.add_argument("--pb", type=int, default=128)
    ap.add_argument("--train-batch", type=int, default=384)
    ap.add_argument("--queries", type=int, default=10_000)
    ap.add_argument("--backend", default="auto")
    # ServeConfig-backed QoS knobs (§17) — the same fields the services
    # are constructed from, so CLI and library cannot drift.
    ap.add_argument("--shed-depth", type=int,
                    default=ServeDemoConfig.qos_shed_depth,
                    help="queued rows at which shedding starts "
                         "(ServeConfig.shed_depth)")
    ap.add_argument("--interactive-deadline-ms", type=float,
                    default=ServeDemoConfig.qos_interactive_deadline_ms,
                    help="interactive lane deadline in the QoS A/B")
    ap.add_argument("--analytics-deadline-ms", type=float,
                    default=ServeDemoConfig.qos_analytics_deadline_ms,
                    help="analytics lane deadline in the QoS A/B")
    ap.add_argument("--quick", action="store_true",
                    help="CI smoke sizes (numbers not meaningful)")
    ap.add_argument("--out", default=None,
                    help="write BENCH_cluster_service.json here")
    ap.add_argument("--trace-out", default=None,
                    help="write a Perfetto/Chrome trace JSON here")
    args = ap.parse_args(argv)
    cfg = ServeDemoConfig(n=args.n, n_models=args.models, pb=args.pb,
                          train_batch=args.train_batch,
                          min_queries=args.queries, backend=args.backend,
                          out_path=args.out, trace_out=args.trace_out)
    if args.quick:
        cfg = ServeDemoConfig(n=1024, n_models=max(2, args.models), pb=64,
                              train_batch=200, dim=8, min_queries=600,
                              max_request=16, k_max=256, n_clients=12,
                              coalesce_bucket=64, coalesce_delay_ms=8.0,
                              qos_n=1024, qos_interactive_clients=6,
                              qos_analytics_clients=2,
                              qos_interactive_requests=60,
                              qos_analytics_requests=12,
                              qos_analytics_deadline_ms=150.0,
                              backend=args.backend, out_path=args.out,
                              trace_out=args.trace_out)
    cfg.qos_shed_depth = args.shed_depth
    cfg.qos_interactive_deadline_ms = args.interactive_deadline_ms
    if not args.quick:
        cfg.qos_analytics_deadline_ms = args.analytics_deadline_ms
    run_demo(cfg)


if __name__ == "__main__":
    main()

"""Train-on-stream, serve-while-training: the full train→publish→serve
pipeline, scaled out to many tenants (DESIGN.md §10/§12).

Per model, a trainer thread streams batches through `OCCEngine.partial_fit`
(arbitrary batch lengths — the partial-epoch carry keeps the stream
bit-identical to a one-shot run) and publishes one immutable version per
committed pass through the DELTA log (O(ΔK·D) per publish), mirrored into
an eager shadow store so the audit can prove delta-materialize ==
eager-copy bit-identity on the live stream.  Concurrently, a pool of
client threads runs a load generator against a `ModelRouter` fronting all
tenants with admission-queue coalescing enabled: ragged request sizes,
concurrent small requests merged into fuller microbatches under the
deadline-or-full policy, one jitted dispatch per microbatch, atomic
hot-swap per model between requests.

After the run, every response is audited:
  * zero stale reads — every coalesced dispatch is replayed from its
    tagged (model, version) snapshot through the service's own jitted
    step (`DispatchRecord` holds the exact padded inputs) and must
    reproduce each member response bit-exactly; versions observed by any
    single client are monotone per model;
  * multi-model isolation + serve == train — response labels are
    bit-identical to engine labels (`core.occ.nearest_center` on the
    tagged model's snapshot pool), per (model, version);
  * delta publication — every published version materializes
    bit-identically from the delta log and from the eager shadow copy;
  * coalescing pays — the same request trace replayed solo (no admission
    queue) must show a WORSE bucket-fill ratio than the coalesced run;
  * ≥ 2 models, ≥ `min_versions` hot-swapped through per model,
    ≥ `min_queries` total rows (full mode: 10k).

p50/p99 latency, QPS, and both fill ratios land in
BENCH_cluster_service.json.

  PYTHONPATH=src python -m repro.launch.serve_clusters [--quick]
"""
from __future__ import annotations

import argparse
import json
import threading
import time
from dataclasses import dataclass, field

import jax.numpy as jnp
import numpy as np

from repro.core import DPMeansTransaction, OCCEngine
from repro.core.occ import nearest_center
from repro.data import dp_stick_breaking_data
from repro.obs import Obs, Tracer
from repro.serving import ClusterService, ModelRouter, SnapshotStore
from repro.serving.cluster_service import _assign_step

__all__ = ["ServeDemoConfig", "run_demo"]


@dataclass
class ServeDemoConfig:
    n: int = 8192              # stream length PER MODEL
    dim: int = 16
    n_models: int = 2
    lam: float = 4.0
    k_max: int = 512
    pb: int = 128              # points per OCC epoch
    train_batch: int = 384     # NOT a multiple of pb: exercises the carry
    min_queries: int = 10_000  # load-generator floor (rows, all models)
    max_request: int = 32      # ragged request sizes in [1, max_request]
    # Closed-loop load: the queue depth per model is ~ n_clients/n_models
    # blocked requests, so the coalesce bucket is sized to that row supply
    # (a bucket far above it turns every flush into a half-empty deadline
    # flush and coalescing stops paying).
    n_clients: int = 16        # concurrent load-generator threads
    coalesce_bucket: int = 64
    coalesce_delay_ms: float = 10.0
    backend: str = "auto"      # service kernel backend
    min_versions: int = 3      # hot-swap floor per model under load
    seed: int = 0
    out_path: str | None = None
    trace_out: str | None = None   # Perfetto JSON of the whole run
    quiet: bool = False


@dataclass
class _Trace:
    """One served request, as recorded by a load-generator client."""
    model: str
    version: int
    q_lo: int
    q_hi: int
    labels: np.ndarray
    scores: np.ndarray
    bucket: int
    group: int
    offset: int
    latency_s: float = 0.0
    client: int = 0


@dataclass
class _Tenant:
    name: str
    x: jnp.ndarray
    engine: OCCEngine
    store: SnapshotStore          # the router's delta store
    shadow: SnapshotStore         # eager shadow for the delta audit
    batches: list = field(default_factory=list)


def _trainer(tn: _Tenant, svc: ClusterService,
             pace_microbatches: int = 2, timeout_s: float = 5.0):
    """Stream batches through partial_fit; between publishes, wait until the
    service has answered a couple more microbatches so every version is
    actually *observed* under load (deterministic interleaving, no sleeps
    tuned to machine speed)."""
    for xb in tn.batches:
        seen = svc.n_microbatches
        tn.engine.partial_fit(xb)
        deadline = time.perf_counter() + timeout_s
        while (svc.n_microbatches < seen + pace_microbatches
               and time.perf_counter() < deadline):
            time.sleep(0.001)
    tn.engine.flush()


def _make_tenant(name: str, i: int, cfg: ServeDemoConfig,
                 router: ModelRouter, obs: Obs) -> _Tenant:
    x, _, _ = dp_stick_breaking_data(cfg.n, seed=cfg.seed + 17 * i,
                                     dim=cfg.dim)
    x = jnp.asarray(x)
    store = router.add_model(name, snapshot_capacity=256, delta=True)
    shadow = SnapshotStore(capacity=256)

    def publish(res, **kw):
        store.publish_pass(res, **kw)
        shadow.publish_pass(res, **kw)

    eng = OCCEngine(
        DPMeansTransaction(cfg.lam * (1.0 + 0.25 * i), k_max=cfg.k_max),
        pb=cfg.pb, validate_cap="adaptive", publish=publish, obs=obs)
    batches = [x[j:j + cfg.train_batch]
               for j in range(0, cfg.n, cfg.train_batch)]
    return _Tenant(name, x, eng, store, shadow, batches)


def run_demo(cfg: ServeDemoConfig) -> dict:
    assert cfg.n_models >= 2, "the scale-out audit needs >= 2 tenants"
    assert cfg.max_request <= cfg.coalesce_bucket
    # ONE shared Obs: trainer engines and every tenant's service land in a
    # single registry / trace file (tracer only when --trace-out asked).
    obs = Obs(tracer=Tracer("serve_clusters") if cfg.trace_out else None,
              trace_path=cfg.trace_out)
    router = ModelRouter(backend=cfg.backend, coalesce=True,
                         coalesce_bucket=cfg.coalesce_bucket,
                         coalesce_delay_ms=cfg.coalesce_delay_ms,
                         audit_log=True,
                         max_bucket=max(128, cfg.coalesce_bucket),
                         obs=obs)
    names = [chr(ord("a") + i) for i in range(cfg.n_models)]
    tenants = {nm: _make_tenant(nm, i, cfg, router, obs)
               for i, nm in enumerate(names)}

    # First batch per tenant before any client starts, so every model has a
    # version (and the jit caches warm under measurement, as in production).
    for tn in tenants.values():
        tn.engine.partial_fit(tn.batches[0])
        tn.batches = tn.batches[1:]

    trainers = [threading.Thread(target=_trainer,
                                 args=(tn, router.service(tn.name)),
                                 daemon=True)
                for tn in tenants.values()]

    # ---------------------------------------------------------------- serve
    traces: list[list[_Trace]] = [[] for _ in range(cfg.n_clients)]
    stop = threading.Event()

    def client(ci: int):
        rng = np.random.default_rng(cfg.seed + 1000 + ci)
        mine = traces[ci]
        while not stop.is_set():
            nm = names[int(rng.integers(0, cfg.n_models))]
            tn = tenants[nm]
            size = int(rng.integers(1, cfg.max_request + 1))
            lo = int(rng.integers(0, cfg.n - size))
            t0 = time.perf_counter()
            resp = router.score(nm, tn.x[lo:lo + size])
            dt = time.perf_counter() - t0
            mine.append(_Trace(nm, resp.version, lo, lo + size, resp.labels,
                               resp.scores, resp.bucket, resp.group,
                               resp.offset, dt, ci))

    t_serve0 = time.perf_counter()
    for t in trainers:
        t.start()
    clients = [threading.Thread(target=client, args=(ci,), daemon=True)
               for ci in range(cfg.n_clients)]
    for c in clients:
        c.start()

    def floors_met() -> bool:
        rows = sum(t.q_hi - t.q_lo for ts in traces for t in ts)
        if rows < cfg.min_queries:
            return False
        for nm in names:
            seen = {t.version for ts in traces for t in ts if t.model == nm}
            if len(seen) < cfg.min_versions:
                return False
        return True

    while any(t.is_alive() for t in trainers) or not floors_met():
        time.sleep(0.005)
        if time.perf_counter() - t_serve0 > 180:
            break    # safety valve; the audit below still decides pass/fail
    for t in trainers:
        t.join()
    stop.set()
    for c in clients:
        c.join()
    serve_wall = time.perf_counter() - t_serve0
    all_traces = [t for ts in traces for t in ts]
    n_rows = sum(t.q_hi - t.q_lo for t in all_traces)

    # ---------------------------------------------------------------- audit
    # Versions monotone per (client, model) — each client's requests are
    # sequential, so the hot-swap point can only move forward for it.
    for ts in traces:
        last: dict[str, int] = {}
        for t in ts:
            assert t.version >= last.get(t.model, -1), \
                "stale read: version went backwards for a client"
            last[t.model] = t.version
    versions_observed = {nm: sorted({t.version for t in all_traces
                                     if t.model == nm}) for nm in names}
    for nm, vs in versions_observed.items():
        assert len(vs) >= cfg.min_versions, (
            f"model {nm}: only {len(vs)} versions observed under load")

    # Zero stale reads: replay every coalesced dispatch from its tagged
    # (model, version) snapshot through the service's own jitted step —
    # exact padded inputs from the audit log, bit-exact member slices.
    by_group: dict[tuple[str, int], list[_Trace]] = {}
    for t in all_traces:
        by_group.setdefault((t.model, t.group), []).append(t)
    stale = parity = 0
    n_replayed = 0
    for nm in names:
        tn = tenants[nm]
        svc = router.service(nm)
        for rec in svc.audit:
            members = by_group.get((nm, rec.group), [])
            if not members:
                continue
            snap = tn.store.get(rec.version)
            assert snap is not None, "audited version evicted — grow the ring"
            d2, idx = _assign_step(snap.centers, snap.mask,
                                   np.int32(snap.count), jnp.asarray(rec.x),
                                   np.int32(rec.n_valid), backend=cfg.backend)
            d2, idx = np.asarray(d2), np.asarray(idx)
            for t in members:
                sl = slice(t.offset, t.offset + (t.q_hi - t.q_lo))
                if not (np.array_equal(t.labels, idx[sl])
                        and np.array_equal(t.scores, d2[sl])):
                    stale += 1
                n_replayed += 1
        # serve == train + isolation: labels bit-identical to engine labels
        # on the tagged MODEL's snapshot (nearest_center on its pool).
        for t in (t for t in all_traces if t.model == nm):
            snap = tn.store.get(t.version)
            _, ide = nearest_center(snap.as_pool(), tn.x[t.q_lo:t.q_hi],
                                    backend="ref")
            if not np.array_equal(t.labels, np.asarray(ide)):
                parity += 1
    assert n_replayed == len(all_traces), "audit log lost a dispatch"
    assert stale == 0, f"{stale} responses not reproducible from their tag"
    assert parity == 0, f"{parity} responses diverge from engine labels"

    # Delta publication: every version materializes bit-identically from
    # the delta log and from the eager shadow copy of the same pass.
    for nm in names:
        tn = tenants[nm]
        assert tn.store.versions() == tn.shadow.versions()
        for v in tn.store.versions():
            sd, se = tn.store.get(v), tn.shadow.get(v)
            assert sd.count == se.count and sd.capacity == se.capacity
            np.testing.assert_array_equal(np.asarray(sd.centers),
                                          np.asarray(se.centers))

    # stream == one-shot (the carry satellite, end to end; tenant 0)
    tn0 = tenants[names[0]]
    one = OCCEngine(DPMeansTransaction(cfg.lam, k_max=cfg.k_max),
                    pb=cfg.pb).run(tn0.x)
    assert int(one.pool.count) == int(tn0.engine.pool.count)
    np.testing.assert_array_equal(np.asarray(one.pool.centers),
                                  np.asarray(tn0.engine.pool.centers))

    # Coalescing pays: replay the same request trace solo (no admission
    # queue) against the same stores and compare bucket-fill ratios.
    fill_coalesced = router.metrics()["bucket_fill_ratio"]
    solo = {nm: ClusterService(tenants[nm].store, backend=cfg.backend,
                               min_bucket=8,
                               max_bucket=max(128, cfg.coalesce_bucket))
            for nm in names}
    for t in all_traces:
        solo[t.model].score(tenants[t.model].x[t.q_lo:t.q_hi])
    solo_rows = sum(s.n_queries for s in solo.values())
    solo_padded = sum(s.n_padded_rows for s in solo.values())
    fill_solo = solo_rows / max(1, solo_padded)
    assert fill_coalesced > fill_solo, (
        f"coalescing did not improve bucket fill: "
        f"{fill_coalesced:.3f} vs solo {fill_solo:.3f}")

    lat = np.asarray([t.latency_s for t in all_traces])
    m = router.metrics()
    record = {
        "bench": "cluster_service",
        "n_models": cfg.n_models,
        "n_train_per_model": cfg.n, "pb": cfg.pb,
        "train_batch": cfg.train_batch,
        "k_final": {nm: int(tenants[nm].engine.pool.count) for nm in names},
        "n_queries": m["n_queries"],
        "n_requests": m["n_requests"],
        "n_microbatches": m["n_microbatches"],
        "query_step_compiles": m["query_step_compiles"],
        "n_versions_published": {nm: len(tenants[nm].store) for nm in names},
        "n_versions_observed": {nm: len(versions_observed[nm])
                                for nm in names},
        "delta_rows_published": {nm: tenants[nm].store.delta_rows_published
                                 for nm in names},
        "zero_stale_reads": stale == 0,
        "serve_train_parity": parity == 0,
        "bucket_fill_coalesced": fill_coalesced,
        "bucket_fill_solo": fill_solo,
        "requests_per_group": {
            nm: m["models"][nm]["requests_per_group"] for nm in names},
        "n_deadline_flushes": {
            nm: m["models"][nm]["n_deadline_flushes"] for nm in names},
        "cap_trace_latest": {
            nm: m["models"][nm]["cap_trace"] for nm in names},
        "qps": n_rows / serve_wall,
        "p50_latency_ms": float(np.percentile(lat, 50) * 1e3),
        "p99_latency_ms": float(np.percentile(lat, 99) * 1e3),
    }
    router.close()
    obs.flush()
    if cfg.out_path is not None:
        with open(cfg.out_path, "w") as f:
            json.dump(record, f, indent=2)
    if not cfg.quiet:
        ks = ", ".join(f"{nm}:K={record['k_final'][nm]}" for nm in names)
        print(f"trained {cfg.n_models} models ({ks}) over {cfg.n} streamed "
              f"points each; versions published: "
              f"{record['n_versions_published']}")
        print(f"served {record['n_queries']} rows / {record['n_requests']} "
              f"requests in {record['n_microbatches']} microbatches across "
              f"{ {nm: len(v) for nm, v in versions_observed.items()} } "
              f"hot-swapped versions")
        print(f"bucket fill: coalesced={fill_coalesced:.3f} vs "
              f"solo={fill_solo:.3f}  "
              f"(requests/group: {record['requests_per_group']})")
        print(f"QPS={record['qps']:.0f}  p50={record['p50_latency_ms']:.2f}ms"
              f"  p99={record['p99_latency_ms']:.2f}ms")
        print("zero stale reads: True   serve==train bit-parity: True   "
              "delta==eager bit-identity: True")
    return record


def main(argv=None):
    ap = argparse.ArgumentParser(description=__doc__.split("\n")[0])
    ap.add_argument("--n", type=int, default=8192)
    ap.add_argument("--models", type=int, default=2)
    ap.add_argument("--pb", type=int, default=128)
    ap.add_argument("--train-batch", type=int, default=384)
    ap.add_argument("--queries", type=int, default=10_000)
    ap.add_argument("--backend", default="auto")
    ap.add_argument("--quick", action="store_true",
                    help="CI smoke sizes (numbers not meaningful)")
    ap.add_argument("--out", default=None,
                    help="write BENCH_cluster_service.json here")
    ap.add_argument("--trace-out", default=None,
                    help="write a Perfetto/Chrome trace JSON here")
    args = ap.parse_args(argv)
    cfg = ServeDemoConfig(n=args.n, n_models=args.models, pb=args.pb,
                          train_batch=args.train_batch,
                          min_queries=args.queries, backend=args.backend,
                          out_path=args.out, trace_out=args.trace_out)
    if args.quick:
        cfg = ServeDemoConfig(n=1024, n_models=max(2, args.models), pb=64,
                              train_batch=200, dim=8, min_queries=600,
                              max_request=16, k_max=256, n_clients=12,
                              coalesce_bucket=64, coalesce_delay_ms=8.0,
                              backend=args.backend, out_path=args.out,
                              trace_out=args.trace_out)
    run_demo(cfg)


if __name__ == "__main__":
    main()

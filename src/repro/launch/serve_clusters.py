"""Train-on-stream, serve-while-training: the first full train→publish→serve
pipeline (DESIGN.md §10).

A trainer thread streams batches through `OCCEngine.partial_fit` (arbitrary
batch lengths — the partial-epoch carry keeps the stream bit-identical to a
one-shot run) and publishes an immutable `ModelSnapshot` per committed
pass.  Concurrently, the main thread runs a load generator against a
`ClusterService`: ragged request sizes, pad-to-bucket microbatching, one
jitted dispatch per microbatch, atomic hot-swap to newer versions between
requests.

After the run, every response is audited:
  * zero stale reads — replaying the tagged version's snapshot through the
    service's own jitted step reproduces each response bit-exactly, and
    observed versions are monotone;
  * serve == train — response labels are bit-identical to engine labels
    (`core.occ.nearest_center` on the tagged snapshot's pool);
  * ≥ 3 versions hot-swapped through, ≥ 10k queries (full mode).

p50/p99 latency and QPS land in BENCH_cluster_service.json.

  PYTHONPATH=src python -m repro.launch.serve_clusters [--quick]
"""
from __future__ import annotations

import argparse
import json
import os
import threading
import time
from dataclasses import dataclass, field

import jax.numpy as jnp
import numpy as np

from repro.core import DPMeansTransaction, OCCEngine
from repro.core.occ import nearest_center
from repro.data import dp_stick_breaking_data
from repro.serving import ClusterService, SnapshotStore, next_bucket
from repro.serving.cluster_service import _assign_step

__all__ = ["ServeDemoConfig", "run_demo"]


@dataclass
class ServeDemoConfig:
    n: int = 8192              # stream length
    dim: int = 16
    lam: float = 4.0
    k_max: int = 512
    pb: int = 128              # points per OCC epoch
    train_batch: int = 384     # NOT a multiple of pb: exercises the carry
    min_queries: int = 10_000  # load-generator floor
    max_request: int = 100     # ragged request sizes in [1, max_request]
    backend: str = "auto"      # service kernel backend
    min_versions: int = 3      # hot-swap floor the service must observe
    seed: int = 0
    out_path: str | None = None
    quiet: bool = False


@dataclass
class _Trace:
    """One served request, as recorded by the load generator."""
    version: int
    q_lo: int
    q_hi: int
    labels: np.ndarray
    scores: np.ndarray
    bucket: int
    latency_s: float = 0.0
    order: int = 0
    extra: dict = field(default_factory=dict)


def _trainer(eng: OCCEngine, batches, svc: ClusterService,
             pace_microbatches: int = 2, timeout_s: float = 5.0):
    """Stream batches through partial_fit; between publishes, wait until the
    service has answered a couple more microbatches so every version is
    actually *observed* under load (deterministic interleaving, no sleeps
    tuned to machine speed)."""
    for xb in batches:
        seen = svc.n_microbatches
        eng.partial_fit(xb)
        deadline = time.perf_counter() + timeout_s
        while (svc.n_microbatches < seen + pace_microbatches
               and time.perf_counter() < deadline):
            time.sleep(0.001)
    eng.flush()


def run_demo(cfg: ServeDemoConfig) -> dict:
    x, _, _ = dp_stick_breaking_data(cfg.n, seed=cfg.seed, dim=cfg.dim)
    x = jnp.asarray(x)
    rng = np.random.default_rng(cfg.seed + 1)

    store = SnapshotStore(capacity=256)   # retain all versions for the audit
    eng = OCCEngine(DPMeansTransaction(cfg.lam, k_max=cfg.k_max), pb=cfg.pb,
                    publish=store.publish_pass)
    svc = ClusterService(store, backend=cfg.backend,
                         max_bucket=next_bucket(cfg.max_request, lo=128))

    batches = [x[i:i + cfg.train_batch]
               for i in range(0, cfg.n, cfg.train_batch)]
    # First batch before starting the thread so the service has a version
    # (and the jit caches warm under measurement, as in production).
    eng.partial_fit(batches[0])
    trainer = threading.Thread(
        target=_trainer, args=(eng, batches[1:], svc), daemon=True)

    # ---------------------------------------------------------------- serve
    traces: list[_Trace] = []
    t_serve0 = time.perf_counter()
    trainer.start()
    while (trainer.is_alive() or len(traces) == 0
           or sum(t.q_hi - t.q_lo for t in traces) < cfg.min_queries
           or len({t.version for t in traces}) < cfg.min_versions):
        size = int(rng.integers(1, cfg.max_request + 1))
        lo = int(rng.integers(0, cfg.n - size))
        q = x[lo:lo + size]
        t0 = time.perf_counter()
        resp = svc.score(q)
        dt = time.perf_counter() - t0
        traces.append(_Trace(resp.version, lo, lo + size, resp.labels,
                             resp.scores, resp.bucket, dt, len(traces)))
        if time.perf_counter() - t_serve0 > 120:
            break    # safety valve; the audit below still decides pass/fail
    serve_wall = time.perf_counter() - t_serve0
    trainer.join()

    # ---------------------------------------------------------------- audit
    versions = [t.version for t in traces]
    assert versions == sorted(versions), "stale read: version went backwards"
    n_versions = len(set(versions))
    assert n_versions >= cfg.min_versions, (
        f"only {n_versions} versions observed under load")

    stale = parity = 0
    for t in traces:
        snap = store.get(t.version)
        assert snap is not None, "audited version evicted — grow the ring"
        # zero stale reads: replaying the *tagged* snapshot through the
        # service's own jitted step must reproduce the response bit-exactly.
        nq = t.q_hi - t.q_lo
        qp = jnp.concatenate([x[t.q_lo:t.q_hi],
                              jnp.zeros((t.bucket - nq, cfg.dim), x.dtype)], 0)
        d2, idx = _assign_step(snap.centers, snap.mask, np.int32(snap.count),
                               qp, np.int32(nq), backend=cfg.backend)
        if not (np.array_equal(t.labels, np.asarray(idx[:nq]))
                and np.array_equal(t.scores, np.asarray(d2[:nq]))):
            stale += 1
        # serve == train: labels bit-identical to engine labels on the
        # same version (nearest_center on the snapshot's pool).
        _, ide = nearest_center(snap.as_pool(), x[t.q_lo:t.q_hi],
                                backend="ref")
        if not np.array_equal(t.labels, np.asarray(ide)):
            parity += 1
    assert stale == 0, f"{stale} responses not reproducible from their tag"
    assert parity == 0, f"{parity} responses diverge from engine labels"

    # stream == one-shot (the carry satellite, end to end)
    one = OCCEngine(DPMeansTransaction(cfg.lam, k_max=cfg.k_max),
                    pb=cfg.pb).run(x)
    assert int(one.pool.count) == int(eng.pool.count)
    np.testing.assert_array_equal(np.asarray(one.pool.centers),
                                  np.asarray(eng.pool.centers))

    lat = np.asarray([t.latency_s for t in traces])
    m = svc.metrics()
    record = {
        "bench": "cluster_service",
        "n_train": cfg.n, "pb": cfg.pb, "train_batch": cfg.train_batch,
        "k_final": int(eng.pool.count),
        "n_queries": m["n_queries"],
        "n_microbatches": m["n_microbatches"],
        "dispatches_per_microbatch": m["dispatches_per_microbatch"],
        "query_step_compiles": m["query_step_compiles"],
        "n_versions_published": len(store),
        "n_versions_observed": n_versions,
        "n_hot_swaps": m["n_swaps"],
        "zero_stale_reads": stale == 0,
        "serve_train_parity": parity == 0,
        "qps": m["n_queries"] / serve_wall,
        "p50_latency_ms": float(np.percentile(lat, 50) * 1e3),
        "p99_latency_ms": float(np.percentile(lat, 99) * 1e3),
        "bucket_hist": m["bucket_hist"],
    }
    if cfg.out_path is not None:
        with open(cfg.out_path, "w") as f:
            json.dump(record, f, indent=2)
    if not cfg.quiet:
        print(f"trained K={record['k_final']} over {cfg.n} streamed points "
              f"({len(store)} versions published)")
        print(f"served {record['n_queries']} queries in "
              f"{record['n_microbatches']} microbatches "
              f"({record['dispatches_per_microbatch']:.2f} dispatches each) "
              f"across {n_versions} hot-swapped versions")
        print(f"QPS={record['qps']:.0f}  p50={record['p50_latency_ms']:.2f}ms"
              f"  p99={record['p99_latency_ms']:.2f}ms")
        print("zero stale reads: True   serve==train bit-parity: True")
    return record


def main(argv=None):
    ap = argparse.ArgumentParser(description=__doc__.split("\n")[0])
    ap.add_argument("--n", type=int, default=8192)
    ap.add_argument("--pb", type=int, default=128)
    ap.add_argument("--train-batch", type=int, default=384)
    ap.add_argument("--queries", type=int, default=10_000)
    ap.add_argument("--backend", default="auto")
    ap.add_argument("--quick", action="store_true",
                    help="CI smoke sizes (numbers not meaningful)")
    ap.add_argument("--out", default=None,
                    help="write BENCH_cluster_service.json here")
    args = ap.parse_args(argv)
    cfg = ServeDemoConfig(n=args.n, pb=args.pb, train_batch=args.train_batch,
                          min_queries=args.queries, backend=args.backend,
                          out_path=args.out)
    if args.quick:
        cfg = ServeDemoConfig(n=1024, pb=64, train_batch=200, dim=8,
                              min_queries=400, max_request=50, k_max=256,
                              backend=args.backend, out_path=args.out)
    run_demo(cfg)


if __name__ == "__main__":
    main()

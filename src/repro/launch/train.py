"""End-to-end training driver.

  PYTHONPATH=src python -m repro.launch.train --arch granite-3-2b \
      --reduced --steps 50 --batch 8 --seq 128 --ckpt-dir /tmp/ckpt

Wires together: config registry -> model -> sharded train step -> token
pipeline -> checkpoint manager -> watchdog -> (optional) OCC data curation.
On this CPU container use --reduced; on a pod the full config + production
mesh engage via --mesh single|multi.
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import CheckpointManager
from repro.configs import TrainConfig, get_arch, reduced
from repro.data.tokens import TokenPipeline
from repro.distributed.fault import StepWatchdog
from repro.distributed.shardings import shard_ctx
from repro.launch.mesh import make_production_mesh
from repro.models import build_model
from repro.training.step import make_train_step, train_state_init


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--steps", type=int, default=20)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--resume", action="store_true")
    ap.add_argument("--mesh", choices=["none", "single", "multi"], default="none")
    ap.add_argument("--dtype", default=None)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--log-every", type=int, default=5)
    args = ap.parse_args(argv)

    arch = get_arch(args.arch)
    if args.reduced:
        arch = reduced(arch)
    if args.dtype:
        arch = arch.replace(dtype=args.dtype)
    elif jax.default_backend() == "cpu":
        arch = arch.replace(dtype="float32")

    mesh = None
    if args.mesh != "none":
        mesh = make_production_mesh(multi_pod=args.mesh == "multi")

    tcfg = TrainConfig(learning_rate=args.lr, warmup_steps=max(2, args.steps // 10),
                       total_steps=args.steps, microbatches=args.microbatches,
                       seed=args.seed)
    model = build_model(arch)
    pipe = TokenPipeline(arch.vocab, args.batch, args.seq, seed=args.seed)
    ckpt = CheckpointManager(args.ckpt_dir) if args.ckpt_dir else None
    watchdog = StepWatchdog()

    with shard_ctx(mesh):
        params = model.init(jax.random.key(args.seed))
        state = train_state_init(params, tcfg)
        start_step = 0
        if ckpt and args.resume and ckpt.latest_step() is not None:
            start_step, state = ckpt.restore(state)
            print(f"resumed from step {start_step}")
        step_fn = jax.jit(make_train_step(model, tcfg), donate_argnums=(0,))

        n_params = model.param_count(params)
        print(f"arch={arch.name} params={n_params:,} steps={args.steps} "
              f"batch={args.batch} seq={args.seq}")
        t_start = time.time()
        for step in range(start_step, args.steps):
            hb = pipe.batch_at(step)
            batch = {k: jnp.asarray(v) for k, v in hb.items()}
            if arch.frontend:
                rng = np.random.default_rng([args.seed, step])
                batch["frontend"] = jnp.asarray(rng.normal(
                    size=(args.batch, arch.frontend_len, arch.frontend_dim)
                ).astype(np.float32))
            t0 = time.time()
            state, metrics = step_fn(state, batch)
            loss = float(metrics["loss"])
            dt = time.time() - t0
            ev = watchdog.observe(step, dt)
            if ev:
                print(f"[straggler] step {step}: {dt:.2f}s vs ewma {ev.ewma:.2f}s")
            if step % args.log_every == 0 or step == args.steps - 1:
                toks = args.batch * args.seq
                print(f"step {step:5d} loss {loss:8.4f} "
                      f"gnorm {float(metrics['grad_norm']):7.3f} "
                      f"lr {float(metrics['lr']):.2e} {dt:6.2f}s "
                      f"({toks / max(dt, 1e-9):,.0f} tok/s)")
            if ckpt and (step + 1) % args.ckpt_every == 0:
                ckpt.save(step + 1, state)
        if ckpt:
            ckpt.save(args.steps, state)
            ckpt.wait()
        print(f"done in {time.time() - t_start:.1f}s; final loss {loss:.4f}")
        return loss


if __name__ == "__main__":
    main()

"""Crash-recoverable multi-process OCC: follower promotion + watermark
resume under a coordinator (§14).

`run_ha_cluster` grows `launch/occ_cluster.py`'s topology into a
highly-available one: R node processes (one master + R-1 socket-replicated
follower stores) and P propose workers, all brokered by a tiny coordinator
in the driver process that speaks only CTRL frames:

  * node 0 is PROMOTEd to master with term 1: it runs the serializing
    epoch loop (`OCCEngine.run_from_proposals` over a `_WorkerPlane`),
    publishes every epoch's pool delta through a `ReplicationServer`, and
    blocks each commit on `wait_acked` — the per-epoch replication
    barrier that makes the commit watermark exact;
  * when the master dies (chaos: a `FaultPlan` kill at the named point
    "master.commit", i.e. `os._exit` right after version v is fully
    acked) every follower's `ReplicationClient` sees a bare EOF — no FIN
    — and reports `orphaned(version)` to the coordinator.  The follower
    with the HIGHEST replicated version (ties → lowest node id) is
    PROMOTEd with term+1;
  * the promoted node seeds its server's shadow from its own replicated
    store (`seed_shadow`), wires the store onto the new server (version
    numbering continues — `apply_delta` advanced `_next_version`), opens
    a fresh worker plane, and resumes the pass with
    `run_from_proposals(x[v*pb:], epoch_base=v, pool=watermark pool)` —
    global epoch numbering, shard addressing and publish versions
    continue exactly where the dead master stopped;
  * workers outlive the master: on EOF they ask the coordinator
    "who is master with term > the one I lost?" (blocking CTRL query),
    reconnect to the new worker plane, take the promoted master's rebase
    broadcast, and keep proposing.  Stale-term frames are fenced at both
    workers and followers, so a zombie master cannot corrupt anyone;
  * every master exports each epoch's outputs BEFORE committing it: a
    sha256 digest of the (assign, send) block plus the epoch's OCCStats
    scalars, sent to the coordinator as CTRL "epoch" records.  The
    coordinator replays the uninterrupted single-process reference and
    checks every epoch digest, every stats triple, the final store digest
    and every surviving follower's digest — the whole killed-and-promoted
    run must be BIT-IDENTICAL to a run where nothing ever failed.

  PYTHONPATH=src python -m repro.launch.ha_cluster --quick \
      --nodes 3 --workers 2 --kill-after 6 --out BENCH_ha.json
"""
from __future__ import annotations

import argparse
import hashlib
import json
import multiprocessing as mp
import os
import socket
import tempfile
import threading
import time
from dataclasses import dataclass

import numpy as np

from repro.obs import Obs, Tracer, merge_traces

__all__ = ["HAConfig", "run_ha_cluster", "ha_node_main", "ha_worker_main"]


@dataclass
class HAConfig:
    n: int = 2048
    dim: int = 8
    lam: float = 3.0
    k_max: int = 128
    pb: int = 64                # points per epoch (split across workers)
    n_workers: int = 2
    n_nodes: int = 3            # 1 master + n_nodes-1 follower replicas
    validate_cap: int | None = None
    seed: int = 0
    model: str = "occ"
    snapshot_capacity: int = 256
    max_queue: int = 1024       # follower backpressure bound (§14)
    # chaos: SIGKILL-equivalent (os._exit 137) the term-1 master right
    # after version v is fully acked by every follower — the promotion
    # watermark is then exactly v, making the whole test deterministic.
    kill_master_after_version: int | None = None
    spawn_timeout_s: float = 180.0
    out_path: str | None = None
    # telemetry: every master phase appends its publishes to a DeltaWAL
    # under wal_dir; each process writes trace_dir/<proc>.json and the
    # driver merges them into trace_out (one Perfetto timeline — valid
    # because CLOCK_MONOTONIC is system-wide on Linux).
    wal_dir: str | None = None
    trace_dir: str | None = None
    trace_out: str | None = None
    quiet: bool = False

    def cluster_kw(self) -> dict:
        """The `ClusterConfig` projection every process derives its data,
        transaction and worker plane from (same seed ⇒ same points)."""
        return dict(n=self.n, dim=self.dim, lam=self.lam, k_max=self.k_max,
                    pb=self.pb, n_workers=self.n_workers, model=self.model,
                    seed=self.seed, validate_cap=self.validate_cap,
                    spawn_timeout_s=self.spawn_timeout_s, quiet=True)


def _outputs_digest(assign_e, send_e) -> str:
    """sha256 over an epoch's raw output block — equal digests across
    processes == bit-identical epoch outputs (assign may be a pytree:
    BP-means emits (pb, K) booleans; leaves hash in flatten order)."""
    import jax
    h = hashlib.sha256()
    for leaf in jax.tree_util.tree_leaves(assign_e):
        h.update(np.ascontiguousarray(np.asarray(leaf)).tobytes())
    h.update(np.ascontiguousarray(np.asarray(send_e)).tobytes())
    return h.hexdigest()


def _send_ctrl(sock: socket.socket, op: str, **fields) -> None:
    from repro.distributed.protocol import ctrl_frame, write_frame
    write_frame(sock, ctrl_frame(op, **fields))


def _read_ctrl(sock: socket.socket) -> dict | None:
    from repro.distributed.protocol import CTRL, read_frame
    fr = read_frame(sock)
    if fr is None:
        return None
    ftype, meta, _ = fr
    if ftype != CTRL:
        raise ValueError(f"expected CTRL frame, got type {ftype}")
    return meta


# ----------------------------------------------------------------- node side

def ha_node_main(cfg_kw: dict, node_id: int, coord_port: int) -> None:
    """One HA node process: follower by default, master when promoted.

    The node holds ONE delta-mode `SnapshotStore` for its whole life — as
    a follower it is the replication target; after a promotion the SAME
    store becomes the primary (its `_next_version` already continues the
    dead master's numbering).  The coordinator drives the node through
    CTRL directives: follow (tail a master; report `orphaned` on bare EOF
    or `report` after an orderly FIN), promote (run the master phase), and
    exit.
    """
    os.environ.setdefault("JAX_PLATFORMS", "cpu")
    from repro.distributed.protocol import hello_frame, write_frame
    from repro.distributed.transport import ReplicationClient, store_digest
    from repro.serving.snapshot import SnapshotStore

    cfg = HAConfig(**cfg_kw)
    obs = Obs()
    if cfg.trace_dir is not None:
        obs = Obs(tracer=Tracer(f"ha.node{node_id}"),
                  trace_path=os.path.join(cfg.trace_dir,
                                          f"node{node_id}.json"))
    store = SnapshotStore(capacity=cfg.snapshot_capacity, delta=True,
                          model=cfg.model)
    coord = socket.create_connection(("127.0.0.1", coord_port), timeout=30.0)
    coord.settimeout(None)
    write_frame(coord, hello_frame("node", cfg.model, worker=node_id))
    try:
        while True:
            msg = _read_ctrl(coord)
            if msg is None or msg["op"] == "exit":
                return
            if msg["op"] == "follow":
                term = int(msg["term"])
                client = ReplicationClient(
                    ("127.0.0.1", int(msg["port"])), model=cfg.model,
                    store=store, term=term, obs=obs)
                try:
                    client.connect()
                    client.run()
                except OSError:
                    pass
                meta = store.latest_meta()
                have = 0 if meta is None else meta.version
                if client.fin_reason is not None:   # orderly end of pass
                    _send_ctrl(coord, "report", node=node_id,
                               digest=store_digest(store), version=have,
                               versions=store.versions(),
                               bootstrapped=client.bootstrapped,
                               n_fenced=client.n_fenced,
                               n_duplicates=client.n_duplicates)
                else:                               # bare EOF: §14 orphaned
                    obs.instant("ha.orphaned", cat="ha", node=node_id,
                                version=have, term=term)
                    _send_ctrl(coord, "orphaned", node=node_id,
                               version=have, term=term)
            elif msg["op"] == "promote":
                obs.instant("ha.promote", cat="ha", node=node_id,
                            term=int(msg["term"]))
                _master_phase(cfg, store, int(msg["term"]),
                              int(msg["n_followers"]), coord, node_id, obs)
    finally:
        obs.flush()
        try:
            coord.close()
        except OSError:
            pass


def _master_phase(cfg: HAConfig, store, term: int, n_followers: int,
                  coord: socket.socket, node_id: int,
                  obs: Obs | None = None) -> None:
    """Run (or resume) the serializing master on this node.

    Resume point v = the store's latest version: versions 1..v hold
    epochs 0..v-1, so the remaining points are x[v*pb:] driven with
    epoch_base=v.  The first worker broadcast is a rebase delta (the
    workers' replicas descend from a dead master's stream) and every
    outbound frame carries `term` for fencing.
    """
    from repro.core.engine import OCCEngine
    from repro.core.occ import block_epochs
    from repro.distributed.fault import FaultPlan, FaultRule
    from repro.distributed.transport import ReplicationServer, store_digest
    from repro.launch.occ_cluster import (ClusterConfig, _ClusterProposer,
                                          _WorkerPlane, _cluster_data,
                                          _cluster_txn)

    ccfg = ClusterConfig(**cfg.cluster_kw())
    x = _cluster_data(ccfg)
    txn = _cluster_txn(ccfg)
    t_total = block_epochs(cfg.n, cfg.pb)
    obs = obs if obs is not None else Obs()

    fault = None
    if cfg.kill_master_after_version is not None and term == 1:
        # the plan carries obs: the kill flushes this node's trace file
        # first, so the victim's timeline survives os._exit
        fault = FaultPlan(
            rules=[FaultRule("master.commit", "kill",
                             nth=cfg.kill_master_after_version)],
            allow_kill=True, obs=obs)

    meta = store.latest_meta()
    v = 0 if meta is None else meta.version
    srv = ReplicationServer(term=term, max_queue=cfg.max_queue, obs=obs)
    if v:
        srv.seed_shadow(cfg.model, store)   # bootstrap joiners from history
    wal = None
    if cfg.wal_dir is not None:
        # each (node, term) master phase logs its publishes durably; the
        # per-term directory keeps a promoted master's log separate from
        # the stream it inherited
        from repro.checkpoint.wal import DeltaWAL, WireTee
        wal = DeltaWAL(os.path.join(cfg.wal_dir,
                                    f"node{node_id}_term{term}"),
                       model=cfg.model, obs=obs)
        if v:
            # seed the fresh log with the inherited watermark as a rebase
            # frame: replay starts from this image, and the WAL shadow is
            # primed for the first (non-rebase) post-promotion delta
            wal.send(store.bootstrap_delta())
        store.wire = WireTee(srv, wal)
    else:
        store.wire = srv
    plane = _WorkerPlane(ccfg)
    _send_ctrl(coord, "serving", node=node_id, term=term,
               repl_port=srv.address[1], worker_port=plane.port, watermark=v)
    plane.accept_workers()
    # deterministic start: every follower attached before epoch v runs, so
    # the per-epoch ack barrier really covers all R-1 replicas
    deadline = time.monotonic() + cfg.spawn_timeout_s
    while (srv.followers(cfg.model) < n_followers
           and time.monotonic() < deadline):
        time.sleep(0.01)
    assert srv.followers(cfg.model) == n_followers, "follower attach"

    pool = None if v == 0 else store.latest().to_pool(cfg.k_max)
    engine = OCCEngine(txn, pb=cfg.pb, validate_cap=cfg.validate_cap,
                       obs=obs)
    proposer = _ClusterProposer(ccfg, txn, plane, term=term,
                                rebase_first=v > 0)

    def on_outputs(ge, ae, sde, stats):
        ns, na, ce = stats
        _send_ctrl(coord, "epoch", node=node_id, term=term, epoch=ge,
                   digest=_outputs_digest(ae, sde),
                   proposed=int(ns), accepted=int(na), cap=int(ce))

    def on_commit(pool_c, ge, t_epochs):
        store.publish_pool(pool_c, n_seen=min(cfg.n, (ge + 1) * cfg.pb),
                           epochs=ge + 1)
        assert srv.wait_acked(ge + 1, cfg.model,
                              timeout=cfg.spawn_timeout_s), "ack barrier"
        if fault is not None:
            # §14 chaos: the kill fires HERE — after version ge+1 is fully
            # replicated — so every follower's watermark is exactly ge+1
            # and the promotion outcome is pinned, not racy.
            fault.at("master.commit")

    res = engine.run_from_proposals(
        x[v * cfg.pb:], proposer, pool=pool, epoch_base=v,
        on_commit=on_commit, on_outputs=on_outputs)
    plane.close()
    _send_ctrl(coord, "done", node=node_id, term=term, epochs=t_total,
               resumed_from=v, k=int(res.pool.count),
               digest=store_digest(store),
               worker_deaths={str(w): e for w, e
                              in proposer.dead_from.items()},
               metrics=srv.metrics())
    srv.close()     # FIN → followers write their reports
    if wal is not None:
        wal.close()
    obs.flush()


# --------------------------------------------------------------- worker side

def _query_master(coord_port: int, min_term: int,
                  timeout: float = 30.0) -> dict | None:
    """Blocking who-is-master CTRL query: the coordinator answers once a
    master with term >= min_term is serving (None/port=None ⇒ shut down)."""
    try:
        s = socket.create_connection(("127.0.0.1", coord_port),
                                     timeout=timeout)
    except OSError:
        return None
    try:
        s.settimeout(None)
        _send_ctrl(s, "get_master", min_term=min_term)
        return _read_ctrl(s)
    except (ConnectionError, OSError, ValueError):
        return None
    finally:
        s.close()


def ha_worker_main(cfg_kw: dict, worker_id: int, coord_port: int) -> None:
    """A propose worker that OUTLIVES its master (§14): serve the current
    master until FIN (pass complete → exit) or EOF (master died →
    re-discover).  After an EOF the worker insists on term strictly above
    the one it lost, so it can never reconnect to a zombie."""
    os.environ.setdefault("JAX_PLATFORMS", "cpu")
    from repro.distributed.protocol import hello_frame, write_frame
    from repro.launch.occ_cluster import (ClusterConfig, _cluster_data,
                                          _cluster_txn, _padded_epochs,
                                          _serve_master)

    cfg = ClusterConfig(**HAConfig(**cfg_kw).cluster_kw())
    x = _cluster_data(cfg)
    txn = _cluster_txn(cfg)
    state = txn.make_state(x, 0)
    _, xp, sp = _padded_epochs(cfg, x, state)
    replica = dict(centers=np.zeros((cfg.k_max, cfg.dim), np.float32),
                   count=0, term=0)
    min_term = 1
    while True:
        info = _query_master(coord_port, min_term)
        if info is None or info.get("port") is None:
            return
        try:
            sock = socket.create_connection(
                ("127.0.0.1", int(info["port"])), timeout=30.0)
        except OSError:
            time.sleep(0.05)    # promoted master not accepting yet
            continue
        sock.settimeout(None)
        write_frame(sock, hello_frame("worker", cfg.model, worker=worker_id,
                                      term=int(info["term"])))
        replica["term"] = max(replica["term"], int(info["term"]))
        if _serve_master(sock, cfg, worker_id, txn, xp, sp, replica) == "fin":
            return
        min_term = replica["term"] + 1


# -------------------------------------------------------------- coordinator

class _Coordinator:
    """The control plane: one listening socket, persistent per-node
    connections (HELLO role="node"), and ephemeral worker queries
    (CTRL get_master).  All shared state lives behind one condition
    variable; the orchestration policy itself runs in `run_ha_cluster`."""

    def __init__(self, cfg: HAConfig, obs: Obs | None = None):
        self.cfg = cfg
        self.obs = obs if obs is not None else Obs()
        self.cv = threading.Condition(threading.RLock())
        self.lsock = socket.create_server(("127.0.0.1", 0))
        self.port = self.lsock.getsockname()[1]
        self.nodes: dict[int, socket.socket] = {}
        self.node_alive: dict[int, bool] = {}
        self.master: dict | None = None     # node/term/repl_port/worker_port
        self.orphans: dict[int, int] = {}   # node → watermark (current term)
        self.epochs: dict[int, dict] = {}   # epoch → digest/stats record
        self.done: dict | None = None
        self.reports: dict[int, dict] = {}
        self.shutdown = False
        threading.Thread(target=self._accept, name="coord-accept",
                         daemon=True).start()

    def _accept(self) -> None:
        while True:
            try:
                sock, _addr = self.lsock.accept()
            except OSError:
                return
            threading.Thread(target=self._serve, args=(sock,),
                             name="coord-conn", daemon=True).start()

    def _serve(self, sock: socket.socket) -> None:
        from repro.distributed.protocol import CTRL, HELLO, read_frame
        try:
            fr = read_frame(sock)
            if fr is None:
                sock.close()
                return
            ftype, meta, _ = fr
            if ftype == HELLO and meta.get("role") == "node":
                nid = int(meta["worker"])
                with self.cv:
                    self.nodes[nid] = sock
                    self.node_alive[nid] = True
                    self.cv.notify_all()
                self._node_reader(nid, sock)
            elif ftype == CTRL and meta.get("op") == "get_master":
                self._answer_get_master(sock, int(meta.get("min_term", 0)))
            elif ftype == CTRL and meta.get("op") == "metrics":
                # text-exposition endpoint: one CTRL round-trip returns the
                # driver-side registry in Prometheus text form
                _send_ctrl(sock, "metrics",
                           text=self.obs.metrics.exposition())
                sock.close()
            else:
                sock.close()
        except (ConnectionError, OSError, ValueError):
            try:
                sock.close()
            except OSError:
                pass

    def _answer_get_master(self, sock: socket.socket, min_term: int) -> None:
        deadline = time.monotonic() + self.cfg.spawn_timeout_s
        with self.cv:
            while (not self.shutdown
                   and (self.master is None
                        or self.master["term"] < min_term)):
                left = deadline - time.monotonic()
                if left <= 0:
                    break
                self.cv.wait(min(left, 0.2))
            info = (None if (self.shutdown or self.master is None
                             or self.master["term"] < min_term)
                    else dict(self.master))
        if info is None:
            _send_ctrl(sock, "master", port=None, term=0)
        else:
            _send_ctrl(sock, "master", port=info["worker_port"],
                       term=info["term"])
        sock.close()

    def _node_reader(self, nid: int, sock: socket.socket) -> None:
        from repro.distributed.protocol import CTRL, read_frame
        try:
            while True:
                fr = read_frame(sock)
                if fr is None:
                    break
                ftype, meta, _ = fr
                if ftype != CTRL:
                    continue
                op = meta.get("op")
                with self.cv:
                    if op == "serving":
                        self.master = dict(
                            node=nid, term=int(meta["term"]),
                            repl_port=int(meta["repl_port"]),
                            worker_port=int(meta["worker_port"]),
                            watermark=int(meta.get("watermark", 0)))
                        self.orphans = {}
                    elif op == "orphaned":
                        self.orphans[nid] = int(meta["version"])
                    elif op == "epoch":
                        e, t = int(meta["epoch"]), int(meta["term"])
                        prev = self.epochs.get(e)
                        if prev is None or t >= prev["term"]:
                            self.epochs[e] = dict(
                                term=t, node=nid, digest=meta["digest"],
                                proposed=int(meta["proposed"]),
                                accepted=int(meta["accepted"]),
                                cap=int(meta["cap"]))
                    elif op == "done":
                        self.done = dict(meta, node=nid)
                    elif op == "report":
                        self.reports[nid] = dict(meta)
                    self.cv.notify_all()
        except (ConnectionError, OSError, ValueError):
            pass
        with self.cv:
            self.node_alive[nid] = False
            self.cv.notify_all()

    def send_to(self, nid: int, op: str, **fields) -> None:
        _send_ctrl(self.nodes[nid], op, **fields)

    def wait(self, pred, what: str) -> None:
        deadline = time.monotonic() + self.cfg.spawn_timeout_s
        with self.cv:
            while not pred():
                left = deadline - time.monotonic()
                assert left > 0, f"coordinator timeout waiting for {what}"
                self.cv.wait(min(left, 0.2))

    def close(self) -> None:
        with self.cv:
            self.shutdown = True
            self.cv.notify_all()
        for sock in [self.lsock, *self.nodes.values()]:
            try:
                sock.close()
            except OSError:
                pass


def run_ha_cluster(cfg: HAConfig) -> dict:
    os.environ.setdefault("JAX_PLATFORMS", "cpu")
    from repro.core.engine import OCCEngine
    from repro.core.occ import block_epochs
    from repro.distributed.transport import store_digest
    from repro.launch.occ_cluster import (ClusterConfig, _cluster_data,
                                          _cluster_txn)
    from repro.serving.snapshot import SnapshotStore

    assert cfg.n_nodes >= 2, "HA needs a master and at least one follower"
    assert cfg.pb % cfg.n_workers == 0, "pb must split evenly across workers"
    t_total = block_epochs(cfg.n, cfg.pb)
    if cfg.kill_master_after_version is not None:
        assert 1 <= cfg.kill_master_after_version < t_total, \
            "kill version must land mid-pass"
    t0 = time.perf_counter()

    # Telemetry plumbing: --trace-out implies a per-process trace_dir (and
    # a WAL dir — a traced run exercises every subsystem, so the merged
    # timeline carries engine, transport, wal, fault AND ha events).
    trace_dir = cfg.trace_dir
    if cfg.trace_out is not None and trace_dir is None:
        trace_dir = tempfile.mkdtemp(prefix="ha_trace_")
    wal_dir = cfg.wal_dir
    if cfg.trace_out is not None and wal_dir is None:
        wal_dir = tempfile.mkdtemp(prefix="ha_wal_")
    driver_obs = Obs()
    if trace_dir is not None:
        driver_obs = Obs(tracer=Tracer("ha.driver"),
                         trace_path=os.path.join(trace_dir, "driver.json"))

    coord = _Coordinator(cfg, obs=driver_obs)
    ctx = mp.get_context("spawn")
    cfg_kw = {**cfg.__dict__, "out_path": None, "trace_out": None,
              "trace_dir": trace_dir, "wal_dir": wal_dir}
    node_procs = [ctx.Process(target=ha_node_main,
                              args=(cfg_kw, i, coord.port), daemon=True)
                  for i in range(cfg.n_nodes)]
    for p in node_procs:
        p.start()
    coord.wait(lambda: len(coord.nodes) == cfg.n_nodes, "node registration")

    promotions = 0
    terms = [1]
    coord.send_to(0, "promote", term=1, n_followers=cfg.n_nodes - 1)
    coord.wait(lambda: coord.master is not None
               and coord.master["term"] == 1, "term-1 master serving")
    for i in range(1, cfg.n_nodes):
        coord.send_to(i, "follow", port=coord.master["repl_port"], term=1)

    worker_procs = [ctx.Process(target=ha_worker_main,
                                args=(cfg_kw, w, coord.port), daemon=True)
                    for w in range(cfg.n_workers)]
    for p in worker_procs:
        p.start()

    resume_epoch = None
    while True:
        def phase():
            if coord.done is not None:
                return "done"
            m = coord.master
            live = [nid for nid, ok in coord.node_alive.items() if ok]
            if (m is not None and not coord.node_alive.get(m["node"], False)
                    and live and all(nid in coord.orphans for nid in live)):
                return "promote"
            return ""
        coord.wait(lambda: phase() != "", "master completion or death")
        if phase() == "done":
            break
        # ------------------------------------------------- §14 promotion
        with coord.cv:
            orphans = dict(coord.orphans)
            old_term = coord.master["term"]
        # highest replicated watermark wins; ties break to the lowest id
        winner = max(orphans, key=lambda nid: (orphans[nid], -nid))
        resume_epoch = orphans[winner]
        new_term = old_term + 1
        promotions += 1
        terms.append(new_term)
        driver_obs.metrics.counter("ha_promotions").inc()
        driver_obs.instant("ha.promote", cat="ha", winner=winner,
                           term=new_term, watermark=resume_epoch)
        if not cfg.quiet:
            print(f"master (term {old_term}) died; promoting node {winner} "
                  f"at watermark {resume_epoch} with term {new_term}")
        coord.send_to(winner, "promote", term=new_term,
                      n_followers=len(orphans) - 1)
        coord.wait(lambda: coord.master is not None
                   and coord.master["term"] == new_term,
                   "promoted master serving")
        for nid in orphans:
            if nid != winner:
                coord.send_to(nid, "follow",
                              port=coord.master["repl_port"], term=new_term)

    final_master = coord.done["node"]
    expected_reports = [nid for nid, ok in coord.node_alive.items()
                        if ok and nid != final_master]
    coord.wait(lambda: all(nid in coord.reports for nid in expected_reports),
               "follower reports")
    with coord.cv:
        for nid, ok in coord.node_alive.items():
            if ok:
                coord.send_to(nid, "exit")
    for p in [*node_procs, *worker_procs]:
        p.join(timeout=30.0)
    coord.close()

    if trace_dir is not None:
        driver_obs.flush()
        if cfg.trace_out is not None:
            # one merged Perfetto timeline: driver + every node (including
            # the killed master — its FaultPlan flushed before os._exit)
            parts = sorted(os.path.join(trace_dir, f)
                           for f in os.listdir(trace_dir)
                           if f.endswith(".json"))
            merge_traces(cfg.trace_out, *parts)

    # --------------------------------------------------------------- audit
    # The uninterrupted single-process reference: same per-epoch digests,
    # same stats, same published store — computed in THIS process.
    ccfg = ClusterConfig(**cfg.cluster_kw())
    x = _cluster_data(ccfg)
    txn = _cluster_txn(ccfg)
    ref_store = SnapshotStore(capacity=cfg.snapshot_capacity, delta=True,
                              model=cfg.model)
    ref_digests: dict[int, str] = {}
    ref_stats: dict[int, tuple] = {}

    def ref_outputs(e, ae, sde, st):
        ref_digests[e] = _outputs_digest(ae, sde)
        ref_stats[e] = (int(st[0]), int(st[1]), int(st[2]))

    def ref_commit(pool, e, t):
        ref_store.publish_pool(pool, n_seen=min(cfg.n, (e + 1) * cfg.pb),
                               epochs=e + 1)

    OCCEngine(txn, pb=cfg.pb, validate_cap=cfg.validate_cap) \
        .run_from_proposals(x, on_commit=ref_commit, on_outputs=ref_outputs)

    epoch_digests_match = (
        sorted(coord.epochs) == list(range(t_total))
        and all(coord.epochs[e]["digest"] == ref_digests[e]
                for e in coord.epochs))
    epoch_stats_match = epoch_digests_match and all(
        (coord.epochs[e]["proposed"], coord.epochs[e]["accepted"],
         coord.epochs[e]["cap"]) == ref_stats[e] for e in coord.epochs)
    ref_digest = store_digest(ref_store)
    final_digest_match = (coord.done["digest"] == ref_digest
                          and int(coord.done["k"])
                          == int(ref_store.latest_meta().count))
    follower_digests_match = [r["digest"] == ref_digest
                              for r in coord.reports.values()]
    overlap = [e for e, rec in coord.epochs.items()
               if resume_epoch is not None and rec["term"] > 1
               and e < resume_epoch]

    record = {
        "bench": "ha",
        "n": cfg.n, "dim": cfg.dim, "pb": cfg.pb,
        "workers": cfg.n_workers, "nodes": cfg.n_nodes,
        "epochs": t_total,
        "k_final": int(coord.done["k"]),
        "promotions": promotions,
        "terms": terms,
        "kill_version": cfg.kill_master_after_version,
        "resume_epoch": resume_epoch,
        "master_node_final": final_master,
        "epoch_digests_match": epoch_digests_match,
        "epoch_stats_match": epoch_stats_match,
        "final_digest_match": final_digest_match,
        "follower_digests_match": follower_digests_match,
        "recomputed_overlap_epochs": overlap,
        "worker_deaths": coord.done.get("worker_deaths", {}),
        "final_term_metrics": coord.done.get("metrics", {}),
        "trace_out": cfg.trace_out,
        "wall_s": time.perf_counter() - t0,
    }
    assert epoch_digests_match, "per-epoch outputs diverged from reference"
    assert epoch_stats_match, "per-epoch OCCStats diverged from reference"
    assert final_digest_match, "final store digest diverged from reference"
    assert follower_digests_match and all(follower_digests_match), \
        "a surviving follower's store diverged"
    if cfg.kill_master_after_version is not None:
        assert promotions == 1, "the master kill did not trigger promotion"
        assert resume_epoch == cfg.kill_master_after_version, (
            f"promotion watermark {resume_epoch} != acked kill version "
            f"{cfg.kill_master_after_version}")
    if cfg.out_path is not None:
        with open(cfg.out_path, "w") as f:
            json.dump(record, f, indent=2)
    if not cfg.quiet:
        print(f"{cfg.n_nodes} nodes x {cfg.n_workers} workers, "
              f"{t_total} epochs -> K={record['k_final']} "
              f"(promotions={promotions}, terms={terms}, "
              f"resume@{resume_epoch})")
        print(f"bit-identical to uninterrupted single-process pass: "
              f"{epoch_digests_match and final_digest_match}")
    return record


def main(argv=None):
    ap = argparse.ArgumentParser(description=__doc__.split("\n")[0])
    ap.add_argument("--n", type=int, default=2048)
    ap.add_argument("--dim", type=int, default=8)
    ap.add_argument("--pb", type=int, default=64)
    ap.add_argument("--workers", type=int, default=2)
    ap.add_argument("--nodes", type=int, default=3)
    ap.add_argument("--kill-after", type=int, default=None,
                    help="kill the term-1 master after this acked version")
    ap.add_argument("--quick", action="store_true",
                    help="CI smoke sizes (numbers not meaningful)")
    ap.add_argument("--out", default=None, help="write BENCH_ha.json here")
    ap.add_argument("--trace-out", default=None,
                    help="merged Perfetto/Chrome trace JSON of all "
                         "processes (implies WAL + per-process tracing)")
    ap.add_argument("--wal-dir", default=None,
                    help="append every master publish to a DeltaWAL here")
    args = ap.parse_args(argv)
    cfg = HAConfig(n=args.n, dim=args.dim, pb=args.pb,
                   n_workers=args.workers, n_nodes=args.nodes,
                   kill_master_after_version=args.kill_after,
                   out_path=args.out, trace_out=args.trace_out,
                   wal_dir=args.wal_dir)
    if args.quick:
        cfg = HAConfig(n=1024, dim=8, pb=64, k_max=128, lam=3.0,
                       n_workers=args.workers, n_nodes=args.nodes,
                       kill_master_after_version=args.kill_after,
                       out_path=args.out, trace_out=args.trace_out,
                       wal_dir=args.wal_dir)
    run_ha_cluster(cfg)


if __name__ == "__main__":
    main()

"""Multi-process OCC: sharded propose workers + serializing master (§13).

The paper's P-machine experiment as real OS processes.  A master process
drives `OCCEngine.run_from_proposals`; P spawned worker processes each
hold a bit-exact replica of the center pool (tailed from the master's
per-epoch DELTA broadcasts) and run the optimistic `propose` phase on a
disjoint contiguous shard of every epoch.  Proposal blocks stream back as
PROPOSE frames; the master reassembles them in worker order (== global
index order), runs the ONE true precomputed validator, commits the epoch,
and publishes the pool delta — to the workers (training plane) and to any
number of socket-connected follower stores via `ReplicationServer`
(replication plane, with acks and snapshot bootstrap for late joiners).

Because a jitted shard-shaped `propose` equals the matching slice of the
jitted full-epoch `propose`, and the master's per-epoch finish equals the
fused scan's epoch body, the whole multi-process run is **bit-identical**
to the single-process `OCCEngine.run` on the same data — final centers,
per-point assignments, `OCCStats`, and every follower's snapshot store.
The driver audits all of that and emits BENCH_transport.json (delta
bytes/publish, replication ack latency p50/p99).

Failure semantics (chaos-tested in tests/test_occ_cluster.py):
  * a worker that dies mid-epoch is detected by socket EOF (belt:
    `fault.HeartbeatTracker` timeout for hangs); its shard is masked
    invalid from that epoch on and the master completes every epoch with
    the survivors' proposals — deterministically, because the dead
    worker's points are excluded exactly from the epoch whose STEP it
    never answered;
  * a follower killed mid-publish simply drops off the ack set; a
    replacement follower bootstraps from a SNAPSHOT frame and tails to
    the same bit-identical store.

  PYTHONPATH=src python -m repro.launch.occ_cluster [--quick] \
      --workers 2 --followers 1 --out BENCH_transport.json
"""
from __future__ import annotations

import argparse
import json
import multiprocessing as mp
import os
import socket
import tempfile
import threading
import time
from dataclasses import dataclass

import numpy as np

__all__ = ["ClusterConfig", "run_cluster", "worker_main"]


@dataclass
class ClusterConfig:
    n: int = 4096
    dim: int = 16
    lam: float = 4.0
    k_max: int = 256
    pb: int = 128               # points per epoch (split across workers)
    n_workers: int = 2
    n_followers: int = 1        # followers connected before epoch 0
    validate_cap: int | None = None
    seed: int = 0
    model: str = "occ"
    snapshot_capacity: int = 256    # ring >= epochs+1: version lists compare
    late_follower: bool = True      # spawn one follower mid-run (bootstrap)
    late_join_frac: float = 0.5     # ...after this fraction of the epochs
    worker_timeout_s: float = 120.0  # heartbeat timeout (EOF detects deaths)
    spawn_timeout_s: float = 120.0   # worker connect + follower join budget
    straggler_threshold: float = 3.0  # epoch slower than this x EWMA → event
    straggler_warmup: int = 3        # ignore compile-dominated first epochs
    # chaos knobs (tests/test_occ_cluster.py pins their outcomes)
    die_worker: int | None = None    # this worker exits without proposing...
    die_epoch: int | None = None     # ...upon receiving STEP for this epoch
    kill_follower_at_epoch: int | None = None  # SIGKILL follower 0 here and
    #                                            respawn a fresh one after
    out_path: str | None = None
    trace_out: str | None = None    # master-side Perfetto JSON
    quiet: bool = False


def _cluster_data(cfg: ClusterConfig):
    """Deterministic per-config dataset — every process regenerates the
    same points from (n, seed, dim), so no training data travels on the
    wire (shards are index ranges, exactly the paper's setup)."""
    import jax.numpy as jnp
    from repro.data import dp_stick_breaking_data
    x, _, _ = dp_stick_breaking_data(cfg.n, seed=cfg.seed, dim=cfg.dim)
    return jnp.asarray(x)


def _cluster_txn(cfg: ClusterConfig):
    from repro.core.dp_means import DPMeansTransaction
    return DPMeansTransaction(cfg.lam, cfg.k_max)


def _padded_epochs(cfg: ClusterConfig, x, state):
    """(x, valid, state) padded to t*pb — the engine's exact epoch
    partition, recomputed identically by master and every worker."""
    import jax
    import jax.numpy as jnp
    from repro.core.occ import block_epochs
    n = x.shape[0]
    t = block_epochs(n, cfg.pb)
    pad = t * cfg.pb - n
    zp = lambda a: jnp.concatenate(
        [a, jnp.zeros((pad,) + a.shape[1:], a.dtype)], 0)
    return t, zp(x), jax.tree.map(zp, state)


# --------------------------------------------------------------- worker side

def _serve_master(sock: socket.socket, cfg: ClusterConfig, worker_id: int,
                  txn, xp, sp, replica: dict) -> str:
    """Serve ONE master connection until FIN ("fin") or a broken stream
    ("eof" — the §14 orphaned signal for the HA worker's reconnect loop).

    `replica` (centers ndarray / count / term) persists across calls so a
    reconnecting HA worker keeps its pool between masters; a promoted
    master's first broadcast is a rebase delta that resets it anyway.
    Term fencing (§14): DELTA/SNAPSHOT/STEP frames below the replica's
    known term are zombie-master traffic and are ignored outright.
    """
    import jax
    import jax.numpy as jnp
    from repro.core.engine import _propose_epoch_jit
    from repro.core.occ import CenterPool
    from repro.distributed.protocol import (
        DELTA, FIN, SNAPSHOT, STEP, frame_delta, propose_frame,
        read_frame, write_frame)

    spb = cfg.pb // cfg.n_workers
    centers = replica["centers"]
    try:
        while True:
            fr = read_frame(sock)
            if fr is None:
                return "eof"
            ftype, meta, arrays = fr
            if ftype in (DELTA, SNAPSHOT, STEP):
                term = int(meta.get("term", 0))
                if term < replica["term"]:
                    continue            # §14 fencing: stale-term frame
                replica["term"] = term
            if ftype in (DELTA, SNAPSHOT):
                delta = frame_delta(meta, arrays)
                if delta.rebase:
                    centers[:] = 0.0
                    replica["count"] = 0
                assert delta.start == replica["count"], \
                    "pool delta gap at worker"
                centers[delta.start:delta.count] = delta.rows
                replica["count"] = delta.count
            elif ftype == STEP:
                e = int(meta["epoch"])
                if cfg.die_epoch == e and cfg.die_worker == worker_id:
                    os._exit(3)          # hard mid-epoch death, no FIN
                count = replica["count"]
                assert int(meta["count"]) == count, "replica out of sync"
                pool = CenterPool(
                    jnp.asarray(centers),
                    jnp.arange(cfg.k_max) < count,
                    jnp.asarray(count, jnp.int32), jnp.asarray(False))
                cut = slice(e * cfg.pb + worker_id * spb,
                            e * cfg.pb + (worker_id + 1) * spb)
                out = _propose_epoch_jit(
                    txn, pool, xp[cut], jax.tree.map(lambda s: s[cut], sp))
                leaves = [np.asarray(l) for l in jax.tree_util.tree_leaves(out)]
                write_frame(sock, propose_frame(e, worker_id, leaves))
            elif ftype == FIN:
                return "fin"
    except (ConnectionError, OSError):
        return "eof"
    finally:
        sock.close()


def worker_main(cfg_kw: dict, worker_id: int, port: int) -> None:
    """One propose worker (spawned process): tail pool deltas, answer STEP
    frames with the jitted shard propose, exit on FIN.

    The pool replica is rebuilt from broadcast deltas only — the worker
    never sees the master's pool object, yet proposes against bit-equal
    state C^{t-1} (append-only pool + prefix mask ⇒ the replica IS the
    pool).  If cfg.die_epoch targets this worker it exits hard (os._exit)
    upon the STEP, before proposing — the chaos tests' mid-epoch death.
    """
    os.environ.setdefault("JAX_PLATFORMS", "cpu")
    from repro.distributed.protocol import hello_frame, write_frame

    cfg = ClusterConfig(**cfg_kw)
    x = _cluster_data(cfg)
    txn = _cluster_txn(cfg)
    state = txn.make_state(x, 0)
    _, xp, sp = _padded_epochs(cfg, x, state)
    replica = dict(centers=np.zeros((cfg.k_max, cfg.dim), np.float32),
                   count=0, term=0)
    sock = socket.create_connection(("127.0.0.1", port), timeout=30.0)
    sock.settimeout(None)
    write_frame(sock, hello_frame("worker", cfg.model, worker=worker_id))
    _serve_master(sock, cfg, worker_id, txn, xp, sp, replica)


# --------------------------------------------------------------- master side

class _WorkerPlane:
    """Master end of the training plane: P worker sockets, a reader thread
    per worker filling the per-epoch inbox, EOF + heartbeat liveness."""

    def __init__(self, cfg: ClusterConfig):
        from repro.distributed.fault import HeartbeatTracker
        self.cfg = cfg
        self.lsock = socket.create_server(("127.0.0.1", 0))
        self.port = self.lsock.getsockname()[1]
        self.conns: dict[int, socket.socket] = {}
        self.alive = [True] * cfg.n_workers
        self.inbox: dict[tuple[int, int], list[np.ndarray]] = {}
        self.cv = threading.Condition()
        self.hb = HeartbeatTracker(timeout=cfg.worker_timeout_s)
        self.procs: list[mp.process.BaseProcess] = []
        self._readers: list[threading.Thread] = []

    def spawn(self) -> None:
        ctx = mp.get_context("spawn")
        cfg_kw = {**self.cfg.__dict__, "out_path": None}
        for w in range(self.cfg.n_workers):
            p = ctx.Process(target=worker_main, args=(cfg_kw, w, self.port),
                            daemon=True)
            p.start()
            self.procs.append(p)
        self.accept_workers()

    def accept_workers(self) -> None:
        """Accept `n_workers` HELLO handshakes — from children this plane
        spawned, or from §14 HA workers reconnecting to a promoted master
        (the plane does not care who forked them)."""
        from repro.distributed.protocol import HELLO, read_frame
        self.lsock.settimeout(self.cfg.spawn_timeout_s)
        for _ in range(self.cfg.n_workers):
            sock, _addr = self.lsock.accept()
            sock.settimeout(None)
            fr = read_frame(sock)
            assert fr is not None and fr[0] == HELLO, "bad worker handshake"
            wid = int(fr[1]["worker"])
            self.conns[wid] = sock
            self.hb.beat(wid)
            t = threading.Thread(target=self._reader, args=(wid, sock),
                                 name=f"worker-rx-{wid}", daemon=True)
            t.start()
            self._readers.append(t)

    def _reader(self, wid: int, sock: socket.socket) -> None:
        from repro.distributed.protocol import PROPOSE, read_frame
        try:
            while True:
                fr = read_frame(sock)
                if fr is None:
                    break
                ftype, meta, arrays = fr
                if ftype == PROPOSE:
                    leaves = [arrays[f"leaf{i}"]
                              for i in range(int(meta["n_leaves"]))]
                    with self.cv:
                        self.inbox[(int(meta["epoch"]), wid)] = leaves
                        self.hb.beat(wid)
                        self.cv.notify_all()
        except (ConnectionError, OSError, ValueError):
            pass
        with self.cv:
            self.alive[wid] = False
            self.cv.notify_all()

    def broadcast(self, frame: bytes) -> None:
        for wid, sock in self.conns.items():
            if not self.alive[wid]:
                continue
            try:
                sock.sendall(frame)
            except OSError:
                with self.cv:
                    self.alive[wid] = False
                    self.cv.notify_all()

    def gather(self, epoch: int) -> dict[int, list[np.ndarray] | None]:
        """Block until every live worker answered `epoch` (or died — EOF is
        the fast path, the heartbeat timeout the hang backstop).  Returns
        worker → leaves, None for workers dead by/at this epoch."""
        with self.cv:
            while True:
                for wid in self.hb.dead_hosts():
                    self.alive[wid] = False     # hang backstop
                missing = [w for w in range(self.cfg.n_workers)
                           if self.alive[w] and (epoch, w) not in self.inbox]
                if not missing:
                    break
                self.cv.wait(0.05)
            return {w: self.inbox.pop((epoch, w), None)
                    for w in range(self.cfg.n_workers)}

    def close(self) -> None:
        from repro.distributed.protocol import fin_frame
        self.broadcast(fin_frame("pass complete"))
        for p in self.procs:
            p.join(timeout=30.0)
        for sock in self.conns.values():
            try:
                sock.close()
            except OSError:
                pass
        self.lsock.close()


class _ClusterProposer:
    """`propose_fn` for `OCCEngine.run_from_proposals`, backed by the
    worker plane: broadcast the epoch-start pool delta + STEP, gather the
    PROPOSE blocks, reassemble leaves in worker order, mask dead shards."""

    def __init__(self, cfg: ClusterConfig, txn, plane: _WorkerPlane,
                 term: int = 0, rebase_first: bool = False):
        self.cfg = cfg
        self.txn = txn
        self.plane = plane
        self.term = term                # §14: stamped on every broadcast
        self.last_count = 0
        self._force_rebase = rebase_first   # promoted master: the workers'
        #   replicas come from a DEAD master's stream — rebase them first
        self._template = None           # (treedef, shard leaf specs)
        self.dead_from: dict[int, int] = {}   # worker → first masked epoch

    def _shard_template(self, pool, x_e, state_e):
        import jax
        spb = self.cfg.pb // self.cfg.n_workers
        cut = lambda a: a[:spb]
        sd = jax.eval_shape(self.txn.propose, pool, cut(x_e),
                            jax.tree.map(cut, state_e))
        leaves, treedef = jax.tree_util.tree_flatten(sd)
        return treedef, [(l.shape, l.dtype) for l in leaves]

    def _pool_delta(self, pool, epoch: int):
        from repro.serving.snapshot import CenterDelta
        cnp = np.asarray(pool.centers)
        count = int(pool.count)
        rebase = epoch == 0 or self._force_rebase
        self._force_rebase = False
        start = 0 if rebase else self.last_count
        self.last_count = count
        return CenterDelta(model=self.cfg.model, version=epoch, start=start,
                           rows=cnp[start:count], count=count,
                           capacity=self.cfg.k_max, rebase=rebase)

    def __call__(self, pool, x_e, state_e, valid_e, *, epoch, offset):
        import jax
        import jax.numpy as jnp
        from repro.distributed.protocol import delta_frame, step_frame
        if self._template is None:
            self._template = self._shard_template(pool, x_e, state_e)
        treedef, specs = self._template
        self.plane.broadcast(delta_frame(self._pool_delta(pool, epoch),
                                         term=self.term))
        self.plane.broadcast(step_frame(epoch, self.last_count,
                                        term=self.term))
        blocks = self.plane.gather(epoch)
        spb = self.cfg.pb // self.cfg.n_workers
        cat = []
        for i, (shape, dtype) in enumerate(specs):
            parts = []
            for w in range(self.cfg.n_workers):
                lv = blocks[w]
                parts.append(np.zeros(shape, dtype) if lv is None else lv[i])
            cat.append(jnp.asarray(np.concatenate(parts, 0)))
        send, payload, aux, safe = jax.tree_util.tree_unflatten(treedef, cat)
        dead = [w for w, lv in blocks.items() if lv is None]
        if dead:
            rows = np.ones((self.cfg.pb,), bool)
            for w in dead:
                self.dead_from.setdefault(w, epoch)
                rows[w * spb:(w + 1) * spb] = False
            valid_e = jnp.logical_and(valid_e, jnp.asarray(rows))
        return send, payload, aux, safe, valid_e


def _masked_reference(cfg: ClusterConfig, engine, dead_from: dict[int, int]):
    """The deterministic chaos oracle: the in-process proposer with the
    SAME shard masking the master applied for dead workers."""
    import jax.numpy as jnp
    base = engine.local_proposer()
    spb = cfg.pb // cfg.n_workers
    masks = {}
    for w, e0 in dead_from.items():
        rows = np.ones((cfg.pb,), bool)
        rows[w * spb:(w + 1) * spb] = False
        masks[w] = (e0, jnp.asarray(rows))

    def fn(pool, x_e, state_e, valid_e, *, epoch, offset):
        s, p, a, sf, ve = base(pool, x_e, state_e, valid_e,
                               epoch=epoch, offset=offset)
        for e0, rows in masks.values():
            if epoch >= e0:
                ve = jnp.logical_and(ve, rows)
        return s, p, a, sf, ve
    return fn


def run_cluster(cfg: ClusterConfig) -> dict:
    os.environ.setdefault("JAX_PLATFORMS", "cpu")
    from repro.core.engine import OCCEngine
    from repro.distributed.transport import ReplicationServer, store_digest
    from repro.launch.occ_follower import follower_main
    from repro.obs import Obs, Tracer
    from repro.serving.snapshot import SnapshotStore

    assert cfg.pb % cfg.n_workers == 0, "pb must split evenly across workers"
    # ONE shared Obs for the master process: engine passes, replication and
    # the straggler watchdog land in one registry / one trace file.
    obs = Obs(tracer=Tracer("occ_cluster.master") if cfg.trace_out else None,
              trace_path=cfg.trace_out)
    t0 = time.perf_counter()
    x = _cluster_data(cfg)
    txn = _cluster_txn(cfg)

    # replication plane: primary store wired straight onto the socket server
    srv = ReplicationServer(obs=obs)
    store = SnapshotStore(capacity=cfg.snapshot_capacity, delta=True,
                          model=cfg.model, wire=srv)
    ctx = mp.get_context("spawn")
    tmp = tempfile.mkdtemp(prefix="occ_cluster_")
    followers: list[dict] = []      # {proc, path, late, replacement}

    def spawn_follower(late: bool, replacement: bool = False) -> None:
        path = os.path.join(tmp, f"follower_{len(followers)}.json")
        p = ctx.Process(
            target=follower_main,
            args=(srv.address[0], srv.address[1], cfg.model, path,
                  cfg.snapshot_capacity),
            daemon=True)
        p.start()
        followers.append(dict(proc=p, path=path, late=late,
                              replacement=replacement))

    for _ in range(cfg.n_followers):
        spawn_follower(late=False)
    deadline = time.monotonic() + cfg.spawn_timeout_s
    while (srv.followers(cfg.model) < cfg.n_followers
           and time.monotonic() < deadline):
        time.sleep(0.01)
    assert srv.followers(cfg.model) == cfg.n_followers, "follower connect"

    # training plane
    plane = _WorkerPlane(cfg)
    plane.spawn()
    proposer = _ClusterProposer(cfg, txn, plane)
    engine = OCCEngine(txn, pb=cfg.pb, validate_cap=cfg.validate_cap,
                       obs=obs)

    killed = {"done": False}
    # straggler watchdog on the master's epoch loop: a slow epoch (a hung
    # or lagging worker that still answers before the heartbeat timeout)
    # emits a StragglerEvent into the run's metrics instead of passing
    # silently — the observability half of §13's failure semantics.
    from repro.distributed.fault import StepWatchdog
    watchdog = StepWatchdog(threshold=cfg.straggler_threshold,
                            warmup_steps=cfg.straggler_warmup, obs=obs)
    last_commit = [time.perf_counter()]

    def on_commit(pool, epoch, t_epochs):
        now = time.perf_counter()
        watchdog.observe(epoch, now - last_commit[0])
        last_commit[0] = now
        store.publish_pool(pool, n_seen=min(cfg.n, (epoch + 1) * cfg.pb),
                           epochs=epoch + 1)
        if (cfg.kill_follower_at_epoch == epoch and not killed["done"]
                and followers):
            followers[0]["proc"].kill()      # mid-publish, no FIN, no ACK
            killed["done"] = True
            spawn_follower(late=True, replacement=True)
        if cfg.late_follower and epoch == max(1, int(t_epochs
                                                     * cfg.late_join_frac)):
            spawn_follower(late=True)

    res = engine.run_from_proposals(x, proposer, on_commit=on_commit)
    plane.close()

    # replication barrier: every surviving follower connected and acked
    latest = store.latest_meta().version
    expect = sum(1 for f in followers
                 if not (killed["done"] and f is followers[0]))
    deadline = time.monotonic() + cfg.spawn_timeout_s
    while (srv.followers(cfg.model) < expect
           and time.monotonic() < deadline):
        time.sleep(0.01)
    assert srv.wait_acked(latest, cfg.model,
                          timeout=cfg.spawn_timeout_s), "ack barrier"
    metrics = srv.metrics()
    srv.close()     # FIN → followers write their reports and exit
    reports = []
    for f in followers:
        f["proc"].join(timeout=30.0)
        if os.path.exists(f["path"]):
            with open(f["path"]) as fh:
                reports.append({**json.load(fh), "late": f["late"],
                                "replacement": f["replacement"]})

    # ------------------------------------------------------------- audit
    # The single-process oracle: the fused one-jit pass (clean run), or the
    # host-driven pass with the same dead-shard masks (chaos run).
    ref_engine = OCCEngine(txn, pb=cfg.pb, validate_cap=cfg.validate_cap)
    if proposer.dead_from:
        ref = ref_engine.run_from_proposals(
            x, _masked_reference(cfg, ref_engine, proposer.dead_from))
    else:
        ref = ref_engine.run(x)
    eq = lambda a, b: bool(np.array_equal(np.asarray(a), np.asarray(b)))
    bit = dict(
        centers=eq(ref.pool.centers, res.pool.centers),
        count=int(ref.pool.count) == int(res.pool.count),
        mask=eq(ref.pool.mask, res.pool.mask),
        assign=eq(ref.assign, res.assign),
        send=eq(ref.send, res.send),
        epoch_of=eq(ref.epoch_of, res.epoch_of),
        stats_proposed=eq(ref.stats.proposed, res.stats.proposed),
        stats_accepted=eq(ref.stats.accepted, res.stats.accepted),
        stats_cap=eq(ref.stats.cap, res.stats.cap),
    )
    primary_digest = store_digest(store)
    follower_ok = [r["digest"] == primary_digest for r in reports]
    boot_ok = all(r["bootstrapped"] for r in reports if r["late"])
    full_stream_ok = all(r["versions"] == store.versions()
                         for r in reports if not r["late"])

    record = {
        "bench": "transport",
        "n": cfg.n, "dim": cfg.dim, "pb": cfg.pb,
        "workers": cfg.n_workers,
        "followers": len(reports),
        "epochs": int(res.stats.proposed.shape[0]),
        "k_final": int(res.pool.count),
        "versions_published": len(store),
        "delta_rows_published": store.delta_rows_published,
        "delta_bytes_per_publish":
            metrics["bytes_sent"] / max(1, metrics["n_sent"]),
        "ack_p50_ms": metrics["ack_p50_ms"],
        "ack_p99_ms": metrics["ack_p99_ms"],
        "n_acks": metrics["n_acks"],
        "n_bootstraps": metrics["n_bootstraps"],
        "bit_identical": bit,
        "follower_digests_match": follower_ok,
        "late_joiners_bootstrapped": boot_ok,
        "full_stream_versions_match": full_stream_ok,
        "worker_deaths": proposer.dead_from,
        "straggler_events": [
            dict(step=ev.step, elapsed_s=ev.elapsed, ratio=ev.ratio)
            for ev in watchdog.events],
        "wall_s": time.perf_counter() - t0,
    }
    obs.flush()
    assert all(bit.values()), f"multi-process run diverged: {bit}"
    assert reports and all(follower_ok), "follower store digest mismatch"
    assert boot_ok, "a late joiner did not bootstrap from a snapshot"
    assert full_stream_ok, "a from-start follower lost versions"
    if cfg.die_worker is not None:
        assert proposer.dead_from.get(cfg.die_worker) == cfg.die_epoch, (
            "worker death not detected at the pinned epoch")
    if cfg.kill_follower_at_epoch is not None:
        rep = [r for r in reports if r["replacement"]]
        assert rep and rep[0]["bootstrapped"], "replacement did not resync"
    if cfg.out_path is not None:
        with open(cfg.out_path, "w") as f:
            json.dump(record, f, indent=2)
    if not cfg.quiet:
        print(f"{cfg.n_workers} workers x {record['epochs']} epochs over "
              f"{cfg.n} points -> K={record['k_final']} "
              f"({record['versions_published']} versions, "
              f"{record['delta_bytes_per_publish']:.0f} B/publish)")
        print(f"bit-identical to single-process pass: "
              f"{all(bit.values())}  followers={len(reports)} "
              f"(late bootstraps ok: {boot_ok})  "
              f"ack p50={record['ack_p50_ms']:.2f}ms "
              f"p99={record['ack_p99_ms']:.2f}ms")
    return record


def main(argv=None):
    ap = argparse.ArgumentParser(description=__doc__.split("\n")[0])
    ap.add_argument("--n", type=int, default=4096)
    ap.add_argument("--dim", type=int, default=16)
    ap.add_argument("--pb", type=int, default=128)
    ap.add_argument("--workers", type=int, default=2)
    ap.add_argument("--followers", type=int, default=1)
    ap.add_argument("--quick", action="store_true",
                    help="CI smoke sizes (numbers not meaningful)")
    ap.add_argument("--out", default=None,
                    help="write BENCH_transport.json here")
    ap.add_argument("--trace-out", default=None,
                    help="write a Perfetto/Chrome trace JSON here")
    args = ap.parse_args(argv)
    cfg = ClusterConfig(n=args.n, dim=args.dim, pb=args.pb,
                        n_workers=args.workers, n_followers=args.followers,
                        out_path=args.out, trace_out=args.trace_out)
    if args.quick:
        cfg = ClusterConfig(n=1024, dim=8, pb=64, k_max=128, lam=3.0,
                            n_workers=args.workers,
                            n_followers=args.followers, out_path=args.out,
                            trace_out=args.trace_out)
    run_cluster(cfg)


if __name__ == "__main__":
    main()

import os
os.environ["XLA_FLAGS"] = os.environ.get(
    "REPRO_DRYRUN_XLA_FLAGS", "--xla_force_host_platform_device_count=512")

"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell.

The two lines above run before ANY other import (jax locks the device count
on first init).  Usage:

  PYTHONPATH=src python -m repro.launch.dryrun --arch qwen3-8b --shape train_4k
  PYTHONPATH=src python -m repro.launch.dryrun --all [--multi-pod] --out results/dryrun

Each cell emits a JSON with memory_analysis, cost_analysis, collective-byte
breakdown (parsed from post-SPMD HLO), sharding decisions, and the roofline
terms.  A failure here (sharding mismatch, OOM at compile, unsupported
collective) is a bug in the system.
"""
import argparse   # noqa: E402
import json       # noqa: E402
import time       # noqa: E402
import traceback  # noqa: E402

import jax        # noqa: E402

from repro.configs import ARCHS, SHAPES, TrainConfig, get_arch, supports_shape  # noqa: E402
from repro.distributed.shardings import shard_ctx                   # noqa: E402
from repro.launch.mesh import make_production_mesh                  # noqa: E402
from repro.launch.specs import plan_cell                            # noqa: E402
from repro import roofline                                          # noqa: E402
from repro.models.model import Model                                # noqa: E402


def run_cell(arch_name: str, shape_name: str, multi_pod: bool,
             variant: dict | None = None, out_dir: str | None = None) -> dict:
    arch = get_arch(arch_name)
    shape = SHAPES[shape_name]
    ok, why = supports_shape(arch, shape)
    label = f"{arch_name} x {shape_name} x {'2x16x16' if multi_pod else '16x16'}"
    if not ok:
        rec = {"cell": label, "status": "skipped", "reason": why,
               "arch": arch_name, "shape": shape_name, "multi_pod": multi_pod}
        _emit(rec, out_dir, arch_name, shape_name, multi_pod, variant)
        print(f"[skip] {label}: {why}")
        return rec

    variant = variant or {}
    if variant:
        arch = arch.replace(**{k: v for k, v in variant.items()
                               if k in arch.__dataclass_fields__ and k != "moe"})
        if "moe_impl" in variant and arch.moe is not None:
            import dataclasses
            arch = arch.replace(
                moe=dataclasses.replace(arch.moe, impl=variant["moe_impl"]))
        if "capacity_factor" in variant and arch.moe is not None:
            import dataclasses
            arch = arch.replace(moe=dataclasses.replace(
                arch.moe, capacity_factor=variant["capacity_factor"]))

    if "mesh_shape" in variant:   # §Perf lever: same chips, different split
        from repro.launch.mesh import compat_mesh
        shp = tuple(variant["mesh_shape"])
        axes = ("data", "model") if len(shp) == 2 else ("pod", "data", "model")
        mesh = compat_mesh(shp, axes)
    else:
        mesh = make_production_mesh(multi_pod=multi_pod)
    n_chips = mesh.size
    t0 = time.time()
    # Production defaults: sequence-parallel activation storage on (see
    # EXPERIMENTS.md §Perf — 5x saved-residual memory win); variants override.
    ctx_kw = {"seq_shard_acts": True}
    ctx_kw.update({k: v for k, v in variant.items()
                   if k in ("seq_shard_acts", "zero3", "force_decode_mode")})
    tcfg = TrainConfig(microbatches=int(variant.get("microbatches", 1)))
    with shard_ctx(mesh, **ctx_kw):
        with mesh:
            plan = plan_cell(arch, shape, mesh, tcfg)
            lowered = plan.lower()
            t_lower = time.time() - t0
            compiled = lowered.compile()
            t_compile = time.time() - t0 - t_lower

            mem = compiled.memory_analysis()
            cost = roofline.hlo_cost_analysis(compiled)
            print(mem)    # proves it fits
            print({k: v for k, v in cost.items()
                   if k in ("flops", "bytes accessed", "optimal_seconds")})

            hlo = compiled.as_text()

    model = Model(arch)
    n_params = model.param_count()
    n_active = roofline.active_params(arch, n_params)
    from repro.models.transformer import segments_for as _segs
    # per-depth trip counts: [microbatch scan, layer scan] (dense: n_layers;
    # hybrid archs unroll segments in python so each body runs `count` times)
    seg_mult = max(c for _, c, _ in _segs(arch))
    trips = ([tcfg.microbatches] if tcfg.microbatches > 1 else []) + [seg_mult]
    mult = seg_mult * max(1, tcfg.microbatches)
    coll = roofline.parse_collectives_nested(hlo, trips)
    coll_raw = roofline.parse_collectives(hlo, loop_multiplier=1)

    # Roofline terms from the analytic model (cost_analysis undercounts
    # rolled scan bodies — see roofline.py; HLO raw numbers recorded below).
    from repro.models.transformer import segments_for
    segs = segments_for(arch)
    ana_f = roofline.analytic_flops(arch, shape, segs)
    ana_b = roofline.analytic_bytes(arch, shape, segs, dict(mesh.shape), n_params)
    flops_dev = ana_f["step_total"] / n_chips
    bytes_dev = ana_b["total"]
    terms = roofline.roofline_terms(flops_dev, bytes_dev, coll.total_bytes)
    mf = roofline.model_flops(arch, shape, n_params, n_active)

    rec = {
        "cell": label, "status": "ok",
        "arch": arch_name, "shape": shape_name, "multi_pod": multi_pod,
        "variant": variant, "meta": plan.meta,
        "n_chips": n_chips, "n_params": n_params, "n_active": n_active,
        "memory": {
            "argument_bytes": getattr(mem, "argument_size_in_bytes", None),
            "output_bytes": getattr(mem, "output_size_in_bytes", None),
            "temp_bytes": getattr(mem, "temp_size_in_bytes", None),
            "peak_bytes": getattr(mem, "peak_memory_in_bytes", None),
        },
        "cost": {
            "flops_per_dev": flops_dev, "bytes_per_dev": bytes_dev,
            "hlo_flops_raw": float(cost.get("flops", 0.0)),
            "hlo_bytes_raw": float(cost.get("bytes accessed", 0.0)),
            "analytic_flops": ana_f, "analytic_bytes": ana_b,
        },
        "collectives": {
            "bytes_by_kind_scaled": coll.bytes_by_kind,
            "bytes_by_kind_raw": coll_raw.bytes_by_kind,
            "count_by_kind": coll.count_by_kind,
            "total_bytes_scaled": coll.total_bytes,
            "loop_multiplier": mult,
        },
        "roofline": terms,
        "model_flops_global": mf,
        "model_flops_per_dev": mf / n_chips,
        "useful_flops_ratio": (mf / n_chips) / flops_dev if flops_dev else None,
        "timings": {"lower_s": t_lower, "compile_s": t_compile},
        "hlo_bytes": len(hlo),
    }
    _emit(rec, out_dir, arch_name, shape_name, multi_pod, variant)
    print(f"[ok] {label}: dominant={terms['dominant']} "
          f"compute={terms['compute_s']:.4f}s memory={terms['memory_s']:.4f}s "
          f"collective={terms['collective_s']:.4f}s "
          f"useful={rec['useful_flops_ratio'] and round(rec['useful_flops_ratio'],3)} "
          f"(lower {t_lower:.0f}s compile {t_compile:.0f}s)")
    return rec


def _emit(rec, out_dir, arch_name, shape_name, multi_pod, variant):
    if not out_dir:
        return
    os.makedirs(out_dir, exist_ok=True)
    vtag = ("__" + "_".join(f"{k}-{v}" for k, v in sorted(variant.items()))) \
        if variant else ""
    fname = f"{arch_name}__{shape_name}__{'mp' if multi_pod else 'sp'}{vtag}.json"
    with open(os.path.join(out_dir, fname.replace('/', '-')), "w") as f:
        json.dump(rec, f, indent=1, default=str)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--both-meshes", action="store_true")
    ap.add_argument("--out", default="results/dryrun")
    ap.add_argument("--variant", default=None,
                    help="JSON dict of ArchConfig / ShardCtx overrides")
    ap.add_argument("--skip-existing", action="store_true")
    args = ap.parse_args()

    variant = json.loads(args.variant) if args.variant else None
    cells: list[tuple[str, str, bool]] = []
    meshes = [False, True] if args.both_meshes else [args.multi_pod]
    if args.all:
        for a in ARCHS:
            for s in SHAPES:
                for mp in meshes:
                    cells.append((a, s, mp))
    else:
        assert args.arch and args.shape, "--arch/--shape or --all"
        for mp in meshes:
            cells.append((args.arch, args.shape, mp))

    failures = []
    for a, s, mp in cells:
        if args.skip_existing and args.out:
            vtag = ("__" + "_".join(f"{k}-{v}" for k, v in sorted((variant or {}).items())))\
                if variant else ""
            f = os.path.join(args.out,
                             f"{a}__{s}__{'mp' if mp else 'sp'}{vtag}.json")
            if os.path.exists(f):
                print(f"[cached] {a} x {s} x {'mp' if mp else 'sp'}")
                continue
        try:
            run_cell(a, s, mp, variant, args.out)
        except Exception as e:
            failures.append((a, s, mp, repr(e)))
            print(f"[FAIL] {a} x {s} x {'mp' if mp else 'sp'}: {e}")
            traceback.print_exc()
    if failures:
        print(f"\n{len(failures)} FAILURES:")
        for f in failures:
            print(" ", f)
        raise SystemExit(1)
    print("\nall cells OK")


if __name__ == "__main__":
    main()

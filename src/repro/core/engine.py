"""Unified OCC engine: one compiled epoch scan for every OCC algorithm.

The paper's observation (and DESIGN.md §2-§3) is that DP-means, OFL, and
BP-means are *one* pattern — optimistic per-point transactions against the
replicated stale state C^{t-1}, plus a serializing validator.  The
`OCCTransaction` protocol captures exactly the algorithm-specific pieces:

  init_pool  — allocate the fixed-capacity global state (may use data stats;
               the engine passes the FIRST EPOCH's points, so batch and
               streaming runs derive identical initializers)
  make_state — per-point auxiliary state for a span of points (e.g. OFL's
               counter-based uniforms, BP-means' previous-pass assignments)
  propose    — the optimistic phase: one batched computation over an epoch's
               points deciding which are sent to the validator
  precompute_accept / accept_pre
             — the validation rule, split into one batched MXU precompute
               (`occ.ValidatePre`) and a D-free scalar decision (§9/§11)
  writeback  — resolve per-point outputs from the validator's verdicts
  refine     — the bulk-synchronous refinement between passes (mean /
               least-squares re-estimation)
  objective  — the algorithm's objective for reporting

`OCCEngine` owns everything the three hand-rolled drivers used to copy:
epoch padding and valid-masking, the serial bootstrap prefix (paper §4.2),
bounded-master validation (`occ.precomputed_gather_validate` — the ONLY
validator; the legacy per-step D-dimensional path lives on solely as the
reference oracle in `core/_reference.py`), mesh sharding of epoch inputs,
and per-epoch statistics.  An entire pass — bootstrap prefix plus all T
bulk-synchronous epochs — runs as a single `jax.lax.scan` inside ONE jit:
the legacy drivers dispatched T compiled epochs from Python and forced a
device→host sync per epoch via `int(n_sent)`; the engine accumulates
`OCCStats` on device and returns them as arrays from the one compiled call
(zero per-epoch host transfers, zero per-epoch dispatch overhead).

Adaptive bounded master (DESIGN.md §11): `validate_cap="adaptive"` sizes the
compaction window from Thm 3.3 — after the bootstrap regime E[#sent per
epoch] ≈ Pb·ε + ΔK, both observable — instead of paying the full (cap, cap)
MXU precompute and O(cap²) scan every epoch.  Caps are power-of-two
bucketed so the jit cache sees a handful of shapes; a pass whose observed
sends exceed its cap (`stats.proposed > stats.cap`) is deterministically
re-dispatched at full width before being committed, so adaptive results are
ALWAYS bit-identical to full-cap results.  The chosen cap is surfaced per
epoch in `OCCStats.cap`.

Transactions are registered as jax pytrees (scalar hyperparameters and rng
keys are leaves; shape-determining fields are static aux data), so the
compiled pass is shared process-wide across engine instances — repeated
calls with the same shapes hit the jit cache exactly like the legacy
module-level epoch jits did.

Streaming: `OCCEngine.partial_fit(batch)` reuses the same transactions and
the same compiled scan for incremental epochs over arriving data — the
online/heavy-traffic serving mode (see examples/streaming_clusters.py).
Batches of ANY length are bit-identical to the one-shot run: the engine
holds back the trailing `n mod pb` points as an explicit partial-epoch
carry so the stream's epoch partition matches the one-shot partition
exactly; `flush()` processes the final short epoch at stream end.  Pool
initialization is deferred to the first committed epoch and computed from
its points, so even data-statistic initializers (BP-means `init_mean`) are
batching-independent.

Train/serve split: the optional `publish=` hook is called with every
committed pass result, so a `serving.SnapshotStore` can freeze immutable
model versions for the read-only serving data plane (DESIGN.md §10) while
the trainer keeps streaming — trainer and service share no mutable state.
"""
from __future__ import annotations

from contextlib import nullcontext
from typing import Any, Callable, NamedTuple, Protocol, runtime_checkable

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.occ import (
    CenterPool, OCCStats, ValidatePre, block_epochs, effective_cap,
    next_pow2, precomputed_gather_validate,
)
from repro.obs.metrics import now as _obs_now

__all__ = ["OCCTransaction", "OCCEngine", "OCCPassResult",
           "resolve_assignments", "accumulate_pass_stats"]


@runtime_checkable
class OCCTransaction(Protocol):
    """What an algorithm must supply to run under the OCC engine.

    Implementations must be registered as jax pytrees (dynamic leaves:
    scalar hyperparameters, rng keys; static aux: anything shape-determining
    such as k_max) so they can flow through the engine's jitted pass.
    """

    def init_pool(self, x: jnp.ndarray) -> CenterPool:
        """Allocate the global state.  The engine calls this with the pass's
        first `pb` points (or everything committed when fewer) — the first
        Pb block, which with a bootstrap prefix spans the prefix plus the
        start of epoch 0 — so data-statistic initializers (BP-means
        `init_mean`) see the same points in one-shot and streaming runs."""
        ...

    def make_state(self, x: jnp.ndarray, offset: int = 0) -> Any:
        """Per-point state pytree (leading dim len(x)) for points starting at
        global index `offset`; () when the transaction is stateless."""
        ...

    def propose(self, pool: CenterPool, x_e: jnp.ndarray, state_e: Any
                ) -> tuple[jnp.ndarray, jnp.ndarray, Any, Any]:
        """Optimistic phase over one epoch's points against C^{t-1}.

        Returns (send (B,) bool, payload (B, D), aux, safe) where `payload`
        is what a sent point proposes (DP/OFL: the point; BP: its residual),
        `aux` is the per-proposal pytree forwarded to the validator (or
        None), and `safe` is the resolved output for points not sent (e.g.
        the nearest-center index, or BP's fitted assignment row).
        """
        ...

    def precompute_accept(self, pool: CenterPool, payload_c: jnp.ndarray,
                          aux_c: Any, count0: jnp.ndarray) -> ValidatePre:
        """Batch-compute every D-dimensional quantity validation can need,
        ONCE on the MXU (REQUIRED — the unified validator contract, §11).

        Payload-append transactions (DP-means, OFL) fill d2_start / idx /
        pair_d2 — reusing the d2/idx the propose phase already found via
        `aux_c` rather than recomputing them; Gram-append transactions
        (BP-means) fill `gram`, the payload inner-product matrix that makes
        the validator refit pure coefficient algebra."""
        ...

    def accept_pre(self, d2_cur: jnp.ndarray, aux_j: Any) -> jnp.ndarray:
        """The D-free accept rule (REQUIRED): given the min squared distance
        to the current pool (payload scan) or the refit residual norm²
        (Gram scan), decide acceptance.  Must be an elementwise monotone
        threshold rule for `scan_mode="logdepth"` to apply (§11)."""
        ...

    def accept(self, pool: CenterPool, payload_j: jnp.ndarray, aux_j: Any,
               count0: jnp.ndarray) -> tuple[jnp.ndarray, jnp.ndarray, Any]:
        """REFERENCE ONLY — the legacy one-proposal-per-step validation rule
        with full D-dimensional recompute.  The engine never calls it; it
        defines the oracle semantics for `core/_reference.py` and the
        serial algorithms."""
        ...

    def writeback(self, send, slots, outs, safe, valid) -> Any:
        """Combine validator verdicts into the per-point epoch output."""
        ...

    def refine(self, pool: CenterPool, x: jnp.ndarray, assign: Any) -> CenterPool:
        """Bulk-synchronous refinement between passes (identity for OFL)."""
        ...

    def objective(self, x: jnp.ndarray, assign: Any, pool: CenterPool) -> jnp.ndarray:
        ...


class OCCPassResult(NamedTuple):
    """Everything one compiled pass returns — all device arrays."""
    pool: CenterPool
    assign: Any             # (N,) int32 or (N, K_max) bool
    send: jnp.ndarray       # (N,) bool — point hit the validator
    epoch_of: jnp.ndarray   # (N,) int32 — epoch each point was processed in
    stats: OCCStats         # (T,) proposed / accepted / cap, on device


def resolve_assignments(send, slots, outs, safe, valid):
    """The DP/OFL writeback: accepted → new slot, rejected → validator's
    nearest-center ref, not sent → optimistic nearest, padding → -1."""
    z = jnp.where(send, jnp.where(slots >= 0, slots, outs), safe)
    return jnp.where(valid, z, -1).astype(jnp.int32)


def accumulate_pass_stats(stat_parts: list[OCCStats]) -> OCCStats:
    """Concatenate per-pass OCCStats into one globally-epoch-numbered tuple
    (empty input → empty stats).  Shared by the multi-pass wrappers so
    every pass's validator load is recorded, not just pass 1's.  `cap`
    concatenates when every part carries it (engine-produced stats always
    do) and stays None when any part is a serial placeholder."""
    if not stat_parts:
        z = jnp.zeros((0,), jnp.int32)
        return OCCStats(z, z, z)
    caps = [s.cap for s in stat_parts]
    return OCCStats(
        jnp.concatenate([s.proposed for s in stat_parts]),
        jnp.concatenate([s.accepted for s in stat_parts]),
        None if any(c is None for c in caps) else jnp.concatenate(caps))


# Trace counter: incremented only when the pass is (re)compiled.  Lets tests
# assert the epoch loop lives inside a single compilation unit.
_PASS_TRACES = 0

# Adaptive-cap policy constants (DESIGN.md §11): smallest cap ever chosen,
# safety margin on the Thm-3.3 estimate, and the decay floor that keeps one
# quiet pass from collapsing the estimate (a retry costs a full re-dispatch).
ADAPTIVE_CAP_MIN = 8
ADAPTIVE_CAP_MARGIN = 2


def _finish_epoch(txn, pool, send, payload, aux, safe, valid_e, validate_cap,
                  scan_mode, replicate=None):
    """Serialize one epoch's proposals: the master half of an OCC epoch.

    Everything after `propose` — valid-masking, the one true precomputed
    validator, writeback, overflow fold, epoch stats.  Split out of
    `_epoch_body` so the proposal block can come from ANYWHERE (the fused
    scan below, or worker processes streaming proposals over sockets in
    `launch/occ_cluster.py`) while validation stays one code path."""
    b = valid_e.shape[0]
    send = jnp.logical_and(send, valid_e)
    pool, slots, outs, sent_ovf = precomputed_gather_validate(
        pool, send, payload, aux, txn.precompute_accept, txn.accept_pre,
        cap=validate_cap, replicate=replicate, scan_mode=scan_mode)
    assign_e = txn.writeback(send, slots, outs, safe, valid_e)
    pool = pool._replace(overflow=jnp.logical_or(pool.overflow, sent_ovf))
    n_sent = jnp.sum(send.astype(jnp.int32))
    n_acc = jnp.sum((slots >= 0).astype(jnp.int32))
    return pool, (assign_e, send, n_sent, n_acc,
                  jnp.asarray(effective_cap(validate_cap, b), jnp.int32))


def _epoch_body(txn, pool, x_e, valid_e, state_e, validate_cap, scan_mode,
                replicate=None):
    """One bulk-synchronous OCC epoch (any width, incl. the width-1 epochs
    of the serial bootstrap prefix) — always on the precomputed validator."""
    send, payload, aux, safe = txn.propose(pool, x_e, state_e)
    return _finish_epoch(txn, pool, send, payload, aux, safe, valid_e,
                         validate_cap, scan_mode, replicate)


def _engine_pass(txn, pool, x, state, *, pb, cap_warm, cap_rest, n_warm,
                 n_bootstrap, mesh, data_axis, scan_mode="serial"):
    """The whole pass: bootstrap prefix + T epochs, one `lax.scan` each,
    inside one jit.  All sizes static; no host round-trips.

    The main epochs split into up to two statically-shaped segments: the
    first `n_warm` run at `cap_warm` (the bootstrap-regime width — epoch 1
    of a cold pool sends everything, Thm 3.3's burn-in) and the rest at
    `cap_rest` (the adaptive Thm-3.3 bound).  Non-adaptive runs pass
    cap_warm == cap_rest and get the single-segment scan unchanged.
    """
    global _PASS_TRACES
    _PASS_TRACES += 1
    n, d = x.shape
    nb = n_bootstrap

    replicate = None
    if mesh is not None:
        # The validator is the replicated master: pin its compacted (cap, …)
        # buffers to the replicated spec so GSPMD gathers once at compaction
        # instead of resharding mid-scan (shardings.occ_validate_sharding).
        from repro.distributed.shardings import occ_validate_sharding
        replicate = lambda a: jax.lax.with_sharding_constraint(
            a, occ_validate_sharding(mesh, a.ndim))

    def epoch_at(cap):
        def epoch(pool, inp):
            return _epoch_body(txn, pool, *inp, cap, scan_mode, replicate)
        return epoch

    # Serial bootstrap prefix (paper §4.2): width-1 epochs are exactly the
    # serial algorithm — each point proposes against the fully up-to-date
    # pool, so this reproduces serial_*_pass on x[:nb].
    assign_b = None
    if nb:
        xb = x[:nb][:, None, :]
        vb = jnp.ones((nb, 1), bool)
        sb = jax.tree.map(lambda s: s[:nb][:, None], state)
        pool, (ab, _, _, _, _) = jax.lax.scan(epoch_at(cap_warm), pool,
                                              (xb, vb, sb))
        assign_b = jax.tree.map(lambda a: a.reshape((nb,) + a.shape[2:]), ab)

    # Main epochs: pad to T*pb, reshape to (T, pb, ...), scan per segment.
    n_rest = n - nb
    t_epochs = block_epochs(n_rest, pb)
    pad = t_epochs * pb - n_rest

    def stack(a):
        flat = jnp.concatenate(
            [a[nb:], jnp.zeros((pad,) + a.shape[1:], a.dtype)], 0)
        return flat.reshape((t_epochs, pb) + a.shape[1:])

    xs = stack(x)
    valid = stack(jnp.ones((n,), bool))
    ss = jax.tree.map(stack, state)
    if mesh is not None:
        # Shard each epoch's points over the data axis: the optimistic phase
        # parallelizes under GSPMD, the validation scan runs replicated
        # (SPMD re-execution of the master).  See shardings.occ_epoch_spec.
        from repro.distributed.shardings import occ_epoch_sharding
        put = lambda a: jax.lax.with_sharding_constraint(
            a, occ_epoch_sharding(mesh, data_axis, pb, a.ndim))
        xs, valid = put(xs), put(valid)
        ss = jax.tree.map(put, ss)

    t_warm = min(n_warm, t_epochs) if cap_warm != cap_rest else 0
    seg_parts = []
    for cap, lo, hi in ((cap_warm, 0, t_warm), (cap_rest, t_warm, t_epochs)):
        if hi <= lo:
            continue
        cut = lambda a: a[lo:hi]
        pool, part = jax.lax.scan(
            epoch_at(cap), pool,
            (cut(xs), cut(valid), jax.tree.map(cut, ss)))
        seg_parts.append(part)
    am, sm, n_sent, n_acc, caps = jax.tree.map(
        lambda *p: jnp.concatenate(p, 0), *seg_parts)

    unstack = lambda a: a.reshape((t_epochs * pb,) + a.shape[2:])[:n_rest]
    assign = jax.tree.map(unstack, am)
    send = unstack(sm)
    if nb:
        assign = jax.tree.map(lambda b, m: jnp.concatenate([b, m], 0),
                              assign_b, assign)
        # Bootstrapped points are processed by the master by construction.
        send = jnp.concatenate([jnp.ones((nb,), bool), send], 0)
    epoch_of = jnp.concatenate([
        jnp.zeros((nb,), jnp.int32),
        jnp.repeat(jnp.arange(t_epochs, dtype=jnp.int32), pb)[:n_rest]])
    return OCCPassResult(pool, assign, send, epoch_of,
                         OCCStats(proposed=n_sent, accepted=n_acc, cap=caps))


_engine_pass_jit = jax.jit(
    _engine_pass,
    static_argnames=("pb", "cap_warm", "cap_rest", "n_warm", "n_bootstrap",
                     "mesh", "data_axis", "scan_mode"))


# Per-epoch jits for the host-driven proposal-source path
# (`OCCEngine.run_from_proposals`).  Key bit-identity fact the multi-process
# cluster rests on: a jitted propose at shard shape equals the matching
# slice of the jitted full-epoch propose, and this per-epoch finish equals
# the fused scan's epoch body — so a pass assembled from worker proposal
# blocks reproduces the single-jit `run()` bitwise (tests/test_occ_cluster).
_propose_epoch_jit = jax.jit(
    lambda txn, pool, x_e, state_e: txn.propose(pool, x_e, state_e))

_finish_epoch_jit = jax.jit(
    lambda txn, pool, send, payload, aux, safe, valid_e, validate_cap,
    scan_mode: _finish_epoch(txn, pool, send, payload, aux, safe, valid_e,
                             validate_cap, scan_mode),
    static_argnames=("validate_cap", "scan_mode"))


class OCCEngine:
    """Driver for OCC transactions: batch passes and streaming epochs.

    Args:
      transaction: an `OCCTransaction` (pytree-registered).
      pb: points per epoch (the paper's P*b product — only the product
        matters algorithmically; `mesh` supplies the physical P).
      validate_cap: bounded-master compaction (occ.precomputed_gather_
        validate).  An int fixes the window; None leaves the master
        unbounded; "adaptive" sizes it per pass from the Thm-3.3 bound
        (observed Pb·ε + K growth, ×2 margin, power-of-two bucketed) with a
        full-width first epoch on cold pools and a deterministic full-width
        retry whenever a pass overflows its window — adaptive results are
        bit-identical to full-cap results by construction.  Overflow of an
        int cap is surfaced on `pool.overflow`.
      scan_mode: "serial" (default) runs the payload accept chain as the
        sequential scalar scan; "logdepth" resolves it as the parallel
        fixed point over the precomputed conflict matrix
        (occ.logdepth_validate) — bit-identical, lower depth.  Gram-append
        transactions (BP-means) always use the Gram-carry scan.
      mesh / data_axis: optional device mesh; each epoch's points are
        sharded over `data_axis` while the validation scan is replicated.
      publish: optional hook `publish(result, n_seen=..., epochs=...,
        cap_est=...)` called after every committed pass (run / partial_fit
        / flush) — the train→serve publication point
        (`SnapshotStore.publish_pass`).  `cap_est` is the adaptive-cap
        estimator at publish time (None when not adaptive), persisted into
        snapshots so `restore()` resumes with a warm cap.
    """

    def __init__(self, transaction: OCCTransaction, pb: int,
                 validate_cap: int | None | str = None,
                 mesh: jax.sharding.Mesh | None = None,
                 data_axis: str = "data",
                 scan_mode: str = "serial",
                 publish: Callable[..., Any] | None = None,
                 obs: Any = None):
        self.txn = transaction
        # Optional telemetry (`repro.obs.Obs`).  None ⇒ ZERO instrumentation
        # cost: no clock reads, no device syncs beyond the caller's own —
        # the occ_engine overhead benchmark A/Bs exactly this switch.
        self.obs = obs
        self.pb = int(pb)
        if isinstance(validate_cap, str) and validate_cap != "adaptive":
            raise ValueError(f"unknown validate_cap {validate_cap!r}")
        if scan_mode not in ("serial", "logdepth"):
            raise ValueError(f"unknown scan_mode {scan_mode!r}")
        self.adaptive = validate_cap == "adaptive"
        self.validate_cap = None if self.adaptive else validate_cap
        self.mesh = mesh
        self.data_axis = data_axis
        self.scan_mode = scan_mode
        self.publish = publish
        self.n_dispatches = 0       # compiled-pass invocations (1 per pass)
        # adaptive-cap observability
        self._cap_est: int | None = None    # None → full width
        self.cap_history: list[int | None] = []   # cap chosen per pass
        self.n_cap_retries = 0
        # streaming state
        self._pool: CenterPool | None = None
        self._n_seen = 0
        self._stat_chunks: list[OCCStats] = []
        self._epoch_base = 0        # global epochs committed so far
        self._carry_x: jnp.ndarray | None = None   # trailing partial epoch
        self._carry_state: Any = None
        self._empty_templates: dict[Any, OCCPassResult] = {}

    # ---------------------------------------------------------- adaptive cap
    def _plan_caps(self, cold: bool) -> tuple[int | None, int | None, int]:
        """(cap_warm, cap_rest, n_warm) for the next dispatched pass."""
        if not self.adaptive:
            return self.validate_cap, self.validate_cap, 0
        rest = self._cap_est
        if rest is None or rest >= self.pb:
            return None, None, 0
        # Cold pool → the first main epoch sends ~everything (Thm 3.3
        # burn-in): keep it full-width, shrink from epoch 2 on.
        return (None, rest, 1) if cold else (rest, rest, 0)

    def _observe_stats(self, stats: OCCStats, cold: bool) -> None:
        """Fold a committed pass's observed load into the Thm-3.3 estimate:
        cap ≈ pow2(2 · (Pb·ε̂ + ΔK̂)) with ε̂, ΔK̂ the post-burn-in per-epoch
        sent rate / pool growth."""
        if not self.adaptive:
            return
        sent = np.asarray(stats.proposed)
        acc = np.asarray(stats.accepted)
        if cold:                       # drop the burn-in epoch's full flood
            sent, acc = sent[1:], acc[1:]
        if sent.size == 0:
            return
        bound = ADAPTIVE_CAP_MARGIN * (int(sent.max()) + int(acc.max()))
        est = next_pow2(max(ADAPTIVE_CAP_MIN, bound))
        if self._cap_est is not None:      # decay floor: halve at most
            est = max(est, self._cap_est // 2)
        self._cap_est = None if est >= self.pb else est

    def _export_pass(self, res: OCCPassResult, t0: float) -> None:
        """Post-pass telemetry export (obs is set): fold the on-device
        `OCCStats` into the registry and the trace WITHOUT adding dispatches
        — the fused pass stays ONE compiled call; stats come back as arrays
        from that call and are read on the host here.  Per-epoch spans are
        synthesized by even subdivision of the measured pass interval
        (flagged ``synthetic_timing`` — the fused scan has no per-epoch
        host timestamps, by design)."""
        m = self.obs.metrics
        prop = np.asarray(res.stats.proposed)    # blocks: pass is done
        acc = np.asarray(res.stats.accepted)
        cap = np.asarray(res.stats.cap)
        t1 = _obs_now()
        n_epochs = int(prop.shape[0])
        n_prop, n_acc = int(prop.sum()), int(acc.sum())
        m.counter("engine_passes").inc()
        m.counter("engine_epochs").inc(n_epochs)
        m.counter("engine_proposed").inc(n_prop)
        m.counter("engine_accepted").inc(n_acc)
        m.counter("engine_rejected").inc(n_prop - n_acc)
        if n_prop:
            # Thm 3.3 conflict rate ε: rejected fraction of proposals.
            m.gauge("engine_conflict_rate").set((n_prop - n_acc) / n_prop)
        if n_epochs:
            m.gauge("engine_cap").set(int(cap[-1]))
        m.histogram("engine_pass_s").observe(t1 - t0)
        tr = self.obs.tracer
        if tr is not None:
            ts0, dur = t0 * 1e6, (t1 - t0) * 1e6
            tr.complete("engine.pass", ts0, dur, cat="engine",
                        args=dict(epochs=n_epochs, proposed=n_prop,
                                  accepted=n_acc,
                                  dispatches=self.n_dispatches))
            if n_epochs:
                step = dur / n_epochs
                for e in range(n_epochs):
                    tr.complete(
                        "engine.epoch", ts0 + e * step, step, cat="engine",
                        args=dict(epoch=e, proposed=int(prop[e]),
                                  accepted=int(acc[e]), cap=int(cap[e]),
                                  synthetic_timing=True))

    def _dispatch(self, pool, x, state, *, n_bootstrap: int, cold: bool,
                  mesh) -> OCCPassResult:
        """One compiled pass, with the adaptive overflow retry: a pass whose
        observed sends exceed its window is re-dispatched at full width
        (deterministic — same inputs), so committed adaptive results are
        always bit-identical to full-cap results."""
        t0 = _obs_now() if self.obs is not None else 0.0
        cap_warm, cap_rest, n_warm = self._plan_caps(cold)
        res = _engine_pass_jit(
            self.txn, pool, x, state, pb=self.pb, cap_warm=cap_warm,
            cap_rest=cap_rest, n_warm=n_warm, n_bootstrap=n_bootstrap,
            mesh=mesh, data_axis=self.data_axis, scan_mode=self.scan_mode)
        self.n_dispatches += 1
        self.cap_history.append(cap_rest)
        if self.adaptive and cap_rest is not None:
            if np.any(np.asarray(res.stats.proposed)
                      > np.asarray(res.stats.cap)):
                self.n_cap_retries += 1
                self._cap_est = None       # estimate was wrong: reset wide
                self.cap_history[-1] = None   # committed pass ran full-width
                res = _engine_pass_jit(
                    self.txn, pool, x, state, pb=self.pb, cap_warm=None,
                    cap_rest=None, n_warm=0, n_bootstrap=n_bootstrap,
                    mesh=mesh, data_axis=self.data_axis,
                    scan_mode=self.scan_mode)
                self.n_dispatches += 1
        self._observe_stats(res.stats, cold)
        if self.obs is not None:
            self._export_pass(res, t0)
        return res

    # ------------------------------------------------------------- batch
    def run(self, x: jnp.ndarray, *, pool: CenterPool | None = None,
            state: Any = None, n_bootstrap: int = 0) -> OCCPassResult:
        """One full pass over x as a single compiled call."""
        cold = pool is None
        if pool is None:
            # Initializer scope = the first Pb block: identical for one-shot
            # and streaming runs (and permutation-free: the data prefix).
            pool = self.txn.init_pool(x[:min(self.pb, x.shape[0])])
        if state is None:
            state = self.txn.make_state(x, 0)
        res = self._dispatch(pool, x, state,
                             n_bootstrap=min(int(n_bootstrap), x.shape[0]),
                             cold=cold, mesh=self.mesh)
        if self.publish is not None:
            self.publish(res, n_seen=x.shape[0],
                         epochs=res.stats.proposed.shape[0],
                         cap_est=self._cap_est)
        return res

    def refine(self, pool: CenterPool, x: jnp.ndarray, assign: Any) -> CenterPool:
        return self.txn.refine(pool, x, assign)

    # ------------------------------------------- pluggable proposal source
    def local_proposer(self):
        """The in-process proposal source: jitted `txn.propose` on the full
        epoch.  `run_from_proposals(x)` with this source is the reference
        the cluster driver's bit-identity audit compares against (and a
        worker's jitted shard propose equals the matching slice of this —
        jit-to-jit exactness is what makes the cluster bitwise faithful)."""
        def propose_fn(pool, x_e, state_e, valid_e, *, epoch, offset):
            send, payload, aux, safe = _propose_epoch_jit(
                self.txn, pool, x_e, state_e)
            return send, payload, aux, safe, valid_e
        return propose_fn

    def run_from_proposals(self, x: jnp.ndarray, propose_fn=None, *,
                           pool: CenterPool | None = None, state: Any = None,
                           n_bootstrap: int = 0, on_commit=None,
                           on_outputs=None,
                           epoch_base: int = 0) -> OCCPassResult:
        """One pass with a PLUGGABLE proposal source — the host-driven dual
        of `run()`, bit-identical to it on the same data.

        Where `run()` fuses propose+validate into one compiled scan,
        this drives the epoch loop from Python and asks `propose_fn` for
        each epoch's proposal block; only the serializing finish
        (`_finish_epoch`: THE validator + writeback) runs here.  That is
        exactly the paper's master: proposals may come from anywhere —
        `local_proposer()` (in-process reference), or P worker processes
        each running `propose` on a disjoint shard with the blocks
        reassembled in global index order (`launch/occ_cluster.py`).

        propose_fn(pool, x_e, state_e, valid_e, *, epoch, offset) returns
        (send, payload, aux, safe, valid_e) for the epoch's `pb` points
        (`offset` is the global index of the epoch's first point; the
        returned valid_e may narrow the input mask, e.g. masking the shard
        of a worker that died mid-epoch).

        on_commit(pool, epoch, t_epochs), when given, runs after each main
        epoch's commit — the per-epoch replication hook: the cluster driver
        publishes the pool delta to followers here, so replication is
        per-epoch exactly as in the paper, not per-pass.

        on_outputs(epoch, assign_e, send_e, stats_e), when given, also runs
        after each main epoch — BEFORE on_commit, so a master that dies
        inside its commit hook has already exported the epoch — with that
        epoch's raw (still padded) assignment block, send mask, and
        (proposed, accepted, cap) scalars.  The §14 audit hook: a
        crash-recovery driver digests per-epoch outputs so runs that cross
        a promotion can be compared bit-for-bit against an uninterrupted
        reference.

        epoch_base shifts the epoch indices reported to propose_fn /
        on_commit / on_outputs (and nothing else): a promoted master that
        resumes from commit watermark v passes the REMAINING points with
        epoch_base=v, so global epoch numbering — and therefore worker
        shard addressing and publish version numbering — continues
        exactly where the dead master stopped.  Offsets stay relative to
        the x of THIS call.

        Adaptive caps need the fused pass's observe/retry machinery and the
        mesh path shards inside the compiled scan; both are refused here.
        Per-epoch dispatches are counted in `n_dispatches` (one per epoch —
        the price of a host-driven loop; `run()` stays 1 per pass).
        """
        if self.adaptive:
            raise ValueError("run_from_proposals requires a fixed/None "
                             "validate_cap (adaptive needs the fused pass)")
        if self.mesh is not None:
            raise ValueError("run_from_proposals is host-driven; use run() "
                             "for mesh-sharded passes")
        if propose_fn is None:
            propose_fn = self.local_proposer()
        cap, sm = self.validate_cap, self.scan_mode
        n, d = x.shape
        nb = min(int(n_bootstrap), n)
        if pool is None:
            pool = self.txn.init_pool(x[:min(self.pb, n)])
        if state is None:
            state = self.txn.make_state(x, 0)

        obs = self.obs
        _span = obs.span if obs is not None else (
            lambda *a, **k: nullcontext())

        # Serial bootstrap prefix: width-1 epochs, stats discarded and send
        # forced True — exactly the fused pass's bootstrap scan.
        assign_parts = []
        for i in range(nb):
            xe = x[i:i + 1]
            se = jax.tree.map(lambda s: s[i:i + 1], state)
            ve = jnp.ones((1,), bool)
            s_, p_, a_, sf_, ve = propose_fn(pool, xe, se, ve,
                                             epoch=0, offset=i)
            pool, (ae, _, _, _, _) = _finish_epoch_jit(
                self.txn, pool, s_, p_, a_, sf_, ve,
                validate_cap=cap, scan_mode=sm)
            self.n_dispatches += 1
            assign_parts.append(ae)
        assign_b = None if not nb else jax.tree.map(
            lambda *p: jnp.concatenate(p, 0), *assign_parts)

        # Main epochs: identical padding/valid-masking to the fused pass.
        n_rest = n - nb
        t_epochs = block_epochs(n_rest, self.pb)
        pad = t_epochs * self.pb - n_rest
        flat = lambda a: jnp.concatenate(
            [a[nb:], jnp.zeros((pad,) + a.shape[1:], a.dtype)], 0)
        xs = flat(x)
        valid = flat(jnp.ones((n,), bool))
        ss = jax.tree.map(flat, state)

        am_parts, sm_parts, sent_l, acc_l, cap_l = [], [], [], [], []
        for e in range(t_epochs):
            ge = epoch_base + e          # global epoch index (§14 resume)
            t0e = _obs_now() if obs is not None else 0.0
            cut = slice(e * self.pb, (e + 1) * self.pb)
            with _span("engine.propose", cat="engine", epoch=ge):
                s_, p_, a_, sf_, ve = propose_fn(
                    pool, xs[cut], jax.tree.map(lambda s: s[cut], ss),
                    valid[cut], epoch=ge, offset=nb + e * self.pb)
            with _span("engine.validate", cat="engine", epoch=ge):
                pool, (ae, sde, ns, na, ce) = _finish_epoch_jit(
                    self.txn, pool, s_, p_, a_, sf_, ve,
                    validate_cap=cap, scan_mode=sm)
            self.n_dispatches += 1
            am_parts.append(ae)
            sm_parts.append(sde)
            sent_l.append(ns)
            acc_l.append(na)
            cap_l.append(ce)
            if obs is not None:
                # Host-driven loop: REAL per-epoch telemetry (unlike the
                # fused pass's synthesized post-pass spans).
                nsi, nai, cei = int(ns), int(na), int(ce)
                m = obs.metrics
                m.counter("engine_epochs").inc()
                m.counter("engine_proposed").inc(nsi)
                m.counter("engine_accepted").inc(nai)
                m.counter("engine_rejected").inc(nsi - nai)
                if nsi:
                    m.gauge("engine_conflict_rate").set((nsi - nai) / nsi)
                m.gauge("engine_cap").set(cei)
                t1e = _obs_now()
                m.histogram("engine_epoch_s").observe(t1e - t0e)
                if obs.tracer is not None:
                    obs.tracer.complete(
                        "engine.epoch", t0e * 1e6, (t1e - t0e) * 1e6,
                        cat="engine",
                        args=dict(epoch=ge, proposed=nsi, accepted=nai,
                                  cap=cei))
            if on_outputs is not None:
                on_outputs(ge, ae, sde, (ns, na, ce))
            if on_commit is not None:
                on_commit(pool, ge, t_epochs)

        unpad = lambda a: a[:n_rest]
        assign = jax.tree.map(
            lambda *p: unpad(jnp.concatenate(p, 0)), *am_parts)
        send = unpad(jnp.concatenate(sm_parts, 0))
        if nb:
            assign = jax.tree.map(lambda b, m: jnp.concatenate([b, m], 0),
                                  assign_b, assign)
            send = jnp.concatenate([jnp.ones((nb,), bool), send], 0)
        epoch_of = jnp.concatenate([
            jnp.zeros((nb,), jnp.int32),
            jnp.repeat(jnp.arange(t_epochs, dtype=jnp.int32),
                       self.pb)[:n_rest]])
        res = OCCPassResult(pool, assign, send, epoch_of,
                            OCCStats(proposed=jnp.stack(sent_l),
                                     accepted=jnp.stack(acc_l),
                                     cap=jnp.stack(cap_l)))
        if self.publish is not None:
            self.publish(res, n_seen=n, epochs=t_epochs,
                         cap_est=self._cap_est)
        return res

    # --------------------------------------------------------- streaming
    @property
    def pool(self) -> CenterPool | None:
        """Current streaming pool (None before the first committed epoch —
        initialization is deferred so data-statistic initializers see the
        first EPOCH, not the first arriving batch)."""
        return self._pool

    @property
    def n_seen(self) -> int:
        """Total points submitted to the stream (including carried ones)."""
        return self._n_seen

    @property
    def n_pending(self) -> int:
        """Points held in the partial-epoch carry, not yet in the pool."""
        return 0 if self._carry_x is None else int(self._carry_x.shape[0])

    @property
    def n_processed(self) -> int:
        """Points whose epoch has been committed to the pool."""
        return self._n_seen - self.n_pending

    @property
    def epochs_done(self) -> int:
        """Global epochs committed so far (the stream's epoch counter)."""
        return self._epoch_base

    @property
    def stats(self) -> OCCStats:
        """All streaming epochs' stats so far, concatenated on device.

        Chunks are consolidated into one array pair on read, so repeated
        reads stay O(1) and the retained list never grows unboundedly."""
        if not self._stat_chunks:
            z = jnp.zeros((0,), jnp.int32)
            return OCCStats(z, z, z)
        if len(self._stat_chunks) > 1:
            self._stat_chunks = [accumulate_pass_stats(self._stat_chunks)]
        return self._stat_chunks[0]

    def reset_stream(self) -> None:
        self._pool, self._n_seen, self._stat_chunks = None, 0, []
        self._epoch_base = 0
        self._carry_x = self._carry_state = None

    def restore(self, snapshot, *, k_max: int) -> None:
        """Resume a stream from a published `serving.ModelSnapshot`.

        Seeds the pool (re-expanded to the trainer's (k_max, D) buffer —
        rows beyond `count` are zero, exactly as in the live pool), the
        global point/epoch counters, AND the adaptive-cap estimator the
        snapshot persisted (`cap_est`), so the restored stream's very
        first pass runs at the warm Thm-3.3 cap instead of paying a
        full-width burn-in pass.  The stream continues from the snapshot's
        `n_seen` — points after the last publish (a pending carry at crash
        time) must be re-sent by the caller.  A restored stream is
        bit-identical to the uninterrupted one from the restore point on
        (adaptive caps never change results — §11's full-width retry)."""
        if self._pool is not None or self._n_seen:
            raise ValueError("restore() requires a fresh engine/stream")
        self._pool = snapshot.to_pool(k_max)
        self._n_seen = snapshot.n_seen
        self._epoch_base = snapshot.epochs
        if self.adaptive and snapshot.cap_est is not None:
            self._cap_est = snapshot.cap_est

    def _empty_stream_result(self, x1: jnp.ndarray, s1: Any) -> OCCPassResult:
        """A zero-point OCCPassResult (pool unchanged, length-0 outputs).

        Returned when a whole batch lands in the partial-epoch carry.  The
        output leaf shapes/dtypes are transaction-specific (DP/OFL: (N,)
        int32; BP: (N, K_max) bool), so they are derived ONCE by shape-only
        tracing of the pass on the carried points — no compute, no dispatch
        — and cached per point shape/dtype: fine-grained streams (arrival
        in sub-pb batches) must not pay a Python re-trace per carry-only
        call.  Before the first commit (no pool yet) the result carries an
        all-zeros pool of the right shape: nothing is in the pool, and the
        initializer must not run until its epoch's points are known.
        """
        key = (x1.shape[1:], str(x1.dtype))
        cached = self._empty_templates.get(key)
        if cached is not None:
            pool = self._pool if self._pool is not None else cached.pool
            return cached._replace(pool=pool)
        global _PASS_TRACES
        traces = _PASS_TRACES          # eval_shape traces without compiling;
        try:                           # don't count it as a compilation
            pool_sd = jax.eval_shape(self.txn.init_pool, x1)
            zero_pool = jax.tree.map(lambda s: jnp.zeros(s.shape, s.dtype),
                                     pool_sd)
            sd = jax.eval_shape(
                lambda p, x, s: _engine_pass(
                    self.txn, p, x, s, pb=self.pb,
                    cap_warm=self.validate_cap, cap_rest=self.validate_cap,
                    n_warm=0, n_bootstrap=0, mesh=None,
                    data_axis=self.data_axis, scan_mode=self.scan_mode),
                zero_pool, x1, s1)
        finally:
            _PASS_TRACES = traces
        empty = lambda s: jnp.zeros((0,) + s.shape[1:], s.dtype)
        # Cache with the NEUTRAL zero pool (a template must not capture the
        # live stream's state — reset_stream would otherwise leak the old
        # pool into a fresh stream's pre-commit results); the caller's
        # current pool is substituted at return time above.
        res = OCCPassResult(
            zero_pool, jax.tree.map(empty, sd.assign), empty(sd.send),
            empty(sd.epoch_of),
            OCCStats(empty(sd.stats.proposed), empty(sd.stats.accepted),
                     empty(sd.stats.cap)))
        self._empty_templates[key] = res
        if self._pool is not None:
            return res._replace(pool=self._pool)
        return res

    def _commit_stream_pass(self, xb: jnp.ndarray, state: Any) -> OCCPassResult:
        """Run one compiled pass over pb-aligned (or final-flush) points and
        fold it into the stream: pool, stats, global epoch numbering,
        publication.  The first commit initializes the pool from ITS first
        epoch's points — the same points the one-shot run's initializer
        sees, so streams are bit-identical even for data-statistic inits."""
        cold = self._pool is None
        if cold:
            self._pool = self.txn.init_pool(xb[:min(self.pb, xb.shape[0])])
        res = self._dispatch(self._pool, xb, state, n_bootstrap=0,
                             cold=cold, mesh=self.mesh)
        self._pool = res.pool
        self._stat_chunks.append(res.stats)
        if len(self._stat_chunks) >= 64:
            _ = self.stats          # consolidate chunks on long streams
        res = res._replace(epoch_of=res.epoch_of + self._epoch_base)
        self._epoch_base += res.stats.proposed.shape[0]
        if self.publish is not None:
            self.publish(res, n_seen=self.n_processed,
                         epochs=self._epoch_base, cap_est=self._cap_est)
        return res

    def partial_fit(self, xb: jnp.ndarray, *, state: Any = None,
                    pool: CenterPool | None = None) -> OCCPassResult:
        """Incremental epochs over an arriving batch (online serving mode).

        The batch is processed against the pool accumulated so far; the
        pool, the count of points seen, and the epoch statistics carry over
        to the next call.  Per-point state is derived from the global point
        index (`make_state(xb, n_seen)`), so e.g. OCC-OFL's counter-based
        uniforms match a one-shot run over the concatenated stream.

        Epoch boundaries are bit-identical to the one-shot run for ANY
        batch length: the trailing `n mod pb` points are held in an
        explicit partial-epoch carry (`n_pending`) and processed when the
        epoch fills in a later call — or by `flush()` at stream end, which
        commits them as the one-shot run's final short epoch.  The returned
        OCCPassResult therefore covers the points *committed* by this call
        (carried points first, then the aligned prefix of this batch);
        concatenating every call's `assign` plus `flush()`'s reproduces the
        one-shot assignment exactly.  `epoch_of` is globally numbered
        across the stream.  A call that only grows the carry returns a
        zero-point result with the pool unchanged.

        Pool initialization is deferred to the first committed epoch and
        computed from its points — exactly the points the one-shot run's
        initializer sees — so even data-statistic initializers (BP-means
        `init_mean`) are batching-independent.  `pool` (first call only)
        still seeds the stream with an explicit initial pool, e.g. a warm
        model restored from a snapshot.
        """
        if pool is not None:
            if self._pool is not None:
                raise ValueError("pool= only seeds the FIRST partial_fit")
            self._pool = pool
        if state is None:
            state = self.txn.make_state(xb, self._n_seen)
        self._n_seen += xb.shape[0]
        if self._carry_x is not None:
            xb = jnp.concatenate([self._carry_x, xb], 0)
            state = jax.tree.map(lambda c, s: jnp.concatenate([c, s], 0),
                                 self._carry_state, state)
        n = xb.shape[0]
        n_full = (n // self.pb) * self.pb
        if n_full < n:
            self._carry_x = xb[n_full:]
            self._carry_state = jax.tree.map(lambda s: s[n_full:], state)
        else:
            self._carry_x = self._carry_state = None
        if n_full == 0:
            return self._empty_stream_result(xb, state)
        xb = xb[:n_full]
        state = jax.tree.map(lambda s: s[:n_full], state)
        return self._commit_stream_pass(xb, state)

    def flush(self) -> OCCPassResult | None:
        """Commit the carried partial epoch as the stream's final short
        epoch (exactly the one-shot run's last epoch).  Returns that
        result, or None when nothing is pending."""
        if self._carry_x is None:
            return None
        xb, state = self._carry_x, self._carry_state
        self._carry_x = self._carry_state = None
        return self._commit_stream_pass(xb, state)

"""Unified OCC engine: one compiled epoch scan for every OCC algorithm.

The paper's observation (and DESIGN.md §2-§3) is that DP-means, OFL, and
BP-means are *one* pattern — optimistic per-point transactions against the
replicated stale state C^{t-1}, plus a serializing validator.  The
`OCCTransaction` protocol captures exactly the algorithm-specific pieces:

  init_pool  — allocate the fixed-capacity global state (may use data stats)
  make_state — per-point auxiliary state for a span of points (e.g. OFL's
               counter-based uniforms, BP-means' previous-pass assignments)
  propose    — the optimistic phase: one batched computation over an epoch's
               points deciding which are sent to the validator
  accept     — the serial validation rule for one proposal, given the pool
               *including this epoch's previously accepted proposals*
  writeback  — resolve per-point outputs from the validator's verdicts
  refine     — the bulk-synchronous refinement between passes (mean /
               least-squares re-estimation)
  objective  — the algorithm's objective for reporting

`OCCEngine` owns everything the three hand-rolled drivers used to copy:
epoch padding and valid-masking, the serial bootstrap prefix (paper §4.2),
bounded-master validation (`gather_validate`), mesh sharding of epoch
inputs, and per-epoch statistics.  An entire pass — bootstrap prefix plus
all T bulk-synchronous epochs — runs as a single `jax.lax.scan` inside ONE
jit: the legacy drivers dispatched T compiled epochs from Python and forced
a device→host sync per epoch via `int(n_sent)`; the engine accumulates
`OCCStats` on device and returns them as arrays from the one compiled call
(zero per-epoch host transfers, zero per-epoch dispatch overhead).

Transactions are registered as jax pytrees (scalar hyperparameters and rng
keys are leaves; shape-determining fields are static aux data), so the
compiled pass is shared process-wide across engine instances — repeated
calls with the same shapes hit the jit cache exactly like the legacy
module-level epoch jits did.

Streaming: `OCCEngine.partial_fit(batch)` reuses the same transactions and
the same compiled scan for incremental epochs over arriving data — the
online/heavy-traffic serving mode (see examples/streaming_clusters.py).
Batches of ANY length are bit-identical to the one-shot run: the engine
holds back the trailing `n mod pb` points as an explicit partial-epoch
carry so the stream's epoch partition matches the one-shot partition
exactly; `flush()` processes the final short epoch at stream end.

Train/serve split: the optional `publish=` hook is called with every
committed pass result, so a `serving.SnapshotStore` can freeze immutable
model versions for the read-only serving data plane (DESIGN.md §10) while
the trainer keeps streaming — trainer and service share no mutable state.
"""
from __future__ import annotations

from typing import Any, Callable, NamedTuple, Protocol, runtime_checkable

import jax
import jax.numpy as jnp

from repro.core.occ import (
    CenterPool, OCCStats, block_epochs, gather_validate,
    precomputed_gather_validate,
)

__all__ = ["OCCTransaction", "OCCEngine", "OCCPassResult",
           "resolve_assignments", "resolve_validate_mode",
           "accumulate_pass_stats"]


@runtime_checkable
class OCCTransaction(Protocol):
    """What an algorithm must supply to run under the OCC engine.

    Implementations must be registered as jax pytrees (dynamic leaves:
    scalar hyperparameters, rng keys; static aux: anything shape-determining
    such as k_max) so they can flow through the engine's jitted pass.
    """

    def init_pool(self, x: jnp.ndarray) -> CenterPool:
        """Allocate the global state; may use data statistics (BP init_mean)."""
        ...

    def make_state(self, x: jnp.ndarray, offset: int = 0) -> Any:
        """Per-point state pytree (leading dim len(x)) for points starting at
        global index `offset`; () when the transaction is stateless."""
        ...

    def propose(self, pool: CenterPool, x_e: jnp.ndarray, state_e: Any
                ) -> tuple[jnp.ndarray, jnp.ndarray, Any, Any]:
        """Optimistic phase over one epoch's points against C^{t-1}.

        Returns (send (B,) bool, payload (B, D), aux, safe) where `payload`
        is what a sent point proposes (DP/OFL: the point; BP: its residual),
        `aux` is the per-proposal pytree forwarded to `accept` (or None),
        and `safe` is the resolved output for points not sent (e.g. the
        nearest-center index, or BP's fitted assignment row).
        """
        ...

    def accept(self, pool: CenterPool, payload_j: jnp.ndarray, aux_j: Any,
               count0: jnp.ndarray) -> tuple[jnp.ndarray, jnp.ndarray, Any]:
        """Serial validation of one proposal.  `count0` is the pool count at
        epoch start (BPValidate fits only against this epoch's accepts).
        Returns (accept bool, vector to append, out_j for writeback).

        This is the legacy / reference path: one D-dimensional computation
        per sequential scan step.  Transactions whose accepted append vector
        IS the payload should ALSO implement the optional fast-path pair

          precompute_accept(pool, payload_c, aux_c, count0) -> ValidatePre
              batch-compute every D-dimensional quantity the validator can
              need, ONCE on the MXU (see occ.ValidatePre) — reusing the
              d2/idx the propose phase already found via `aux_c` rather than
              recomputing them;
          accept_pre(d2_cur, aux_j) -> bool
              the scalar accept rule given the min squared distance to the
              current pool,

        which degrade the serializing scan to O(cap²) scalar work
        (occ.precomputed_validate).  The engine picks the fast path whenever
        `precompute_accept` is defined (see `resolve_validate_mode`);
        BP-means cannot use it — its append vector is the validator-refit
        residual, not the sent payload — and stays on this path."""
        ...

    def writeback(self, send, slots, outs, safe, valid) -> Any:
        """Combine validator verdicts into the per-point epoch output."""
        ...

    def refine(self, pool: CenterPool, x: jnp.ndarray, assign: Any) -> CenterPool:
        """Bulk-synchronous refinement between passes (identity for OFL)."""
        ...

    def objective(self, x: jnp.ndarray, assign: Any, pool: CenterPool) -> jnp.ndarray:
        ...


class OCCPassResult(NamedTuple):
    """Everything one compiled pass returns — all device arrays."""
    pool: CenterPool
    assign: Any             # (N,) int32 or (N, K_max) bool
    send: jnp.ndarray       # (N,) bool — point hit the validator
    epoch_of: jnp.ndarray   # (N,) int32 — epoch each point was processed in
    stats: OCCStats         # (T,) proposed / accepted, on device


def resolve_assignments(send, slots, outs, safe, valid):
    """The DP/OFL writeback: accepted → new slot, rejected → validator's
    nearest-center ref, not sent → optimistic nearest, padding → -1."""
    z = jnp.where(send, jnp.where(slots >= 0, slots, outs), safe)
    return jnp.where(valid, z, -1).astype(jnp.int32)


def accumulate_pass_stats(stat_parts: list[OCCStats]) -> OCCStats:
    """Concatenate per-pass OCCStats into one globally-epoch-numbered pair
    (empty input → empty stats).  Shared by the multi-pass wrappers so
    every pass's validator load is recorded, not just pass 1's."""
    if not stat_parts:
        z = jnp.zeros((0,), jnp.int32)
        return OCCStats(z, z)
    return OCCStats(
        jnp.concatenate([s.proposed for s in stat_parts]),
        jnp.concatenate([s.accepted for s in stat_parts]))


# Trace counter: incremented only when the pass is (re)compiled.  Lets tests
# assert the epoch loop lives inside a single compilation unit.
_PASS_TRACES = 0


def resolve_validate_mode(txn, validate_mode: str = "auto") -> str:
    """Which validator the engine runs for this transaction.

    "auto" resolves to "precomputed" when the transaction defines the
    `precompute_accept` / `accept_pre` fast-path pair (DP-means, OFL) and to
    "legacy" otherwise (BP-means); "precomputed" / "legacy" force the path.
    """
    has_fast = (callable(getattr(txn, "precompute_accept", None))
                and callable(getattr(txn, "accept_pre", None)))
    if validate_mode == "auto":
        return "precomputed" if has_fast else "legacy"
    if validate_mode not in ("precomputed", "legacy"):
        raise ValueError(f"unknown validate_mode {validate_mode!r}")
    if validate_mode == "precomputed" and not has_fast:
        raise ValueError(
            f"{type(txn).__name__} defines no precompute_accept fast path")
    return validate_mode


def _epoch_body(txn, pool, x_e, valid_e, state_e, validate_cap,
                validate_mode: str = "auto", replicate=None):
    """One bulk-synchronous OCC epoch (any width, incl. the width-1 epochs
    of the serial bootstrap prefix)."""
    count0 = pool.count
    send, payload, aux, safe = txn.propose(pool, x_e, state_e)
    send = jnp.logical_and(send, valid_e)
    if resolve_validate_mode(txn, validate_mode) == "precomputed":
        pool, slots, outs, sent_ovf = precomputed_gather_validate(
            pool, send, payload, aux, txn.precompute_accept, txn.accept_pre,
            cap=validate_cap, replicate=replicate)
    else:
        accept = lambda p, v_j, a_j: txn.accept(p, v_j, a_j, count0)
        pool, slots, outs, sent_ovf = gather_validate(
            pool, send, payload, accept, aux, cap=validate_cap)
    assign_e = txn.writeback(send, slots, outs, safe, valid_e)
    pool = pool._replace(overflow=jnp.logical_or(pool.overflow, sent_ovf))
    n_sent = jnp.sum(send.astype(jnp.int32))
    n_acc = jnp.sum((slots >= 0).astype(jnp.int32))
    return pool, (assign_e, send, n_sent, n_acc)


def _engine_pass(txn, pool, x, state, *, pb, validate_cap, n_bootstrap,
                 mesh, data_axis, validate_mode="auto"):
    """The whole pass: bootstrap prefix + T epochs, one `lax.scan` each,
    inside one jit.  All sizes static; no host round-trips."""
    global _PASS_TRACES
    _PASS_TRACES += 1
    n, d = x.shape
    nb = n_bootstrap

    replicate = None
    if mesh is not None:
        # The validator is the replicated master: pin its compacted (cap, …)
        # buffers to the replicated spec so GSPMD gathers once at compaction
        # instead of resharding mid-scan (shardings.occ_validate_sharding).
        from repro.distributed.shardings import occ_validate_sharding
        replicate = lambda a: jax.lax.with_sharding_constraint(
            a, occ_validate_sharding(mesh, a.ndim))

    def epoch(pool, inp):
        return _epoch_body(txn, pool, *inp, validate_cap, validate_mode,
                           replicate)

    # Serial bootstrap prefix (paper §4.2): width-1 epochs are exactly the
    # serial algorithm — each point proposes against the fully up-to-date
    # pool, so this reproduces serial_*_pass on x[:nb].
    assign_b = None
    if nb:
        xb = x[:nb][:, None, :]
        vb = jnp.ones((nb, 1), bool)
        sb = jax.tree.map(lambda s: s[:nb][:, None], state)
        pool, (ab, _, _, _) = jax.lax.scan(epoch, pool, (xb, vb, sb))
        assign_b = jax.tree.map(lambda a: a.reshape((nb,) + a.shape[2:]), ab)

    # Main epochs: pad to T*pb, reshape to (T, pb, ...), scan.
    n_rest = n - nb
    t_epochs = block_epochs(n_rest, pb)
    pad = t_epochs * pb - n_rest

    def stack(a):
        flat = jnp.concatenate(
            [a[nb:], jnp.zeros((pad,) + a.shape[1:], a.dtype)], 0)
        return flat.reshape((t_epochs, pb) + a.shape[1:])

    xs = stack(x)
    valid = stack(jnp.ones((n,), bool))
    ss = jax.tree.map(stack, state)
    if mesh is not None:
        # Shard each epoch's points over the data axis: the optimistic phase
        # parallelizes under GSPMD, the validation scan runs replicated
        # (SPMD re-execution of the master).  See shardings.occ_epoch_spec.
        from repro.distributed.shardings import occ_epoch_sharding
        put = lambda a: jax.lax.with_sharding_constraint(
            a, occ_epoch_sharding(mesh, data_axis, pb, a.ndim))
        xs, valid = put(xs), put(valid)
        ss = jax.tree.map(put, ss)

    pool, (am, sm, n_sent, n_acc) = jax.lax.scan(epoch, pool, (xs, valid, ss))

    unstack = lambda a: a.reshape((t_epochs * pb,) + a.shape[2:])[:n_rest]
    assign = jax.tree.map(unstack, am)
    send = unstack(sm)
    if nb:
        assign = jax.tree.map(lambda b, m: jnp.concatenate([b, m], 0),
                              assign_b, assign)
        # Bootstrapped points are processed by the master by construction.
        send = jnp.concatenate([jnp.ones((nb,), bool), send], 0)
    epoch_of = jnp.concatenate([
        jnp.zeros((nb,), jnp.int32),
        jnp.repeat(jnp.arange(t_epochs, dtype=jnp.int32), pb)[:n_rest]])
    return OCCPassResult(pool, assign, send, epoch_of,
                         OCCStats(proposed=n_sent, accepted=n_acc))


_engine_pass_jit = jax.jit(
    _engine_pass,
    static_argnames=("pb", "validate_cap", "n_bootstrap", "mesh", "data_axis",
                     "validate_mode"))


class OCCEngine:
    """Driver for OCC transactions: batch passes and streaming epochs.

    Args:
      transaction: an `OCCTransaction` (pytree-registered).
      pb: points per epoch (the paper's P*b product — only the product
        matters algorithmically; `mesh` supplies the physical P).
      validate_cap: bounded-master compaction (see occ.gather_validate);
        overflow is surfaced on `pool.overflow`.
      validate_mode: "auto" (default — precomputed fast path when the
        transaction supports it, see `resolve_validate_mode`), or force
        "precomputed" / "legacy".  The two paths are bit-identical
        (tests/test_validator_equivalence.py); legacy is retained as the
        full-recompute reference implementation.
      mesh / data_axis: optional device mesh; each epoch's points are
        sharded over `data_axis` while the validation scan is replicated.
      publish: optional hook `publish(result, n_seen=..., epochs=...)`
        called after every committed pass (run / partial_fit / flush) —
        the train→serve publication point (`SnapshotStore.publish_pass`).
    """

    def __init__(self, transaction: OCCTransaction, pb: int,
                 validate_cap: int | None = None,
                 mesh: jax.sharding.Mesh | None = None,
                 data_axis: str = "data",
                 validate_mode: str = "auto",
                 publish: Callable[..., Any] | None = None):
        self.txn = transaction
        self.pb = int(pb)
        self.validate_cap = validate_cap
        self.mesh = mesh
        self.data_axis = data_axis
        self.validate_mode = resolve_validate_mode(transaction, validate_mode)
        self.publish = publish
        self.n_dispatches = 0       # compiled-pass invocations (1 per pass)
        # streaming state
        self._pool: CenterPool | None = None
        self._n_seen = 0
        self._stat_chunks: list[OCCStats] = []
        self._epoch_base = 0        # global epochs committed so far
        self._carry_x: jnp.ndarray | None = None   # trailing partial epoch
        self._carry_state: Any = None
        self._empty_templates: dict[Any, OCCPassResult] = {}

    # ------------------------------------------------------------- batch
    def run(self, x: jnp.ndarray, *, pool: CenterPool | None = None,
            state: Any = None, n_bootstrap: int = 0) -> OCCPassResult:
        """One full pass over x as a single compiled call."""
        if pool is None:
            pool = self.txn.init_pool(x)
        if state is None:
            state = self.txn.make_state(x, 0)
        res = _engine_pass_jit(
            self.txn, pool, x, state, pb=self.pb,
            validate_cap=self.validate_cap,
            n_bootstrap=min(int(n_bootstrap), x.shape[0]),
            mesh=self.mesh, data_axis=self.data_axis,
            validate_mode=self.validate_mode)
        self.n_dispatches += 1
        if self.publish is not None:
            self.publish(res, n_seen=x.shape[0],
                         epochs=res.stats.proposed.shape[0])
        return res

    def refine(self, pool: CenterPool, x: jnp.ndarray, assign: Any) -> CenterPool:
        return self.txn.refine(pool, x, assign)

    # --------------------------------------------------------- streaming
    @property
    def pool(self) -> CenterPool | None:
        """Current streaming pool (None before the first partial_fit)."""
        return self._pool

    @property
    def n_seen(self) -> int:
        """Total points submitted to the stream (including carried ones)."""
        return self._n_seen

    @property
    def n_pending(self) -> int:
        """Points held in the partial-epoch carry, not yet in the pool."""
        return 0 if self._carry_x is None else int(self._carry_x.shape[0])

    @property
    def n_processed(self) -> int:
        """Points whose epoch has been committed to the pool."""
        return self._n_seen - self.n_pending

    @property
    def epochs_done(self) -> int:
        """Global epochs committed so far (the stream's epoch counter)."""
        return self._epoch_base

    @property
    def stats(self) -> OCCStats:
        """All streaming epochs' stats so far, concatenated on device.

        Chunks are consolidated into one array pair on read, so repeated
        reads stay O(1) and the retained list never grows unboundedly."""
        if not self._stat_chunks:
            z = jnp.zeros((0,), jnp.int32)
            return OCCStats(z, z)
        if len(self._stat_chunks) > 1:
            merged = OCCStats(
                jnp.concatenate([s.proposed for s in self._stat_chunks]),
                jnp.concatenate([s.accepted for s in self._stat_chunks]))
            self._stat_chunks = [merged]
        return self._stat_chunks[0]

    def reset_stream(self) -> None:
        self._pool, self._n_seen, self._stat_chunks = None, 0, []
        self._epoch_base = 0
        self._carry_x = self._carry_state = None

    def _empty_stream_result(self, x1: jnp.ndarray, s1: Any) -> OCCPassResult:
        """A zero-point OCCPassResult (pool unchanged, length-0 outputs).

        Returned when a whole batch lands in the partial-epoch carry.  The
        output leaf shapes/dtypes are transaction-specific (DP/OFL: (N,)
        int32; BP: (N, K_max) bool), so they are derived ONCE by shape-only
        tracing of the pass on the carried points — no compute, no dispatch
        — and cached per point shape/dtype: fine-grained streams (arrival
        in sub-pb batches) must not pay a Python re-trace per carry-only
        call.
        """
        key = (x1.shape[1:], str(x1.dtype))
        cached = self._empty_templates.get(key)
        if cached is not None:
            return cached._replace(pool=self._pool)
        global _PASS_TRACES
        traces = _PASS_TRACES          # eval_shape traces without compiling;
        try:                           # don't count it as a compilation
            sd = jax.eval_shape(
                lambda p, x, s: _engine_pass(
                    self.txn, p, x, s, pb=self.pb,
                    validate_cap=self.validate_cap, n_bootstrap=0,
                    mesh=None, data_axis=self.data_axis,
                    validate_mode=self.validate_mode),
                self._pool, x1, s1)
        finally:
            _PASS_TRACES = traces
        empty = lambda s: jnp.zeros((0,) + s.shape[1:], s.dtype)
        res = OCCPassResult(
            self._pool, jax.tree.map(empty, sd.assign), empty(sd.send),
            empty(sd.epoch_of),
            OCCStats(empty(sd.stats.proposed), empty(sd.stats.accepted)))
        self._empty_templates[key] = res
        return res

    def _commit_stream_pass(self, xb: jnp.ndarray, state: Any) -> OCCPassResult:
        """Run one compiled pass over pb-aligned (or final-flush) points and
        fold it into the stream: pool, stats, global epoch numbering,
        publication."""
        res = _engine_pass_jit(
            self.txn, self._pool, xb, state, pb=self.pb,
            validate_cap=self.validate_cap, n_bootstrap=0,
            mesh=self.mesh, data_axis=self.data_axis,
            validate_mode=self.validate_mode)
        self.n_dispatches += 1
        self._pool = res.pool
        self._stat_chunks.append(res.stats)
        if len(self._stat_chunks) >= 64:
            _ = self.stats          # consolidate chunks on long streams
        res = res._replace(epoch_of=res.epoch_of + self._epoch_base)
        self._epoch_base += res.stats.proposed.shape[0]
        if self.publish is not None:
            self.publish(res, n_seen=self.n_processed,
                         epochs=self._epoch_base)
        return res

    def partial_fit(self, xb: jnp.ndarray, *, state: Any = None,
                    pool: CenterPool | None = None) -> OCCPassResult:
        """Incremental epochs over an arriving batch (online serving mode).

        The batch is processed against the pool accumulated so far; the
        pool, the count of points seen, and the epoch statistics carry over
        to the next call.  Per-point state is derived from the global point
        index (`make_state(xb, n_seen)`), so e.g. OCC-OFL's counter-based
        uniforms match a one-shot run over the concatenated stream.

        Epoch boundaries are bit-identical to the one-shot run for ANY
        batch length: the trailing `n mod pb` points are held in an
        explicit partial-epoch carry (`n_pending`) and processed when the
        epoch fills in a later call — or by `flush()` at stream end, which
        commits them as the one-shot run's final short epoch.  The returned
        OCCPassResult therefore covers the points *committed* by this call
        (carried points first, then the aligned prefix of this batch);
        concatenating every call's `assign` plus `flush()`'s reproduces the
        one-shot assignment exactly.  `epoch_of` is globally numbered
        across the stream.  A call that only grows the carry returns a
        zero-point result with the pool unchanged.

        `pool` (first call only) seeds the stream with an explicit initial
        pool — e.g. BP-means' mean-initialized pool computed over data the
        stream's first batch hasn't seen.  Without it the pool initializes
        from the first batch, which for transactions whose `init_pool` uses
        data statistics is the one (documented) way a stream can differ
        from the one-shot run.
        """
        if pool is not None:
            if self._pool is not None:
                raise ValueError("pool= only seeds the FIRST partial_fit")
            self._pool = pool
        if self._pool is None:
            self._pool = self.txn.init_pool(xb)
        if state is None:
            state = self.txn.make_state(xb, self._n_seen)
        self._n_seen += xb.shape[0]
        if self._carry_x is not None:
            xb = jnp.concatenate([self._carry_x, xb], 0)
            state = jax.tree.map(lambda c, s: jnp.concatenate([c, s], 0),
                                 self._carry_state, state)
        n = xb.shape[0]
        n_full = (n // self.pb) * self.pb
        if n_full < n:
            self._carry_x = xb[n_full:]
            self._carry_state = jax.tree.map(lambda s: s[n_full:], state)
        else:
            self._carry_x = self._carry_state = None
        if n_full == 0:
            return self._empty_stream_result(xb, state)
        xb = xb[:n_full]
        state = jax.tree.map(lambda s: s[:n_full], state)
        return self._commit_stream_pass(xb, state)

    def flush(self) -> OCCPassResult | None:
        """Commit the carried partial epoch as the stream's final short
        epoch (exactly the one-shot run's last epoch).  Returns that
        result, or None when nothing is pending."""
        if self._carry_x is None:
            return None
        xb, state = self._carry_x, self._carry_state
        self._carry_x = self._carry_state = None
        return self._commit_stream_pass(xb, state)

"""DP-means: serial (Alg. 1) and OCC-parallel (Alg. 3 + DPValidate Alg. 2).

The OCC version is serially equivalent to Alg. 1 under the Thm-3.1
permutation: within an epoch, non-proposed points (whose assignment depends
only on C^{t-1}) are ordered before proposed points, which are validated in
global index order.
"""
from __future__ import annotations

import math
from functools import partial
from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.objective import dp_means_objective
from repro.core.occ import (
    CenterPool, OCCStats, make_pool, nearest_center, serial_validate,
    gather_validate,
)

__all__ = ["DPMeansResult", "serial_dp_means_pass", "serial_dp_means",
           "occ_dp_means_pass", "occ_dp_means"]


class DPMeansResult(NamedTuple):
    pool: CenterPool
    z: jnp.ndarray              # (N,) int32 — assignment to pool slot
    stats: OCCStats             # per-epoch proposed / accepted counts
    send: jnp.ndarray           # (N,) bool — point was sent to the validator
    epoch_of: jnp.ndarray       # (N,) int32 — epoch each point was processed in
    n_iters: int
    objective: jnp.ndarray


def _dp_accept(lam2: float):
    """DPValidate accept rule: accept iff not within lambda of any center."""
    def accept_fn(pool: CenterPool, x_j, aux_j):
        d2, ref = nearest_center(pool, x_j)
        return d2 > lam2, x_j, ref
    return accept_fn


# ---------------------------------------------------------------------------
# Serial DP-means (Alg. 1)
# ---------------------------------------------------------------------------

@partial(jax.jit, static_argnames=("k_max",))
def serial_dp_means_pass(x: jnp.ndarray, lam: float, k_max: int,
                         pool: CenterPool | None = None):
    """One serial pass of Alg. 1's inner loop: scan points in order,
    assigning to the nearest center or creating a new one.

    Equivalent to validating *every* point serially — the degenerate OCC run
    with P = b = 1.  Returns (pool, z).
    """
    if pool is None:
        pool = make_pool(k_max, x.shape[-1], x.dtype)
    lam2 = jnp.asarray(lam, x.dtype) ** 2
    send = jnp.ones((x.shape[0],), bool)
    pool, slots, refs = serial_validate(pool, send, x, _dp_accept(lam2))
    z = jnp.where(slots >= 0, slots, refs).astype(jnp.int32)
    return pool, z


def _recompute_means(x: jnp.ndarray, z: jnp.ndarray, pool: CenterPool) -> CenterPool:
    """Second phase of Alg. 1/3: mu_k <- Mean({x_i | z_i = k}).

    Slots with no assigned points keep their previous vector (cannot happen
    within the creating iteration; can after reassignment in later ones).
    Trivially parallel: segment sums are psum-able over the data axis.
    """
    k_max = pool.centers.shape[0]
    zc = jnp.clip(z, 0, k_max - 1)
    valid = z >= 0
    w = valid.astype(x.dtype)
    sums = jax.ops.segment_sum(x * w[:, None], zc, num_segments=k_max)
    cnts = jax.ops.segment_sum(w, zc, num_segments=k_max)
    means = sums / jnp.maximum(cnts, 1.0)[:, None]
    new_centers = jnp.where((cnts > 0)[:, None] & pool.mask[:, None], means, pool.centers)
    return pool._replace(centers=new_centers)


def serial_dp_means(x: jnp.ndarray, lam: float, k_max: int = 256,
                    max_iters: int = 20) -> DPMeansResult:
    """Full serial DP-means (Alg. 1): alternate the assignment/creation pass
    with the centroid recomputation until assignments are fixed."""
    n = x.shape[0]
    pool = make_pool(k_max, x.shape[-1], x.dtype)
    z_prev = None
    it = 0
    for it in range(1, max_iters + 1):
        pool, z = serial_dp_means_pass(x, lam, k_max, pool)
        pool = _recompute_means(x, z, pool)
        if z_prev is not None and bool(jnp.all(z == z_prev)):
            break
        z_prev = z
    obj = dp_means_objective(x, pool.centers, lam, pool.mask)
    t = np.zeros((1,), np.int32)
    return DPMeansResult(pool, z, OCCStats(t, t), jnp.zeros((n,), bool),
                         jnp.zeros((n,), jnp.int32), it, obj)


# ---------------------------------------------------------------------------
# OCC DP-means (Alg. 3)
# ---------------------------------------------------------------------------

@partial(jax.jit, static_argnames=("validate_cap",))
def _dp_epoch(pool: CenterPool, xs: jnp.ndarray, valid: jnp.ndarray,
              lam2: jnp.ndarray, validate_cap: int | None = None):
    """One bulk-synchronous OCC epoch over Pb points (Alg. 3 inner body).

    Optimistic phase — one batched distance computation against the
    replicated C^{t-1} (sharded over the `data` mesh axis under pjit; this is
    each "processor" handling its block).  Points beyond lambda of every
    center are proposals; the rest are safely assigned.

    Validation phase — deterministic serial scan (DPValidate), replicated.
    """
    d2, idx = nearest_center(pool, xs)
    send = jnp.logical_and(d2 > lam2, valid)
    pool2, slots, refs, v_overflow = gather_validate(
        pool, send, xs, _dp_accept(lam2), cap=validate_cap)
    z = jnp.where(send, jnp.where(slots >= 0, slots, refs), idx).astype(jnp.int32)
    z = jnp.where(valid, z, -1)
    n_sent = jnp.sum(send.astype(jnp.int32))
    n_acc = jnp.sum((slots >= 0).astype(jnp.int32))
    pool2 = pool2._replace(overflow=jnp.logical_or(pool2.overflow, v_overflow))
    return pool2, z, send, n_sent, n_acc


def occ_dp_means(
    x: jnp.ndarray,
    lam: float,
    pb: int,
    k_max: int = 256,
    max_iters: int = 1,
    bootstrap: bool = False,
    validate_cap: int | None = None,
    mesh: jax.sharding.Mesh | None = None,
    data_axis: str = "data",
) -> DPMeansResult:
    """OCC DP-means (Alg. 3).

    Args:
      x: (N, D) data.  pb: points per epoch (the paper's P*b product — only
      the product matters algorithmically; the mesh supplies the physical P).
      max_iters: outer while-loop passes (1 = the paper's Fig-3 setting).
      bootstrap: serially pre-process the first pb/16 points (paper §4.2).
      validate_cap: bounded-master compaction (see occ.gather_validate).
      mesh: optional device mesh; epoch inputs are sharded over `data_axis`
      and the optimistic phase parallelizes under GSPMD while the validation
      scan executes replicated (SPMD re-execution of the master).
    """
    n, d = x.shape
    lam2 = jnp.asarray(lam, x.dtype) ** 2
    pool = make_pool(k_max, d, x.dtype)
    z = jnp.full((n,), -1, jnp.int32)
    send_all = jnp.zeros((n,), bool)
    epoch_of = jnp.zeros((n,), jnp.int32)

    put = None
    if mesh is not None:
        shd = jax.sharding.NamedSharding(mesh, jax.sharding.PartitionSpec(data_axis))
        put = lambda a: jax.device_put(a, shd)

    start = 0
    if bootstrap:
        nb = max(1, pb // 16)
        pool, zb = serial_dp_means_pass(x[:nb], lam, k_max, pool)
        z = z.at[:nb].set(zb)
        send_all = send_all.at[:nb].set(True)  # bootstrapped points hit the master
        start = nb

    n_rest = n - start
    t_epochs = max(1, math.ceil(n_rest / pb))
    pad = t_epochs * pb - n_rest
    xs = jnp.concatenate([x[start:], jnp.zeros((pad, d), x.dtype)], 0)
    valid = jnp.concatenate([jnp.ones((n_rest,), bool), jnp.zeros((pad,), bool)])

    stats_p, stats_a = [], []
    z_prev = None
    it_done = 0
    for it in range(1, max_iters + 1):
        it_done = it
        for t in range(t_epochs):
            xe = xs[t * pb:(t + 1) * pb]
            ve = valid[t * pb:(t + 1) * pb]
            if put is not None:
                xe, ve = put(xe), put(ve)
            pool, ze, se, n_sent, n_acc = _dp_epoch(pool, xe, ve, lam2, validate_cap)
            lo = start + t * pb
            hi = min(lo + pb, n)
            keep = hi - lo
            z = z.at[lo:hi].set(ze[:keep])
            send_all = send_all.at[lo:hi].set(se[:keep])
            epoch_of = epoch_of.at[lo:hi].set(t)
            if it == 1:
                stats_p.append(int(n_sent))
                stats_a.append(int(n_acc))
        pool = _recompute_means(x, z, pool)
        if z_prev is not None and bool(jnp.all(z == z_prev)):
            break
        z_prev = z
    obj = dp_means_objective(x, pool.centers, lam, pool.mask)
    stats = OCCStats(np.asarray(stats_p, np.int32), np.asarray(stats_a, np.int32))
    return DPMeansResult(pool, z, stats, send_all, epoch_of, it_done, obj)


def thm31_permutation(result: DPMeansResult, n: int) -> np.ndarray:
    """Build the serial order of Thm 3.1 from an OCC run: epochs in order;
    within an epoch, non-validated points (index order) precede validated
    points (validation = index order)."""
    send = np.asarray(result.send)
    epoch = np.asarray(result.epoch_of)
    idx = np.arange(n)
    order = np.lexsort((idx, send.astype(np.int32), epoch))
    return idx[order]

"""DP-means: serial (Alg. 1) and OCC-parallel (Alg. 3 + DPValidate Alg. 2).

The OCC version is a ~40-line declarative `DPMeansTransaction` run by the
unified `OCCEngine` (core/engine.py): one compiled `lax.scan` over epochs
replaces the legacy hand-rolled Python epoch loop.  `occ_dp_means` remains
as the backward-compatible convenience wrapper returning `DPMeansResult`.

Serial equivalence (Thm 3.1): within an epoch, non-proposed points (whose
assignment depends only on C^{t-1}) are ordered before proposed points,
which are validated in global index order.
"""
from __future__ import annotations

from dataclasses import dataclass
from functools import partial
from typing import Any, NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.engine import (
    OCCEngine, accumulate_pass_stats, resolve_assignments,
)
from repro.core.objective import dp_means_objective, sq_dists
from repro.core.occ import (
    CenterPool, OCCStats, ValidatePre, make_pool, nearest_center,
    nearest_center_with_new, serial_validate,
)

__all__ = ["DPMeansResult", "DPMeansTransaction", "serial_dp_means_pass",
           "serial_dp_means", "occ_dp_means", "thm31_permutation"]


class DPMeansResult(NamedTuple):
    pool: CenterPool
    z: jnp.ndarray              # (N,) int32 — assignment to pool slot
    stats: OCCStats             # per-epoch proposed / accepted counts
    send: jnp.ndarray           # (N,) bool — point was sent to the validator
    epoch_of: jnp.ndarray       # (N,) int32 — epoch each point was processed in
    n_iters: int
    objective: jnp.ndarray


def _dp_accept(lam2):
    """DPValidate accept rule: accept iff not within lambda of any center."""
    def accept_fn(pool: CenterPool, x_j, aux_j):
        d2, ref = nearest_center(pool, x_j)
        return d2 > lam2, x_j, ref
    return accept_fn


@jax.tree_util.register_pytree_node_class
@dataclass(frozen=True)
class DPMeansTransaction:
    """DP-means as an OCC transaction (Alg. 3 optimistic phase + Alg. 2
    DPValidate): propose a point as a new cluster iff it is farther than
    lambda from every center of C^{t-1}."""
    lam: Any
    k_max: int = 256

    def tree_flatten(self):
        return (self.lam,), (self.k_max,)

    @classmethod
    def tree_unflatten(cls, aux, children):
        return cls(children[0], *aux)

    def _lam2(self, dtype):
        return jnp.asarray(self.lam, dtype) ** 2

    def init_pool(self, x):
        return make_pool(self.k_max, x.shape[-1], x.dtype)

    def make_state(self, x, offset: int = 0):
        return ()

    def propose(self, pool, x_e, state_e):
        d2, idx = nearest_center(pool, x_e)
        # Thread (d2, idx) to the validator: accept/precompute_accept reuse
        # them instead of recomputing the C^{t-1} distances from scratch.
        # Threshold in d2's dtype — f32 on the Pallas backend regardless of
        # input dtype — so propose and both validator paths round λ² alike.
        return d2 > self._lam2(d2.dtype), x_e, (d2, idx), idx

    def precompute_accept(self, pool, payload_c, aux_c, count0):
        # Unified validator contract (DESIGN.md §11): the C^{t-1} distances
        # were already found by propose (threaded in aux); the only fresh
        # MXU work is the payload pairwise matrix — after which DPValidate
        # is pure scalar (and, being a monotone threshold rule, eligible
        # for the log-depth resolution).
        d2s, idxs = aux_c
        return ValidatePre(d2s, idxs, sq_dists(payload_c, payload_c), None)

    def accept_pre(self, d2_cur, aux_j):
        return d2_cur > self._lam2(d2_cur.dtype)

    def accept(self, pool, x_j, aux_j, count0):
        # REFERENCE ONLY (core/_reference.py): per-step recompute in which
        # only this epoch's new slots (>= count0) are measured fresh; the
        # C^{t-1} part comes threaded from propose.
        d2s_j, idxs_j = aux_j
        d2, ref = nearest_center_with_new(pool, x_j, d2s_j, idxs_j, count0)
        return d2 > self._lam2(d2.dtype), x_j, ref

    def writeback(self, send, slots, outs, safe, valid):
        return resolve_assignments(send, slots, outs, safe, valid)

    def refine(self, pool, x, z):
        return _recompute_means(x, z, pool)

    def objective(self, x, z, pool):
        return dp_means_objective(x, pool.centers, self.lam, pool.mask)


# ---------------------------------------------------------------------------
# Serial DP-means (Alg. 1)
# ---------------------------------------------------------------------------

@partial(jax.jit, static_argnames=("k_max",))
def serial_dp_means_pass(x: jnp.ndarray, lam: float, k_max: int,
                         pool: CenterPool | None = None):
    """One serial pass of Alg. 1's inner loop: scan points in order,
    assigning to the nearest center or creating a new one.

    Equivalent to validating *every* point serially — the degenerate OCC run
    with P = b = 1.  Returns (pool, z).
    """
    if pool is None:
        pool = make_pool(k_max, x.shape[-1], x.dtype)
    lam2 = jnp.asarray(lam, x.dtype) ** 2
    send = jnp.ones((x.shape[0],), bool)
    pool, slots, refs = serial_validate(pool, send, x, _dp_accept(lam2))
    z = jnp.where(slots >= 0, slots, refs).astype(jnp.int32)
    return pool, z


def _recompute_means(x: jnp.ndarray, z: jnp.ndarray, pool: CenterPool) -> CenterPool:
    """Second phase of Alg. 1/3: mu_k <- Mean({x_i | z_i = k}).

    Slots with no assigned points keep their previous vector (cannot happen
    within the creating iteration; can after reassignment in later ones).
    Trivially parallel: segment sums are psum-able over the data axis.
    """
    k_max = pool.centers.shape[0]
    zc = jnp.clip(z, 0, k_max - 1)
    valid = z >= 0
    w = valid.astype(x.dtype)
    sums = jax.ops.segment_sum(x * w[:, None], zc, num_segments=k_max)
    cnts = jax.ops.segment_sum(w, zc, num_segments=k_max)
    means = sums / jnp.maximum(cnts, 1.0)[:, None]
    new_centers = jnp.where((cnts > 0)[:, None] & pool.mask[:, None], means, pool.centers)
    return pool._replace(centers=new_centers)


def serial_dp_means(x: jnp.ndarray, lam: float, k_max: int = 256,
                    max_iters: int = 20) -> DPMeansResult:
    """Full serial DP-means (Alg. 1): alternate the assignment/creation pass
    with the centroid recomputation until assignments are fixed."""
    n = x.shape[0]
    pool = make_pool(k_max, x.shape[-1], x.dtype)
    z_prev = None
    it = 0
    for it in range(1, max_iters + 1):
        pool, z = serial_dp_means_pass(x, lam, k_max, pool)
        pool = _recompute_means(x, z, pool)
        if z_prev is not None and bool(jnp.all(z == z_prev)):
            break
        z_prev = z
    obj = dp_means_objective(x, pool.centers, lam, pool.mask)
    t = np.zeros((1,), np.int32)
    return DPMeansResult(pool, z, OCCStats(t, t), jnp.zeros((n,), bool),
                         jnp.zeros((n,), jnp.int32), it, obj)


# ---------------------------------------------------------------------------
# OCC DP-means (Alg. 3) — compatibility wrapper over the engine
# ---------------------------------------------------------------------------

def occ_dp_means(
    x: jnp.ndarray,
    lam: float,
    pb: int,
    k_max: int = 256,
    max_iters: int = 1,
    bootstrap: bool = False,
    validate_cap: int | None | str = None,
    mesh: jax.sharding.Mesh | None = None,
    data_axis: str = "data",
    scan_mode: str = "serial",
) -> DPMeansResult:
    """OCC DP-means (Alg. 3) — convenience wrapper running
    `DPMeansTransaction` under `OCCEngine`.

    Args:
      x: (N, D) data.  pb: points per epoch (the paper's P*b product — only
      the product matters algorithmically; the mesh supplies the physical P).
      max_iters: outer while-loop passes (1 = the paper's Fig-3 setting).
      bootstrap: serially pre-process the first pb/16 points (paper §4.2).
      validate_cap: bounded-master compaction — an int, None, or "adaptive"
      for the Thm-3.3-sized window (see OCCEngine; bit-identical results).
      scan_mode: "serial" | "logdepth" accept resolution (bit-identical).
      mesh: optional device mesh; epoch inputs are sharded over `data_axis`
      and the optimistic phase parallelizes under GSPMD while the validation
      scan executes replicated (SPMD re-execution of the master).
    """
    n = x.shape[0]
    txn = DPMeansTransaction(lam, k_max)
    eng = OCCEngine(txn, pb, validate_cap=validate_cap, mesh=mesh,
                    data_axis=data_axis, scan_mode=scan_mode)
    nb = min(n, max(1, pb // 16)) if bootstrap else 0

    z = jnp.full((n,), -1, jnp.int32)
    send = jnp.zeros((n,), bool)
    epoch_of = jnp.zeros((n,), jnp.int32)
    stat_parts: list[OCCStats] = []
    epoch_base = 0
    z_prev = None
    it_done = 0
    pool = None
    for it in range(1, max_iters + 1):
        it_done = it
        if it == 1:
            res = eng.run(x, n_bootstrap=nb)
            z, send, epoch_of = res.assign, res.send, res.epoch_of
        else:
            # Bootstrapped points keep their serial-prefix assignment; later
            # passes re-run only the bulk-synchronous epochs (seed semantics).
            res = eng.run(x[nb:], pool=pool)
            z = z.at[nb:].set(res.assign)
            send = send.at[nb:].set(res.send)
            epoch_of = epoch_of.at[nb:].set(res.epoch_of + epoch_base)
        # Every pass's validator load is recorded — epochs number globally
        # across passes, so stats[t] lines up with epoch_of == t.
        stat_parts.append(res.stats)
        epoch_base += res.stats.proposed.shape[0]
        pool = txn.refine(res.pool, x, z)
        if z_prev is not None and bool(jnp.all(z == z_prev)):
            break
        z_prev = z
    stats = accumulate_pass_stats(stat_parts)
    obj = txn.objective(x, z, pool)
    return DPMeansResult(pool, z, stats, send, epoch_of, it_done, obj)


def thm31_permutation(result: DPMeansResult, n: int) -> np.ndarray:
    """Build the serial order of Thm 3.1 from an OCC run: epochs in order;
    within an epoch, non-validated points (index order) precede validated
    points (validation = index order)."""
    send = np.asarray(result.send)
    epoch = np.asarray(result.epoch_of)
    idx = np.arange(n)
    order = np.lexsort((idx, send.astype(np.int32), epoch))
    return idx[order]

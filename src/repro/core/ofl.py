"""Online Facility Location: serial (Meyerson [17]) and OCC-parallel (Alg. 4/5).

Serial OFL processes points in one pass: x becomes a facility with
probability min(1, d^2/lambda^2) where d is the distance to the nearest
open facility; otherwise it is assigned to that facility.

OCC OFL (Alg. 4): a point is *sent* to the validator with the probability
computed from the stale state C^{t-1}; the validator accepts it with the
conditional probability such that the *net* acceptance probability equals
the serial algorithm's with the up-to-date state (Appendix B.3, Eq. 2-4).

Bit-exact serializability: each point i owns one uniform draw
u_i = U(fold_in(key, i)).  Send iff u_i < min(1, d^2/lam^2); validator
accepts iff u_i < min(1, d*^2/lam^2).  Since d* <= d, the joint event is
exactly {u_i < min(1, d*^2/lam^2)} — the serial decision with the same u_i —
so distributed and serial runs agree draw-for-draw, which makes Thm 3.1
testable exactly rather than only in distribution.
"""
from __future__ import annotations

import math
from functools import partial
from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.objective import dp_means_objective
from repro.core.occ import (
    CenterPool, OCCStats, make_pool, nearest_center, serial_validate,
    gather_validate,
)

__all__ = ["OFLResult", "point_uniforms", "serial_ofl", "occ_ofl"]


class OFLResult(NamedTuple):
    pool: CenterPool
    z: jnp.ndarray
    stats: OCCStats
    send: jnp.ndarray
    epoch_of: jnp.ndarray
    objective: jnp.ndarray


def point_uniforms(key: jax.Array, n: int) -> jnp.ndarray:
    """One counter-based uniform per point — shared by serial & OCC runs."""
    keys = jax.vmap(lambda i: jax.random.fold_in(key, i))(jnp.arange(n))
    return jax.vmap(lambda k: jax.random.uniform(k))(keys)


def _ofl_accept(lam2):
    def accept_fn(pool: CenterPool, x_j, u_j):
        d2, ref = nearest_center(pool, x_j)
        p = jnp.minimum(1.0, d2 / lam2)   # empty pool -> inf/lam2 -> 1
        return u_j < p, x_j, ref
    return accept_fn


@partial(jax.jit, static_argnames=("k_max",))
def serial_ofl(x: jnp.ndarray, u: jnp.ndarray, lam: float, k_max: int):
    """Serial OFL over points in the given order, with per-point uniforms u."""
    pool = make_pool(k_max, x.shape[-1], x.dtype)
    lam2 = jnp.asarray(lam, x.dtype) ** 2
    send = jnp.ones((x.shape[0],), bool)
    pool, slots, refs = serial_validate(pool, send, x, _ofl_accept(lam2), aux=u)
    z = jnp.where(slots >= 0, slots, refs).astype(jnp.int32)
    return pool, z


@partial(jax.jit, static_argnames=("validate_cap",))
def _ofl_epoch(pool: CenterPool, xs, valid, u, lam2, validate_cap=None):
    d2, idx = nearest_center(pool, xs)
    p_send = jnp.minimum(1.0, d2 / lam2)
    send = jnp.logical_and(u < p_send, valid)
    pool2, slots, refs, v_overflow = gather_validate(
        pool, send, xs, _ofl_accept(lam2), aux=u, cap=validate_cap)
    z = jnp.where(send, jnp.where(slots >= 0, slots, refs), idx).astype(jnp.int32)
    z = jnp.where(valid, z, -1)
    pool2 = pool2._replace(overflow=jnp.logical_or(pool2.overflow, v_overflow))
    return pool2, z, send, jnp.sum(send.astype(jnp.int32)), jnp.sum((slots >= 0).astype(jnp.int32))


def occ_ofl(
    x: jnp.ndarray,
    lam: float,
    pb: int,
    key: jax.Array,
    k_max: int = 256,
    validate_cap: int | None = None,
    mesh: jax.sharding.Mesh | None = None,
    data_axis: str = "data",
) -> OFLResult:
    """OCC Online Facility Location (Alg. 4).  Single pass by construction."""
    n, d = x.shape
    lam2 = jnp.asarray(lam, x.dtype) ** 2
    u = point_uniforms(key, n)
    pool = make_pool(k_max, d, x.dtype)
    t_epochs = max(1, math.ceil(n / pb))
    pad = t_epochs * pb - n
    xs = jnp.concatenate([x, jnp.zeros((pad, d), x.dtype)], 0)
    us = jnp.concatenate([u, jnp.ones((pad,), u.dtype)], 0)
    valid = jnp.concatenate([jnp.ones((n,), bool), jnp.zeros((pad,), bool)])

    put = None
    if mesh is not None:
        shd = jax.sharding.NamedSharding(mesh, jax.sharding.PartitionSpec(data_axis))
        put = lambda a: jax.device_put(a, shd)

    z = jnp.full((n,), -1, jnp.int32)
    send_all = jnp.zeros((n,), bool)
    epoch_of = jnp.zeros((n,), jnp.int32)
    stats_p, stats_a = [], []
    for t in range(t_epochs):
        sl = slice(t * pb, (t + 1) * pb)
        xe, ue, ve = xs[sl], us[sl], valid[sl]
        if put is not None:
            xe, ue, ve = put(xe), put(ue), put(ve)
        pool, ze, se, n_sent, n_acc = _ofl_epoch(pool, xe, ve, ue, lam2, validate_cap)
        lo, hi = t * pb, min((t + 1) * pb, n)
        z = z.at[lo:hi].set(ze[:hi - lo])
        send_all = send_all.at[lo:hi].set(se[:hi - lo])
        epoch_of = epoch_of.at[lo:hi].set(t)
        stats_p.append(int(n_sent))
        stats_a.append(int(n_acc))
    obj = dp_means_objective(x, pool.centers, lam, pool.mask)
    stats = OCCStats(np.asarray(stats_p, np.int32), np.asarray(stats_a, np.int32))
    return OFLResult(pool, z, stats, send_all, epoch_of, obj)

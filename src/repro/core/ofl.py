"""Online Facility Location: serial (Meyerson [17]) and OCC-parallel (Alg. 4/5).

Serial OFL processes points in one pass: x becomes a facility with
probability min(1, d^2/lambda^2) where d is the distance to the nearest
open facility; otherwise it is assigned to that facility.

OCC OFL (Alg. 4): a point is *sent* to the validator with the probability
computed from the stale state C^{t-1}; the validator accepts it with the
conditional probability such that the *net* acceptance probability equals
the serial algorithm's with the up-to-date state (Appendix B.3, Eq. 2-4).

Bit-exact serializability: each point i owns one uniform draw
u_i = U(fold_in(key, i)).  Send iff u_i < min(1, d^2/lam^2); validator
accepts iff u_i < min(1, d*^2/lam^2).  Since d* <= d, the joint event is
exactly {u_i < min(1, d*^2/lam^2)} — the serial decision with the same u_i —
so distributed and serial runs agree draw-for-draw, which makes Thm 3.1
testable exactly rather than only in distribution.

The uniforms are counter-based in the *global* point index, so the
streaming surface (`OCCEngine.partial_fit`) reproduces a one-shot run over
the concatenated stream draw-for-draw as well — for ANY batch lengths: the
engine's partial-epoch carry keeps the stream's epoch partition identical
to the one-shot partition (tests/test_stream_carry.py).

The OCC version is a declarative `OFLTransaction` run by the unified
`OCCEngine` (core/engine.py); `occ_ofl` remains as the backward-compatible
wrapper returning `OFLResult`.
"""
from __future__ import annotations

from dataclasses import dataclass
from functools import partial
from typing import Any, NamedTuple

import jax
import jax.numpy as jnp

from repro.core.engine import OCCEngine, resolve_assignments
from repro.core.objective import dp_means_objective, sq_dists
from repro.core.occ import (
    CenterPool, OCCStats, ValidatePre, make_pool, nearest_center,
    nearest_center_with_new, serial_validate,
)

__all__ = ["OFLResult", "OFLTransaction", "point_uniforms", "serial_ofl",
           "occ_ofl"]


class OFLResult(NamedTuple):
    pool: CenterPool
    z: jnp.ndarray
    stats: OCCStats
    send: jnp.ndarray
    epoch_of: jnp.ndarray
    objective: jnp.ndarray


def point_uniforms(key: jax.Array, n: int, offset: int = 0) -> jnp.ndarray:
    """One counter-based uniform per global point index — shared by serial,
    OCC, and streaming runs."""
    idx = offset + jnp.arange(n)
    keys = jax.vmap(lambda i: jax.random.fold_in(key, i))(idx)
    return jax.vmap(lambda k: jax.random.uniform(k))(keys)


def _ofl_accept(lam2):
    def accept_fn(pool: CenterPool, x_j, u_j):
        d2, ref = nearest_center(pool, x_j)
        p = jnp.minimum(1.0, d2 / lam2)   # empty pool -> inf/lam2 -> 1
        return u_j < p, x_j, ref
    return accept_fn


@jax.tree_util.register_pytree_node_class
@dataclass(frozen=True)
class OFLTransaction:
    """OCC Online Facility Location as a transaction (Alg. 4/5): the
    per-point state is its counter-based uniform draw, making the validator
    decision the exact serial decision (App. B.3)."""
    lam: Any
    k_max: int
    key: jax.Array

    def tree_flatten(self):
        return (self.lam, self.key), (self.k_max,)

    @classmethod
    def tree_unflatten(cls, aux, children):
        lam, key = children
        return cls(lam, aux[0], key)

    def _lam2(self, dtype):
        return jnp.asarray(self.lam, dtype) ** 2

    def init_pool(self, x):
        return make_pool(self.k_max, x.shape[-1], x.dtype)

    def make_state(self, x, offset: int = 0):
        return point_uniforms(self.key, x.shape[0], offset)

    def propose(self, pool, x_e, u_e):
        d2, idx = nearest_center(pool, x_e)
        # Threshold in d2's dtype — f32 on the Pallas backend regardless of
        # input dtype — so propose and both validator paths round λ² alike.
        p_send = jnp.minimum(1.0, d2 / self._lam2(d2.dtype))
        # Thread (u, d2, idx): the validator needs the point's uniform AND
        # can reuse the C^{t-1} distances instead of recomputing them.
        return u_e < p_send, x_e, (u_e, d2, idx), idx

    def precompute_accept(self, pool, payload_c, aux_c, count0):
        # Unified validator contract (DESIGN.md §11): one payload pairwise
        # matrix on the MXU; the per-step rule then needs only the point's
        # own uniform — a monotone threshold in d², so the log-depth
        # resolution applies (u < min(1, ·/λ²) commutes with min exactly).
        u, d2s, idxs = aux_c
        return ValidatePre(d2s, idxs, sq_dists(payload_c, payload_c), u)

    def accept_pre(self, d2_cur, u_j):
        p = jnp.minimum(1.0, d2_cur / self._lam2(d2_cur.dtype))
        return u_j < p

    def accept(self, pool, x_j, aux_j, count0):
        # REFERENCE ONLY (core/_reference.py): accept iff u < min(1, d*²/λ²)
        # with d* over the current pool — only the new slots are measured
        # fresh (App. B.3).
        u_j, d2s_j, idxs_j = aux_j
        d2, ref = nearest_center_with_new(pool, x_j, d2s_j, idxs_j, count0)
        p = jnp.minimum(1.0, d2 / self._lam2(d2.dtype))
        return u_j < p, x_j, ref

    def writeback(self, send, slots, outs, safe, valid):
        return resolve_assignments(send, slots, outs, safe, valid)

    def refine(self, pool, x, z):
        return pool   # single-pass algorithm: no refinement phase

    def objective(self, x, z, pool):
        return dp_means_objective(x, pool.centers, self.lam, pool.mask)


@partial(jax.jit, static_argnames=("k_max",))
def serial_ofl(x: jnp.ndarray, u: jnp.ndarray, lam: float, k_max: int):
    """Serial OFL over points in the given order, with per-point uniforms u."""
    pool = make_pool(k_max, x.shape[-1], x.dtype)
    lam2 = jnp.asarray(lam, x.dtype) ** 2
    send = jnp.ones((x.shape[0],), bool)
    pool, slots, refs = serial_validate(pool, send, x, _ofl_accept(lam2), aux=u)
    z = jnp.where(slots >= 0, slots, refs).astype(jnp.int32)
    return pool, z


def occ_ofl(
    x: jnp.ndarray,
    lam: float,
    pb: int,
    key: jax.Array,
    k_max: int = 256,
    validate_cap: int | None | str = None,
    mesh: jax.sharding.Mesh | None = None,
    data_axis: str = "data",
    scan_mode: str = "serial",
) -> OFLResult:
    """OCC Online Facility Location (Alg. 4) — convenience wrapper running
    `OFLTransaction` under `OCCEngine`.  Single pass by construction."""
    txn = OFLTransaction(lam, k_max, key)
    eng = OCCEngine(txn, pb, validate_cap=validate_cap, mesh=mesh,
                    data_axis=data_axis, scan_mode=scan_mode)
    res = eng.run(x)
    obj = txn.objective(x, res.assign, res.pool)
    return OFLResult(res.pool, res.assign, res.stats, res.send,
                     res.epoch_of, obj)

"""Generic Optimistic Concurrency Control (OCC) scaffolding — paper §1.1.

The OCC pattern: partition data over P processors; each epoch every
processor optimistically processes its block of b points against the
replicated global state C^{t-1}; operations that may violate serial
invariants (new cluster / feature proposals) are *serially validated*;
accepted state changes are replicated before the next epoch.

TPU adaptation (see DESIGN.md §2): proposals within an epoch are produced by
one batched, MXU-tiled computation over the Pb points (the per-point
decisions depend only on C^{t-1}, so vectorization preserves the serial
order of Thm 3.1); validation is a deterministic `lax.scan` in global index
order, executed replicated on every device (SPMD re-execution of the
"master") or gathered to a single device (classic mode).

The global center/feature set C grows over time; JAX needs static shapes, so
C lives in a fixed-capacity masked buffer (`CenterPool`). Overflow is
detected and surfaced — it is the analogue of the paper's master running out
of memory.
"""
from __future__ import annotations

import math
from typing import Any, Callable, NamedTuple

import jax
import jax.numpy as jnp

from repro.core.objective import sq_dists

__all__ = [
    "CenterPool", "make_pool", "pool_append_serial", "block_epochs",
    "serial_validate", "nearest_center", "OCCStats",
]


class CenterPool(NamedTuple):
    """Fixed-capacity masked buffer holding the global state C."""
    centers: jnp.ndarray   # (K_max, D)
    mask: jnp.ndarray      # (K_max,) bool — slot holds a validated center
    count: jnp.ndarray     # () int32 — number of valid slots (== mask.sum())
    overflow: jnp.ndarray  # () bool — a validated accept did not fit


class OCCStats(NamedTuple):
    """Per-epoch bookkeeping used by the Fig-3 / Thm-3.3 experiments."""
    proposed: jnp.ndarray  # (T,) number of points sent to the validator
    accepted: jnp.ndarray  # (T,) number of proposals accepted as new centers


def make_pool(k_max: int, dim: int, dtype=jnp.float32) -> CenterPool:
    return CenterPool(
        centers=jnp.zeros((k_max, dim), dtype),
        mask=jnp.zeros((k_max,), bool),
        count=jnp.zeros((), jnp.int32),
        overflow=jnp.zeros((), bool),
    )


def nearest_center(pool: CenterPool, x: jnp.ndarray) -> tuple[jnp.ndarray, jnp.ndarray]:
    """Min squared distance and argmin over valid centers.

    x: (..., D).  Returns (d2min (...,), idx (...,)).  Empty pool -> +inf / -1.
    """
    d2 = sq_dists(x.reshape(-1, x.shape[-1]), pool.centers)
    d2 = jnp.where(pool.mask[None, :], d2, jnp.inf)
    d2min = jnp.min(d2, axis=-1)
    idx = jnp.where(jnp.isfinite(d2min), jnp.argmin(d2, axis=-1), -1)
    batch_shape = x.shape[:-1]
    return d2min.reshape(batch_shape), idx.reshape(batch_shape)


def pool_append_serial(pool: CenterPool, x: jnp.ndarray, do: jnp.ndarray) -> tuple[CenterPool, jnp.ndarray]:
    """Append x at slot `count` if `do` (traced bool). Returns (pool, slot).

    slot is the written index, or -1 when not written / overflowed.
    """
    k_max = pool.centers.shape[0]
    fits = pool.count < k_max
    write = jnp.logical_and(do, fits)
    slot = jnp.where(write, pool.count, -1)
    idx = jnp.clip(pool.count, 0, k_max - 1)
    centers = jnp.where(
        write,
        jax.lax.dynamic_update_slice(pool.centers, x[None, :].astype(pool.centers.dtype), (idx, 0)),
        pool.centers,
    )
    mask = jnp.where(write, pool.mask.at[idx].set(True), pool.mask)
    count = pool.count + write.astype(jnp.int32)
    overflow = jnp.logical_or(pool.overflow, jnp.logical_and(do, ~fits))
    return CenterPool(centers, mask, count, overflow), slot


def block_epochs(n: int, pb: int) -> int:
    """Number of bulk-synchronous epochs for n points with Pb points/epoch."""
    return max(1, math.ceil(n / pb))


def serial_validate(
    pool: CenterPool,
    send: jnp.ndarray,              # (B,) bool — proposal flags in index order
    payload: jnp.ndarray,           # (B, D) — proposed center / feature vectors
    accept_fn: Callable[[CenterPool, jnp.ndarray, Any], tuple[jnp.ndarray, Any]],
    aux: Any = None,                # per-proposal auxiliary pytree (leading dim B)
) -> tuple[CenterPool, jnp.ndarray, Any]:
    """The serializing validator: a deterministic scan in global index order.

    `accept_fn(pool, x_j, aux_j) -> (accept: bool0-d, append_vec, out_j)`
    decides, given the state *including previously accepted proposals of this
    epoch*, whether proposal j becomes a new center, and what vector to
    append (DP/OFL append x_j itself; BP-means appends the residual, Alg. 8).
    Rejected proposals get their reference resolved by the caller via
    `out_j` (e.g. nearest-center index).

    Returns (pool', slot (B,) int32 — accepted slot or -1, outs).
    This is Alg. 2 (DPValidate) / Alg. 5 (OFLValidate) / Alg. 8 (BPValidate)
    generically; identical on every device, hence safe to run replicated.
    """
    if aux is None:
        aux = jnp.zeros((send.shape[0],), jnp.int32)

    def step(carry, inp):
        pool = carry
        send_j, x_j, aux_j = inp
        accept, append_vec, out_j = accept_fn(pool, x_j, aux_j)
        accept = jnp.logical_and(accept, send_j)
        pool, slot = pool_append_serial(pool, append_vec, accept)
        return pool, (slot, out_j)

    pool, (slots, outs) = jax.lax.scan(step, pool, (send, payload, aux))
    return pool, slots, outs


def gather_validate(
    pool: CenterPool,
    send: jnp.ndarray,
    payload: jnp.ndarray,
    accept_fn,
    aux: Any = None,
    cap: int | None = None,
):
    """Bounded-master variant: compact the sent proposals (stable order) to a
    fixed-size buffer of `cap` slots before the serial scan.

    This keeps the sequential scan O(cap) instead of O(Pb) — the production
    analogue of the paper's master only *seeing* the sent points.  Thm 3.3
    bounds E[#sent] by Pb + K_N so cap ~ Pb is safe after epoch 1; overflow
    is surfaced via the returned flag.
    """
    b = send.shape[0]
    if cap is None or cap >= b:
        pool, slots, outs = serial_validate(pool, send, payload, accept_fn, aux)
        return pool, slots, outs, jnp.zeros((), bool)

    n_sent = jnp.sum(send.astype(jnp.int32))
    sent_overflow = n_sent > cap
    # Stable compaction: indices of sent proposals in ascending order.
    order = jnp.argsort(jnp.where(send, jnp.arange(b), b), stable=True)[:cap]
    send_c = send[order]
    payload_c = payload[order]
    aux_c = None if aux is None else jax.tree.map(lambda a: a[order], aux)
    pool, slots_c, outs_c = serial_validate(pool, send_c, payload_c, accept_fn, aux_c)
    # Scatter results back to the full index space.
    slots = jnp.full((b,), -1, jnp.int32).at[order].set(slots_c, mode="drop")
    outs = jax.tree.map(
        lambda o: jnp.zeros((b,) + o.shape[1:], o.dtype).at[order].set(o, mode="drop"),
        outs_c,
    )
    return pool, slots, outs, sent_overflow

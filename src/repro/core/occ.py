"""Generic Optimistic Concurrency Control (OCC) scaffolding — paper §1.1.

The OCC pattern: partition data over P processors; each epoch every
processor optimistically processes its block of b points against the
replicated global state C^{t-1}; operations that may violate serial
invariants (new cluster / feature proposals) are *serially validated*;
accepted state changes are replicated before the next epoch.

TPU adaptation (see DESIGN.md §2): proposals within an epoch are produced by
one batched, MXU-tiled computation over the Pb points (the per-point
decisions depend only on C^{t-1}, so vectorization preserves the serial
order of Thm 3.1); validation is a deterministic `lax.scan` in global index
order, executed replicated on every device (SPMD re-execution of the
"master") or gathered to a single device (classic mode).

Two validator implementations share those serial semantics (DESIGN.md §9):
`serial_validate` / `gather_validate` — the legacy reference, one
D-dimensional recompute per sequential step — and `precomputed_validate` /
`precomputed_gather_validate`, which batch every D-dimensional quantity
into one MXU precompute (`ValidatePre`) and scan over pure scalars.

The global center/feature set C grows over time; JAX needs static shapes, so
C lives in a fixed-capacity masked buffer (`CenterPool`). Overflow is
detected and surfaced — it is the analogue of the paper's master running out
of memory.
"""
from __future__ import annotations

import math
from typing import Any, Callable, NamedTuple

import jax
import jax.numpy as jnp

from repro.core.objective import sq_dists
from repro.kernels import ops as _kops

__all__ = [
    "CenterPool", "make_pool", "pool_append_serial", "block_epochs",
    "serial_validate", "nearest_center", "nearest_center_with_new",
    "OCCStats", "ValidatePre", "precomputed_validate",
    "precomputed_gather_validate",
]


class CenterPool(NamedTuple):
    """Fixed-capacity masked buffer holding the global state C."""
    centers: jnp.ndarray   # (K_max, D)
    mask: jnp.ndarray      # (K_max,) bool — slot holds a validated center
    count: jnp.ndarray     # () int32 — number of valid slots (== mask.sum())
    overflow: jnp.ndarray  # () bool — a validated accept did not fit


class OCCStats(NamedTuple):
    """Per-epoch bookkeeping used by the Fig-3 / Thm-3.3 experiments."""
    proposed: jnp.ndarray  # (T,) number of points sent to the validator
    accepted: jnp.ndarray  # (T,) number of proposals accepted as new centers


def make_pool(k_max: int, dim: int, dtype=jnp.float32) -> CenterPool:
    return CenterPool(
        centers=jnp.zeros((k_max, dim), dtype),
        mask=jnp.zeros((k_max,), bool),
        count=jnp.zeros((), jnp.int32),
        overflow=jnp.zeros((), bool),
    )


def nearest_center(pool: CenterPool, x: jnp.ndarray,
                   backend: str = "auto") -> tuple[jnp.ndarray, jnp.ndarray]:
    """Min squared distance and argmin over valid centers.

    x: (..., D).  Returns (d2min (...,), idx (...,)).  Empty pool -> +inf / -1.

    Routed through the `kernels/ops.assign` backend dispatch (DESIGN.md §9):
    MXU-tiled Pallas on TPU with the work restricted to a count-rounded
    active prefix of the pool, jnp reference elsewhere.  Sub-tile batches
    (single-point serial-scan steps) stay on the jnp path even on TPU —
    a per-step pallas_call on an 8-row-padded point is pure overhead, and
    keeping the serial references on one primitive preserves their
    bit-exactness against the validator's jnp-computed distances.
    """
    xf = x.reshape(-1, x.shape[-1])
    if backend == "auto" and xf.shape[0] < 8:
        backend = "ref"
    d2min, idx = _kops.assign(xf, pool.centers, pool.mask,
                              count=pool.count, backend=backend)
    batch_shape = x.shape[:-1]
    return d2min.reshape(batch_shape), idx.reshape(batch_shape)


def nearest_center_with_new(pool: CenterPool, x: jnp.ndarray,
                            d2_start: jnp.ndarray, idx_start: jnp.ndarray,
                            count0: jnp.ndarray):
    """`nearest_center` over C^{t-1} ∪ this epoch's accepts, given the
    distance to C^{t-1} already computed in the propose phase.

    Only slots >= count0 (the epoch's new centers) are measured fresh; the
    epoch-start part reuses (d2_start, idx_start) threaded through `aux`.
    On a distance tie the new slot loses: its index is always higher, and a
    full argmin picks the lowest index.  x: (D,) — one validator step.
    """
    k_max = pool.centers.shape[0]
    new_mask = jnp.logical_and(pool.mask, jnp.arange(k_max) >= count0)
    d2 = sq_dists(x[None, :], pool.centers)[0]
    d2 = jnp.where(new_mask, d2, jnp.inf)
    best_new = jnp.min(d2)
    use_new = best_new < d2_start
    idx = jnp.where(use_new, jnp.argmin(d2), idx_start)
    return jnp.minimum(d2_start, best_new), idx


def pool_append_serial(pool: CenterPool, x: jnp.ndarray, do: jnp.ndarray) -> tuple[CenterPool, jnp.ndarray]:
    """Append x at slot `count` if `do` (traced bool). Returns (pool, slot).

    slot is the written index, or -1 when not written / overflowed.
    """
    k_max = pool.centers.shape[0]
    fits = pool.count < k_max
    write = jnp.logical_and(do, fits)
    slot = jnp.where(write, pool.count, -1)
    idx = jnp.clip(pool.count, 0, k_max - 1)
    centers = jnp.where(
        write,
        jax.lax.dynamic_update_slice(pool.centers, x[None, :].astype(pool.centers.dtype), (idx, 0)),
        pool.centers,
    )
    mask = jnp.where(write, pool.mask.at[idx].set(True), pool.mask)
    count = pool.count + write.astype(jnp.int32)
    overflow = jnp.logical_or(pool.overflow, jnp.logical_and(do, ~fits))
    return CenterPool(centers, mask, count, overflow), slot


def block_epochs(n: int, pb: int) -> int:
    """Number of bulk-synchronous epochs for n points with Pb points/epoch."""
    return max(1, math.ceil(n / pb))


def serial_validate(
    pool: CenterPool,
    send: jnp.ndarray,              # (B,) bool — proposal flags in index order
    payload: jnp.ndarray,           # (B, D) — proposed center / feature vectors
    accept_fn: Callable[[CenterPool, jnp.ndarray, Any], tuple[jnp.ndarray, Any]],
    aux: Any = None,                # per-proposal auxiliary pytree (leading dim B)
) -> tuple[CenterPool, jnp.ndarray, Any]:
    """The serializing validator: a deterministic scan in global index order.

    `accept_fn(pool, x_j, aux_j) -> (accept: bool0-d, append_vec, out_j)`
    decides, given the state *including previously accepted proposals of this
    epoch*, whether proposal j becomes a new center, and what vector to
    append (DP/OFL append x_j itself; BP-means appends the residual, Alg. 8).
    Rejected proposals get their reference resolved by the caller via
    `out_j` (e.g. nearest-center index).

    Returns (pool', slot (B,) int32 — accepted slot or -1, outs).
    This is Alg. 2 (DPValidate) / Alg. 5 (OFLValidate) / Alg. 8 (BPValidate)
    generically; identical on every device, hence safe to run replicated.
    """
    if aux is None:
        aux = jnp.zeros((send.shape[0],), jnp.int32)

    def step(carry, inp):
        pool = carry
        send_j, x_j, aux_j = inp
        accept, append_vec, out_j = accept_fn(pool, x_j, aux_j)
        accept = jnp.logical_and(accept, send_j)
        pool, slot = pool_append_serial(pool, append_vec, accept)
        return pool, (slot, out_j)

    pool, (slots, outs) = jax.lax.scan(step, pool, (send, payload, aux))
    return pool, slots, outs


def _compact_sent(send: jnp.ndarray, cap: int):
    """Bounded-master compaction: stable indices of the first `cap` sent
    proposals (ascending global order) + the sent_overflow flag.  Shared by
    both validator implementations so their windows are identical."""
    b = send.shape[0]
    n_sent = jnp.sum(send.astype(jnp.int32))
    sent_overflow = n_sent > cap if cap < b else jnp.zeros((), bool)
    order = jnp.argsort(jnp.where(send, jnp.arange(b), b), stable=True)[:cap]
    return order, sent_overflow


def _scatter_back(order: jnp.ndarray, b: int, slots_c: jnp.ndarray, outs_c):
    """Scatter compacted validator verdicts back to the full index space."""
    slots = jnp.full((b,), -1, jnp.int32).at[order].set(slots_c, mode="drop")
    outs = jax.tree.map(
        lambda o: jnp.zeros((b,) + o.shape[1:], o.dtype).at[order].set(o, mode="drop"),
        outs_c,
    )
    return slots, outs


def gather_validate(
    pool: CenterPool,
    send: jnp.ndarray,
    payload: jnp.ndarray,
    accept_fn,
    aux: Any = None,
    cap: int | None = None,
):
    """Bounded-master variant: compact the sent proposals (stable order) to a
    fixed-size buffer of `cap` slots before the serial scan.

    This keeps the sequential scan O(cap) instead of O(Pb) — the production
    analogue of the paper's master only *seeing* the sent points.  Thm 3.3
    bounds E[#sent] by Pb + K_N so cap ~ Pb is safe after epoch 1; overflow
    is surfaced via the returned flag.
    """
    b = send.shape[0]
    if cap is None or cap >= b:
        pool, slots, outs = serial_validate(pool, send, payload, accept_fn, aux)
        return pool, slots, outs, jnp.zeros((), bool)

    order, sent_overflow = _compact_sent(send, cap)
    send_c = send[order]
    payload_c = payload[order]
    aux_c = None if aux is None else jax.tree.map(lambda a: a[order], aux)
    pool, slots_c, outs_c = serial_validate(pool, send_c, payload_c, accept_fn, aux_c)
    slots, outs = _scatter_back(order, b, slots_c, outs_c)
    return pool, slots, outs, sent_overflow


# ---------------------------------------------------------------------------
# Precomputed (D-free) validation — DESIGN.md §9
# ---------------------------------------------------------------------------

class ValidatePre(NamedTuple):
    """Everything D-dimensional the fast validator needs, batched on the MXU.

    Covers transactions whose accepted append vector IS the payload (DP-means,
    OFL): a new center can only come from the sent set, so every distance the
    serial scan will ever consult is either payload→C^{t-1} (computed once in
    propose and threaded through `aux`) or payload→payload (`pair_d2`).

    d2_start:  (cap,)  min squared distance to the epoch-start centers.
    idx_start: (cap,)  int32 — that center's slot, -1 when the pool is empty.
    pair_d2:   (cap, cap)  payload pairwise squared distances; row j is
               consulted against proposals appended before j.
    aux:       per-proposal decision scalars (leading dim cap; e.g. OFL's
               uniforms), or None when the rule needs only d2.
    """
    d2_start: jnp.ndarray
    idx_start: jnp.ndarray
    pair_d2: jnp.ndarray
    aux: Any


def precomputed_validate(
    pool: CenterPool,
    send_c: jnp.ndarray,            # (cap,) bool — compacted proposal flags
    payload_c: jnp.ndarray,         # (cap, D) — compacted payloads
    pre: ValidatePre,
    decide_fn: Callable[[jnp.ndarray, Any], jnp.ndarray],
) -> tuple[CenterPool, jnp.ndarray, jnp.ndarray]:
    """The serializing scan with ZERO D-dimensional work per step.

    Same serial semantics as `serial_validate` (deterministic, compaction
    order == global index order), but each step is O(cap) scalar mask/min/
    compare logic over precomputed distances: the carry is (count, overflow,
    per-proposal slots), never the (K_max, D) center buffer.  Accepted
    payloads are written back to the pool in ONE batched scatter afterwards
    — O(cap·D) total instead of O(cap·K_max·D) sequential.

    `decide_fn(d2_cur, aux_j) -> bool` is the transaction's accept rule given
    the min squared distance to the *current* pool (epoch-start ∪ this
    epoch's appends).  Returns (pool', slots_c (cap,) int32, refs_c (cap,)
    int32 — nearest-center reference for rejected proposals).
    """
    cap = send_c.shape[0]
    k_max = pool.centers.shape[0]
    count0 = pool.count

    def step(carry, inp):
        count, overflow, slots_c = carry
        j, send_j, d2s_j, idxs_j, pair_j, aux_j = inp
        # Distance to this epoch's previously appended proposals: a masked
        # row of the precomputed pairwise matrix (slots_c >= 0 marks them).
        d2_new = jnp.where(slots_c >= 0, pair_j, jnp.inf)
        best_new = jnp.min(d2_new)
        # Strict <: on a tie the full argmin picks the lower slot, which is
        # always the epoch-start center (new slots sit at >= count0).
        use_new = best_new < d2s_j
        d2_cur = jnp.minimum(d2s_j, best_new)
        ref = jnp.where(use_new, slots_c[jnp.argmin(d2_new)], idxs_j)
        acc = jnp.logical_and(decide_fn(d2_cur, aux_j), send_j)
        fits = count < k_max
        app = jnp.logical_and(acc, fits)
        slot = jnp.where(app, count, -1)
        slots_c = jax.lax.dynamic_update_index_in_dim(slots_c, slot, j, 0)
        count = count + app.astype(jnp.int32)
        overflow = jnp.logical_or(overflow, jnp.logical_and(acc, ~fits))
        return (count, overflow, slots_c), ref

    aux = pre.aux
    if aux is None:
        aux = jnp.zeros((cap,), jnp.int32)
    init = (count0, pool.overflow, jnp.full((cap,), -1, jnp.int32))
    (count, overflow, slots_c), refs_c = jax.lax.scan(
        step, init, (jnp.arange(cap), send_c, pre.d2_start, pre.idx_start,
                     pre.pair_d2, aux))

    # One batched pool write: appended slots are unique by construction.
    widx = jnp.where(slots_c >= 0, slots_c, k_max)   # out-of-range rows drop
    centers = pool.centers.at[widx].set(
        payload_c.astype(pool.centers.dtype), mode="drop")
    mask = pool.mask.at[widx].set(True, mode="drop")
    return CenterPool(centers, mask, count, overflow), slots_c, refs_c


def precomputed_gather_validate(
    pool: CenterPool,
    send: jnp.ndarray,
    payload: jnp.ndarray,
    aux: Any,
    precompute_fn: Callable[..., ValidatePre],
    decide_fn: Callable[[jnp.ndarray, Any], jnp.ndarray],
    cap: int | None = None,
    replicate: Callable[[jnp.ndarray], jnp.ndarray] | None = None,
):
    """Bounded-master validation on the precomputed fast path.

    Compacts the sent proposals (stable order, as `gather_validate`), runs
    `precompute_fn(pool, payload_c, aux_c, count0)` ONCE on the MXU, then the
    D-free scalar scan, then scatters verdicts back to the full index space.
    `replicate` (optional) constrains the compacted buffers to the master's
    replicated sharding before the scan (see shardings.occ_validate_sharding).
    """
    b = send.shape[0]
    count0 = pool.count
    cap_c = b if cap is None or cap >= b else cap
    order, sent_overflow = _compact_sent(send, cap_c)
    send_c = send[order]
    payload_c = payload[order]
    aux_c = None if aux is None else jax.tree.map(lambda a: a[order], aux)
    if replicate is not None:
        send_c, payload_c = replicate(send_c), replicate(payload_c)
        aux_c = None if aux_c is None else jax.tree.map(replicate, aux_c)
    pre = precompute_fn(pool, payload_c, aux_c, count0)
    pool, slots_c, refs_c = precomputed_validate(
        pool, send_c, payload_c, pre, decide_fn)
    slots, outs = _scatter_back(order, b, slots_c, refs_c)
    return pool, slots, outs, sent_overflow

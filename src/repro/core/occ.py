"""Generic Optimistic Concurrency Control (OCC) scaffolding — paper §1.1.

The OCC pattern: partition data over P processors; each epoch every
processor optimistically processes its block of b points against the
replicated global state C^{t-1}; operations that may violate serial
invariants (new cluster / feature proposals) are *serially validated*;
accepted state changes are replicated before the next epoch.

TPU adaptation (see DESIGN.md §2): proposals within an epoch are produced by
one batched, MXU-tiled computation over the Pb points (the per-point
decisions depend only on C^{t-1}, so vectorization preserves the serial
order of Thm 3.1); validation is a deterministic `lax.scan` in global index
order, executed replicated on every device (SPMD re-execution of the
"master") or gathered to a single device (classic mode).

The precomputed fast path is the ONLY engine validator (DESIGN.md §9/§11):
`precomputed_gather_validate` batches every D-dimensional quantity into one
MXU precompute (`ValidatePre`) and then runs a D-free serializing scan —
the payload scan (`precomputed_validate`, DP-means/OFL), its log-depth
formulation (`logdepth_validate`, `scan_mode="logdepth"`), or the
Gram-carry scan (`precomputed_validate_gram`, BP-means).  The legacy
per-step D-dimensional recompute survives only as a reference
implementation in `core/_reference.py` (tests + benchmark baselines);
`serial_validate` below remains as the vehicle for the paper's *serial*
algorithms (Alg. 1/7 and Meyerson's OFL), which are definitions, not an
engine path.

The global center/feature set C grows over time; JAX needs static shapes, so
C lives in a fixed-capacity masked buffer (`CenterPool`). Overflow is
detected and surfaced — it is the analogue of the paper's master running out
of memory.
"""
from __future__ import annotations

import math
from typing import Any, Callable, NamedTuple

import jax
import jax.numpy as jnp

from repro.core.objective import sq_dists
from repro.kernels import ops as _kops

__all__ = [
    "CenterPool", "make_pool", "pool_append_serial", "block_epochs",
    "next_pow2", "serial_validate", "nearest_center",
    "nearest_center_with_new", "OCCStats", "ValidatePre",
    "precomputed_validate", "precomputed_validate_gram",
    "logdepth_validate", "precomputed_gather_validate",
]


def next_pow2(n: int) -> int:
    """Smallest power of two >= n (1 for n <= 1).  The shared bucketing
    primitive: the engine's adaptive validator cap and the serving plane's
    capacity/request buckets (serving/snapshot.next_bucket) both quantize
    through this, so jit caches key on a handful of shapes."""
    p = 1
    while p < n:
        p <<= 1
    return p


class CenterPool(NamedTuple):
    """Fixed-capacity masked buffer holding the global state C."""
    centers: jnp.ndarray   # (K_max, D)
    mask: jnp.ndarray      # (K_max,) bool — slot holds a validated center
    count: jnp.ndarray     # () int32 — number of valid slots (== mask.sum())
    overflow: jnp.ndarray  # () bool — a validated accept did not fit


class OCCStats(NamedTuple):
    """Per-epoch bookkeeping used by the Fig-3 / Thm-3.3 experiments.

    `cap` records the bounded-master compaction width each epoch actually
    ran with (the epoch width when the master was unbounded) — the
    observability surface for the Thm-3.3 adaptive cap (DESIGN.md §11):
    `proposed[t] > cap[t]` is exactly the sent-overflow condition the
    engine's adaptive mode retries on.  Serial algorithms construct their
    placeholder stats with `cap=None`.
    """
    proposed: jnp.ndarray  # (T,) number of points sent to the validator
    accepted: jnp.ndarray  # (T,) number of proposals accepted as new centers
    cap: jnp.ndarray | None = None  # (T,) int32 validator cap per epoch


def make_pool(k_max: int, dim: int, dtype=jnp.float32) -> CenterPool:
    return CenterPool(
        centers=jnp.zeros((k_max, dim), dtype),
        mask=jnp.zeros((k_max,), bool),
        count=jnp.zeros((), jnp.int32),
        overflow=jnp.zeros((), bool),
    )


def nearest_center(pool: CenterPool, x: jnp.ndarray,
                   backend: str = "auto") -> tuple[jnp.ndarray, jnp.ndarray]:
    """Min squared distance and argmin over valid centers.

    x: (..., D).  Returns (d2min (...,), idx (...,)).  Empty pool -> +inf / -1.

    Routed through the `kernels/ops.assign` backend dispatch (DESIGN.md §9):
    MXU-tiled Pallas on TPU with the work restricted to a count-rounded
    active prefix of the pool, jnp reference elsewhere.  Sub-tile batches
    (single-point serial-scan steps) stay on the jnp path even on TPU —
    a per-step pallas_call on an 8-row-padded point is pure overhead, and
    keeping the serial references on one primitive preserves their
    bit-exactness against the validator's jnp-computed distances.
    """
    xf = x.reshape(-1, x.shape[-1])
    if backend == "auto" and xf.shape[0] < 8:
        backend = "ref"
    d2min, idx = _kops.assign(xf, pool.centers, pool.mask,
                              count=pool.count, backend=backend)
    batch_shape = x.shape[:-1]
    return d2min.reshape(batch_shape), idx.reshape(batch_shape)


def nearest_center_with_new(pool: CenterPool, x: jnp.ndarray,
                            d2_start: jnp.ndarray, idx_start: jnp.ndarray,
                            count0: jnp.ndarray):
    """`nearest_center` over C^{t-1} ∪ this epoch's accepts, given the
    distance to C^{t-1} already computed in the propose phase.

    Only slots >= count0 (the epoch's new centers) are measured fresh; the
    epoch-start part reuses (d2_start, idx_start) threaded through `aux`.
    On a distance tie the new slot loses: its index is always higher, and a
    full argmin picks the lowest index.  x: (D,) — one validator step.
    """
    k_max = pool.centers.shape[0]
    new_mask = jnp.logical_and(pool.mask, jnp.arange(k_max) >= count0)
    d2 = sq_dists(x[None, :], pool.centers)[0]
    d2 = jnp.where(new_mask, d2, jnp.inf)
    best_new = jnp.min(d2)
    use_new = best_new < d2_start
    idx = jnp.where(use_new, jnp.argmin(d2), idx_start)
    return jnp.minimum(d2_start, best_new), idx


def pool_append_serial(pool: CenterPool, x: jnp.ndarray, do: jnp.ndarray) -> tuple[CenterPool, jnp.ndarray]:
    """Append x at slot `count` if `do` (traced bool). Returns (pool, slot).

    slot is the written index, or -1 when not written / overflowed.
    """
    k_max = pool.centers.shape[0]
    fits = pool.count < k_max
    write = jnp.logical_and(do, fits)
    slot = jnp.where(write, pool.count, -1)
    idx = jnp.clip(pool.count, 0, k_max - 1)
    centers = jnp.where(
        write,
        jax.lax.dynamic_update_slice(pool.centers, x[None, :].astype(pool.centers.dtype), (idx, 0)),
        pool.centers,
    )
    mask = jnp.where(write, pool.mask.at[idx].set(True), pool.mask)
    count = pool.count + write.astype(jnp.int32)
    overflow = jnp.logical_or(pool.overflow, jnp.logical_and(do, ~fits))
    return CenterPool(centers, mask, count, overflow), slot


def block_epochs(n: int, pb: int) -> int:
    """Number of bulk-synchronous epochs for n points with Pb points/epoch."""
    return max(1, math.ceil(n / pb))


def serial_validate(
    pool: CenterPool,
    send: jnp.ndarray,              # (B,) bool — proposal flags in index order
    payload: jnp.ndarray,           # (B, D) — proposed center / feature vectors
    accept_fn: Callable[[CenterPool, jnp.ndarray, Any], tuple[jnp.ndarray, Any]],
    aux: Any = None,                # per-proposal auxiliary pytree (leading dim B)
) -> tuple[CenterPool, jnp.ndarray, Any]:
    """The serializing validator: a deterministic scan in global index order.

    `accept_fn(pool, x_j, aux_j) -> (accept: bool0-d, append_vec, out_j)`
    decides, given the state *including previously accepted proposals of this
    epoch*, whether proposal j becomes a new center, and what vector to
    append (DP/OFL append x_j itself; BP-means appends the residual, Alg. 8).
    Rejected proposals get their reference resolved by the caller via
    `out_j` (e.g. nearest-center index).

    Returns (pool', slot (B,) int32 — accepted slot or -1, outs).
    This is Alg. 2 (DPValidate) / Alg. 5 (OFLValidate) / Alg. 8 (BPValidate)
    generically; identical on every device, hence safe to run replicated.
    """
    if aux is None:
        aux = jnp.zeros((send.shape[0],), jnp.int32)

    def step(carry, inp):
        pool = carry
        send_j, x_j, aux_j = inp
        accept, append_vec, out_j = accept_fn(pool, x_j, aux_j)
        accept = jnp.logical_and(accept, send_j)
        pool, slot = pool_append_serial(pool, append_vec, accept)
        return pool, (slot, out_j)

    pool, (slots, outs) = jax.lax.scan(step, pool, (send, payload, aux))
    return pool, slots, outs


def effective_cap(cap: int | None, b: int) -> int:
    """The bounded master's actual compaction width for a width-b epoch —
    THE single definition: `precomputed_gather_validate` compacts to it and
    the engine records it in `OCCStats.cap`, so the adaptive overflow check
    (`proposed > cap`) is exact by construction, not by parallel copies."""
    return b if cap is None or cap >= b else cap


def _compact_sent(send: jnp.ndarray, cap: int):
    """Bounded-master compaction: stable indices of the first `cap` sent
    proposals (ascending global order) + the sent_overflow flag.  Shared by
    both validator implementations so their windows are identical."""
    b = send.shape[0]
    n_sent = jnp.sum(send.astype(jnp.int32))
    sent_overflow = n_sent > cap if cap < b else jnp.zeros((), bool)
    order = jnp.argsort(jnp.where(send, jnp.arange(b), b), stable=True)[:cap]
    return order, sent_overflow


def _scatter_back(order: jnp.ndarray, b: int, slots_c: jnp.ndarray, outs_c):
    """Scatter compacted validator verdicts back to the full index space."""
    slots = jnp.full((b,), -1, jnp.int32).at[order].set(slots_c, mode="drop")
    outs = jax.tree.map(
        lambda o: jnp.zeros((b,) + o.shape[1:], o.dtype).at[order].set(o, mode="drop"),
        outs_c,
    )
    return slots, outs


# ---------------------------------------------------------------------------
# Precomputed (D-free) validation — DESIGN.md §9/§11
# ---------------------------------------------------------------------------

class ValidatePre(NamedTuple):
    """Everything D-dimensional the fast validator needs, batched on the MXU.

    Payload-append transactions (DP-means, OFL — the accepted append vector
    IS the payload): a new center can only come from the sent set, so every
    distance the serial scan will ever consult is either payload→C^{t-1}
    (computed once in propose and threaded through `aux`) or
    payload→payload (`pair_d2`); `gram` stays None.

    Gram-append transactions (BP-means — the accepted append vector is the
    validator-refit *residual*): every vector the refit can ever touch is a
    signed combination of sent payloads, so all refit dot products reduce
    to the payload Gram matrix `gram[i, j] = r_i · r_j` and validation
    becomes pure coefficient algebra (`precomputed_validate_gram`);
    d2_start / idx_start / pair_d2 stay None.

    d2_start:  (cap,)  min squared distance to the epoch-start centers.
    idx_start: (cap,)  int32 — that center's slot, -1 when the pool is empty.
    pair_d2:   (cap, cap)  payload pairwise squared distances; row j is
               consulted against proposals appended before j.
    aux:       per-proposal decision scalars (leading dim cap; e.g. OFL's
               uniforms), or None when the rule needs only d2.
    gram:      (cap, cap)  payload inner products r_i · r_j (BP-means), or
               None for payload-append transactions.
    """
    d2_start: jnp.ndarray | None
    idx_start: jnp.ndarray | None
    pair_d2: jnp.ndarray | None
    aux: Any
    gram: jnp.ndarray | None = None


def precomputed_validate(
    pool: CenterPool,
    send_c: jnp.ndarray,            # (cap,) bool — compacted proposal flags
    payload_c: jnp.ndarray,         # (cap, D) — compacted payloads
    pre: ValidatePre,
    decide_fn: Callable[[jnp.ndarray, Any], jnp.ndarray],
) -> tuple[CenterPool, jnp.ndarray, jnp.ndarray]:
    """The serializing scan with ZERO D-dimensional work per step.

    Same serial semantics as `serial_validate` (deterministic, compaction
    order == global index order), but each step is O(cap) scalar mask/min/
    compare logic over precomputed distances: the carry is (count, overflow,
    per-proposal slots), never the (K_max, D) center buffer.  Accepted
    payloads are written back to the pool in ONE batched scatter afterwards
    — O(cap·D) total instead of O(cap·K_max·D) sequential.

    `decide_fn(d2_cur, aux_j) -> bool` is the transaction's accept rule given
    the min squared distance to the *current* pool (epoch-start ∪ this
    epoch's appends).  Returns (pool', slots_c (cap,) int32, refs_c (cap,)
    int32 — nearest-center reference for rejected proposals).
    """
    cap = send_c.shape[0]
    k_max = pool.centers.shape[0]
    count0 = pool.count

    def step(carry, inp):
        count, overflow, slots_c = carry
        j, send_j, d2s_j, idxs_j, pair_j, aux_j = inp
        # Distance to this epoch's previously appended proposals: a masked
        # row of the precomputed pairwise matrix (slots_c >= 0 marks them).
        d2_new = jnp.where(slots_c >= 0, pair_j, jnp.inf)
        best_new = jnp.min(d2_new)
        # Strict <: on a tie the full argmin picks the lower slot, which is
        # always the epoch-start center (new slots sit at >= count0).
        use_new = best_new < d2s_j
        d2_cur = jnp.minimum(d2s_j, best_new)
        ref = jnp.where(use_new, slots_c[jnp.argmin(d2_new)], idxs_j)
        acc = jnp.logical_and(decide_fn(d2_cur, aux_j), send_j)
        fits = count < k_max
        app = jnp.logical_and(acc, fits)
        slot = jnp.where(app, count, -1)
        slots_c = jax.lax.dynamic_update_index_in_dim(slots_c, slot, j, 0)
        count = count + app.astype(jnp.int32)
        overflow = jnp.logical_or(overflow, jnp.logical_and(acc, ~fits))
        return (count, overflow, slots_c), ref

    aux = pre.aux
    if aux is None:
        aux = jnp.zeros((cap,), jnp.int32)
    init = (count0, pool.overflow, jnp.full((cap,), -1, jnp.int32))
    (count, overflow, slots_c), refs_c = jax.lax.scan(
        step, init, (jnp.arange(cap), send_c, pre.d2_start, pre.idx_start,
                     pre.pair_d2, aux))

    # One batched pool write: appended slots are unique by construction.
    widx = jnp.where(slots_c >= 0, slots_c, k_max)   # out-of-range rows drop
    centers = pool.centers.at[widx].set(
        payload_c.astype(pool.centers.dtype), mode="drop")
    mask = pool.mask.at[widx].set(True, mode="drop")
    return CenterPool(centers, mask, count, overflow), slots_c, refs_c


def logdepth_validate(
    pool: CenterPool,
    send_c: jnp.ndarray,
    payload_c: jnp.ndarray,
    pre: ValidatePre,
    decide_fn: Callable[[jnp.ndarray, Any], jnp.ndarray],
) -> tuple[CenterPool, jnp.ndarray, jnp.ndarray]:
    """`precomputed_validate` with the sequential accept chain replaced by a
    log-depth parallel resolution (DESIGN.md §11) — bit-identical verdicts.

    Key algebra: for a monotone threshold rule, accepting is intersective —
    decide(min(a, b), aux) == decide(a, aux) AND decide(b, aux) holds
    *exactly* in floats (min never rounds; DP's `d2 > λ²` and OFL's
    `u < min(1, d2/λ²)` are both monotone, and x ↦ min(1, x/λ²) commutes
    with min elementwise).  The serial recurrence therefore collapses to

        accept_j = base_j ∧ ∀ accepted i<j : surv[i, j]

    with base = decide(d2_start) ∧ send and surv[i, j] = decide(pair_d2[i,
    j], aux_j) — the lexicographically-first independent set of the `¬surv`
    conflict digraph.  It is resolved as a Kleene fixed point of
    boolean-semiring matvecs: each round accepts every still-alive proposal
    with no alive earlier killer and retires its victims, so the round
    count is the conflict graph's greedy chain depth — O(log cap) in the
    paper's low-conflict regime (Thm 3.3), never more than cap — while
    every round is parallel O(cap²) bit work on the precomputed matrix.
    Slots then come from one `associative_scan` prefix sum and refs from
    one masked column-min, both exact.

    Pool-capacity overflow makes acceptance rank-dependent (an accepted
    proposal that does not fit is appended nowhere and kills nobody), so
    that rare epoch falls back to the serial scan under `lax.cond` —
    verdicts stay bit-identical there too.
    """
    cap = send_c.shape[0]
    k_max = pool.centers.shape[0]
    count0 = pool.count
    aux = pre.aux
    if aux is None:
        aux = jnp.zeros((cap,), jnp.int32)
    aux_row = jax.tree.map(lambda a: a[None, ...], aux)   # broadcast over i

    base = jnp.logical_and(decide_fn(pre.d2_start, aux), send_c)
    # surv[i, j]: would j still accept with i's payload in the pool?
    surv = decide_fn(pre.pair_d2, aux_row)
    tri = jnp.arange(cap)[:, None] < jnp.arange(cap)[None, :]
    kill = jnp.logical_and(~surv, tri)

    def round_(state):
        alive, accepted = state
        blocked = jnp.any(jnp.logical_and(kill, alive[:, None]), axis=0)
        newly = jnp.logical_and(alive, ~blocked)
        accepted = jnp.logical_or(accepted, newly)
        victims = jnp.any(jnp.logical_and(kill, newly[:, None]), axis=0)
        alive = jnp.logical_and(alive, ~jnp.logical_or(newly, victims))
        return alive, accepted

    _, accepted = jax.lax.while_loop(
        lambda s: jnp.any(s[0]), round_,
        (base, jnp.zeros((cap,), bool)))

    def finish():
        rank = jax.lax.associative_scan(jnp.add, accepted.astype(jnp.int32))
        slots_c = jnp.where(accepted, count0 + rank - 1, -1)
        # refs: min over the FINAL accepted prefix — same value set (and the
        # same lowest-index tie-break) the serial chain of minimums sees.
        d2_new = jnp.where(jnp.logical_and(accepted[:, None], tri),
                           pre.pair_d2, jnp.inf)
        best_new = jnp.min(d2_new, axis=0)
        arg_new = jnp.argmin(d2_new, axis=0)
        use_new = best_new < pre.d2_start
        refs_c = jnp.where(use_new, slots_c[arg_new], pre.idx_start)
        widx = jnp.where(slots_c >= 0, slots_c, k_max)
        centers = pool.centers.at[widx].set(
            payload_c.astype(pool.centers.dtype), mode="drop")
        mask = pool.mask.at[widx].set(True, mode="drop")
        new_pool = CenterPool(centers, mask, count0 + rank[-1], pool.overflow)
        return new_pool, slots_c, refs_c

    n_acc = jnp.sum(accepted.astype(jnp.int32))
    return jax.lax.cond(
        count0 + n_acc > k_max,
        lambda: precomputed_validate(pool, send_c, payload_c, pre, decide_fn),
        finish)


def precomputed_validate_gram(
    pool: CenterPool,
    send_c: jnp.ndarray,            # (cap,) bool — compacted proposal flags
    payload_c: jnp.ndarray,         # (cap, D) — compacted payload residuals
    pre: ValidatePre,
    decide_fn: Callable[[jnp.ndarray, Any], jnp.ndarray],
) -> tuple[CenterPool, jnp.ndarray, jnp.ndarray]:
    """The BP-means serializing scan with ZERO D-dimensional work per step
    (DESIGN.md §11) — the Gram-carry fast path.

    BPValidate (Alg. 8) re-fits each proposed residual r_j against the
    features accepted *earlier this epoch* and appends what remains.  Every
    such feature is a signed combination of sent payloads (by induction:
    f_m = r_{k_m} - Σ z f_l), so the scan carries each accepted feature's
    coefficient row c_m over payloads and derives every refit dot product
    from the precomputed payload Gram matrix G = R Rᵀ (`pre.gram`):

        r · f_m   = (G a) · c_m      with a the running residual's coeffs,
        ‖f_m‖²    = the residual norm² carried from m's own acceptance,
        ‖r - f‖²  = ‖r‖² - 2 r·f + ‖f‖².

    Each inner refit step is O(cap) vector algebra (one dot, two subtracts)
    and runs only `n_acc` times per proposal (`fori_loop` to the number of
    features accepted so far — sequential work tracks the Thm-3.3 conflict
    rate, not the cap), vs the reference's O(K_max · D) coordinate pass per
    step with a (K_max, D) pool carry.  Accepted residuals are materialised
    afterwards in ONE (cap, cap) @ (cap, D) MXU matmul.

    Returns (pool', slots_c (cap,) int32, z_c (cap, K_max) bool — each
    proposal's fit against this epoch's accepted features, scattered to
    pool slots; epoch-new slots are contiguous from count0 by construction).
    The coefficient algebra is exact in real arithmetic but reassociates
    float sums, so vs the D-dimensional reference the contract is
    bit-identical *decisions* (tests/test_validator_equivalence.py) and
    ulp-level centers.
    """
    cap = send_c.shape[0]
    k_max = pool.centers.shape[0]
    count0 = pool.count
    gram = pre.gram
    aux = pre.aux
    if aux is None:
        aux = jnp.zeros((cap,), jnp.int32)

    def step(carry, inp):
        # The pool count is count0 + nacc invariantly (only this scan
        # appends within the epoch), so nacc is the one counter carried.
        coef, gcoef, fnorm2, nacc, overflow = carry
        j, send_j, g_row, aux_j = inp

        def fit(m, st):
            a, u, rn2, z = st
            c_m = coef[m]
            dot = jnp.dot(u, c_m)
            z_m = 2.0 * dot > fnorm2[m]
            a = jnp.where(z_m, a - c_m, a)
            u = jnp.where(z_m, u - gcoef[m], u)
            rn2 = jnp.where(z_m, rn2 - 2.0 * dot + fnorm2[m], rn2)
            return a, u, rn2, z.at[m].set(z_m)

        a0 = (jnp.arange(cap) == j).astype(gram.dtype)
        a, u, rn2, z_j = jax.lax.fori_loop(
            0, nacc, fit, (a0, g_row, g_row[j], jnp.zeros((cap,), bool)))

        acc = jnp.logical_and(decide_fn(rn2, aux_j), send_j)
        fits = count0 + nacc < k_max
        app = jnp.logical_and(acc, fits)
        slot = jnp.where(app, count0 + nacc, -1)
        # Row writes go to an out-of-range index when not appending, so the
        # scatter drops instead of selecting between two full (cap, cap)
        # buffers — keeps the carry update O(cap) per step, not O(cap²).
        row = jnp.where(app, nacc, cap)
        coef = coef.at[row].set(a, mode="drop")
        gcoef = gcoef.at[row].set(u, mode="drop")  # u == G a: new G-row
        fnorm2 = fnorm2.at[row].set(rn2, mode="drop")
        nacc = nacc + app.astype(jnp.int32)
        overflow = jnp.logical_or(overflow, jnp.logical_and(acc, ~fits))
        return (coef, gcoef, fnorm2, nacc, overflow), (slot, z_j)

    z0 = jnp.zeros((cap, cap), gram.dtype)
    init = (z0, z0, jnp.zeros((cap,), gram.dtype),
            jnp.zeros((), jnp.int32), pool.overflow)
    (coef, _, _, nacc, overflow), (slots_c, z_mat) = jax.lax.scan(
        step, init, (jnp.arange(cap), send_c, gram, aux))

    # Epoch-new features occupy contiguous slots [count0, count0 + nacc):
    # scatter the acceptance-ordered fit bits / residual rows to pool slots.
    new_slots = count0 + jnp.arange(cap)
    z_c = jnp.zeros((cap, k_max), bool).at[:, new_slots].set(
        z_mat, mode="drop")
    feats = coef @ payload_c                    # ONE MXU materialisation
    widx = jnp.where(jnp.arange(cap) < nacc, new_slots, k_max)
    centers = pool.centers.at[widx].set(
        feats.astype(pool.centers.dtype), mode="drop")
    mask = pool.mask.at[widx].set(True, mode="drop")
    return CenterPool(centers, mask, count0 + nacc, overflow), slots_c, z_c


def precomputed_gather_validate(
    pool: CenterPool,
    send: jnp.ndarray,
    payload: jnp.ndarray,
    aux: Any,
    precompute_fn: Callable[..., ValidatePre],
    decide_fn: Callable[[jnp.ndarray, Any], jnp.ndarray],
    cap: int | None = None,
    replicate: Callable[[jnp.ndarray], jnp.ndarray] | None = None,
    scan_mode: str = "serial",
):
    """Bounded-master validation — THE engine validator (DESIGN.md §9/§11).

    Compacts the sent proposals (stable order == global index order), runs
    `precompute_fn(pool, payload_c, aux_c, count0)` ONCE on the MXU, then a
    D-free serializing resolution, then scatters verdicts back to the full
    index space.  The resolution is picked from the ValidatePre contents
    and `scan_mode`: `pre.gram` set → the BP-means Gram-carry scan;
    otherwise the payload scalar scan (`scan_mode="serial"`) or its
    log-depth fixed-point formulation (`scan_mode="logdepth"`).

    `replicate` (optional) constrains the compacted buffers — inputs AND
    every precomputed (cap, …) ValidatePre leaf — to the master's
    replicated sharding before the scan, so GSPMD gathers once at
    compaction instead of resharding mid-scan, at whatever cap the epoch
    runs with (see shardings.occ_validate_sharding).
    """
    b = send.shape[0]
    count0 = pool.count
    cap_c = effective_cap(cap, b)
    order, sent_overflow = _compact_sent(send, cap_c)
    send_c = send[order]
    payload_c = payload[order]
    aux_c = None if aux is None else jax.tree.map(lambda a: a[order], aux)
    if replicate is not None:
        send_c, payload_c = replicate(send_c), replicate(payload_c)
        aux_c = None if aux_c is None else jax.tree.map(replicate, aux_c)
    pre = precompute_fn(pool, payload_c, aux_c, count0)
    if replicate is not None:
        pre = jax.tree.map(replicate, pre)
    if pre.gram is not None:
        validate = precomputed_validate_gram
    elif scan_mode == "logdepth":
        validate = logdepth_validate
    elif scan_mode == "serial":
        validate = precomputed_validate
    else:
        raise ValueError(f"unknown scan_mode {scan_mode!r}")
    pool, slots_c, refs_c = validate(pool, send_c, payload_c, pre, decide_fn)
    slots, outs = _scatter_back(order, b, slots_c, refs_c)
    return pool, slots, outs, sent_overflow

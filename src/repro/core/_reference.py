"""Reference validators — tests and benchmark baselines ONLY.

The engine's single validation pipeline is `occ.precomputed_gather_validate`
(DESIGN.md §11).  This module preserves the pre-refactor legacy path — one
full D-dimensional recompute per sequential scan step through each
transaction's `accept` method — as the independent oracle that the fast
paths are checked against (`tests/test_validator_equivalence.py`) and timed
against (`benchmarks/validator_scan.py`).  Nothing under `repro.core`
imports this module; it must never re-enter the engine.
"""
from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp

from repro.core.occ import (
    CenterPool, OCCStats, _compact_sent, _scatter_back, block_epochs,
    serial_validate,
)

__all__ = ["_reference_validate", "reference_pass"]


def _reference_validate(
    pool: CenterPool,
    send: jnp.ndarray,
    payload: jnp.ndarray,
    accept_fn,
    aux: Any = None,
    cap: int | None = None,
):
    """Legacy bounded-master validation (the pre-§11 `gather_validate`):
    compact the sent proposals (stable order) to `cap` slots, then run the
    serial scan with `accept_fn` recomputing every D-dimensional quantity
    per step.  Same compaction window as the fast path (`_compact_sent` is
    shared), so verdicts are directly comparable."""
    b = send.shape[0]
    if cap is None or cap >= b:
        pool, slots, outs = serial_validate(pool, send, payload, accept_fn, aux)
        return pool, slots, outs, jnp.zeros((), bool)

    order, sent_overflow = _compact_sent(send, cap)
    send_c = send[order]
    payload_c = payload[order]
    aux_c = None if aux is None else jax.tree.map(lambda a: a[order], aux)
    pool, slots_c, outs_c = serial_validate(pool, send_c, payload_c,
                                            accept_fn, aux_c)
    slots, outs = _scatter_back(order, b, slots_c, outs_c)
    return pool, slots, outs, sent_overflow


def _reference_epoch(txn, pool, x_e, valid_e, state_e, cap):
    """One OCC epoch on the legacy path — mirrors `engine._epoch_body` with
    the validator swapped for the per-step D-dimensional reference."""
    count0 = pool.count
    send, payload, aux, safe = txn.propose(pool, x_e, state_e)
    send = jnp.logical_and(send, valid_e)
    accept = lambda p, v_j, a_j: txn.accept(p, v_j, a_j, count0)
    pool, slots, outs, sent_ovf = _reference_validate(
        pool, send, payload, accept, aux, cap=cap)
    assign_e = txn.writeback(send, slots, outs, safe, valid_e)
    pool = pool._replace(overflow=jnp.logical_or(pool.overflow, sent_ovf))
    n_sent = jnp.sum(send.astype(jnp.int32))
    n_acc = jnp.sum((slots >= 0).astype(jnp.int32))
    return pool, assign_e, send, n_sent, n_acc


_reference_epoch_jit = jax.jit(_reference_epoch, static_argnames=("cap",))


def reference_pass(txn, pool: CenterPool, x: jnp.ndarray, state: Any = None,
                   *, pb: int, cap: int | None = None):
    """A whole bulk-synchronous pass on the legacy validator: the Python
    epoch loop the engine replaced, kept as the end-to-end oracle.  Returns
    an (pool, assign, send, stats) tuple comparable to `OCCEngine.run`
    outputs (no bootstrap prefix; epoch partition identical to the
    engine's)."""
    if state is None:
        state = txn.make_state(x, 0)
    n = x.shape[0]
    t_epochs = block_epochs(n, pb)
    assigns, sends, n_sents, n_accs = [], [], [], []
    for t in range(t_epochs):
        lo, hi = t * pb, min((t + 1) * pb, n)
        width = hi - lo
        x_e = x[lo:hi]
        state_e = jax.tree.map(lambda s: s[lo:hi], state)
        if width < pb:     # pad the final short epoch like the engine does
            padf = lambda a: jnp.concatenate(
                [a, jnp.zeros((pb - width,) + a.shape[1:], a.dtype)], 0)
            x_e = padf(x_e)
            state_e = jax.tree.map(padf, state_e)
        valid_e = jnp.arange(pb) < width
        pool, assign_e, send_e, n_sent, n_acc = _reference_epoch_jit(
            txn, pool, x_e, valid_e, state_e, cap)
        assigns.append(jax.tree.map(lambda a: a[:width], assign_e))
        sends.append(send_e[:width])
        n_sents.append(n_sent)
        n_accs.append(n_acc)
    assign = jax.tree.map(lambda *a: jnp.concatenate(a, 0), *assigns)
    send = jnp.concatenate(sends, 0)
    cap_eff = pb if cap is None or cap >= pb else cap
    stats = OCCStats(jnp.stack(n_sents), jnp.stack(n_accs),
                     jnp.full((t_epochs,), cap_eff, jnp.int32))
    return pool, assign, send, stats

"""BP-means: serial (Alg. 7) and OCC-parallel (Alg. 6 + BPValidate Alg. 8).

Latent binary feature learning: x_i ~ sum_k z_ik f_k.  The per-point
transaction is (1) a greedy coordinate pass setting each z_ik in feature
order, (2) if the residual norm exceeds lambda, proposing the residual as a
new feature.  BPValidate re-fits each proposed feature against the features
*newly accepted in this epoch* and accepts the remaining residual (Alg. 8).

Serial equivalence holds because the greedy coordinate pass visits features
in creation order: decisions over old features depend only on old features,
so worker-side fitting against C^{t-1} followed by validator-side fitting of
the residual against the epoch's new features reproduces exactly the serial
pass over C^{t-1} ∪ Ĉ (Appendix B.2).

The OCC version is a declarative `BPMeansTransaction` run by the unified
`OCCEngine` (core/engine.py); `occ_bp_means` remains as the backward-
compatible wrapper returning `BPMeansResult`.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any, NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.engine import OCCEngine, accumulate_pass_stats
from repro.core.objective import bp_means_objective
from repro.core.occ import (
    CenterPool, OCCStats, ValidatePre, make_pool, serial_validate,
)

__all__ = ["BPMeansResult", "BPMeansTransaction", "coordinate_pass",
           "serial_bp_means_pass", "serial_bp_means", "occ_bp_means"]


class BPMeansResult(NamedTuple):
    pool: CenterPool            # features live in pool.centers
    z: jnp.ndarray              # (N, K_max) bool
    stats: OCCStats
    send: jnp.ndarray
    epoch_of: jnp.ndarray
    n_iters: int
    objective: jnp.ndarray


def coordinate_pass(x: jnp.ndarray, z0: jnp.ndarray, pool: CenterPool,
                    feat_mask: jnp.ndarray | None = None):
    """Greedy single pass over features in order (Alg. 7 inner loop).

    x: (B, D), z0: (B, K_max) bool initial assignment.  For each feature k
    in index order set z_k = argmin_{0,1} ||r_excl_k - z_k f_k||^2, i.e.
    z_k = 1 iff 2 r·f_k > ||f_k||^2 with r excluding f_k's current term.
    Returns (z, residual) with residual = x - z F.  Batched; O(K_max) scan.
    """
    mask = pool.mask if feat_mask is None else feat_mask
    r0 = x - (z0 & mask[None, :]).astype(x.dtype) @ pool.centers

    def step(r, inp):
        f_k, m_k, z_k = inp                       # (D,), (), (B,)
        r_excl = r + z_k[:, None].astype(r.dtype) * f_k[None, :]
        znew = jnp.logical_and(m_k, 2.0 * (r_excl @ f_k) > f_k @ f_k)
        r = r_excl - znew[:, None].astype(r.dtype) * f_k[None, :]
        return r, znew

    r, z_t = jax.lax.scan(step, r0, (pool.centers, mask, (z0 & mask[None, :]).T))
    return z_t.T, r


def _created_rows(slots: jnp.ndarray, k_max: int) -> jnp.ndarray:
    """(B, K_max) bool: one-hot of each point's accepted slot (or all-False)."""
    created = jax.nn.one_hot(jnp.where(slots >= 0, slots, 0), k_max, dtype=bool)
    return jnp.logical_and(created, (slots >= 0)[:, None])


@jax.tree_util.register_pytree_node_class
@dataclass(frozen=True)
class BPMeansTransaction:
    """OCC BP-means as a transaction (Alg. 6 optimistic phase + Alg. 8
    BPValidate): workers fit each point against C^{t-1} and propose the
    residual; the validator re-fits proposals against this epoch's newly
    accepted features before deciding."""
    lam: Any
    k_max: int = 256
    init_mean: bool = True

    def tree_flatten(self):
        return (self.lam,), (self.k_max, self.init_mean)

    @classmethod
    def tree_unflatten(cls, aux, children):
        return cls(children[0], *aux)

    def _lam2(self, dtype):
        return jnp.asarray(self.lam, dtype) ** 2

    def init_pool(self, x):
        pool = make_pool(self.k_max, x.shape[-1], x.dtype)
        if not self.init_mean:
            return pool
        # Alg. 7 initialization: f_1 = mean(x) (one psum), z_i1 = 1.  The
        # engine hands this the pass's first Pb block, so batch and
        # streaming runs seed the same feature (§11 / test_stream_carry).
        centers = pool.centers.at[0].set(jnp.mean(x, axis=0))
        return pool._replace(centers=centers, mask=pool.mask.at[0].set(True),
                             count=jnp.ones((), jnp.int32))

    def make_state(self, x, offset: int = 0):
        z = jnp.zeros((x.shape[0], self.k_max), bool)
        return z.at[:, 0].set(True) if self.init_mean else z

    def propose(self, pool, x_e, z0_e):
        z_old, r = coordinate_pass(x_e, z0_e, pool)
        resid2 = jnp.sum(r * r, axis=-1)
        return resid2 > self._lam2(x_e.dtype), r, None, z_old

    def precompute_accept(self, pool, payload_c, aux_c, count0):
        # Gram-carry fast path (DESIGN.md §11): BPValidate appends the REFIT
        # RESIDUAL, not the sent payload — but every feature the refit can
        # touch is a signed combination of sent payloads, so the payload
        # Gram matrix G = R Rᵀ covers every dot product the scan needs.
        # The engine routes this to `occ.precomputed_validate_gram`.
        return ValidatePre(None, None, None, aux_c,
                           gram=payload_c @ payload_c.T)

    def accept_pre(self, resid2, aux_j):
        # Alg. 8 acceptance on the carried refit residual norm².
        return resid2 > self._lam2(resid2.dtype)

    def accept(self, pool, f_new, aux_j, count0):
        # REFERENCE ONLY (core/_reference.py): BPValidate by explicit
        # D-dimensional refit — fit f_new against features accepted *this
        # epoch* (slots >= count0), accept the residual if still badly
        # represented.  The Gram scan is decision-identical to this rule
        # (tests/test_validator_equivalence.py); its appended residuals
        # differ only by float reassociation of the same exact algebra.
        k_max = pool.centers.shape[0]
        epoch_mask = jnp.logical_and(pool.mask, jnp.arange(k_max) >= count0)
        zref, r = coordinate_pass(f_new[None, :], jnp.zeros((1, k_max), bool),
                                  pool, epoch_mask)
        resid2 = jnp.sum(r[0] * r[0])
        return resid2 > self._lam2(f_new.dtype), r[0], zref[0]

    def writeback(self, send, slots, outs, safe, valid):
        created = _created_rows(slots, self.k_max)
        z = jnp.logical_or(
            safe, jnp.logical_or(jnp.logical_and(outs, send[:, None]), created))
        return jnp.logical_and(z, valid[:, None])

    def refine(self, pool, x, z):
        return _reestimate(x, z, pool)

    def objective(self, x, z, pool):
        return bp_means_objective(x, z, pool.centers, self.lam, pool.mask)


# ---------------------------------------------------------------------------
# Serial BP-means (Alg. 7)
# ---------------------------------------------------------------------------

@jax.jit
def _serial_bp_pass(x, z, pool, lam2):
    """Serial pass: each point fits against the *current* feature set (which
    grows during the pass), then may create its residual as a feature."""
    def accept_fn(p: CenterPool, x_j, z_j):
        znew, r = coordinate_pass(x_j[None, :], z_j[None, :], p)
        resid2 = jnp.sum(r[0] * r[0])
        return resid2 > lam2, r[0], znew[0]

    send = jnp.ones((x.shape[0],), bool)
    pool, slots, z_out = serial_validate(pool, send, x, accept_fn, aux=z)
    k_max = pool.centers.shape[0]
    z = jnp.logical_or(z_out, _created_rows(slots, k_max))
    return pool, z


def _reestimate(x, z, pool, ridge=1e-6):
    """F <- (Z^T Z)^{-1} Z^T X restricted to valid features (parallel sums)."""
    zf = jnp.logical_and(z, pool.mask[None, :]).astype(x.dtype)
    ztz = zf.T @ zf
    ztx = zf.T @ x
    diag = jnp.where(pool.mask, ridge, 1.0)
    a = ztz * (pool.mask[:, None] & pool.mask[None, :]) + jnp.diag(diag)
    f = jnp.linalg.solve(a, ztx * pool.mask[:, None])
    return pool._replace(centers=jnp.where(pool.mask[:, None], f, pool.centers))


def serial_bp_means_pass(x, lam, k_max, pool=None, z=None, init_mean=True):
    lam2 = jnp.asarray(lam, x.dtype) ** 2
    if pool is None:
        txn = BPMeansTransaction(lam, k_max, init_mean)
        pool = txn.init_pool(x)
        z = txn.make_state(x)
    return _serial_bp_pass(x, z, pool, lam2)


def serial_bp_means(x, lam, k_max=256, max_iters=10, init_mean=True) -> BPMeansResult:
    n = x.shape[0]
    pool, z = serial_bp_means_pass(x, lam, k_max, init_mean=init_mean)
    pool = _reestimate(x, z, pool)
    it = 1
    for it in range(2, max_iters + 1):
        z_prev = z
        pool, z = serial_bp_means_pass(x, lam, k_max, pool, z)
        pool = _reestimate(x, z, pool)
        if bool(jnp.all(z == z_prev)):
            break
    obj = bp_means_objective(x, z, pool.centers, lam, pool.mask)
    t = np.zeros((1,), np.int32)
    return BPMeansResult(pool, z, OCCStats(t, t), jnp.zeros((n,), bool),
                         jnp.zeros((n,), jnp.int32), it, obj)


# ---------------------------------------------------------------------------
# OCC BP-means (Alg. 6) — compatibility wrapper over the engine
# ---------------------------------------------------------------------------

def occ_bp_means(
    x: jnp.ndarray,
    lam: float,
    pb: int,
    k_max: int = 256,
    max_iters: int = 1,
    init_mean: bool = True,
    bootstrap: bool = False,
    validate_cap: int | None | str = None,
    mesh: jax.sharding.Mesh | None = None,
    data_axis: str = "data",
) -> BPMeansResult:
    """OCC BP-means (Alg. 6) with bulk-synchronous epochs of Pb points —
    convenience wrapper running `BPMeansTransaction` under `OCCEngine`
    (Gram-carry validation; `validate_cap` accepts "adaptive" like the
    other transactions).  `init_mean` seeds f₁ from the first Pb block's
    mean (the engine's initializer scope), so batch and streaming runs
    agree."""
    n = x.shape[0]
    txn = BPMeansTransaction(lam, k_max, init_mean)
    eng = OCCEngine(txn, pb, validate_cap=validate_cap, mesh=mesh,
                    data_axis=data_axis)
    nb = min(n, max(1, pb // 16)) if bootstrap else 0

    z = txn.make_state(x)
    send = jnp.zeros((n,), bool)
    epoch_of = jnp.zeros((n,), jnp.int32)
    stat_parts: list[OCCStats] = []
    epoch_base = 0
    z_prev = None
    it_done = 0
    pool = None
    for it in range(1, max_iters + 1):
        it_done = it
        if it == 1:
            res = eng.run(x, state=z, n_bootstrap=nb)
            z, send, epoch_of = res.assign, res.send, res.epoch_of
        else:
            # Bootstrapped points keep their serial-prefix assignment; later
            # passes re-run only the bulk-synchronous epochs (seed semantics).
            res = eng.run(x[nb:], pool=pool, state=z[nb:])
            z = z.at[nb:].set(res.assign)
            send = send.at[nb:].set(res.send)
            epoch_of = epoch_of.at[nb:].set(res.epoch_of + epoch_base)
        # Every pass's validator load is recorded, with global epoch numbers.
        stat_parts.append(res.stats)
        epoch_base += res.stats.proposed.shape[0]
        pool = txn.refine(res.pool, x, z)
        if z_prev is not None and bool(jnp.all(z == z_prev)):
            break
        z_prev = z
    stats = accumulate_pass_stats(stat_parts)
    obj = txn.objective(x, z, pool)
    return BPMeansResult(pool, z, stats, send, epoch_of, it_done, obj)

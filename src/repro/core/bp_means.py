"""BP-means: serial (Alg. 7) and OCC-parallel (Alg. 6 + BPValidate Alg. 8).

Latent binary feature learning: x_i ~ sum_k z_ik f_k.  The per-point
transaction is (1) a greedy coordinate pass setting each z_ik in feature
order, (2) if the residual norm exceeds lambda, proposing the residual as a
new feature.  BPValidate re-fits each proposed feature against the features
*newly accepted in this epoch* and accepts the remaining residual (Alg. 8).

Serial equivalence holds because the greedy coordinate pass visits features
in creation order: decisions over old features depend only on old features,
so worker-side fitting against C^{t-1} followed by validator-side fitting of
the residual against the epoch's new features reproduces exactly the serial
pass over C^{t-1} ∪ Ĉ (Appendix B.2).
"""
from __future__ import annotations

import math
from functools import partial
from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.objective import bp_means_objective
from repro.core.occ import CenterPool, OCCStats, make_pool, serial_validate

__all__ = ["BPMeansResult", "coordinate_pass", "serial_bp_means_pass",
           "serial_bp_means", "occ_bp_means"]


class BPMeansResult(NamedTuple):
    pool: CenterPool            # features live in pool.centers
    z: jnp.ndarray              # (N, K_max) bool
    stats: OCCStats
    send: jnp.ndarray
    epoch_of: jnp.ndarray
    n_iters: int
    objective: jnp.ndarray


def coordinate_pass(x: jnp.ndarray, z0: jnp.ndarray, pool: CenterPool,
                    feat_mask: jnp.ndarray | None = None):
    """Greedy single pass over features in order (Alg. 7 inner loop).

    x: (B, D), z0: (B, K_max) bool initial assignment.  For each feature k
    in index order set z_k = argmin_{0,1} ||r_excl_k - z_k f_k||^2, i.e.
    z_k = 1 iff 2 r·f_k > ||f_k||^2 with r excluding f_k's current term.
    Returns (z, residual) with residual = x - z F.  Batched; O(K_max) scan.
    """
    mask = pool.mask if feat_mask is None else feat_mask
    r0 = x - (z0 & mask[None, :]).astype(x.dtype) @ pool.centers

    def step(r, inp):
        f_k, m_k, z_k = inp                       # (D,), (), (B,)
        r_excl = r + z_k[:, None].astype(r.dtype) * f_k[None, :]
        znew = jnp.logical_and(m_k, 2.0 * (r_excl @ f_k) > f_k @ f_k)
        r = r_excl - znew[:, None].astype(r.dtype) * f_k[None, :]
        return r, znew

    r, z_t = jax.lax.scan(step, r0, (pool.centers, mask, (z0 & mask[None, :]).T))
    return z_t.T, r


def _bp_accept(lam2, count0):
    """BPValidate: fit f_new against features accepted *this epoch* (slots
    >= count0), accept the residual if still badly represented."""
    def accept_fn(pool: CenterPool, f_new, _aux):
        k_max = pool.centers.shape[0]
        epoch_mask = jnp.logical_and(pool.mask, jnp.arange(k_max) >= count0)
        zref, r = coordinate_pass(f_new[None, :], jnp.zeros((1, k_max), bool),
                                  pool, epoch_mask)
        resid2 = jnp.sum(r[0] * r[0])
        return resid2 > lam2, r[0], zref[0]
    return accept_fn


# ---------------------------------------------------------------------------
# Serial BP-means (Alg. 7)
# ---------------------------------------------------------------------------

@partial(jax.jit, static_argnames=())
def _serial_bp_pass(x, z, pool, lam2):
    """Serial pass: each point fits against the *current* feature set (which
    grows during the pass), then may create its residual as a feature."""
    def accept_fn(p: CenterPool, x_j, z_j):
        znew, r = coordinate_pass(x_j[None, :], z_j[None, :], p)
        resid2 = jnp.sum(r[0] * r[0])
        return resid2 > lam2, r[0], znew[0]

    send = jnp.ones((x.shape[0],), bool)
    pool, slots, z_out = serial_validate(pool, send, x, accept_fn, aux=z)
    k_max = pool.centers.shape[0]
    created = jax.nn.one_hot(jnp.where(slots >= 0, slots, 0), k_max, dtype=bool)
    created = jnp.logical_and(created, (slots >= 0)[:, None])
    z = jnp.logical_or(z_out, created)
    return pool, z


def _init_mean(x, k_max):
    """Alg. 7 initialization: z_i1 = 1, f_1 = mean(x)."""
    pool = make_pool(k_max, x.shape[-1], x.dtype)
    centers = pool.centers.at[0].set(jnp.mean(x, axis=0))
    pool = pool._replace(centers=centers, mask=pool.mask.at[0].set(True),
                         count=jnp.ones((), jnp.int32))
    z = jnp.zeros((x.shape[0], k_max), bool).at[:, 0].set(True)
    return pool, z


def _reestimate(x, z, pool, ridge=1e-6):
    """F <- (Z^T Z)^{-1} Z^T X restricted to valid features (parallel sums)."""
    k_max = pool.centers.shape[0]
    zf = jnp.logical_and(z, pool.mask[None, :]).astype(x.dtype)
    ztz = zf.T @ zf
    ztx = zf.T @ x
    diag = jnp.where(pool.mask, ridge, 1.0)
    a = ztz * (pool.mask[:, None] & pool.mask[None, :]) + jnp.diag(diag)
    f = jnp.linalg.solve(a, ztx * pool.mask[:, None])
    return pool._replace(centers=jnp.where(pool.mask[:, None], f, pool.centers))


def serial_bp_means_pass(x, lam, k_max, pool=None, z=None, init_mean=True):
    lam2 = jnp.asarray(lam, x.dtype) ** 2
    if pool is None:
        if init_mean:
            pool, z = _init_mean(x, k_max)
        else:
            pool = make_pool(k_max, x.shape[-1], x.dtype)
            z = jnp.zeros((x.shape[0], k_max), bool)
    return _serial_bp_pass(x, z, pool, lam2)


def serial_bp_means(x, lam, k_max=256, max_iters=10, init_mean=True) -> BPMeansResult:
    n = x.shape[0]
    pool, z = serial_bp_means_pass(x, lam, k_max, init_mean=init_mean)
    pool = _reestimate(x, z, pool)
    it = 1
    for it in range(2, max_iters + 1):
        z_prev = z
        pool, z = serial_bp_means_pass(x, lam, k_max, pool, z)
        pool = _reestimate(x, z, pool)
        if bool(jnp.all(z == z_prev)):
            break
    obj = bp_means_objective(x, z, pool.centers, lam, pool.mask)
    t = np.zeros((1,), np.int32)
    return BPMeansResult(pool, z, OCCStats(t, t), jnp.zeros((n,), bool),
                         jnp.zeros((n,), jnp.int32), it, obj)


# ---------------------------------------------------------------------------
# OCC BP-means (Alg. 6)
# ---------------------------------------------------------------------------

@jax.jit
def _bp_epoch(pool: CenterPool, xs, valid, z0, lam2):
    """One OCC epoch: batched optimistic fit against C^{t-1}; residual
    proposals serially validated against this epoch's accepted features."""
    count0 = pool.count
    z_old, r = coordinate_pass(xs, z0, pool)
    resid2 = jnp.sum(r * r, axis=-1)
    send = jnp.logical_and(resid2 > lam2, valid)
    pool2, slots, zref = serial_validate(pool, send, r, _bp_accept(lam2, count0))
    k_max = pool.centers.shape[0]
    created = jnp.logical_and(
        jax.nn.one_hot(jnp.where(slots >= 0, slots, 0), k_max, dtype=bool),
        (slots >= 0)[:, None])
    z = jnp.logical_or(z_old, jnp.logical_or(jnp.logical_and(zref, send[:, None]), created))
    z = jnp.logical_and(z, valid[:, None])
    return pool2, z, send, jnp.sum(send.astype(jnp.int32)), jnp.sum((slots >= 0).astype(jnp.int32))


def occ_bp_means(
    x: jnp.ndarray,
    lam: float,
    pb: int,
    k_max: int = 256,
    max_iters: int = 1,
    init_mean: bool = True,
    bootstrap: bool = False,
    mesh: jax.sharding.Mesh | None = None,
    data_axis: str = "data",
) -> BPMeansResult:
    """OCC BP-means (Alg. 6) with bulk-synchronous epochs of Pb points."""
    n, d = x.shape
    lam2 = jnp.asarray(lam, x.dtype) ** 2
    if init_mean:
        pool, z = _init_mean(x, k_max)   # parallel global mean (one psum)
    else:
        pool = make_pool(k_max, d, x.dtype)
        z = jnp.zeros((n, k_max), bool)
    send_all = jnp.zeros((n,), bool)
    epoch_of = jnp.zeros((n,), jnp.int32)

    put = None
    if mesh is not None:
        shd = jax.sharding.NamedSharding(mesh, jax.sharding.PartitionSpec(data_axis))
        put = lambda a: jax.device_put(a, shd)

    start = 0
    if bootstrap:
        nb = max(1, pb // 16)
        pool, zb = serial_bp_means_pass(x[:nb], lam, k_max, pool, z[:nb])
        z = z.at[:nb].set(zb)
        send_all = send_all.at[:nb].set(True)
        start = nb

    n_rest = n - start
    t_epochs = max(1, math.ceil(n_rest / pb))
    pad = t_epochs * pb - n_rest
    xs = jnp.concatenate([x[start:], jnp.zeros((pad, d), x.dtype)], 0)
    valid = jnp.concatenate([jnp.ones((n_rest,), bool), jnp.zeros((pad,), bool)])

    stats_p, stats_a = [], []
    z_prev = None
    it_done = 0
    for it in range(1, max_iters + 1):
        it_done = it
        for t in range(t_epochs):
            sl = slice(t * pb, (t + 1) * pb)
            lo = start + t * pb
            hi = min(lo + pb, n)
            ze0 = z[lo:hi] if hi - lo == pb else \
                jnp.zeros((pb, k_max), bool).at[:hi - lo].set(z[lo:hi])
            xe, ve = xs[sl], valid[sl]
            if put is not None:
                xe, ve, ze0 = put(xe), put(ve), put(ze0)
            pool, ze, se, n_sent, n_acc = _bp_epoch(pool, xe, ve, ze0, lam2)
            z = z.at[lo:hi].set(ze[:hi - lo])
            send_all = send_all.at[lo:hi].set(se[:hi - lo])
            epoch_of = epoch_of.at[lo:hi].set(t)
            if it == 1:
                stats_p.append(int(n_sent))
                stats_a.append(int(n_acc))
        pool = _reestimate(x, z, pool)
        if z_prev is not None and bool(jnp.all(z == z_prev)):
            break
        z_prev = z
    obj = bp_means_objective(x, z, pool.centers, lam, pool.mask)
    stats = OCCStats(np.asarray(stats_p, np.int32), np.asarray(stats_a, np.int32))
    return BPMeansResult(pool, z, stats, send_all, epoch_of, it_done, obj)

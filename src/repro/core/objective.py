"""Objectives from the paper.

J(C) = sum_x min_{mu in C} ||x - mu||^2 + lambda^2 |C|        (Eq. 5, DP-means / FL)
BP-means cost = sum_i ||x_i - Z_i F||^2 + lambda^2 K          (MAD-Bayes / BP-means)
"""
from __future__ import annotations

import jax.numpy as jnp

__all__ = ["sq_dists", "dp_means_objective", "bp_means_objective"]


def sq_dists(x: jnp.ndarray, centers: jnp.ndarray) -> jnp.ndarray:
    """Pairwise squared euclidean distances (N, D) x (K, D) -> (N, K).

    Uses the expanded form ||x||^2 + ||mu||^2 - 2 x mu^T so the inner term is
    a single matmul (MXU-friendly; the Pallas kernel tiles the same algebra).
    Clamped at zero against fp cancellation.
    """
    x = jnp.asarray(x)
    centers = jnp.asarray(centers)
    x2 = jnp.sum(x * x, axis=-1, keepdims=True)          # (N, 1)
    c2 = jnp.sum(centers * centers, axis=-1)[None, :]    # (1, K)
    cross = x @ centers.T                                # (N, K)
    return jnp.maximum(x2 + c2 - 2.0 * cross, 0.0)


def dp_means_objective(x: jnp.ndarray, centers: jnp.ndarray, lam: float,
                       mask: jnp.ndarray | None = None) -> jnp.ndarray:
    """Facility-location / DP-means objective J(C) (paper Eq. 5)."""
    d2 = sq_dists(x, centers)
    if mask is not None:
        d2 = jnp.where(mask[None, :], d2, jnp.inf)
        k = jnp.sum(mask)
    else:
        k = centers.shape[0]
    return jnp.sum(jnp.min(d2, axis=-1)) + lam * lam * k


def bp_means_objective(x: jnp.ndarray, z: jnp.ndarray, feats: jnp.ndarray,
                       lam: float, mask: jnp.ndarray | None = None) -> jnp.ndarray:
    """BP-means cost: ||X - Z F||_F^2 + lambda^2 K."""
    if mask is not None:
        z = z * mask[None, :]
        k = jnp.sum(mask)
    else:
        k = feats.shape[0]
    resid = x - z.astype(x.dtype) @ feats
    return jnp.sum(resid * resid) + lam * lam * k

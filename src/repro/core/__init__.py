"""The paper's contribution: one OCC pattern + DP-means / OFL / BP-means.

Primary entry point: `OCCEngine` running an `OCCTransaction` — the
concurrency-control mechanism (epoch scan, serializing validator, mesh
sharding, bounded master, streaming `partial_fit`) is factored out of the
algorithms, which are ~50-line declarative transactions.  The legacy
`occ_dp_means` / `occ_ofl` / `occ_bp_means` entry points remain as thin
convenience wrappers over the engine.
"""
from repro.core.occ import (
    CenterPool, OCCStats, ValidatePre, make_pool, nearest_center,
    nearest_center_with_new, serial_validate, precomputed_validate,
    precomputed_validate_gram, logdepth_validate,
    precomputed_gather_validate,
)
from repro.core.engine import (
    OCCEngine, OCCTransaction, OCCPassResult, resolve_assignments,
)
from repro.core.objective import sq_dists, dp_means_objective, bp_means_objective
from repro.core.dp_means import (
    DPMeansResult, DPMeansTransaction, serial_dp_means, serial_dp_means_pass,
    occ_dp_means, thm31_permutation,
)
from repro.core.ofl import (
    OFLResult, OFLTransaction, serial_ofl, occ_ofl, point_uniforms,
)
from repro.core.bp_means import (
    BPMeansResult, BPMeansTransaction, serial_bp_means, serial_bp_means_pass,
    occ_bp_means, coordinate_pass,
)

"""The paper's contribution: OCC pattern + DP-means / OFL / BP-means."""
from repro.core.occ import (
    CenterPool, OCCStats, make_pool, nearest_center, serial_validate,
    gather_validate,
)
from repro.core.objective import sq_dists, dp_means_objective, bp_means_objective
from repro.core.dp_means import (
    DPMeansResult, serial_dp_means, serial_dp_means_pass, occ_dp_means,
    thm31_permutation,
)
from repro.core.ofl import OFLResult, serial_ofl, occ_ofl, point_uniforms
from repro.core.bp_means import (
    BPMeansResult, serial_bp_means, serial_bp_means_pass, occ_bp_means,
    coordinate_pass,
)

"""repro: OCC for Distributed Unsupervised Learning (NIPS 2013) as a
multi-pod JAX training/serving framework.  See README.md / DESIGN.md."""
__version__ = "0.1.0"

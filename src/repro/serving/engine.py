"""Batched serving engine (continuous-batching-lite).

A fixed pool of B slots shares one stacked KV cache.  Requests claim free
slots, prefill writes their KV into the slot (per-slot positions), and one
jitted decode_step advances every active slot per tick; finished slots are
recycled without disturbing neighbors.  This is the slot-based design of
production engines, scoped to aligned prefill (no chunked-prefill queue).
"""
from __future__ import annotations

from dataclasses import dataclass, field
from functools import partial
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

__all__ = ["ServeEngine", "Request"]


@dataclass
class Request:
    uid: int
    prompt: np.ndarray            # (S,) int32
    max_new: int = 32
    out: list[int] = field(default_factory=list)
    slot: int = -1
    done: bool = False


class ServeEngine:
    def __init__(self, model, params, n_slots: int = 4, cache_len: int = 512,
                 decode_mode: str = "tp", greedy: bool = True):
        self.model = model
        self.params = params
        self.n_slots = n_slots
        self.cache_len = cache_len
        self.greedy = greedy
        self.caches = model.init_cache(n_slots, cache_len)
        self.pos = np.zeros((n_slots,), np.int32)
        self.active: list[Request | None] = [None] * n_slots
        self.last_tok = np.zeros((n_slots,), np.int32)
        self._decode = jax.jit(partial(model.decode_step,
                                       decode_mode=decode_mode))

    # ---------------------------------------------------------------- intake
    def _free_slots(self) -> list[int]:
        return [i for i, r in enumerate(self.active) if r is None]

    def submit(self, req: Request) -> bool:
        slots = self._free_slots()
        if not slots:
            return False
        slot = slots[0]
        req.slot = slot
        self.active[slot] = req
        self._prefill_into_slot(req)
        return True

    def _prefill_into_slot(self, req: Request):
        """Token-by-token prefill through decode_step on the slot's lane.

        (Aligned batch prefill via model.prefill is used by launch/serve.py
        when a whole batch starts together; the per-slot path keeps slot
        recycling simple and reuses the same jitted step.)
        """
        toks = req.prompt.astype(np.int32)
        for t, tok in enumerate(toks):
            tok_b = np.zeros((self.n_slots, 1), np.int32)
            tok_b[req.slot, 0] = tok
            pos_b = self.pos.copy()
            logits, self.caches = self._decode(
                self.params, self.caches, jnp.asarray(tok_b), jnp.asarray(pos_b))
            self.pos[req.slot] += 1
        self.last_tok[req.slot] = int(jnp.argmax(logits[req.slot]))

    # ----------------------------------------------------------------- ticks
    def step(self) -> list[Request]:
        """One decode tick across all active slots; returns finished reqs."""
        if not any(r is not None for r in self.active):
            return []
        tok_b = self.last_tok.reshape(-1, 1)
        logits, self.caches = self._decode(
            self.params, self.caches, jnp.asarray(tok_b),
            jnp.asarray(self.pos))
        nxt = np.asarray(jnp.argmax(logits, axis=-1), np.int32)
        finished = []
        for i, req in enumerate(self.active):
            if req is None:
                continue
            req.out.append(int(tok_b[i, 0]))
            self.pos[i] += 1
            self.last_tok[i] = nxt[i]
            if len(req.out) >= req.max_new or self.pos[i] >= self.cache_len - 1:
                req.done = True
                finished.append(req)
                self.active[i] = None
                self.pos[i] = 0      # recycle slot
        return finished

    def run(self, requests: list[Request]) -> list[Request]:
        """Drive a request list to completion with slot recycling."""
        pending = list(requests)
        done: list[Request] = []
        while pending or any(r is not None for r in self.active):
            while pending and self._free_slots():
                self.submit(pending.pop(0))
            done.extend(self.step())
        return done

"""Batched cluster-assignment service over published snapshots (§10/§12).

The read-only data plane of the train/serve split: a `ClusterService`
answers `assign` / `score` / `topk` queries against the newest
`ModelSnapshot` in a `SnapshotStore`, while the OCC trainer keeps
publishing new versions.

Microbatching & jit-cache policy:
  * Each public call is ONE microbatch and ONE jitted dispatch.  Ragged
    request sizes are padded up to a power-of-two bucket
    (`min_bucket..max_bucket`), so the jit cache is keyed on a handful of
    (request bucket, snapshot capacity bucket) pairs and stays warm under
    arbitrary traffic — a new model *version* never retraces (same shapes),
    only a new capacity bucket does.
  * Padding rows are masked with the query-prefix count (`n_valid`) inside
    the kernel dispatch (`kernels/ops.serve_assign`) — they return (inf,
    -1) and are sliced off before the response, so they can never alias a
    real answer.

Admission queue (DESIGN.md §12, QoS rebuilt in §17): `coalesce=True`
puts small requests through an admission queue that merges CONCURRENT
requests into one fuller microbatch — the CYCLADES move of batching
conflict-free work into fuller units, applied to the serving plane: the
ONE-dispatch-per-microbatch invariant then amortizes across requests
(and across tenants, via the router) instead of padding each tiny
request up to its own bucket.  Requests queue per (kind, k, lane) with
INDEPENDENT deadline timers; flush policy per group is deadline-or-full
(a group dispatches the moment its rows would fill `coalesce_bucket`,
or when its earliest per-request deadline expires — a stalled or absent
partner can never hold a request past its latency budget, and a long
batch deadline can never delay an interactive flush).  The lane
scheduler (`serving/qos.py`) lets `interactive` preempt
`batch`/`analytics` at flush-scheduling time with a starvation-proof
aging credit; under measured overload (queue depth or deadline-miss
rate past `ServeConfig` thresholds) sheddable queries (`max_staleness
> 0`, non-interactive lanes) degrade to a stale pinned snapshot instead
of queueing.  Every request in a group is answered from the ONE
snapshot pinned at flush time and tagged with its version (and
group/offset) — and every degraded response is tagged with the stale
version it was served from plus a `degraded` flag — so responses ALWAYS
replay bit-exactly from their tagged version; requests larger than the
coalesce bucket bypass the queue onto the solo path unchanged.

The typed request surface is `submit(Query(...))`; `assign`/`score`/
`topk` are thin shims constructing a `Query` with defaults (verified
bit-identical to the historical call forms in tests/test_serving.py).

Hot-swap semantics: the service re-reads `store.latest()` exactly once per
microbatch; the whole microbatch is computed against that one immutable
snapshot and the response is tagged with its version.  Swapping is a single
reference read — no locks on the query path, no torn reads (immutability
contract, serving/snapshot.py), and versions observed by any single client
are monotone because the store's versions are (a client's next request can
only be flushed after its previous one resolved).

Sharding (optional `mesh`): snapshots are placed replicated
(`shardings.serve_snapshot_sharding`) and query rows are sharded over the
data axis (`serve_query_sharding`) — read-only data parallelism with zero
center-side collectives.
"""
from __future__ import annotations

import functools
import threading
from typing import Any, NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels import ops as _kops
from repro.kernels.topk_stream import topk_tile_loads
from repro.obs import Obs
from repro.obs.metrics import now as _now
from repro.serving import qos
from repro.serving.qos import Query, ServeConfig
from repro.serving.snapshot import ModelSnapshot, SnapshotStore, next_bucket

__all__ = ["ClusterService", "ServeResponse", "DispatchRecord", "Query",
           "ServeConfig"]


class ServeResponse(NamedTuple):
    """One request's answer, tagged with everything needed to replay it."""
    version: int            # ModelSnapshot.version used for every row
    labels: np.ndarray      # (B,) int32 — assigned center / (B, k) for topk
    scores: np.ndarray | None   # (B,) squared distance / (B, k) for topk
    bucket: int             # padded microbatch size actually dispatched
    model: str | None = None    # owning model (set when served via a router)
    group: int = -1         # coalesced dispatch id (-1: solo dispatch)
    offset: int = 0         # this request's first row within the dispatch
    degraded: bool = False  # served from the stale shed pin under overload
    #                         (version tags the PIN — replay still bit-exact)


class DispatchRecord(NamedTuple):
    """Audit-log entry: one jitted dispatch, exactly as it ran.  Replaying
    `x` (same padded shape, same rows) through the service's own jitted
    step against the version-`version` snapshot must reproduce every
    member response bit-exactly — the zero-stale-read proof for coalesced
    and solo dispatches alike."""
    group: int
    version: int
    kind: str               # "score" | "topk"
    k: int                  # top-k width (0 for score)
    bucket: int
    n_valid: int
    x: np.ndarray           # (bucket, D) — the exact padded dispatch input
    spans: tuple[tuple[int, int], ...]   # member request row ranges
    probes: int = 0         # coarse cells probed per query (0: flat dispatch
    #                         — replay through _topk_step; >0: hierarchical
    #                         multi-probe — replay through _mp_topk_step)
    degraded: bool = False  # shed-path dispatch against the stale pin;
    #                         `version` is the pin's — replay is identical


# Trace counter: incremented only when a query step is (re)compiled.  Lets
# tests assert hot-swapping versions does NOT retrace (warm-cache contract)
# and that equal-shape tenants share one compilation (router contract).
_QUERY_TRACES = 0


def _constrained(centers, mask, xq, mesh, data_axis):
    if mesh is None:
        return centers, mask, xq
    from repro.distributed.shardings import (
        serve_query_sharding, serve_snapshot_sharding,
    )
    cons = jax.lax.with_sharding_constraint
    centers = cons(centers, serve_snapshot_sharding(mesh, centers.ndim))
    mask = cons(mask, serve_snapshot_sharding(mesh, mask.ndim))
    xq = cons(xq, serve_query_sharding(mesh, data_axis, xq.shape[0], xq.ndim))
    return centers, mask, xq


@functools.partial(jax.jit, static_argnames=("backend", "mesh", "data_axis"))
def _assign_step(centers, mask, count, xq, n_valid, *, backend,
                 mesh=None, data_axis="data"):
    """THE jitted query step: one dispatch per microbatch, cache-keyed on
    (bucket, capacity, backend) — never on the version, and never on the
    MODEL: the cache is module-level, so router tenants with equal
    capacity buckets share compilations."""
    global _QUERY_TRACES
    _QUERY_TRACES += 1
    centers, mask, xq = _constrained(centers, mask, xq, mesh, data_axis)
    return _kops.serve_assign(xq, centers, mask, count=count,
                              n_valid=n_valid, backend=backend)


@functools.partial(jax.jit, static_argnames=("k", "backend", "mesh",
                                             "data_axis"))
def _topk_step(centers, mask, count, xq, n_valid, *, k, backend,
               mesh=None, data_axis="data"):
    global _QUERY_TRACES
    _QUERY_TRACES += 1
    centers, mask, xq = _constrained(centers, mask, xq, mesh, data_axis)
    return _kops.serve_topk(xq, centers, k, mask=mask, count=count,
                            n_valid=n_valid, backend=backend)


@functools.partial(jax.jit, static_argnames=("k", "p", "u_cap", "backend"))
def _mp_topk_step(coarse, coarse_mask, fine, fine_ids, fine_mask, xq,
                  n_valid, *, k, p, u_cap, backend):
    """Hierarchical multi-probe top-k: route each query to its p nearest
    coarse cells, take the microbatch's probed-cell UNION, and stream only
    those fine shards (`kernels/ops.serve_topk_multiprobe` — on the Pallas
    path the gather lives in the BlockSpec index map, so unprobed shards
    never leave HBM).  One jitted dispatch, cache-keyed on (bucket,
    hier shape, k, p, u_cap, backend) — never on the version.

    `u_cap` bounds the union statically (min(n_cells, pow2(bucket*p)) at
    the call site, so it can never truncate a real union).  Returns
    (d2, idx, n_probed) with idx ORIGINAL flat indices; padded query rows
    route nowhere (their coarse probes are -1 under `n_valid`) and come
    back (inf, -1) like every other backend.
    """
    global _QUERY_TRACES
    _QUERY_TRACES += 1
    b = xq.shape[0]
    n_cells = coarse.shape[0]
    # Route: p nearest coarse cells per query (same selection kernel, so
    # routing inherits the deterministic (d2, id) tie order).
    _, cells_q = _kops.serve_topk(xq, coarse, p, mask=coarse_mask,
                                  n_valid=n_valid, backend=backend)
    ok = cells_q >= 0
    safe = jnp.where(ok, cells_q, n_cells)
    # Microbatch union of probed cells, packed ascending with -1 padding
    # (the layout serve_topk_multiprobe's clamped index map expects).
    memb = jnp.zeros((n_cells,), bool).at[safe].set(True, mode="drop")
    union = jnp.nonzero(memb, size=u_cap, fill_value=-1)[0].astype(jnp.int32)
    n_probed = jnp.sum(union >= 0).astype(jnp.int32)
    # Per-query membership over union slots: scatter probes into a one-hot
    # row (trash column n_cells absorbs invalid probes), gather at union.
    onehot = jnp.zeros((b, n_cells + 1), bool).at[
        jnp.arange(b)[:, None], safe].set(True)
    member = (onehot[:, jnp.where(union >= 0, union, n_cells)]
              & (union >= 0)[None, :])
    d2, idx = _kops.serve_topk_multiprobe(
        xq, fine, fine_ids, fine_mask, union, member, k,
        u_count=n_probed, n_valid=n_valid, backend=backend)
    return d2, idx, n_probed


class _Pending:
    """One admitted request waiting for its lane's coalesced dispatch."""
    __slots__ = ("x", "query", "lane", "t", "deadline_t", "event", "out",
                 "err")

    def __init__(self, x, query: Query, lane: str, deadline_s: float):
        self.x, self.query, self.lane = x, query, lane
        self.t = _now()
        self.deadline_t = self.t + deadline_s
        self.event = threading.Event()
        self.out = self.err = None


class _AdmissionQueue:
    """Per-(kind, k, lane) request queues under one lane scheduler.

    Requests are admitted FIFO into their (kind, k, lane) group; each
    group carries its OWN deadline (earliest per-request deadline, where
    a request's deadline is its `Query.deadline_ms` or its lane's
    configured budget).  One scheduler thread runs the pure policy from
    `serving/qos.py`: `select_flush` picks the group to dispatch (ready
    = full-or-deadline; interactive preempts batch/analytics; aging
    credits bound starvation) and `next_deadline` bounds the wait, so a
    long batch deadline can never delay an interactive flush.  With
    `priority_lanes=False` the legacy single-queue policy
    (`select_flush_fifo`: only the group holding the globally oldest
    request may flush) runs instead — the measurable FIFO baseline for
    the QoS A/B in launch/serve_clusters.

    Close semantics (the PR-10 race fix): `close()` marks the queue
    closed and the scheduler FLUSHES every already-admitted request
    before exiting — pending work is dispatched, never dropped.  A
    submit racing with close either lands in a flushed group or fails
    fast with "service closed"; none can hang or lose its answer.
    """

    def __init__(self, service: "ClusterService", bucket: int,
                 cfg: ServeConfig):
        self._svc = service
        self._cfg = cfg
        self.bucket = bucket
        self._groups: dict[tuple, list[_Pending]] = {}
        self._credits: dict[tuple, int] = {}
        self._cond = threading.Condition()
        self._stop = False
        self._thread = threading.Thread(
            target=self._loop, daemon=True,
            name=f"admission-{service.name or id(service)}")
        self._thread.start()

    def submit(self, x, query: Query, lane: str,
               timeout_s: float = 60.0) -> ServeResponse:
        deadline_s = (query.deadline_ms / 1e3
                      if query.deadline_ms is not None
                      else self._cfg.lane_delay_s(lane))
        item = _Pending(x, query, lane, deadline_s)
        key = (query.kind, query.k, lane)
        with self._cond:
            if self._stop:
                raise RuntimeError("service closed")
            self._groups.setdefault(key, []).append(item)
            self._svc._lane_depth(lane).add(x.shape[0])
            self._cond.notify_all()
        if not item.event.wait(timeout_s):
            raise RuntimeError("admission queue flush timed out")
        if item.err is not None:
            raise item.err
        return item.out

    def depth_rows(self) -> int:
        """Total queued rows across every group — the shed-policy input."""
        with self._cond:
            return sum(it.x.shape[0] for g in self._groups.values()
                       for it in g)

    def close(self) -> None:
        with self._cond:
            self._stop = True
            self._cond.notify_all()
        self._thread.join(timeout=10)

    # ---------------------------------------------------------- scheduler
    def _states(self) -> list[qos.LaneState]:
        return [qos.LaneState(key, key[2],
                              sum(it.x.shape[0] for it in g),
                              g[0].t, min(it.deadline_t for it in g))
                for key, g in self._groups.items() if g]

    def _drain_locked(self, key: tuple) -> list[_Pending]:
        """Longest FIFO prefix of the group that fits the bucket."""
        group = self._groups[key]
        take, total = [], 0
        while group:
            nxt = group[0].x.shape[0]
            if take and total + nxt > self.bucket:
                break          # never overshoot the bucket once non-empty
            take.append(group.pop(0))
            total += nxt
        if not group:
            del self._groups[key]
        self._svc._lane_depth(key[2]).add(-total)
        return take

    def _loop(self) -> None:
        while True:
            with self._cond:
                while True:
                    states = self._states()
                    now_t = _now()
                    if self._cfg.priority_lanes:
                        pick = qos.select_flush(
                            states, now_t, self._credits, self.bucket,
                            self._cfg.aging_limit)
                    else:
                        pick = qos.select_flush_fifo(states, now_t,
                                                     self.bucket)
                    if pick is None and self._stop and states:
                        # Closing: nothing is due yet, but everything
                        # already admitted must still be DISPATCHED
                        # (flush-not-drop) — drain earliest-deadline
                        # first until the queues are empty.
                        key = min(states, key=lambda s: s.deadline_t).key
                        pick = qos.FlushDecision(key, "close", ())
                    if pick is not None:
                        for k in pick.passed_over:
                            self._credits[k] = self._credits.get(k, 0) + 1
                        self._credits.pop(pick.key, None)
                        batch = self._drain_locked(pick.key)
                        break
                    if self._stop:
                        return
                    wake = qos.next_deadline(states)
                    self._cond.wait(None if wake is None
                                    else max(0.0, wake - now_t))
            try:
                self._svc._flush_group(batch, lane=pick.key[2],
                                       reason=pick.reason)
            except Exception as e:        # propagate to every waiter
                for it in batch:
                    it.err = e
                    it.event.set()


class ClusterService:
    """Serves batched assignment queries from a SnapshotStore.

    Construction: `ClusterService(store, config)` where `config` is a
    `ServeConfig` (see serving/qos.py for every knob's meaning) — or the
    historical keyword form `ClusterService(store, backend=...,
    coalesce=...)`: any ServeConfig field passed as a keyword is
    `replace`d into the config, so every pre-§17 call site still works
    unchanged.  Runtime objects stay out of the config:

      store: the `SnapshotStore` the trainer publishes into.
      name: model tag stamped on responses (set by the router).
      mesh / data_axis: optional device mesh for replicated-snapshot /
        sharded-query serving.
      obs: optional shared `repro.obs.Obs`; counters/histograms land in
        its registry (labeled by model) and query dispatches become trace
        spans when a tracer is attached.
      shed_signal: optional zero-arg callable returning an external
        overload score; the shed decision uses max(own score, signal).
        The router wires a fleet-wide queue-depth signal through this so
        one tenant's backlog can start shedding a co-located tenant's
        sheddable traffic before the shared process melts.

    Request surface: `submit(Query(...))` is THE entrypoint;
    `assign`/`score`/`topk` are shims constructing the equivalent Query
    (bit-identical responses — pinned by tests/test_serving.py).  The
    multi-probe exactness knob (`config.probes`, DESIGN.md §16): None
    serves top-k flat; int p routes each query to its p nearest coarse
    cells over the snapshot's hierarchical layout (requires
    `SnapshotStore(hier=True)`), p >= n_cells IS the flat step;
    `config.recall_audit_every` > 0 runs the paid-for recall@k spot
    check every Nth multi-probe dispatch.  `config.audit_log` retains a
    `DispatchRecord` per jitted dispatch (exact padded inputs + member
    spans) so every response — including degraded shed-path responses —
    replays bit-exactly from its tagged version.  Unbounded growth:
    enable for audits/tests, not steady production.
    """

    def __init__(self, store: SnapshotStore,
                 config: ServeConfig | None = None, *,
                 name: str | None = None,
                 mesh: jax.sharding.Mesh | None = None,
                 data_axis: str = "data",
                 obs: Obs | None = None,
                 shed_signal=None,
                 **overrides):
        if config is None:
            config = ServeConfig()
        if overrides:
            config = config.replace(**overrides)
        assert config.probes is None or mesh is None, \
            "multi-probe serving is not supported with a mesh yet"
        self.config = config
        self.store = store
        self.probes = config.probes
        self.recall_audit_every = config.recall_audit_every
        self.backend = config.backend
        self.min_bucket = config.min_bucket
        self.max_bucket = config.max_bucket
        self.name = name
        self.coalesce_bucket = min(config.coalesce_bucket, config.max_bucket)
        self.mesh = mesh
        self.data_axis = data_axis
        self._shed_signal = shed_signal
        # Observability (§15): one dispatch per microbatch is the
        # contract.  Scalar counters live in the obs registry — each
        # counter's own lock makes flusher-thread vs request-thread
        # increments atomic (the old ad-hoc ints required every call site
        # to remember _mlock; the registry makes lost updates impossible).
        # serve_dispatches is bumped at every jitted-step CALL SITE (not
        # alongside serve_microbatches) so the ratio actually measures the
        # contract; _traces0 anchors the process-wide compile counter.
        # _mlock still guards the non-scalar tallies (bucket/version
        # histograms, group ids, current version).
        self.obs = obs if obs is not None else Obs()
        mlab = dict(model=name or "")
        m = self.obs.metrics
        self._c_queries = m.counter("serve_queries", **mlab)
        self._c_requests = m.counter("serve_requests", **mlab)
        self._c_microbatches = m.counter("serve_microbatches", **mlab)
        self._c_dispatches = m.counter("serve_dispatches", **mlab)
        self._c_padded = m.counter("serve_padded_rows", **mlab)
        self._c_groups = m.counter("serve_coalesced_groups", **mlab)
        self._c_group_requests = m.counter("serve_group_requests", **mlab)
        self._c_flush_deadline = m.counter("serve_flushes", reason="deadline",
                                           **mlab)
        self._c_flush_full = m.counter("serve_flushes", reason="full", **mlab)
        self._c_swaps = m.counter("serve_swaps", **mlab)
        self._c_compiles = m.counter("serve_jit_compiles", **mlab)
        # Top-k DMA accounting (§16): per dispatch, how many fine shards
        # (multi-probe) / center tiles (flat) the kernel schedule streams
        # vs skips.  Counted from the SAME clamp arithmetic the kernel's
        # index maps use (`topk_tile_loads`), so the counters are the
        # schedule's ground truth on every backend, not a Pallas-only
        # readback.  The recall gauge is last-audit recall@k (see
        # `recall_audit_every`); 0 until a first audit runs.
        self._c_topk_mp = m.counter("serve_topk_multiprobe_dispatches",
                                    **mlab)
        self._c_shards_probed = m.counter("serve_topk_shards_probed", **mlab)
        self._c_tiles_skipped = m.counter("serve_topk_tiles_skipped", **mlab)
        self._c_recall_audits = m.counter("serve_topk_recall_audits", **mlab)
        self._g_recall = m.gauge("serve_topk_recall", **mlab)
        self._n_topk_dispatches = 0     # audit cadence (guarded by _mlock)
        self._h_queue_wait = m.histogram("serve_queue_wait_s", **mlab)
        self._h_dispatch = m.histogram("serve_dispatch_s", **mlab)
        self._h_request = m.histogram("serve_request_s", **mlab)
        # QoS families (§17): per-lane queue depth (rows currently
        # admitted), per-(lane, reason) flush counts from the lane
        # scheduler, shed counts, the deadline-miss EWMA (a flush landing
        # more than one lane budget late), and the derived overload gauge
        # (`qos.overload_score` — 1.0 = shedding starts).
        self._g_depth = {lane: m.gauge("serve_lane_depth", lane=lane, **mlab)
                         for lane in qos.LANES}
        self._c_shed = {lane: m.counter("serve_shed", lane=lane, **mlab)
                        for lane in qos.LANES}
        self._c_lane_flush: dict[tuple[str, str], Any] = {}
        self._e_miss = m.ewma("serve_deadline_miss_rate", **mlab)
        self._g_overload = m.gauge("serve_overload_score", **mlab)
        self._mlab = mlab
        self._traces0 = _QUERY_TRACES
        self.bucket_hist: dict[int, int] = {}
        self.version_hist: dict[int, int] = {}
        self._cur_version: int | None = None
        self._mlock = threading.Lock()
        self._next_group = 0
        self._shed_pin: ModelSnapshot | None = None   # guarded by _mlock
        self.audit: list[DispatchRecord] | None = (
            [] if config.audit_log else None)
        self._queue = (_AdmissionQueue(self, self.coalesce_bucket, config)
                       if config.coalesce else None)

    def _lane_depth(self, lane: str):
        return self._g_depth[lane]

    def _lane_flush_counter(self, lane: str, reason: str):
        c = self._c_lane_flush.get((lane, reason))
        if c is None:
            c = self._c_lane_flush[(lane, reason)] = self.obs.metrics.counter(
                "serve_lane_flushes", lane=lane, reason=reason, **self._mlab)
        return c

    # ---------------------------------------------- legacy counter surface
    @property
    def n_queries(self) -> int:
        return int(self._c_queries.value)

    @property
    def n_requests(self) -> int:
        return int(self._c_requests.value)

    @property
    def n_microbatches(self) -> int:
        return int(self._c_microbatches.value)

    @property
    def n_dispatches(self) -> int:
        return int(self._c_dispatches.value)

    @property
    def n_padded_rows(self) -> int:
        return int(self._c_padded.value)

    @property
    def n_groups(self) -> int:
        return int(self._c_groups.value)

    @property
    def n_group_requests(self) -> int:
        return int(self._c_group_requests.value)

    @property
    def n_deadline_flushes(self) -> int:
        return int(self._c_flush_deadline.value)

    @property
    def n_swaps(self) -> int:
        return int(self._c_swaps.value)

    # ------------------------------------------------------------ internals
    def _take_snapshot(self) -> ModelSnapshot:
        """The hot-swap point: one atomic ref read per microbatch."""
        snap = self.store.latest()
        if snap is None:
            raise RuntimeError("no model version published yet")
        with self._mlock:
            if snap.version != self._cur_version:
                if self._cur_version is not None:
                    self._c_swaps.inc()
                self._cur_version = snap.version
        return snap

    def _pad(self, x: jnp.ndarray) -> tuple[jnp.ndarray, int]:
        n = x.shape[0]
        bucket = next_bucket(n, self.min_bucket, self.max_bucket)
        if n < bucket:
            x = jnp.concatenate(
                [x, jnp.zeros((bucket - n,) + x.shape[1:], x.dtype)], 0)
        return x, bucket

    def _account(self, snap: ModelSnapshot, n: int, bucket: int) -> None:
        self._c_queries.inc(n)
        self._c_microbatches.inc()
        self._c_padded.inc(bucket)
        with self._mlock:
            self.bucket_hist[bucket] = self.bucket_hist.get(bucket, 0) + 1
            self.version_hist[snap.version] = (
                self.version_hist.get(snap.version, 0) + n)

    def _record(self, group, snap, kind, k, bucket, n, xp, spans,
                probes: int = 0, degraded: bool = False) -> None:
        if self.audit is not None:
            self.audit.append(DispatchRecord(
                group, snap.version, kind, k, bucket, n,
                np.asarray(xp), tuple(spans), probes, degraded))

    def _split(self, x) -> list[jnp.ndarray]:
        x = jnp.asarray(x)
        if x.ndim == 1:
            x = x[None, :]
        if x.shape[0] <= self.max_bucket:
            return [x]
        return [x[i:i + self.max_bucket]
                for i in range(0, x.shape[0], self.max_bucket)]

    def _mp_probes(self, snap) -> int:
        """Effective probe width for this dispatch: 0 = flat (probes off,
        or p >= n_cells — "probe everything" IS the flat step, which is
        what makes the p = all bit-identity a construction, not a test)."""
        if self.probes is None:
            return 0
        h = snap.hier
        if h is None:
            raise RuntimeError(
                "probes is set but the published snapshot has no "
                "hierarchical layout — publish via SnapshotStore(hier=True)")
        return 0 if self.probes >= h.n_cells else self.probes

    def _flat_topk(self, snap, xp, n, k):
        return _topk_step(
            snap.centers, snap.mask, np.int32(snap.count), xp,
            np.int32(n), k=k, backend=self.backend, mesh=self.mesh,
            data_axis=self.data_axis)

    def _audit_recall(self, snap, xp, n, k, idx) -> None:
        """Paid-for spot check: flat top-k on the SAME microbatch, recall@k
        of the multi-probe answer against it, published as a gauge."""
        _, flat_idx = self._flat_topk(snap, xp, n, k)
        approx = np.asarray(idx)[:n]
        exact = np.asarray(flat_idx)[:n]
        hits = tot = 0
        for a_row, e_row in zip(approx, exact):
            e = set(int(i) for i in e_row if i >= 0)
            if not e:
                continue
            a = set(int(i) for i in a_row if i >= 0)
            hits += len(a & e)
            tot += len(e)
        self._c_recall_audits.inc()
        self._g_recall.set(hits / tot if tot else 1.0)

    def _run_step(self, snap, xp, n, kind, k):
        """One jitted dispatch (the only two call sites of the steps).

        Top-k dispatches run under a `topk.dispatch` span and route to the
        multi-probe step when the exactness knob says so; the span +
        counters account probed shards vs skipped tiles from the kernel
        schedule's own clamp arithmetic (`topk_tile_loads`), on every
        backend.
        """
        traces0 = _QUERY_TRACES
        mp = self._mp_probes(snap) if kind == "topk" else 0
        n_probed = None
        span = ("topk.dispatch" if kind == "topk" else "serve.dispatch")
        t0 = _now()
        with self.obs.span(span, cat="serve", kind=kind,
                           bucket=int(xp.shape[0]), version=snap.version,
                           probes=mp):
            if mp:
                h = snap.hier
                u_cap = min(h.n_cells, next_bucket(xp.shape[0] * mp, 1))
                d2, idx, n_probed = _mp_topk_step(
                    h.coarse, h.coarse_mask, h.fine, h.fine_ids,
                    h.fine_mask, xp, np.int32(n), k=k, p=mp, u_cap=u_cap,
                    backend=self.backend)
            elif kind == "topk":
                d2, idx = self._flat_topk(snap, xp, n, k)
            else:
                d2, idx = _assign_step(
                    snap.centers, snap.mask, np.int32(snap.count), xp,
                    np.int32(n), backend=self.backend, mesh=self.mesh,
                    data_axis=self.data_axis)
        self._h_dispatch.observe(_now() - t0)
        self._c_dispatches.inc()
        if kind == "topk":
            if mp:
                probed = int(jax.device_get(n_probed))
                self._c_topk_mp.inc()
                self._c_shards_probed.inc(probed)
                self._c_tiles_skipped.inc(snap.hier.n_cells - probed)
            else:
                cap = snap.capacity
                bk = min(128, max(8, cap))
                k_tiles = (cap + bk - 1) // bk
                self._c_tiles_skipped.inc(
                    k_tiles - topk_tile_loads(int(snap.count), cap))
            with self._mlock:
                self._n_topk_dispatches += 1
                n_topk = self._n_topk_dispatches
            if (mp and self.recall_audit_every > 0
                    and n_topk % self.recall_audit_every == 0):
                self._audit_recall(snap, xp, n, k, idx)
        if _QUERY_TRACES != traces0:
            self._c_compiles.inc(_QUERY_TRACES - traces0)
        return d2, idx

    # ----------------------------------------------------------- coalescing
    def _flush_group(self, items: list[_Pending], lane: str = "interactive",
                     reason: str = "deadline") -> None:
        """Dispatch one coalesced group: ONE snapshot pin, ONE jitted step,
        per-request slices tagged (version, group, offset).  `reason` is
        the lane scheduler's verdict ("full" | "deadline" | "aged" |
        "close"); the legacy `serve_flushes` counters keep their
        historical fill-based split so pre-§17 dashboards read the same."""
        snap = self._take_snapshot()
        q0 = items[0].query
        kind, k = q0.kind, q0.k
        kk = min(k, snap.capacity) if kind == "topk" else 0
        x = (jnp.concatenate([it.x for it in items], 0)
             if len(items) > 1 else items[0].x)
        n = x.shape[0]
        t_flush = _now()
        grace = self.config.miss_grace_s(lane)
        missed = any(t_flush > it.deadline_t + grace for it in items)
        self._e_miss.observe(1.0 if missed else 0.0)
        for it in items:        # admission-to-flush wait per member request
            self._h_queue_wait.observe(t_flush - it.t)
        xp, bucket = self._pad(x)
        d2, idx = self._run_step(snap, xp, n, kind, kk)
        self._account(snap, n, bucket)
        self._c_groups.inc()
        self._c_group_requests.inc(len(items))
        self._c_requests.inc(len(items))
        deadline_flush = n < self.coalesce_bucket
        (self._c_flush_deadline if deadline_flush
         else self._c_flush_full).inc()
        self._lane_flush_counter(lane, reason).inc()
        self.obs.instant("serve.flush", cat="serve", reason=reason,
                         lane=lane, requests=len(items), rows=n)
        with self._mlock:
            gid = self._next_group
            self._next_group += 1
        spans, lo = [], 0
        for it in items:
            spans.append((lo, lo + it.x.shape[0]))
            lo += it.x.shape[0]
        self._record(gid, snap, kind, kk, bucket, n, xp, spans,
                     self._mp_probes(snap) if kind == "topk" else 0)
        labels, scores = np.asarray(idx), np.asarray(d2)
        for it, (lo, hi) in zip(items, spans):
            it.out = ServeResponse(
                snap.version, labels[lo:hi],
                scores[lo:hi] if it.query.want_scores else None, bucket,
                model=self.name, group=gid, offset=lo)
            it.event.set()

    def close(self) -> None:
        """Stop the admission queue (no-op for solo services).  Requests
        already admitted are FLUSHED on the way down, never dropped;
        submits racing past the stop flag fail fast with RuntimeError."""
        if self._queue is not None:
            self._queue.close()
            self._queue = None

    # ------------------------------------------------------------- shedding
    def _overload(self) -> float:
        """Current overload score; published as `serve_overload_score`."""
        rows = self._queue.depth_rows() if self._queue is not None else 0
        score = qos.overload_score(rows, self.config.shed_depth,
                                   self._e_miss.value,
                                   self.config.shed_miss_rate)
        if self._shed_signal is not None:
            score = max(score, float(self._shed_signal()))
        self._g_overload.set(score)
        return score

    def queue_depth_rows(self) -> int:
        """Rows currently queued for admission (0 for solo services) —
        the router's fleet-wide shed signal reads this per tenant."""
        return self._queue.depth_rows() if self._queue is not None else 0

    def _stale_pin(self, max_staleness: int) -> ModelSnapshot:
        """The graceful-degradation snapshot: pinned once and HELD while
        shedding (no per-shed latest() chase — a stable version keeps the
        jit cache warm and makes degraded replay deterministic), re-pinned
        only when it drifts past the caller's staleness tolerance or the
        store moved backwards (recovery truncation)."""
        latest = self.store.latest()
        if latest is None:
            raise RuntimeError("no model version published yet")
        with self._mlock:
            pin = self._shed_pin
            if (pin is None or pin.version > latest.version
                    or pin.version < latest.version - max_staleness):
                pin = self._shed_pin = latest
        return pin

    # -------------------------------------------------------------- queries
    def _solo(self, x, kind: str, k: int, snap: ModelSnapshot | None = None,
              degraded: bool = False) -> ServeResponse:
        """The solo path: this request is its own microbatch (split into
        max_bucket chunks when giant).  The snapshot is pinned ONCE for the
        whole request — even when it splits, every row is answered by the
        same version (the one in the tag); hot-swap is between requests.
        The shed path passes its stale pin (and degraded=True) explicitly;
        the record and response carry the flag so replay audits know the
        version tag is the pin's, not latest-at-dispatch."""
        if snap is None:
            snap = self._take_snapshot()
        kk = min(k, snap.capacity) if kind == "topk" else 0
        parts_l, parts_s, bucket = [], [], 0
        for xc in self._split(x):
            n = xc.shape[0]
            xp, bucket = self._pad(xc)
            d2, idx = self._run_step(snap, xp, n, kind, kk)
            self._account(snap, n, bucket)
            self._record(-1, snap, kind, kk, bucket, n, xp, [(0, n)],
                         self._mp_probes(snap) if kind == "topk" else 0,
                         degraded)
            parts_l.append(np.asarray(idx[:n]))
            parts_s.append(np.asarray(d2[:n]))
        self._c_requests.inc()
        return ServeResponse(snap.version, np.concatenate(parts_l),
                             np.concatenate(parts_s), bucket,
                             model=self.name, degraded=degraded)

    def submit(self, query: Query) -> ServeResponse:
        """THE serving entrypoint: every request — typed or via the
        `assign`/`score`/`topk` shims — lands here.

        Routing: requests of <= coalesce_bucket rows go through the
        admission queue in their priority lane; under measured overload
        sheddable requests (non-interactive lane, max_staleness > 0)
        skip the queue and are answered solo from the stale shed pin
        with `degraded=True`; oversized requests take the solo path."""
        t0 = _now()
        x = jnp.asarray(query.x)
        if x.ndim == 1:
            x = x[None, :]
        if self._queue is not None and x.shape[0] <= self.coalesce_bucket:
            lane = qos.effective_lane(query.priority,
                                      self.config.priority_lanes)
            if qos.should_shed(lane, query.max_staleness, self._overload()):
                self._c_shed[lane].inc()
                resp = self._solo(x, query.kind, query.k,
                                  snap=self._stale_pin(query.max_staleness),
                                  degraded=True)
            else:
                resp = self._queue.submit(x, query, lane)
        else:
            resp = self._solo(x, query.kind, query.k)
        if not query.want_scores and resp.scores is not None:
            resp = resp._replace(scores=None)
        self._h_request.observe(_now() - t0)
        return resp

    def score(self, x) -> ServeResponse:
        """Nearest-center label AND squared distance per query row."""
        return self.submit(Query(x))

    def assign(self, x) -> ServeResponse:
        """Nearest-center label per query row (scores omitted)."""
        return self.submit(Query(x, want_scores=False))

    def topk(self, x, k: int = 4) -> ServeResponse:
        """k nearest centers per query row, distances ascending."""
        return self.submit(Query(x, kind="topk", k=k))

    # -------------------------------------------------------------- metrics
    def metrics(self) -> dict[str, Any]:
        meta = self.store.latest_meta()
        return {
            "model": self.name,
            "n_queries": self.n_queries,
            "n_requests": self.n_requests,
            "n_microbatches": self.n_microbatches,
            "n_dispatches": self.n_dispatches,
            "dispatches_per_microbatch":
                self.n_dispatches / max(1, self.n_microbatches),
            # admission-queue effectiveness: valid rows per padded row
            # dispatched — coalescing exists to push this toward 1.0.
            "bucket_fill_ratio": self.n_queries / max(1, self.n_padded_rows),
            "n_coalesced_groups": self.n_groups,
            "n_deadline_flushes": self.n_deadline_flushes,
            "requests_per_group":
                self.n_group_requests / max(1, self.n_groups),
            "n_swaps": self.n_swaps,
            # QoS (§17): lane-scheduler + shed-policy readouts.  The
            # overload gauge holds the score at the LAST admission
            # decision; lane flush counts are keyed "lane/reason" from
            # the scheduler's verdicts; shed counts are degraded-path
            # responses per lane (always 0 for interactive).
            "overload_score": self._g_overload.value,
            "deadline_miss_rate": self._e_miss.value,
            "lane_depth_rows": {lane: int(g.value)
                                for lane, g in self._g_depth.items()},
            "lane_flushes": {f"{lane}/{reason}": int(c.value)
                             for (lane, reason), c
                             in sorted(dict(self._c_lane_flush).items())},
            "n_shed": {lane: int(c.value)
                       for lane, c in self._c_shed.items()},
            # registry-backed latency readouts (§15): total request wall
            # time and admission-queue wait, per this service's labels.
            "request_p50_ms": 1e3 * self._h_request.percentile(50)
                if self._h_request.count else 0.0,
            "request_p99_ms": 1e3 * self._h_request.percentile(99)
                if self._h_request.count else 0.0,
            "queue_wait_p99_ms": 1e3 * self._h_queue_wait.percentile(99)
                if self._h_queue_wait.count else 0.0,
            # query-step compilations since this service was built
            # (process-wide counter: exact when one service is live;
            # router tenants with equal shapes share compilations, which
            # is what the router-level counter proves).
            "query_step_compiles": _QUERY_TRACES - self._traces0,
            # multi-probe top-k accounting (§16): probed-shard / skipped-
            # tile totals from the kernel schedule's clamp arithmetic, and
            # the exactness knob's last audited recall@k (1.0 means the
            # audit saw no loss; the gauge is 0 until a first audit runs).
            "topk_probes": self.probes,
            "n_topk_multiprobe": int(self._c_topk_mp.value),
            "topk_shards_probed": int(self._c_shards_probed.value),
            "topk_tiles_skipped": int(self._c_tiles_skipped.value),
            "topk_recall_audits": int(self._c_recall_audits.value),
            "topk_recall": self._g_recall.value,
            "versions_served": sorted(self.version_hist),
            "bucket_hist": dict(sorted(self.bucket_hist.items())),
            # training-side observability surfaced at the serving endpoint:
            # the adaptive-cap estimator and per-epoch cap trace of the
            # newest published version (DESIGN.md §11 — closes the
            # ROADMAP observability loop; no dense materialization).
            "latest_version": None if meta is None else meta.version,
            "cap_est": None if meta is None else meta.cap_est,
            "cap_trace": None if meta is None else meta.cap_trace,
        }

"""Batched cluster-assignment service over published snapshots (DESIGN.md §10).

The read-only data plane of the train/serve split: a `ClusterService`
answers `assign` / `score` / `topk` queries against the newest
`ModelSnapshot` in a `SnapshotStore`, while the OCC trainer keeps
publishing new versions.

Microbatching & jit-cache policy:
  * Each public call is ONE microbatch and ONE jitted dispatch.  Ragged
    request sizes are padded up to a power-of-two bucket
    (`min_bucket..max_bucket`), so the jit cache is keyed on a handful of
    (request bucket, snapshot capacity bucket) pairs and stays warm under
    arbitrary traffic — a new model *version* never retraces (same shapes),
    only a new capacity bucket does.
  * Padding rows are masked with the query-prefix count (`n_valid`) inside
    the kernel dispatch (`kernels/ops.serve_assign`) — they return (inf,
    -1) and are sliced off before the response, so they can never alias a
    real answer.

Hot-swap semantics: the service re-reads `store.latest()` exactly once per
microbatch; the whole microbatch is computed against that one immutable
snapshot and the response is tagged with its version.  Swapping is a single
reference read — no locks on the query path, no torn reads (immutability
contract, serving/snapshot.py), and versions observed by any single client
are monotone because the store's versions are.

Sharding (optional `mesh`): snapshots are placed replicated
(`shardings.serve_snapshot_sharding`) and query rows are sharded over the
data axis (`serve_query_sharding`) — read-only data parallelism with zero
center-side collectives.
"""
from __future__ import annotations

import functools
from typing import Any, NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels import ops as _kops
from repro.serving.snapshot import ModelSnapshot, SnapshotStore, next_bucket

__all__ = ["ClusterService", "ServeResponse"]


class ServeResponse(NamedTuple):
    """One microbatch's answer, tagged with the version that produced it."""
    version: int            # ModelSnapshot.version used for every row
    labels: np.ndarray      # (B,) int32 — assigned center / (B, k) for topk
    scores: np.ndarray | None   # (B,) squared distance / (B, k) for topk
    bucket: int             # padded microbatch size actually dispatched


# Trace counter: incremented only when a query step is (re)compiled.  Lets
# tests assert hot-swapping versions does NOT retrace (warm-cache contract).
_QUERY_TRACES = 0


def _constrained(centers, mask, xq, mesh, data_axis):
    if mesh is None:
        return centers, mask, xq
    from repro.distributed.shardings import (
        serve_query_sharding, serve_snapshot_sharding,
    )
    cons = jax.lax.with_sharding_constraint
    centers = cons(centers, serve_snapshot_sharding(mesh, centers.ndim))
    mask = cons(mask, serve_snapshot_sharding(mesh, mask.ndim))
    xq = cons(xq, serve_query_sharding(mesh, data_axis, xq.shape[0], xq.ndim))
    return centers, mask, xq


@functools.partial(jax.jit, static_argnames=("backend", "mesh", "data_axis"))
def _assign_step(centers, mask, count, xq, n_valid, *, backend,
                 mesh=None, data_axis="data"):
    """THE jitted query step: one dispatch per microbatch, cache-keyed on
    (bucket, capacity, backend) — never on the version."""
    global _QUERY_TRACES
    _QUERY_TRACES += 1
    centers, mask, xq = _constrained(centers, mask, xq, mesh, data_axis)
    return _kops.serve_assign(xq, centers, mask, count=count,
                              n_valid=n_valid, backend=backend)


@functools.partial(jax.jit, static_argnames=("k", "backend", "mesh",
                                             "data_axis"))
def _topk_step(centers, mask, count, xq, n_valid, *, k, backend,
               mesh=None, data_axis="data"):
    global _QUERY_TRACES
    _QUERY_TRACES += 1
    centers, mask, xq = _constrained(centers, mask, xq, mesh, data_axis)
    return _kops.serve_topk(xq, centers, k, mask=mask, count=count,
                            n_valid=n_valid, backend=backend)


class ClusterService:
    """Serves batched assignment queries from a SnapshotStore.

    Args:
      store: the `SnapshotStore` the trainer publishes into.
      backend: `kernels/ops` backend for the assignment kernel ("auto":
        Pallas on TPU, jnp reference elsewhere — the same dispatch, and
        hence the same numerics, as the engine's propose phase, which is
        what makes serve-vs-train bit-parity hold).
      min_bucket / max_bucket: power-of-two request bucket bounds; requests
        larger than max_bucket are split into max_bucket microbatches.
      mesh / data_axis: optional device mesh for replicated-snapshot /
        sharded-query serving.
    """

    def __init__(self, store: SnapshotStore, backend: str = "auto",
                 min_bucket: int = 8, max_bucket: int = 4096,
                 mesh: jax.sharding.Mesh | None = None,
                 data_axis: str = "data"):
        assert min_bucket & (min_bucket - 1) == 0, "min_bucket: power of two"
        assert max_bucket & (max_bucket - 1) == 0, "max_bucket: power of two"
        self.store = store
        self.backend = backend
        self.min_bucket = min_bucket
        self.max_bucket = max_bucket
        self.mesh = mesh
        self.data_axis = data_axis
        # observability: one dispatch per microbatch is the contract.
        # n_dispatches is incremented at every jitted-step CALL SITE (not
        # alongside n_microbatches) so the ratio actually measures the
        # contract; _traces0 anchors the process-wide compile counter.
        self.n_queries = 0
        self.n_microbatches = 0
        self.n_dispatches = 0
        self.n_swaps = 0
        self._traces0 = _QUERY_TRACES
        self.bucket_hist: dict[int, int] = {}
        self.version_hist: dict[int, int] = {}
        self._cur_version: int | None = None

    # ------------------------------------------------------------ internals
    def _take_snapshot(self) -> ModelSnapshot:
        """The hot-swap point: one atomic ref read per microbatch."""
        snap = self.store.latest()
        if snap is None:
            raise RuntimeError("no model version published yet")
        if snap.version != self._cur_version:
            if self._cur_version is not None:
                self.n_swaps += 1
            self._cur_version = snap.version
        return snap

    def _pad(self, x: jnp.ndarray) -> tuple[jnp.ndarray, int]:
        n = x.shape[0]
        bucket = next_bucket(n, self.min_bucket, self.max_bucket)
        if n < bucket:
            x = jnp.concatenate(
                [x, jnp.zeros((bucket - n,) + x.shape[1:], x.dtype)], 0)
        return x, bucket

    def _account(self, snap: ModelSnapshot, n: int, bucket: int) -> None:
        self.n_queries += n
        self.n_microbatches += 1
        self.bucket_hist[bucket] = self.bucket_hist.get(bucket, 0) + 1
        self.version_hist[snap.version] = (
            self.version_hist.get(snap.version, 0) + n)

    def _split(self, x) -> list[jnp.ndarray]:
        x = jnp.asarray(x)
        if x.ndim == 1:
            x = x[None, :]
        if x.shape[0] <= self.max_bucket:
            return [x]
        return [x[i:i + self.max_bucket]
                for i in range(0, x.shape[0], self.max_bucket)]

    # -------------------------------------------------------------- queries
    def score(self, x) -> ServeResponse:
        """Nearest-center label AND squared distance per query row.

        The snapshot is pinned ONCE for the whole request — even when a
        giant request splits into several max_bucket microbatches, every
        row is answered by the same version (the one in the tag); the
        hot-swap point is between requests.
        """
        snap = self._take_snapshot()
        parts_l, parts_s, bucket = [], [], 0
        for xc in self._split(x):
            n = xc.shape[0]
            xp, bucket = self._pad(xc)
            d2, idx = _assign_step(
                snap.centers, snap.mask, np.int32(snap.count), xp,
                np.int32(n), backend=self.backend, mesh=self.mesh,
                data_axis=self.data_axis)
            self.n_dispatches += 1
            self._account(snap, n, bucket)
            parts_l.append(np.asarray(idx[:n]))
            parts_s.append(np.asarray(d2[:n]))
        return ServeResponse(snap.version, np.concatenate(parts_l),
                             np.concatenate(parts_s), bucket)

    def assign(self, x) -> ServeResponse:
        """Nearest-center label per query row (scores omitted)."""
        return self.score(x)._replace(scores=None)

    def topk(self, x, k: int = 4) -> ServeResponse:
        """k nearest centers per query row, distances ascending."""
        snap = self._take_snapshot()
        parts_l, parts_s, bucket = [], [], 0
        for xc in self._split(x):
            n = xc.shape[0]
            xp, bucket = self._pad(xc)
            kk = min(k, snap.capacity)
            d2, idx = _topk_step(
                snap.centers, snap.mask, np.int32(snap.count), xp,
                np.int32(n), k=kk, backend=self.backend, mesh=self.mesh,
                data_axis=self.data_axis)
            self.n_dispatches += 1
            self._account(snap, n, bucket)
            parts_l.append(np.asarray(idx[:n]))
            parts_s.append(np.asarray(d2[:n]))
        return ServeResponse(snap.version, np.concatenate(parts_l),
                             np.concatenate(parts_s), bucket)

    # -------------------------------------------------------------- metrics
    def metrics(self) -> dict[str, Any]:
        return {
            "n_queries": self.n_queries,
            "n_microbatches": self.n_microbatches,
            "n_dispatches": self.n_dispatches,
            "dispatches_per_microbatch":
                self.n_dispatches / max(1, self.n_microbatches),
            "n_swaps": self.n_swaps,
            # query-step compilations since this service was built
            # (process-wide counter: exact when one service is live).
            # Bounded by the distinct (bucket, capacity) pairs — hot swaps
            # and steady traffic must not grow it.
            "query_step_compiles": _QUERY_TRACES - self._traces0,
            "versions_served": sorted(self.version_hist),
            "bucket_hist": dict(sorted(self.bucket_hist.items())),
        }

"""Mixed-traffic QoS for the serving plane: typed requests, priority
lanes, and load shedding as PURE logic (DESIGN.md §17).

The OCC premise — optimistically admit work, resolve conflicts only when
they materialize — applied to admission control: every request is
admitted optimistically into a per-(kind, k, lane) queue; the conflict
(an interactive deadline about to be eaten by a batch flush, an overload
about to blow every latency budget) is resolved at flush-scheduling
time by the lane scheduler and the shed policy below.  Everything here
is deliberately free of threads, clocks, and jax: the scheduler and the
shed policy are pure functions over explicit state, unit-testable
without a running service, and `cluster_service._AdmissionQueue` is a
thin threaded shell around them.

Three public surfaces:

* `Query` — the typed request: what used to be positional
  `assign(x)/score(x)/topk(x, k)` calls with no way to say "this is a
  batch analytics scan, it can be 3 versions stale, don't stall the
  interactive lane for it".  `kind`/`k` select the jit program,
  `priority` selects the lane, `deadline_ms` overrides the lane's
  coalesce deadline, `max_staleness` (versions behind latest) is the
  consistency point the caller can tolerate — 0 means "latest only,
  never shed".
* `ServeConfig` — ONE dataclass holding every service/router knob
  (backend, buckets, coalescing, probes, QoS thresholds), shared by
  `ClusterService`, `ModelRouter`, and the `launch/serve_clusters` CLI
  so the three construction surfaces cannot drift.
* the lane scheduler (`select_flush` / `next_deadline`) and shed policy
  (`overload_score` / `should_shed`) — see each docstring.
"""
from __future__ import annotations

import dataclasses
from typing import Any, NamedTuple

__all__ = [
    "LANES", "LANE_RANK", "Query", "ServeConfig", "LaneState",
    "FlushDecision", "select_flush", "select_flush_fifo", "next_deadline",
    "overload_score", "should_shed", "effective_lane",
]

#: Priority lanes, best first.  `interactive` preempts `batch` preempts
#: `analytics` at flush-scheduling time; the aging credit in
#: `select_flush` bounds how long preemption can defer a ready lane.
LANES = ("interactive", "batch", "analytics")
LANE_RANK = {lane: i for i, lane in enumerate(LANES)}

_KINDS = ("score", "topk")


@dataclasses.dataclass(frozen=True, eq=False)
class Query:
    """One typed serving request.

    `assign`/`score`/`topk` on the service and router are thin shims
    constructing one of these with defaults — `submit(Query(...))` is
    the single entrypoint they all route through.

    Fields:
      x: the query rows, (B, D) (or (D,) for a single row).
      kind: "score" (nearest center) or "topk" (k nearest centers).
      k: top-k width; required >= 1 for kind="topk", must stay 0 for
        kind="score" (it would be silently ignored otherwise).
      priority: lane name from `LANES`.
      deadline_ms: coalesce-deadline override for this request; None
        uses the lane's configured deadline (`ServeConfig.lane_delay_ms`).
      max_staleness: how many versions behind the newest published
        snapshot this caller tolerates.  0 = latest only — such queries
        are NEVER shed to a stale pin.  > 0 marks the query sheddable
        under overload (batch/analytics lanes only).
      want_scores: include distances in the response (labels always come).
    """
    x: Any
    kind: str = "score"
    k: int = 0
    priority: str = "interactive"
    deadline_ms: float | None = None
    max_staleness: int = 0
    want_scores: bool = True

    def __post_init__(self):
        if self.kind not in _KINDS:
            raise ValueError(f"Query.kind must be one of {_KINDS}, "
                             f"got {self.kind!r}")
        if self.kind == "topk" and self.k < 1:
            raise ValueError("Query(kind='topk') requires k >= 1")
        if self.kind == "score" and self.k != 0:
            raise ValueError("Query(kind='score') must leave k == 0 "
                             "(a nonzero k would be silently ignored)")
        if self.priority not in LANES:
            raise ValueError(f"Query.priority must be one of {LANES}, "
                             f"got {self.priority!r}")
        if self.deadline_ms is not None and not self.deadline_ms > 0:
            raise ValueError("Query.deadline_ms must be > 0 or None")
        if not isinstance(self.max_staleness, int) or self.max_staleness < 0:
            raise ValueError("Query.max_staleness must be an int >= 0")


@dataclasses.dataclass(frozen=True)
class ServeConfig:
    """Every construction knob of the serving plane, in one place.

    `ClusterService(store, config)` and `ModelRouter(config)` both take
    one of these (plus keyword overrides), and `launch/serve_clusters`
    builds its CLI flags from the same fields — service-level and
    router-level construction cannot drift.

    Serving-core knobs (semantics unchanged from §10/§12/§16):
      backend, min_bucket / max_bucket, coalesce / coalesce_bucket /
      coalesce_delay_ms, audit_log, probes / recall_audit_every.

    QoS knobs (§17):
      priority_lanes: True runs the lane scheduler (per-(kind, k, lane)
        queues, independent deadline timers, preemption + aging).  False
        is the PR-5 legacy policy — ONE logical queue whose head group
        gates every flush (head-of-line blocking included) — kept as the
        measurable FIFO baseline for the QoS A/B.
      batch_delay_ms / analytics_delay_ms: per-lane coalesce deadlines;
        None derives 8x / 16x the interactive deadline
        (`coalesce_delay_ms`) — batch lanes trade latency for fill.
      aging_limit: how many times a READY lower-priority group may be
        passed over by preemption before it must win (starvation proof).
      shed_depth: total queued rows across lanes at which sheddable
        queries stop queueing and degrade to the stale pin.
      shed_miss_rate: recent deadline-miss rate (EWMA of late flushes)
        with the same effect.
      miss_grace_ms: how late a flush must be past its group deadline to
        count as a miss; None derives the lane's own deadline (a flush
        more than one full budget late is a miss).
    """
    backend: str = "auto"
    min_bucket: int = 8
    max_bucket: int = 4096
    coalesce: bool = False
    coalesce_bucket: int = 64
    coalesce_delay_ms: float = 2.0
    audit_log: bool = False
    probes: int | None = None
    recall_audit_every: int = 0
    # --- QoS (§17) ---
    priority_lanes: bool = True
    batch_delay_ms: float | None = None
    analytics_delay_ms: float | None = None
    aging_limit: int = 4
    shed_depth: int = 512
    shed_miss_rate: float = 0.5
    miss_grace_ms: float | None = None

    def __post_init__(self):
        for f in ("min_bucket", "max_bucket", "coalesce_bucket"):
            v = getattr(self, f)
            if v < 1 or v & (v - 1):
                raise ValueError(f"ServeConfig.{f} must be a power of two, "
                                 f"got {v}")
        if self.probes is not None and self.probes < 1:
            raise ValueError("ServeConfig.probes must be None or >= 1")
        if self.coalesce_delay_ms <= 0:
            raise ValueError("ServeConfig.coalesce_delay_ms must be > 0")
        if self.aging_limit < 1:
            raise ValueError("ServeConfig.aging_limit must be >= 1")
        if self.shed_depth < 1 or self.shed_miss_rate <= 0:
            raise ValueError("ServeConfig shed thresholds must be positive")

    def replace(self, **kw) -> "ServeConfig":
        return dataclasses.replace(self, **kw)

    def lane_delay_s(self, lane: str) -> float:
        """The lane's coalesce deadline, seconds (per-query
        `Query.deadline_ms` overrides it)."""
        base = self.coalesce_delay_ms
        if lane == "batch":
            ms = self.batch_delay_ms if self.batch_delay_ms is not None \
                else 8.0 * base
        elif lane == "analytics":
            ms = self.analytics_delay_ms \
                if self.analytics_delay_ms is not None else 16.0 * base
        else:
            ms = base
        return ms / 1e3

    def miss_grace_s(self, lane: str) -> float:
        if self.miss_grace_ms is not None:
            return self.miss_grace_ms / 1e3
        return self.lane_delay_s(lane)


def effective_lane(priority: str, priority_lanes: bool) -> str:
    """The lane a query actually queues in: its priority, or the single
    legacy lane when the scheduler runs in FIFO-baseline mode."""
    return priority if priority_lanes else LANES[0]


class LaneState(NamedTuple):
    """One queue group as the scheduler sees it (pure data)."""
    key: tuple          # (kind, k, lane) — the group identity
    lane: str
    rows: int           # queued rows in this group
    oldest_t: float     # admission time of the oldest queued request
    deadline_t: float   # earliest per-request deadline in the group


class FlushDecision(NamedTuple):
    key: tuple                       # group to flush NOW
    reason: str                      # "full" | "deadline" | "aged"
    passed_over: tuple[tuple, ...]   # ready groups preempted this round


def select_flush(states: list[LaneState], now_t: float,
                 credits: dict[tuple, int], bucket: int,
                 aging_limit: int) -> FlushDecision | None:
    """Pick the group to flush now, or None if nothing is ready.

    A group is *ready* when its rows would fill the bucket or its
    deadline has expired — each group's timer is its own, so a stalled
    batch group waiting out a long deadline can never delay an
    interactive group's flush (deadline-timer independence).

    Among ready groups, the best lane wins (`LANE_RANK`; ties broken by
    earliest deadline, then earliest admission) — interactive preempts
    batch preempts analytics.  Starvation proof: every ready group that
    loses a round earns one aging credit (the caller bumps
    `credits[key]` for each `passed_over` entry); once a group has been
    passed over `aging_limit` times it enters the *aged* pool, which
    preempts everything — a batch lane under sustained interactive
    pressure drains after at most `aging_limit` interactive flushes.
    """
    ready = [s for s in states
             if s.rows >= bucket or now_t >= s.deadline_t]
    if not ready:
        return None
    order = (lambda s: (LANE_RANK[s.lane], s.deadline_t, s.oldest_t, s.key))
    best = min(ready, key=order)
    aged = [s for s in ready if credits.get(s.key, 0) >= aging_limit]
    win = min(aged, key=order) if aged else best
    reason = ("aged" if win.key != best.key
              else "full" if win.rows >= bucket else "deadline")
    passed = tuple(s.key for s in ready if s.key != win.key)
    return FlushDecision(win.key, reason, passed)


def select_flush_fifo(states: list[LaneState], now_t: float,
                      bucket: int) -> FlushDecision | None:
    """The PR-5 legacy policy, kept as the measurable FIFO baseline:
    only the group holding the globally OLDEST request may flush, when
    full or past ITS deadline.  An interactive request queued behind a
    batch group at the head waits for that group's flush first — the
    head-of-line blocking the lane scheduler exists to remove."""
    if not states:
        return None
    head = min(states, key=lambda s: (s.oldest_t, s.key))
    if head.rows >= bucket:
        return FlushDecision(head.key, "full", ())
    if now_t >= head.deadline_t:
        return FlushDecision(head.key, "deadline", ())
    return None


def next_deadline(states: list[LaneState]) -> float | None:
    """Earliest group deadline — the scheduler thread's wake-up time.
    Independent timers mean the wait is a min over ALL groups, not the
    head group's budget."""
    return min((s.deadline_t for s in states), default=None)


def overload_score(queue_rows: int, shed_depth: int,
                   miss_rate: float, shed_miss_rate: float) -> float:
    """The autoscaling signal, and the shed trigger at >= 1.0.

    Derived from the two pressure metrics the registry already tracks:
    total queued rows across lanes (queue depth) and the EWMA of
    deadline-missed flushes (a flush landing more than one budget late
    means the flusher can't keep up — the same signal that drives
    `serve_flushes{reason="deadline"}` and the bucket-fill ratio toward
    their overload regimes).  Each term is normalized by its configured
    threshold; the max is published as the `serve_overload_score` gauge:
    0 = idle, 1.0 = at threshold (shedding starts), > 1 = shedding."""
    return max(queue_rows / max(1, shed_depth),
               miss_rate / max(1e-9, shed_miss_rate))


def should_shed(lane: str, max_staleness: int, score: float) -> bool:
    """Shed = answer from the stale pinned snapshot instead of queueing.

    Only under measured overload (score >= 1), only for batch/analytics
    lanes, and only when the caller declared staleness tolerance —
    `max_staleness=0` queries are NEVER shed, whatever the load."""
    return (score >= 1.0 and lane != LANES[0] and max_staleness > 0)

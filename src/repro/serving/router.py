"""Multi-model router: many snapshot stores behind one service (§12).

The scale-out front of the serving plane: a `ModelRouter` owns one
`(SnapshotStore, ClusterService)` pair per named model and routes
assign/score/topk requests by model name.  The design invariants:

* **Per-model versioning & atomic hot-swap** — each model keeps its own
  monotone version sequence and its own hot-swap point; publishing to one
  model can never change another model's responses (isolation is by
  construction: tenants share NO mutable state, only compiled code).
* **Shared jit caches across tenants** — the jitted query steps are
  module-level (`cluster_service._assign_step` / `_topk_step`), cache-keyed
  on (request bucket, capacity bucket, backend) and never on the model:
  two tenants whose snapshots land in the same capacity bucket reuse ONE
  compilation.  `metrics()["query_step_compiles"]` counts compiles since
  router construction — bounded by the distinct (bucket, capacity) pairs
  across ALL tenants, not by the tenant count.
* **Coalescing per model** — with `config.coalesce` every tenant service
  gets an admission queue (requests against different models can never
  share a dispatch — the centers differ — so queues and lanes are per
  model; the jit-cache sharing above is what keeps the multi-tenant
  compile footprint flat).
* **Fleet-wide shed policy (§17)** — every tenant service is constructed
  with a `shed_signal` that reads TOTAL queued rows across all tenants
  against `config.shed_depth`: the queues are per model, but the flusher
  threads contend for one process's devices, so one tenant's backlog
  starts shedding every tenant's sheddable (batch/analytics,
  max_staleness > 0) traffic before the shared process melts.
  Interactive / max_staleness=0 traffic is never shed, per-tenant or
  fleet-wide.
* **Replication-ready** — `add_model(delta=True, wire=channel)` publishes
  through the append-only delta log and emits the `CenterDelta` wire
  stream (`distributed/replication.py`): a follower router on another host
  reconstructs every tenant's versions bit-identically.
"""
from __future__ import annotations

import threading
from typing import Any

import jax

from repro.obs import Obs
from repro.serving import cluster_service as _cs
from repro.serving.cluster_service import ClusterService, ServeResponse
from repro.serving.qos import Query, ServeConfig
from repro.serving.snapshot import SnapshotStore

__all__ = ["ModelRouter"]


class ModelRouter:
    """Routes batched assignment queries to named per-model services.

    Construction mirrors `ClusterService`: `ModelRouter(config)` with a
    shared `ServeConfig` (see serving/qos.py), or the historical keyword
    form (`ModelRouter(coalesce=True, ...)`) — ServeConfig fields passed
    as keywords are `replace`d into the config.  The config is every
    tenant's default; `add_model` accepts per-tenant ServeConfig-field
    overrides (or a whole `config=`).  Thread-safe: `add_model` and
    queries may race (the model map flips atomically under a lock;
    queries hold a reference to their tenant's service for the duration
    of the call).
    """

    def __init__(self, config: ServeConfig | None = None, *,
                 mesh: jax.sharding.Mesh | None = None,
                 data_axis: str = "data",
                 obs: Obs | None = None,
                 **overrides):
        if config is None:
            config = ServeConfig()
        if overrides:
            config = config.replace(**overrides)
        self.config = config
        # ONE shared obs: every tenant's counters land in the same
        # registry (distinguished by their model= label), so the router-
        # level aggregates below are plain registry reads.
        self.obs = obs if obs is not None else Obs()
        self.mesh = mesh
        self.data_axis = data_axis
        self._services: dict[str, ClusterService] = {}
        self._lock = threading.Lock()
        self._traces0 = _cs._QUERY_TRACES

    # ------------------------------------------------------------ model mgmt
    def _fleet_shed_signal(self):
        """Fleet-wide overload term: total queued rows across every
        tenant, normalized by the shared shed_depth threshold.  Each
        service takes max(own score, this) at admission time."""
        def signal() -> float:
            with self._lock:
                svcs = list(self._services.values())
            rows = sum(svc.queue_depth_rows() for svc in svcs)
            return rows / max(1, self.config.shed_depth)
        return signal

    def add_model(self, name: str, store: SnapshotStore | None = None, *,
                  snapshot_capacity: int = 16, delta: bool = False,
                  wire: Any = None, max_model_capacity: int | None = None,
                  config: ServeConfig | None = None,
                  **service_overrides) -> SnapshotStore:
        """Register a tenant; returns its store (hand `store.publish_pass`
        to the tenant's `OCCEngine(publish=)`).  `config` replaces the
        router default wholesale for this tenant; bare ServeConfig fields
        in `service_overrides` patch it."""
        with self._lock:
            if name in self._services:
                raise ValueError(f"model {name!r} already registered")
        if store is None:
            store = SnapshotStore(capacity=snapshot_capacity, delta=delta,
                                  model=name, wire=wire,
                                  max_model_capacity=max_model_capacity)
        cfg = config if config is not None else self.config
        if service_overrides:
            cfg = cfg.replace(**service_overrides)
        # Construct outside the lock (coalescing services spawn a flusher
        # thread); re-check under it so a racing duplicate never leaks that
        # thread — the loser closes its service and raises.
        svc = ClusterService(store, cfg, name=name, mesh=self.mesh,
                             data_axis=self.data_axis, obs=self.obs,
                             shed_signal=self._fleet_shed_signal())
        with self._lock:
            if name in self._services:
                svc.close()
                raise ValueError(f"model {name!r} already registered")
            self._services[name] = svc
        return store

    def remove_model(self, name: str) -> None:
        with self._lock:
            svc = self._services.pop(name)
        svc.close()

    def close(self) -> None:
        with self._lock:
            svcs = list(self._services.values())
            self._services.clear()
        for svc in svcs:
            svc.close()

    def models(self) -> list[str]:
        with self._lock:
            return sorted(self._services)

    def service(self, model: str) -> ClusterService:
        with self._lock:
            svc = self._services.get(model)
        if svc is None:
            raise KeyError(f"unknown model {model!r}")
        return svc

    def store(self, model: str) -> SnapshotStore:
        return self.service(model).store

    def publish_hook(self, model: str):
        """The tenant's `OCCEngine(publish=...)` target."""
        return self.store(model).publish_pass

    # --------------------------------------------------------------- queries
    def submit(self, model: str, query: Query) -> ServeResponse:
        """Typed entrypoint, mirroring `ClusterService.submit`."""
        return self.service(model).submit(query)

    def score(self, model: str, x) -> ServeResponse:
        return self.service(model).score(x)

    def assign(self, model: str, x) -> ServeResponse:
        return self.service(model).assign(x)

    def topk(self, model: str, x, k: int = 4) -> ServeResponse:
        return self.service(model).topk(x, k=k)

    # --------------------------------------------------------------- metrics
    def metrics(self) -> dict[str, Any]:
        with self._lock:
            svcs = dict(self._services)
        per_model = {name: svc.metrics() for name, svc in sorted(svcs.items())}
        return {
            "models": per_model,
            "n_models": len(per_model),
            "n_queries": sum(m["n_queries"] for m in per_model.values()),
            "n_requests": sum(m["n_requests"] for m in per_model.values()),
            "n_microbatches": sum(m["n_microbatches"]
                                  for m in per_model.values()),
            "bucket_fill_ratio": (
                sum(m["n_queries"] for m in per_model.values())
                / max(1, sum(svc.n_padded_rows for svc in svcs.values()))),
            # fleet-wide QoS pressure: the max of every tenant's last
            # published overload score, plus total shed counts per lane.
            "overload_score": max(
                (m["overload_score"] for m in per_model.values()),
                default=0.0),
            "n_shed": {
                lane: sum(m["n_shed"][lane] for m in per_model.values())
                for lane in ("interactive", "batch", "analytics")},
            # compiles since ROUTER construction, across every tenant —
            # bounded by distinct (bucket, capacity, backend) triples, NOT
            # by tenant count: the shared-jit-cache proof.
            "query_step_compiles": _cs._QUERY_TRACES - self._traces0,
        }

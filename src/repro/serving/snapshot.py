"""Immutable model snapshots: the train→serve publication point (DESIGN.md §10/§12).

The serving-side dual of the paper's optimistic write-side protocol,
following the versioned-parameter-store idea of *Parameter Database* (Goel
et al., 2015): OCC training *publishes* immutable model versions; a
read-only data plane serves assignment/score queries against them
concurrently.  Trainer and service share no mutable state — the only
channel is `SnapshotStore.publish_pass`, handed to `OCCEngine(publish=...)`.

Immutability contract:
  * A `ModelSnapshot` is frozen at publish time: its arrays are sliced
    copies of the pool buffers and are never written again.  Readers may
    hold a snapshot across any number of queries; nothing the trainer does
    can change what they see (zero stale/torn reads by construction).
  * `version` is assigned monotonically under the store lock; a response
    tagged with version v was computed entirely from snapshot v.

Capacity bucketing: the pool's valid slots are a prefix, so a snapshot
compacts `(K_max, D)` down to the next power-of-two capacity >= count
(min 8, the TPU sublane tile).  Capacities move through a handful of
buckets as the model grows, so the service's jitted query steps recompile
once per (request bucket, capacity bucket) and then stay warm across
versions — publishing a new version never causes a serve-path recompile
unless the model actually outgrew its capacity bucket.

Delta publication (DESIGN.md §12): within an engine stream the pool is
append-only between publishes (the validator only ever appends; `refine`
is not on the streaming path), so version v+1 differs from v by exactly
the rows [count_v, count_{v+1}).  `SnapshotStore(delta=True)` exploits
this: each publish slices ONLY the new rows off the device — O(ΔK·D)
instead of the O(capacity·D) live-prefix copy — appends them to an
append-only `CenterLog`, and registers a lazy `DeltaSnapshot` whose
`materialize()` reconstructs the dense, capacity-bucketed buffers
bit-identically to the eager copy (rows beyond `count` are zero in the
pool by construction, so log-prefix + zero-pad IS the eager slice).  The
emitted `CenterDelta` is the replication wire format: shipping the deltas
over a channel (`distributed/replication.py`) and `apply_delta`-ing them
into a follower store reproduces every version bit-identically.

Append-only contract: delta mode trusts that rows below the publish
watermark did not change since the previous publish.  A caller that
rewrote the prefix (e.g. an explicit `refine` between passes) must pass
`rebase=True`, which re-logs the full prefix.  A one-row guard (the last
previously-published row is re-compared, O(D)) auto-rebases on the common
violation; `verify=True` upgrades the guard to a full O(count·D) bit-check
(tests use it — production publishes stay O(ΔK·D)).
"""
from __future__ import annotations

import threading
from collections import OrderedDict
from dataclasses import dataclass, field
from typing import Any, NamedTuple

import jax.numpy as jnp
import numpy as np

from repro.core.engine import OCCPassResult
from repro.core.occ import CenterPool, next_pow2

__all__ = ["ModelSnapshot", "SnapshotStore", "next_bucket", "freeze_snapshot",
           "CenterDelta", "CenterLog", "DeltaSnapshot", "HierIndex",
           "build_hier"]

_MIN_CAPACITY = 8   # TPU sublane tile: the smallest useful center buffer


@dataclass(frozen=True)
class HierIndex:
    """Two-level routing layout over a snapshot's flat center prefix
    (DESIGN.md §16) — the IVF-style structure behind multi-probe top-k.

    Built at publish time from the flat buffers and immutable alongside
    them.  `coarse` holds ~sqrt(K) routing centers (a strided sample of
    the active prefix); every active center belongs to exactly ONE cell
    (its nearest coarse center, ties to the lower cell), and cell c's
    members sit in `fine[c]` padded to the common power-of-two
    `shard_cap`, ordered by ascending original index (stable grouping).
    `fine_ids` maps each shard slot back to its ORIGINAL flat index (-1
    pad) — top-k over shards returns flat indices, so hierarchical
    serving is indistinguishable from flat serving to clients.

    The layout is PURELY an access-path permutation: `fine` rows are
    bit-copies of flat rows, so probing every cell reproduces the flat
    top-k bit-identically (the p = all exactness contract), and routing
    quality (how well the strided coarse sample matches the data) only
    ever affects recall at p < all, never correctness.
    """
    coarse: jnp.ndarray       # (n_cells, D) routing centers
    coarse_mask: jnp.ndarray  # (n_cells,) bool — all True after clamping
    fine: jnp.ndarray         # (n_cells, shard_cap, D) member rows
    fine_ids: jnp.ndarray     # (n_cells, shard_cap) int32 flat index, -1 pad
    fine_mask: jnp.ndarray    # (n_cells, shard_cap) bool
    n_cells: int
    shard_cap: int


def build_hier(centers, mask, count: int, *, n_cells: int | None = None,
               shard_cap: int | None = None) -> HierIndex | None:
    """Group a flat center prefix into the two-level HierIndex.

    Host-side, O(count · n_cells · D) for the routing pass plus a stable
    argsort — publish-time cost, never on the query path.  Defaults:
    n_cells = pow2(ceil(sqrt(count))) clamped to <= count (so every cell
    is seeded by a distinct stride sample), shard_cap = pow2(max cell
    population).  Returns None for an empty model.
    """
    count = int(count)
    if count <= 0:
        return None
    cn = np.asarray(centers[:count])
    d = cn.shape[1]
    if n_cells is None:
        n_cells = next_pow2(max(int(np.ceil(np.sqrt(count))), 1))
    while n_cells > count:
        n_cells //= 2
    n_cells = max(n_cells, 1)
    # Deterministic coarse seeds: a stride sample of the active prefix.
    coarse = cn[(np.arange(n_cells) * count) // n_cells]
    # Route every active center to its nearest coarse cell (f32 ref
    # algebra; ties to the lower cell — same convention as every argmin
    # in the repo).
    from repro.kernels import ops as _kops
    _, cell = _kops.assign(jnp.asarray(cn), jnp.asarray(coarse),
                           jnp.ones((n_cells,), bool), backend="ref")
    cell = np.asarray(cell)
    counts = np.bincount(cell, minlength=n_cells)
    cap = next_bucket(int(counts.max()), lo=_MIN_CAPACITY)
    if shard_cap is not None:
        if shard_cap < counts.max():
            raise ValueError(
                f"shard_cap={shard_cap} < largest cell ({int(counts.max())})")
        cap = shard_cap
    order = np.argsort(cell, kind="stable")      # groups cells, keeps
    #                                              ascending ids per cell
    start = np.concatenate([[0], np.cumsum(counts)[:-1]])
    ranks = np.arange(count) - start[cell[order]]
    fine = np.zeros((n_cells, cap, d), cn.dtype)
    fine_ids = np.full((n_cells, cap), -1, np.int32)
    fine_mask = np.zeros((n_cells, cap), bool)
    fine[cell[order], ranks] = cn[order]
    fine_ids[cell[order], ranks] = order.astype(np.int32)
    fine_mask[cell[order], ranks] = True
    return HierIndex(coarse=jnp.asarray(coarse),
                     coarse_mask=jnp.ones((n_cells,), bool),
                     fine=jnp.asarray(fine), fine_ids=jnp.asarray(fine_ids),
                     fine_mask=jnp.asarray(fine_mask),
                     n_cells=n_cells, shard_cap=cap)


def next_bucket(n: int, lo: int = _MIN_CAPACITY, hi: int | None = None) -> int:
    """Smallest power of two >= n, clamped to [lo, hi] (lo a power of two).
    Shares the core bucketing primitive with the engine's adaptive
    validator cap (occ.next_pow2) so bucket policy lives in one place."""
    b = max(lo, next_pow2(n))
    return b if hi is None else min(b, hi)


@dataclass(frozen=True)
class ModelSnapshot:
    """One immutable published model version.

    Array fields are device arrays frozen at publish time; scalar metadata
    is host Python (synced once per publish, never on the query path).
    """
    version: int            # monotone id assigned by the store
    centers: jnp.ndarray    # (capacity, D) — capacity-bucketed prefix copy
    mask: jnp.ndarray       # (capacity,) bool — prefix mask (arange < count)
    count: int              # valid centers (== K of this version)
    capacity: int           # power-of-two buffer size (jit-cache key)
    n_seen: int = 0         # training points folded in when frozen
    epochs: int = 0         # global OCC epochs committed when frozen
    overflow: bool = False  # pool/validator overflow was raised in training
    objective: float | None = None   # optional objective metadata
    cap_est: int | None = None       # adaptive-cap estimator at publish time
    cap_trace: tuple[int, ...] | None = None  # per-epoch OCCStats.cap of the
    #                                           pass that produced this version
    hier: HierIndex | None = None    # optional two-level routing layout,
    #                                  built at publish time (build_hier);
    #                                  None on flat-only snapshots — the flat
    #                                  buffers above are authoritative either
    #                                  way (hier is an access path, not data)

    @property
    def k(self) -> int:
        return self.count

    def materialize(self) -> "ModelSnapshot":
        """Already dense — the lazy/eager publication duals share one call
        surface (`DeltaSnapshot.materialize()` produces exactly this)."""
        return self

    def as_pool(self) -> CenterPool:
        """View this snapshot as a (read-only) CenterPool — lets serving
        results be parity-checked against `core.occ.nearest_center` on the
        exact buffers the service used."""
        return CenterPool(self.centers, self.mask,
                          jnp.asarray(self.count, jnp.int32),
                          jnp.asarray(self.overflow, bool))

    def to_pool(self, k_max: int) -> CenterPool:
        """Re-expand into a trainer-shaped (k_max, D) pool — the warm-start
        seed for `OCCEngine.restore`.  Rows beyond `count` are zero, exactly
        as in a live pool, so a restored stream is bit-identical to the
        uninterrupted one."""
        if k_max < self.count:
            raise ValueError(f"k_max={k_max} < snapshot count {self.count}")
        centers = jnp.zeros((k_max, self.centers.shape[1]),
                            self.centers.dtype)
        centers = centers.at[:self.count].set(self.centers[:self.count])
        mask = jnp.arange(k_max) < self.count
        return CenterPool(centers, mask,
                          jnp.asarray(self.count, jnp.int32),
                          jnp.asarray(self.overflow, bool))


def freeze_snapshot(pool: CenterPool, version: int, *, n_seen: int = 0,
                    epochs: int = 0, objective: float | None = None,
                    max_capacity: int | None = None,
                    cap_est: int | None = None,
                    cap_trace: tuple[int, ...] | None = None,
                    hier_spec: tuple[int | None, int | None] | None = None,
                    ) -> ModelSnapshot:
    """Freeze a CenterPool into an immutable, capacity-bucketed snapshot.

    One host sync (count/overflow scalars) per publish; the center slice is
    a fresh device array the trainer never touches again.  `hier_spec`
    (n_cells, shard_cap — either may be None for the defaults) additionally
    builds the two-level `HierIndex` over the same prefix; the flat buffers
    are identical either way, so `materialize()` stays bit-identical to a
    flat-only publish and `hier` is pure added access path.
    """
    count = int(pool.count)
    k_max = pool.centers.shape[0]
    cap = next_bucket(count, hi=min(k_max, max_capacity or k_max))
    if cap < count:
        # Silent truncation would drop live centers and break the
        # serve==train parity contract; refuse loudly instead.
        raise ValueError(
            f"max_capacity={max_capacity} cannot hold {count} live centers")
    centers = jnp.asarray(pool.centers[:cap])
    mask = jnp.arange(cap) < count
    hier = None
    if hier_spec is not None:
        hier = build_hier(centers, mask, count,
                          n_cells=hier_spec[0], shard_cap=hier_spec[1])
    return ModelSnapshot(version=version, centers=centers, mask=mask,
                         count=count, capacity=cap, n_seen=n_seen,
                         epochs=epochs, overflow=bool(pool.overflow),
                         objective=objective, cap_est=cap_est,
                         cap_trace=cap_trace, hier=hier)


# ---------------------------------------------------------------------------
# Delta publication (DESIGN.md §12)
# ---------------------------------------------------------------------------

class CenterDelta(NamedTuple):
    """One publish, as it crosses the wire: the rows version v adds over
    v-1 plus the scalar metadata of v.  `apply_delta`-ing the stream into
    a follower store reproduces every version bit-identically — this tuple
    IS the cross-host replication format (stubbed in-process by
    `distributed.replication.DeltaChannel`)."""
    model: str | None       # routing tag on a shared channel
    version: int            # assigned by the PRIMARY store
    start: int              # first row this delta writes (== prior count)
    rows: np.ndarray        # (ΔK, D) appended center rows (bit-exact)
    count: int              # watermark after applying == start + len(rows)
    capacity: int           # the primary's capacity bucket (depends on its
    #                         K_max clamp, so it travels on the wire — the
    #                         follower must materialize the same shape)
    rebase: bool            # True → rows span [0, count): a fresh base
    n_seen: int = 0
    epochs: int = 0
    overflow: bool = False
    objective: float | None = None
    cap_est: int | None = None
    cap_trace: tuple[int, ...] | None = None

    @property
    def nbytes(self) -> int:
        return self.rows.nbytes


class CenterLog:
    """Append-only dense row store backing a delta-mode SnapshotStore.

    Amortized-doubling host buffer: `append` is O(ΔK·D), `dense(count,
    capacity)` materializes a snapshot's center buffer — log prefix plus
    zero pad, which is bit-identical to the eager `pool.centers[:capacity]`
    slice because pool rows beyond `count` are zero by construction (the
    validator's batched write drops out-of-range slots)."""

    def __init__(self, dim: int, dtype=np.float32):
        self._dim = dim
        self._dtype = np.dtype(dtype)
        self._buf = np.zeros((_MIN_CAPACITY, dim), self._dtype)
        self._n = 0

    @property
    def rows(self) -> int:
        return self._n

    def append(self, rows: np.ndarray) -> None:
        rows = np.asarray(rows, self._dtype)
        need = self._n + rows.shape[0]
        if need > self._buf.shape[0]:
            grown = np.zeros((next_pow2(need), self._dim), self._dtype)
            grown[:self._n] = self._buf[:self._n]
            self._buf = grown
        self._buf[self._n:need] = rows
        self._n = need

    def row(self, i: int) -> np.ndarray:
        return self._buf[i]

    def dense(self, count: int, capacity: int) -> jnp.ndarray:
        """(capacity, D) device buffer: log[:count] + zero pad."""
        out = np.zeros((capacity, self._dim), self._dtype)
        out[:count] = self._buf[:count]
        return jnp.asarray(out)


@dataclass
class DeltaSnapshot:
    """Lazy published version: metadata now, dense buffers on first read.

    Publishing one of these costs O(ΔK·D) (the delta slice); the dense
    (capacity, D) reconstruction is deferred to `materialize()` — off the
    trainer's critical path, paid at most once per version (cached), and
    never paid at all by versions that are evicted unread."""
    version: int
    count: int
    capacity: int
    n_seen: int
    epochs: int
    overflow: bool
    objective: float | None
    cap_est: int | None
    cap_trace: tuple[int, ...] | None
    _log: CenterLog
    _dense: ModelSnapshot | None = None
    hier_spec: tuple[int | None, int | None] | None = None

    def materialize(self) -> ModelSnapshot:
        """Dense, capacity-bucketed buffers — bit-identical to the eager
        `freeze_snapshot` copy of the same pool (a benign race may build
        the cache twice; both builds are equal by construction).  A
        configured `hier_spec` builds the HierIndex here — deferred like
        the dense buffers, paid once per materialized version."""
        if self._dense is None:
            centers = self._log.dense(self.count, self.capacity)
            mask = jnp.arange(self.capacity) < self.count
            hier = None
            if self.hier_spec is not None:
                hier = build_hier(centers, mask, self.count,
                                  n_cells=self.hier_spec[0],
                                  shard_cap=self.hier_spec[1])
            self._dense = ModelSnapshot(
                version=self.version, centers=centers, mask=mask,
                count=self.count, capacity=self.capacity, n_seen=self.n_seen,
                epochs=self.epochs, overflow=self.overflow,
                objective=self.objective, cap_est=self.cap_est,
                cap_trace=self.cap_trace, hier=hier)
        return self._dense


@dataclass
class SnapshotStore:
    """Thread-safe ring of published model versions.

    The trainer publishes (`publish_pass` as the engine's `publish=` hook,
    or `publish_pool` directly); services read `latest()` / `get(version)`.
    Old versions are evicted FIFO beyond `capacity` — in-flight readers
    holding an evicted snapshot are unaffected (immutability), the store
    just stops handing it out.

    `delta=True` switches publication to the append-only center log: each
    publish slices only the new rows (O(ΔK·D)), readers materialize dense
    buffers lazily (bit-identical to the eager copy), and every publish
    emits a `CenterDelta` — to `wire` when given (the replication channel),
    and always retrievable by followers via `apply_delta` on their side.
    The delta log retains at most K_max rows total regardless of ring
    eviction (append-only ⇒ bounded by the pool capacity).

    `hier=True` (optionally with `hier_cells` / `hier_shard_cap`) builds a
    two-level `HierIndex` (DESIGN.md §16) on every published version —
    eagerly at publish for eager stores, at first materialize for delta
    stores.  The flat buffers are byte-identical with or without it; the
    index only adds the multi-probe access path `ClusterService(probes=p)`
    serves from.  The hier config is LOCAL store policy, not wire state: a
    follower decides for itself whether its replicas carry the index.
    """
    capacity: int = 16
    max_model_capacity: int | None = None
    delta: bool = False
    hier: bool = False
    hier_cells: int | None = None
    hier_shard_cap: int | None = None
    model: str | None = None            # wire tag for emitted deltas
    wire: Any = None                    # optional .send(CenterDelta) channel
    _ring: "OrderedDict[int, Any]" = field(default_factory=OrderedDict)
    _next_version: int = 1
    _lock: threading.Lock = field(default_factory=threading.Lock)
    _log: CenterLog | None = None
    _watermark: int = 0                 # rows published into the log so far
    n_deltas: int = 0
    delta_rows_published: int = 0       # Σ ΔK over all publishes

    def publish_pool(self, pool: CenterPool, *, n_seen: int = 0,
                     epochs: int = 0, objective: float | None = None,
                     cap_est: int | None = None,
                     cap_trace: tuple[int, ...] | None = None,
                     rebase: bool = False,
                     verify: bool = False) -> ModelSnapshot | DeltaSnapshot:
        """Freeze and publish; returns the new snapshot with its version."""
        # Freeze outside the lock would race the version order; the slice
        # is cheap (device-side copy / ΔK rows), so publish holds the lock.
        with self._lock:
            if not self.delta:
                snap = freeze_snapshot(
                    pool, self._next_version, n_seen=n_seen, epochs=epochs,
                    objective=objective, cap_est=cap_est, cap_trace=cap_trace,
                    max_capacity=self.max_model_capacity,
                    hier_spec=self._hier_spec())
                self._next_version += 1
                self._register(snap)
                return snap
            return self._publish_delta_locked(
                pool, n_seen=n_seen, epochs=epochs, objective=objective,
                cap_est=cap_est, cap_trace=cap_trace, rebase=rebase,
                verify=verify)

    def _publish_delta_locked(self, pool, *, n_seen, epochs, objective,
                              cap_est, cap_trace, rebase, verify):
        count = int(pool.count)
        k_max = pool.centers.shape[0]
        cap = next_bucket(count, hi=min(k_max,
                                        self.max_model_capacity or k_max))
        if cap < count:
            raise ValueError(
                f"max_model_capacity={self.max_model_capacity} cannot hold "
                f"{count} live centers")
        if self._log is None:
            self._log = CenterLog(pool.centers.shape[1],
                                  np.asarray(pool.centers[:1]).dtype)
        wm = self._watermark
        # Append-only guards: a shrunk count can never be append-only; the
        # one-row check catches a rewritten prefix (refine) at O(D); verify
        # upgrades it to the full O(count·D) bit-check for tests.
        if count < wm:
            rebase = True
        elif wm and not rebase:
            probe = slice(0, wm) if verify else slice(wm - 1, wm)
            if not np.array_equal(np.asarray(pool.centers[probe]),
                                  self._log._buf[probe]):
                rebase = True
        start = 0 if rebase else wm
        rows = np.asarray(pool.centers[start:count])
        if rebase:
            # A fresh log, NOT a reset: ring snapshots published before the
            # rebase keep their reference to the old log (never written
            # again — appends go to the new object), so every older version
            # still materializes its original centers bit-identically and
            # an in-flight materialize() can never read a torn buffer.
            self._log = CenterLog(pool.centers.shape[1],
                                  np.asarray(pool.centers[:1]).dtype)
        self._log.append(rows)
        self._watermark = count
        delta = CenterDelta(
            model=self.model, version=self._next_version, start=start,
            rows=rows, count=count, capacity=cap, rebase=rebase,
            n_seen=n_seen, epochs=epochs, overflow=bool(pool.overflow),
            objective=objective, cap_est=cap_est, cap_trace=cap_trace)
        self._next_version += 1
        snap = self._snapshot_from_delta(delta)
        self._register(snap)
        self.n_deltas += 1
        self.delta_rows_published += rows.shape[0]
        if self.wire is not None:
            self.wire.send(delta)
        return snap

    def _hier_spec(self) -> tuple[int | None, int | None] | None:
        if not self.hier:
            return None
        return (self.hier_cells, self.hier_shard_cap)

    def _snapshot_from_delta(self, delta: CenterDelta):
        return DeltaSnapshot(
            version=delta.version, count=delta.count, capacity=delta.capacity,
            n_seen=delta.n_seen, epochs=delta.epochs,
            overflow=delta.overflow, objective=delta.objective,
            cap_est=delta.cap_est, cap_trace=delta.cap_trace, _log=self._log,
            hier_spec=self._hier_spec())

    def _register(self, snap) -> None:
        self._ring[snap.version] = snap
        while len(self._ring) > self.capacity:
            self._ring.popitem(last=False)

    def apply_delta(self, delta: CenterDelta) -> ModelSnapshot | DeltaSnapshot:
        """Follower side of replication: fold one wire delta into this
        store, reproducing the primary's version bit-identically.  Versions
        come from the wire (the primary assigned them); deltas must arrive
        in order per model — the channel preserves it."""
        with self._lock:
            if not self.delta:
                raise ValueError("apply_delta requires a delta-mode store")
            if self._log is None or delta.rebase:
                # Rebase allocates a fresh log (see _publish_delta_locked):
                # the follower's older versions keep the old one.
                self._log = CenterLog(delta.rows.shape[1], delta.rows.dtype)
                self._watermark = 0
            if delta.start != self._watermark:
                raise ValueError(
                    f"delta gap: have {self._watermark} rows, delta starts "
                    f"at {delta.start} (version {delta.version})")
            self._log.append(delta.rows)
            self._watermark = delta.count
            self._next_version = delta.version + 1
            snap = self._snapshot_from_delta(delta)
            self._register(snap)
            self.n_deltas += 1
            self.delta_rows_published += delta.rows.shape[0]
            return snap

    def bootstrap_delta(self) -> CenterDelta | None:
        """The latest version as a full-prefix REBASE delta — the SNAPSHOT
        bootstrap payload for a late-joining follower (DESIGN.md §13).
        `apply_delta`-ing it rebuilds this store's newest version
        bit-identically on a fresh (or stale) follower store, which then
        tails subsequent deltas with no gap: rebase semantics already
        cover bootstrap, so followers need no separate code path."""
        with self._lock:
            if not self._ring:
                return None
            snap = next(reversed(self._ring.values()))
            if self.delta:
                # after a rebase the current log backs the latest version
                rows = self._log._buf[:snap.count].copy()
            else:
                rows = np.asarray(snap.centers[:snap.count])
            return CenterDelta(
                model=self.model, version=snap.version, start=0, rows=rows,
                count=snap.count, capacity=snap.capacity, rebase=True,
                n_seen=snap.n_seen, epochs=snap.epochs,
                overflow=bool(snap.overflow), objective=snap.objective,
                cap_est=snap.cap_est, cap_trace=snap.cap_trace)

    def publish_pass(self, result: OCCPassResult, *, n_seen: int = 0,
                     epochs: int = 0,
                     cap_est: int | None = None) -> Any:
        """`OCCEngine(publish=store.publish_pass)` — one version per
        committed pass.  Persists the engine's adaptive-cap estimator and
        the pass's per-epoch `OCCStats.cap` trace into the snapshot, so a
        restored stream resumes with a warm cap and the serving metrics can
        surface the trace (DESIGN.md §11/§12)."""
        cap = result.stats.cap
        trace = None if cap is None else tuple(
            int(c) for c in np.asarray(cap))
        return self.publish_pool(result.pool, n_seen=n_seen, epochs=epochs,
                                 cap_est=cap_est, cap_trace=trace)

    def latest(self) -> ModelSnapshot | None:
        with self._lock:
            if not self._ring:
                return None
            snap = next(reversed(self._ring.values()))
        return snap.materialize()

    def latest_meta(self) -> Any:
        """Newest published version WITHOUT materializing dense buffers —
        the metadata read for metrics/observability endpoints."""
        with self._lock:
            if not self._ring:
                return None
            return next(reversed(self._ring.values()))

    def get(self, version: int) -> ModelSnapshot | None:
        with self._lock:
            snap = self._ring.get(version)
        return None if snap is None else snap.materialize()

    def versions(self) -> list[int]:
        with self._lock:
            return list(self._ring)

    def __len__(self) -> int:
        with self._lock:
            return len(self._ring)

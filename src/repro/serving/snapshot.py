"""Immutable model snapshots: the train→serve publication point (DESIGN.md §10).

The serving-side dual of the paper's optimistic write-side protocol,
following the versioned-parameter-store idea of *Parameter Database* (Goel
et al., 2015): OCC training *publishes* immutable model versions; a
read-only data plane serves assignment/score queries against them
concurrently.  Trainer and service share no mutable state — the only
channel is `SnapshotStore.publish_pass`, handed to `OCCEngine(publish=...)`.

Immutability contract:
  * A `ModelSnapshot` is frozen at publish time: its arrays are sliced
    copies of the pool buffers and are never written again.  Readers may
    hold a snapshot across any number of queries; nothing the trainer does
    can change what they see (zero stale/torn reads by construction).
  * `version` is assigned monotonically under the store lock; a response
    tagged with version v was computed entirely from snapshot v.

Capacity bucketing: the pool's valid slots are a prefix, so a snapshot
compacts `(K_max, D)` down to the next power-of-two capacity >= count
(min 8, the TPU sublane tile).  Capacities move through a handful of
buckets as the model grows, so the service's jitted query steps recompile
once per (request bucket, capacity bucket) and then stay warm across
versions — publishing a new version never causes a serve-path recompile
unless the model actually outgrew its capacity bucket.
"""
from __future__ import annotations

import threading
from collections import OrderedDict
from dataclasses import dataclass, field
from typing import Any

import jax.numpy as jnp

from repro.core.engine import OCCPassResult
from repro.core.occ import CenterPool, next_pow2

__all__ = ["ModelSnapshot", "SnapshotStore", "next_bucket", "freeze_snapshot"]

_MIN_CAPACITY = 8   # TPU sublane tile: the smallest useful center buffer


def next_bucket(n: int, lo: int = _MIN_CAPACITY, hi: int | None = None) -> int:
    """Smallest power of two >= n, clamped to [lo, hi] (lo a power of two).
    Shares the core bucketing primitive with the engine's adaptive
    validator cap (occ.next_pow2) so bucket policy lives in one place."""
    b = max(lo, next_pow2(n))
    return b if hi is None else min(b, hi)


@dataclass(frozen=True)
class ModelSnapshot:
    """One immutable published model version.

    Array fields are device arrays frozen at publish time; scalar metadata
    is host Python (synced once per publish, never on the query path).
    """
    version: int            # monotone id assigned by the store
    centers: jnp.ndarray    # (capacity, D) — capacity-bucketed prefix copy
    mask: jnp.ndarray       # (capacity,) bool — prefix mask (arange < count)
    count: int              # valid centers (== K of this version)
    capacity: int           # power-of-two buffer size (jit-cache key)
    n_seen: int = 0         # training points folded in when frozen
    epochs: int = 0         # global OCC epochs committed when frozen
    overflow: bool = False  # pool/validator overflow was raised in training
    objective: float | None = None   # optional objective metadata

    @property
    def k(self) -> int:
        return self.count

    def as_pool(self) -> CenterPool:
        """View this snapshot as a (read-only) CenterPool — lets serving
        results be parity-checked against `core.occ.nearest_center` on the
        exact buffers the service used."""
        return CenterPool(self.centers, self.mask,
                          jnp.asarray(self.count, jnp.int32),
                          jnp.asarray(self.overflow, bool))


def freeze_snapshot(pool: CenterPool, version: int, *, n_seen: int = 0,
                    epochs: int = 0, objective: float | None = None,
                    max_capacity: int | None = None) -> ModelSnapshot:
    """Freeze a CenterPool into an immutable, capacity-bucketed snapshot.

    One host sync (count/overflow scalars) per publish; the center slice is
    a fresh device array the trainer never touches again.
    """
    count = int(pool.count)
    k_max = pool.centers.shape[0]
    cap = next_bucket(count, hi=min(k_max, max_capacity or k_max))
    if cap < count:
        # Silent truncation would drop live centers and break the
        # serve==train parity contract; refuse loudly instead.
        raise ValueError(
            f"max_capacity={max_capacity} cannot hold {count} live centers")
    centers = jnp.asarray(pool.centers[:cap])
    mask = jnp.arange(cap) < count
    return ModelSnapshot(version=version, centers=centers, mask=mask,
                         count=count, capacity=cap, n_seen=n_seen,
                         epochs=epochs, overflow=bool(pool.overflow),
                         objective=objective)


@dataclass
class SnapshotStore:
    """Thread-safe ring of published model versions.

    The trainer publishes (`publish_pass` as the engine's `publish=` hook,
    or `publish_pool` directly); services read `latest()` / `get(version)`.
    Old versions are evicted FIFO beyond `capacity` — in-flight readers
    holding an evicted snapshot are unaffected (immutability), the store
    just stops handing it out.
    """
    capacity: int = 16
    max_model_capacity: int | None = None
    _ring: "OrderedDict[int, ModelSnapshot]" = field(default_factory=OrderedDict)
    _next_version: int = 1
    _lock: threading.Lock = field(default_factory=threading.Lock)

    def publish_pool(self, pool: CenterPool, *, n_seen: int = 0,
                     epochs: int = 0,
                     objective: float | None = None) -> ModelSnapshot:
        """Freeze and publish; returns the new snapshot with its version."""
        # Freeze outside the lock would race the version order; the slice
        # is cheap (device-side copy), so publish holds the lock throughout.
        with self._lock:
            snap = freeze_snapshot(pool, self._next_version, n_seen=n_seen,
                                   epochs=epochs, objective=objective,
                                   max_capacity=self.max_model_capacity)
            self._next_version += 1
            self._ring[snap.version] = snap
            while len(self._ring) > self.capacity:
                self._ring.popitem(last=False)
            return snap

    def publish_pass(self, result: OCCPassResult, *, n_seen: int = 0,
                     epochs: int = 0) -> ModelSnapshot:
        """`OCCEngine(publish=store.publish_pass)` — one version per
        committed pass."""
        return self.publish_pool(result.pool, n_seen=n_seen, epochs=epochs)

    def latest(self) -> ModelSnapshot | None:
        with self._lock:
            if not self._ring:
                return None
            return next(reversed(self._ring.values()))

    def get(self, version: int) -> ModelSnapshot | None:
        with self._lock:
            return self._ring.get(version)

    def versions(self) -> list[int]:
        with self._lock:
            return list(self._ring)

    def __len__(self) -> int:
        with self._lock:
            return len(self._ring)

"""Serving data plane: LM slot engine + the cluster train/serve split.

`SnapshotStore` + `ClusterService` are the paper-side serving stack
(DESIGN.md §10): OCC training publishes immutable `ModelSnapshot` versions;
the read-only service answers batched assign/score/topk queries against
them with pad-to-bucket microbatching and atomic hot-swap.
"""
from repro.serving.engine import ServeEngine
from repro.serving.snapshot import (
    ModelSnapshot, SnapshotStore, freeze_snapshot, next_bucket,
)
from repro.serving.cluster_service import ClusterService, ServeResponse

__all__ = ["ServeEngine", "ModelSnapshot", "SnapshotStore",
           "freeze_snapshot", "next_bucket", "ClusterService",
           "ServeResponse"]

"""Serving data plane: LM slot engine + the cluster train/serve split.

`SnapshotStore` + `ClusterService` are the paper-side serving stack
(DESIGN.md §10): OCC training publishes immutable `ModelSnapshot` versions;
the read-only service answers batched assign/score/topk queries against
them with pad-to-bucket microbatching and atomic hot-swap.  The §12
scale-out layer adds `ModelRouter` (many tenants behind one service with
shared jit caches), delta snapshot publication (`CenterDelta`/`CenterLog`,
O(ΔK·D) publishes + the replication wire format), and admission-queue
coalescing (`ClusterService(coalesce=True)`).  The §17 QoS layer types
the request surface — `submit(Query(...))` with priority lanes, per-lane
deadlines, and staleness-tolerant load shedding — and collapses every
construction knob into one shared `ServeConfig`.
"""
from repro.serving.engine import ServeEngine
from repro.serving.qos import Query, ServeConfig
from repro.serving.snapshot import (
    CenterDelta, CenterLog, DeltaSnapshot, ModelSnapshot, SnapshotStore,
    freeze_snapshot, next_bucket,
)
from repro.serving.cluster_service import (
    ClusterService, DispatchRecord, ServeResponse,
)
from repro.serving.router import ModelRouter

__all__ = ["ServeEngine", "ModelSnapshot", "SnapshotStore",
           "freeze_snapshot", "next_bucket", "ClusterService",
           "ServeResponse", "ModelRouter", "CenterDelta", "CenterLog",
           "DeltaSnapshot", "DispatchRecord", "Query", "ServeConfig"]

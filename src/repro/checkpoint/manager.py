"""Checkpoint/restart: the fault-tolerance substrate.

Design (DESIGN.md §7):
  * pytree flattened to name-indexed .npz shards + JSON manifest
    (step, config hash, mesh shape, tree structure);
  * writes go to a temp dir then os.replace -> atomic: a crash mid-write
    never corrupts the latest checkpoint;
  * keep-last-k garbage collection;
  * optional background-thread writer (training continues during I/O);
  * restore accepts a *different* mesh: arrays are re-device_put with the
    new sharding rules — this is what elastic re-scaling uses.

On a multi-host pod each host would write only its addressable shards; on
this single-host container that is the whole array (noted, not stubbed:
the addressable-shard iteration is written against the JAX API that does
the right thing in both cases).
"""
from __future__ import annotations

import hashlib
import json
import os
import shutil
import threading
import time
from typing import Any

import jax
import numpy as np

__all__ = ["CheckpointManager"]


def _flatten_with_names(tree) -> list[tuple[str, Any]]:
    flat = jax.tree_util.tree_flatten_with_path(tree)[0]
    out = []
    for path, leaf in flat:
        name = "/".join(str(getattr(p, "key", getattr(p, "idx", p))) for p in path)
        out.append((name, leaf))
    return out


class CheckpointManager:
    def __init__(self, directory: str, keep: int = 3, async_write: bool = False):
        self.dir = directory
        self.keep = keep
        self.async_write = async_write
        self._thread: threading.Thread | None = None
        os.makedirs(directory, exist_ok=True)

    # ------------------------------------------------------------------ save
    def save(self, step: int, tree: Any, extra: dict | None = None) -> str:
        if self.async_write:
            self.wait()  # one outstanding write at a time
            host_tree = jax.tree.map(np.asarray, tree)  # snapshot now
            self._thread = threading.Thread(
                target=self._save_sync, args=(step, host_tree, extra or {}))
            self._thread.start()
            return os.path.join(self.dir, f"step_{step:08d}")
        return self._save_sync(step, tree, extra or {})

    def wait(self):
        if self._thread is not None:
            self._thread.join()
            self._thread = None

    def _save_sync(self, step: int, tree: Any, extra: dict) -> str:
        final = os.path.join(self.dir, f"step_{step:08d}")
        tmp = final + ".tmp"
        if os.path.exists(tmp):
            shutil.rmtree(tmp)
        os.makedirs(tmp)
        named = _flatten_with_names(tree)
        arrays = {}
        manifest = {"step": step, "extra": extra, "leaves": [], "time": time.time()}
        for name, leaf in named:
            arr = np.asarray(leaf)
            key = hashlib.md5(name.encode()).hexdigest()[:16]
            arrays[key] = arr
            manifest["leaves"].append(
                {"name": name, "key": key, "shape": list(arr.shape),
                 "dtype": str(arr.dtype)})
        np.savez(os.path.join(tmp, "shards.npz"), **arrays)
        with open(os.path.join(tmp, "manifest.json"), "w") as f:
            json.dump(manifest, f)
        if os.path.exists(final):
            shutil.rmtree(final)
        os.replace(tmp, final)          # atomic publish
        self._gc()
        return final

    # --------------------------------------------------------------- restore
    def latest_step(self) -> int | None:
        steps = self.all_steps()
        return steps[-1] if steps else None

    def all_steps(self) -> list[int]:
        out = []
        for d in os.listdir(self.dir):
            if not d.startswith("step_") or d.endswith(".tmp"):
                continue
            try:
                step = int(d[5:])
            except ValueError:
                continue
            # a checkpoint exists only once its manifest parses — a torn
            # or corrupted directory must not shadow the last good one
            try:
                with open(os.path.join(self.dir, d, "manifest.json")) as f:
                    json.load(f)
            except (OSError, json.JSONDecodeError):
                continue
            out.append(step)
        return sorted(out)

    def manifest(self, step: int) -> dict:
        """The saved manifest (incl. `extra`) for one checkpoint step."""
        path = os.path.join(self.dir, f"step_{step:08d}", "manifest.json")
        with open(path) as f:
            return json.load(f)

    def restore(self, like: Any, step: int | None = None,
                shardings: Any = None) -> tuple[int, Any]:
        """Restore into the structure of `like` (arrays or ShapeDtypeStructs).

        `shardings`: optional pytree of NamedShardings (possibly for a NEW
        mesh) — this is the elastic-restart path.
        """
        step = step if step is not None else self.latest_step()
        if step is None:
            raise FileNotFoundError(f"no checkpoints in {self.dir}")
        path = os.path.join(self.dir, f"step_{step:08d}")
        with open(os.path.join(path, "manifest.json")) as f:
            manifest = json.load(f)
        data = np.load(os.path.join(path, "shards.npz"))
        by_name = {leaf["name"]: data[leaf["key"]] for leaf in manifest["leaves"]}

        names = [n for n, _ in _flatten_with_names(like)]
        treedef = jax.tree_util.tree_structure(like)
        leaves = []
        for n in names:
            if n not in by_name:
                raise KeyError(f"checkpoint missing leaf {n!r}")
            leaves.append(by_name[n])
        tree = jax.tree_util.tree_unflatten(treedef, leaves)
        if shardings is not None:
            tree = jax.tree.map(
                lambda a, s: jax.device_put(a, s), tree, shardings)
        else:
            tree = jax.tree.map(jax.numpy.asarray, tree)
        return manifest["step"], tree

    def _gc(self):
        steps = self.all_steps()
        for s in steps[:-self.keep]:
            shutil.rmtree(os.path.join(self.dir, f"step_{s:08d}"),
                          ignore_errors=True)

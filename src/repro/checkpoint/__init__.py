from repro.checkpoint.manager import CheckpointManager
from repro.checkpoint.wal import DeltaWAL, WireTee, recover_wal

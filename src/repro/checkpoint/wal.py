"""Durable write-ahead log for the delta publication stream (§14).

`DeltaWAL` sits on a store's `wire` seam — the same seam the socket
transport uses — and makes the `CenterDelta` stream the durable source of
truth:

  * every published delta is appended to the current WAL segment as an
    encoded DELTA frame (`protocol.delta_frame` — the SAME bytes that go
    on the socket, so the one codec and its golden fixture also pin the
    on-disk format) plus a crc32 trailer over the frame bytes, flushed
    (+ fsync by default) before `send` returns: a delta the trainer
    believes published survives a crash, and a record that only LOOKS
    complete (torn payload later overwritten by unrelated bytes) is
    caught by the checksum, not replayed as corrupt state;
  * every `checkpoint_every` versions the WAL's internal shadow store is
    checkpointed through `CheckpointManager` (atomic tmp+rename, keep-k
    GC) as a full-prefix rebase image, and the log rotates to a fresh
    segment — replay work after a crash is bounded by one interval;
  * `recover()` rebuilds a store bit-identically: restore the newest
    checkpoint as a rebase delta, then replay segment frames with newer
    versions, in order, through the ordinary `apply_delta` path.  A torn
    tail — a partial frame from a crash mid-append — is detected by the
    frame header/length check and cleanly ends replay (the torn delta was
    never acknowledged, so losing it is correct).

`WireTee` fans one store's publishes to several wires (e.g. a
`ReplicationServer` for followers AND a `DeltaWAL` for durability) — the
wire seam is duck-typed on `send`, so any combination composes.

Resume is then `OCCEngine.restore(store.latest(), k_max=...)` plus
re-feeding the points after `n_seen` — bit-identical to the uninterrupted
run (pinned in tests/test_checkpoint.py and §14's recovery walkthrough).
"""
from __future__ import annotations

import os
import struct
import zlib
from typing import Any

import numpy as np

from repro.checkpoint.manager import CheckpointManager
from repro.distributed.protocol import (DELTA, MAGIC, PROTOCOL_VERSION,
                                        decode_frame, delta_frame,
                                        frame_delta)
from repro.obs import Obs
from repro.obs.metrics import now as _now
from repro.serving.snapshot import CenterDelta, SnapshotStore

__all__ = ["DeltaWAL", "WireTee", "recover_wal"]

_HEADER = struct.Struct("!4sBBI")


class WireTee:
    """Fan one publish stream out to several wires, in order."""

    def __init__(self, *wires: Any):
        self.wires = tuple(wires)

    def send(self, delta: CenterDelta) -> None:
        for w in self.wires:
            w.send(delta)

    def close(self) -> None:
        for w in self.wires:
            close = getattr(w, "close", None)
            if close is not None:
                close()


class DeltaWAL:
    """Append-only delta log + periodic full checkpoints in `directory`.

    Layout:
      directory/ckpt/step_XXXXXXXX/   CheckpointManager images (rows +
                                      delta metadata in `extra`)
      directory/seg_XXXXXXXX.log      frame log; the suffix is the
                                      checkpoint version the segment
                                      starts after (first = 0)

    `fsync=False` trades durability-to-media for speed (data still
    reaches the OS on every append) — the recovery *logic* is identical,
    so tests and benchmarks may disable it.
    """

    def __init__(self, directory: str, model: str | None = None,
                 checkpoint_every: int = 8, keep: int = 3,
                 fsync: bool = True, shadow_capacity: int = 4,
                 obs: Obs | None = None):
        self.dir = directory
        self.model = model
        self.checkpoint_every = checkpoint_every
        self.fsync = fsync
        self.obs = obs if obs is not None else Obs()
        m = self.obs.metrics
        self._c_appended = m.counter("wal_appends")
        self._c_bytes = m.counter("wal_bytes_appended")
        self._c_checkpoints = m.counter("wal_checkpoints")
        self._c_rotations = m.counter("wal_segment_rotations")
        self._h_append = m.histogram("wal_append_s")
        self._h_fsync = m.histogram("wal_fsync_s")
        os.makedirs(directory, exist_ok=True)
        self.ckpt = CheckpointManager(os.path.join(directory, "ckpt"),
                                      keep=keep)
        # the shadow folds every delta so a checkpoint is always one
        # bootstrap_delta away — same trick as the ReplicationServer
        self._shadow = SnapshotStore(capacity=shadow_capacity, delta=True,
                                     model=model)
        steps = self.ckpt.all_steps()
        self._seg_base = steps[-1] if steps else 0
        self._seg = open(self._seg_path(self._seg_base), "ab")

    def _seg_path(self, base: int) -> str:
        return os.path.join(self.dir, f"seg_{base:08d}.log")

    # ------------------------------------------------------------- the wire

    @property
    def n_appended(self) -> int:
        return int(self._c_appended.value)

    @property
    def n_checkpoints(self) -> int:
        return int(self._c_checkpoints.value)

    @property
    def bytes_appended(self) -> int:
        return int(self._c_bytes.value)

    def send(self, delta: CenterDelta) -> None:
        t0 = _now()
        with self.obs.span("wal.append", cat="wal", version=delta.version):
            if delta.model != self.model:
                raise ValueError(f"WAL for {self.model!r} got a delta for "
                                 f"{delta.model!r}")
            self._shadow.apply_delta(delta)
            frame = delta_frame(delta)
            record = frame + struct.pack("!I", zlib.crc32(frame))
            self._seg.write(record)
            self._seg.flush()
            if self.fsync:
                tf = _now()
                os.fsync(self._seg.fileno())
                self._h_fsync.observe(_now() - tf)
            self._c_appended.inc()
            self._c_bytes.inc(len(record))
        self._h_append.observe(_now() - t0)
        if (self.checkpoint_every
                and delta.version % self.checkpoint_every == 0):
            self._checkpoint(delta.version)

    def _checkpoint(self, version: int) -> None:
        boot = self._shadow.bootstrap_delta()
        meta = dict(model=boot.model, version=boot.version, count=boot.count,
                    capacity=boot.capacity, n_seen=boot.n_seen,
                    epochs=boot.epochs, overflow=bool(boot.overflow),
                    objective=boot.objective, cap_est=boot.cap_est,
                    cap_trace=None if boot.cap_trace is None
                    else list(boot.cap_trace))
        with self.obs.span("wal.checkpoint", cat="wal", version=version):
            self.ckpt.save(version, {"rows": np.asarray(boot.rows)},
                           extra=meta)
            self._c_checkpoints.inc()
            # rotate: later frames land in a fresh segment keyed to this
            # image
            self._seg.close()
            self._seg = open(self._seg_path(version), "ab")
            self._seg_base = version
            self._c_rotations.inc()
            self._gc_segments()

    def _gc_segments(self) -> None:
        """Segments entirely covered by the oldest KEPT checkpoint are
        dead: every frame in seg_B holds versions <= some later kept
        image whenever B < oldest kept step."""
        steps = self.ckpt.all_steps()
        if not steps:
            return
        oldest = steps[0]
        for base in self.segment_bases():
            if base < oldest and base != self._seg_base:
                try:
                    os.remove(self._seg_path(base))
                except OSError:
                    pass

    def segment_bases(self) -> list[int]:
        return _segment_bases(self.dir)

    def sync(self) -> None:
        self._seg.flush()
        os.fsync(self._seg.fileno())

    def close(self) -> None:
        try:
            self._seg.flush()
            self._seg.close()
        except OSError:
            pass


def _segment_bases(directory: str) -> list[int]:
    out = []
    for fn in os.listdir(directory):
        if fn.startswith("seg_") and fn.endswith(".log"):
            try:
                out.append(int(fn[4:-4]))
            except ValueError:
                pass
    return sorted(out)


def _iter_segment_frames(path: str):
    """Decoded (meta, arrays) for each complete DELTA record in a segment.
    A torn tail — header, payload or crc trailer cut short by a crash
    mid-append, a header that does not parse, or a crc mismatch (a torn
    payload later padded by unrelated bytes) — ends iteration cleanly at
    the last intact record."""
    with open(path, "rb") as f:
        buf = f.read()
    off = 0
    while off + _HEADER.size <= len(buf):
        magic, ver, ftype, plen = _HEADER.unpack_from(buf, off)
        if magic != MAGIC or ver != PROTOCOL_VERSION:
            return              # torn/corrupt header: stop at last good frame
        end = off + _HEADER.size + plen
        if end + 4 > len(buf):
            return              # torn payload or missing crc trailer
        frame = buf[off:end]
        (crc,) = struct.unpack_from("!I", buf, end)
        if crc != zlib.crc32(frame):
            return              # payload bytes are not what was appended
        ft, meta, arrays = decode_frame(frame)
        if ft == DELTA:
            yield meta, arrays
        off = end + 4


def recover_wal(directory: str, model: str | None = None,
                capacity: int = 16,
                obs: Obs | None = None) -> tuple[SnapshotStore, dict]:
    """Rebuild a delta store from a `DeltaWAL` directory.

    Newest checkpoint image (if any) applies first as a rebase delta, then
    every logged frame with a newer version replays through `apply_delta`
    in version order.  Returns (store, info) where info reports
    `ckpt_version` (0 = no checkpoint), `n_replayed`, and `n_skipped`
    (frames already covered by the checkpoint)."""
    obs = obs if obs is not None else Obs()
    t0 = _now()
    store = SnapshotStore(capacity=capacity, delta=True, model=model)
    ckpt = CheckpointManager(os.path.join(directory, "ckpt"))
    step = ckpt.latest_step()
    if step is not None:
        manifest = ckpt.manifest(step)
        _, tree = ckpt.restore({"rows": np.zeros(0)}, step=step)
        extra = manifest["extra"]
        ct = extra.get("cap_trace")
        rows = np.asarray(tree["rows"], np.float32)
        boot = CenterDelta(
            model=extra["model"], version=extra["version"], start=0,
            rows=rows, count=extra["count"], capacity=extra["capacity"],
            rebase=True, n_seen=extra["n_seen"], epochs=extra["epochs"],
            overflow=bool(extra["overflow"]), objective=extra["objective"],
            cap_est=extra["cap_est"],
            cap_trace=None if ct is None else tuple(ct))
        store.apply_delta(boot)
    n_replayed = n_skipped = 0
    for base in _segment_bases(directory):
        for meta, arrays in _iter_segment_frames(
                os.path.join(directory, f"seg_{base:08d}.log")):
            delta = frame_delta(meta, arrays)
            latest = store.latest_meta()
            if latest is not None and delta.version <= latest.version:
                n_skipped += 1
                continue
            store.apply_delta(delta)
            n_replayed += 1
    dur = _now() - t0
    obs.metrics.histogram("wal_recover_s").observe(dur)
    obs.metrics.counter("wal_frames_replayed").inc(n_replayed)
    if obs.tracer is not None:
        obs.tracer.complete("wal.recover", t0 * 1e6, dur * 1e6, cat="wal",
                            args=dict(ckpt_version=step or 0,
                                      n_replayed=n_replayed,
                                      n_skipped=n_skipped))
    return store, dict(ckpt_version=step or 0, n_replayed=n_replayed,
                       n_skipped=n_skipped)

"""Straggler / failure detection and deterministic fault injection.

The OCC paper's bulk-synchronous epochs are themselves the straggler story
for the *algorithm* (epoch size b bounds the blast radius of a slow worker).
For training we add a host-side watchdog: per-step wall-time EWMA with a
multiplicative threshold; breaches emit StragglerEvents that the launcher
acts on (re-dispatch, shrink via elastic.plan_shrunk_mesh, or ignore).

`FaultPlan` (§14) is the chaos half: a declarative list of `FaultRule`s —
delay / drop / duplicate a frame, reset a socket, kill the process — bound
to *named injection points* that the transport consults on its hot paths
(`server.send`, `server.writer`, `client.apply`, `client.connect`, ...).
Rules trigger on the nth hit of a point, on every k-th hit, or with a
seeded probability, so a chaos test is a (plan, seed) pair that replays
the same failure schedule on every run.  Absent a plan the hooks cost one
`is None` check.

This is host-side control-plane logic — it works identically with 1 or
4096 devices, and the tests drive it with synthetic timings.
"""
from __future__ import annotations

import os
import random
import threading
from collections import Counter
from dataclasses import dataclass, field
from typing import Any

from repro.obs.metrics import now as _now

__all__ = ["StragglerEvent", "StepWatchdog", "HeartbeatTracker",
           "FaultRule", "FaultEvent", "FaultPlan"]


@dataclass(frozen=True)
class StragglerEvent:
    step: int
    elapsed: float
    ewma: float
    ratio: float


@dataclass
class StepWatchdog:
    threshold: float = 3.0        # step slower than threshold x EWMA -> event
    alpha: float = 0.1            # EWMA smoothing
    warmup_steps: int = 5         # ignore compile/first steps
    ewma: float | None = None
    _seen: int = 0
    events: list[StragglerEvent] = field(default_factory=list)
    obs: Any = None               # optional repro.obs.Obs

    def observe(self, step: int, elapsed: float) -> StragglerEvent | None:
        self._seen += 1
        if self._seen <= self.warmup_steps:
            return None
        if self.ewma is None:
            self.ewma = elapsed
            return None
        event = None
        ratio = elapsed / max(self.ewma, 1e-9)
        if ratio > self.threshold:
            event = StragglerEvent(step, elapsed, self.ewma, ratio)
            self.events.append(event)
            # do not fold outliers into the EWMA
            if self.obs is not None:
                self.obs.metrics.counter("watchdog_stragglers").inc()
                self.obs.instant("fault.straggler", cat="fault", step=step,
                                 elapsed_s=elapsed, ratio=ratio)
        else:
            self.ewma = (1 - self.alpha) * self.ewma + self.alpha * elapsed
        return event


@dataclass
class HeartbeatTracker:
    """Host-level liveness: hosts check in each step; silence -> dead.
    Default clock is the shared obs monotonic clock (wall-clock `time.time`
    would double-count NTP steps as silence)."""
    timeout: float = 60.0
    last_seen: dict[int, float] = field(default_factory=dict)

    def beat(self, host_id: int, now: float | None = None):
        self.last_seen[host_id] = now if now is not None else _now()

    def dead_hosts(self, now: float | None = None) -> list[int]:
        now = now if now is not None else _now()
        return [h for h, t in self.last_seen.items() if now - t > self.timeout]


# ------------------------------------------------------- fault injection

@dataclass(frozen=True)
class FaultRule:
    """One injection rule: fire `kind` at `point` on a trigger condition.

    Triggers (first match wins per rule; combine rules for several):
      nth    fire on exactly the nth hit of the point (1-based)
      every  fire on every `every`-th hit
      prob   seeded coin per hit (deterministic for a fixed hit order)
    `count` caps total fires for the rule (0 = unlimited).
    """
    point: str                 # e.g. "server.writer", "client.apply"
    kind: str                  # "delay" | "drop" | "dup" | "reset" | "kill"
    nth: int = 0
    every: int = 0
    prob: float = 0.0
    delay_s: float = 0.0       # for kind == "delay"
    count: int = 0

    def __post_init__(self):
        if self.kind not in ("delay", "drop", "dup", "reset", "kill"):
            raise ValueError(f"unknown fault kind {self.kind!r}")
        if not (self.nth or self.every or self.prob):
            raise ValueError("rule needs a trigger: nth, every or prob")


@dataclass(frozen=True)
class FaultEvent:
    """One fired injection — the plan's audit trail for chaos tests."""
    point: str
    kind: str
    hit: int                    # which hit of the point fired


class FaultPlan:
    """Deterministic fault schedule consulted at named transport points.

    `at(point)` counts a hit and returns the rules that fire on it (in
    declaration order); the *caller* interprets the kinds — the plan only
    decides *when*.  nth/every triggers are exactly reproducible; `prob`
    draws from one seeded stream under the plan lock, so it replays
    exactly whenever the global hit order replays (single-threaded
    drivers) and is still seed-stable in distribution otherwise.

    `kill()` is the one kind the plan executes itself (`os._exit`) since
    no caller can act after it — gated behind `allow_kill` so a plan
    deserialized from CLI flags cannot kill a test runner by accident.
    """

    def __init__(self, rules: tuple[FaultRule, ...] | list[FaultRule] = (),
                 seed: int = 0, allow_kill: bool = False,
                 obs: Any = None):
        self.rules = tuple(rules)
        self.allow_kill = allow_kill
        self.obs = obs                  # optional repro.obs.Obs
        self._rng = random.Random(seed)
        self._hits: Counter = Counter()
        self._fires: Counter = Counter()
        self.events: list[FaultEvent] = []
        self._lock = threading.Lock()

    def at(self, point: str) -> list[FaultRule]:
        """Register one hit of `point`; the rules firing on it, in order."""
        with self._lock:
            self._hits[point] += 1
            n = self._hits[point]
            fired = []
            for i, r in enumerate(self.rules):
                if r.point != point:
                    continue
                if r.count and self._fires[i] >= r.count:
                    continue
                hit = bool(r.nth and n == r.nth) \
                    or bool(r.every and n % r.every == 0) \
                    or bool(r.prob and self._rng.random() < r.prob)
                if hit:
                    self._fires[i] += 1
                    fired.append(r)
                    self.events.append(FaultEvent(point, r.kind, n))
                    if self.obs is not None:
                        # every injected fault is a trace event: a chaos
                        # run's timeline is replayable from the trace
                        self.obs.metrics.counter("fault_injections",
                                                 point=point,
                                                 kind=r.kind).inc()
                        self.obs.instant("fault.inject", cat="fault",
                                         point=point, kind=r.kind, hit=n)
                    if r.kind == "kill":
                        self._kill(point)
            return fired

    def hits(self, point: str) -> int:
        with self._lock:
            return self._hits[point]

    def _kill(self, point: str) -> None:
        if not self.allow_kill:
            raise RuntimeError(f"kill at {point!r} but allow_kill=False")
        if self.obs is not None:
            # the ONE exception to no-flushing: persist the victim's trace
            # first, or the chaos timeline loses exactly the interesting
            # process (os._exit skips atexit by design)
            self.obs.flush()
        # simulate SIGKILL: no atexit, no flushing, no goodbye frames
        os._exit(137)

"""Straggler / failure detection at the step level.

The OCC paper's bulk-synchronous epochs are themselves the straggler story
for the *algorithm* (epoch size b bounds the blast radius of a slow worker).
For training we add a host-side watchdog: per-step wall-time EWMA with a
multiplicative threshold; breaches emit StragglerEvents that the launcher
acts on (re-dispatch, shrink via elastic.plan_shrunk_mesh, or ignore).

This is host-side control-plane logic — it works identically with 1 or
4096 devices, and the tests drive it with synthetic timings.
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field

__all__ = ["StragglerEvent", "StepWatchdog", "HeartbeatTracker"]


@dataclass(frozen=True)
class StragglerEvent:
    step: int
    elapsed: float
    ewma: float
    ratio: float


@dataclass
class StepWatchdog:
    threshold: float = 3.0        # step slower than threshold x EWMA -> event
    alpha: float = 0.1            # EWMA smoothing
    warmup_steps: int = 5         # ignore compile/first steps
    ewma: float | None = None
    _seen: int = 0
    events: list[StragglerEvent] = field(default_factory=list)

    def observe(self, step: int, elapsed: float) -> StragglerEvent | None:
        self._seen += 1
        if self._seen <= self.warmup_steps:
            return None
        if self.ewma is None:
            self.ewma = elapsed
            return None
        event = None
        ratio = elapsed / max(self.ewma, 1e-9)
        if ratio > self.threshold:
            event = StragglerEvent(step, elapsed, self.ewma, ratio)
            self.events.append(event)
            # do not fold outliers into the EWMA
        else:
            self.ewma = (1 - self.alpha) * self.ewma + self.alpha * elapsed
        return event


@dataclass
class HeartbeatTracker:
    """Host-level liveness: hosts check in each step; silence -> dead."""
    timeout: float = 60.0
    last_seen: dict[int, float] = field(default_factory=dict)

    def beat(self, host_id: int, now: float | None = None):
        self.last_seen[host_id] = now if now is not None else time.time()

    def dead_hosts(self, now: float | None = None) -> list[int]:
        now = now if now is not None else time.time()
        return [h for h, t in self.last_seen.items() if now - t > self.timeout]

"""The OCC wire protocol: length-prefixed frames over a byte stream (§13).

One frame format carries BOTH planes of the multi-process system:

  replication plane (master → follower, follower → master):
    HELLO     follower/worker introduces itself (role, model, have_version)
    SNAPSHOT  full-prefix bootstrap: a rebase `CenterDelta` spanning
              [0, count) — a late joiner applies it through the SAME
              `SnapshotStore.apply_delta` path as any other delta and is
              then bit-identical to the primary (bootstrap state machine,
              DESIGN.md §13)
    DELTA     one publish: the `CenterDelta` tuple, rows as raw f32 bytes
    ACK       follower has durably applied `version` (per-follower ack;
              the server's commit watermark is the min over followers)
    FIN       orderly shutdown (reason string)

  training plane (master ↔ worker, §13 worker/master epoch protocol):
    STEP      master starts epoch e: workers propose on their shard
    PROPOSE   worker w's proposal block for epoch e — the flattened leaves
              of `txn.propose` on its shard, concatenated master-side in
              worker order (== global index order)

  control plane (crash recovery / promotion, §14):
    CTRL      one coordinator/HA control message: an `op` string plus
              op-specific scalar fields (who-is-master, orphaned-watermark
              reports, PROMOTE/FOLLOW directives, per-epoch output digests)

Term fencing (§14): HELLO, STEP, DELTA and SNAPSHOT frames carry the
sender's `term` — the promotion epoch, bumped by every master handover.  A
receiver that has seen term t rejects frames with term < t, so a zombie
master that missed its own demotion cannot corrupt workers or followers;
a server receiving a HELLO with a NEWER term than its own knows it is the
zombie and must fence itself off.

Framing: a fixed 10-byte header `!4sBBI` (magic, protocol version, frame
type, payload length) followed by the payload: `!I` metadata length, the
metadata as canonical JSON (sorted keys, no whitespace — byte-stable so
the golden fixture test can pin the format), then each declared array's
raw C-order bytes in declaration order.  Every multi-byte integer on the
wire is big-endian; array bytes are little-endian (numpy '<' dtypes are
declared explicitly in the metadata).  Non-finite floats are not
representable in JSON and are encoded as null (None).

The codec is pure bytes↔values — no sockets in this module — so the
golden wire-format tests pin it without any I/O.
"""
from __future__ import annotations

import json
import math
import socket
import struct
from typing import Any

import numpy as np

from repro.serving.snapshot import CenterDelta

__all__ = [
    "HELLO", "SNAPSHOT", "DELTA", "ACK", "FIN", "STEP", "PROPOSE", "CTRL",
    "FRAME_NAMES", "PROTOCOL_VERSION", "encode_frame", "decode_frame",
    "read_frame", "write_frame", "delta_frame", "frame_delta", "hello_frame",
    "ack_frame", "fin_frame", "step_frame", "propose_frame", "ctrl_frame",
]

MAGIC = b"OCC1"
# v2: HELLO/STEP/DELTA/SNAPSHOT carry `term` (promotion fencing, §14) and
# the CTRL frame type joins the family.  Golden fixture regenerated.
PROTOCOL_VERSION = 2
_HEADER = struct.Struct("!4sBBI")   # magic, proto version, frame type, len

HELLO, SNAPSHOT, DELTA, ACK, FIN, STEP, PROPOSE, CTRL = range(1, 9)
FRAME_NAMES = {HELLO: "HELLO", SNAPSHOT: "SNAPSHOT", DELTA: "DELTA",
               ACK: "ACK", FIN: "FIN", STEP: "STEP", PROPOSE: "PROPOSE",
               CTRL: "CTRL"}


def _canonical_json(meta: dict) -> bytes:
    return json.dumps(meta, sort_keys=True, separators=(",", ":"),
                      allow_nan=False).encode("utf-8")


def _json_scalar(v):
    """JSON-safe scalar: numpy scalars → Python, non-finite floats → None."""
    if isinstance(v, (np.bool_,)):
        return bool(v)
    if isinstance(v, np.integer):
        return int(v)
    if isinstance(v, (float, np.floating)):
        v = float(v)
        return v if math.isfinite(v) else None
    return v


def encode_frame(ftype: int, meta: dict | None = None,
                 arrays: list[tuple[str, np.ndarray]] | None = None) -> bytes:
    """One frame as bytes.  `arrays` is an ordered list of (name, ndarray);
    their dtype/shape specs land in the metadata under "__arrays__" and the
    raw C-order bytes follow the JSON in declaration order."""
    meta = {k: _json_scalar(v) for k, v in (meta or {}).items()}
    blobs = []
    specs = []
    for name, a in (arrays or []):
        a = np.ascontiguousarray(a)
        # pin byte order explicitly: '<' dtypes decode identically anywhere
        dt = a.dtype.newbyteorder("<")
        specs.append([name, dt.str, list(a.shape)])
        blobs.append(a.astype(dt, copy=False).tobytes())
    meta["__arrays__"] = specs
    mj = _canonical_json(meta)
    payload = struct.pack("!I", len(mj)) + mj + b"".join(blobs)
    return _HEADER.pack(MAGIC, PROTOCOL_VERSION, ftype, len(payload)) + payload


def decode_frame(buf: bytes) -> tuple[int, dict, dict[str, np.ndarray]]:
    """Inverse of `encode_frame`: (frame type, metadata, arrays by name).
    Decoded arrays own their memory (safe to hold past the buffer)."""
    magic, ver, ftype, plen = _HEADER.unpack_from(buf, 0)
    if magic != MAGIC:
        raise ValueError(f"bad frame magic {magic!r}")
    if ver != PROTOCOL_VERSION:
        raise ValueError(f"unsupported protocol version {ver}")
    if len(buf) < _HEADER.size + plen:
        raise ValueError("truncated frame")
    off = _HEADER.size
    (mlen,) = struct.unpack_from("!I", buf, off)
    off += 4
    meta = json.loads(bytes(buf[off:off + mlen]).decode("utf-8"))
    off += mlen
    arrays: dict[str, np.ndarray] = {}
    for name, dtstr, shape in meta.pop("__arrays__", []):
        dt = np.dtype(dtstr)
        nbytes = dt.itemsize * int(np.prod(shape, dtype=np.int64))
        a = np.frombuffer(buf, dt, count=int(np.prod(shape, dtype=np.int64)),
                          offset=off).reshape(shape).copy()
        arrays[name] = a
        off += nbytes
    return ftype, meta, arrays


# --------------------------------------------------------------- socket I/O

def _recv_exact(sock: socket.socket, n: int) -> bytes | None:
    """n bytes or None on clean EOF; raises on mid-frame EOF."""
    chunks = []
    got = 0
    while got < n:
        b = sock.recv(n - got)
        if not b:
            if got == 0:
                return None
            raise ConnectionError("EOF mid-frame")
        chunks.append(b)
        got += len(b)
    return b"".join(chunks)


def read_frame(sock: socket.socket
               ) -> tuple[int, dict, dict[str, np.ndarray]] | None:
    """Read one length-prefixed frame; None on clean EOF (peer closed)."""
    head = _recv_exact(sock, _HEADER.size)
    if head is None:
        return None
    magic, ver, ftype, plen = _HEADER.unpack(head)
    if magic != MAGIC:
        raise ValueError(f"bad frame magic {magic!r}")
    payload = _recv_exact(sock, plen)
    if payload is None:
        raise ConnectionError("EOF mid-frame")
    return decode_frame(head + payload)


def write_frame(sock: socket.socket, frame: bytes) -> None:
    sock.sendall(frame)


# ------------------------------------------------------------ frame builders

def delta_frame(delta: CenterDelta, ftype: int = DELTA,
                term: int = 0) -> bytes:
    """A `CenterDelta` on the wire (DELTA, or SNAPSHOT for the full-prefix
    rebase bootstrap — same layout, different frame type).  `term` is the
    sender's promotion term (§14 fencing); 0 = single-master deployment."""
    meta = dict(model=delta.model, version=delta.version, start=delta.start,
                count=delta.count, capacity=delta.capacity,
                rebase=bool(delta.rebase), n_seen=delta.n_seen,
                epochs=delta.epochs, overflow=bool(delta.overflow),
                objective=delta.objective, cap_est=delta.cap_est,
                cap_trace=None if delta.cap_trace is None
                else list(delta.cap_trace), term=term)
    return encode_frame(ftype, meta, [("rows", np.asarray(delta.rows))])


def frame_delta(meta: dict, arrays: dict[str, np.ndarray]) -> CenterDelta:
    """Reconstruct the `CenterDelta` from a decoded DELTA/SNAPSHOT frame."""
    ct = meta.get("cap_trace")
    return CenterDelta(
        model=meta["model"], version=meta["version"], start=meta["start"],
        rows=arrays["rows"], count=meta["count"], capacity=meta["capacity"],
        rebase=bool(meta["rebase"]), n_seen=meta.get("n_seen", 0),
        epochs=meta.get("epochs", 0), overflow=bool(meta.get("overflow")),
        objective=meta.get("objective"), cap_est=meta.get("cap_est"),
        cap_trace=None if ct is None else tuple(ct))


def hello_frame(role: str, model: str | None = None, have_version: int = 0,
                worker: int = -1, term: int = 0) -> bytes:
    return encode_frame(HELLO, dict(role=role, model=model,
                                    have_version=have_version, worker=worker,
                                    term=term))


def ack_frame(model: str | None, version: int) -> bytes:
    return encode_frame(ACK, dict(model=model, version=version))


def fin_frame(reason: str = "") -> bytes:
    return encode_frame(FIN, dict(reason=reason))


def step_frame(epoch: int, count: int, term: int = 0) -> bytes:
    """Master → worker: start epoch `epoch`; `count` echoes the pool
    watermark so the worker can assert its replica is in sync; `term` is
    the sender's promotion term — a worker that has already answered a
    term-t master must reject STEPs from any term < t (§14)."""
    return encode_frame(STEP, dict(epoch=epoch, count=count, term=term))


def ctrl_frame(op: str, **fields) -> bytes:
    """One control-plane message (§14): an `op` string plus op-specific
    JSON-scalar fields.  The HA coordinator and its nodes speak only CTRL
    frames — who-is-master queries, orphaned-watermark reports, the
    PROMOTE/FOLLOW directives, per-epoch output digests, done/ready acks —
    so the control protocol shares the one framed codec (and its golden
    fixture) with the data planes."""
    return encode_frame(CTRL, dict(op=op, **fields))


def propose_frame(epoch: int, worker: int,
                  leaves: list[np.ndarray]) -> bytes:
    """Worker → master: the flattened `txn.propose` output leaves for this
    worker's shard of epoch `epoch`.  Leaf order is jax tree-flatten order —
    both sides derive the treedef from the same transaction, so the
    structure never travels on the wire."""
    arrays = [(f"leaf{i}", np.asarray(a)) for i, a in enumerate(leaves)]
    return encode_frame(PROPOSE, dict(epoch=epoch, worker=worker,
                                      n_leaves=len(leaves)), arrays)

"""Cross-host snapshot replication: the delta stream as wire format (§12).

`serving/snapshot.py`'s delta publication makes every publish an
append-only `CenterDelta` — O(ΔK·D) rows plus scalar metadata.  That
tuple IS the replication protocol: ship the per-model delta stream in
order and `SnapshotStore.apply_delta` it into follower stores, and every
follower version is bit-identical to the primary's (versions are assigned
once, by the primary, and travel on the wire).

`DeltaChannel` is the in-process loopback backend of the `Transport`
interface (`distributed/transport.py`): a thread-safe ordered queue with
per-model follower registration and explicit `pump()` delivery — tests
drive delivery deterministically, and swapping in the socket-backed
`ReplicationServer` changes nothing about the stores or the protocol,
which is the point of the shared interface.  Byte counters expose the
replication cost: Σ ΔK·D·itemsize, NOT versions × capacity × D — the
log-vs-prefix saving the delta format exists for.
"""
from __future__ import annotations

import threading
from collections import deque
from typing import Any

from repro.distributed.transport import Transport
from repro.serving.snapshot import CenterDelta, SnapshotStore

__all__ = ["DeltaChannel", "make_follower"]


class DeltaChannel(Transport):
    """In-process, ordered, thread-safe delta stream with fan-out.

    Publishers call `send` (SnapshotStore does it on every delta-mode
    publish when constructed with `wire=channel`); followers attach per
    model tag and receive deltas in publish order on `pump()`.  Delivery
    is pull-based so tests control interleaving; `pump` is safe to call
    from any thread, concurrently with senders.
    """

    def __init__(self):
        super().__init__()
        self._q: deque[CenterDelta] = deque()
        self._lock = threading.Lock()
        self._followers: dict[str | None, list[SnapshotStore]] = {}
        self._acked: dict[str | None, dict[int, int]] = {}
        #            model → {id(store): last applied version}

    def send(self, delta: CenterDelta) -> None:
        with self._lock:
            self._q.append(delta)
            self.n_sent += 1
            self.bytes_sent += delta.nbytes

    def attach(self, model: str | None, store: SnapshotStore) -> SnapshotStore:
        """Register a follower store for one model's delta stream."""
        if not store.delta:
            raise ValueError("followers must be delta-mode stores")
        with self._lock:
            self._followers.setdefault(model, []).append(store)
        return store

    def pending(self) -> int:
        with self._lock:
            return len(self._q)

    def commit_watermark(self, model: str | None = None) -> int | None:
        """Min version every attached follower of `model` has applied
        (0 for a follower that has applied nothing; None if no followers)
        — the loopback analogue of the socket server's ack watermark,
        where delivery via `pump` IS the ack."""
        with self._lock:
            stores = self._followers.get(model, ())
            if not stores:
                return None
            acked = self._acked.get(model, {})
            return min(acked.get(id(s), 0) for s in stores)

    def pump(self, max_items: int | None = None) -> int:
        """Deliver queued deltas to attached followers, in order.  Returns
        the number of deltas delivered.  Deltas for models with no
        follower are dropped (delivered to nobody) — the primary's ring is
        the source of truth; followers that attach later start from the
        next rebase/bootstrap they see."""
        delivered = 0
        while max_items is None or delivered < max_items:
            with self._lock:
                if not self._q:
                    break
                delta = self._q.popleft()
                followers = list(self._followers.get(delta.model, ()))
            for store in followers:
                # A follower attached mid-stream is not yet bootstrapped:
                # it can only start on a stream head (start == 0); anything
                # later must wait for the next rebase.
                if store.n_deltas == 0 and delta.start != 0:
                    continue
                store.apply_delta(delta)
                with self._lock:
                    self._acked.setdefault(delta.model,
                                           {})[id(store)] = delta.version
            with self._lock:
                self.n_delivered += 1
            delivered += 1
        return delivered


def make_follower(channel: DeltaChannel, model: str | None,
                  capacity: int = 16, **store_kw: Any) -> SnapshotStore:
    """A delta-mode follower store attached to `channel` for `model` —
    the receive side of cross-host serving: point a `ClusterService` (or a
    follower `ModelRouter` tenant) at it and `pump()` on arrival."""
    store = SnapshotStore(capacity=capacity, delta=True, model=model,
                          **store_kw)
    return channel.attach(model, store)

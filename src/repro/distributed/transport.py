"""Socket transport for delta replication: acks, watermark, bootstrap,
backpressure, fencing and fault injection (§13–§14).

The `Transport` interface is the seam between the OCC publication path and
the bytes that carry it: a `SnapshotStore(delta=True, wire=transport)`
calls `send(CenterDelta)` on every publish and never learns whether the
other side is a deque in the same process (`replication.DeltaChannel`, the
loopback backend) or follower processes on real sockets
(`ReplicationServer` here).  Both back ends preserve the one invariant the
stores rely on: per-model deltas arrive in publish order — live deltas
exactly once, with any loss repaired by a full-prefix SNAPSHOT rebase so
the *state* stream is still exactly-once.

`ReplicationServer` is the primary's side of the wire:

  * per-follower ACKs — each follower acknowledges every version it has
    durably applied; the server records per-(connection, version) ack
    latency for the replication benchmarks;
  * commit watermark — `commit_watermark(model)` is the min acked version
    over live followers: everything at or below it is replicated
    everywhere, the transport-level analogue of the serializing master's
    commit point in the paper;
  * snapshot bootstrap — the server folds every outbound delta into an
    internal shadow follower store; a late joiner (HELLO with
    `have_version` behind the shadow's latest) first receives a SNAPSHOT
    frame: the shadow's latest version as a full-prefix REBASE delta.
    `SnapshotStore.apply_delta` already implements rebase semantics, so
    bootstrap needs no new follower code path — the joiner applies the
    snapshot like any delta and then tails the live stream, landing
    bit-identical to a follower that was attached from version 1;
  * backpressure (§14) — per-follower outbound queues are BOUNDED
    (`max_queue`).  A follower too slow to drain its queue is lagged: its
    queued frames are discarded and replaced by one fresh SNAPSHOT (the
    shadow's latest as a rebase delta), so server memory per follower is
    bounded by `max_queue` frames + 1 snapshot while the follower still
    converges to the exact primary state — the drop-to-resync policy;
  * term fencing (§14) — the server stamps every outbound frame with its
    promotion `term`.  A HELLO carrying a NEWER term proves a newer
    master has been promoted: the server marks itself `fenced` and stops
    accepting connections — the zombie-master guard.

`ReplicationClient` is the follower loop: connect → HELLO → apply
SNAPSHOT/DELTA frames into a local delta-mode store → ACK each version →
stop on FIN or EOF.  With `reconnect=True` a broken stream is retried
with exponential backoff + seeded full jitter; the HELLO carries the
store's latest version, so a reconnect resumes exactly where the stream
broke (or takes a SNAPSHOT resync if it fell behind).  Duplicate frames
(at-least-once redelivery after a reconnect race, or chaos `dup`
injection) are ACKed but not re-applied; a sequence gap (chaos `drop`)
raises inside `apply_delta` and is healed by the same reconnect-and-
resync path.  Frames with a stale term are rejected without ACK.

Both sides accept a `fault.FaultPlan` and consult it at named points
(`server.writer`, `client.apply`) — the chaos tests drive delayed,
dropped, duplicated frames and socket resets through real code paths.
"""
from __future__ import annotations

import abc
import queue
import random
import socket
import threading
import time
from typing import Any

import numpy as np

from repro.distributed.fault import FaultPlan
from repro.distributed.protocol import (ACK, DELTA, FIN, HELLO, SNAPSHOT,
                                        ack_frame, delta_frame, fin_frame,
                                        frame_delta, hello_frame, read_frame,
                                        write_frame)
from repro.obs import Obs
from repro.obs.metrics import now as _now
from repro.serving.snapshot import CenterDelta, SnapshotStore

__all__ = ["Transport", "ReplicationServer", "ReplicationClient",
           "store_digest"]


class Transport(abc.ABC):
    """Delta fan-out seam between a primary store and its followers.

    Implementations must deliver each model's deltas to every follower in
    publish order, exactly once at the state level (a lossy path must
    repair itself with a rebase SNAPSHOT).  `pump`/`pending` exist for
    pull-based back ends (the in-process loopback lets tests control
    interleaving); push-based back ends deliver asynchronously and leave
    them as no-ops.
    """

    def __init__(self) -> None:
        self.n_sent = 0        # deltas accepted for delivery
        self.n_delivered = 0   # delta→follower deliveries completed
        self.bytes_sent = 0    # payload bytes accepted for delivery

    @abc.abstractmethod
    def send(self, delta: CenterDelta) -> None:
        """Enqueue one published delta for delivery to followers."""

    @abc.abstractmethod
    def attach(self, model: str | None, store: SnapshotStore) -> SnapshotStore:
        """Register an in-process follower store for one model's stream."""

    def pump(self, max_items: int | None = None) -> int:
        """Deliver queued deltas (pull-based back ends); 0 for push-based."""
        return 0

    def pending(self) -> int:
        """Deltas accepted but not yet delivered everywhere."""
        return 0

    def commit_watermark(self, model: str | None = None) -> int | None:
        """Min version every live follower of `model` has applied (None if
        no followers) — everything <= it is fully replicated."""
        return None

    def close(self) -> None:
        """Release transport resources; followers see an orderly FIN."""


def store_digest(store: SnapshotStore) -> str:
    """Content digest of a store's latest version: sha256 over (count,
    capacity, live center bytes).  Equal digests == bit-identical latest
    snapshots — the cross-process identity check the e2e drivers pin."""
    import hashlib
    snap = store.latest()
    h = hashlib.sha256()
    if snap is None:
        return h.hexdigest()
    h.update(f"{snap.count}:{snap.capacity}:".encode())
    h.update(np.ascontiguousarray(np.asarray(snap.centers)).tobytes())
    return h.hexdigest()


class _FollowerConn:
    """Server-side state for one connected follower socket."""

    def __init__(self, sock: socket.socket, model: str | None,
                 have_version: int, max_queue: int):
        self.sock = sock
        self.model = model
        self.have_version = have_version
        # bounded: a slow follower triggers drop-to-resync, never unbounded
        # server memory (max_queue=0 keeps the legacy unbounded behavior)
        self.q: "queue.Queue[bytes | None]" = queue.Queue(maxsize=max_queue)
        self.acked = 0                      # highest version ACKed
        self.alive = True
        self.sent_ts: dict[int, float] = {}  # version → enqueue time
        self.bootstrap_version: int | None = None
        self.resync_version: int | None = None   # pending lag-resync target
        self.dropped = 0                    # frames discarded on overflow
        self.idx = -1                       # stable follower index (obs label)


class ReplicationServer(Transport):
    """Primary-side socket transport: fan-out, acks, watermark, bootstrap,
    bounded-queue backpressure and term fencing.

    One accept thread; per follower connection one reader (ACKs, runs the
    handshake) and one writer (drains the outbound frame queue) thread.
    `send` never blocks on a slow follower — frames queue per connection
    up to `max_queue`, beyond which the queue is dropped and the follower
    scheduled for a SNAPSHOT resync; a dead connection is detected by
    EOF/send failure and deregistered.
    """

    def __init__(self, host: str = "127.0.0.1", port: int = 0,
                 shadow_capacity: int = 4, max_queue: int = 1024,
                 term: int = 0, fault: FaultPlan | None = None,
                 obs: Obs | None = None):
        # Counters live in the obs registry (§15); the legacy attribute
        # names (n_sent, n_resyncs, ...) remain as read-only properties.
        self.obs = obs if obs is not None else Obs()
        m = self.obs.metrics
        self._c_sent = m.counter("transport_deltas_sent")
        self._c_delivered = m.counter("transport_deltas_delivered")
        self._c_bytes = m.counter("transport_bytes", dir="out_published")
        self._c_bytes_wire = m.counter("transport_bytes", dir="out_wire")
        self._c_frames_in = m.counter("transport_frames_in")
        self._c_bootstraps = m.counter("transport_bootstraps")
        self._c_resyncs = m.counter("transport_resyncs")
        self._c_dropped = m.counter("transport_dropped_frames")
        self._c_fenced = m.counter("transport_fenced_hellos")
        self._h_ack = m.histogram("transport_ack_rtt_s")
        self._g_term = m.gauge("transport_term")
        self._g_term.set(term)
        self._lock = threading.RLock()
        self._acked_cv = threading.Condition(self._lock)
        self._shadow: dict[str | None, SnapshotStore] = {}
        self._shadow_capacity = shadow_capacity
        self._max_queue = max_queue
        self.term = term
        self.fault = fault
        self.fenced = False        # a newer-term master exists (§14)
        self._conns: list[_FollowerConn] = []
        self._conn_seq = 0         # stable per-follower obs label
        self._local: dict[str | None, list[SnapshotStore]] = {}
        self._local_acked: dict[int, int] = {}   # id(store) → version
        self._closing = False
        self._lsock = socket.create_server((host, port))
        self.address = self._lsock.getsockname()
        self._threads: list[threading.Thread] = []
        t = threading.Thread(target=self._accept_loop,
                             name="repl-accept", daemon=True)
        t.start()
        self._threads.append(t)

    # ---------------------------------------------- legacy counter surface
    @property
    def n_sent(self) -> int:
        return int(self._c_sent.value)

    @property
    def n_delivered(self) -> int:
        return int(self._c_delivered.value)

    @property
    def bytes_sent(self) -> int:
        return int(self._c_bytes.value)

    @property
    def n_bootstraps(self) -> int:
        return int(self._c_bootstraps.value)

    @property
    def n_resyncs(self) -> int:
        return int(self._c_resyncs.value)

    @property
    def n_dropped_frames(self) -> int:
        return int(self._c_dropped.value)

    @property
    def n_fenced_hellos(self) -> int:
        return int(self._c_fenced.value)

    # ------------------------------------------------------------- sending

    def send(self, delta: CenterDelta) -> None:
        with self.obs.span("transport.send", cat="transport",
                           version=delta.version):
            with self._lock:
                if self._closing:
                    raise RuntimeError("transport closed")
                if self.fenced:
                    raise RuntimeError(
                        f"fenced: a master with term > {self.term} exists")
                shadow = self._shadow.get(delta.model)
                if shadow is None:
                    shadow = SnapshotStore(capacity=self._shadow_capacity,
                                           delta=True, model=delta.model)
                    self._shadow[delta.model] = shadow
                shadow.apply_delta(delta)
                frame = delta_frame(delta, term=self.term)
                self._c_sent.inc()
                self._c_bytes.inc(len(frame))
                for store in self._local.get(delta.model, ()):  # loopback
                    store.apply_delta(delta)
                    self._local_acked[id(store)] = delta.version
                    self._c_delivered.inc()
                now = _now()
                depth = 0
                for conn in self._conns:
                    if conn.alive and conn.model == delta.model:
                        self._enqueue(conn, shadow, delta, frame, now)
                        depth += conn.q.qsize()
                if self.obs.tracer is not None:
                    self.obs.tracer.counter(
                        "transport.queue_depth", {"frames": depth},
                        cat="transport")

    def _enqueue(self, conn: _FollowerConn, shadow: SnapshotStore,
                 delta: CenterDelta, frame: bytes, now: float) -> None:
        """Offer one live frame to a follower queue under the drop-to-
        resync backpressure policy (§14): on overflow, discard everything
        queued for this follower and enqueue ONE fresh SNAPSHOT instead —
        the shadow already folded `delta`, so the snapshot covers it and
        the next live delta continues the stream with no gap.  Per-
        follower server memory is bounded by max_queue frames + 1
        snapshot, and the follower still converges bit-identically."""
        try:
            conn.q.put_nowait(frame)
            conn.sent_ts[delta.version] = now
            self.obs.metrics.gauge("transport_queue_depth",
                                   follower=conn.idx).set(conn.q.qsize())
            return
        except queue.Full:
            pass
        dropped = 0
        while True:
            try:
                conn.q.get_nowait()
                dropped += 1
            except queue.Empty:
                break
        conn.dropped += dropped
        self._c_dropped.inc(dropped + 1)   # +1: the frame never queued
        conn.sent_ts.clear()
        boot = shadow.bootstrap_delta()
        conn.q.put_nowait(delta_frame(boot, SNAPSHOT, term=self.term))
        conn.sent_ts[boot.version] = now
        conn.resync_version = boot.version
        self._c_resyncs.inc()
        self.obs.instant("transport.resync", cat="transport",
                         version=boot.version, dropped=dropped + 1)

    def attach(self, model: str | None,
               store: SnapshotStore) -> SnapshotStore:
        """In-process follower (delivered synchronously on send).  A store
        attached after publishes began is bootstrapped from the shadow —
        the same rebase-snapshot path a late socket joiner takes."""
        if not store.delta:
            raise ValueError("followers must be delta-mode stores")
        with self._lock:
            shadow = self._shadow.get(model)
            if shadow is not None and len(shadow):
                boot = shadow.bootstrap_delta()
                if boot is not None and store.n_deltas == 0:
                    store.apply_delta(boot)
                    self._local_acked[id(store)] = boot.version
                    self._c_bootstraps.inc()
            self._local.setdefault(model, []).append(store)
        return store

    def seed_shadow(self, model: str | None, store: SnapshotStore) -> None:
        """Adopt `store`'s full prefix as this server's shadow for `model`
        — the promotion path (§14): a promoted follower's server must
        bootstrap late or stale joiners from its own replicated history
        before it has published anything itself."""
        boot = store.bootstrap_delta()
        with self._lock:
            shadow = SnapshotStore(capacity=self._shadow_capacity,
                                   delta=True, model=model)
            if boot is not None:
                shadow.apply_delta(boot)
            self._shadow[model] = shadow

    # ------------------------------------------------------------ watermark

    def commit_watermark(self, model: str | None = None) -> int | None:
        with self._lock:
            acks = [c.acked for c in self._conns
                    if c.alive and c.model == model]
            acks += [self._local_acked.get(id(s), 0)
                     for s in self._local.get(model, ())]
        return min(acks) if acks else None

    def wait_acked(self, version: int, model: str | None = None,
                   timeout: float = 30.0) -> bool:
        """Block until every live follower of `model` has acked `version`
        (vacuously true with zero followers).  The replication barrier the
        cluster driver uses before declaring a pass fully replicated.

        Wakes promptly — never runs to the full timeout — when a follower
        is dropped (the watermark is recomputed over the survivors) or
        the server is closed/aborted mid-wait (returns False: the barrier
        can no longer be met)."""
        deadline = time.monotonic() + timeout
        with self._acked_cv:
            while True:
                if self._closing:
                    return False
                wm = self.commit_watermark(model)
                if wm is None or wm >= version:
                    return True
                left = deadline - time.monotonic()
                if left <= 0:
                    return False
                self._acked_cv.wait(min(left, 0.2))

    def followers(self, model: str | None = None) -> int:
        with self._lock:
            return sum(1 for c in self._conns
                       if c.alive and c.model == model)

    def pending(self) -> int:
        with self._lock:
            return sum(c.q.qsize() for c in self._conns if c.alive)

    def max_pending_bound(self) -> int:
        """The backpressure guarantee: queued frames per follower never
        exceed max_queue (+1 slot headroom for the resync SNAPSHOT)."""
        return self._max_queue + 1 if self._max_queue else 0

    def metrics(self) -> dict:
        h = self._h_ack
        n_acks = h.count
        return dict(n_sent=self.n_sent, n_delivered=self.n_delivered,
                    bytes_sent=self.bytes_sent, n_acks=n_acks,
                    n_bootstraps=self.n_bootstraps,
                    n_resyncs=self.n_resyncs,
                    n_dropped_frames=self.n_dropped_frames,
                    n_fenced_hellos=self.n_fenced_hellos,
                    max_queue=self._max_queue, term=self.term,
                    ack_p50_ms=1e3 * h.percentile(50) if n_acks else 0.0,
                    ack_p99_ms=1e3 * h.percentile(99) if n_acks else 0.0)

    # ----------------------------------------------------------- conn plumbing

    def _accept_loop(self) -> None:
        while True:
            try:
                sock, _addr = self._lsock.accept()
            except OSError:        # listening socket closed: shutdown
                return
            t = threading.Thread(target=self._serve_conn, args=(sock,),
                                 name="repl-conn", daemon=True)
            t.start()
            with self._lock:
                self._threads.append(t)

    def _serve_conn(self, sock: socket.socket) -> None:
        conn: _FollowerConn | None = None
        try:
            fr = read_frame(sock)
            if fr is None or fr[0] != HELLO:
                sock.close()
                return
            _, meta, _ = fr
            if meta.get("role") != "follower":
                write_frame(sock, fin_frame("replication port is "
                                            "follower-only"))
                sock.close()
                return
            peer_term = int(meta.get("term", 0))
            if peer_term > self.term:
                # §14 zombie guard: a follower from a NEWER term proves a
                # newer master was promoted — this server must stand down.
                with self._acked_cv:
                    self.fenced = True
                    self._c_fenced.inc()
                    self._acked_cv.notify_all()
                self.obs.instant("transport.fenced", cat="transport",
                                 term=self.term, peer_term=peer_term)
                write_frame(sock, fin_frame(
                    f"fenced: server term {self.term} < peer {peer_term}"))
                sock.close()
                return
            conn = _FollowerConn(sock, meta.get("model"),
                                 int(meta.get("have_version", 0)),
                                 self._max_queue)
            with self._lock:
                if self._closing:
                    sock.close()
                    return
                # Bootstrap decision and registration are one atomic step:
                # every version after the snapshot flows through the live
                # fan-out, so the joiner sees no gap and no duplicate.
                shadow = self._shadow.get(conn.model)
                if shadow is not None and len(shadow):
                    latest = shadow.latest_meta().version
                    if conn.have_version != latest:
                        boot = shadow.bootstrap_delta()
                        conn.sent_ts[boot.version] = _now()
                        conn.q.put(delta_frame(boot, SNAPSHOT,
                                               term=self.term))
                        conn.bootstrap_version = boot.version
                        self._c_bootstraps.inc()
                        self.obs.instant("transport.bootstrap",
                                         cat="transport",
                                         version=boot.version)
                conn.idx = self._conn_seq
                self._conn_seq += 1
                self._conns.append(conn)
            wt = threading.Thread(target=self._writer, args=(conn,),
                                  name="repl-write", daemon=True)
            wt.start()
            with self._lock:
                self._threads.append(wt)
            self._reader(conn)
        except (ConnectionError, ValueError, OSError):
            pass
        finally:
            if conn is not None:
                self._drop(conn)
            else:
                try:
                    sock.close()
                except OSError:
                    pass

    def _reader(self, conn: _FollowerConn) -> None:
        while True:
            fr = read_frame(conn.sock)
            if fr is None:
                return
            ftype, meta, _ = fr
            self._c_frames_in.inc()
            if ftype == ACK:
                with self._acked_cv:
                    version = int(meta["version"])
                    conn.acked = max(conn.acked, version)
                    ts = conn.sent_ts.pop(version, None)
                    if ts is not None:
                        self._h_ack.observe(_now() - ts)
                    if (conn.resync_version is not None
                            and version >= conn.resync_version):
                        conn.resync_version = None   # lagger caught up
                    self._acked_cv.notify_all()
            elif ftype == FIN:
                return

    def _writer(self, conn: _FollowerConn) -> None:
        while True:
            frame = conn.q.get()
            if frame is None:
                return
            send_n = 1
            for rule in (self.fault.at("server.writer")
                         if self.fault is not None else ()):
                if rule.kind == "delay":
                    time.sleep(rule.delay_s)
                elif rule.kind == "drop":
                    send_n = 0           # frame vanishes on the wire
                elif rule.kind == "dup":
                    send_n = 2           # at-least-once redelivery
                elif rule.kind == "reset":
                    self._drop(conn)     # hard socket reset, no FIN
                    return
            try:
                for _ in range(send_n):
                    conn.sock.sendall(frame)
                self._c_bytes_wire.inc(send_n * len(frame))
            except OSError:
                self._drop(conn)
                return

    def _drop(self, conn: _FollowerConn) -> None:
        with self._acked_cv:
            if not conn.alive:
                return
            conn.alive = False
            if conn in self._conns:
                self._conns.remove(conn)
            # a dead follower no longer holds the watermark back
            self._acked_cv.notify_all()
        self._put_final(conn, None)
        try:
            conn.sock.close()
        except OSError:
            pass

    @staticmethod
    def _put_final(conn: _FollowerConn, *frames: bytes | None) -> None:
        """Queue shutdown frames even on a full bounded queue (evicting
        stale entries — we are tearing the connection down anyway)."""
        for fr in frames:
            while True:
                try:
                    conn.q.put_nowait(fr)
                    break
                except queue.Full:
                    try:
                        conn.q.get_nowait()
                    except queue.Empty:
                        pass

    def close(self, reason: str = "shutdown") -> None:
        with self._acked_cv:
            if self._closing:
                return
            self._closing = True
            conns = list(self._conns)
            self._acked_cv.notify_all()   # wake wait_acked: barrier is off
        fin = fin_frame(reason)
        for conn in conns:
            self._put_final(conn, fin, None)
        try:
            self._lsock.close()
        except OSError:
            pass
        # writers flush the FIN; followers close; readers see EOF and drop
        for t in list(self._threads):
            if t is not threading.current_thread():
                t.join(timeout=5.0)

    def abort(self) -> None:
        """Crash the primary: close the listener and every follower socket
        with NO FIN — followers observe a bare EOF, the §14 orphaned
        signal that starts promotion.  Queued frames are discarded."""
        with self._acked_cv:
            if self._closing:
                return
            self._closing = True
            conns = list(self._conns)
            self._acked_cv.notify_all()
        try:
            self._lsock.close()
        except OSError:
            pass
        for conn in conns:
            try:
                conn.sock.shutdown(socket.SHUT_RDWR)
            except OSError:
                pass
            try:
                conn.sock.close()
            except OSError:
                pass
            self._put_final(conn, None)


class ReplicationClient:
    """Follower loop over one socket: HELLO → apply deltas → ACK → FIN.

    `store` may be a pre-existing delta-mode store (reconnect: HELLO
    carries its latest version, and the server bootstraps only if that is
    behind) or None for a fresh joiner.

    With `reconnect=True`, a broken stream (EOF, socket error, or a
    sequence gap from a lost frame) is retried: exponential backoff
    doubling from `backoff_s` up to `backoff_max_s`, multiplied by a
    seeded full jitter in [1, 2) — `backoff_log` records every sleep for
    the tests.  The failure counter resets whenever a connection made
    progress, so `max_retries` bounds CONSECUTIVE fruitless attempts.
    Duplicates are ACKed but not re-applied; frames with `term` below the
    client's known term are rejected without ACK (§14 fencing).
    """

    def __init__(self, address: tuple[str, int], model: str | None = None,
                 store: SnapshotStore | None = None, capacity: int = 16,
                 connect_timeout: float = 10.0, reconnect: bool = False,
                 max_retries: int = 6, backoff_s: float = 0.05,
                 backoff_max_s: float = 2.0, seed: int = 0, term: int = 0,
                 fault: FaultPlan | None = None, obs: Obs | None = None):
        self.address = tuple(address)
        self.model = model
        self.store = store if store is not None else SnapshotStore(
            capacity=capacity, delta=True, model=model)
        self.connect_timeout = connect_timeout
        self.reconnect = reconnect
        self.max_retries = max_retries
        self.backoff_s = backoff_s
        self.backoff_max_s = backoff_max_s
        self.term = term
        self.fault = fault
        self.obs = obs if obs is not None else Obs()
        m = self.obs.metrics
        self._c_applied = m.counter("transport_client_applied")
        self._c_bytes_in = m.counter("transport_bytes", dir="in_applied")
        # redelivered versions ACKed, not applied / sequence gaps healed by
        # reconnect / stale-term frames rejected / successful re-connections
        self._c_duplicates = m.counter("transport_client_duplicates")
        self._c_gaps = m.counter("transport_client_gaps")
        self._c_fenced = m.counter("transport_client_fenced")
        self._c_reconnects = m.counter("transport_client_reconnects")
        self.backoff_log: list[float] = []
        self.bootstrapped = False
        self.fin_reason: str | None = None
        self._rng = random.Random(seed)
        self._sock: socket.socket | None = None
        self._ever_connected = False
        self._stop = False
        self._thread: threading.Thread | None = None
        self._applied_cv = threading.Condition()

    @property
    def n_applied(self) -> int:
        return int(self._c_applied.value)

    @property
    def n_duplicates(self) -> int:
        return int(self._c_duplicates.value)

    @property
    def n_gaps(self) -> int:
        return int(self._c_gaps.value)

    @property
    def n_fenced(self) -> int:
        return int(self._c_fenced.value)

    @property
    def n_reconnects(self) -> int:
        return int(self._c_reconnects.value)

    def connect(self) -> None:
        meta = self.store.latest_meta()
        have = 0 if meta is None else meta.version
        self._sock = socket.create_connection(self.address,
                                              timeout=self.connect_timeout)
        self._sock.settimeout(None)
        write_frame(self._sock, hello_frame("follower", self.model,
                                            have_version=have,
                                            term=self.term))
        if self._ever_connected:
            self._c_reconnects.inc()
            self.obs.instant("transport.reconnect", cat="transport")
        self._ever_connected = True

    def run(self) -> None:
        """Apply the stream until FIN, orderly EOF, or retry exhaustion
        (inline; `start` for a thread).  Each applied version is ACKed
        immediately after the store commit — the ack IS the durability
        signal upstream."""
        failures = 0
        try:
            while not self._stop:
                if self._sock is None:
                    try:
                        self.connect()
                    except OSError:
                        if not self._backoff(failures):
                            return
                        failures += 1
                        continue
                outcome, progressed = self._run_stream()
                self._close_sock()
                if outcome == "fin" or self._stop or not self.reconnect:
                    return
                if progressed:
                    failures = 0
                if not self._backoff(failures):
                    return
                failures += 1
        finally:
            self.close()

    def _backoff(self, failures: int) -> bool:
        """Sleep before retry `failures`; False when retries are off or
        exhausted.  Exponential with seeded full jitter in [1, 2)x."""
        if not self.reconnect or self._stop or failures >= self.max_retries:
            return False
        delay = min(self.backoff_max_s, self.backoff_s * (2 ** failures))
        delay *= 1.0 + self._rng.random()
        self.backoff_log.append(delay)
        time.sleep(delay)
        return True

    def _run_stream(self) -> tuple[str, bool]:
        """Drain one connection; (outcome, made-progress).  Outcomes:
        "fin" (orderly stop — never retried), "eof"/"conn" (stream broke),
        "gap" (lost frame detected by the store: reconnect so the server's
        bootstrap path resyncs us)."""
        sock = self._sock
        progressed = False
        try:
            while not self._stop:
                fr = read_frame(sock)
                if fr is None:
                    return "eof", progressed
                ftype, meta, arrays = fr
                if ftype in (DELTA, SNAPSHOT):
                    term = int(meta.get("term", 0))
                    if term < self.term:
                        # §14: a zombie master's frame — reject, no ACK
                        self._c_fenced.inc()
                        continue
                    self.term = max(self.term, term)
                    delta = frame_delta(meta, arrays)
                    if self.fault is not None:
                        dropped = False
                        for rule in self.fault.at("client.apply"):
                            if rule.kind == "delay":
                                time.sleep(rule.delay_s)
                            elif rule.kind == "drop":
                                dropped = True    # lost in apply: no ACK
                            elif rule.kind == "reset":
                                self._close_sock()
                                return "conn", progressed
                        if dropped:
                            continue
                    have = self.store.latest_meta()
                    if have is not None and delta.version <= have.version:
                        # at-least-once redelivery: already applied — ACK
                        # again (the server may have lost the first ack)
                        self._c_duplicates.inc()
                        write_frame(sock, ack_frame(self.model,
                                                    delta.version))
                        progressed = True
                        continue
                    try:
                        with self.obs.span("transport.apply",
                                           cat="transport",
                                           version=delta.version):
                            self.store.apply_delta(delta)
                    except ValueError:
                        # sequence gap (dropped frame): reconnect; HELLO
                        # advertises our version and the server resyncs
                        self._c_gaps.inc()
                        self.obs.instant("transport.gap", cat="transport",
                                         version=delta.version)
                        return "gap", progressed
                    self._c_applied.inc()
                    self._c_bytes_in.inc(delta.nbytes)
                    with self._applied_cv:
                        if ftype == SNAPSHOT:
                            self.bootstrapped = True
                        self._applied_cv.notify_all()
                    write_frame(sock, ack_frame(self.model, delta.version))
                    progressed = True
                elif ftype == FIN:
                    self.fin_reason = meta.get("reason", "")
                    return "fin", progressed
            return "fin", progressed
        except (ConnectionError, OSError):
            return "conn", progressed

    def start(self) -> "ReplicationClient":
        if self._sock is None and not self.reconnect:
            self.connect()
        self._thread = threading.Thread(target=self.run, name="repl-client",
                                        daemon=True)
        self._thread.start()
        return self

    def wait_version(self, version: int, timeout: float = 30.0) -> bool:
        """Block until the local store holds `version` (or newer)."""
        deadline = time.monotonic() + timeout
        with self._applied_cv:
            while True:
                meta = self.store.latest_meta()
                if meta is not None and meta.version >= version:
                    return True
                left = deadline - time.monotonic()
                if left <= 0:
                    return False
                self._applied_cv.wait(min(left, 0.2))

    def stop(self) -> None:
        """Request the loop to exit (unblocks a pending read)."""
        self._stop = True
        self._close_sock()

    def join(self, timeout: float | None = None) -> None:
        if self._thread is not None:
            self._thread.join(timeout)

    def _close_sock(self) -> None:
        if self._sock is not None:
            try:
                self._sock.close()
            except OSError:
                pass
            self._sock = None

    def close(self) -> None:
        self._close_sock()

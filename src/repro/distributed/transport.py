"""Socket transport for delta replication: acks, watermark, bootstrap (§13).

The `Transport` interface is the seam between the OCC publication path and
the bytes that carry it: a `SnapshotStore(delta=True, wire=transport)`
calls `send(CenterDelta)` on every publish and never learns whether the
other side is a deque in the same process (`replication.DeltaChannel`, the
loopback backend) or follower processes on real sockets
(`ReplicationServer` here).  Both back ends preserve the one invariant the
stores rely on: per-model deltas arrive in publish order, exactly once.

`ReplicationServer` is the primary's side of the wire:

  * per-follower ACKs — each follower acknowledges every version it has
    durably applied; the server records per-(connection, version) ack
    latency for the replication benchmarks;
  * commit watermark — `commit_watermark(model)` is the min acked version
    over live followers: everything at or below it is replicated
    everywhere, the transport-level analogue of the serializing master's
    commit point in the paper;
  * snapshot bootstrap — the server folds every outbound delta into an
    internal shadow follower store; a late joiner (HELLO with
    `have_version` behind the shadow's latest) first receives a SNAPSHOT
    frame: the shadow's latest version as a full-prefix REBASE delta.
    `SnapshotStore.apply_delta` already implements rebase semantics, so
    bootstrap needs no new follower code path — the joiner applies the
    snapshot like any delta and then tails the live stream, landing
    bit-identical to a follower that was attached from version 1.

`ReplicationClient` is the follower loop: connect → HELLO → apply
SNAPSHOT/DELTA frames into a local delta-mode store → ACK each version →
stop on FIN or EOF.  It runs inline (`run()`) or on a daemon thread
(`start()`); `launch/occ_follower.py` wraps it as a process entrypoint.
"""
from __future__ import annotations

import abc
import queue
import socket
import threading
import time
from typing import Any

import numpy as np

from repro.distributed.protocol import (ACK, DELTA, FIN, HELLO, SNAPSHOT,
                                        ack_frame, delta_frame, fin_frame,
                                        frame_delta, hello_frame, read_frame,
                                        write_frame)
from repro.serving.snapshot import CenterDelta, SnapshotStore

__all__ = ["Transport", "ReplicationServer", "ReplicationClient",
           "store_digest"]


class Transport(abc.ABC):
    """Delta fan-out seam between a primary store and its followers.

    Implementations must deliver each model's deltas to every follower in
    publish order, exactly once.  `pump`/`pending` exist for pull-based
    back ends (the in-process loopback lets tests control interleaving);
    push-based back ends deliver asynchronously and leave them as no-ops.
    """

    def __init__(self) -> None:
        self.n_sent = 0        # deltas accepted for delivery
        self.n_delivered = 0   # delta→follower deliveries completed
        self.bytes_sent = 0    # payload bytes accepted for delivery

    @abc.abstractmethod
    def send(self, delta: CenterDelta) -> None:
        """Enqueue one published delta for delivery to followers."""

    @abc.abstractmethod
    def attach(self, model: str | None, store: SnapshotStore) -> SnapshotStore:
        """Register an in-process follower store for one model's stream."""

    def pump(self, max_items: int | None = None) -> int:
        """Deliver queued deltas (pull-based back ends); 0 for push-based."""
        return 0

    def pending(self) -> int:
        """Deltas accepted but not yet delivered everywhere."""
        return 0

    def commit_watermark(self, model: str | None = None) -> int | None:
        """Min version every live follower of `model` has applied (None if
        no followers) — everything <= it is fully replicated."""
        return None

    def close(self) -> None:
        """Release transport resources; followers see an orderly FIN."""


def store_digest(store: SnapshotStore) -> str:
    """Content digest of a store's latest version: sha256 over (count,
    capacity, live center bytes).  Equal digests == bit-identical latest
    snapshots — the cross-process identity check the e2e drivers pin."""
    import hashlib
    snap = store.latest()
    h = hashlib.sha256()
    if snap is None:
        return h.hexdigest()
    h.update(f"{snap.count}:{snap.capacity}:".encode())
    h.update(np.ascontiguousarray(np.asarray(snap.centers)).tobytes())
    return h.hexdigest()


class _FollowerConn:
    """Server-side state for one connected follower socket."""

    def __init__(self, sock: socket.socket, model: str | None,
                 have_version: int):
        self.sock = sock
        self.model = model
        self.have_version = have_version
        self.q: "queue.SimpleQueue[bytes | None]" = queue.SimpleQueue()
        self.acked = 0                      # highest version ACKed
        self.alive = True
        self.sent_ts: dict[int, float] = {}  # version → enqueue time
        self.bootstrap_version: int | None = None


class ReplicationServer(Transport):
    """Primary-side socket transport: fan-out, acks, watermark, bootstrap.

    One accept thread; per follower connection one reader (ACKs, runs the
    handshake) and one writer (drains the outbound frame queue) thread.
    `send` never blocks on a slow follower — frames queue per connection;
    a dead connection is detected by EOF/send failure and deregistered.
    """

    def __init__(self, host: str = "127.0.0.1", port: int = 0,
                 shadow_capacity: int = 4):
        super().__init__()
        self._lock = threading.RLock()
        self._acked_cv = threading.Condition(self._lock)
        self._shadow: dict[str | None, SnapshotStore] = {}
        self._shadow_capacity = shadow_capacity
        self._conns: list[_FollowerConn] = []
        self._local: dict[str | None, list[SnapshotStore]] = {}
        self._local_acked: dict[int, int] = {}   # id(store) → version
        self.ack_latency_s: list[float] = []
        self.n_bootstraps = 0
        self._closing = False
        self._lsock = socket.create_server((host, port))
        self.address = self._lsock.getsockname()
        self._threads: list[threading.Thread] = []
        t = threading.Thread(target=self._accept_loop,
                             name="repl-accept", daemon=True)
        t.start()
        self._threads.append(t)

    # ------------------------------------------------------------- sending

    def send(self, delta: CenterDelta) -> None:
        frame = delta_frame(delta)
        with self._lock:
            if self._closing:
                raise RuntimeError("transport closed")
            shadow = self._shadow.get(delta.model)
            if shadow is None:
                shadow = SnapshotStore(capacity=self._shadow_capacity,
                                       delta=True, model=delta.model)
                self._shadow[delta.model] = shadow
            shadow.apply_delta(delta)
            self.n_sent += 1
            self.bytes_sent += len(frame)
            for store in self._local.get(delta.model, ()):  # loopback attach
                store.apply_delta(delta)
                self._local_acked[id(store)] = delta.version
                self.n_delivered += 1
            now = time.perf_counter()
            for conn in self._conns:
                if conn.alive and conn.model == delta.model:
                    conn.sent_ts[delta.version] = now
                    conn.q.put(frame)

    def attach(self, model: str | None,
               store: SnapshotStore) -> SnapshotStore:
        """In-process follower (delivered synchronously on send).  A store
        attached after publishes began is bootstrapped from the shadow —
        the same rebase-snapshot path a late socket joiner takes."""
        if not store.delta:
            raise ValueError("followers must be delta-mode stores")
        with self._lock:
            shadow = self._shadow.get(model)
            if shadow is not None and len(shadow):
                boot = shadow.bootstrap_delta()
                if boot is not None and store.n_deltas == 0:
                    store.apply_delta(boot)
                    self._local_acked[id(store)] = boot.version
                    self.n_bootstraps += 1
            self._local.setdefault(model, []).append(store)
        return store

    # ------------------------------------------------------------ watermark

    def commit_watermark(self, model: str | None = None) -> int | None:
        with self._lock:
            acks = [c.acked for c in self._conns
                    if c.alive and c.model == model]
            acks += [self._local_acked.get(id(s), 0)
                     for s in self._local.get(model, ())]
        return min(acks) if acks else None

    def wait_acked(self, version: int, model: str | None = None,
                   timeout: float = 30.0) -> bool:
        """Block until every live follower of `model` has acked `version`
        (vacuously true with zero followers).  The replication barrier the
        cluster driver uses before declaring a pass fully replicated."""
        deadline = time.monotonic() + timeout
        with self._acked_cv:
            while True:
                wm = self.commit_watermark(model)
                if wm is None or wm >= version:
                    return True
                left = deadline - time.monotonic()
                if left <= 0:
                    return False
                self._acked_cv.wait(min(left, 0.2))

    def followers(self, model: str | None = None) -> int:
        with self._lock:
            return sum(1 for c in self._conns
                       if c.alive and c.model == model)

    def pending(self) -> int:
        with self._lock:
            return sum(c.q.qsize() for c in self._conns if c.alive)

    def metrics(self) -> dict:
        with self._lock:
            lat = sorted(self.ack_latency_s)
            pct = (lambda p: 1e3 * lat[min(len(lat) - 1,
                                           int(p * len(lat)))] if lat else 0.0)
            return dict(n_sent=self.n_sent, n_delivered=self.n_delivered,
                        bytes_sent=self.bytes_sent, n_acks=len(lat),
                        n_bootstraps=self.n_bootstraps,
                        ack_p50_ms=pct(0.50), ack_p99_ms=pct(0.99))

    # ----------------------------------------------------------- conn plumbing

    def _accept_loop(self) -> None:
        while True:
            try:
                sock, _addr = self._lsock.accept()
            except OSError:        # listening socket closed: shutdown
                return
            t = threading.Thread(target=self._serve_conn, args=(sock,),
                                 name="repl-conn", daemon=True)
            t.start()
            with self._lock:
                self._threads.append(t)

    def _serve_conn(self, sock: socket.socket) -> None:
        conn: _FollowerConn | None = None
        try:
            fr = read_frame(sock)
            if fr is None or fr[0] != HELLO:
                sock.close()
                return
            _, meta, _ = fr
            if meta.get("role") != "follower":
                write_frame(sock, fin_frame("replication port is "
                                            "follower-only"))
                sock.close()
                return
            conn = _FollowerConn(sock, meta.get("model"),
                                 int(meta.get("have_version", 0)))
            with self._lock:
                if self._closing:
                    sock.close()
                    return
                # Bootstrap decision and registration are one atomic step:
                # every version after the snapshot flows through the live
                # fan-out, so the joiner sees no gap and no duplicate.
                shadow = self._shadow.get(conn.model)
                if shadow is not None and len(shadow):
                    latest = shadow.latest_meta().version
                    if conn.have_version != latest:
                        boot = shadow.bootstrap_delta()
                        conn.sent_ts[boot.version] = time.perf_counter()
                        conn.q.put(delta_frame(boot, SNAPSHOT))
                        conn.bootstrap_version = boot.version
                        self.n_bootstraps += 1
                self._conns.append(conn)
            wt = threading.Thread(target=self._writer, args=(conn,),
                                  name="repl-write", daemon=True)
            wt.start()
            with self._lock:
                self._threads.append(wt)
            self._reader(conn)
        except (ConnectionError, ValueError, OSError):
            pass
        finally:
            if conn is not None:
                self._drop(conn)
            else:
                try:
                    sock.close()
                except OSError:
                    pass

    def _reader(self, conn: _FollowerConn) -> None:
        while True:
            fr = read_frame(conn.sock)
            if fr is None:
                return
            ftype, meta, _ = fr
            if ftype == ACK:
                with self._acked_cv:
                    conn.acked = max(conn.acked, int(meta["version"]))
                    ts = conn.sent_ts.pop(int(meta["version"]), None)
                    if ts is not None:
                        self.ack_latency_s.append(time.perf_counter() - ts)
                    self._acked_cv.notify_all()
            elif ftype == FIN:
                return

    def _writer(self, conn: _FollowerConn) -> None:
        while True:
            frame = conn.q.get()
            if frame is None:
                return
            try:
                conn.sock.sendall(frame)
            except OSError:
                self._drop(conn)
                return

    def _drop(self, conn: _FollowerConn) -> None:
        with self._acked_cv:
            if not conn.alive:
                return
            conn.alive = False
            if conn in self._conns:
                self._conns.remove(conn)
            # a dead follower no longer holds the watermark back
            self._acked_cv.notify_all()
        conn.q.put(None)
        try:
            conn.sock.close()
        except OSError:
            pass

    def close(self, reason: str = "shutdown") -> None:
        with self._lock:
            if self._closing:
                return
            self._closing = True
            conns = list(self._conns)
        fin = fin_frame(reason)
        for conn in conns:
            conn.q.put(fin)
            conn.q.put(None)
        try:
            self._lsock.close()
        except OSError:
            pass
        # writers flush the FIN; followers close; readers see EOF and drop
        for t in list(self._threads):
            if t is not threading.current_thread():
                t.join(timeout=5.0)


class ReplicationClient:
    """Follower loop over one socket: HELLO → apply deltas → ACK → FIN.

    `store` may be a pre-existing delta-mode store (reconnect: HELLO
    carries its latest version, and the server bootstraps only if that is
    behind) or None for a fresh joiner.
    """

    def __init__(self, address: tuple[str, int], model: str | None = None,
                 store: SnapshotStore | None = None, capacity: int = 16,
                 connect_timeout: float = 10.0):
        self.address = tuple(address)
        self.model = model
        self.store = store if store is not None else SnapshotStore(
            capacity=capacity, delta=True, model=model)
        self.connect_timeout = connect_timeout
        self.n_applied = 0
        self.bootstrapped = False
        self.fin_reason: str | None = None
        self._sock: socket.socket | None = None
        self._thread: threading.Thread | None = None
        self._applied_cv = threading.Condition()

    def connect(self) -> None:
        meta = self.store.latest_meta()
        have = 0 if meta is None else meta.version
        self._sock = socket.create_connection(self.address,
                                              timeout=self.connect_timeout)
        self._sock.settimeout(None)
        write_frame(self._sock, hello_frame("follower", self.model,
                                            have_version=have))

    def run(self) -> None:
        """Apply the stream until FIN or EOF (inline; `start` for a
        thread).  Each applied version is ACKed immediately after the
        store commit — the ack IS the durability signal upstream."""
        if self._sock is None:
            self.connect()
        sock = self._sock
        try:
            while True:
                fr = read_frame(sock)
                if fr is None:
                    return
                ftype, meta, arrays = fr
                if ftype in (DELTA, SNAPSHOT):
                    delta = frame_delta(meta, arrays)
                    self.store.apply_delta(delta)
                    with self._applied_cv:
                        self.n_applied += 1
                        if ftype == SNAPSHOT:
                            self.bootstrapped = True
                        self._applied_cv.notify_all()
                    write_frame(sock, ack_frame(self.model, delta.version))
                elif ftype == FIN:
                    self.fin_reason = meta.get("reason", "")
                    return
        except (ConnectionError, OSError):
            return
        finally:
            self.close()

    def start(self) -> "ReplicationClient":
        self.connect()
        self._thread = threading.Thread(target=self.run, name="repl-client",
                                        daemon=True)
        self._thread.start()
        return self

    def wait_version(self, version: int, timeout: float = 30.0) -> bool:
        """Block until the local store holds `version` (or newer)."""
        deadline = time.monotonic() + timeout
        with self._applied_cv:
            while True:
                meta = self.store.latest_meta()
                if meta is not None and meta.version >= version:
                    return True
                left = deadline - time.monotonic()
                if left <= 0:
                    return False
                self._applied_cv.wait(min(left, 0.2))

    def join(self, timeout: float | None = None) -> None:
        if self._thread is not None:
            self._thread.join(timeout)

    def close(self) -> None:
        if self._sock is not None:
            try:
                self._sock.close()
            except OSError:
                pass
            self._sock = None

"""Elastic scaling: rebuild the mesh after node loss and reshard state.

Policy (DESIGN.md §7): node failures shrink the `data` axis (DP degree) —
TP groups must stay intact because weights are sharded across them, so a
dead host inside a TP group takes its whole group's data-rank out.  The
surviving mesh keeps the same `model` extent; params/opt state are restored
from the latest checkpoint with the new shardings; the data pipeline
re-seeds deterministically from (seed, step).

On real hardware the device list comes from jax.devices() after the runtime
excludes the failed hosts; here `surviving_devices` is injectable so tests
can simulate failures on the 512-host-device dry-run mesh.
"""
from __future__ import annotations

import math
from dataclasses import dataclass

import jax
import numpy as np
from jax.sharding import Mesh

__all__ = ["plan_shrunk_mesh", "ElasticPlan"]


@dataclass(frozen=True)
class ElasticPlan:
    old_shape: dict[str, int]
    new_shape: dict[str, int]
    lost_ranks: int

    @property
    def new_axis_sizes(self) -> tuple[int, ...]:
        return tuple(self.new_shape.values())


def plan_shrunk_mesh(mesh: Mesh, n_failed: int,
                     data_axis: str = "data") -> ElasticPlan:
    """Compute the largest surviving mesh after `n_failed` device failures.

    Each failure removes ceil(failures / devices-per-data-rank) data ranks.
    Keeps `model` (and `pod`) extents; shrinks `data`.
    """
    shape = dict(mesh.shape)
    per_rank = math.prod(s for a, s in shape.items() if a != data_axis)
    lost_ranks = math.ceil(n_failed / per_rank) if n_failed else 0
    new_data = shape[data_axis] - lost_ranks
    if new_data < 1:
        raise RuntimeError(
            f"too many failures: {n_failed} kills all {shape[data_axis]} data ranks")
    new_shape = dict(shape)
    new_shape[data_axis] = new_data
    return ElasticPlan(shape, new_shape, lost_ranks)


def build_mesh_from_plan(plan: ElasticPlan, devices=None) -> Mesh:
    """Materialize the shrunk mesh from surviving devices."""
    names = tuple(plan.new_shape.keys())
    sizes = plan.new_axis_sizes
    need = math.prod(sizes)
    devs = np.asarray(devices if devices is not None else jax.devices())[:need]
    if devs.size < need:
        raise RuntimeError(f"need {need} devices, have {devs.size}")
    return Mesh(devs.reshape(sizes), names)

"""Sharding rules: how every parameter / activation maps onto the mesh.

Axes (DESIGN.md §5):
  pod   — cross-pod data parallelism (gradient all-reduce crosses DCN/ICI-X)
  data  — in-pod data parallelism + ZeRO-3 weight sharding
  model — tensor parallelism (heads / d_ff / experts / vocab), context
          parallelism for long KV caches

All helpers are divisibility-aware: an axis is only used when it evenly
divides the dimension, so e.g. kv_heads=8 on a 16-way model axis falls back
to replication (Megatron-style GQA TP) and global_batch=1 falls back to
context-parallel-only — the decisions the dry-run log records.
"""
from __future__ import annotations

import contextlib
import re
from dataclasses import dataclass, field
from typing import Any

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

__all__ = ["ShardCtx", "shard_ctx", "current_ctx", "constrain", "batch_spec",
           "param_specs", "input_shardings", "axes_that_divide",
           "occ_epoch_sharding", "occ_validate_sharding",
           "serve_snapshot_sharding", "serve_query_sharding",
           "compat_shard_map"]


def compat_shard_map(f, **kw):
    """`jax.shard_map` across jax versions (older releases only have
    `jax.experimental.shard_map.shard_map`)."""
    sm = getattr(jax, "shard_map", None)
    if sm is None:
        from jax.experimental.shard_map import shard_map as sm
    return sm(f, **kw)


@dataclass
class ShardCtx:
    mesh: Mesh | None = None
    data_axes: tuple[str, ...] = ("pod", "data")   # axes used for batch DP
    model_axis: str = "model"
    # hillclimb levers (see EXPERIMENTS.md §Perf)
    seq_shard_acts: bool = False      # sequence-parallel activations between blocks
    zero3: bool = True                # shard weights over data axes too
    cp_decode_axes: tuple[str, ...] = ("model",)   # KV-cache context-parallel axes
    force_decode_mode: str | None = None           # override tp/cp heuristic

    def axis_size(self, name: str) -> int:
        if self.mesh is None or name not in self.mesh.shape:
            return 1
        return self.mesh.shape[name]

    @property
    def present_data_axes(self) -> tuple[str, ...]:
        if self.mesh is None:
            return ()
        return tuple(a for a in self.data_axes if a in self.mesh.shape)


_CTX = ShardCtx()


@contextlib.contextmanager
def shard_ctx(mesh: Mesh | None, **kw):
    """Install a sharding context; model code reads it via current_ctx()."""
    global _CTX
    prev = _CTX
    _CTX = ShardCtx(mesh=mesh, **kw)
    try:
        yield _CTX
    finally:
        _CTX = prev


def current_ctx() -> ShardCtx:
    return _CTX


def _prod(xs) -> int:
    out = 1
    for x in xs:
        out *= x
    return out


def axes_that_divide(dim: int, axes: tuple[str, ...], ctx: ShardCtx) -> tuple[str, ...]:
    """Largest prefix of `axes` whose total size divides `dim`."""
    out: list[str] = []
    size = 1
    for a in axes:
        s = ctx.axis_size(a)
        if s <= 1:
            continue
        if dim % (size * s) == 0:
            out.append(a)
            size *= s
        else:
            break
    return tuple(out)


def _norm_elem(dim: int, elem, ctx: ShardCtx):
    """Normalize one PartitionSpec element with divisibility fallback."""
    if elem is None:
        return None
    axes = (elem,) if isinstance(elem, str) else tuple(elem)
    ok = axes_that_divide(dim, axes, ctx)
    if not ok:
        return None
    return ok[0] if len(ok) == 1 else ok


def spec_for(shape: tuple[int, ...], elems: tuple, ctx: ShardCtx | None = None) -> P:
    ctx = ctx or _CTX
    assert len(shape) == len(elems), (shape, elems)
    return P(*[_norm_elem(d, e, ctx) for d, e in zip(shape, elems)])


def constrain(x: jax.Array, *elems) -> jax.Array:
    """with_sharding_constraint with divisibility fallback; no-op w/o mesh."""
    ctx = _CTX
    if ctx.mesh is None:
        return x
    spec = spec_for(x.shape, elems, ctx)
    return jax.lax.with_sharding_constraint(x, NamedSharding(ctx.mesh, spec))


def batch_spec(batch: int, ctx: ShardCtx | None = None):
    """Sharding element for the global-batch dim (DP over pod+data)."""
    ctx = ctx or _CTX
    return axes_that_divide(batch, ctx.present_data_axes, ctx) or None


def occ_epoch_sharding(mesh: Mesh, data_axis: str, pb: int,
                       rank: int) -> NamedSharding:
    """Sharding for the OCC engine's stacked (T, pb, ...) epoch inputs
    (DESIGN.md §5): each epoch's pb points are sharded over `data_axis` —
    the paper's P workers — with divisibility fallback to replication.
    The leading epoch dim stays unsharded (it is the scan axis)."""
    ctx = ShardCtx(mesh=mesh, data_axes=(data_axis,))
    elem = _norm_elem(pb, data_axis, ctx)
    return NamedSharding(mesh, P(None, elem, *([None] * (rank - 2))))


def occ_validate_sharding(mesh: Mesh, rank: int) -> NamedSharding:
    """Replicated sharding for the bounded master's compacted (cap, …)
    validator buffers (DESIGN.md §2/§9/§11): validation is SPMD
    re-execution of the master on every device, so the compaction gather
    happens once and the D-free resolution runs on replicated operands —
    no mid-scan resharding.

    Applied to the compacted inputs AND every precomputed `ValidatePre`
    leaf — the (cap, cap) pairwise / Gram matrices included — at whatever
    cap the epoch runs with: replication has no dimension to split, so the
    adaptive cap's shrunken warm/rest-segment buffers (power-of-two
    bucketed, engine §11) all share this one spec and never retrigger
    layout decisions when the window resizes."""
    return NamedSharding(mesh, P(*([None] * rank)))


def serve_snapshot_sharding(mesh: Mesh, rank: int) -> NamedSharding:
    """Replicated placement for published snapshot buffers (DESIGN.md §10):
    the serving data plane is read-only data parallelism — every device
    answers queries against its own full copy of the (capacity, D) model
    version, so query fan-out needs no center-side collectives at all.
    Same placement as the validator's replicated master; delegated so the
    two stay in lockstep by construction."""
    return occ_validate_sharding(mesh, rank)


def serve_query_sharding(mesh: Mesh, data_axis: str, bucket: int,
                         rank: int) -> NamedSharding:
    """Sharding for a bucket-padded query microbatch: rows split over
    `data_axis` (divisibility fallback to replication — buckets are powers
    of two, so any power-of-two axis divides), trailing dims unsharded.
    With the snapshot replicated, each device scores bucket/|data| queries
    and results concatenate with zero cross-device traffic."""
    ctx = ShardCtx(mesh=mesh, data_axes=(data_axis,))
    elem = _norm_elem(bucket, data_axis, ctx)
    return NamedSharding(mesh, P(elem, *([None] * (rank - 1))))


def res_constrain(x: jax.Array, batch_axes) -> jax.Array:
    """Residual-stream constraint between blocks.

    With seq_shard_acts (sequence parallelism), saved activations are stored
    seq-sharded over the model axis — Megatron-SP style: GSPMD inserts the
    all-gather at the next block's projections and the reduce-scatter after
    its output matmul, cutting per-layer saved-residual memory by |model|.
    """
    ctx = _CTX
    seq = ctx.model_axis if ctx.seq_shard_acts else None
    return constrain(x, batch_axes, seq, None)


# ---------------------------------------------------------------------------
# Parameter sharding rules, keyed on parameter path names.
# Convention: path is a "/"-joined key string from the params dict tree.
# Each rule: (regex, per-dim spec template). Templates may use "DATA" (ZeRO
# axes), "MODEL", None. First match wins; unmatched params are replicated.
# ---------------------------------------------------------------------------

_RULES: list[tuple[str, tuple]] = [
    (r"tok_embed$",            ("MODEL", "DATA")),        # (V, D)
    (r"lm_head$",              ("DATA", "MODEL")),        # (D, V)
    (r"(wq|wg|wu|in_w|dt_w|fe_w1|cross_wq)$", ("DATA", "MODEL")),  # (D, out)
    (r"(wk|wv|cross_wk|cross_wv)$", ("DATA", "MODEL")),   # (D, kv_out)
    (r"(wo|wd|out_w|fe_w2|cross_wo)$", ("MODEL", "DATA")),# (in, D)
    (r"router$",               ("DATA", None)),           # (D, E)
    (r"we_(g|u)$",             ("MODEL", "DATA", None)),  # (E, D, F)
    (r"we_d$",                 ("MODEL", None, "DATA")),  # (E, F, D)
    (r"conv_w$",               (None, "MODEL")),          # (width, inner)
    (r"(a_log|d_skip)$",       ("MODEL",)),               # (H_ssm,)
    (r"(qn|kn|norm\w*|.*_norm|gn)$", (None,)),            # norms: replicated
    (r"(ig_w|fg_w|og_w|zg_w)$", ("DATA", "MODEL")),       # xlstm gate projs
    (r"(ig_r|fg_r|og_r|zg_r)$", (None, None)),            # slstm recurrent (small)
]

# Stacked-per-layer params get a leading L dim (replicated) — handled by rank.


def _spec_template_for(path: str) -> tuple | None:
    for pat, tmpl in _RULES:
        if re.search(pat, path):
            return tmpl
    return None


def param_specs(params: Any, ctx: ShardCtx | None = None) -> Any:
    """PartitionSpec pytree matching `params` (arrays or ShapeDtypeStructs)."""
    ctx = ctx or _CTX

    def resolve(path_elems, leaf) -> P:
        path = "/".join(str(getattr(p, "key", getattr(p, "idx", p))) for p in path_elems)
        tmpl = _spec_template_for(path)
        shape = leaf.shape
        if tmpl is None:
            return P(*([None] * len(shape)))
        tmpl = tuple(tmpl)
        if len(tmpl) < len(shape):          # stacked layer / segment dims
            tmpl = (None,) * (len(shape) - len(tmpl)) + tmpl
        elif len(tmpl) > len(shape):
            tmpl = tmpl[-len(shape):]
        elems = []
        for d, t in zip(shape, tmpl):
            if t == "DATA":
                elems.append(_norm_elem(d, ctx.present_data_axes, ctx) if ctx.zero3 else None)
            elif t == "MODEL":
                elems.append(_norm_elem(d, ctx.model_axis, ctx))
            else:
                elems.append(_norm_elem(d, t, ctx) if t else None)
        return P(*elems)

    return jax.tree_util.tree_map_with_path(resolve, params)


def input_shardings(tree: Any, ctx: ShardCtx | None = None) -> Any:
    """NamedShardings for a spec pytree (helper for jit in_shardings)."""
    ctx = ctx or _CTX
    assert ctx.mesh is not None
    return jax.tree.map(lambda s: NamedSharding(ctx.mesh, s), tree,
                        is_leaf=lambda s: isinstance(s, P))

from repro.distributed.shardings import (
    ShardCtx, shard_ctx, current_ctx, constrain, batch_spec, param_specs,
    input_shardings,
)
from repro.distributed.transport import (
    Transport, ReplicationServer, ReplicationClient, store_digest,
)
from repro.distributed.replication import DeltaChannel, make_follower

"""Unified observability layer (§15): metrics registry + span tracing.

`Obs` bundles the two surfaces every instrumented component takes as an
optional ``obs=`` parameter:

  * ``obs.metrics`` — a `MetricsRegistry` (always present; creating one is
    cheap and components need it for their `metrics()` readouts);
  * ``obs.tracer`` — an optional `Tracer`; when absent, `obs.span(...)` /
    `obs.instant(...)` are no-ops, so tracing costs nothing unless a
    driver passed ``--trace-out``.

Components default to a private `Obs()` when none is supplied, so their
counters always work standalone; drivers pass ONE shared `Obs` down the
stack so engine, transport, WAL, serving, and fault events land in a
single registry and a single per-process trace file.
"""
from __future__ import annotations

from contextlib import nullcontext

from repro.obs.metrics import (Counter, Gauge, Histogram, MetricsRegistry,
                               DEFAULT_BUCKETS, now)
from repro.obs.trace import (Tracer, load_trace, merge_traces,
                             trace_categories, validate_trace)

__all__ = ["Obs", "Counter", "Gauge", "Histogram", "MetricsRegistry",
           "Tracer", "DEFAULT_BUCKETS", "now", "load_trace",
           "merge_traces", "trace_categories", "validate_trace"]

_NULL = nullcontext()


class Obs:
    """Bundle of a metrics registry and an optional tracer."""

    __slots__ = ("metrics", "tracer", "trace_path")

    def __init__(self, registry: MetricsRegistry | None = None,
                 tracer: Tracer | None = None,
                 trace_path: str | None = None):
        self.metrics = MetricsRegistry() if registry is None else registry
        self.tracer = tracer
        self.trace_path = trace_path

    def span(self, name: str, cat: str = "", **args):
        """Trace span context manager; no-op without a tracer."""
        if self.tracer is None:
            return _NULL
        return self.tracer.span(name, cat=cat, args=args or None)

    def instant(self, name: str, cat: str = "", **args) -> None:
        if self.tracer is not None:
            self.tracer.instant(name, cat=cat, args=args or None)

    def flush(self) -> None:
        """Persist the trace now (called before a fault-injected kill so
        the victim's timeline survives `os._exit`)."""
        if self.tracer is not None and self.trace_path is not None:
            self.tracer.save(self.trace_path)

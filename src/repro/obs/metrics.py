"""Thread-safe metrics registry: counters, gauges, fixed-bucket histograms.

One registry instance is the single measurement path for a process: engine
passes, transport acks, WAL fsyncs, serving microbatches, and the CI
regression gate all observe into (and read back from) the same three
instrument kinds.  Design points:

* **Labeled families** — `registry.counter("transport_bytes", dir="out")`
  get-or-creates the `(name, labels)` child; the family pins the
  instrument kind at first use (a name cannot be a counter in one call
  site and a histogram in another).
* **Per-instrument locks** — every `inc`/`set`/`observe` is atomic under
  its own lock, so concurrent writers (admission-queue flusher thread vs
  request threads, replication writer vs reader) never lose updates; the
  unsynchronized read-modify-write races of the old ad-hoc `metrics()`
  dicts are structurally impossible here.
* **Histograms keep exact samples up to a bound** — percentile queries
  (`p50`/`p99` for the serving gate, `min` for best-of-trials benchmark
  metrics) are exact while `count <= sample_limit` and fall back to
  geometric-bucket interpolation after, so long-running servers stay
  O(buckets) while benchmarks stay exact.

Readout is `dump()` (nested plain dict, JSON-safe) or `exposition()`
(Prometheus-style text, served over the coordinator CTRL channel).
"""
from __future__ import annotations

import math
import threading
import time
from contextlib import contextmanager

__all__ = ["Counter", "Ewma", "Gauge", "Histogram", "MetricsRegistry",
           "DEFAULT_BUCKETS", "now"]


def now() -> float:
    """The one sanctioned clock for instrumented code: monotonic seconds.

    On Linux this is CLOCK_MONOTONIC — system-wide, so timestamps taken in
    different processes of one cluster are directly comparable (which is
    what lets per-process trace files merge into one timeline).  Raw
    `time.perf_counter()` / `time.time()` in the instrumented trees is
    rejected by tools/lint_timing.py; call this instead."""
    return time.monotonic()


#: Geometric latency buckets, seconds: 1us .. ~100s, x4 per step.
DEFAULT_BUCKETS = tuple(1e-6 * 4.0 ** i for i in range(13))


def _label_key(labels: dict) -> tuple:
    return tuple(sorted((k, str(v)) for k, v in labels.items()))


def _label_str(key: tuple) -> str:
    return ",".join(f'{k}="{v}"' for k, v in key)


class Counter:
    """Monotonically increasing value; `inc` is atomic."""

    __slots__ = ("_lock", "_value")

    def __init__(self):
        self._lock = threading.Lock()
        self._value = 0.0

    def inc(self, n: float = 1.0) -> None:
        with self._lock:
            self._value += n

    @property
    def value(self) -> float:
        with self._lock:
            return self._value


class Gauge:
    """Last-write-wins value (plus atomic add for up/down tracking)."""

    __slots__ = ("_lock", "_value")

    def __init__(self):
        self._lock = threading.Lock()
        self._value = 0.0

    def set(self, v: float) -> None:
        with self._lock:
            self._value = float(v)

    def add(self, n: float) -> None:
        with self._lock:
            self._value += n

    @property
    def value(self) -> float:
        with self._lock:
            return self._value


class Ewma:
    """Exponentially weighted moving average of observed samples.

    The rolling-rate instrument: observe 1.0 on an event (a deadline
    miss) and 0.0 on a non-event (an on-time flush) and `value` is the
    recent event *rate* with O(1) state — the serving plane's overload
    detector reads it every admission.  The first observation seeds the
    average exactly (no zero-bias warm-up)."""

    __slots__ = ("_lock", "alpha", "_value", "count")

    def __init__(self, alpha: float = 0.2):
        assert 0.0 < alpha <= 1.0
        self._lock = threading.Lock()
        self.alpha = alpha
        self._value = 0.0
        self.count = 0

    def observe(self, v: float) -> None:
        v = float(v)
        with self._lock:
            self._value = (v if self.count == 0
                           else self.alpha * v
                           + (1.0 - self.alpha) * self._value)
            self.count += 1

    @property
    def value(self) -> float:
        with self._lock:
            return self._value


class Histogram:
    """Fixed-bucket histogram with exact percentiles up to `sample_limit`.

    Buckets are upper-bound thresholds (`le` semantics); one overflow
    bucket catches the tail.  While fewer than `sample_limit` samples have
    been observed, `percentile` sorts the raw samples and interpolates
    linearly (numpy-compatible); beyond that it interpolates within the
    matching bucket — bounded memory, ~bucket-resolution accuracy."""

    __slots__ = ("_lock", "buckets", "counts", "count", "total",
                 "_min", "_max", "_samples", "sample_limit")

    def __init__(self, buckets=None, sample_limit: int = 8192):
        self.buckets = tuple(sorted(buckets or DEFAULT_BUCKETS))
        self.counts = [0] * (len(self.buckets) + 1)
        self._lock = threading.Lock()
        self.count = 0
        self.total = 0.0
        self._min = math.inf
        self._max = -math.inf
        self._samples: list[float] = []
        self.sample_limit = sample_limit

    def observe(self, v: float) -> None:
        v = float(v)
        with self._lock:
            i = 0
            while i < len(self.buckets) and v > self.buckets[i]:
                i += 1
            self.counts[i] += 1
            self.count += 1
            self.total += v
            self._min = min(self._min, v)
            self._max = max(self._max, v)
            if len(self._samples) < self.sample_limit:
                self._samples.append(v)

    @property
    def min(self) -> float:
        with self._lock:
            return self._min if self.count else math.nan

    @property
    def max(self) -> float:
        with self._lock:
            return self._max if self.count else math.nan

    @property
    def mean(self) -> float:
        with self._lock:
            return self.total / self.count if self.count else math.nan

    def percentile(self, q: float) -> float:
        """q in [0, 100]."""
        with self._lock:
            if not self.count:
                return math.nan
            if len(self._samples) == self.count:
                xs = sorted(self._samples)
                pos = (q / 100.0) * (len(xs) - 1)
                lo = int(math.floor(pos))
                hi = min(lo + 1, len(xs) - 1)
                return xs[lo] + (xs[hi] - xs[lo]) * (pos - lo)
            # Bucket interpolation: find the bucket holding rank q.
            rank = (q / 100.0) * self.count
            seen = 0
            for i, c in enumerate(self.counts):
                if seen + c >= rank and c > 0:
                    lo = self.buckets[i - 1] if i > 0 else min(
                        self._min, self.buckets[0])
                    hi = (self.buckets[i] if i < len(self.buckets)
                          else self._max)
                    frac = (rank - seen) / c
                    return lo + (hi - lo) * frac
                seen += c
            return self._max

    def snapshot(self) -> dict:
        with self._lock:
            count, total = self.count, self.total
            mn = self._min if count else None
            mx = self._max if count else None
            counts = list(self.counts)
        out = dict(count=count, sum=total, min=mn, max=mx,
                   buckets=list(self.buckets), counts=counts)
        if count:
            out["p50"] = self.percentile(50)
            out["p99"] = self.percentile(99)
        return out


class _Family:
    __slots__ = ("kind", "children", "kwargs")

    def __init__(self, kind, kwargs):
        self.kind = kind
        self.kwargs = kwargs
        self.children: dict[tuple, object] = {}


_KINDS = {"counter": Counter, "gauge": Gauge, "histogram": Histogram,
          "ewma": Ewma}


class MetricsRegistry:
    """Get-or-create registry of labeled instrument families."""

    def __init__(self):
        self._lock = threading.Lock()
        self._families: dict[str, _Family] = {}

    def _get(self, kind: str, name: str, labels: dict, **kwargs):
        key = _label_key(labels)
        with self._lock:
            fam = self._families.get(name)
            if fam is None:
                fam = self._families[name] = _Family(kind, kwargs)
            elif fam.kind != kind:
                raise TypeError(
                    f"metric {name!r} is a {fam.kind}, requested {kind}")
            child = fam.children.get(key)
            if child is None:
                child = fam.children[key] = _KINDS[kind](**fam.kwargs)
            return child

    def counter(self, name: str, **labels) -> Counter:
        return self._get("counter", name, labels)

    def gauge(self, name: str, **labels) -> Gauge:
        return self._get("gauge", name, labels)

    def histogram(self, name: str, buckets=None, **labels) -> Histogram:
        return self._get("histogram", name, labels, buckets=buckets)

    def ewma(self, name: str, alpha: float = 0.2, **labels) -> Ewma:
        """Rolling-rate instrument (see `Ewma`); `alpha` is pinned at the
        family's first use, like histogram buckets."""
        return self._get("ewma", name, labels, alpha=alpha)

    @contextmanager
    def timer(self, name: str, **labels):
        """Observe the elapsed monotonic seconds of the with-block into
        `histogram(name, **labels)` — the benchmark measurement path."""
        h = self.histogram(name, **labels)
        t0 = now()
        try:
            yield h
        finally:
            h.observe(now() - t0)

    def value(self, name: str, **labels) -> float:
        """Scalar readback: counter/gauge value (0.0 if never touched)."""
        key = _label_key(labels)
        with self._lock:
            fam = self._families.get(name)
            child = fam.children.get(key) if fam else None
        return child.value if child is not None else 0.0

    def get_histogram(self, name: str, **labels) -> Histogram | None:
        key = _label_key(labels)
        with self._lock:
            fam = self._families.get(name)
            if fam is None or fam.kind != "histogram":
                return None
            return fam.children.get(key)

    def names(self) -> list[str]:
        with self._lock:
            return sorted(self._families)

    def dump(self) -> dict:
        """JSON-safe nested dict of every family and child."""
        with self._lock:
            items = [(name, fam.kind, dict(fam.children))
                     for name, fam in sorted(self._families.items())]
        out = {}
        for name, kind, children in items:
            vals = {}
            for key, child in sorted(children.items()):
                label = _label_str(key)
                if kind == "histogram":
                    vals[label] = child.snapshot()
                else:
                    vals[label] = child.value
            out[name] = {"type": kind, "values": vals}
        return out

    def exposition(self) -> str:
        """Prometheus-style text exposition (the CTRL-channel endpoint)."""
        lines = []
        for name, fam in self.dump().items():
            lines.append(f"# TYPE {name} {fam['type']}")
            for label, val in fam["values"].items():
                tag = f"{{{label}}}" if label else ""
                if fam["type"] == "histogram":
                    lines.append(f"{name}_count{tag} {val['count']}")
                    lines.append(f"{name}_sum{tag} {val['sum']:.9g}")
                    if val["count"]:
                        lines.append(f"{name}_p50{tag} {val['p50']:.9g}")
                        lines.append(f"{name}_p99{tag} {val['p99']:.9g}")
                else:
                    lines.append(f"{name}{tag} {val:.9g}")
        return "\n".join(lines) + "\n"

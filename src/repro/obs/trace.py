"""Span tracing with Chrome-trace / Perfetto JSON export.

A `Tracer` collects trace events for ONE process; each event carries the
Chrome trace-event-format fields (`name`, `cat`, `ph`, `ts` in
microseconds, `pid`, `tid`, optional `dur`/`args`):

  * ``span(...)``   — context manager → one complete event (ph "X");
  * ``instant(...)``— point event (ph "i"), e.g. an injected fault or a
    promotion decision;
  * ``counter(...)``— sampled series (ph "C"), e.g. queue depth over time.

Thread tracks name themselves lazily: the first event emitted from a
thread records a `thread_name` metadata event, so the admission-queue
flusher, replication writer threads, and worker receive loops each get
their own labeled row in the Perfetto UI for free.

Timestamps come from an injectable `clock` (default: the shared monotonic
clock in `obs.metrics.now`).  Because CLOCK_MONOTONIC is system-wide on
Linux, traces written by different processes of one cluster run share a
timebase — `merge_traces` just concatenates their `traceEvents` and the
per-process `pid` keeps the tracks separate.  `validate_trace` is the
schema check used by tests: spans must nest properly and start times must
be monotone per (pid, tid) track.
"""
from __future__ import annotations

import json
import os
import threading

from repro.obs.metrics import now as _monotonic

__all__ = ["Tracer", "load_trace", "merge_traces", "validate_trace",
           "trace_categories"]


class Tracer:
    """Per-process trace-event collector (thread-safe)."""

    def __init__(self, process_name: str | None = None,
                 pid: int | None = None, clock=None):
        self.pid = os.getpid() if pid is None else int(pid)
        self.clock = clock or _monotonic
        self._lock = threading.Lock()
        self._events: list[dict] = []
        self._named_tids: set[int] = set()
        if process_name is not None:
            self._emit(dict(name="process_name", ph="M", pid=self.pid,
                            tid=0, ts=0,
                            args={"name": str(process_name)}))

    # -- internals ---------------------------------------------------------
    def _emit(self, ev: dict) -> None:
        with self._lock:
            self._events.append(ev)

    def _tid(self, tid: int | None) -> int:
        if tid is None:
            t = threading.current_thread()
            tid = t.ident or 0
            if tid not in self._named_tids:
                with self._lock:
                    if tid in self._named_tids:
                        return tid
                    self._named_tids.add(tid)
                    self._events.append(dict(
                        name="thread_name", ph="M", pid=self.pid, tid=tid,
                        ts=0, args={"name": t.name}))
        return tid

    def _us(self) -> float:
        return self.clock() * 1e6

    # -- event API ---------------------------------------------------------
    def set_thread_name(self, name: str, tid: int | None = None) -> None:
        tid = self._tid(tid)
        self._emit(dict(name="thread_name", ph="M", pid=self.pid, tid=tid,
                        ts=0, args={"name": str(name)}))

    def span(self, name: str, cat: str = "", args: dict | None = None,
             tid: int | None = None) -> "_Span":
        return _Span(self, name, cat, args, tid)

    def complete(self, name: str, ts_us: float, dur_us: float,
                 cat: str = "", args: dict | None = None,
                 tid: int | None = None) -> None:
        """Record an already-measured interval (post-pass stats export)."""
        ev = dict(name=name, cat=cat, ph="X", ts=float(ts_us),
                  dur=max(0.0, float(dur_us)), pid=self.pid,
                  tid=self._tid(tid))
        if args:
            ev["args"] = args
        self._emit(ev)

    def instant(self, name: str, cat: str = "", args: dict | None = None,
                tid: int | None = None) -> None:
        ev = dict(name=name, cat=cat, ph="i", s="t", ts=self._us(),
                  pid=self.pid, tid=self._tid(tid))
        if args:
            ev["args"] = args
        self._emit(ev)

    def counter(self, name: str, values: dict, cat: str = "",
                tid: int | None = None) -> None:
        self._emit(dict(name=name, cat=cat, ph="C", ts=self._us(),
                        pid=self.pid, tid=self._tid(tid),
                        args={k: float(v) for k, v in values.items()}))

    # -- export ------------------------------------------------------------
    def events(self) -> list[dict]:
        with self._lock:
            return list(self._events)

    def to_chrome(self) -> dict:
        return {"traceEvents": self.events(), "displayTimeUnit": "ms"}

    def json_bytes(self) -> bytes:
        """Canonical bytes (sorted keys, fixed separators) — the byte-level
        golden-fixture representation."""
        return json.dumps(self.to_chrome(), sort_keys=True,
                          separators=(",", ":")).encode()

    def save(self, path: str) -> None:
        tmp = path + ".tmp"
        with open(tmp, "w") as f:
            json.dump(self.to_chrome(), f)
        os.replace(tmp, path)


class _Span:
    """Context manager recording one complete ("X") event on exit."""

    __slots__ = ("tracer", "name", "cat", "args", "tid", "t0")

    def __init__(self, tracer, name, cat, args, tid):
        self.tracer, self.name, self.cat = tracer, name, cat
        self.args = dict(args) if args else None
        self.tid = tid

    def __enter__(self):
        self.tid = self.tracer._tid(self.tid)
        self.t0 = self.tracer._us()
        return self

    def set(self, **kw) -> None:
        """Attach result args discovered inside the span."""
        if self.args is None:
            self.args = {}
        self.args.update(kw)

    def __exit__(self, exc_type, exc, tb):
        t1 = self.tracer._us()
        ev = dict(name=self.name, cat=self.cat, ph="X", ts=self.t0,
                  dur=t1 - self.t0, pid=self.tracer.pid, tid=self.tid)
        if exc_type is not None:
            self.set(error=exc_type.__name__)
        if self.args:
            ev["args"] = self.args
        self.tracer._emit(ev)
        return False


def load_trace(path: str) -> dict:
    with open(path) as f:
        return json.load(f)


def merge_traces(out_path: str, *sources) -> dict:
    """Concatenate traceEvents from tracers / trace dicts / trace-file
    paths into one Chrome trace (valid because all processes share the
    system-wide monotonic timebase; pids keep tracks distinct)."""
    events: list[dict] = []
    for src in sources:
        if isinstance(src, Tracer):
            events.extend(src.events())
        elif isinstance(src, dict):
            events.extend(src.get("traceEvents", []))
        else:
            try:
                events.extend(load_trace(src).get("traceEvents", []))
            except (OSError, ValueError):
                continue        # a crashed process may leave no/torn file
    merged = {"traceEvents": events, "displayTimeUnit": "ms"}
    if out_path:
        tmp = out_path + ".tmp"
        with open(tmp, "w") as f:
            json.dump(merged, f)
        os.replace(tmp, out_path)
    return merged


def validate_trace(trace: dict) -> list[str]:
    """Schema check; returns a list of problems (empty = valid).

    Enforced invariants: required fields per phase type, non-negative
    durations, monotone start times per (pid, tid) track, and proper
    nesting of complete events within a track (a span that starts inside
    an enclosing span must also end inside it)."""
    problems: list[str] = []
    events = trace.get("traceEvents")
    if not isinstance(events, list):
        return ["traceEvents missing or not a list"]
    tracks: dict[tuple, list[dict]] = {}
    for i, ev in enumerate(events):
        for field in ("name", "ph", "ts", "pid", "tid"):
            if field not in ev:
                problems.append(f"event {i} missing {field!r}")
                break
        else:
            if ev["ph"] == "X":
                if "dur" not in ev:
                    problems.append(f"event {i} ({ev['name']}) ph=X "
                                    f"missing dur")
                elif ev["dur"] < 0:
                    problems.append(f"event {i} ({ev['name']}) dur < 0")
                else:
                    tracks.setdefault((ev["pid"], ev["tid"]),
                                      []).append(ev)
    for (pid, tid), evs in tracks.items():
        last_ts = -float("inf")
        stack: list[tuple[float, float, str]] = []   # (end, start, name)
        for ev in sorted(evs, key=lambda e: (e["ts"], -e["dur"])):
            if ev["ts"] < last_ts:
                problems.append(
                    f"track ({pid},{tid}): ts not monotone at "
                    f"{ev['name']}")
            last_ts = ev["ts"]
            end = ev["ts"] + ev["dur"]
            while stack and ev["ts"] >= stack[-1][0] - 1e-6:
                stack.pop()
            if stack and end > stack[-1][0] + 1e-6:
                problems.append(
                    f"track ({pid},{tid}): span {ev['name']!r} "
                    f"[{ev['ts']:.1f},{end:.1f}] overlaps but does not "
                    f"nest in {stack[-1][2]!r} ending {stack[-1][0]:.1f}")
            stack.append((end, ev["ts"], ev["name"]))
    return problems


def trace_categories(trace: dict) -> set[str]:
    """Distinct non-metadata categories present (subsystem coverage)."""
    return {ev.get("cat", "") for ev in trace.get("traceEvents", [])
            if ev.get("ph") != "M" and ev.get("cat")}

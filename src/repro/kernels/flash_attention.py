"""Causal flash-attention forward kernel (Pallas TPU), GQA-aware.

Used on the prefill / serving path.  Streaming softmax with running
(max, sum, acc) scratch carried across KV tiles; KV tiles strictly above the
diagonal are skipped via pl.when (the TPU grid is sequential, so skipped
steps cost nothing).  GQA: the kv-head index map is h // group, so grouped
KV is never materialized per-query-head in HBM.

Block defaults (bq=bk=128, Dh<=256) keep the VMEM working set
(bq*Dh + 2*bk*Dh + bq*bk floats) small and MXU-aligned.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

__all__ = ["flash_attention"]

NEG_INF = -1e30


def _fa_kernel(q_ref, k_ref, v_ref, o_ref, m_scr, l_scr, acc_scr,
               *, scale: float, bq: int, bk: int, causal: bool, nk: int):
    qb = pl.program_id(1)
    kb = pl.program_id(2)

    @pl.when(kb == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    # Causal: KV tile fully above the diagonal contributes nothing.
    needed = (not causal) or (kb * bk <= qb * bq + bq - 1)

    @pl.when(needed)
    def _body():
        q = q_ref[0].astype(jnp.float32) * scale        # (bq, dh)
        k = k_ref[0].astype(jnp.float32)                # (bk, dh)
        v = v_ref[0].astype(jnp.float32)                # (bk, dh)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32)  # (bq, bk)
        if causal:
            qi = qb * bq + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0)
            ki = kb * bk + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
            s = jnp.where(ki <= qi, s, NEG_INF)

        m_prev = m_scr[...]
        l_prev = l_scr[...]
        m_cur = jnp.max(s, axis=-1)
        m_new = jnp.maximum(m_prev, m_cur)
        p = jnp.exp(s - m_new[:, None])
        alpha = jnp.exp(m_prev - m_new)
        l_new = alpha * l_prev + jnp.sum(p, axis=-1)
        acc_scr[...] = acc_scr[...] * alpha[:, None] + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32)
        m_scr[...] = m_new
        l_scr[...] = l_new

    @pl.when(kb == nk - 1)
    def _finalize():
        l = jnp.maximum(l_scr[...], 1e-30)
        o_ref[0] = (acc_scr[...] / l[:, None]).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("causal", "block_q", "block_k",
                                             "scale", "interpret"))
def flash_attention(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray,
                    causal: bool = True, scale: float | None = None,
                    block_q: int = 128, block_k: int = 128,
                    interpret: bool = False):
    """q: (B, H, S, Dh); k, v: (B, Hkv, S, Dh) with H % Hkv == 0."""
    b, h, s, dh = q.shape
    hkv = k.shape[1]
    assert h % hkv == 0, (h, hkv)
    group = h // hkv
    if scale is None:
        scale = dh ** -0.5
    bq = min(block_q, s)
    bk = min(block_k, s)
    assert s % bq == 0 and s % bk == 0, "seq must divide block sizes"
    nq, nk = s // bq, s // bk
    grid = (b * h, nq, nk)

    def q_map(bh, i, j):
        return (bh, i, 0)

    # kv head for flattened (b*h) index: b_idx = bh // h ; kv = (bh % h) // group
    def kv_index(bh, i, j):
        b_idx = bh // h
        kv_h = (bh % h) // group
        return (b_idx * hkv + kv_h, j, 0)

    qf = q.reshape(b * h, s, dh)
    kf = k.reshape(b * hkv, s, dh)
    vf = v.reshape(b * hkv, s, dh)

    out = pl.pallas_call(
        functools.partial(_fa_kernel, scale=scale, bq=bq, bk=bk,
                          causal=causal, nk=nk),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, bq, dh), q_map),
            pl.BlockSpec((1, bk, dh), kv_index),
            pl.BlockSpec((1, bk, dh), kv_index),
        ],
        out_specs=pl.BlockSpec((1, bq, dh), q_map),
        out_shape=jax.ShapeDtypeStruct((b * h, s, dh), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq,), jnp.float32),
            pltpu.VMEM((bq,), jnp.float32),
            pltpu.VMEM((bq, dh), jnp.float32),
        ],
        interpret=interpret,
    )(qf, kf, vf)
    return out.reshape(b, h, s, dh)

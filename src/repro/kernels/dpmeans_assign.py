"""Pallas TPU kernel for the OCC hot loop: pairwise sq-distance + argmin.

TPU adaptation of the paper's `argmin_{mu in C} ||x - mu||` (DESIGN.md §6/§9):
instead of a GPU-style point-per-thread gather, the distance matrix block is
an MXU matmul (||x||^2 + ||mu||^2 - 2 x mu^T) with a *running* min/argmin
carried across center tiles — the same streaming-reduction structure as
flash attention's running softmax.

Grid: (n_blocks, k_blocks); the k axis is the sequential ("arbitrary")
dimension so output tiles are revisited and accumulated in place.
VMEM working set per step: bn*D (points) + bk*D (centers) + bn*bk (distances)
— block defaults keep this well under a v5e core's ~16 MiB VMEM budget with
D up to 8192.

Active-prefix restriction: the pool's valid slots are a prefix (centers are
appended serially), so `k_active` — the pool count, a *traced* scalar passed
as a scalar-prefetch operand — restricts the work to the count-rounded
prefix twice over:

  * compute: `pl.when` skips the kernel body for tiles at or beyond the
    prefix, so skipped tiles do no MXU/VPU work;
  * HBM traffic: the center/mask BlockSpec index maps (which receive the
    prefetched scalar *before* the kernel body runs) clamp the block index
    at the last active tile, so the pipeline re-addresses an
    already-resident block instead of DMAing a dead one — Pallas elides the
    copy when consecutive grid steps map to the same block.

The grid stays static (K_max tiles, JAX needs static shapes) but both the
compute AND the HBM transfer per epoch track the *occupied* pool size
rather than the K_max capacity.

`dpmeans_assign_emulate` is a vmapped jnp re-implementation of the exact
kernel schedule (same tiles, same f32 accumulation, same running-argmin
tie-breaking, same prefix skipping) — the fast stand-in for interpret mode,
whose per-grid-step Python loop is too slow to parity-check production
shapes (serving buckets) in CI.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

__all__ = ["dpmeans_assign", "dpmeans_assign_emulate"]


def _assign_kernel(k_active_ref, x_ref, c_ref, mask_ref, d2_ref, idx_ref, *,
                   bk: int):
    kb = pl.program_id(1)

    @pl.when(kb == 0)
    def _init():
        d2_ref[...] = jnp.full_like(d2_ref, jnp.inf)
        idx_ref[...] = jnp.full_like(idx_ref, -1)

    # Skip whole center tiles beyond the active prefix: every slot in the
    # tile is masked out anyway, so the running min/argmin cannot change.
    @pl.when(kb * bk < k_active_ref[0])
    def _work():
        x = x_ref[...].astype(jnp.float32)            # (bn, D)
        c = c_ref[...].astype(jnp.float32)            # (bk, D)
        m = mask_ref[...]                             # (bk,)

        x2 = jnp.sum(x * x, axis=-1, keepdims=True)   # (bn, 1)
        c2 = jnp.sum(c * c, axis=-1)[None, :]         # (1, bk)
        # MXU: the only O(bn*bk*D) term is a single matmul.
        d2 = jnp.maximum(x2 + c2 - 2.0 * jax.lax.dot_general(
            x, c, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32), 0.0)
        d2 = jnp.where(m[None, :], d2, jnp.inf)       # masked-out centers

        loc_min = jnp.min(d2, axis=-1)                # (bn,)
        loc_idx = jnp.argmin(d2, axis=-1).astype(jnp.int32) + kb * bk

        run_min = d2_ref[...]
        run_idx = idx_ref[...]
        better = loc_min < run_min
        d2_ref[...] = jnp.where(better, loc_min, run_min)
        idx_ref[...] = jnp.where(better, loc_idx, run_idx)


@functools.partial(jax.jit,
                   static_argnames=("block_n", "block_k", "interpret"))
def dpmeans_assign(x: jnp.ndarray, centers: jnp.ndarray, mask: jnp.ndarray,
                   count: jnp.ndarray | None = None,
                   block_n: int = 256, block_k: int = 128,
                   interpret: bool = False):
    """Min squared distance and argmin over masked centers.

    x: (N, D), centers: (K, D), mask: (K,) bool.  `count` (traced scalar,
    optional) bounds the valid prefix — center tiles at index >= count are
    skipped entirely (mask must already be False there; the pool invariant
    guarantees it).  Returns (d2min (N,) f32, idx (N,) int32, -1 where no
    valid center).  N, K are padded to block multiples internally.
    """
    n, d = x.shape
    k = centers.shape[0]
    bn = min(block_n, max(8, n))
    bk = min(block_k, max(8, k))
    n_pad = (-n) % bn
    k_pad = (-k) % bk
    if n_pad:
        x = jnp.concatenate([x, jnp.zeros((n_pad, d), x.dtype)], 0)
    if k_pad:
        centers = jnp.concatenate([centers, jnp.zeros((k_pad, d), centers.dtype)], 0)
        mask = jnp.concatenate([mask, jnp.zeros((k_pad,), bool)], 0)
    np_, kp = x.shape[0], centers.shape[0]
    k_active = jnp.full((1,), k if count is None else count, jnp.int32)

    # Scalar-prefetch index map: clamp the center-tile index at the last
    # active tile.  The prefetched count is known before the kernel body,
    # so the pipeline addresses tile min(j, last_active) — a block already
    # in VMEM for every skipped step — and the dead tiles' HBM DMA is
    # elided along with their compute (the `pl.when` in the body).
    def _center_tile(i, j, k_ref):
        last = jnp.maximum((k_ref[0] + bk - 1) // bk, 1) - 1
        return jnp.minimum(j, last), 0

    def _mask_tile(i, j, k_ref):
        return _center_tile(i, j, k_ref)[0]

    grid = (np_ // bn, kp // bk)
    d2, idx = pl.pallas_call(
        functools.partial(_assign_kernel, bk=bk),
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=1,
            grid=grid,
            in_specs=[
                pl.BlockSpec((bn, d), lambda i, j, k_ref: (i, 0)),
                pl.BlockSpec((bk, d), _center_tile),
                pl.BlockSpec((bk,), _mask_tile),
            ],
            out_specs=[
                pl.BlockSpec((bn,), lambda i, j, k_ref: (i,)),
                pl.BlockSpec((bn,), lambda i, j, k_ref: (i,)),
            ],
        ),
        out_shape=[
            jax.ShapeDtypeStruct((np_,), jnp.float32),
            jax.ShapeDtypeStruct((np_,), jnp.int32),
        ],
        interpret=interpret,
    )(k_active, x, centers, mask)
    return d2[:n], idx[:n]


@functools.partial(jax.jit, static_argnames=("block_n", "block_k"))
def dpmeans_assign_emulate(x: jnp.ndarray, centers: jnp.ndarray,
                           mask: jnp.ndarray,
                           count: jnp.ndarray | None = None,
                           block_n: int = 256, block_k: int = 128):
    """Vmapped emulation of the Pallas kernel's exact schedule.

    Same contract as `dpmeans_assign`, computed as vmap-over-n-blocks of a
    scan-over-k-tiles that mirrors the kernel body op for op: identical
    padding/clamping, the same f32 `dot_general` per tile, per-tile argmin
    + running strict-< merge (so cross-tile ties resolve to the lower tile
    exactly as the kernel does), and count-based tile skipping.  Runs as
    ONE compiled XLA computation — no per-grid-step Python — so production
    shapes (serving buckets, large K_max) can be parity-checked in CI where
    interpret mode would take minutes.
    """
    n, d = x.shape
    k = centers.shape[0]
    bn = min(block_n, max(8, n))
    bk = min(block_k, max(8, k))
    n_pad = (-n) % bn
    k_pad = (-k) % bk
    if n_pad:
        x = jnp.concatenate([x, jnp.zeros((n_pad, d), x.dtype)], 0)
    if k_pad:
        centers = jnp.concatenate(
            [centers, jnp.zeros((k_pad, d), centers.dtype)], 0)
        mask = jnp.concatenate([mask, jnp.zeros((k_pad,), bool)], 0)
    k_active = jnp.asarray(k if count is None else count, jnp.int32)

    xb = x.reshape(-1, bn, d)
    cb = centers.reshape(-1, bk, d)
    mb = mask.reshape(-1, bk)
    kbs = jnp.arange(cb.shape[0], dtype=jnp.int32)

    def one_block(xblk):
        xf = xblk.astype(jnp.float32)
        x2 = jnp.sum(xf * xf, axis=-1, keepdims=True)

        def tile(carry, inp):
            run_min, run_idx = carry
            kb, c, m = inp
            cf = c.astype(jnp.float32)
            c2 = jnp.sum(cf * cf, axis=-1)[None, :]
            d2 = jnp.maximum(x2 + c2 - 2.0 * jax.lax.dot_general(
                xf, cf, (((1,), (1,)), ((), ())),
                preferred_element_type=jnp.float32), 0.0)
            d2 = jnp.where(m[None, :], d2, jnp.inf)
            loc_min = jnp.min(d2, axis=-1)
            loc_idx = jnp.argmin(d2, axis=-1).astype(jnp.int32) + kb * bk
            better = jnp.logical_and(loc_min < run_min, kb * bk < k_active)
            return (jnp.where(better, loc_min, run_min),
                    jnp.where(better, loc_idx, run_idx)), None

        init = (jnp.full((bn,), jnp.inf, jnp.float32),
                jnp.full((bn,), -1, jnp.int32))
        (d2m, idxm), _ = jax.lax.scan(tile, init, (kbs, cb, mb))
        return d2m, idxm

    d2, idx = jax.vmap(one_block)(xb)
    return d2.reshape(-1)[:n], idx.reshape(-1)[:n]

"""Pallas TPU kernel for the OCC hot loop: pairwise sq-distance + argmin.

TPU adaptation of the paper's `argmin_{mu in C} ||x - mu||` (DESIGN.md §6/§9):
instead of a GPU-style point-per-thread gather, the distance matrix block is
an MXU matmul (||x||^2 + ||mu||^2 - 2 x mu^T) with a *running* min/argmin
carried across center tiles — the same streaming-reduction structure as
flash attention's running softmax.

Grid: (n_blocks, k_blocks); the k axis is the sequential ("arbitrary")
dimension so output tiles are revisited and accumulated in place.
VMEM working set per step: bn*D (points) + bk*D (centers) + bn*bk (distances)
— block defaults keep this well under a v5e core's ~16 MiB VMEM budget with
D up to 8192.

Active-prefix restriction: the pool's valid slots are a prefix (centers are
appended serially), so `k_active` — the pool count, a *traced* scalar passed
through SMEM — lets the kernel skip every center tile that starts at or
beyond the count-rounded prefix.  The grid stays static (K_max tiles, JAX
needs static shapes) but skipped tiles do no MXU/VPU work, so per-epoch
propose cost tracks the *occupied* pool size rather than the K_max capacity.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

__all__ = ["dpmeans_assign"]


def _assign_kernel(k_active_ref, x_ref, c_ref, mask_ref, d2_ref, idx_ref, *,
                   bk: int):
    kb = pl.program_id(1)

    @pl.when(kb == 0)
    def _init():
        d2_ref[...] = jnp.full_like(d2_ref, jnp.inf)
        idx_ref[...] = jnp.full_like(idx_ref, -1)

    # Skip whole center tiles beyond the active prefix: every slot in the
    # tile is masked out anyway, so the running min/argmin cannot change.
    @pl.when(kb * bk < k_active_ref[0])
    def _work():
        x = x_ref[...].astype(jnp.float32)            # (bn, D)
        c = c_ref[...].astype(jnp.float32)            # (bk, D)
        m = mask_ref[...]                             # (bk,)

        x2 = jnp.sum(x * x, axis=-1, keepdims=True)   # (bn, 1)
        c2 = jnp.sum(c * c, axis=-1)[None, :]         # (1, bk)
        # MXU: the only O(bn*bk*D) term is a single matmul.
        d2 = jnp.maximum(x2 + c2 - 2.0 * jax.lax.dot_general(
            x, c, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32), 0.0)
        d2 = jnp.where(m[None, :], d2, jnp.inf)       # masked-out centers

        loc_min = jnp.min(d2, axis=-1)                # (bn,)
        loc_idx = jnp.argmin(d2, axis=-1).astype(jnp.int32) + kb * bk

        run_min = d2_ref[...]
        run_idx = idx_ref[...]
        better = loc_min < run_min
        d2_ref[...] = jnp.where(better, loc_min, run_min)
        idx_ref[...] = jnp.where(better, loc_idx, run_idx)


@functools.partial(jax.jit,
                   static_argnames=("block_n", "block_k", "interpret"))
def dpmeans_assign(x: jnp.ndarray, centers: jnp.ndarray, mask: jnp.ndarray,
                   count: jnp.ndarray | None = None,
                   block_n: int = 256, block_k: int = 128,
                   interpret: bool = False):
    """Min squared distance and argmin over masked centers.

    x: (N, D), centers: (K, D), mask: (K,) bool.  `count` (traced scalar,
    optional) bounds the valid prefix — center tiles at index >= count are
    skipped entirely (mask must already be False there; the pool invariant
    guarantees it).  Returns (d2min (N,) f32, idx (N,) int32, -1 where no
    valid center).  N, K are padded to block multiples internally.
    """
    n, d = x.shape
    k = centers.shape[0]
    bn = min(block_n, max(8, n))
    bk = min(block_k, max(8, k))
    n_pad = (-n) % bn
    k_pad = (-k) % bk
    if n_pad:
        x = jnp.concatenate([x, jnp.zeros((n_pad, d), x.dtype)], 0)
    if k_pad:
        centers = jnp.concatenate([centers, jnp.zeros((k_pad, d), centers.dtype)], 0)
        mask = jnp.concatenate([mask, jnp.zeros((k_pad,), bool)], 0)
    np_, kp = x.shape[0], centers.shape[0]
    k_active = jnp.full((1,), k if count is None else count, jnp.int32)

    grid = (np_ // bn, kp // bk)
    d2, idx = pl.pallas_call(
        functools.partial(_assign_kernel, bk=bk),
        grid=grid,
        in_specs=[
            pl.BlockSpec(memory_space=pltpu.SMEM),
            pl.BlockSpec((bn, d), lambda i, j: (i, 0)),
            pl.BlockSpec((bk, d), lambda i, j: (j, 0)),
            pl.BlockSpec((bk,), lambda i, j: (j,)),
        ],
        out_specs=[
            pl.BlockSpec((bn,), lambda i, j: (i,)),
            pl.BlockSpec((bn,), lambda i, j: (i,)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((np_,), jnp.float32),
            jax.ShapeDtypeStruct((np_,), jnp.int32),
        ],
        interpret=interpret,
    )(k_active, x, centers, mask)
    return d2[:n], idx[:n]

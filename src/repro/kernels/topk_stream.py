"""Pallas TPU kernels for streaming serve-time top-k (DESIGN.md §16).

The retrieval-serving hot path: k nearest centers per query at center
counts where materializing the full (B, K) distance matrix is the cost.
Same streaming-reduction shape as `dpmeans_assign` (and flash attention's
running softmax), with the running scalar min generalized to a running
top-k candidate buffer:

  * Grid (n_blocks, k_tiles); the tile axis is sequential, so the (bn, k)
    output block is revisited and merged in place.  No (bn, K) row ever
    exists — VMEM holds bn*D (queries) + bk*D (one center tile) + bn*bk
    (one distance tile) + 2*bn*k (candidates).
  * Per tile: ONE f32 MXU matmul produces the (bn, bk) distance tile, then
    `ref.topk_merge_ref` folds it into the running candidates — k unrolled
    lexicographic-(d2, id) extraction steps over (bn, k + bk).  The merge
    is O(k*(k+bk)) VPU work per row against O(bk*D) MXU work for the tile,
    so for k << D the matmul still dominates (cost model in §16).
  * Active-prefix DMA skip: the center count rides in as a scalar-prefetch
    operand.  `pl.when` skips dead tiles' compute, and the BlockSpec index
    maps clamp the tile index at the last active tile so the pipeline
    re-addresses a block already resident in VMEM — Pallas elides the copy
    when consecutive grid steps map to the same block, so tiles beyond the
    active prefix issue ZERO HBM loads.  `topk_tile_loads` is the exact
    accounting of that index-map sequence; the emulate paths return it so
    CI can assert the elision arithmetic at production shapes.

`topk_multiprobe_stream` is the two-level variant serving hierarchical
snapshots (serving/snapshot.build_hier): the scalar-prefetch operands are
the microbatch's probed-cell union (packed ascending) plus its length, and
the center-tile index map reads `cells_ref[j]` — the GATHER HAPPENS IN THE
INDEX MAP, so unprobed shards never leave HBM at all; there is no
materialized (U, S, D) gather buffer.  A per-(query, cell) `member` mask
restricts each query to its own probed cells, which keeps the union
computation microbatch-shared (a requirement: only shared 2-D matmuls are
bitwise-reproducible against the flat kernel — DESIGN.md §16).

Selection is by lexicographic (d2, original id), which equals
`lax.top_k`'s lower-index-first tie order and is invariant to candidate
tiling/ordering — so for f32 inputs flat kernel == multiprobe kernel ==
`ref.topk_ref` bit-exactly (the D-contraction is never split, so even the
distances are bitwise equal), across every block size.  The `*_emulate`
twins replay the exact kernel schedule as vmapped jnp at compiled speed
(interpret mode cannot sweep production shapes in CI time).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.ref import TOPK_SENTINEL, topk_merge_ref

__all__ = ["topk_stream", "topk_stream_emulate", "topk_multiprobe_stream",
           "topk_multiprobe_emulate", "topk_tile_loads"]


def topk_tile_loads(count: int, k_total: int, block_k: int = 128) -> int:
    """Center-tile HBM loads one row-block sweep of the flat kernel issues.

    Walks the clamped index-map sequence literally: the pipeline DMAs a
    block only when the mapped index changes between consecutive grid
    steps, so loads == the number of distinct consecutive mapped indices
    == max(1, ceil(count/bk)) — and tiles beyond the active prefix
    contribute zero.  Tests assert the emulate paths' on-device accounting
    against this host-side walk.
    """
    bk = min(block_k, max(8, k_total))
    k_pad = (-k_total) % bk
    k_tiles = (k_total + k_pad) // bk
    last = max((count + bk - 1) // bk, 1) - 1
    loads, prev = 0, None
    for j in range(k_tiles):
        mapped = min(j, last)
        if mapped != prev:
            loads += 1
        prev = mapped
    return loads


def _finalize(d2, idx):
    """Shared post-pass: exhausted candidate slots surface as (inf, -1)."""
    return d2, jnp.where(jnp.isfinite(d2), idx, -1).astype(jnp.int32)


# ---------------------------------------------------------------------------
# Flat streaming kernel
# ---------------------------------------------------------------------------

def _topk_kernel(k_active_ref, x_ref, c_ref, mask_ref, d2_ref, idx_ref, *,
                 bk: int, kk: int):
    kb = pl.program_id(1)

    @pl.when(kb == 0)
    def _init():
        d2_ref[...] = jnp.full_like(d2_ref, jnp.inf)
        idx_ref[...] = jnp.full_like(idx_ref, TOPK_SENTINEL)

    @pl.when(kb * bk < k_active_ref[0])
    def _work():
        x = x_ref[...].astype(jnp.float32)            # (bn, D)
        c = c_ref[...].astype(jnp.float32)            # (bk, D)
        m = mask_ref[...]                             # (bk,)
        bn = x.shape[0]

        x2 = jnp.sum(x * x, axis=-1, keepdims=True)
        c2 = jnp.sum(c * c, axis=-1)[None, :]
        d2 = jnp.maximum(x2 + c2 - 2.0 * jax.lax.dot_general(
            x, c, (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32), 0.0)
        d2 = jnp.where(m[None, :], d2, jnp.inf)
        ids = (jax.lax.broadcasted_iota(jnp.int32, (bn, bk), 1) + kb * bk)

        nd, ni = topk_merge_ref(d2_ref[...], idx_ref[...], d2, ids, kk)
        d2_ref[...] = nd
        idx_ref[...] = ni


@functools.partial(jax.jit,
                   static_argnames=("k", "block_n", "block_k", "interpret"))
def topk_stream(x: jnp.ndarray, centers: jnp.ndarray, mask: jnp.ndarray,
                k: int, count: jnp.ndarray | None = None,
                block_n: int = 256, block_k: int = 128,
                interpret: bool = False):
    """k nearest centers, streamed: (d2 (N, k) f32 ascending, idx (N, k)).

    x (N, D), centers (K, D), mask (K,) bool, `count` an optional traced
    scalar bounding the valid prefix (tiles at/after it skip compute AND
    HBM DMA).  Ties break by lower index; exhausted slots are (inf, -1).
    k is a compile-time constant and should stay small (the merge unrolls
    k extraction steps).  k may exceed K — the tail comes back exhausted.
    """
    n, d = x.shape
    kc = centers.shape[0]
    bn = min(block_n, max(8, n))
    bk = min(block_k, max(8, kc))
    n_pad = (-n) % bn
    k_pad = (-kc) % bk
    if n_pad:
        x = jnp.concatenate([x, jnp.zeros((n_pad, d), x.dtype)], 0)
    if k_pad:
        centers = jnp.concatenate(
            [centers, jnp.zeros((k_pad, d), centers.dtype)], 0)
        mask = jnp.concatenate([mask, jnp.zeros((k_pad,), bool)], 0)
    np_, kp = x.shape[0], centers.shape[0]
    k_active = jnp.full((1,), kc if count is None else count, jnp.int32)

    def _center_tile(i, j, k_ref):
        last = jnp.maximum((k_ref[0] + bk - 1) // bk, 1) - 1
        return jnp.minimum(j, last), 0

    def _mask_tile(i, j, k_ref):
        return _center_tile(i, j, k_ref)[0]

    grid = (np_ // bn, kp // bk)
    d2, idx = pl.pallas_call(
        functools.partial(_topk_kernel, bk=bk, kk=k),
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=1,
            grid=grid,
            in_specs=[
                pl.BlockSpec((bn, d), lambda i, j, k_ref: (i, 0)),
                pl.BlockSpec((bk, d), _center_tile),
                pl.BlockSpec((bk,), _mask_tile),
            ],
            out_specs=[
                pl.BlockSpec((bn, k), lambda i, j, k_ref: (i, 0)),
                pl.BlockSpec((bn, k), lambda i, j, k_ref: (i, 0)),
            ],
        ),
        out_shape=[
            jax.ShapeDtypeStruct((np_, k), jnp.float32),
            jax.ShapeDtypeStruct((np_, k), jnp.int32),
        ],
        interpret=interpret,
    )(k_active, x, centers, mask)
    d2, idx = _finalize(d2, idx)
    return d2[:n], idx[:n]


@functools.partial(jax.jit, static_argnames=("k", "block_n", "block_k",
                                             "with_loads"))
def topk_stream_emulate(x: jnp.ndarray, centers: jnp.ndarray,
                        mask: jnp.ndarray, k: int,
                        count: jnp.ndarray | None = None,
                        block_n: int = 256, block_k: int = 128,
                        with_loads: bool = False):
    """Vmapped emulation of `topk_stream`'s exact schedule (bitwise-equal).

    vmap-over-n-blocks of a scan-over-center-tiles mirroring the kernel
    body op for op: same padding, same f32 tile matmul, same
    `topk_merge_ref` fold, same count-gated tile skipping.  ONE compiled
    XLA computation — parity-checks production buckets in CI where
    interpret mode would take minutes.  `with_loads=True` additionally
    returns the center-tile HBM load count implied by the kernel's clamped
    index map (== `topk_tile_loads`): the on-device side of the
    DMA-elision accounting.
    """
    n, d = x.shape
    kc = centers.shape[0]
    bn = min(block_n, max(8, n))
    bk = min(block_k, max(8, kc))
    n_pad = (-n) % bn
    k_pad = (-kc) % bk
    if n_pad:
        x = jnp.concatenate([x, jnp.zeros((n_pad, d), x.dtype)], 0)
    if k_pad:
        centers = jnp.concatenate(
            [centers, jnp.zeros((k_pad, d), centers.dtype)], 0)
        mask = jnp.concatenate([mask, jnp.zeros((k_pad,), bool)], 0)
    k_active = jnp.asarray(kc if count is None else count, jnp.int32)

    xb = x.reshape(-1, bn, d)
    cb = centers.reshape(-1, bk, d)
    mb = mask.reshape(-1, bk)
    kbs = jnp.arange(cb.shape[0], dtype=jnp.int32)

    def one_block(xblk):
        xf = xblk.astype(jnp.float32)
        x2 = jnp.sum(xf * xf, axis=-1, keepdims=True)

        def tile(carry, inp):
            run_d, run_i = carry
            kb, c, m = inp
            cf = c.astype(jnp.float32)
            c2 = jnp.sum(cf * cf, axis=-1)[None, :]
            d2 = jnp.maximum(x2 + c2 - 2.0 * jax.lax.dot_general(
                xf, cf, (((1,), (1,)), ((), ())),
                preferred_element_type=jnp.float32), 0.0)
            d2 = jnp.where(m[None, :], d2, jnp.inf)
            ids = (jax.lax.broadcasted_iota(jnp.int32, (bn, bk), 1)
                   + kb * bk)
            nd, ni = topk_merge_ref(run_d, run_i, d2, ids, k)
            active = kb * bk < k_active
            return (jnp.where(active, nd, run_d),
                    jnp.where(active, ni, run_i)), None

        init = (jnp.full((bn, k), jnp.inf, jnp.float32),
                jnp.full((bn, k), TOPK_SENTINEL, jnp.int32))
        (d2k, idk), _ = jax.lax.scan(tile, init, (kbs, cb, mb))
        return d2k, idk

    d2, idx = jax.vmap(one_block)(xb)
    d2, idx = _finalize(d2.reshape(-1, k), idx.reshape(-1, k))
    d2, idx = d2[:n], idx[:n]
    if not with_loads:
        return d2, idx
    # The kernel's index-map sequence, evaluated on-device: block j maps to
    # min(j, last); a load happens iff the mapped index changed vs step
    # j-1.  Equals max(1, ceil(count/bk)) — zero loads past the prefix.
    last = jnp.maximum((k_active + bk - 1) // bk, 1) - 1
    mapped = jnp.minimum(kbs, last)
    loads = 1 + jnp.sum(mapped[1:] != mapped[:-1]).astype(jnp.int32)
    return d2, idx, loads


# ---------------------------------------------------------------------------
# Two-level multi-probe kernel (hierarchical snapshots)
# ---------------------------------------------------------------------------

def _mp_kernel(u_count_ref, cells_ref, x_ref, f_ref, ids_ref, fmask_ref,
               member_ref, d2_ref, idx_ref, *, kk: int):
    j = pl.program_id(1)

    @pl.when(j == 0)
    def _init():
        d2_ref[...] = jnp.full_like(d2_ref, jnp.inf)
        idx_ref[...] = jnp.full_like(idx_ref, TOPK_SENTINEL)

    @pl.when(j < u_count_ref[0])
    def _work():
        x = x_ref[...].astype(jnp.float32)            # (bn, D)
        c = f_ref[0].astype(jnp.float32)              # (S, D) — one shard
        ids = ids_ref[0]                              # (S,)
        fm = fmask_ref[0]                             # (S,)
        mem = member_ref[...][:, 0]                   # (bn,)

        x2 = jnp.sum(x * x, axis=-1, keepdims=True)
        c2 = jnp.sum(c * c, axis=-1)[None, :]
        d2 = jnp.maximum(x2 + c2 - 2.0 * jax.lax.dot_general(
            x, c, (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32), 0.0)
        d2 = jnp.where(fm[None, :] & mem[:, None], d2, jnp.inf)

        nd, ni = topk_merge_ref(
            d2_ref[...], idx_ref[...], d2,
            jnp.broadcast_to(ids[None, :], d2.shape), kk)
        d2_ref[...] = nd
        idx_ref[...] = ni


@functools.partial(jax.jit, static_argnames=("k", "block_n", "interpret"))
def topk_multiprobe_stream(x: jnp.ndarray, fine: jnp.ndarray,
                           fine_ids: jnp.ndarray, fine_mask: jnp.ndarray,
                           cells: jnp.ndarray, member: jnp.ndarray, k: int,
                           u_count: jnp.ndarray | None = None,
                           block_n: int = 256, interpret: bool = False):
    """Stream ONLY the probed fine shards: (d2 (B, k) f32, idx (B, k)).

    fine (n_cells, S, D) / fine_ids / fine_mask per build_hier; cells (U,)
    the probed-cell union (packed ascending, -1 pad — entries are clamped,
    membership must already be False there); member (B, U); `u_count` the
    traced number of real union entries.  Grid is (B/bn, U) with ONE shard
    per tile; the shard index map reads `cells_ref[j]` — the gather lives
    in the index map, so unprobed shards are never DMAd and tiles past
    `u_count` re-address the resident block (zero HBM loads), exactly the
    flat kernel's prefix clamp with the union as the prefix.
    """
    b, d = x.shape
    s = fine.shape[1]
    u = cells.shape[0]
    bn = min(block_n, max(8, b))
    b_pad = (-b) % bn
    if b_pad:
        x = jnp.concatenate([x, jnp.zeros((b_pad, d), x.dtype)], 0)
        member = jnp.concatenate(
            [member, jnp.zeros((b_pad, u), bool)], 0)
    bp = x.shape[0]
    u_active = jnp.full((1,), u if u_count is None else u_count, jnp.int32)
    cells_cl = jnp.maximum(cells, 0).astype(jnp.int32)

    def _shard_tile(i, j, u_ref, cells_ref):
        jc = jnp.minimum(j, jnp.maximum(u_ref[0], 1) - 1)
        return cells_ref[jc], 0, 0

    def _shard_vec(i, j, u_ref, cells_ref):
        return _shard_tile(i, j, u_ref, cells_ref)[:2]

    def _member_tile(i, j, u_ref, cells_ref):
        return i, jnp.minimum(j, jnp.maximum(u_ref[0], 1) - 1)

    grid = (bp // bn, u)
    d2, idx = pl.pallas_call(
        functools.partial(_mp_kernel, kk=k),
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=2,
            grid=grid,
            in_specs=[
                pl.BlockSpec((bn, d), lambda i, j, u_ref, cells_ref: (i, 0)),
                pl.BlockSpec((1, s, d), _shard_tile),
                pl.BlockSpec((1, s), _shard_vec),
                pl.BlockSpec((1, s), _shard_vec),
                pl.BlockSpec((bn, 1), _member_tile),
            ],
            out_specs=[
                pl.BlockSpec((bn, k), lambda i, j, u_ref, cells_ref: (i, 0)),
                pl.BlockSpec((bn, k), lambda i, j, u_ref, cells_ref: (i, 0)),
            ],
        ),
        out_shape=[
            jax.ShapeDtypeStruct((bp, k), jnp.float32),
            jax.ShapeDtypeStruct((bp, k), jnp.int32),
        ],
        interpret=interpret,
    )(u_active, cells_cl, x, fine, fine_ids, fine_mask, member)
    d2, idx = _finalize(d2, idx)
    return d2[:b], idx[:b]


@functools.partial(jax.jit, static_argnames=("k", "block_n", "with_loads"))
def topk_multiprobe_emulate(x: jnp.ndarray, fine: jnp.ndarray,
                            fine_ids: jnp.ndarray, fine_mask: jnp.ndarray,
                            cells: jnp.ndarray, member: jnp.ndarray, k: int,
                            u_count: jnp.ndarray | None = None,
                            block_n: int = 256, with_loads: bool = False):
    """Vmapped emulation of `topk_multiprobe_stream`'s exact schedule.

    Same contract; scan-over-union-ranks with the shard gathered per step
    (`fine[cells[j]]` — the index-map gather, replayed as dynamic
    indexing), merge gated on rank < u_count.  `with_loads=True` also
    returns the shard HBM loads the clamped index map implies:
    max(1, u_count) — independent of n_cells, the multi-probe DMA-skip
    claim in one number.
    """
    b, d = x.shape
    s = fine.shape[1]
    u = cells.shape[0]
    bn = min(block_n, max(8, b))
    b_pad = (-b) % bn
    if b_pad:
        x = jnp.concatenate([x, jnp.zeros((b_pad, d), x.dtype)], 0)
        member = jnp.concatenate(
            [member, jnp.zeros((b_pad, u), bool)], 0)
    u_active = jnp.asarray(u if u_count is None else u_count, jnp.int32)
    cells_cl = jnp.maximum(cells, 0).astype(jnp.int32)

    xb = x.reshape(-1, bn, d)
    memb = member.reshape(-1, bn, u)
    ranks = jnp.arange(u, dtype=jnp.int32)

    def one_block(xblk, mblk):
        xf = xblk.astype(jnp.float32)
        x2 = jnp.sum(xf * xf, axis=-1, keepdims=True)

        def tile(carry, inp):
            run_d, run_i = carry
            j, cell, mem = inp
            cf = fine[cell].astype(jnp.float32)
            c2 = jnp.sum(cf * cf, axis=-1)[None, :]
            d2 = jnp.maximum(x2 + c2 - 2.0 * jax.lax.dot_general(
                xf, cf, (((1,), (1,)), ((), ())),
                preferred_element_type=jnp.float32), 0.0)
            d2 = jnp.where(fine_mask[cell][None, :] & mem[:, None],
                           d2, jnp.inf)
            nd, ni = topk_merge_ref(
                run_d, run_i, d2,
                jnp.broadcast_to(fine_ids[cell][None, :], d2.shape), k)
            active = j < u_active
            return (jnp.where(active, nd, run_d),
                    jnp.where(active, ni, run_i)), None

        init = (jnp.full((bn, k), jnp.inf, jnp.float32),
                jnp.full((bn, k), TOPK_SENTINEL, jnp.int32))
        (d2k, idk), _ = jax.lax.scan(
            tile, init, (ranks, cells_cl, jnp.moveaxis(mblk, 1, 0)))
        return d2k, idk

    d2, idx = jax.vmap(one_block)(xb, memb)
    d2, idx = _finalize(d2.reshape(-1, k), idx.reshape(-1, k))
    d2, idx = d2[:b], idx[:b]
    if not with_loads:
        return d2, idx
    last = jnp.maximum(u_active, 1) - 1
    mapped = jnp.minimum(ranks, last)
    loads = 1 + jnp.sum(mapped[1:] != mapped[:-1]).astype(jnp.int32)
    return d2, idx, loads

"""Fused RMSNorm Pallas kernel: one HBM read, normalize+scale in VMEM.

Grid over row blocks; the full feature dim sits in VMEM (d_model <= 8k is
~32 KiB/row fp32 — a (256, 8192) block is 8 MiB, within VMEM budget).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

__all__ = ["rmsnorm"]


def _rmsnorm_kernel(x_ref, w_ref, o_ref, *, eps: float):
    x = x_ref[...].astype(jnp.float32)
    w = w_ref[...].astype(jnp.float32)
    ms = jnp.mean(x * x, axis=-1, keepdims=True)
    o_ref[...] = (x * jax.lax.rsqrt(ms + eps) * w[None, :]).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("eps", "block_rows", "interpret"))
def rmsnorm(x: jnp.ndarray, weight: jnp.ndarray, eps: float = 1e-6,
            block_rows: int = 256, interpret: bool = False):
    """x: (..., D) — normalized over the last dim, scaled by weight (D,)."""
    orig_shape = x.shape
    d = orig_shape[-1]
    xf = x.reshape(-1, d)
    n = xf.shape[0]
    br = min(block_rows, n)
    pad = (-n) % br
    if pad:
        xf = jnp.concatenate([xf, jnp.zeros((pad, d), x.dtype)], 0)
    out = pl.pallas_call(
        functools.partial(_rmsnorm_kernel, eps=eps),
        grid=(xf.shape[0] // br,),
        in_specs=[pl.BlockSpec((br, d), lambda i: (i, 0)),
                  pl.BlockSpec((d,), lambda i: (0,))],
        out_specs=pl.BlockSpec((br, d), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct(xf.shape, x.dtype),
        interpret=interpret,
    )(xf, weight)
    return out[:n].reshape(orig_shape)

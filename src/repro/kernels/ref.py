"""Pure-jnp oracles for every Pallas kernel (the correctness ground truth).

Each `<name>_ref` is the semantic spec; kernel sweep tests assert_allclose
against these across shapes and dtypes.
"""
from __future__ import annotations

import jax.numpy as jnp
import jax

__all__ = ["assign_ref", "pairwise_argmin_ref", "topk_ref",
           "topk_merge_ref", "topk_multiprobe_ref", "TOPK_SENTINEL",
           "flash_attention_ref", "rmsnorm_ref", "swiglu_ref"]

# Invalid-candidate id inside the top-k selection: larger than any real
# center index, so the lexicographic (d2, id) order pushes exhausted slots
# last deterministically.  Callers map it to -1 wherever d2 is non-finite.
TOPK_SENTINEL = 2**31 - 1


def assign_ref(x: jnp.ndarray, centers: jnp.ndarray, mask: jnp.ndarray):
    """`ops.assign` oracle: masked min sq-distance + argmin, idx = -1 where
    no valid center.  Computes IN THE INPUT DTYPE (same expanded-matmul
    algebra as core.objective.sq_dists) so routing `nearest_center` through
    it preserves the propose phase's dtype/precision contract exactly."""
    x2 = jnp.sum(x * x, axis=-1, keepdims=True)
    c2 = jnp.sum(centers * centers, axis=-1)[None, :]
    d2 = jnp.maximum(x2 + c2 - 2.0 * (x @ centers.T), 0.0)
    d2 = jnp.where(mask[None, :], d2, jnp.inf)
    d2min = jnp.min(d2, axis=-1)
    idx = jnp.where(jnp.isfinite(d2min),
                    jnp.argmin(d2, axis=-1), -1).astype(jnp.int32)
    return d2min, idx


def pairwise_argmin_ref(x: jnp.ndarray, centers: jnp.ndarray,
                        mask: jnp.ndarray | None = None):
    """Min squared distance + argmin over centers.  x (N,D), centers (K,D).
    Computes in float32 (matching the Pallas kernel's accumulation dtype)."""
    xf = x.astype(jnp.float32)
    cf = centers.astype(jnp.float32)
    x2 = jnp.sum(xf * xf, axis=-1, keepdims=True)
    c2 = jnp.sum(cf * cf, axis=-1)[None, :]
    d2 = jnp.maximum(x2 + c2 - 2.0 * (xf @ cf.T), 0.0)
    if mask is not None:
        d2 = jnp.where(mask[None, :], d2, jnp.inf)
    return jnp.min(d2, axis=-1), jnp.argmin(d2, axis=-1).astype(jnp.int32)


def topk_ref(x: jnp.ndarray, centers: jnp.ndarray, k: int,
             mask: jnp.ndarray | None = None):
    """k nearest centers per query: (d2 (N, k) ascending, idx (N, k) int32).

    Same input-dtype expanded-matmul algebra as `assign_ref` (so the top-1
    column is bit-identical to `assign_ref`'s verdict); slots beyond the
    valid set come back as (inf, -1).  `lax.top_k` breaks distance ties by
    lower index — matching `argmin`, so topk[...,:1] == assign exactly.

    Scoring is restricted to the masked active prefix at the SOURCE: rows
    outside the mask are zeroed before the matmul, so NaN/inf garbage in
    padded pool slots (stale payloads past `count`, snapshot capacity
    padding) cannot poison the distance matrix or the top-k sort order —
    invalid slots are (inf, -1) by construction, never by luck.  For valid
    columns the algebra is untouched (zeroing only changes columns the
    inf-mask overwrites anyway), preserving the top1 == assign contract.
    """
    if mask is not None:
        centers = jnp.where(mask[:, None], centers, 0)
    x2 = jnp.sum(x * x, axis=-1, keepdims=True)
    c2 = jnp.sum(centers * centers, axis=-1)[None, :]
    d2 = jnp.maximum(x2 + c2 - 2.0 * (x @ centers.T), 0.0)
    if mask is not None:
        d2 = jnp.where(mask[None, :], d2, jnp.inf)
    neg, idx = jax.lax.top_k(-d2, k)
    d2k = -neg
    idx = jnp.where(jnp.isfinite(d2k), idx, -1).astype(jnp.int32)
    return d2k, idx


def topk_merge_ref(run_d: jnp.ndarray, run_i: jnp.ndarray,
                   d2: jnp.ndarray, ids: jnp.ndarray, k: int):
    """Running top-k merge by lexicographic (d2, id) — THE selection spec.

    run_d/run_i: (N, k) current candidates (pad: (inf, TOPK_SENTINEL)).
    d2/ids:      (N, M) new candidates (invalid: d2=inf, any id).
    Returns the new (N, k), ascending by (d2, id): k unrolled extraction
    steps, each taking the distance minimum and, among ties, the smallest
    id — exactly `lax.top_k`'s lower-index-first tie order when ids are
    the candidates' original positions.  Because selection depends only on
    the candidate (value, id) MULTISET, the result is invariant to how
    callers tile or reorder candidates — the property that makes the
    streaming kernel (kernels/topk_stream.py), its vmapped emulation, and
    the gathered multi-probe path all bit-identical to `topk_ref` for f32
    inputs, whatever their block sizes.  (inf, TOPK_SENTINEL) pads are a
    fixed point of the extraction (consuming one re-creates it), so ragged
    candidate sets need no special casing.  Used as the merge body INSIDE
    the Pallas kernel as well — keeping the oracle and the kernel on one
    implementation is what turns parity into a construction, not a test.
    """
    cat_d = jnp.concatenate([run_d, d2], axis=1)
    cat_i = jnp.concatenate([run_i, ids], axis=1)
    out_d, out_i = [], []
    for _ in range(k):
        dmin = jnp.min(cat_d, axis=1)
        tie = cat_d == dmin[:, None]
        imin = jnp.min(jnp.where(tie, cat_i, TOPK_SENTINEL), axis=1)
        out_d.append(dmin)
        out_i.append(imin)
        hit = tie & (cat_i == imin[:, None])
        cat_d = jnp.where(hit, jnp.inf, cat_d)
        cat_i = jnp.where(hit, TOPK_SENTINEL, cat_i)
    return (jnp.concatenate([d[:, None] for d in out_d], axis=1),
            jnp.concatenate([i[:, None] for i in out_i], axis=1))


def topk_multiprobe_ref(x: jnp.ndarray, fine: jnp.ndarray,
                        fine_ids: jnp.ndarray, fine_mask: jnp.ndarray,
                        cells: jnp.ndarray, member: jnp.ndarray, k: int):
    """Multi-probe top-k oracle over a two-level (cell → shard) layout.

    x (B, D); fine (n_cells, S, D) shard buffers; fine_ids/fine_mask
    (n_cells, S) original flat indices (-1 pad) / validity; cells (U,)
    int32 — the microbatch's probed-cell union, packed ascending, -1 pad;
    member (B, U) bool — query b may see candidates of cells[u].

    The distance computation deliberately gathers the probed shards into
    ONE (U*S, D) row matrix and runs a single 2-D matmul shared by the
    whole microbatch: on XLA a row-gathered matmul is bitwise-equal to the
    corresponding columns of the flat `x @ centers.T` (per-query batched
    einsums are NOT), and selection is by (d2, original id) — so when the
    union covers every active cell and member is all-true, the result is
    bit-identical to `topk_ref` on the flat buffers, tie order included.
    Masked shard rows are zeroed before the matmul (same NaN/inf guard as
    `topk_ref`); per-query membership only ever masks AFTER the matmul,
    so it cannot perturb surviving columns.
    """
    s = fine.shape[1]
    u = cells.shape[0]
    cc = jnp.maximum(cells, 0)
    g = jnp.take(fine, cc, axis=0).reshape(u * s, -1)
    gids = jnp.take(fine_ids, cc, axis=0).reshape(u * s)
    gmask = (jnp.take(fine_mask, cc, axis=0).reshape(u * s)
             & jnp.repeat(cells >= 0, s))
    g = jnp.where(gmask[:, None], g, 0)
    x2 = jnp.sum(x * x, axis=-1, keepdims=True)
    g2 = jnp.sum(g * g, axis=-1)[None, :]
    d2 = jnp.maximum(x2 + g2 - 2.0 * (x @ g.T), 0.0)
    ok = gmask[None, :] & jnp.repeat(member, s, axis=1)
    d2 = jnp.where(ok, d2, jnp.inf)
    init_d = jnp.full((x.shape[0], k), jnp.inf, d2.dtype)
    init_i = jnp.full((x.shape[0], k), TOPK_SENTINEL, jnp.int32)
    d2k, idx = topk_merge_ref(init_d, init_i, d2,
                              jnp.broadcast_to(gids[None, :], d2.shape), k)
    idx = jnp.where(jnp.isfinite(d2k), idx, -1).astype(jnp.int32)
    return d2k, idx


def flash_attention_ref(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray,
                        causal: bool = True, scale: float | None = None):
    """Reference attention.  q (B,H,S,Dh); k,v (B,Hkv,S,Dh); GQA broadcast."""
    b, h, s, dh = q.shape
    hkv = k.shape[1]
    g = h // hkv
    kq = jnp.repeat(k, g, axis=1)
    vq = jnp.repeat(v, g, axis=1)
    if scale is None:
        scale = dh ** -0.5
    logits = jnp.einsum("bhqd,bhkd->bhqk", q.astype(jnp.float32),
                        kq.astype(jnp.float32)) * scale
    if causal:
        qi = jnp.arange(s)[:, None]
        ki = jnp.arange(s)[None, :]
        logits = jnp.where(ki <= qi, logits, -jnp.inf)
    w = jax.nn.softmax(logits, axis=-1)
    out = jnp.einsum("bhqk,bhkd->bhqd", w, vq.astype(jnp.float32))
    return out.astype(q.dtype)


def rmsnorm_ref(x: jnp.ndarray, weight: jnp.ndarray, eps: float = 1e-6):
    xf = x.astype(jnp.float32)
    ms = jnp.mean(xf * xf, axis=-1, keepdims=True)
    return ((xf * jax.lax.rsqrt(ms + eps)) * weight.astype(jnp.float32)).astype(x.dtype)


def swiglu_ref(gate: jnp.ndarray, up: jnp.ndarray):
    gf = gate.astype(jnp.float32)
    return (jax.nn.silu(gf) * up.astype(jnp.float32)).astype(gate.dtype)

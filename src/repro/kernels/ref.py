"""Pure-jnp oracles for every Pallas kernel (the correctness ground truth).

Each `<name>_ref` is the semantic spec; kernel sweep tests assert_allclose
against these across shapes and dtypes.
"""
from __future__ import annotations

import jax.numpy as jnp
import jax

__all__ = ["assign_ref", "pairwise_argmin_ref", "topk_ref",
           "flash_attention_ref", "rmsnorm_ref", "swiglu_ref"]


def assign_ref(x: jnp.ndarray, centers: jnp.ndarray, mask: jnp.ndarray):
    """`ops.assign` oracle: masked min sq-distance + argmin, idx = -1 where
    no valid center.  Computes IN THE INPUT DTYPE (same expanded-matmul
    algebra as core.objective.sq_dists) so routing `nearest_center` through
    it preserves the propose phase's dtype/precision contract exactly."""
    x2 = jnp.sum(x * x, axis=-1, keepdims=True)
    c2 = jnp.sum(centers * centers, axis=-1)[None, :]
    d2 = jnp.maximum(x2 + c2 - 2.0 * (x @ centers.T), 0.0)
    d2 = jnp.where(mask[None, :], d2, jnp.inf)
    d2min = jnp.min(d2, axis=-1)
    idx = jnp.where(jnp.isfinite(d2min),
                    jnp.argmin(d2, axis=-1), -1).astype(jnp.int32)
    return d2min, idx


def pairwise_argmin_ref(x: jnp.ndarray, centers: jnp.ndarray,
                        mask: jnp.ndarray | None = None):
    """Min squared distance + argmin over centers.  x (N,D), centers (K,D).
    Computes in float32 (matching the Pallas kernel's accumulation dtype)."""
    xf = x.astype(jnp.float32)
    cf = centers.astype(jnp.float32)
    x2 = jnp.sum(xf * xf, axis=-1, keepdims=True)
    c2 = jnp.sum(cf * cf, axis=-1)[None, :]
    d2 = jnp.maximum(x2 + c2 - 2.0 * (xf @ cf.T), 0.0)
    if mask is not None:
        d2 = jnp.where(mask[None, :], d2, jnp.inf)
    return jnp.min(d2, axis=-1), jnp.argmin(d2, axis=-1).astype(jnp.int32)


def topk_ref(x: jnp.ndarray, centers: jnp.ndarray, k: int,
             mask: jnp.ndarray | None = None):
    """k nearest centers per query: (d2 (N, k) ascending, idx (N, k) int32).

    Same input-dtype expanded-matmul algebra as `assign_ref` (so the top-1
    column is bit-identical to `assign_ref`'s verdict); slots beyond the
    valid set come back as (inf, -1).  `lax.top_k` breaks distance ties by
    lower index — matching `argmin`, so topk[...,:1] == assign exactly.

    Scoring is restricted to the masked active prefix at the SOURCE: rows
    outside the mask are zeroed before the matmul, so NaN/inf garbage in
    padded pool slots (stale payloads past `count`, snapshot capacity
    padding) cannot poison the distance matrix or the top-k sort order —
    invalid slots are (inf, -1) by construction, never by luck.  For valid
    columns the algebra is untouched (zeroing only changes columns the
    inf-mask overwrites anyway), preserving the top1 == assign contract.
    """
    if mask is not None:
        centers = jnp.where(mask[:, None], centers, 0)
    x2 = jnp.sum(x * x, axis=-1, keepdims=True)
    c2 = jnp.sum(centers * centers, axis=-1)[None, :]
    d2 = jnp.maximum(x2 + c2 - 2.0 * (x @ centers.T), 0.0)
    if mask is not None:
        d2 = jnp.where(mask[None, :], d2, jnp.inf)
    neg, idx = jax.lax.top_k(-d2, k)
    d2k = -neg
    idx = jnp.where(jnp.isfinite(d2k), idx, -1).astype(jnp.int32)
    return d2k, idx


def flash_attention_ref(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray,
                        causal: bool = True, scale: float | None = None):
    """Reference attention.  q (B,H,S,Dh); k,v (B,Hkv,S,Dh); GQA broadcast."""
    b, h, s, dh = q.shape
    hkv = k.shape[1]
    g = h // hkv
    kq = jnp.repeat(k, g, axis=1)
    vq = jnp.repeat(v, g, axis=1)
    if scale is None:
        scale = dh ** -0.5
    logits = jnp.einsum("bhqd,bhkd->bhqk", q.astype(jnp.float32),
                        kq.astype(jnp.float32)) * scale
    if causal:
        qi = jnp.arange(s)[:, None]
        ki = jnp.arange(s)[None, :]
        logits = jnp.where(ki <= qi, logits, -jnp.inf)
    w = jax.nn.softmax(logits, axis=-1)
    out = jnp.einsum("bhqk,bhkd->bhqd", w, vq.astype(jnp.float32))
    return out.astype(q.dtype)


def rmsnorm_ref(x: jnp.ndarray, weight: jnp.ndarray, eps: float = 1e-6):
    xf = x.astype(jnp.float32)
    ms = jnp.mean(xf * xf, axis=-1, keepdims=True)
    return ((xf * jax.lax.rsqrt(ms + eps)) * weight.astype(jnp.float32)).astype(x.dtype)


def swiglu_ref(gate: jnp.ndarray, up: jnp.ndarray):
    gf = gate.astype(jnp.float32)
    return (jax.nn.silu(gf) * up.astype(jnp.float32)).astype(gate.dtype)

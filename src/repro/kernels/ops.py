"""Public jit'd wrappers for the Pallas kernels.

On the TPU target the Pallas path runs natively; on this CPU container the
kernels execute under interpret=True (kernel body in Python) for
correctness, and callers default to the jnp reference for speed.  The
`backend` knob makes the choice explicit and testable:

  backend="auto"      -> pallas on TPU, ref elsewhere (production default)
  backend="pallas"    -> pallas, interpret=True off-TPU (kernel validation)
  backend="ref"       -> pure-jnp oracle
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels import ref as _ref
from repro.kernels.dpmeans_assign import dpmeans_assign as _dpmeans_assign
from repro.kernels.flash_attention import flash_attention as _flash_attention
from repro.kernels.rmsnorm import rmsnorm as _rmsnorm
from repro.kernels.swiglu import swiglu as _swiglu

__all__ = ["pairwise_argmin", "flash_attention", "rmsnorm", "swiglu",
           "on_tpu"]


def on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def _resolve(backend: str) -> tuple[bool, bool]:
    """-> (use_pallas, interpret)"""
    if backend == "auto":
        return (True, False) if on_tpu() else (False, False)
    if backend == "pallas":
        return True, not on_tpu()
    if backend == "ref":
        return False, False
    raise ValueError(f"unknown backend {backend!r}")


def pairwise_argmin(x, centers, mask=None, backend: str = "auto", **blocks):
    use_pallas, interp = _resolve(backend)
    if mask is None:
        mask = jnp.ones((centers.shape[0],), bool)
    if use_pallas:
        return _dpmeans_assign(x, centers, mask, interpret=interp, **blocks)
    return _ref.pairwise_argmin_ref(x, centers, mask)


def flash_attention(q, k, v, causal=True, scale=None, backend: str = "auto",
                    **blocks):
    use_pallas, interp = _resolve(backend)
    if use_pallas:
        return _flash_attention(q, k, v, causal=causal, scale=scale,
                                interpret=interp, **blocks)
    return _ref.flash_attention_ref(q, k, v, causal=causal, scale=scale)


def rmsnorm(x, weight, eps: float = 1e-6, backend: str = "auto", **blocks):
    use_pallas, interp = _resolve(backend)
    if use_pallas:
        return _rmsnorm(x, weight, eps=eps, interpret=interp, **blocks)
    return _ref.rmsnorm_ref(x, weight, eps=eps)


def swiglu(gate, up, backend: str = "auto", **blocks):
    use_pallas, interp = _resolve(backend)
    if use_pallas:
        return _swiglu(gate, up, interpret=interp, **blocks)
    return _ref.swiglu_ref(gate, up)

"""Public jit'd wrappers for the Pallas kernels.

On the TPU target the Pallas path runs natively; on this CPU container the
kernels execute under interpret=True (kernel body in Python) for
correctness, and callers default to the jnp reference for speed.  The
`backend` knob makes the choice explicit and testable:

  backend="auto"      -> pallas on TPU, ref elsewhere (production default)
  backend="pallas"    -> pallas, interpret=True off-TPU (kernel validation)
  backend="ref"       -> pure-jnp oracle
  backend="emulate"   -> vmapped emulation of the kernel's exact schedule
                         (assign/pairwise_argmin only) — interpret-mode
                         semantics at compiled speed, for parity-checking
                         production shapes (serving buckets) in CI
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels import ref as _ref
from repro.kernels.dpmeans_assign import (
    dpmeans_assign as _dpmeans_assign,
    dpmeans_assign_emulate as _dpmeans_assign_emulate,
)
from repro.kernels.flash_attention import flash_attention as _flash_attention
from repro.kernels.rmsnorm import rmsnorm as _rmsnorm
from repro.kernels.swiglu import swiglu as _swiglu

__all__ = ["assign", "pairwise_argmin", "serve_assign", "serve_topk",
           "flash_attention", "rmsnorm", "swiglu", "on_tpu"]


def on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def _resolve(backend: str) -> tuple[bool, bool]:
    """-> (use_pallas, interpret)"""
    if backend == "auto":
        return (True, False) if on_tpu() else (False, False)
    if backend == "pallas":
        return True, not on_tpu()
    if backend == "ref":
        return False, False
    raise ValueError(f"unknown backend {backend!r}")


def assign(x, centers, mask=None, count=None, backend: str = "auto",
           **blocks):
    """Nearest-center assignment — THE OCC propose/validate primitive.

    x (N, D), centers (K, D), mask (K,) bool, count optional traced scalar
    bounding the valid prefix.  Returns (d2min (N,), idx (N,) int32) with
    idx = -1 (and d2min = inf) where no valid center exists; d2min is f32
    on the Pallas path (kernel accumulation dtype) and the input dtype on
    the reference path (preserving nearest_center's precision contract).

    Backend dispatch (DESIGN.md §9): pallas on TPU (MXU-tiled, count-rounded
    active prefix — tiles beyond the pool count are skipped), pallas
    interpret=True off-TPU for kernel validation, jnp reference elsewhere
    (the reference cannot skip work — static shapes — so count folds into
    the mask, which the pool invariant makes a no-op).
    """
    if mask is None:
        mask = jnp.ones((centers.shape[0],), bool)
    if count is not None:
        mask = jnp.logical_and(mask, jnp.arange(centers.shape[0]) < count)
    if backend == "emulate":
        return _dpmeans_assign_emulate(x, centers, mask, count=count, **blocks)
    use_pallas, interp = _resolve(backend)
    if use_pallas:
        return _dpmeans_assign(x, centers, mask, count=count,
                               interpret=interp, **blocks)
    return _ref.assign_ref(x, centers, mask)


def pairwise_argmin(x, centers, mask=None, backend: str = "auto", **blocks):
    """Raw kernel/oracle pair for parity testing — NOT the production
    primitive (that is `assign`).  Differences are deliberate: no count
    restriction, no -1-on-empty contract, and the reference path computes
    in f32 (the kernel's accumulation dtype) so sweeps compare the Pallas
    body against a like-for-like oracle across input dtypes."""
    if mask is None:
        mask = jnp.ones((centers.shape[0],), bool)
    if backend == "emulate":
        return _dpmeans_assign_emulate(x, centers, mask, **blocks)
    use_pallas, interp = _resolve(backend)
    if use_pallas:
        return _dpmeans_assign(x, centers, mask, interpret=interp, **blocks)
    return _ref.pairwise_argmin_ref(x, centers, mask)


def serve_assign(x, centers, mask=None, count=None, n_valid=None,
                 backend: str = "auto", **blocks):
    """Bucket-padded assignment — the serving-plane query primitive.

    Same contract as `assign` plus *query*-prefix masking: the service pads
    ragged request batches up to a power-of-two bucket (so jit caches stay
    warm across request sizes) and passes `n_valid`, the count of real
    rows; padding rows come back as (inf, -1) and can never alias a real
    response.  The center-side count prefix (`count`) works exactly as in
    `assign` — one kernel dispatch covers both maskings.
    """
    d2, idx = assign(x, centers, mask, count=count, backend=backend, **blocks)
    if n_valid is not None:
        ok = jnp.arange(x.shape[0]) < n_valid
        d2 = jnp.where(ok, d2, jnp.inf)
        idx = jnp.where(ok, idx, -1)
    return d2, idx


def serve_topk(x, centers, k: int, mask=None, count=None, n_valid=None,
               backend: str = "auto"):
    """k nearest centers per query: (d2 (N, k) ascending, idx (N, k)).

    Serving-plane ranking query with the same bucket/count-prefix masking
    as `serve_assign`; invalid (masked / padded / beyond-count) slots are
    (inf, -1).  All backends run the jnp algebra (`ref.topk_ref`): top-k
    needs the full distance row, so there is no streamed running-min kernel
    to dispatch to — the O(N·K) matrix is one MXU matmul and `lax.top_k`
    lowers natively on TPU.  `topk[..., :1]` equals `serve_assign` on the
    ref backend bit-exactly (same algebra, same tie-breaking).

    Like `serve_assign`, scoring is restricted to the active prefix: the
    count/mask validity is applied to the center rows BEFORE the distance
    matmul (`topk_ref` zeroes masked rows), so NaN/inf-laden payloads
    sitting in padded slots can never surface in — or reorder — the
    top-k (tests/test_serving.py pins this).
    """
    if mask is None:
        mask = jnp.ones((centers.shape[0],), bool)
    if count is not None:
        mask = jnp.logical_and(mask, jnp.arange(centers.shape[0]) < count)
    d2, idx = _ref.topk_ref(x, centers, k, mask)
    if n_valid is not None:
        ok = (jnp.arange(x.shape[0]) < n_valid)[:, None]
        d2 = jnp.where(ok, d2, jnp.inf)
        idx = jnp.where(ok, idx, -1)
    return d2, idx


def flash_attention(q, k, v, causal=True, scale=None, backend: str = "auto",
                    **blocks):
    use_pallas, interp = _resolve(backend)
    if use_pallas:
        return _flash_attention(q, k, v, causal=causal, scale=scale,
                                interpret=interp, **blocks)
    return _ref.flash_attention_ref(q, k, v, causal=causal, scale=scale)


def rmsnorm(x, weight, eps: float = 1e-6, backend: str = "auto", **blocks):
    use_pallas, interp = _resolve(backend)
    if use_pallas:
        return _rmsnorm(x, weight, eps=eps, interpret=interp, **blocks)
    return _ref.rmsnorm_ref(x, weight, eps=eps)


def swiglu(gate, up, backend: str = "auto", **blocks):
    use_pallas, interp = _resolve(backend)
    if use_pallas:
        return _swiglu(gate, up, interpret=interp, **blocks)
    return _ref.swiglu_ref(gate, up)

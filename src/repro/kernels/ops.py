"""Public jit'd wrappers for the Pallas kernels.

On the TPU target the Pallas path runs natively; on this CPU container the
kernels execute under interpret=True (kernel body in Python) for
correctness, and callers default to the jnp reference for speed.  The
`backend` knob makes the choice explicit and testable:

  backend="auto"      -> pallas on TPU, ref elsewhere (production default)
  backend="pallas"    -> pallas, interpret=True off-TPU (kernel validation)
  backend="ref"       -> pure-jnp oracle
  backend="emulate"   -> vmapped emulation of the kernel's exact schedule
                         (assign/pairwise_argmin only) — interpret-mode
                         semantics at compiled speed, for parity-checking
                         production shapes (serving buckets) in CI
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels import ref as _ref
from repro.kernels.dpmeans_assign import (
    dpmeans_assign as _dpmeans_assign,
    dpmeans_assign_emulate as _dpmeans_assign_emulate,
)
from repro.kernels.flash_attention import flash_attention as _flash_attention
from repro.kernels.rmsnorm import rmsnorm as _rmsnorm
from repro.kernels.swiglu import swiglu as _swiglu
from repro.kernels.topk_stream import (
    topk_stream as _topk_stream,
    topk_stream_emulate as _topk_stream_emulate,
    topk_multiprobe_stream as _topk_mp_stream,
    topk_multiprobe_emulate as _topk_mp_emulate,
)

__all__ = ["assign", "pairwise_argmin", "serve_assign", "serve_topk",
           "serve_topk_multiprobe", "flash_attention", "rmsnorm", "swiglu",
           "on_tpu"]


def on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def _resolve(backend: str) -> tuple[bool, bool]:
    """-> (use_pallas, interpret)"""
    if backend == "auto":
        return (True, False) if on_tpu() else (False, False)
    if backend == "pallas":
        return True, not on_tpu()
    if backend == "ref":
        return False, False
    raise ValueError(f"unknown backend {backend!r}")


def assign(x, centers, mask=None, count=None, backend: str = "auto",
           **blocks):
    """Nearest-center assignment — THE OCC propose/validate primitive.

    x (N, D), centers (K, D), mask (K,) bool, count optional traced scalar
    bounding the valid prefix.  Returns (d2min (N,), idx (N,) int32) with
    idx = -1 (and d2min = inf) where no valid center exists; d2min is f32
    on the Pallas path (kernel accumulation dtype) and the input dtype on
    the reference path (preserving nearest_center's precision contract).

    Backend dispatch (DESIGN.md §9): pallas on TPU (MXU-tiled, count-rounded
    active prefix — tiles beyond the pool count are skipped), pallas
    interpret=True off-TPU for kernel validation, jnp reference elsewhere
    (the reference cannot skip work — static shapes — so count folds into
    the mask, which the pool invariant makes a no-op).
    """
    if mask is None:
        mask = jnp.ones((centers.shape[0],), bool)
    if count is not None:
        mask = jnp.logical_and(mask, jnp.arange(centers.shape[0]) < count)
    if backend == "emulate":
        return _dpmeans_assign_emulate(x, centers, mask, count=count, **blocks)
    use_pallas, interp = _resolve(backend)
    if use_pallas:
        return _dpmeans_assign(x, centers, mask, count=count,
                               interpret=interp, **blocks)
    return _ref.assign_ref(x, centers, mask)


def pairwise_argmin(x, centers, mask=None, backend: str = "auto", **blocks):
    """Raw kernel/oracle pair for parity testing — NOT the production
    primitive (that is `assign`).  Differences are deliberate: no count
    restriction, no -1-on-empty contract, and the reference path computes
    in f32 (the kernel's accumulation dtype) so sweeps compare the Pallas
    body against a like-for-like oracle across input dtypes."""
    if mask is None:
        mask = jnp.ones((centers.shape[0],), bool)
    if backend == "emulate":
        return _dpmeans_assign_emulate(x, centers, mask, **blocks)
    use_pallas, interp = _resolve(backend)
    if use_pallas:
        return _dpmeans_assign(x, centers, mask, interpret=interp, **blocks)
    return _ref.pairwise_argmin_ref(x, centers, mask)


def serve_assign(x, centers, mask=None, count=None, n_valid=None,
                 backend: str = "auto", **blocks):
    """Bucket-padded assignment — the serving-plane query primitive.

    Same contract as `assign` plus *query*-prefix masking: the service pads
    ragged request batches up to a power-of-two bucket (so jit caches stay
    warm across request sizes) and passes `n_valid`, the count of real
    rows; padding rows come back as (inf, -1) and can never alias a real
    response.  The center-side count prefix (`count`) works exactly as in
    `assign` — one kernel dispatch covers both maskings.
    """
    d2, idx = assign(x, centers, mask, count=count, backend=backend, **blocks)
    if n_valid is not None:
        ok = jnp.arange(x.shape[0]) < n_valid
        d2 = jnp.where(ok, d2, jnp.inf)
        idx = jnp.where(ok, idx, -1)
    return d2, idx


def _next_pow2(n: int) -> int:
    # Local duplicate of core.occ.next_pow2: core.occ imports this module,
    # so importing it back would be a cycle.
    p = 1
    while p < n:
        p <<= 1
    return p


def _static_count(count):
    """The host int behind `count`, or None when it is traced/absent."""
    if count is None or isinstance(count, jax.core.Tracer):
        return None
    try:
        return int(count)
    except Exception:
        return None


def _mask_queries(d2, idx, n_valid):
    if n_valid is None:
        return d2, idx
    ok = (jnp.arange(d2.shape[0]) < n_valid)[:, None]
    return jnp.where(ok, d2, jnp.inf), jnp.where(ok, idx, -1)


def serve_topk(x, centers, k: int, mask=None, count=None, n_valid=None,
               backend: str = "auto", **blocks):
    """k nearest centers per query: (d2 (N, k) ascending, idx (N, k)).

    Serving-plane ranking query with the same bucket/count-prefix masking
    as `serve_assign`; invalid (masked / padded / beyond-count) slots are
    (inf, -1); distance ties break by lower index on EVERY backend.  Full
    backend dispatch (DESIGN.md §16): pallas streams center tiles through
    VMEM carrying k running candidates and skips HBM DMA beyond the active
    prefix (`kernels/topk_stream.py`); "emulate" replays that exact tile
    schedule as vmapped jnp; "ref" runs the one-matmul + `lax.top_k`
    oracle.  For f32 inputs all three agree bit-exactly — the streamed
    merge is tiling-invariant and the D-contraction is never split.
    `topk[..., :1]` equals `serve_assign` bit-exactly on each backend
    (same algebra, same tie-breaking).

    Active-prefix restriction happens at the SOURCE on every backend:
    masked rows are zeroed before the ref matmul / inf-masked per tile in
    the kernel, so NaN/inf-laden payloads in padded slots can never
    surface in — or reorder — the top-k (tests/test_serving.py pins
    this).  When `count` is a HOST int (benchmarks, the retrieval example
    — not the service's traced per-version scalar), the ref/emulate paths
    additionally slice the center buffer to the pow2-rounded active prefix
    before any compute, so CPU backends pay O(pow2(count)) instead of
    O(K_max) at count << K_max; a prefix slice changes no surviving
    distance bitwise.  k may exceed the (sliced) capacity — the overflow
    columns come back (inf, -1).
    """
    if mask is None:
        mask = jnp.ones((centers.shape[0],), bool)
    static_c = _static_count(count)
    if count is not None:
        mask = jnp.logical_and(mask, jnp.arange(centers.shape[0]) < count)
    if static_c is not None and backend in ("ref", "emulate", "auto") \
            and not on_tpu():
        kp = min(centers.shape[0], max(_next_pow2(max(static_c, 1)), 8))
        if kp < centers.shape[0]:
            centers, mask = centers[:kp], mask[:kp]
    kk = min(k, centers.shape[0])
    if backend == "emulate":
        d2, idx = _topk_stream_emulate(x, centers, mask, kk, count=count,
                                       **blocks)
    else:
        use_pallas, interp = _resolve(backend)
        if use_pallas:
            d2, idx = _topk_stream(x, centers, mask, kk, count=count,
                                   interpret=interp, **blocks)
        else:
            d2, idx = _ref.topk_ref(x, centers, kk, mask)
    if kk < k:
        pad = k - kk
        d2 = jnp.concatenate(
            [d2, jnp.full((d2.shape[0], pad), jnp.inf, d2.dtype)], 1)
        idx = jnp.concatenate(
            [idx, jnp.full((idx.shape[0], pad), -1, jnp.int32)], 1)
    return _mask_queries(d2, idx, n_valid)


def serve_topk_multiprobe(x, fine, fine_ids, fine_mask, cells, member,
                          k: int, u_count=None, n_valid=None,
                          backend: str = "auto", **blocks):
    """Top-k over a hierarchical snapshot's probed fine shards.

    x (B, D); fine (n_cells, S, D) + fine_ids/fine_mask (n_cells, S) per
    `serving.snapshot.build_hier`; cells (U,) the microbatch's probed-cell
    union (packed ascending, -1 pad); member (B, U) per-query membership;
    `u_count` the number of real union entries.  Returns (d2 (B, k), idx
    (B, k)) where idx are ORIGINAL flat-snapshot indices — when the union
    covers every active cell and member is all-true, bit-identical to
    `serve_topk` on the flat buffers (the p = all exactness contract,
    DESIGN.md §16).  Pallas streams only the probed shards (the gather
    lives in the BlockSpec index map — unprobed shards never leave HBM);
    ref gathers the union once and runs ONE shared 2-D matmul, the only
    batched-distance formulation XLA reproduces bitwise against the flat
    matmul.
    """
    if backend == "emulate":
        d2, idx = _topk_mp_emulate(x, fine, fine_ids, fine_mask, cells,
                                   member, k, u_count=u_count, **blocks)
    else:
        use_pallas, interp = _resolve(backend)
        if use_pallas:
            d2, idx = _topk_mp_stream(x, fine, fine_ids, fine_mask, cells,
                                      member, k, u_count=u_count,
                                      interpret=interp, **blocks)
        else:
            d2, idx = _ref.topk_multiprobe_ref(x, fine, fine_ids, fine_mask,
                                               cells, member, k)
    return _mask_queries(d2, idx, n_valid)


def flash_attention(q, k, v, causal=True, scale=None, backend: str = "auto",
                    **blocks):
    use_pallas, interp = _resolve(backend)
    if use_pallas:
        return _flash_attention(q, k, v, causal=causal, scale=scale,
                                interpret=interp, **blocks)
    return _ref.flash_attention_ref(q, k, v, causal=causal, scale=scale)


def rmsnorm(x, weight, eps: float = 1e-6, backend: str = "auto", **blocks):
    use_pallas, interp = _resolve(backend)
    if use_pallas:
        return _rmsnorm(x, weight, eps=eps, interpret=interp, **blocks)
    return _ref.rmsnorm_ref(x, weight, eps=eps)


def swiglu(gate, up, backend: str = "auto", **blocks):
    use_pallas, interp = _resolve(backend)
    if use_pallas:
        return _swiglu(gate, up, interpret=interp, **blocks)
    return _ref.swiglu_ref(gate, up)

"""Fused SwiGLU activation kernel: silu(gate) * up in one VMEM pass.

Avoids materializing silu(gate) in HBM between the two ops — a pure
memory-roofline win on the MLP path.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

__all__ = ["swiglu"]


def _swiglu_kernel(g_ref, u_ref, o_ref):
    g = g_ref[...].astype(jnp.float32)
    u = u_ref[...].astype(jnp.float32)
    o_ref[...] = (g * jax.lax.logistic(g) * u).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("block_rows", "interpret"))
def swiglu(gate: jnp.ndarray, up: jnp.ndarray, block_rows: int = 256,
           interpret: bool = False):
    """Elementwise silu(gate) * up; shapes must match."""
    assert gate.shape == up.shape
    orig_shape = gate.shape
    d = orig_shape[-1]
    gf = gate.reshape(-1, d)
    uf = up.reshape(-1, d)
    n = gf.shape[0]
    br = min(block_rows, n)
    pad = (-n) % br
    if pad:
        gf = jnp.concatenate([gf, jnp.zeros((pad, d), gate.dtype)], 0)
        uf = jnp.concatenate([uf, jnp.zeros((pad, d), up.dtype)], 0)
    out = pl.pallas_call(
        _swiglu_kernel,
        grid=(gf.shape[0] // br,),
        in_specs=[pl.BlockSpec((br, d), lambda i: (i, 0)),
                  pl.BlockSpec((br, d), lambda i: (i, 0))],
        out_specs=pl.BlockSpec((br, d), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct(gf.shape, gate.dtype),
        interpret=interpret,
    )(gf, uf)
    return out[:n].reshape(orig_shape)

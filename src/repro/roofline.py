"""Roofline analysis from compiled dry-run artifacts (DESIGN.md §8).

Three terms per (arch x shape x mesh) cell, all in seconds:

  compute    = HLO_FLOPs_per_device / peak_FLOPs_per_chip
  memory     = HLO_bytes_per_device / HBM_bw_per_chip
  collective = collective_bytes_per_device / link_bw_per_chip

`compiled.cost_analysis()` reports post-SPMD per-device flops/bytes, so the
per-chip division above is the same as the global/(chips*peak) form.

Collective bytes are not in cost_analysis: we parse the post-partitioning
HLO text, sum the output-shape bytes of every all-gather / all-reduce /
reduce-scatter / all-to-all / collective-permute, and multiply ops that live
inside while-loop bodies (scan-over-layers) by the known trip count — XLA
keeps the loop rolled, so the static text contains one copy.  Trip counts
are recovered from the HLO itself (scan induction bound) where possible and
fall back to the config's layer count.

Hardware constants: TPU v5e — 197 TFLOP/s bf16, 819 GB/s HBM, ~50 GB/s/link ICI.
"""
from __future__ import annotations

import math
import re
from dataclasses import dataclass, field

__all__ = ["HW", "hlo_cost_analysis", "parse_collectives", "roofline_terms",
           "model_flops"]


def hlo_cost_analysis(compiled) -> dict:
    """`compiled.cost_analysis()` across jax versions: older releases return
    a per-device list of dicts, newer ones a single dict."""
    cost = compiled.cost_analysis()
    if isinstance(cost, (list, tuple)):
        return cost[0] if cost else {}
    return cost

PEAK_FLOPS = 197e12          # bf16 per chip
HBM_BW = 819e9               # bytes/s per chip
ICI_BW = 50e9                # bytes/s per link (approx, per chip)

HW = {"peak_flops": PEAK_FLOPS, "hbm_bw": HBM_BW, "ici_bw": ICI_BW}

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "token": 0, "s4": 1, "u4": 1, "f8e4m3fn": 1, "f8e5m2": 1,
}

_COLL_RE = re.compile(
    r"=\s*(.+?)\s*"
    r"(all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start)?\(")
_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
# computation headers: `%name (params...) -> type {` — params may contain
# nested parens (tuple-typed scan carries), hence the greedy middle match
_COMP_HDR_RE = re.compile(r"^\s*(?:ENTRY\s+)?%?([\w.\-]+)\s*\(.*\)\s*->\s*.+\{\s*$")
_BODY_RE = re.compile(r"(?:body|condition)=%?([\w.\-]+)")
_CALLS_RE = re.compile(r"(?:calls|to_apply)=%?([\w.\-]+)")


def _shape_bytes(text: str) -> int:
    """Sum bytes of all shapes found in an HLO result type string."""
    total = 0
    for dt, dims in _SHAPE_RE.findall(text):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                if d:
                    n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


@dataclass
class CollectiveStats:
    bytes_by_kind: dict[str, int] = field(default_factory=dict)
    count_by_kind: dict[str, int] = field(default_factory=dict)

    @property
    def total_bytes(self) -> int:
        return sum(self.bytes_by_kind.values())


def parse_collectives(hlo_text: str, loop_multiplier: int = 1) -> CollectiveStats:
    """Sum collective bytes from post-SPMD HLO text.

    XLA keeps lax.scan rolled (one while body in the text), so collectives
    inside computations referenced as while bodies — or reachable from them
    via calls= — are scaled by `loop_multiplier` (the dominant scan's trip
    count: the layer count for our stacks).  Nested scans of different trip
    counts get the same single multiplier (documented approximation; the
    cell JSON stores raw and scaled numbers).
    """
    # Pass 1: collectives + call edges per computation, loop-body names.
    per_comp: dict[str, dict[str, int]] = {}
    per_comp_cnt: dict[str, dict[str, int]] = {}
    calls: dict[str, set] = {}
    body_names: set[str] = set()
    cur = ""
    for line in hlo_text.splitlines():
        hdr = _COMP_HDR_RE.match(line)
        if hdr:
            cur = hdr.group(1)
            continue
        for name in _BODY_RE.findall(line):
            body_names.add(name)
        for name in _CALLS_RE.findall(line):
            calls.setdefault(cur, set()).add(name)
        m = _COLL_RE.search(line)
        if not m:
            continue
        kind = m.group(2)
        nbytes = _shape_bytes(m.group(1))
        d = per_comp.setdefault(cur, {})
        d[kind] = d.get(kind, 0) + nbytes
        c = per_comp_cnt.setdefault(cur, {})
        c[kind] = c.get(kind, 0) + 1

    # Pass 2: computations transitively reachable from loop bodies.
    in_loop: set[str] = set()
    frontier = set(body_names)
    while frontier:
        nxt = set()
        for name in frontier:
            if name in in_loop:
                continue
            in_loop.add(name)
            nxt |= calls.get(name, set())
        frontier = nxt - in_loop

    stats = CollectiveStats()
    for comp, kinds in per_comp.items():
        if not isinstance(kinds, dict):
            continue
        mult = loop_multiplier if comp in in_loop else 1
        for kind, nbytes in kinds.items():
            stats.bytes_by_kind[kind] = stats.bytes_by_kind.get(kind, 0) + nbytes * mult
    for comp, kinds in per_comp_cnt.items():
        for kind, n in kinds.items():
            stats.count_by_kind[kind] = stats.count_by_kind.get(kind, 0) + n
    return stats


def parse_collectives_nested(hlo_text: str, loop_trips: list[int]) -> CollectiveStats:
    """Depth-aware variant: `loop_trips[d]` is the trip count of while loops
    at nesting depth d (0 = outermost, e.g. [microbatches, layers]).  A
    collective inside a depth-d body is scaled by prod(loop_trips[:d+1]);
    deeper loops than provided reuse the last trip count once (inner chunk
    scans typically hold no collectives)."""
    per_comp: dict[str, dict[str, int]] = {}
    calls: dict[str, set] = {}
    while_bodies: dict[str, set] = {}   # comp -> bodies of whiles inside it
    cur = ""
    for line in hlo_text.splitlines():
        hdr = _COMP_HDR_RE.match(line)
        if hdr:
            cur = hdr.group(1)
            continue
        for name in _BODY_RE.findall(line):
            while_bodies.setdefault(cur, set()).add(name)
        for name in _CALLS_RE.findall(line):
            calls.setdefault(cur, set()).add(name)
        m = _COLL_RE.search(line)
        if m:
            d = per_comp.setdefault(cur, {})
            d[m.group(2)] = d.get(m.group(2), 0) + _shape_bytes(m.group(1))

    # nesting depth per computation (ENTRY not in body sets -> depth 0)
    all_bodies = set().union(*while_bodies.values()) if while_bodies else set()
    roots = set(per_comp) | set(calls) | set(while_bodies)
    depth: dict[str, int] = {c: 0 for c in roots - all_bodies}
    frontier = list(depth)
    while frontier:
        c = frontier.pop()
        dc = depth[c]
        for b in while_bodies.get(c, ()):       # entering a while: depth+1
            if depth.get(b, -1) < dc + 1:
                depth[b] = dc + 1
                frontier.append(b)
        for b in calls.get(c, ()):              # fusion call: same depth
            if depth.get(b, -1) < dc:
                depth[b] = dc
                frontier.append(b)

    stats = CollectiveStats()
    for comp, kinds in per_comp.items():
        d = depth.get(comp, 0)
        mult = 1
        for i in range(min(d, len(loop_trips))):
            mult *= loop_trips[i]
        if d > len(loop_trips) and loop_trips:
            mult *= loop_trips[-1]
        for kind, nbytes in kinds.items():
            stats.bytes_by_kind[kind] = stats.bytes_by_kind.get(kind, 0) + nbytes * mult
            stats.count_by_kind[kind] = stats.count_by_kind.get(kind, 0) + 1
    return stats


def roofline_terms(flops_per_dev: float, bytes_per_dev: float,
                   coll_bytes_per_dev: float) -> dict:
    compute = flops_per_dev / PEAK_FLOPS
    memory = bytes_per_dev / HBM_BW
    collective = coll_bytes_per_dev / ICI_BW
    terms = {"compute_s": compute, "memory_s": memory, "collective_s": collective}
    dom = max(terms, key=lambda k: terms[k])
    terms["dominant"] = dom
    bound = max(compute, memory, collective)
    terms["roofline_fraction"] = compute / bound if bound > 0 else 0.0
    return terms


def model_flops(arch, shape, n_params: int, n_active: int | None = None) -> float:
    """MODEL_FLOPS: 6*N*D train / 2*N*D forward, N_active for MoE."""
    n = n_active if n_active is not None else n_params
    if shape.kind == "train":
        return 6.0 * n * shape.global_batch * shape.seq_len
    if shape.kind == "prefill":
        return 2.0 * n * shape.global_batch * shape.seq_len
    return 2.0 * n * shape.global_batch   # decode: one token per example


def active_params(arch, n_params: int, model=None) -> int:
    """N_active for MoE archs: expert params scaled by top_k / n_experts."""
    if arch.moe is None:
        return n_params
    e, k = arch.moe.n_experts, arch.moe.top_k
    expert = arch.n_layers * 3 * arch.d_model * arch.d_ff * e
    return int(n_params - expert + expert * (k / e))


# ===========================================================================
# Analytic cost model (per DESIGN.md §8 and EXPERIMENTS.md §Roofline).
#
# XLA's cost_analysis() counts a rolled while-loop body ONCE, so for
# scan-over-layers programs the compiled numbers undercount by ~L.  The
# roofline therefore uses this analytic model — exact matmul FLOP counts per
# block type — validated against cost_analysis() on small *unrolled* configs
# (tests/test_roofline.py).  Collective bytes still come from the HLO parse.
#
# Conventions: matmul(m,n,k) = 2mnk FLOPs; T = tokens processed; causal
# attention scores cost 1/2 of full.  Train multiplier: fwd + 2x bwd + 1x
# remat recompute = 4x fwd (remat="full"), 3x without.
# ===========================================================================

def _attn_fwd_flops(cfg, t: int, s_ctx: int, causal: bool = True) -> float:
    d, h, hkv, hd = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.hd
    proj = 2.0 * t * d * (2 * h * hd + 2 * hkv * hd)      # q, o, k, v
    sc = 0.5 if causal else 1.0
    scores = 2.0 * t * s_ctx * h * hd * sc * 2            # qk^T + w.v
    return proj + scores


def _mlp_fwd_flops(cfg, t: int) -> float:
    return 6.0 * t * cfg.d_model * cfg.d_ff


def _moe_fwd_flops(cfg, t: int, seq: int) -> float:
    d, f = cfg.d_model, cfg.d_ff
    e, k = cfg.moe.n_experts, cfg.moe.top_k
    router = 2.0 * t * d * e
    experts = 6.0 * t * k * d * f
    cap = min(int(math.ceil(seq * k / e * cfg.moe.capacity_factor)), seq)
    if cfg.moe.impl == "capacity":
        dispatch = 2 * (2.0 * t * e * cap * d)   # dispatch + combine einsums
    elif cfg.moe.impl == "hybrid":
        dispatch = 2.0 * t * e * cap * d         # combine einsum only
    else:
        dispatch = 0.0                           # gather / ragged / dense
    return router + experts + dispatch


def _mamba_fwd_flops(cfg, t: int) -> float:
    d = cfg.d_model
    di = cfg.ssm_expand * d
    n = cfg.ssm_state
    hs = di // cfg.ssm_head_dim
    q = cfg.ssm_chunk
    in_proj = 2.0 * t * d * (2 * di + 2 * n + hs)
    conv = 2.0 * t * di * cfg.conv_width
    intra = 2.0 * t * q * (n + di) * 0.5          # causal-masked chunk matmuls
    inter = 2.0 * t * di * n * 2                  # y_inter + state update
    out = 2.0 * t * di * d
    return in_proj + conv + intra + inter + out


def _mlstm_fwd_flops(cfg, t: int) -> float:
    d, h = cfg.d_model, cfg.n_heads
    hd = d // h
    q = cfg.ssm_chunk
    proj = 2.0 * t * d * (5 * d + 2 * h)          # q,k,v,og,wo + gates
    intra = 6.0 * t * q * d * 0.5                 # g, y_num, n_num (causal)
    inter = 2.0 * t * d * hd * 2                  # C.q + state outer products
    return proj + intra + inter


def _slstm_fwd_flops(cfg, t: int) -> float:
    d, h = cfg.d_model, cfg.n_heads
    hd = d // h
    proj = 2.0 * t * d * (2 * d + 2 * h) + 2.0 * t * d * d
    recur = t * h * (2 * 2 * hd * hd + 8 * hd)    # zg_r/og_r matvecs + gates
    return proj + recur


def _block_fwd_flops(cfg, kind: str, t: int, s_ctx: int, seq: int) -> float:
    if kind in ("attn_mlp", "shared_attn", "enc_attn_mlp"):
        f = _attn_fwd_flops(cfg, t, s_ctx, causal=(kind != "enc_attn_mlp"))
        if cfg.d_ff:
            f += _mlp_fwd_flops(cfg, t)
        return f
    if kind == "attn_moe":
        return _attn_fwd_flops(cfg, t, s_ctx) + _moe_fwd_flops(cfg, t, seq)
    if kind == "dec_attn_mlp":
        f = _attn_fwd_flops(cfg, t, s_ctx)
        # cross attention: proj for q/o on T, kv on T_enc, scores over S_enc
        d, h, hkv, hd = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.hd
        b = max(t // max(seq, 1), 1)
        t_enc = b * cfg.frontend_len
        f += 2.0 * t * d * 2 * h * hd + 2.0 * t_enc * d * 2 * hkv * hd
        f += 2.0 * t * cfg.frontend_len * h * hd * 2
        f += _mlp_fwd_flops(cfg, t)
        return f
    if kind == "mamba":
        return _mamba_fwd_flops(cfg, t)
    if kind == "mlstm":
        return _mlstm_fwd_flops(cfg, t)
    if kind == "slstm":
        return _slstm_fwd_flops(cfg, t)
    raise ValueError(kind)


def analytic_flops(arch, shape, segments) -> dict:
    """Global forward/step FLOPs for one cell, by component."""
    b, s = shape.global_batch, shape.seq_len
    kind = shape.kind
    if kind in ("train", "prefill"):
        t, s_ctx = b * s, s
    else:
        t, s_ctx = b, s                           # one token, full-cache scores
    out: dict[str, float] = {}
    body = 0.0
    for (k, count, _sh) in segments:
        body += count * _block_fwd_flops(arch, k, t, s_ctx, s)
    out["body_fwd"] = body
    if arch.is_encdec:
        t_enc = (b if kind != "train" and kind != "prefill" else b) * arch.frontend_len
        t_enc = b * arch.frontend_len
        enc = arch.enc_layers * _block_fwd_flops(arch, "enc_attn_mlp",
                                                 t_enc, arch.frontend_len, arch.frontend_len)
        if kind == "decode":
            enc = 0.0                             # encoder ran at prefill
        out["encoder_fwd"] = enc
        body += enc
    head_t = t if kind != "decode" else b
    if kind == "prefill":
        head_t = b                                # only last-position logits
    out["lm_head_fwd"] = 2.0 * head_t * arch.d_model * arch.vocab
    fwd = body + out["lm_head_fwd"]
    out["fwd_total"] = fwd
    if kind == "train":
        mult = 4.0 if arch.remat == "full" else 3.0
        out["train_mult"] = mult
        out["step_total"] = fwd * mult
    else:
        out["step_total"] = fwd
    return out


def analytic_bytes(arch, shape, segments, mesh_shape: dict,
                   n_params: int) -> dict:
    """Per-DEVICE HBM bytes for one step (the memory-roofline numerator).

    Model: TP weight shards are read once per matmul use (attention scores
    stay in VMEM — the Pallas flash path is the TPU target); activations
    count residual-width tensors in/out per block; decode reads its cache
    shard once per token.  Coefficients documented inline; validated for
    order against memory_analysis/cost_analysis in tests.
    """
    chips = math.prod(mesh_shape.values())
    model_ax = mesh_shape.get("model", 1)
    data_ax = chips // model_ax
    b, s = shape.global_batch, shape.seq_len
    dt = 2 if arch.dtype == "bfloat16" else 4
    d = arch.d_model
    kind = shape.kind
    t_dev = (b * s) / data_ax if kind in ("train", "prefill") else b / data_ax

    w_shard = n_params * dt / chips
    w_gathered = n_params * dt / model_ax        # what compute actually reads
    out: dict[str, float] = {}
    if kind == "train":
        # fwd + remat recompute + dgrad + wgrad weight reads; grads f32 RW;
        # AdamW: read+write mu/nu/params (f32-equivalents sharded over chips)
        out["weights"] = 4 * w_gathered
        out["optimizer"] = (n_params * (4 + 4 + 4) * 2 + n_params * 4 * 2) / chips
        act_coeff = 12.0                          # residual-width tensors per block
        n_blocks = sum(c for _, c, _ in segments) + arch.enc_layers
        out["activations"] = act_coeff * n_blocks * t_dev * d * dt * 2
        out["logits"] = 2 * t_dev * (arch.vocab / model_ax) * 4 * 2
    elif kind == "prefill":
        out["weights"] = w_gathered
        act_coeff = 6.0
        n_blocks = sum(c for _, c, _ in segments) + arch.enc_layers
        out["activations"] = act_coeff * n_blocks * t_dev * d * dt
        out["cache_write"] = _cache_bytes(arch, segments, b, s, dt) / chips
        out["logits"] = 2 * (b / data_ax) * (arch.vocab / model_ax) * 4
    else:
        out["weights"] = w_gathered               # every weight read per token
        out["cache_rw"] = _cache_bytes(arch, segments, b, s, dt) / chips
        out["activations"] = 24.0 * sum(c for _, c, _ in segments) * t_dev * d * dt
        out["logits"] = 2 * (b / data_ax) * (arch.vocab / model_ax) * 4
    out["total"] = sum(v for k, v in out.items() if k != "total")
    return out


def _cache_bytes(arch, segments, b: int, s: int, dt: int) -> float:
    """Global decode-state bytes across all layers."""
    total = 0.0
    for kind, count, _ in segments:
        if kind in ("attn_mlp", "attn_moe", "shared_attn", "dec_attn_mlp"):
            total += count * 2 * b * s * arch.n_kv_heads * arch.hd * dt
            if kind == "dec_attn_mlp":
                total += count * 2 * b * arch.frontend_len * arch.n_kv_heads * arch.hd * dt
        elif kind == "mamba":
            di = arch.ssm_expand * arch.d_model
            hs = di // arch.ssm_head_dim
            total += count * b * (hs * arch.ssm_head_dim * arch.ssm_state * 4
                                  + (arch.conv_width - 1) * di * dt)
        elif kind == "mlstm":
            hd = arch.d_model // arch.n_heads
            total += count * b * arch.n_heads * (hd * hd + hd) * 4
        elif kind == "slstm":
            hd = arch.d_model // arch.n_heads
            total += count * b * arch.n_heads * (3 * hd + 1) * 4
    return total

"""Precomputed (D-free) validator vs legacy per-step recompute.

The legacy serializing validator does O(cap · K_max · D) *sequential* work
per epoch: every scan step recomputes distances against the full
fixed-capacity pool and rewrites the (K_max, D) center carry.  The
precomputed path (DESIGN.md §9) batches all D-dimensional work into one MXU
precompute — payload→C^{t-1} distances reused from propose plus one
(cap, cap) payload pairwise matrix — leaving an O(cap²) scalar scan and a
single batched pool write.

This benchmark times both paths of the SAME compiled engine pass on a
validator-bound configuration (large cap, K_max >= 512, D >= 256), checks
they produce bit-identical results, and records the trajectory in
BENCH_validator.json.

  PYTHONPATH=src python -m benchmarks.validator_scan
"""
from __future__ import annotations

import json
import os
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import DPMeansTransaction, OCCEngine
from repro.core.occ import block_epochs
from repro.data import dp_stick_breaking_data


def run(n: int = 2048, d: int = 256, k_max: int = 512, pb: int = 512,
        cap: int = 256, lam: float = 16.0, repeats: int = 3,
        out_path: str | None = None, quiet: bool = False):
    x, _, _ = dp_stick_breaking_data(n, dim=d, seed=0)
    x = jnp.asarray(x)
    txn = DPMeansTransaction(lam, k_max=k_max)
    t_epochs = block_epochs(n, pb)

    eng_fast = OCCEngine(txn, pb, validate_cap=cap,
                         validate_mode="precomputed")
    eng_legacy = OCCEngine(txn, pb, validate_cap=cap,
                           validate_mode="legacy")

    # warm both compilations and check the math is bit-identical
    rf = jax.block_until_ready(eng_fast.run(x))
    rl = jax.block_until_ready(eng_legacy.run(x))
    assert np.array_equal(np.asarray(rf.assign), np.asarray(rl.assign))
    assert np.array_equal(np.asarray(rf.pool.centers),
                          np.asarray(rl.pool.centers))
    assert np.array_equal(np.asarray(rf.stats.proposed),
                          np.asarray(rl.stats.proposed))

    t0 = time.time()
    for _ in range(repeats):
        jax.block_until_ready(eng_legacy.run(x))
    legacy_s = (time.time() - t0) / repeats

    t0 = time.time()
    for _ in range(repeats):
        jax.block_until_ready(eng_fast.run(x))
    fast_s = (time.time() - t0) / repeats

    record = {
        "bench": "validator_scan",
        "n": n, "d": d, "k_max": k_max, "pb": pb, "cap": cap,
        "t_epochs": t_epochs, "repeats": repeats,
        "legacy_wall_s": legacy_s,
        "precomputed_wall_s": fast_s,
        "speedup": legacy_s / fast_s,
        "legacy_step_cost": "O(cap*K_max*D) sequential + (K_max,D) carry",
        "precomputed_step_cost": "one MXU precompute + O(cap^2) scalar scan",
        "proposed_total": int(np.asarray(rf.stats.proposed).sum()),
        "accepted_total": int(np.asarray(rf.stats.accepted).sum()),
    }
    # Only persist when a path is given (the __main__ canonical run does);
    # suite/CI fast-mode invocations must not clobber the tracked record.
    if out_path is not None:
        with open(out_path, "w") as f:
            json.dump(record, f, indent=2)

    rows = [
        (f"validator_legacy_n{n}_d{d}_k{k_max}_cap{cap}", legacy_s * 1e6,
         "per_step=O(K_max*D)"),
        (f"validator_precomputed_n{n}_d{d}_k{k_max}_cap{cap}", fast_s * 1e6,
         f"per_step=O(cap);speedup={legacy_s / fast_s:.2f}x"),
    ]
    if not quiet:
        for r in rows:
            print(f"{r[0]},{r[1]:.0f},{r[2]}")
    return rows


if __name__ == "__main__":
    run(out_path=os.path.join(os.path.dirname(os.path.dirname(
        os.path.abspath(__file__))), "BENCH_validator.json"))

"""Unified precomputed validator vs the legacy per-step recompute.

The legacy serializing validator (now `core/_reference.py`, tests/bench
only) does O(cap · K_max · D) *sequential* work per epoch: every scan step
recomputes distances — or, for BP-means, a full coordinate-pass refit —
against the full fixed-capacity pool and rewrites the (K_max, D) center
carry.  The engine path (DESIGN.md §9/§11) batches all D-dimensional work
into one MXU precompute and leaves a D-free serializing resolution.

Variants timed here, all on the SAME problem sizes:

  dp_reference / dp_precomputed  — the PR-2 pair (payload scalar scan)
  dp_logdepth                    — the §11 fixed-point resolution
  dp_adaptive                    — Thm-3.3 adaptive cap, post-burn-in pass
                                   (vs the same warm pass at full cap)
  bp_reference / bp_gram         — BP-means legacy refit vs Gram-carry scan

Each fast path is checked against its reference (bit-identical for DP,
decision-identical for BP) before timing, and the trajectory lands in
BENCH_validator.json with deltas vs the previous tracked record (the PR-2
baseline on first run after this refactor).

  PYTHONPATH=src python -m benchmarks.validator_scan
"""
from __future__ import annotations

import json
import os
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import (
    BPMeansTransaction, DPMeansTransaction, OCCEngine,
    precomputed_gather_validate,
)
from repro.core._reference import _reference_validate, reference_pass
from repro.core.occ import block_epochs
from repro.data import dp_stick_breaking_data


def _time(fn, repeats):
    jax.block_until_ready(fn())              # warm the jit cache, fully
    best = float("inf")
    for _ in range(repeats):                 # min-of-repeats: robust to the
        t0 = time.time()                     # CI container's noisy wall clock
        jax.block_until_ready(fn())
        best = min(best, time.time() - t0)
    return best


def run(n: int = 2048, d: int = 256, k_max: int = 512, pb: int = 512,
        cap: int = 256, lam: float = 16.0, bp_lam: float = 14.0,
        repeats: int = 5, out_path: str | None = None, quiet: bool = False):
    x, _, _ = dp_stick_breaking_data(n, dim=d, seed=0)
    x = jnp.asarray(x)
    t_epochs = block_epochs(n, pb)
    rows = []

    # ---------------------------------------------------------- DP-means
    txn = DPMeansTransaction(lam, k_max=k_max)
    pool0 = txn.init_pool(x[:pb])
    eng_fast = OCCEngine(txn, pb, validate_cap=cap)
    eng_logd = OCCEngine(txn, pb, validate_cap=cap, scan_mode="logdepth")

    rf = jax.block_until_ready(eng_fast.run(x))
    rl = jax.block_until_ready(eng_logd.run(x))
    rp, ra, _, rst = reference_pass(txn, pool0, x, pb=pb, cap=cap)
    assert np.array_equal(np.asarray(rf.assign), np.asarray(ra))
    assert np.array_equal(np.asarray(rf.pool.centers), np.asarray(rp.centers))
    assert np.array_equal(np.asarray(rf.assign), np.asarray(rl.assign))
    assert np.array_equal(np.asarray(rf.pool.centers),
                          np.asarray(rl.pool.centers))
    assert np.array_equal(np.asarray(rf.stats.proposed),
                          np.asarray(rst.proposed))

    ref_s = _time(lambda: reference_pass(txn, pool0, x, pb=pb, cap=cap)[0]
                  .centers, repeats)
    fast_s = _time(lambda: eng_fast.run(x).pool.centers, repeats)
    logd_s = _time(lambda: eng_logd.run(x).pool.centers, repeats)

    # Adaptive cap: time a WARM pass (the Thm-3.3 regime the cap targets —
    # epoch 1 of a cold pool always runs full-width by design).
    eng_ad = OCCEngine(txn, pb, validate_cap="adaptive")
    warm = eng_ad.run(x)                  # burn-in: observes the sent rate
    eng_ad.run(x, pool=warm.pool)         # warm pass: shrunken cap live
    cap_ad = eng_ad.cap_history[-1]
    ra2 = jax.block_until_ready(eng_ad.run(x, pool=warm.pool))
    rf2 = jax.block_until_ready(eng_fast.run(x, pool=warm.pool))
    assert np.array_equal(np.asarray(ra2.assign), np.asarray(rf2.assign))
    adapt_s = _time(lambda: eng_ad.run(x, pool=warm.pool).pool.centers,
                    repeats)
    full_warm_s = _time(lambda: eng_fast.run(x, pool=warm.pool).pool.centers,
                        repeats)
    assert eng_ad.n_cap_retries == 0

    # ---------------------------------------------------------- BP-means
    txb = BPMeansTransaction(bp_lam, k_max=k_max, init_mean=False)
    zb = txb.make_state(x)
    poolb = txb.init_pool(x[:pb])
    eng_bp = OCCEngine(txb, pb, validate_cap=cap)
    bf = jax.block_until_ready(eng_bp.run(x, state=zb))
    bp_ref, bra, _, brst = reference_pass(txb, poolb, x, state=zb, pb=pb,
                                          cap=cap)
    assert np.array_equal(np.asarray(bf.assign), np.asarray(bra))
    assert np.array_equal(np.asarray(bf.stats.proposed),
                          np.asarray(brst.proposed))
    assert int(bf.pool.count) == int(bp_ref.count)

    # The validator in isolation — the serialization point the §11 Gram
    # carry rewrites.  Epoch-1 inputs (cold pool: everything proposes, the
    # cap window saturates) are the heaviest serial load; propose cost is
    # identical on both paths and timed separately for context.
    prop_step = jax.jit(txb.propose)
    send_b, payload_b, aux_b, _ = prop_step(poolb, x[:pb], zb[:pb])
    count0_b = poolb.count
    acc_b = lambda p, v_j, a_j: txb.accept(p, v_j, a_j, count0_b)
    gram_step = jax.jit(lambda p, s, pay: precomputed_gather_validate(
        p, s, pay, None, txb.precompute_accept, txb.accept_pre, cap=cap))
    ref_step = jax.jit(lambda p, s, pay: _reference_validate(
        p, s, pay, acc_b, None, cap=cap))
    bp_ref_s = _time(lambda: ref_step(poolb, send_b, payload_b)[0].centers,
                     repeats)
    bp_gram_s = _time(lambda: gram_step(poolb, send_b, payload_b)[0].centers,
                      repeats)
    bp_prop_s = _time(lambda: prop_step(poolb, x[:pb], zb[:pb])[1], repeats)

    # Whole-pass wall clock (propose + validate + writeback, all epochs).
    bp_pass_ref_s = _time(lambda: reference_pass(
        txb, poolb, x, state=zb, pb=pb, cap=cap)[0].centers, repeats)
    bp_pass_gram_s = _time(lambda: eng_bp.run(x, state=zb).pool.centers,
                           repeats)

    record = {
        "bench": "validator_scan",
        "n": n, "d": d, "k_max": k_max, "pb": pb, "cap": cap,
        "t_epochs": t_epochs, "repeats": repeats,
        "dp_reference_wall_s": ref_s,
        "dp_precomputed_wall_s": fast_s,
        "dp_logdepth_wall_s": logd_s,
        "dp_speedup": ref_s / fast_s,
        "dp_adaptive_wall_s": adapt_s,
        "dp_fullcap_warm_wall_s": full_warm_s,
        "dp_adaptive_speedup_after_epoch1": full_warm_s / adapt_s,
        "dp_adaptive_cap": cap_ad,
        "bp_reference_validator_epoch_s": bp_ref_s,
        "bp_gram_validator_epoch_s": bp_gram_s,
        "bp_validator_speedup": bp_ref_s / bp_gram_s,
        "bp_propose_epoch_s": bp_prop_s,
        "bp_reference_pass_wall_s": bp_pass_ref_s,
        "bp_gram_pass_wall_s": bp_pass_gram_s,
        "bp_pass_speedup": bp_pass_ref_s / bp_pass_gram_s,
        "bp_k": int(bf.pool.count),
        "reference_step_cost": "O(cap*K_max*D) sequential + (K_max,D) carry",
        "precomputed_step_cost": "one MXU precompute + D-free resolution",
        "proposed_total": int(np.asarray(rf.stats.proposed).sum()),
        "accepted_total": int(np.asarray(rf.stats.accepted).sum()),
    }
    # Deltas vs the previously tracked record (PR-2 baseline on the first
    # run after the §11 refactor: its fields were legacy_/precomputed_).
    if out_path is not None and os.path.exists(out_path):
        with open(out_path) as f:
            prev = json.load(f)
        prev_ref = prev.get("dp_reference_wall_s",
                            prev.get("legacy_wall_s"))
        prev_fast = prev.get("dp_precomputed_wall_s",
                             prev.get("precomputed_wall_s"))
        if prev_ref and prev_fast:
            record["baseline"] = {
                "dp_reference_wall_s": prev_ref,
                "dp_precomputed_wall_s": prev_fast,
                "dp_speedup": prev_ref / prev_fast,
                # The PR-2 record was mean-of-repeats; this bench switched
                # to min-of-repeats, so part of any delta is methodology.
                "timing": prev.get("timing", "mean_of_repeats"),
            }
            record["dp_precomputed_delta_vs_baseline"] = prev_fast / fast_s
    record["timing"] = "min_of_repeats"
    # Only persist when a path is given (the __main__ canonical run does);
    # suite/CI fast-mode invocations must not clobber the tracked record.
    if out_path is not None:
        with open(out_path, "w") as f:
            json.dump(record, f, indent=2)

    tag = f"n{n}_d{d}_k{k_max}_cap{cap}"
    rows += [
        (f"validator_dp_reference_{tag}", ref_s * 1e6,
         "per_step=O(K_max*D)"),
        (f"validator_dp_precomputed_{tag}", fast_s * 1e6,
         f"per_step=O(cap);speedup={ref_s / fast_s:.2f}x"),
        (f"validator_dp_logdepth_{tag}", logd_s * 1e6,
         f"fixed_point;vs_serial={fast_s / logd_s:.2f}x"),
        (f"validator_dp_adaptive_{tag}", adapt_s * 1e6,
         f"cap={cap_ad};warm_speedup={full_warm_s / adapt_s:.2f}x"),
        (f"validator_bp_reference_{tag}", bp_ref_s * 1e6,
         "per_step=O(K_max*D) refit;epoch1_validator_only"),
        (f"validator_bp_gram_{tag}", bp_gram_s * 1e6,
         f"gram_carry;speedup={bp_ref_s / bp_gram_s:.2f}x"
         f";propose_epoch_us={bp_prop_s * 1e6:.0f}"),
        (f"validator_bp_pass_{tag}", bp_pass_gram_s * 1e6,
         f"whole_pass;vs_reference={bp_pass_ref_s / bp_pass_gram_s:.2f}x"),
    ]
    if not quiet:
        for r in rows:
            print(f"{r[0]},{r[1]:.0f},{r[2]}")
    return rows


if __name__ == "__main__":
    run(out_path=os.path.join(os.path.dirname(os.path.dirname(
        os.path.abspath(__file__))), "BENCH_validator.json"))

# One function per paper table. Print ``name,us_per_call,derived`` CSV.
"""Benchmark harness.

  PYTHONPATH=src python -m benchmarks.run [--fast] [--quick] [--only fig3,...]

Suites:
  fig3       — paper Fig 3 / Fig 6: rejections vs N, bounded by Pb
  fig4       — paper Fig 4: strong scaling (emulated hosts + workload model)
  occ_engine — single-jit epoch scan vs legacy Python epoch loop
  validator  — precomputed (D-free) validator vs legacy per-step recompute
  serve      — cluster-serving plane: per-bucket latency + train-while-serve
  transport  — replication sockets: delta bytes/publish + commit latency
  recovery   — crash recovery: WAL append cost + checkpoint+replay time
  kernels    — Pallas kernel microbenches
  roofline   — §Roofline summary from the dry-run artifacts

--fast shrinks repeats/sizes (local iteration); --quick shrinks further to
a smoke pass over EVERY suite — wired into CI so benchmark scripts can't
silently rot (numbers from --quick are not meaningful, only liveness).
"""
from __future__ import annotations

import argparse
import sys


def main(argv=None) -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--fast", action="store_true",
                    help="smaller repeats / sizes (local iteration)")
    ap.add_argument("--quick", action="store_true",
                    help="minimal smoke sizes for CI — liveness only")
    ap.add_argument("--only", default=None,
                    help="comma-separated subset: "
                         "fig3,fig4,occ_engine,validator,serve,transport,"
                         "recovery,kernels,roofline")
    args = ap.parse_args(argv)
    only = set(args.only.split(",")) if args.only else None
    if args.quick:
        args.fast = True

    def want(name):
        return only is None or name in only

    print("name,us_per_call,derived")
    rows = []
    if want("fig3"):
        from benchmarks import fig3_rejections
        rows += fig3_rejections.run(
            repeats=1 if args.quick else (5 if args.fast else 20),
            ns=(256,) if args.quick else
               ((256, 1024) if args.fast else (256, 1024, 2560)),
            pbs=(64,) if args.quick else (16, 64, 256))
    if want("fig4"):
        from benchmarks import fig4_scaling
        rows += fig4_scaling.run(
            n=1024 if args.quick else (4096 if args.fast else 16384),
            pb=256 if args.quick else (512 if args.fast else 2048),
            ps=(1, 2) if args.quick else
               ((1, 2, 4) if args.fast else (1, 2, 4, 8)))
    if want("occ_engine"):
        from benchmarks import occ_engine
        rows += occ_engine.run(
            n=512 if args.quick else (2048 if args.fast else 8192),
            pb=128 if args.fast else 256,
            repeats=1 if args.quick else (3 if args.fast else 5))
    if want("validator"):
        from benchmarks import validator_scan
        d = 64 if args.quick else (128 if args.fast else 256)
        # thresholds scale with the data diameter so the smoke sizes drive
        # a comparable send/accept mix through every variant (DP + BP +
        # adaptive + logdepth)
        rows += validator_scan.run(
            n=256 if args.quick else (1024 if args.fast else 2048),
            d=d,
            k_max=64 if args.quick else (256 if args.fast else 512),
            pb=64 if args.quick else (256 if args.fast else 512),
            cap=32 if args.quick else (128 if args.fast else 256),
            lam=16.0 * (d / 256.0) ** 0.5,
            bp_lam=14.0 * (d / 256.0) ** 0.5,
            repeats=1 if args.quick else 3)
    if want("serve"):
        from benchmarks import cluster_service
        rows += cluster_service.run(
            n_train=1024 if args.quick else (4096 if args.fast else 8192),
            dim=8 if args.quick else 16,
            buckets=(8, 64) if args.quick else
                    ((8, 64, 512) if args.fast else (8, 64, 512, 4096)),
            repeats=2 if args.quick else (5 if args.fast else 20),
            coalesce_clients=4 if args.quick else 8,
            coalesce_reqs=8 if args.quick else 25,
            topk_ks=(4096,) if args.quick else
                    ((4096, 32768) if args.fast else (4096, 32768, 131072)),
            # --quick: steady-state + coalescing only; the CI workflow runs
            # the multi-model train-while-serve demo as its own serve-e2e
            # job, and the regression gate (check_regress) as its own step
            demo_queries=0 if args.quick else
                         (1000 if args.fast else 2000))
    if want("transport"):
        from benchmarks import transport
        rows += transport.run(
            n_followers=2,
            versions=8 if args.quick else (16 if args.fast else 32),
            trials=1 if args.quick else 3)
    if want("recovery"):
        from benchmarks import recovery
        rows += recovery.run(
            versions=10 if args.quick else (30 if args.fast else 62),
            checkpoint_every=4 if args.quick else 8,
            trials=1 if args.quick else 3)
    if want("kernels"):
        from benchmarks import kernels
        rows += kernels.run(quick=args.quick)
    if want("roofline"):
        from benchmarks import roofline_table
        rows += roofline_table.run()
    print(f"# {len(rows)} benchmark rows", file=sys.stderr)


if __name__ == "__main__":
    main()

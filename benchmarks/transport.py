"""Replication transport microbench: delta bytes/publish + commit latency.

In-process but over REAL loopback sockets: a delta-mode primary
`SnapshotStore` wired onto a `ReplicationServer`, N `ReplicationClient`
follower threads tailing it.  Measures the two §13 replication costs:

  * payload bytes per publish — O(ΔK·D) delta rows, not the
    O(capacity·D) a full-snapshot wire would pay;
  * publish→commit latency — `publish_pool` returning through
    `wait_acked` (every live follower durably applied + ACKed), i.e. the
    replication barrier the cluster driver runs per pass; the server's
    own per-ack samples give the one-way ack p50/p99.

`launch/occ_cluster.py` emits the multi-process e2e record
(BENCH_transport.json); this is the repeatable single-process microbench.

  PYTHONPATH=src python -m benchmarks.transport
"""
from __future__ import annotations

import json
import os
import time

import jax.numpy as jnp
import numpy as np

from repro.core.occ import CenterPool
from repro.distributed.transport import (ReplicationClient, ReplicationServer,
                                         store_digest)
from repro.obs import Obs
from repro.serving.snapshot import SnapshotStore


def _pools(versions: int, dk: int, dim: int):
    """An append-only version chain: version v holds the first v*dk rows
    of one fixed base — every publish after the first is a pure delta."""
    k_max = versions * dk
    base = np.random.default_rng(0).normal(
        size=(k_max, dim)).astype(np.float32)
    out = []
    for v in range(1, versions + 1):
        k = v * dk
        centers = jnp.zeros((k_max, dim), jnp.float32).at[:k].set(base[:k])
        out.append(CenterPool(centers, jnp.arange(k_max) < k,
                              jnp.asarray(k, jnp.int32), jnp.asarray(False)))
    return out


def measure_commit(n_followers: int, versions: int, dk: int, dim: int,
                   inject_sleep_s: float = 0.0, obs: Obs | None = None,
                   trial: int = 0) -> dict:
    """One trial: fresh server + followers, publish the whole chain with a
    commit barrier per version; returns latency stats and wire metrics.

    All timing goes through the registry: per-commit latency is observed
    into the ``bench_transport_commit_s{trial=..}`` histogram (the sleep
    injection lands INSIDE the timed block, so the regression gate's
    self-test exercises the registry measurement path itself), and the
    server's own ack RTT histogram shares the registry when a caller
    passes its `obs`."""
    obs = obs if obs is not None else Obs()
    pools = _pools(versions, dk, dim)
    srv = ReplicationServer(obs=obs)
    store = SnapshotStore(capacity=versions + 1, delta=True, model="bench",
                          wire=srv)
    clients = [ReplicationClient(srv.address, model="bench",
                                 capacity=versions + 1).start()
               for _ in range(n_followers)]
    try:
        for v, pool in enumerate(pools, start=1):
            with obs.metrics.timer("bench_transport_commit_s", trial=trial):
                store.publish_pool(pool)
                assert srv.wait_acked(v, "bench", timeout=30.0)
                if inject_sleep_s:
                    time.sleep(inject_sleep_s)
        assert all(store_digest(c.store) == store_digest(store)
                   for c in clients)
        m = srv.metrics()
    finally:
        srv.close()
    for c in clients:
        c.join(10.0)
    h = obs.metrics.get_histogram("bench_transport_commit_s", trial=trial)
    return dict(commit_p50_us=float(h.percentile(50) * 1e6),
                commit_p99_us=float(h.percentile(99) * 1e6),
                bytes_per_publish=m["bytes_sent"] / max(1, m["n_sent"]),
                ack_p50_ms=m["ack_p50_ms"], ack_p99_ms=m["ack_p99_ms"],
                n_acks=m["n_acks"])


def run(n_followers: int = 2, versions: int = 32, dk: int = 4, dim: int = 16,
        trials: int = 3, out_path: str | None = None, quiet: bool = False):
    best = None
    for _ in range(trials):
        t = measure_commit(n_followers, versions, dk, dim)
        if best is None or t["commit_p50_us"] < best["commit_p50_us"]:
            best = t
    record = {
        "bench": "transport_micro",
        "followers": n_followers, "versions": versions,
        "dk": dk, "dim": dim, "trials": trials,
        **best,
    }
    if out_path is not None:
        with open(out_path, "w") as f:
            json.dump(record, f, indent=2)
    rows = [
        (f"transport_commit_f{n_followers}_v{versions}",
         best["commit_p50_us"],
         f"p99_us={best['commit_p99_us']:.0f};"
         f"ack_p50_ms={best['ack_p50_ms']:.2f};"
         f"ack_p99_ms={best['ack_p99_ms']:.2f}"),
        (f"transport_delta_wire_f{n_followers}_v{versions}",
         best["commit_p50_us"],
         f"bytes_per_publish={best['bytes_per_publish']:.0f};"
         f"acks={best['n_acks']}"),
    ]
    if not quiet:
        for r in rows:
            print(f"{r[0]},{r[1]:.0f},{r[2]}")
    return rows


if __name__ == "__main__":
    run(out_path=os.path.join(os.path.dirname(os.path.dirname(
        os.path.abspath(__file__))), "BENCH_transport_micro.json"))

"""Render the §Roofline table from results/dryrun/*.json (the dry-run must
have been run first: python -m repro.launch.dryrun --all --both-meshes)."""
from __future__ import annotations

import glob
import json
import os

RESULTS = os.path.join(os.path.dirname(os.path.dirname(os.path.abspath(__file__))),
                       "results", "dryrun")


def load_cells(pattern: str = "*.json") -> list[dict]:
    cells = []
    for f in sorted(glob.glob(os.path.join(RESULTS, pattern))):
        with open(f) as fh:
            cells.append(json.load(fh))
    return cells


def markdown_table(cells: list[dict], multi_pod: bool = False) -> str:
    hdr = ("| arch | shape | dominant | compute s | memory s | collective s | "
           "MFU-bound | useful-FLOPs ratio | peak GB/dev |\n"
           "|---|---|---|---|---|---|---|---|---|\n")
    rows = []
    for c in cells:
        if c.get("multi_pod") != multi_pod or c.get("variant"):
            continue
        if c.get("status") == "skipped":
            rows.append(f"| {c['arch']} | {c['shape']} | — | — | — | — | — | — "
                        f"| skipped: {c['reason'][:40]} |")
            continue
        t = c["roofline"]
        mfu = (c["model_flops_per_dev"] / 197e12) / max(
            t["compute_s"], t["memory_s"], t["collective_s"])
        peak = (c["memory"]["temp_bytes"] or 0) / 1e9
        rows.append(
            f"| {c['arch']} | {c['shape']} | {t['dominant'].replace('_s','')} "
            f"| {t['compute_s']:.4f} | {t['memory_s']:.4f} "
            f"| {t['collective_s']:.4f} | {mfu:.3f} "
            f"| {c['useful_flops_ratio']:.3f} | {peak:.1f} |")
    return hdr + "\n".join(rows) + "\n"


def run(quiet: bool = False):
    cells = load_cells()
    ok = [c for c in cells if c.get("status") == "ok"]
    skipped = [c for c in cells if c.get("status") == "skipped"]
    rows = [("roofline_cells_ok", 0.0, f"count={len(ok)}"),
            ("roofline_cells_skipped", 0.0, f"count={len(skipped)}")]
    if ok:
        worst = min(ok, key=lambda c: (c["model_flops_per_dev"] / 197e12) /
                    max(c["roofline"]["compute_s"], c["roofline"]["memory_s"],
                        c["roofline"]["collective_s"]))
        rows.append(("roofline_worst_cell", 0.0,
                     f"{worst['arch']}x{worst['shape']}"
                     f";dominant={worst['roofline']['dominant']}"))
    if not quiet:
        for r in rows:
            print(f"{r[0]},{r[1]:.0f},{r[2]}")
        print(markdown_table(cells))
    return rows


if __name__ == "__main__":
    run()

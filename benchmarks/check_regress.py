"""Benchmark regression gate — fails CI on real slowdowns in key metrics.

Measures the latency-critical paths at --quick sizes:

  * ``validator_pass_us`` — one warm compiled OCC pass (bootstrap + epoch
    scan + the §11 precomputed validator: the training hot path);
  * ``service_p99_ms`` / ``service_p50_ms`` — solo request latency through
    `ClusterService.score` with warm jit caches (the serving hot path);
  * ``serve_topk_us`` — warm `ClusterService.topk` microbatch latency (the
    §16 retrieval-serving hot path: streaming top-k dispatch);
  * ``serve_qos_p99_us`` — interactive p99 through the coalescing admission
    queue while an analytics scan sits parked in its own lane (the §17
    mixed-traffic hot path: priority lanes must keep the interactive
    deadline timer independent of the parked scan);
  * ``transport_commit_us`` — median publish→all-followers-acked latency
    over loopback sockets (the §13 replication barrier hot path);
  * ``recovery_replay_us`` — full `recover_wal` wall time (checkpoint
    restore + delta replay: the §14 crash-recovery MTTR path).

Raw wall times are machine-dependent, so the GATE compares *normalized*
metrics: each raw time divided by ``reference_us``, a warm jitted matmul
timed on the same machine in the same process.  A slower CI runner scales
metric and reference together and the ratio holds; a code regression (or
the built-in ``--inject-sleep-ms`` self-test) inflates only the metric and
trips the gate.  Timings take the MIN over trials (robust to scheduler
noise; p99 is a per-trial tail, then min over trials).

The committed baseline lives in ``benchmarks/baselines/
BENCH_regress_quick.json`` (regenerate with ``--update`` after an
intentional perf change).  Exit status: 0 clean, 1 on >``--tol`` (default
30%) normalized slowdown in any key metric.  With ``--history-dir``
pointing at prior green-run ``--out`` artifacts, each metric's tolerance
tightens from the blanket 30% down toward its OBSERVED run-to-run spread
(median/MAD over the rolling window — see `rolling_tolerance`), so a CI
that accumulates artifacts gets a progressively sharper gate for free.

  PYTHONPATH=src python -m benchmarks.check_regress            # gate
  PYTHONPATH=src python -m benchmarks.check_regress --update   # rebaseline
  PYTHONPATH=src python -m benchmarks.check_regress --inject-sleep-ms 2
  # ^ self-test: the injected sleep must make the gate FAIL (exit 1)
"""
from __future__ import annotations

import argparse
import json
import os
import sys
import time

import jax
import jax.numpy as jnp
import numpy as np

KEY_METRICS = ("validator_pass_us", "service_p99_ms", "serve_topk_us",
               "serve_qos_p99_us", "transport_commit_us",
               "recovery_replay_us")
BASELINE = os.path.join(os.path.dirname(os.path.abspath(__file__)),
                        "baselines", "BENCH_regress_quick.json")
SIZES = dict(n=1024, dim=16, pb=64, k_max=256, lam=4.0,
             n_requests=200, request=17, trials=7,
             qos_requests=40, qos_trials=3, qos_deadline_ms=3.0,
             repl_followers=2, repl_versions=16, repl_trials=3,
             wal_versions=30, wal_dk=4, wal_ckpt_every=8, wal_trials=3)


def _reference_us(obs, trials: int = 7, reps: int = 50) -> float:
    """Warm jitted matmul on this machine: the speed normalizer (timed
    through the registry like every other metric here)."""
    a = jnp.asarray(np.random.default_rng(0).normal(
        size=(512, 512)).astype(np.float32))
    f = jax.jit(lambda a: a @ a)
    f(a).block_until_ready()
    for _ in range(trials):
        with obs.metrics.timer("bench_reference_s"):
            for _ in range(reps):
                f(a).block_until_ready()
    return obs.metrics.get_histogram("bench_reference_s").min / reps * 1e6


def _hist_summary(obs, name: str, **labels) -> dict | None:
    h = obs.metrics.get_histogram(name, **labels)
    if h is None or not h.count:
        return None
    return dict(count=h.count, p50=float(h.percentile(50)),
                p99=float(h.percentile(99)))


def measure(inject_sleep_ms: float = 0.0) -> dict:
    """Every number below is read back from ONE shared `MetricsRegistry`:
    the gate's own timers (``bench_*_s`` histograms; sleep injection lands
    INSIDE the timed blocks, so the self-test exercises the registry
    measurement path) plus the components' internal histograms
    (engine_pass_s, serve_request_s, transport_ack_rtt_s, wal_*_s), which
    ride along in the artifact as `component_metrics`."""
    from repro.core import DPMeansTransaction, OCCEngine
    from repro.data import dp_stick_breaking_data
    from repro.obs import Obs
    from repro.serving import ClusterService, SnapshotStore

    s = SIZES
    obs = Obs()
    m = obs.metrics
    x, _, _ = dp_stick_breaking_data(s["n"], seed=0, dim=s["dim"])
    x = jnp.asarray(x)
    inject = inject_sleep_ms / 1e3

    # --- validator pass: one compiled pass, warm ------------------------
    eng = OCCEngine(DPMeansTransaction(s["lam"], k_max=s["k_max"]),
                    pb=s["pb"], obs=obs)
    eng.run(x).pool.count.block_until_ready()        # compile + warm
    for _ in range(s["trials"]):
        with m.timer("bench_validator_pass_s"):
            eng.run(x).pool.count.block_until_ready()
            if inject:
                time.sleep(inject)   # --inject-sleep-ms self-test hook
    validator_pass_us = m.get_histogram("bench_validator_pass_s").min * 1e6

    # --- service latency: warm solo requests ----------------------------
    store = SnapshotStore()
    eng2 = OCCEngine(DPMeansTransaction(s["lam"], k_max=s["k_max"]),
                     pb=s["pb"], publish=store.publish_pass)
    eng2.partial_fit(x)
    eng2.flush()
    svc = ClusterService(store, obs=obs)
    q = x[:s["request"]]
    svc.score(q)                                     # warm (bucket, cap)
    p50s, p99s = [], []
    for t in range(s["trials"]):
        for _ in range(s["n_requests"]):
            with m.timer("bench_service_request_s", trial=t):
                svc.score(q)
                if inject:
                    time.sleep(inject)
        h = m.get_histogram("bench_service_request_s", trial=t)
        p50s.append(h.percentile(50))    # n_requests < sample_limit:
        p99s.append(h.percentile(99))    # exact, numpy-compatible

    # --- top-k serving: warm streaming-topk microbatch (§16) -------------
    svc.topk(q, k=8)                                 # warm (bucket, cap, k)
    for _ in range(s["trials"]):
        with m.timer("bench_serve_topk_s"):
            for _ in range(20):
                svc.topk(q, k=8)
                if inject:
                    time.sleep(inject)   # inside the timed block
    serve_topk_us = m.get_histogram("bench_serve_topk_s").min / 20 * 1e6

    # --- QoS mixed traffic: interactive p99 behind a parked scan (§17) ---
    import threading
    from repro.serving import Query, ServeConfig
    qsvc = ClusterService(
        store, ServeConfig(coalesce=True, coalesce_bucket=64,
                           coalesce_delay_ms=s["qos_deadline_ms"]), obs=obs)
    qi = q[:5]
    qsvc.score(qi)                   # warm the coalesced dispatch shapes
    qsvc.topk(q, k=8)
    park = threading.Thread(target=lambda: qsvc.submit(
        Query(q, kind="topk", k=8, priority="analytics",
              deadline_ms=120_000.0, max_staleness=2)))
    park.start()
    while qsvc.queue_depth_rows() < s["request"]:
        pass                         # the scan is parked in its own lane
    qp99s = []
    for t in range(s["qos_trials"]):
        for _ in range(s["qos_requests"]):
            with m.timer("bench_serve_qos_s", trial=t):
                qsvc.score(qi)
                if inject:
                    time.sleep(inject)
        qp99s.append(m.get_histogram("bench_serve_qos_s",
                                     trial=t).percentile(99))
    serve_qos_p99_us = min(qp99s) * 1e6
    qsvc.close()                     # flushes the parked scan (never drops)
    park.join(timeout=10)

    # --- replication commit: publish → all followers acked ---------------
    from benchmarks.transport import measure_commit
    transport_commit_us = min(
        measure_commit(s["repl_followers"], s["repl_versions"], dk=4,
                       dim=s["dim"], inject_sleep_s=inject,
                       obs=obs, trial=t)["commit_p50_us"]
        for t in range(s["repl_trials"]))

    # --- crash recovery: checkpoint restore + WAL delta replay -----------
    from benchmarks.recovery import measure_recovery
    recovery_replay_us = min(
        measure_recovery(s["wal_versions"], s["wal_dk"], s["dim"],
                         s["wal_ckpt_every"], inject_sleep_s=inject,
                         obs=obs, trial=t)["recovery_replay_us"]
        for t in range(s["wal_trials"]))

    ref_us = _reference_us(obs)
    metrics = {
        "validator_pass_us": validator_pass_us,
        "service_p50_ms": float(min(p50s) * 1e3),
        "service_p99_ms": float(min(p99s) * 1e3),
        "serve_topk_us": serve_topk_us,
        "serve_qos_p99_us": serve_qos_p99_us,
        "transport_commit_us": transport_commit_us,
        "recovery_replay_us": recovery_replay_us,
    }
    return {
        "bench": "regress_quick",
        "sizes": dict(s),
        "reference_us": ref_us,
        "metrics": metrics,
        "normalized": {k: v / ref_us for k, v in metrics.items()},
        # supplementary: what the instrumented components measured about
        # themselves during the same run (same registry, free to export)
        "component_metrics": {
            "engine_pass_s": _hist_summary(obs, "engine_pass_s"),
            "serve_request_s": _hist_summary(obs, "serve_request_s",
                                             model=""),
            "serve_queue_wait_s": _hist_summary(obs, "serve_queue_wait_s",
                                                model=""),
            "transport_ack_rtt_s": _hist_summary(obs, "transport_ack_rtt_s"),
            "wal_append_s": _hist_summary(obs, "wal_append_s"),
            "wal_recover_s": _hist_summary(obs, "wal_recover_s"),
        },
    }


def rolling_tolerance(history: list[float], base: float, default_tol: float,
                      floor: float = 0.10, min_points: int = 3,
                      k: float = 5.0) -> float:
    """Per-metric gate tolerance from a rolling window of prior HEALTHY
    normalized measurements (pure; unit-tested in
    tests/test_check_regress.py).

    The default 30% tolerance is sized for one cold CI runner with no
    memory; with a history of green-run artifacts the metric's real run-
    to-run spread is known, and the gate can afford to be tighter.  Spread
    is estimated robustly — median/MAD over the history-to-baseline ratios
    (MAD scaled by 1.4826 ≈ sigma for a normal), so one noisy historical
    run widens nothing — then:

        tol = clamp(|median - 1| + k * sigma, floor, default_tol)

    The |median - 1| term keeps a systematic baseline/runner offset from
    eating the noise allowance.  Fewer than `min_points` samples: the
    default applies unchanged (no history, no claims)."""
    if base <= 0 or len(history) < min_points:
        return default_tol
    ratios = sorted(h / base for h in history)
    med = ratios[len(ratios) // 2]
    mad = sorted(abs(r - med) for r in ratios)[len(ratios) // 2]
    spread = abs(med - 1.0) + k * 1.4826 * mad
    return min(default_tol, max(floor, spread))


def load_history(history_dir: str) -> dict[str, list[float]]:
    """Normalized key metrics from every parseable BENCH*.json artifact in
    `history_dir` (prior green runs' --out files).  Torn or foreign files
    are skipped — a corrupt artifact must not widen or crash the gate."""
    out: dict[str, list[float]] = {k: [] for k in KEY_METRICS}
    if not os.path.isdir(history_dir):
        return out
    for fn in sorted(os.listdir(history_dir)):
        if not (fn.startswith("BENCH") and fn.endswith(".json")):
            continue
        try:
            with open(os.path.join(history_dir, fn)) as f:
                rec = json.load(f)
            if rec.get("bench") != "regress_quick":
                continue
            norm = rec["normalized"]
            for key in KEY_METRICS:
                if key in norm:
                    out[key].append(float(norm[key]))
        except (OSError, ValueError, KeyError, TypeError):
            continue
    return out


def check(baseline: dict, fresh: dict, tol: float,
          history: dict[str, list[float]] | None = None) -> list[str]:
    failures = []
    for key in KEY_METRICS:
        base = baseline["normalized"].get(key)
        if base is None:        # metric newer than the committed baseline
            print(f"{key}: no baseline entry — skipped (rebaseline with "
                  f"--update)")
            continue
        key_tol = rolling_tolerance(history.get(key, ()) if history else [],
                                    base, tol)
        now = fresh["normalized"][key]
        ratio = now / base
        verdict = "FAIL" if ratio > 1.0 + key_tol else "ok"
        tightened = (f", tol={100 * key_tol:.0f}% from "
                     f"{len(history[key])}-run history"
                     if history and key_tol < tol else "")
        print(f"{key}: baseline_norm={base:.3f} fresh_norm={now:.3f} "
              f"ratio={ratio:.2f} (raw {fresh['metrics'][key]:.0f} vs "
              f"{baseline['metrics'][key]:.0f}) [{verdict}{tightened}]")
        if ratio > 1.0 + key_tol:
            failures.append(
                f"{key} regressed {100 * (ratio - 1):.0f}% "
                f"(> {100 * key_tol:.0f}% tolerance)")
    return failures


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.split("\n")[0])
    ap.add_argument("--baseline", default=BASELINE)
    ap.add_argument("--tol", type=float,
                    default=float(os.environ.get("CHECK_REGRESS_TOL", 0.30)))
    ap.add_argument("--update", action="store_true",
                    help="write the fresh measurement as the new baseline")
    ap.add_argument("--inject-sleep-ms", type=float, default=0.0,
                    help="inject an artificial slowdown into the measured "
                         "paths — the gate must then FAIL (self-test)")
    ap.add_argument("--history-dir", default=None,
                    help="directory of prior green-run --out artifacts; "
                         "with >=3 of them the per-metric tolerance "
                         "tightens to the observed run-to-run spread")
    ap.add_argument("--out", default=None,
                    help="also write the fresh measurement here (artifact)")
    args = ap.parse_args(argv)

    fresh = measure(args.inject_sleep_ms)
    print(f"reference_us={fresh['reference_us']:.1f}  "
          f"(machine-speed normalizer)")
    if args.out:
        with open(args.out, "w") as f:
            json.dump(fresh, f, indent=2)
    if args.update:
        os.makedirs(os.path.dirname(args.baseline), exist_ok=True)
        with open(args.baseline, "w") as f:
            json.dump(fresh, f, indent=2)
        print(f"baseline updated: {args.baseline}")
        return 0
    if not os.path.exists(args.baseline):
        print(f"no baseline at {args.baseline}; run with --update first",
              file=sys.stderr)
        return 2
    with open(args.baseline) as f:
        baseline = json.load(f)
    history = (load_history(args.history_dir)
               if args.history_dir else None)
    failures = check(baseline, fresh, args.tol, history)
    if failures:
        for msg in failures:
            print(f"REGRESSION: {msg}", file=sys.stderr)
        return 1
    print("regression gate: clean")
    return 0


if __name__ == "__main__":
    sys.exit(main())

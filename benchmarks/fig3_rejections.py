"""Paper Figure 3 (+ Appendix C.1 Figure 6): expected number of proposed-
but-rejected clusters/features vs data size N, for varying Pb.

Claim under test: E[M_N - k_N] is bounded by Pb and flat in N
(Thm 3.3: E[#sent] <= Pb + E[K_N]).
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import occ_bp_means, occ_dp_means, occ_ofl
from repro.data import (bp_stick_breaking_data, dp_stick_breaking_data,
                        separable_cluster_data)


def run(repeats: int = 20, ns=(256, 1024, 2560), pbs=(16, 64, 256),
        lam: float = 4.0, quiet: bool = False):
    rows = []
    for algo in ("dpmeans", "ofl", "bpmeans", "dpmeans_separable"):
        for pb in pbs:
            for n in ns:
                rejs, t0 = [], time.time()
                for r in range(repeats):
                    if algo == "dpmeans":
                        x, _, _ = dp_stick_breaking_data(n, seed=1000 + r)
                        res = occ_dp_means(jnp.asarray(x), lam, pb=pb,
                                           k_max=max(256, n), max_iters=1)
                    elif algo == "dpmeans_separable":
                        x, _, _ = separable_cluster_data(n, seed=1000 + r)
                        res = occ_dp_means(jnp.asarray(x), 1.0, pb=pb,
                                           k_max=max(256, n), max_iters=1)
                    elif algo == "ofl":
                        x, _, _ = dp_stick_breaking_data(n, seed=1000 + r)
                        res = occ_ofl(jnp.asarray(x), lam, pb=pb,
                                      key=jax.random.key(r), k_max=max(512, n))
                    else:
                        x, _, _ = bp_stick_breaking_data(n, seed=1000 + r)
                        res = occ_bp_means(jnp.asarray(x), lam, pb=pb,
                                           k_max=max(256, n), max_iters=1)
                    rejs.append(int(res.stats.proposed.sum())
                                - int(res.stats.accepted.sum()))
                mean_rej = float(np.mean(rejs))
                us = (time.time() - t0) / repeats * 1e6
                rows.append((f"fig3_{algo}_pb{pb}_n{n}", us,
                             f"rejections={mean_rej:.1f};bound_pb={pb};"
                             f"flat={'yes' if mean_rej <= pb else 'NO'}"))
                if not quiet:
                    print(f"{rows[-1][0]},{us:.0f},{rows[-1][2]}")
    return rows


if __name__ == "__main__":
    run()

"""Paper Figure 4: strong scaling of the distributed algorithms.

The paper measured wall time on 1/2/4/8 EC2 instances.  This container has
one physical core, so emulated host devices cannot show real speedup;
what this benchmark validates is (a) the distributed code path end-to-end
on a P-way mesh, and (b) the *workload model* the paper's scaling rests on:
per-worker points N/P and master (validator) load <= Pb + K_N per epoch.
We report both wall time and the modeled speedup T(P) ~ N/P + master_load,
which reproduces Fig 4's shape (near-perfect for DP/BP, first-epoch-bound
for OFL).
"""
from __future__ import annotations

import json
import os
import subprocess
import sys
import time

import numpy as np

_WORKER = """
import time, jax, jax.numpy as jnp, numpy as np
from repro.core import occ_dp_means, occ_ofl, occ_bp_means
from repro.data import dp_stick_breaking_data, bp_stick_breaking_data
P = {P}
algo = "{algo}"
n, pb = {n}, {pb}
from repro.launch.mesh import compat_mesh
mesh = compat_mesh((P,), ("data",))
if algo == "bpmeans":
    x, _, _ = bp_stick_breaking_data(n, seed=0)
else:
    x, _, _ = dp_stick_breaking_data(n, seed=0)
x = jnp.asarray(x)
def go():
    if algo == "dpmeans":
        return occ_dp_means(x, 4.0, pb=pb, k_max=512, max_iters=1, mesh=mesh)
    if algo == "ofl":
        return occ_ofl(x, 4.0, pb=pb, key=jax.random.key(0), k_max=1024, mesh=mesh)
    return occ_bp_means(x, 4.0, pb=pb, k_max=512, max_iters=1, mesh=mesh)
res = go()  # compile + run once
t0 = time.time(); res = go(); dt = time.time() - t0
sent = int(np.asarray(res.stats.proposed).sum())
acc = int(np.asarray(res.stats.accepted).sum())
print("RESULT", dt, sent, acc)
"""


def run(n: int = 16384, pb: int = 2048, ps=(1, 2, 4, 8), quiet: bool = False):
    rows = []
    repo = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    for algo in ("dpmeans", "ofl", "bpmeans"):
        base_model = None
        for p in ps:
            env = dict(os.environ)
            env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={p}"
            env["PYTHONPATH"] = os.path.join(repo, "src")
            code = _WORKER.format(P=p, algo=algo, n=n, pb=pb)
            t0 = time.time()
            out = subprocess.run([sys.executable, "-c", code], env=env,
                                 capture_output=True, text=True, timeout=1200)
            assert out.returncode == 0, out.stderr[-2000:]
            line = [l for l in out.stdout.splitlines() if l.startswith("RESULT")][0]
            _, dt, sent, acc = line.split()
            dt, sent, acc = float(dt), int(sent), int(acc)
            # workload model: worker n/P per epoch + serial validation `sent`
            model = n / p + sent
            if base_model is None:
                base_model = model
            rows.append((f"fig4_{algo}_P{p}", dt * 1e6,
                         f"modeled_speedup={base_model / model:.2f};"
                         f"master_load={sent};accepted={acc}"))
            if not quiet:
                print(f"{rows[-1][0]},{dt * 1e6:.0f},{rows[-1][2]}")
    return rows


if __name__ == "__main__":
    run()

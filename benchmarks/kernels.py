"""Kernel microbenchmarks: jit'd wrapper timings + interpret-mode parity.

On this CPU container the "ref" backend timings are the meaningful ones
(the Pallas path runs interpreted, i.e. Python-speed — validated for
correctness, not speed).  On TPU the same harness times the real kernels.
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels import ops


def _time(fn, *args, iters: int = 20) -> float:
    out = fn(*args)
    jax.block_until_ready(out)
    t0 = time.time()
    for _ in range(iters):
        out = fn(*args)
    jax.block_until_ready(out)
    return (time.time() - t0) / iters * 1e6


def _topk_rows(rng, quick: bool):
    """serve_topk at retrieval-serving scale (DESIGN.md §16): flat oracle
    (full (B, K) matrix) vs the streaming schedule (emulate — static-count
    prefix slice + tile skip) vs hierarchical multi-probe over the same
    buffers.  K sweeps 2^12..2^17; counts are ragged (~K/4 active) so the
    active-prefix machinery actually earns its rows."""
    from repro.kernels.topk_stream import topk_tile_loads
    from repro.serving.snapshot import build_hier

    rows = []
    b, d, k = 64, 64, 16
    x = jnp.asarray(rng.normal(size=(b, d)).astype(np.float32))
    for kc in ((4096,) if quick else (4096, 32768, 131072)):
        count = kc // 4 + 37
        c = jnp.asarray(rng.normal(size=(kc, d)).astype(np.float32))
        m = jnp.asarray(np.arange(kc) < count)

        # flat: traced count -> no prefix slicing, full-width matmul + sort
        cnt = jnp.asarray(count, jnp.int32)
        us = _time(lambda: ops.serve_topk(x, c, k, mask=m, count=cnt,
                                          backend="ref"))
        rows.append((f"kern_serve_topk_flat_K{kc}", us,
                     f"count={count};k={k};backend=ref"))

        # streaming schedule: host count -> pow2 prefix slice + tile skip
        us = _time(lambda: ops.serve_topk(x, c, k, mask=m, count=count,
                                          backend="emulate"))
        loads = topk_tile_loads(count, kc)
        rows.append((f"kern_serve_topk_stream_K{kc}", us,
                     f"count={count};k={k};backend=emulate;"
                     f"tile_loads={loads}of{-(-kc // 128)}"))

        # multi-probe: p=4 of the hier layout built from the same prefix
        h = build_hier(jnp.where(m[:, None], c, 0), m, count)
        p = min(4, h.n_cells)
        _, cq = ops.serve_topk(x, h.coarse, p, mask=h.coarse_mask,
                               backend="ref")
        cq_np = np.asarray(cq)
        probed = np.unique(cq_np[cq_np >= 0])
        u = len(probed)
        cells = np.full((min(h.n_cells, max(8, u)),), -1, np.int32)
        cells[:u] = probed
        member = np.zeros((b, len(cells)), bool)
        for ui, pc in enumerate(probed):
            member[:, ui] = (cq_np == pc).any(axis=1)
        cells_j, member_j = jnp.asarray(cells), jnp.asarray(member)
        ucnt = jnp.asarray(u, jnp.int32)
        us = _time(lambda: ops.serve_topk_multiprobe(
            x, h.fine, h.fine_ids, h.fine_mask, cells_j, member_j, k,
            u_count=ucnt, backend="emulate"))
        rows.append((f"kern_serve_topk_multiprobe_K{kc}", us,
                     f"count={count};k={k};p={p};probed={u}of{h.n_cells};"
                     f"shard_cap={h.shard_cap};backend=emulate"))
    return rows


def run(quiet: bool = False, quick: bool = False):
    rng = np.random.default_rng(0)
    rows = []
    backend = "pallas" if ops.on_tpu() else "ref"

    x = jnp.asarray(rng.normal(size=(4096, 64)).astype(np.float32))
    c = jnp.asarray(rng.normal(size=(256, 64)).astype(np.float32))
    m = jnp.ones((256,), bool)
    us = _time(lambda: ops.pairwise_argmin(x, c, m, backend=backend))
    flops = 2 * 4096 * 256 * 64
    rows.append(("kern_dpmeans_assign_4096x256x64", us,
                 f"backend={backend};gflops={flops / us / 1e3:.2f}"))

    q = jnp.asarray(rng.normal(size=(1, 8, 1024, 64)).astype(np.float32))
    k = jnp.asarray(rng.normal(size=(1, 2, 1024, 64)).astype(np.float32))
    v = jnp.asarray(rng.normal(size=(1, 2, 1024, 64)).astype(np.float32))
    us = _time(lambda: ops.flash_attention(q, k, v, backend=backend))
    rows.append(("kern_flash_attention_1x8x1024x64", us, f"backend={backend}"))

    xx = jnp.asarray(rng.normal(size=(8192, 2048)).astype(np.float32))
    w = jnp.ones((2048,), jnp.float32)
    us = _time(lambda: ops.rmsnorm(xx, w, backend=backend))
    gbs = 2 * xx.size * 4 / us / 1e3
    rows.append(("kern_rmsnorm_8192x2048", us, f"backend={backend};gbps={gbs:.1f}"))

    g = jnp.asarray(rng.normal(size=(8192, 2048)).astype(np.float32))
    u = jnp.asarray(rng.normal(size=(8192, 2048)).astype(np.float32))
    us = _time(lambda: ops.swiglu(g, u, backend=backend))
    rows.append(("kern_swiglu_8192x2048", us, f"backend={backend}"))

    # interpret-mode parity spot check (the Pallas body itself)
    d2p, _ = ops.pairwise_argmin(x[:64], c[:32], m[:32], backend="pallas")
    d2r, _ = ops.pairwise_argmin(x[:64], c[:32], m[:32], backend="ref")
    ok = bool(jnp.allclose(d2p, d2r, atol=1e-4))
    rows.append(("kern_pallas_interpret_parity", 0.0, f"allclose={ok}"))

    rows += _topk_rows(rng, quick)

    if not quiet:
        for r in rows:
            print(f"{r[0]},{r[1]:.0f},{r[2]}")
    return rows


if __name__ == "__main__":
    run()

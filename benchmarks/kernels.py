"""Kernel microbenchmarks: jit'd wrapper timings + interpret-mode parity.

On this CPU container the "ref" backend timings are the meaningful ones
(the Pallas path runs interpreted, i.e. Python-speed — validated for
correctness, not speed).  On TPU the same harness times the real kernels.
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels import ops


def _time(fn, *args, iters: int = 20) -> float:
    out = fn(*args)
    jax.block_until_ready(out)
    t0 = time.time()
    for _ in range(iters):
        out = fn(*args)
    jax.block_until_ready(out)
    return (time.time() - t0) / iters * 1e6


def run(quiet: bool = False):
    rng = np.random.default_rng(0)
    rows = []
    backend = "pallas" if ops.on_tpu() else "ref"

    x = jnp.asarray(rng.normal(size=(4096, 64)).astype(np.float32))
    c = jnp.asarray(rng.normal(size=(256, 64)).astype(np.float32))
    m = jnp.ones((256,), bool)
    us = _time(lambda: ops.pairwise_argmin(x, c, m, backend=backend))
    flops = 2 * 4096 * 256 * 64
    rows.append(("kern_dpmeans_assign_4096x256x64", us,
                 f"backend={backend};gflops={flops / us / 1e3:.2f}"))

    q = jnp.asarray(rng.normal(size=(1, 8, 1024, 64)).astype(np.float32))
    k = jnp.asarray(rng.normal(size=(1, 2, 1024, 64)).astype(np.float32))
    v = jnp.asarray(rng.normal(size=(1, 2, 1024, 64)).astype(np.float32))
    us = _time(lambda: ops.flash_attention(q, k, v, backend=backend))
    rows.append(("kern_flash_attention_1x8x1024x64", us, f"backend={backend}"))

    xx = jnp.asarray(rng.normal(size=(8192, 2048)).astype(np.float32))
    w = jnp.ones((2048,), jnp.float32)
    us = _time(lambda: ops.rmsnorm(xx, w, backend=backend))
    gbs = 2 * xx.size * 4 / us / 1e3
    rows.append(("kern_rmsnorm_8192x2048", us, f"backend={backend};gbps={gbs:.1f}"))

    g = jnp.asarray(rng.normal(size=(8192, 2048)).astype(np.float32))
    u = jnp.asarray(rng.normal(size=(8192, 2048)).astype(np.float32))
    us = _time(lambda: ops.swiglu(g, u, backend=backend))
    rows.append(("kern_swiglu_8192x2048", us, f"backend={backend}"))

    # interpret-mode parity spot check (the Pallas body itself)
    d2p, _ = ops.pairwise_argmin(x[:64], c[:32], m[:32], backend="pallas")
    d2r, _ = ops.pairwise_argmin(x[:64], c[:32], m[:32], backend="ref")
    ok = bool(jnp.allclose(d2p, d2r, atol=1e-4))
    rows.append(("kern_pallas_interpret_parity", 0.0, f"allclose={ok}"))

    if not quiet:
        for r in rows:
            print(f"{r[0]},{r[1]:.0f},{r[2]}")
    return rows


if __name__ == "__main__":
    run()

# One function per paper table. Print ``name,us_per_call,derived`` CSV.
"""Cluster-serving benchmark: the train→publish→serve pipeline under load.

Three measurements:
  * steady-state service latency per request bucket (warm jit caches,
    single published version) — the pure serving-plane cost;
  * admission-queue coalescing: a burst of small concurrent requests
    through a coalescing service vs the same burst solo — bucket-fill
    ratio and requests per dispatched group;
  * mixed-traffic QoS (§17): interactive latency while an analytics scan
    sits parked on a long deadline — priority lanes vs the FIFO baseline
    under the same offered load (the head-of-line-blocking A/B);
  * the end-to-end multi-model train-while-serve demo
    (launch/serve_clusters.run_demo): concurrent trainers + coalescing
    load generator with the full zero-stale-read / bit-parity /
    delta-publication audit; p50/p99 + QPS + fill ratios land in
    BENCH_cluster_service.json.

  PYTHONPATH=src python -m benchmarks.cluster_service
"""
from __future__ import annotations

import os
import threading
import time

import jax.numpy as jnp
import numpy as np

from repro.core import CenterPool, DPMeansTransaction, OCCEngine
from repro.data import dp_stick_breaking_data
from repro.launch.serve_clusters import ServeDemoConfig, run_demo
from repro.serving import ClusterService, SnapshotStore


def _warm_store(n_train: int, dim: int):
    x, _, _ = dp_stick_breaking_data(n_train, seed=0, dim=dim)
    x = jnp.asarray(x)
    store = SnapshotStore()
    eng = OCCEngine(DPMeansTransaction(4.0, k_max=512), pb=128,
                    publish=store.publish_pass)
    eng.partial_fit(x)
    eng.flush()
    return x, store


def _steady_state_rows(x, store, buckets, repeats: int):
    """Per-bucket microbatch latency against one warm snapshot."""
    svc = ClusterService(store, max_bucket=max(buckets))
    rows = []
    for b in buckets:
        q = x[:b]
        svc.score(q)                       # warm the (bucket, cap) cache
        t0 = time.perf_counter()
        for _ in range(repeats):
            svc.score(q)
        us = (time.perf_counter() - t0) / repeats * 1e6
        rows.append((f"cluster_service_assign_b{b}", us,
                     f"qps={b / us * 1e6:.0f};k={store.latest().count}"))
    return rows


def _coalescing_rows(x, store, n_clients: int, reqs_per_client: int,
                     max_request: int = 16, bucket: int = 64):
    """Burst of small concurrent requests: coalesced vs solo fill ratio."""
    svc = ClusterService(store, coalesce=True, coalesce_bucket=bucket,
                         coalesce_delay_ms=5.0, max_bucket=max(128, bucket))
    rng = np.random.default_rng(5)
    sizes = [[int(rng.integers(1, max_request + 1))
              for _ in range(reqs_per_client)] for _ in range(n_clients)]

    def client(ci):
        for s in sizes[ci]:
            svc.score(x[:s])

    threads = [threading.Thread(target=client, args=(ci,))
               for ci in range(n_clients)]
    t0 = time.perf_counter()
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    wall_us = (time.perf_counter() - t0) * 1e6
    m = svc.metrics()
    svc.close()
    solo = ClusterService(store, max_bucket=max(128, bucket))
    for per_client in sizes:
        for s in per_client:
            solo.score(x[:s])
    ms = solo.metrics()
    n_req = sum(len(s) for s in sizes)
    return [(
        "cluster_service_coalesced_fill", wall_us / n_req,
        f"fill={m['bucket_fill_ratio']:.3f};"
        f"solo_fill={ms['bucket_fill_ratio']:.3f};"
        f"reqs_per_group={m['requests_per_group']:.2f};"
        f"deadline_flushes={m['n_deadline_flushes']}")]


def _qos_rows(x, store, n_interactive: int = 80, deadline_ms: float = 3.0,
              scan_deadline_ms: float = 400.0):
    """Adversarial mix, lanes vs FIFO: one analytics top-k scan parked on
    a long deadline while an interactive stream runs.  With priority
    lanes the interactive group flushes on its OWN timer; the FIFO
    baseline holds every flush behind the parked head until its deadline
    expires — the p99 gap IS the head-of-line blocking."""
    from repro.serving import Query, ServeConfig
    rows = []
    for label, lanes in (("lanes", True), ("fifo", False)):
        svc = ClusterService(store, ServeConfig(
            coalesce=True, coalesce_bucket=64, coalesce_delay_ms=deadline_ms,
            max_bucket=128, priority_lanes=lanes))
        svc.score(x[:5])                   # warm the coalesced shapes
        svc.topk(x[:32], k=8)
        park = threading.Thread(target=lambda: svc.submit(
            Query(x[:32], kind="topk", k=8, priority="analytics",
                  deadline_ms=scan_deadline_ms, max_staleness=2)))
        park.start()
        while svc.queue_depth_rows() < 32:
            pass                           # scan admitted and parked
        lat = []
        for _ in range(n_interactive):
            t0 = time.perf_counter()
            svc.score(x[:5])
            lat.append(time.perf_counter() - t0)
        m = svc.metrics()
        svc.close()
        park.join(timeout=10)
        lat.sort()
        p50 = lat[len(lat) // 2]
        p99 = lat[min(len(lat) - 1, int(0.99 * len(lat)))]
        rows.append((
            f"cluster_service_qos_{label}", p50 * 1e6,
            f"p99_ms={p99 * 1e3:.2f};deadline_ms={deadline_ms};"
            f"scan_deadline_ms={scan_deadline_ms};"
            f"miss_rate={m['deadline_miss_rate']:.2f}"))
    return rows


def _topk_serving_rows(dim: int, topk_ks, repeats: int, probes: int = 4,
                       bucket: int = 64, k: int = 8):
    """Large-K top-k serving (§16): flat vs hierarchical multi-probe
    through the full ClusterService path — same synthetic center pool
    published into a hier store; the mp row carries its own recall@k
    measurement from a post-timing audited dispatch (the audit pays for a
    flat dispatch, so it is kept OUT of the timed window).  The query
    bucket is the small latency-sensitive regime — that is where probing
    prunes (a 4096-query batch probes every cell anyway), and on this CPU
    container the ref oracle pays O(u_cap * shard_cap) per dispatch, so
    mp repeats are capped (liveness + recall, not CPU speed — the DMA-skip
    claim is the TPU kernel's, measured by the loads accounting)."""
    rng = np.random.default_rng(7)
    rows = []
    mp_repeats = min(repeats, 3)
    for kc in topk_ks:
        count = kc - kc // 8 - 3              # ragged active prefix
        cn = np.zeros((kc, dim), np.float32)
        cn[:count] = rng.normal(size=(count, dim)).astype(np.float32)
        pool = CenterPool(jnp.asarray(cn),
                          jnp.asarray(np.arange(kc) < count),
                          jnp.asarray(count, jnp.int32),
                          jnp.asarray(False))
        store = SnapshotStore(hier=True)
        store.publish_pool(pool)
        q = jnp.asarray(rng.normal(size=(bucket, dim)).astype(np.float32))
        h = store.latest().hier
        for label, svc in (
                ("flat", ClusterService(store, max_bucket=bucket)),
                ("mp", ClusterService(store, max_bucket=bucket,
                                      probes=probes,
                                      recall_audit_every=mp_repeats + 2))):
            reps = mp_repeats if label == "mp" else repeats
            svc.topk(q, k=k)                  # warm the jit cache
            t0 = time.perf_counter()
            for _ in range(reps):
                svc.topk(q, k=k)
            us = (time.perf_counter() - t0) / reps * 1e6
            derived = (f"k={k};count={count};cells={h.n_cells};"
                       f"qps={bucket / us * 1e6:.0f}")
            if label == "mp":
                svc.topk(q, k=k)              # dispatch #reps+2: audited
                met = svc.metrics()
                derived += (f";p={probes};recall={met['topk_recall']:.3f};"
                            f"shards={met['topk_shards_probed']}"
                            f"/{met['topk_shards_probed'] + met['topk_tiles_skipped']}")
            rows.append((f"cluster_service_topk_{label}_K{kc}", us, derived))
    return rows


def run(n_train: int = 8192, dim: int = 16, buckets=(8, 64, 512, 4096),
        repeats: int = 20, demo_queries: int = 2000,
        coalesce_clients: int = 8, coalesce_reqs: int = 25,
        topk_ks=(4096, 32768, 131072),
        out_path: str | None = None, quiet: bool = False):
    x, store = _warm_store(n_train, dim)
    rows = _steady_state_rows(x, store, buckets, repeats)
    rows += _coalescing_rows(x, store, coalesce_clients, coalesce_reqs)
    rows += _qos_rows(x, store)
    rows += _topk_serving_rows(dim, topk_ks, repeats)

    # demo_queries=0 skips the train-while-serve demo — CI's --quick smoke
    # does, because the workflow runs `repro.launch.serve_clusters --quick`
    # as its own job; paying for the trainers+audit twice buys nothing.
    if demo_queries > 0:
        cfg = ServeDemoConfig(n=max(1024, n_train // 4), dim=dim, pb=128,
                              train_batch=300, min_queries=demo_queries,
                              quiet=True, out_path=out_path)
        rec = run_demo(cfg)
        rows.append((
            "cluster_service_train_serve_p50",
            rec["p50_latency_ms"] * 1e3,
            f"qps={rec['qps']:.0f};models={rec['n_models']};"
            f"p99_ms={rec['p99_latency_ms']:.2f};"
            f"fill={rec['bucket_fill_coalesced']:.3f}vs"
            f"{rec['bucket_fill_solo']:.3f};"
            f"stale_free={rec['zero_stale_reads']};"
            f"parity={rec['serve_train_parity']}"))
        qab = rec.get("qos_ab")
        if qab:
            rows.append((
                "cluster_service_qos_ab_interactive_p99",
                qab["qos"]["interactive_p99_ms"] * 1e3,
                f"fifo_p99_ms={qab['fifo']['interactive_p99_ms']:.2f};"
                f"speedup={qab['interactive_p99_speedup']:.2f}x;"
                f"shed={qab['qos']['n_shed']};"
                f"degraded_replayed={qab['qos']['n_degraded_replayed']}"))
    if not quiet:
        for r in rows:
            print(f"{r[0]},{r[1]:.0f},{r[2]}")
    return rows


if __name__ == "__main__":
    run(out_path=os.path.join(os.path.dirname(os.path.dirname(
        os.path.abspath(__file__))), "BENCH_cluster_service.json"))

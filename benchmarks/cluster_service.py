# One function per paper table. Print ``name,us_per_call,derived`` CSV.
"""Cluster-serving benchmark: the train→publish→serve pipeline under load.

Two measurements:
  * steady-state service latency per request bucket (warm jit caches,
    single published version) — the pure serving-plane cost;
  * the end-to-end train-while-serve demo (launch/serve_clusters.run_demo):
    concurrent trainer + load generator with the full zero-stale-read /
    bit-parity audit; p50/p99 + QPS land in BENCH_cluster_service.json.

  PYTHONPATH=src python -m benchmarks.cluster_service
"""
from __future__ import annotations

import os
import time

import jax.numpy as jnp
import numpy as np

from repro.core import DPMeansTransaction, OCCEngine
from repro.data import dp_stick_breaking_data
from repro.launch.serve_clusters import ServeDemoConfig, run_demo
from repro.serving import ClusterService, SnapshotStore


def _steady_state_rows(n_train: int, dim: int, buckets, repeats: int):
    """Per-bucket microbatch latency against one warm snapshot."""
    x, _, _ = dp_stick_breaking_data(n_train, seed=0, dim=dim)
    x = jnp.asarray(x)
    store = SnapshotStore()
    eng = OCCEngine(DPMeansTransaction(4.0, k_max=512), pb=128,
                    publish=store.publish_pass)
    eng.partial_fit(x)
    eng.flush()
    svc = ClusterService(store, max_bucket=max(buckets))
    rows = []
    for b in buckets:
        q = x[:b]
        svc.score(q)                       # warm the (bucket, cap) cache
        t0 = time.perf_counter()
        for _ in range(repeats):
            svc.score(q)
        us = (time.perf_counter() - t0) / repeats * 1e6
        rows.append((f"cluster_service_assign_b{b}", us,
                     f"qps={b / us * 1e6:.0f};k={store.latest().count}"))
    return rows


def run(n_train: int = 8192, dim: int = 16, buckets=(8, 64, 512, 4096),
        repeats: int = 20, demo_queries: int = 2000,
        out_path: str | None = None, quiet: bool = False):
    rows = _steady_state_rows(n_train, dim, buckets, repeats)

    # demo_queries=0 skips the train-while-serve demo — CI's --quick smoke
    # does, because the workflow runs `repro.launch.serve_clusters --quick`
    # as its own step; paying for the trainer+audit twice buys nothing.
    if demo_queries > 0:
        cfg = ServeDemoConfig(n=max(1024, n_train // 4), dim=dim, pb=128,
                              train_batch=300, min_queries=demo_queries,
                              quiet=True, out_path=out_path)
        rec = run_demo(cfg)
        rows.append((
            "cluster_service_train_serve_p50",
            rec["p50_latency_ms"] * 1e3,
            f"qps={rec['qps']:.0f};versions={rec['n_versions_observed']};"
            f"p99_ms={rec['p99_latency_ms']:.2f};"
            f"stale_free={rec['zero_stale_reads']};"
            f"parity={rec['serve_train_parity']}"))
    if not quiet:
        for r in rows:
            print(f"{r[0]},{r[1]:.0f},{r[2]}")
    return rows


if __name__ == "__main__":
    run(out_path=os.path.join(os.path.dirname(os.path.dirname(
        os.path.abspath(__file__))), "BENCH_cluster_service.json"))

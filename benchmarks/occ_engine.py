"""Engine vs legacy driver: single-jit epoch scan vs Python epoch loop.

The legacy drivers (pre-engine `occ_dp_means` et al.) dispatched one
compiled epoch per Python-loop step and forced a device->host sync per
epoch via `int(n_sent)`.  The unified engine runs the whole pass as one
`lax.scan` inside one jit with stats accumulated on device.  This benchmark
times both on identical math (the legacy loop reuses the engine's epoch
body, so the difference is pure dispatch/sync overhead), and records the
perf trajectory in BENCH_occ_engine.json.

  PYTHONPATH=src python -m benchmarks.occ_engine
"""
from __future__ import annotations

import json
import os
import time
from functools import partial

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import DPMeansTransaction, OCCEngine
from repro.core.engine import _epoch_body
from repro.core.occ import block_epochs
from repro.data import dp_stick_breaking_data
from repro.obs import Obs, Tracer

#: hard budget for telemetry on the fused pass (obs=None must stay free;
#: obs-on pays one post-pass stats export — asserted below, recorded in
#: BENCH_occ_engine.json and quoted in DESIGN.md §15)
OBS_OVERHEAD_LIMIT_PCT = 2.0


@partial(jax.jit, static_argnames=("cap",))
def _legacy_epoch(txn, pool, xe, ve, cap):
    pool, (ze, se, n_sent, n_acc, _cap) = _epoch_body(
        txn, pool, xe, ve, (), cap, "serial")
    return pool, (ze, se, n_sent, n_acc)


def _legacy_pass(txn, x, pb):
    """The seed driver pattern: T separate compiled-epoch dispatches plus a
    per-epoch host round-trip for the stats."""
    n, d = x.shape
    pool = txn.init_pool(x)
    t_epochs = block_epochs(n, pb)
    pad = t_epochs * pb - n
    xs = jnp.concatenate([x, jnp.zeros((pad, d), x.dtype)], 0)
    valid = jnp.concatenate([jnp.ones((n,), bool), jnp.zeros((pad,), bool)])
    z = jnp.full((n,), -1, jnp.int32)
    stats_p, stats_a = [], []
    for t in range(t_epochs):
        sl = slice(t * pb, (t + 1) * pb)
        pool, (ze, _se, n_sent, n_acc) = _legacy_epoch(
            txn, pool, xs[sl], valid[sl], None)
        lo, hi = t * pb, min((t + 1) * pb, n)
        z = z.at[lo:hi].set(ze[:hi - lo])
        stats_p.append(int(n_sent))    # <- the per-epoch device->host sync
        stats_a.append(int(n_acc))
    return pool, z, np.asarray(stats_p, np.int32), t_epochs


def run(n: int = 8192, pb: int = 256, repeats: int = 5, lam: float = 4.0,
        out_path: str | None = None, quiet: bool = False):
    x, _, _ = dp_stick_breaking_data(n, seed=0)
    x = jnp.asarray(x)
    txn = DPMeansTransaction(lam, k_max=512)
    eng = OCCEngine(txn, pb)
    t_epochs = block_epochs(n, pb)

    # warm both compilations and check the math is identical
    pool_l, z_l, stats_l, _ = _legacy_pass(txn, x, pb)
    res = jax.block_until_ready(eng.run(x))
    assert np.array_equal(np.asarray(res.assign), np.asarray(z_l))
    assert np.array_equal(np.asarray(res.stats.proposed), stats_l)

    t0 = time.time()
    for _ in range(repeats):
        _legacy_pass(txn, x, pb)
    legacy_s = (time.time() - t0) / repeats

    t0 = time.time()
    for _ in range(repeats):
        jax.block_until_ready(eng.run(x))
    engine_s = (time.time() - t0) / repeats

    # --- telemetry overhead: the SAME fused pass with full obs (registry
    # + tracer) vs obs=None.  The real effect is sub-1% (one post-pass
    # stats export on a ONE-dispatch pass), far below scheduler noise on a
    # shared runner, so the A/B alternates run order per iteration, takes
    # min-of-many per side, and re-measures before declaring a breach.
    eng_obs = OCCEngine(txn, pb, obs=Obs(tracer=Tracer("bench")))
    jax.block_until_ready(eng_obs.run(x))            # warm
    for attempt in range(3):
        best_plain = best_obs = float("inf")
        for i in range(max(repeats, 15)):
            pair = [eng, eng_obs] if i % 2 == 0 else [eng_obs, eng]
            for e in pair:
                t0 = time.perf_counter()
                jax.block_until_ready(e.run(x))
                dt = time.perf_counter() - t0
                if e is eng:
                    best_plain = min(best_plain, dt)
                else:
                    best_obs = min(best_obs, dt)
        obs_overhead_pct = 100.0 * (best_obs - best_plain) / best_plain
        if obs_overhead_pct < OBS_OVERHEAD_LIMIT_PCT:
            break
    assert obs_overhead_pct < OBS_OVERHEAD_LIMIT_PCT, (
        f"tracing overhead {obs_overhead_pct:.2f}% exceeds the "
        f"{OBS_OVERHEAD_LIMIT_PCT}% budget on the fused pass")

    record = {
        "bench": "occ_engine",
        "n": n, "pb": pb, "t_epochs": t_epochs, "repeats": repeats,
        "legacy_wall_s": legacy_s,
        "engine_wall_s": engine_s,
        "speedup": legacy_s / engine_s,
        "legacy_dispatches_per_pass": t_epochs,
        "legacy_host_syncs_per_pass": 2 * t_epochs,
        "engine_dispatches_per_pass": 1,
        "engine_host_syncs_per_pass": 0,
        "engine_obs_wall_s": best_obs,
        "engine_plain_wall_s": best_plain,
        "obs_overhead_pct": obs_overhead_pct,
        "obs_overhead_limit_pct": OBS_OVERHEAD_LIMIT_PCT,
    }
    # Only persist when a path is given (the __main__ canonical run does);
    # suite/CI fast-mode invocations must not clobber the tracked record.
    if out_path is not None:
        with open(out_path, "w") as f:
            json.dump(record, f, indent=2)

    rows = [
        (f"occ_engine_legacy_n{n}_pb{pb}", legacy_s * 1e6,
         f"dispatches={t_epochs};host_syncs={2 * t_epochs}"),
        (f"occ_engine_scan_n{n}_pb{pb}", engine_s * 1e6,
         f"dispatches=1;host_syncs=0;speedup={legacy_s / engine_s:.2f}x"),
        (f"occ_engine_obs_n{n}_pb{pb}", best_obs * 1e6,
         f"obs_overhead_pct={obs_overhead_pct:.2f};"
         f"limit={OBS_OVERHEAD_LIMIT_PCT}"),
    ]
    if not quiet:
        for r in rows:
            print(f"{r[0]},{r[1]:.0f},{r[2]}")
    return rows


if __name__ == "__main__":
    run(out_path=os.path.join(os.path.dirname(os.path.dirname(
        os.path.abspath(__file__))), "BENCH_occ_engine.json"))

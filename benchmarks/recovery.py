"""Crash-recovery microbench: checkpoint-restore + WAL-replay latency (§14).

Builds a `DeltaWAL` the way a trainer would — an append-only version chain
published through the store's wire seam, with full checkpoints every
`checkpoint_every` versions — then "crashes" (drops the in-memory store)
and times `recover_wal`: restore the newest checkpoint image + replay the
logged deltas past it.  That wall time IS the §14 MTTR contribution of
state reconstruction, and it is the quantity the checkpoint cadence
bounds: replay work never exceeds one interval, so

  * ``recovery_replay_us`` — median full `recover_wal` wall time (the
    regression-gate key metric: a codec, checkpoint-manager, or
    apply_delta slowdown shows up here);
  * ``append_us`` — median per-publish WAL append cost (the durability
    tax the trainer pays per epoch; fsync off, as in the e2e drivers);
  * ``replayed`` / ``ckpt_version`` — what recovery actually did, so the
    numbers can't silently measure an empty replay.

Every trial asserts the recovered store digest equals the pre-crash one —
a recovery bench that recovers wrong state must fail, not report a time.

  PYTHONPATH=src python -m benchmarks.recovery
"""
from __future__ import annotations

import json
import os
import shutil
import tempfile
import time

import jax.numpy as jnp
import numpy as np

from repro.checkpoint.wal import DeltaWAL, recover_wal
from repro.core.occ import CenterPool
from repro.distributed.transport import store_digest
from repro.obs import Obs
from repro.serving.snapshot import SnapshotStore


def _pools(versions: int, dk: int, dim: int):
    """Append-only chain: version v holds the first v*dk rows (same shape
    as benchmarks/transport.py, so the delta payloads are comparable)."""
    k_max = versions * dk
    base = np.random.default_rng(0).normal(
        size=(k_max, dim)).astype(np.float32)
    out = []
    for v in range(1, versions + 1):
        k = v * dk
        centers = jnp.zeros((k_max, dim), jnp.float32).at[:k].set(base[:k])
        out.append(CenterPool(centers, jnp.arange(k_max) < k,
                              jnp.asarray(k, jnp.int32), jnp.asarray(False)))
    return out


def measure_recovery(versions: int, dk: int, dim: int,
                     checkpoint_every: int, inject_sleep_s: float = 0.0,
                     obs: Obs | None = None, trial: int = 0) -> dict:
    """One trial: write the WAL, crash, time `recover_wal` end to end.

    Timing is registry-sourced: per-publish append cost observes into
    ``bench_wal_append_s{trial=..}`` and the recovery wall time into
    ``bench_recovery_s{trial=..}`` (sleep injection INSIDE the timed
    block); the WAL's own fsync/append histograms land in the same
    registry when the caller passes `obs`."""
    obs = obs if obs is not None else Obs()
    pools = _pools(versions, dk, dim)
    tmp = tempfile.mkdtemp(prefix="occ-recovery-bench-")
    try:
        wal = DeltaWAL(tmp, model="bench", checkpoint_every=checkpoint_every,
                       fsync=False, obs=obs)
        store = SnapshotStore(capacity=versions + 1, delta=True,
                              model="bench", wire=wal)
        for pool in pools:
            with obs.metrics.timer("bench_wal_append_s", trial=trial):
                store.publish_pool(pool)
        wal.close()
        digest = store_digest(store)

        with obs.metrics.timer("bench_recovery_s", trial=trial):
            rec, info = recover_wal(tmp, model="bench",
                                    capacity=versions + 1, obs=obs)
            if inject_sleep_s:
                time.sleep(inject_sleep_s)
        h_rec = obs.metrics.get_histogram("bench_recovery_s", trial=trial)
        h_app = obs.metrics.get_histogram("bench_wal_append_s", trial=trial)
        assert store_digest(rec) == digest, "recovery is not bit-identical"
        return dict(
            recovery_replay_us=float(h_rec.max * 1e6),
            append_us=float(h_app.percentile(50)) * 1e6,
            ckpt_version=info["ckpt_version"],
            replayed=info["n_replayed"],
            wal_bytes=wal.bytes_appended,
            n_checkpoints=wal.n_checkpoints,
        )
    finally:
        shutil.rmtree(tmp, ignore_errors=True)


def run(versions: int = 30, dk: int = 4, dim: int = 16,
        checkpoint_every: int = 8, trials: int = 3,
        out_path: str | None = None):
    """CSV rows for benchmarks/run.py; MIN over trials per metric.
    `versions` deliberately not a multiple of `checkpoint_every`: the
    timed path must include delta replay, not just the image restore."""
    results = [measure_recovery(versions, dk, dim, checkpoint_every)
               for _ in range(trials)]
    best = {k: min(r[k] for r in results)
            for k in ("recovery_replay_us", "append_us")}
    last = results[-1]
    record = dict(bench="recovery", versions=versions, dk=dk, dim=dim,
                  checkpoint_every=checkpoint_every, trials=trials,
                  **best, ckpt_version=last["ckpt_version"],
                  replayed=last["replayed"], wal_bytes=last["wal_bytes"],
                  n_checkpoints=last["n_checkpoints"])
    if out_path is not None:
        with open(out_path, "w") as f:
            json.dump(record, f, indent=2)
    rows = [
        ("recovery_replay", best["recovery_replay_us"],
         f"ckpt@{last['ckpt_version']}+{last['replayed']}deltas"),
        ("recovery_wal_append", best["append_us"],
         f"{last['wal_bytes'] / versions:.0f}B/publish"),
    ]
    for name, us, derived in rows:
        print(f"{name},{us:.1f},{derived}")
    return rows


if __name__ == "__main__":
    run(out_path=os.environ.get("BENCH_OUT"))

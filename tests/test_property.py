"""Hypothesis property tests for the system's invariants."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

hypothesis = pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

from repro.core import (occ_dp_means, occ_ofl, serial_dp_means_pass,
                        point_uniforms)
from repro.core.dp_means import thm31_permutation
from repro.core.objective import sq_dists

SET = dict(max_examples=15, deadline=None)


@st.composite
def dp_problem(draw):
    n = draw(st.integers(32, 160))
    d = draw(st.integers(2, 8))
    pb = draw(st.sampled_from([8, 16, 64]))
    lam = draw(st.floats(0.5, 6.0))
    seed = draw(st.integers(0, 2 ** 16))
    rng = np.random.default_rng(seed)
    x = rng.normal(size=(n, d)).astype(np.float32) * 2.0
    return jnp.asarray(x), pb, lam


@given(dp_problem())
@settings(**SET)
def test_dpmeans_serializability_property(prob):
    """For ANY data / Pb / lambda, the OCC run equals the serial run on the
    Thm-3.1 permutation — the paper's core claim as a property."""
    x, pb, lam = prob
    res = occ_dp_means(x, lam, pb=pb, k_max=x.shape[0], max_iters=1)
    perm = thm31_permutation(res, x.shape[0])
    pool_s, z_s = serial_dp_means_pass(x[perm], lam, x.shape[0])
    assert int(pool_s.count) == int(res.pool.count)
    assert np.array_equal(np.asarray(z_s), np.asarray(res.z)[perm])


@given(dp_problem())
@settings(**SET)
def test_accepted_centers_pairwise_separated(prob):
    """DPValidate invariant: accepted centers (pre mean-recompute) are
    pairwise further than lambda apart — otherwise one would have covered
    the other at validation."""
    x, pb, lam = prob
    res = occ_dp_means(x, lam, pb=pb, k_max=x.shape[0], max_iters=1)
    # centers at creation are the points whose z points at a slot they created:
    z = np.asarray(res.z)
    k = int(res.pool.count)
    creators = {}
    for i in np.nonzero(np.asarray(res.send))[0]:
        s = z[i]
        if s >= 0 and s not in creators:
            creators[s] = i
    pts = np.asarray(x)[[creators[s] for s in sorted(creators) if s < k]]
    if len(pts) >= 2:
        d2 = np.array(sq_dists(jnp.asarray(pts), jnp.asarray(pts)))
        np.fill_diagonal(d2, np.inf)
        assert d2.min() > lam * lam - 1e-4


@given(dp_problem())
@settings(**SET)
def test_every_point_assigned_validly(prob):
    x, pb, lam = prob
    res = occ_dp_means(x, lam, pb=pb, k_max=x.shape[0], max_iters=1)
    z = np.asarray(res.z)
    k = int(res.pool.count)
    assert ((z >= 0) & (z < k)).all()
    assert not bool(res.pool.overflow)


@given(st.integers(0, 2 ** 16), st.sampled_from([8, 32]))
@settings(**SET)
def test_ofl_uniforms_deterministic(seed, n):
    u1 = point_uniforms(jax.random.key(seed), n)
    u2 = point_uniforms(jax.random.key(seed), n)
    assert np.array_equal(np.asarray(u1), np.asarray(u2))
    assert ((np.asarray(u1) >= 0) & (np.asarray(u1) < 1)).all()


@given(dp_problem(), st.integers(0, 100))
@settings(max_examples=10, deadline=None)
def test_ofl_center_count_vs_lambda(prob, seed):
    """Monotonicity sanity: smaller lambda -> no fewer facilities."""
    x, pb, lam = prob
    k_small = int(occ_ofl(x, lam * 0.5, pb=pb, key=jax.random.key(seed),
                          k_max=x.shape[0]).pool.count)
    k_large = int(occ_ofl(x, lam * 2.0, pb=pb, key=jax.random.key(seed),
                          k_max=x.shape[0]).pool.count)
    assert k_small >= k_large - 2   # coupled-u monotonicity, small slack

"""Pallas kernels vs pure-jnp oracles: shape/dtype sweeps, interpret=True."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops, ref


@pytest.mark.parametrize("n,k,d", [(17, 5, 3), (64, 32, 16), (100, 37, 16),
                                   (256, 128, 64), (33, 130, 8)])
@pytest.mark.parametrize("dtype", [np.float32, np.float16])
def test_dpmeans_assign_sweep(rng, n, k, d, dtype):
    x = jnp.asarray(rng.normal(size=(n, d)).astype(dtype))
    c = jnp.asarray(rng.normal(size=(k, d)).astype(dtype))
    m = jnp.asarray(rng.uniform(size=k) > 0.25)
    d2p, ip = ops.pairwise_argmin(x, c, m, backend="pallas",
                                  block_n=32, block_k=16)
    d2r, ir = ref.pairwise_argmin_ref(x, c, m)
    np.testing.assert_allclose(np.asarray(d2p), np.asarray(d2r),
                               atol=5e-3 if dtype == np.float16 else 1e-4)
    np.testing.assert_array_equal(np.asarray(ip), np.asarray(ir))


def test_dpmeans_assign_empty_mask(rng):
    x = jnp.asarray(rng.normal(size=(8, 4)).astype(np.float32))
    c = jnp.asarray(rng.normal(size=(4, 4)).astype(np.float32))
    m = jnp.zeros((4,), bool)
    d2, idx = ops.pairwise_argmin(x, c, m, backend="pallas", block_n=8, block_k=4)
    assert np.all(np.isinf(np.asarray(d2)))
    assert np.all(np.asarray(idx) == -1)    # kernel contract: -1 when empty


@pytest.mark.parametrize("n,k,d", [
    (5, 3, 2),        # n and k both below the minimum tile
    (9, 5, 4),        # K < 8: bk clamps up, k-padding fills the tile
    (7, 130, 8),      # ragged K across many tiles, ragged n
    (130, 7, 16),     # ragged N across tiles, K < 8
    (31, 33, 5),      # both non-multiples of the block sizes
])
def test_dpmeans_assign_interpret_ragged_parity(rng, n, k, d):
    """Interpret-mode Pallas vs sq_dists reference on ragged N/K shapes
    (non-multiples of block sizes, K < 8) — exactly the awkward pool sizes
    the OCC engine produces."""
    x = jnp.asarray(rng.normal(size=(n, d)).astype(np.float32))
    c = jnp.asarray(rng.normal(size=(k, d)).astype(np.float32))
    m = jnp.asarray(rng.uniform(size=k) > 0.3)
    d2p, ip = ops.assign(x, c, m, backend="pallas", block_n=16, block_k=8)
    d2r, ir = ops.assign(x, c, m, backend="ref")
    np.testing.assert_allclose(np.asarray(d2p), np.asarray(d2r), atol=1e-4)
    np.testing.assert_array_equal(np.asarray(ip), np.asarray(ir))


@pytest.mark.parametrize("count", [0, 3, 7, 8, 37])
def test_dpmeans_assign_count_prefix_parity(rng, count):
    """The count-rounded active prefix: tiles beyond `count` are skipped on
    the Pallas path; results must equal the reference with the prefix mask.
    Covers count == 0 (empty pool) and count == K (all tiles active)."""
    n, k, d = 20, 37, 6
    x = jnp.asarray(rng.normal(size=(n, d)).astype(np.float32))
    c = jnp.asarray(rng.normal(size=(k, d)).astype(np.float32))
    # pool invariant: valid slots are a prefix of the buffer
    m = jnp.asarray(np.arange(k) < count)
    cnt = jnp.asarray(count, jnp.int32)
    d2p, ip = ops.assign(x, c, m, count=cnt, backend="pallas",
                         block_n=16, block_k=8)
    d2r, ir = ops.assign(x, c, m, count=cnt, backend="ref")
    np.testing.assert_allclose(np.asarray(d2p), np.asarray(d2r), atol=1e-4)
    np.testing.assert_array_equal(np.asarray(ip), np.asarray(ir))
    if count == 0:
        assert np.all(np.asarray(ip) == -1)


def test_assign_ref_matches_legacy_nearest_center_semantics(rng):
    """ops.assign(ref) == masked sq_dists min/argmin with -1 on empty — the
    exact contract core.occ.nearest_center is built on."""
    from repro.core.objective import sq_dists
    x = jnp.asarray(rng.normal(size=(12, 5)).astype(np.float32))
    c = jnp.asarray(rng.normal(size=(9, 5)).astype(np.float32))
    m = jnp.asarray(np.arange(9) < 4)
    d2, idx = ops.assign(x, c, m, count=jnp.asarray(4, jnp.int32),
                         backend="ref")
    d2_ref = jnp.where(m[None, :], sq_dists(x, c), jnp.inf)
    np.testing.assert_array_equal(np.asarray(d2),
                                  np.asarray(jnp.min(d2_ref, -1)))
    np.testing.assert_array_equal(np.asarray(idx),
                                  np.asarray(jnp.argmin(d2_ref, -1)))


@pytest.mark.parametrize("b,h,hkv,s,dh", [(1, 4, 4, 128, 32), (2, 8, 2, 128, 32),
                                          (2, 4, 1, 256, 64)])
def test_flash_attention_sweep(rng, b, h, hkv, s, dh):
    q = jnp.asarray(rng.normal(size=(b, h, s, dh)).astype(np.float32))
    k = jnp.asarray(rng.normal(size=(b, hkv, s, dh)).astype(np.float32))
    v = jnp.asarray(rng.normal(size=(b, hkv, s, dh)).astype(np.float32))
    op = ops.flash_attention(q, k, v, backend="pallas", block_q=64, block_k=64)
    orf = ref.flash_attention_ref(q, k, v)
    np.testing.assert_allclose(np.asarray(op), np.asarray(orf), atol=2e-3)


def test_flash_attention_noncausal(rng):
    q = jnp.asarray(rng.normal(size=(1, 2, 128, 16)).astype(np.float32))
    k = jnp.asarray(rng.normal(size=(1, 2, 128, 16)).astype(np.float32))
    v = jnp.asarray(rng.normal(size=(1, 2, 128, 16)).astype(np.float32))
    op = ops.flash_attention(q, k, v, causal=False, backend="pallas",
                             block_q=64, block_k=64)
    orf = ref.flash_attention_ref(q, k, v, causal=False)
    np.testing.assert_allclose(np.asarray(op), np.asarray(orf), atol=2e-3)


@pytest.mark.parametrize("shape", [(7, 33), (64, 256), (3, 5, 128)])
@pytest.mark.parametrize("dtype", [np.float32, np.float16])
def test_rmsnorm_sweep(rng, shape, dtype):
    x = jnp.asarray(rng.normal(size=shape).astype(dtype))
    w = jnp.asarray(rng.normal(size=shape[-1]).astype(dtype))
    got = ops.rmsnorm(x, w, backend="pallas", block_rows=16)
    want = ref.rmsnorm_ref(x, w)
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32),
                               atol=1e-2 if dtype == np.float16 else 1e-5)


@pytest.mark.parametrize("shape", [(5, 17), (128, 512), (2, 3, 64)])
def test_swiglu_sweep(rng, shape):
    g = jnp.asarray(rng.normal(size=shape).astype(np.float32))
    u = jnp.asarray(rng.normal(size=shape).astype(np.float32))
    got = ops.swiglu(g, u, backend="pallas", block_rows=8)
    np.testing.assert_allclose(np.asarray(got), np.asarray(ref.swiglu_ref(g, u)),
                               atol=1e-6)


def test_backend_resolution():
    assert not ops.on_tpu()
    with pytest.raises(ValueError):
        ops._resolve("nope")


# ------------------------------------------------- emulation harness (CI)

@pytest.mark.parametrize("n,k,d,count", [
    (17, 5, 3, None), (33, 130, 8, 37), (20, 37, 6, 0), (20, 37, 6, 8),
    (7, 130, 8, 100),
])
def test_emulate_bitwise_matches_interpret(rng, n, k, d, count):
    """`dpmeans_assign_emulate` mirrors the kernel schedule op for op, so
    on shapes interpret mode CAN sweep the two are BIT-identical (same
    tiles, same f32 dot_general, same running-argmin merges) — which is
    what licenses the emulation as the large-shape parity oracle."""
    x = jnp.asarray(rng.normal(size=(n, d)).astype(np.float32))
    c = jnp.asarray(rng.normal(size=(k, d)).astype(np.float32))
    m = (jnp.asarray(np.arange(k) < count) if count is not None
         else jnp.asarray(rng.uniform(size=k) > 0.25))
    cnt = None if count is None else jnp.asarray(count, jnp.int32)
    d2p, ip = ops.assign(x, c, m, count=cnt, backend="pallas",
                         block_n=16, block_k=8)
    d2e, ie = ops.assign(x, c, m, count=cnt, backend="emulate",
                         block_n=16, block_k=8)
    np.testing.assert_array_equal(np.asarray(d2p), np.asarray(d2e))
    np.testing.assert_array_equal(np.asarray(ip), np.asarray(ie))


def test_emulate_production_shape_parity(rng):
    """The point of the harness: a serving-bucket-sized shape (interpret
    mode would loop 8x16 grid steps in Python per call — minutes) checked
    against the jnp oracle in one compiled call."""
    x = jnp.asarray(rng.normal(size=(2048, 48)).astype(np.float32))
    c = jnp.asarray(rng.normal(size=(1024, 48)).astype(np.float32))
    count = 517
    m = jnp.asarray(np.arange(1024) < count)
    cnt = jnp.asarray(count, jnp.int32)
    d2e, ie = ops.assign(x, c, m, count=cnt, backend="emulate")
    d2r, ir = ops.assign(x, c, m, count=cnt, backend="ref")
    np.testing.assert_allclose(np.asarray(d2e), np.asarray(d2r), atol=1e-3)
    np.testing.assert_array_equal(np.asarray(ie), np.asarray(ir))


def test_emulate_pairwise_argmin_entry(rng):
    x = jnp.asarray(rng.normal(size=(40, 12)).astype(np.float32))
    c = jnp.asarray(rng.normal(size=(24, 12)).astype(np.float32))
    d2e, ie = ops.pairwise_argmin(x, c, backend="emulate",
                                  block_n=16, block_k=8)
    d2p, ip = ops.pairwise_argmin(x, c, backend="pallas",
                                  block_n=16, block_k=8)
    np.testing.assert_array_equal(np.asarray(d2e), np.asarray(d2p))
    np.testing.assert_array_equal(np.asarray(ie), np.asarray(ip))


# --------------------------------------------------- serving-plane entries

def test_serve_assign_query_prefix_masking(rng):
    """Bucket padding rows come back (inf, -1) on every backend; real rows
    equal plain `assign`."""
    x = jnp.asarray(rng.normal(size=(32, 6)).astype(np.float32))
    c = jnp.asarray(rng.normal(size=(16, 6)).astype(np.float32))
    m = jnp.asarray(np.arange(16) < 9)
    cnt = jnp.asarray(9, jnp.int32)
    nv = jnp.asarray(20, jnp.int32)
    for backend in ("ref", "emulate", "pallas"):
        kw = {} if backend == "ref" else {"block_n": 16, "block_k": 8}
        d2, idx = ops.serve_assign(x, c, m, count=cnt, n_valid=nv,
                                   backend=backend, **kw)
        d2a, ia = ops.assign(x, c, m, count=cnt, backend=backend, **kw)
        np.testing.assert_array_equal(np.asarray(idx[:20]),
                                      np.asarray(ia[:20]))
        np.testing.assert_array_equal(np.asarray(d2[:20]),
                                      np.asarray(d2a[:20]))
        assert (np.asarray(idx[20:]) == -1).all()
        assert np.isinf(np.asarray(d2[20:])).all()


def test_serve_topk_matches_full_sort(rng):
    from repro.core.objective import sq_dists
    x = jnp.asarray(rng.normal(size=(15, 7)).astype(np.float32))
    c = jnp.asarray(rng.normal(size=(20, 7)).astype(np.float32))
    count = 13
    m = jnp.asarray(np.arange(20) < count)
    d2k, idxk = ops.serve_topk(x, c, 5, mask=m,
                               count=jnp.asarray(count, jnp.int32),
                               n_valid=jnp.asarray(12, jnp.int32))
    full = np.where(np.arange(20)[None, :] < count,
                    np.asarray(sq_dists(x, c)), np.inf)
    order = np.argsort(full, axis=1, kind="stable")[:, :5]
    np.testing.assert_array_equal(np.asarray(idxk[:12]), order[:12])
    assert (np.diff(np.asarray(d2k[:12]), axis=1) >= 0).all()
    assert (np.asarray(idxk[12:]) == -1).all()
    # top-1 column == serve_assign verdict (same algebra, same ties)
    _, ia = ops.serve_assign(x, c, m, count=jnp.asarray(count, jnp.int32),
                             backend="ref")
    np.testing.assert_array_equal(np.asarray(idxk[:12, 0]),
                                  np.asarray(ia[:12]))


def test_serve_topk_active_prefix_immune_to_garbage_slots(rng):
    """Slots beyond the active prefix may hold arbitrary stale payloads —
    including NaN/inf — after pool reuse or snapshot capacity padding.
    `serve_topk` scores only the active prefix (masked rows are zeroed
    before the matmul), so garbage slots can neither surface in the top-k
    nor perturb the scores of valid slots, and asking for k > count yields
    clean (inf, -1) tails rather than garbage indices."""
    x = jnp.asarray(rng.normal(size=(9, 6)).astype(np.float32))
    c_clean = jnp.asarray(rng.normal(size=(16, 6)).astype(np.float32))
    count = 5
    poisoned = c_clean.at[count:].set(jnp.nan).at[count + 1].set(jnp.inf)
    cnt = jnp.asarray(count, jnp.int32)
    k = 8                                     # > count: forces padded tail
    d2_ref, idx_ref = ops.serve_topk(x, c_clean, k, count=cnt)
    d2_poi, idx_poi = ops.serve_topk(x, poisoned, k, count=cnt)
    np.testing.assert_array_equal(np.asarray(idx_ref), np.asarray(idx_poi))
    np.testing.assert_array_equal(np.asarray(d2_ref), np.asarray(d2_poi))
    assert (np.asarray(idx_poi) < count).all()            # never a padded slot
    assert (np.asarray(idx_poi[:, count:]) == -1).all()   # clean k>count tail
    assert np.isinf(np.asarray(d2_poi[:, count:])).all()
    assert np.isfinite(np.asarray(d2_poi[:, :count])).all()


# ------------------------------------------- streaming top-k (DESIGN.md §16)
#
# Parity tiers, per the §16 precision note: for f32 inputs the streamed
# merge is candidate-multiset-invariant, and at MXU-aligned shapes (D a
# lane multiple, K a block multiple) XLA CPU reproduces the tile matmuls
# bitwise against the flat one — so aligned shapes assert BITWISE equality
# of (d2, idx) across ref/emulate/interpret.  At deliberately awkward
# shapes (D=19, K=300) the last-ulp of the d2 reduction may differ between
# tilings, so ragged sweeps assert idx exactly + d2 to 1e-5 — while
# emulate vs interpret stays bitwise EVERYWHERE (identical op sequence).

from repro.kernels.topk_stream import (
    topk_stream_emulate, topk_tile_loads, topk_multiprobe_emulate,
)
from repro.serving.snapshot import build_hier


@pytest.mark.parametrize("n,kc,d,count,k", [
    (17, 20, 5, 13, 4),      # ragged everything
    (37, 300, 19, 211, 7),   # many tiles, awkward D
    (9, 20, 6, 5, 8),        # k > count: padded tail
    (20, 37, 6, 0, 3),       # empty pool
    (33, 130, 8, 130, 5),    # count == K, all tiles active
])
def test_topk_stream_ragged_parity(rng, n, kc, d, count, k):
    x = jnp.asarray(rng.normal(size=(n, d)).astype(np.float32))
    c = jnp.asarray(rng.normal(size=(kc, d)).astype(np.float32))
    m = jnp.asarray(np.arange(kc) < count)
    cnt = jnp.asarray(count, jnp.int32)
    d2r, ir = ops.serve_topk(x, c, k, mask=m, count=cnt, backend="ref")
    d2p, ip = ops.serve_topk(x, c, k, mask=m, count=cnt, backend="pallas",
                             block_n=16, block_k=8)
    d2e, ie = ops.serve_topk(x, c, k, mask=m, count=cnt, backend="emulate",
                             block_n=16, block_k=8)
    np.testing.assert_array_equal(np.asarray(ip), np.asarray(ir))
    np.testing.assert_allclose(np.asarray(d2p), np.asarray(d2r), atol=1e-5)
    # emulate replays the kernel schedule op for op: bitwise vs interpret
    np.testing.assert_array_equal(np.asarray(d2e), np.asarray(d2p))
    np.testing.assert_array_equal(np.asarray(ie), np.asarray(ip))


def test_topk_stream_bitwise_at_aligned_shapes(rng):
    """MXU-aligned serving shapes: all three backends bit-identical in
    BOTH distances and indices, ragged active prefix included."""
    x = jnp.asarray(rng.normal(size=(64, 64)).astype(np.float32))
    c = jnp.asarray(rng.normal(size=(512, 64)).astype(np.float32))
    count = 387
    m = jnp.asarray(np.arange(512) < count)
    cnt = jnp.asarray(count, jnp.int32)
    d2r, ir = ops.serve_topk(x, c, 8, mask=m, count=cnt, backend="ref")
    d2e, ie = ops.serve_topk(x, c, 8, mask=m, count=cnt, backend="emulate")
    d2p, ip = ops.serve_topk(x, c, 8, mask=m, count=cnt, backend="pallas",
                             block_n=32, block_k=128)
    np.testing.assert_array_equal(np.asarray(d2e), np.asarray(d2r))
    np.testing.assert_array_equal(np.asarray(ie), np.asarray(ir))
    np.testing.assert_array_equal(np.asarray(d2p), np.asarray(d2r))
    np.testing.assert_array_equal(np.asarray(ip), np.asarray(ir))


def test_topk_top1_column_equals_serve_assign(rng):
    """topk[:, :1] == serve_assign on each backend — same algebra, same
    lower-index tie order (the contract layered services rely on)."""
    x = jnp.asarray(rng.normal(size=(31, 16)).astype(np.float32))
    c = jnp.asarray(rng.normal(size=(64, 16)).astype(np.float32))
    cnt = jnp.asarray(41, jnp.int32)
    m = jnp.asarray(np.arange(64) < 41)
    for backend in ("ref", "emulate", "pallas"):
        kw = {} if backend == "ref" else {"block_n": 16, "block_k": 8}
        d2k, ik = ops.serve_topk(x, c, 3, mask=m, count=cnt,
                                 backend=backend, **kw)
        d2a, ia = ops.serve_assign(x, c, m, count=cnt, backend=backend,
                                   **kw)
        np.testing.assert_array_equal(np.asarray(ik[:, 0]), np.asarray(ia))


def test_topk_static_count_slicing_bitwise(rng):
    """A HOST-int count lets CPU backends slice to the pow2 active prefix
    pre-matmul; the result must be bitwise what the traced-count full-
    width dispatch produces (a prefix slice changes no surviving lane)."""
    x = jnp.asarray(rng.normal(size=(16, 16)).astype(np.float32))
    c = jnp.asarray(rng.normal(size=(1024, 16)).astype(np.float32))
    count = 53                                # pow2 pad -> 64 of 1024
    m = jnp.asarray(np.arange(1024) < count)
    for backend in ("ref", "emulate"):
        d2s, is_ = ops.serve_topk(x, c, 6, mask=m, count=count,
                                  backend=backend)
        d2t, it = ops.serve_topk(x, c, 6, mask=m,
                                 count=jnp.asarray(count, jnp.int32),
                                 backend=backend)
        np.testing.assert_array_equal(np.asarray(d2s), np.asarray(d2t))
        np.testing.assert_array_equal(np.asarray(is_), np.asarray(it))


@pytest.mark.parametrize("count", [0, 1, 5, 64, 130, 300, 512])
def test_topk_tile_loads_accounting(rng, count):
    """Emulate-mode DMA accounting == the host-side index-map walk, and
    tiles beyond the active prefix issue ZERO loads (the dpmeans_assign
    assertion style, applied to the top-k schedule)."""
    kc, bk = 512, 128
    x = jnp.asarray(rng.normal(size=(8, 16)).astype(np.float32))
    c = jnp.asarray(rng.normal(size=(kc, 16)).astype(np.float32))
    m = jnp.asarray(np.arange(kc) < count)
    d2, idx, loads = topk_stream_emulate(
        x, c, m, 4, count=jnp.asarray(count, jnp.int32), block_k=bk,
        with_loads=True)
    walk = topk_tile_loads(count, kc, block_k=bk)
    assert int(loads) == walk
    assert walk == max(1, -(-count // bk))    # active tiles only
    assert walk <= kc // bk                   # never the full-K sweep


def test_topk_k_exceeds_capacity_padded_columns(rng):
    """k > buffer capacity: overflow columns are (inf, -1) on every
    backend, real columns untouched."""
    x = jnp.asarray(rng.normal(size=(7, 5)).astype(np.float32))
    c = jnp.asarray(rng.normal(size=(12, 5)).astype(np.float32))
    cnt = jnp.asarray(12, jnp.int32)
    for backend in ("ref", "emulate", "pallas"):
        kw = {} if backend == "ref" else {"block_n": 8, "block_k": 8}
        d2, idx = ops.serve_topk(x, c, 20, count=cnt, backend=backend, **kw)
        assert d2.shape == (7, 20)
        assert (np.asarray(idx[:, 12:]) == -1).all()
        assert np.isinf(np.asarray(d2[:, 12:])).all()
        assert (np.asarray(idx[:, :12]) >= 0).all()


def test_topk_duplicate_distance_tiebreak_determinism(rng):
    """Duplicated center rows force exact distance ties; every backend
    must break them identically — ascending index within each tie run
    (lax.top_k's order, pinned by the lexicographic (d2, id) merge)."""
    base = rng.normal(size=(8, 6)).astype(np.float32)
    c = jnp.asarray(np.repeat(base, 3, axis=0))        # rows 3i,3i+1,3i+2 equal
    x = jnp.asarray(rng.normal(size=(11, 6)).astype(np.float32))
    cnt = jnp.asarray(24, jnp.int32)
    outs = {}
    for backend in ("ref", "emulate", "pallas"):
        kw = {} if backend == "ref" else {"block_n": 8, "block_k": 8}
        d2, idx = ops.serve_topk(x, c, 6, count=cnt, backend=backend, **kw)
        outs[backend] = (np.asarray(d2), np.asarray(idx))
    for b in ("emulate", "pallas"):
        np.testing.assert_array_equal(outs[b][1], outs["ref"][1])
        np.testing.assert_array_equal(outs[b][0], outs["ref"][0])
    d2, idx = outs["ref"]
    for r in range(11):
        for j in range(1, 6):
            if d2[r, j] == d2[r, j - 1]:               # exact tie
                assert idx[r, j] > idx[r, j - 1]       # ascending ids
        # duplicates: each triple's members surface lowest-index first
        assert idx[r, 0] % 3 == 0                      # nearest triple's row 3i


def test_topk_multiprobe_full_union_bitwise_flat(rng):
    """p = all at the ops level: union covering every cell + all-true
    membership is bit-identical to flat serve_topk on every backend —
    garbage in padded shard slots included."""
    kc, d, count = 512, 64, 437
    cn = rng.normal(size=(kc, d)).astype(np.float32)
    cn[count:] = np.nan
    m = jnp.asarray(np.arange(kc) < count)
    h = build_hier(jnp.asarray(np.nan_to_num(cn)), m, count)
    x = jnp.asarray(rng.normal(size=(32, d)).astype(np.float32))
    cells = jnp.arange(h.n_cells, dtype=jnp.int32)
    member = jnp.ones((32, h.n_cells), bool)
    d2f, if_ = ops.serve_topk(x, jnp.asarray(np.nan_to_num(cn)), 9, mask=m,
                              count=jnp.asarray(count, jnp.int32),
                              backend="ref")
    for backend in ("ref", "emulate", "pallas"):
        d2m, im = ops.serve_topk_multiprobe(
            x, h.fine, h.fine_ids, h.fine_mask, cells, member, 9,
            u_count=jnp.asarray(h.n_cells, jnp.int32), backend=backend)
        np.testing.assert_array_equal(np.asarray(d2m), np.asarray(d2f))
        np.testing.assert_array_equal(np.asarray(im), np.asarray(if_))


def test_topk_multiprobe_partial_union_matches_candidate_oracle(rng):
    """Partial probes: backends agree on indices exactly (distances to f32
    tolerance — the gathered widths here are deliberately unaligned, §16
    precision note) AND match a brute-force numpy top-k over exactly the
    probed candidate set."""
    kc, d, count = 256, 16, 201
    cn = rng.normal(size=(kc, d)).astype(np.float32)
    m = jnp.asarray(np.arange(kc) < count)
    h = build_hier(jnp.asarray(cn), m, count)
    b, k = 9, 5
    x = rng.normal(size=(b, d)).astype(np.float32)
    probed = np.sort(rng.choice(h.n_cells, size=3, replace=False))
    cells = np.full((h.n_cells,), -1, np.int32)
    cells[:3] = probed
    member = np.zeros((b, h.n_cells), bool)
    member[:, :3] = rng.uniform(size=(b, 3)) > 0.3
    outs = {}
    for backend in ("ref", "emulate", "pallas"):
        outs[backend] = ops.serve_topk_multiprobe(
            x, h.fine, h.fine_ids, h.fine_mask, jnp.asarray(cells),
            jnp.asarray(member), k, u_count=jnp.asarray(3, jnp.int32),
            backend=backend)
    for bk_ in ("emulate", "pallas"):
        np.testing.assert_array_equal(np.asarray(outs[bk_][1]),
                                      np.asarray(outs["ref"][1]))
        np.testing.assert_allclose(np.asarray(outs[bk_][0]),
                                   np.asarray(outs["ref"][0]), atol=1e-5)
    # brute force over the candidate multiset
    ids = np.asarray(h.fine_ids)
    msk = np.asarray(h.fine_mask)
    d2o, io_ = np.asarray(outs["ref"][0]), np.asarray(outs["ref"][1])
    for q in range(b):
        cand = [int(i) for u in range(3) if member[q, u]
                for i in ids[probed[u]][msk[probed[u]]]]
        dd = np.sort([float(np.sum((x[q] - cn[i]) ** 2)) for i in cand])
        got = io_[q][io_[q] >= 0]
        assert len(got) == min(k, len(cand))
        np.testing.assert_allclose(np.sort(d2o[q][np.isfinite(d2o[q])]),
                                   dd[:len(got)], atol=1e-4)
        assert set(got) <= set(cand)


# ------------------------------------ hypothesis layer (streaming top-k)

try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:
    HAVE_HYPOTHESIS = False

if HAVE_HYPOTHESIS:
    @settings(max_examples=25, deadline=None)
    @given(data=st.data())
    def test_hypothesis_topk_stream_parity(data):
        """Any (n, K, count, k, duplicate run): ref and emulate agree on
        indices exactly, distances to f32 tolerance, tails are (inf, -1),
        and rows are lexicographically (d2, idx) ascending."""
        n = data.draw(st.integers(1, 40), label="n")
        kc = data.draw(st.integers(1, 200), label="K")
        count = data.draw(st.integers(0, kc), label="count")
        k = data.draw(st.integers(1, 12), label="k")
        dup = data.draw(st.booleans(), label="dup")
        rng = np.random.default_rng(data.draw(st.integers(0, 2**31),
                                              label="seed"))
        c = rng.normal(size=(kc, 8)).astype(np.float32)
        if dup and kc >= 2:
            c[1::2] = c[0::2][: c[1::2].shape[0]]      # force exact ties
        x = jnp.asarray(rng.normal(size=(n, 8)).astype(np.float32))
        m = jnp.asarray(np.arange(kc) < count)
        cnt = jnp.asarray(count, jnp.int32)
        d2r, ir = ops.serve_topk(x, jnp.asarray(c), k, mask=m, count=cnt,
                                 backend="ref")
        d2e, ie = ops.serve_topk(x, jnp.asarray(c), k, mask=m, count=cnt,
                                 backend="emulate", block_n=16, block_k=8)
        np.testing.assert_array_equal(np.asarray(ie), np.asarray(ir))
        np.testing.assert_allclose(np.asarray(d2e), np.asarray(d2r),
                                   atol=1e-5)
        d2, idx = np.asarray(d2r), np.asarray(ir)
        valid = idx >= 0
        assert (valid.sum(1) == min(k, count)).all()
        assert np.isinf(d2[~valid]).all()
        for r in range(n):                     # lexicographic ascending
            row_d, row_i = d2[r][valid[r]], idx[r][valid[r]]
            assert (np.diff(row_d) >= 0).all()
            same = np.diff(row_d) == 0
            assert (np.diff(row_i)[same] > 0).all()
else:  # pragma: no cover - exercised only without hypothesis
    def test_hypothesis_topk_layer_skipped():
        pytest.skip("hypothesis not installed; deterministic layer still ran")

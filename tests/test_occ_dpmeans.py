"""DP-means: Thm 3.1 serializability (exact), Thm 3.3 master bound,
objective behaviour, bootstrap, bounded-master validation."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import occ_dp_means, serial_dp_means, serial_dp_means_pass
from repro.core.dp_means import _recompute_means, thm31_permutation
from repro.core.objective import dp_means_objective
from repro.data import dp_stick_breaking_data, separable_cluster_data

LAM = 4.0


@pytest.mark.parametrize("pb", [16, 64, 256])
def test_serializability_exact(pb):
    """Thm 3.1: OCC run == serial run on the constructed permutation —
    identical assignments AND identical centers in creation order."""
    x, _, _ = dp_stick_breaking_data(512, seed=1)
    x = jnp.asarray(x)
    res = occ_dp_means(x, LAM, pb=pb, k_max=128, max_iters=1)
    perm = thm31_permutation(res, x.shape[0])
    pool_s, z_s = serial_dp_means_pass(x[perm], LAM, 128)
    assert int(pool_s.count) == int(res.pool.count)
    assert np.array_equal(np.asarray(z_s), np.asarray(res.z)[perm])
    pool_s = _recompute_means(x[perm], z_s, pool_s)
    k = int(res.pool.count)
    np.testing.assert_allclose(np.asarray(pool_s.centers[:k]),
                               np.asarray(res.pool.centers[:k]), atol=1e-5)


def test_master_bound_separable():
    """Thm 3.3: E[#sent] <= Pb + K_N under the separation assumptions
    (App. C.1 data).  Deterministic bound holds per-epoch construction:
    at most Pb sends in the first epoch a cluster is seen."""
    sent, bound = [], []
    for seed in range(5):
        x, z_true, _ = separable_cluster_data(2048, seed=seed)
        res = occ_dp_means(jnp.asarray(x), 1.0, pb=128, k_max=256, max_iters=1)
        sent.append(int(res.stats.proposed.sum()))
        bound.append(128 + int(z_true.max()) + 1)
    # expectation bound with per-run slack
    assert np.mean(sent) <= np.mean(bound) * 1.1
    # every accepted center count matches k_N under separation
    assert int(res.pool.count) == int(z_true.max()) + 1


def test_rejections_flat_in_n():
    """Fig 3a: E[M_N - k_N] bounded by Pb, flat as N grows."""
    pb = 64
    rejects = []
    for n in (256, 1024, 2048):
        x, _, _ = separable_cluster_data(n, seed=7)
        res = occ_dp_means(jnp.asarray(x), 1.0, pb=pb, k_max=256, max_iters=1)
        rejects.append(int(res.stats.proposed.sum()) - int(res.pool.count))
    assert all(r <= pb for r in rejects)


def test_objective_improves_with_iters():
    x, _, _ = dp_stick_breaking_data(512, seed=3)
    x = jnp.asarray(x)
    r1 = occ_dp_means(x, LAM, pb=64, k_max=128, max_iters=1)
    r5 = occ_dp_means(x, LAM, pb=64, k_max=128, max_iters=5)
    assert float(r5.objective) <= float(r1.objective) + 1e-3


def test_multipass_stats_accumulate():
    """max_iters > 1 keeps EVERY pass's validator stats (one entry per
    epoch, globally numbered), not just pass 1's."""
    x, _, _ = dp_stick_breaking_data(512, seed=3)
    x = jnp.asarray(x)
    t = 512 // 64
    r1 = occ_dp_means(x, LAM, pb=64, k_max=128, max_iters=1)
    r5 = occ_dp_means(x, LAM, pb=64, k_max=128, max_iters=5)
    assert r5.n_iters > 1
    assert r5.stats.proposed.shape == (t * r5.n_iters,)
    assert r5.stats.accepted.shape == (t * r5.n_iters,)
    # pass 1 is bit-identical to the single-pass run
    np.testing.assert_array_equal(np.asarray(r5.stats.proposed[:t]),
                                  np.asarray(r1.stats.proposed))
    # epoch_of numbers epochs globally: the last pass's epochs are labelled
    # [t*(n_iters-1), t*n_iters) so stats[epoch_of[i]] is always meaningful
    assert int(r5.epoch_of.max()) == t * r5.n_iters - 1
    assert int(r5.epoch_of.min()) == t * (r5.n_iters - 1)


def test_matches_serial_quality():
    x, _, _ = dp_stick_breaking_data(512, seed=4)
    x = jnp.asarray(x)
    rs = serial_dp_means(x, LAM, k_max=128, max_iters=5)
    ro = occ_dp_means(x, LAM, pb=64, k_max=128, max_iters=5)
    assert float(ro.objective) <= 1.3 * float(rs.objective)


def test_bootstrap_preserves_serializability_quality():
    x, _, _ = dp_stick_breaking_data(512, seed=5)
    x = jnp.asarray(x)
    rb = occ_dp_means(x, LAM, pb=64, k_max=128, max_iters=1, bootstrap=True)
    rn = occ_dp_means(x, LAM, pb=64, k_max=128, max_iters=1)
    # bootstrap reduces first-epoch master load (paper §4.2)
    assert rb.stats.proposed[0] <= rn.stats.proposed[0]
    assert float(rb.objective) <= 1.5 * float(rn.objective)


def test_bounded_master_cap():
    """The bounded master produces identical results when the cap is not
    exceeded."""
    x, _, _ = dp_stick_breaking_data(256, seed=6)
    x = jnp.asarray(x)
    r_full = occ_dp_means(x, LAM, pb=64, k_max=128, max_iters=1)
    r_cap = occ_dp_means(x, LAM, pb=64, k_max=128, max_iters=1,
                         validate_cap=64)
    assert int(r_full.pool.count) == int(r_cap.pool.count)
    assert np.array_equal(np.asarray(r_full.z), np.asarray(r_cap.z))


def test_overflow_flag():
    x, _, _ = dp_stick_breaking_data(256, seed=6)
    res = occ_dp_means(jnp.asarray(x), 0.01, pb=64, k_max=8, max_iters=1)
    assert bool(res.pool.overflow)


def test_objective_function():
    x = jnp.asarray([[0.0, 0.0], [1.0, 0.0]])
    c = jnp.asarray([[0.0, 0.0]])
    # J = 0 + 1 + lam^2 * 1
    assert float(dp_means_objective(x, c, 2.0)) == pytest.approx(1.0 + 4.0)

"""Per-architecture smoke tests: reduced config, one forward/train step on
CPU, output shapes + no NaNs; decode path consistent with teacher forcing."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCHS, TrainConfig, reduced
from repro.models import build_model
from repro.training.step import make_train_step, train_state_init

# Heavy per-arch LM smoke tests — deselected in CI (`-m "not slow"`).
pytestmark = pytest.mark.slow

ALL_ARCHS = sorted(ARCHS)


def _setup(name, seed=0):
    cfg = reduced(ARCHS[name]).replace(dtype="float32")
    m = build_model(cfg)
    params = m.init(jax.random.key(seed))
    rng = np.random.default_rng(seed)
    B, S = 2, 32
    batch = {
        "tokens": jnp.asarray(rng.integers(0, cfg.vocab, (B, S)), jnp.int32),
        "labels": jnp.asarray(rng.integers(0, cfg.vocab, (B, S)), jnp.int32),
    }
    if cfg.frontend:
        batch["frontend"] = jnp.asarray(
            rng.normal(size=(B, cfg.frontend_len, cfg.frontend_dim)),
            jnp.float32)
    return cfg, m, params, batch


@pytest.mark.parametrize("name", ALL_ARCHS)
def test_forward_and_shapes(name):
    cfg, m, params, batch = _setup(name)
    loss = m.loss(params, batch)
    assert loss.shape == ()
    assert bool(jnp.isfinite(loss))
    assert float(loss) > 0


@pytest.mark.parametrize("name", ALL_ARCHS)
def test_one_train_step(name):
    cfg, m, params, batch = _setup(name)
    tcfg = TrainConfig(total_steps=10, warmup_steps=2)
    state = train_state_init(params, tcfg)
    step = make_train_step(m, tcfg)
    state, metrics = step(state, batch)
    assert bool(jnp.isfinite(metrics["loss"]))
    assert bool(jnp.isfinite(metrics["grad_norm"]))
    # params actually changed
    delta = jax.tree.map(lambda a, b: float(jnp.max(jnp.abs(a - b))),
                         state.params, params)
    assert max(jax.tree.leaves(delta)) > 0


@pytest.mark.parametrize("name", ALL_ARCHS)
def test_decode_matches_forward(name):
    """Prefill(S) + decode(token S) == prefill(S+1) — the serving path is
    consistent with teacher forcing for every family."""
    cfg, m, params, batch = _setup(name, seed=1)
    rng = np.random.default_rng(1)
    B, S = 2, 16
    toks = jnp.asarray(rng.integers(0, cfg.vocab, (B, S + 1)), jnp.int32)
    b1 = dict(batch, tokens=toks[:, :S])
    b2 = dict(batch, tokens=toks[:, :S + 1])
    lg1, caches = m.prefill(params, b1)
    lg2, _ = m.prefill(params, b2)
    n_prefix = cfg.frontend_len if (cfg.frontend and not cfg.is_encdec) else 0

    def pad_seq(a):
        if a.ndim >= 4 and a.shape[2] == S + n_prefix:
            pad = jnp.zeros(a.shape[:2] + (4,) + a.shape[3:], a.dtype)
            return jnp.concatenate([a, pad], axis=2)
        return a

    caches = jax.tree.map(pad_seq, caches)
    pos = jnp.full((B,), S + n_prefix, jnp.int32)
    lg_dec, _ = m.decode_step(params, caches, toks[:, S:S + 1], pos)
    np.testing.assert_allclose(np.asarray(lg_dec), np.asarray(lg2), atol=2e-3)


@pytest.mark.parametrize("name", ["zamba2-7b", "xlstm-1.3b"])
def test_subquadratic_flag(name):
    from repro.configs import SHAPES, supports_shape
    ok, _ = supports_shape(ARCHS[name], SHAPES["long_500k"])
    assert ok


def test_full_attention_skips_long():
    from repro.configs import SHAPES, supports_shape
    ok, why = supports_shape(ARCHS["qwen3-8b"], SHAPES["long_500k"])
    assert not ok and "full-attention" in why


def test_param_counts_full_configs():
    """Full (non-reduced) configs hit the advertised parameter scale."""
    expect = {"granite-3-2b": (2.0e9, 3.5e9), "qwen3-8b": (7e9, 9.5e9),
              "phi3.5-moe-42b-a6.6b": (38e9, 46e9), "olmoe-1b-7b": (6e9, 8e9),
              "xlstm-1.3b": (1.0e9, 1.9e9), "zamba2-7b": (6e9, 9e9)}
    for name, (lo, hi) in expect.items():
        m = build_model(ARCHS[name])
        n = m.param_count()
        assert lo <= n <= hi, (name, n)


def test_moe_impls_agree():
    """All four MoE dispatch implementations compute the same function
    (high capacity factor -> no drops)."""
    import dataclasses
    from repro.models.moe import init_moe, moe_apply
    cfg = reduced(ARCHS["olmoe-1b-7b"]).replace(dtype="float32")
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.normal(size=(2, 16, cfg.d_model)).astype(np.float32))
    p = init_moe(jax.random.key(0), cfg)
    outs = {}
    for impl in ["dense", "capacity", "gather", "ragged", "hybrid"]:
        c = cfg.replace(moe=dataclasses.replace(cfg.moe, impl=impl,
                                                capacity_factor=8.0))
        outs[impl] = np.asarray(moe_apply(p, x, c, None))
    for impl in ["capacity", "gather", "ragged", "hybrid"]:
        np.testing.assert_allclose(outs[impl], outs["dense"], atol=1e-4)

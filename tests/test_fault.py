"""Fault machinery unit tests: watchdog, heartbeats, FaultPlan (§14).

All synthetic — no sockets, no sleeps beyond microseconds.  The chaos
tests that drive `FaultPlan` through real transport paths live in
`test_transport.py` (frame-level) and `test_occ_cluster.py` (process-
level kill/promotion).
"""
import pytest

from repro.distributed.fault import (FaultEvent, FaultPlan, FaultRule,
                                     HeartbeatTracker, StepWatchdog)


# -------------------------------------------------------------- StepWatchdog

def test_watchdog_flags_straggler_after_warmup():
    wd = StepWatchdog(threshold=3.0, alpha=0.5, warmup_steps=2)
    # warmup steps never fire, whatever their timing
    assert wd.observe(0, 100.0) is None
    assert wd.observe(1, 100.0) is None
    assert wd.observe(2, 1.0) is None        # first post-warmup seeds EWMA
    assert wd.observe(3, 1.0) is None        # 1.0x: quiet
    ev = wd.observe(4, 10.0)                 # 10x the EWMA: straggler
    assert ev is not None and ev.step == 4 and ev.ratio > 3.0
    assert wd.events == [ev]


def test_watchdog_outliers_not_folded_into_ewma():
    wd = StepWatchdog(threshold=2.0, alpha=0.5, warmup_steps=0)
    wd.observe(0, 1.0)
    wd.observe(1, 50.0)                      # fires, EWMA must stay 1.0
    assert wd.ewma == 1.0
    assert wd.observe(2, 1.5) is None        # normal step still judged vs 1.0
    assert len(wd.events) == 1


def test_watchdog_ewma_tracks_gradual_drift_quietly():
    wd = StepWatchdog(threshold=3.0, alpha=0.3, warmup_steps=0)
    t = 1.0
    for step in range(30):                   # 10% slower every step
        t *= 1.10
        assert wd.observe(step, t) is None, "gradual drift must not fire"
    assert wd.ewma > 1.0


# ---------------------------------------------------------- HeartbeatTracker

def test_heartbeat_dead_hosts_synthetic_clock():
    hb = HeartbeatTracker(timeout=10.0)
    hb.beat(0, now=100.0)
    hb.beat(1, now=100.0)
    hb.beat(2, now=105.0)
    assert hb.dead_hosts(now=109.0) == []
    assert hb.dead_hosts(now=111.0) == [0, 1]      # 2 beat at 105
    hb.beat(0, now=112.0)                          # resurrection
    assert hb.dead_hosts(now=113.0) == [1]


# -------------------------------------------------------------- FaultPlan

def test_fault_rule_validates_kind_and_trigger():
    with pytest.raises(ValueError, match="kind"):
        FaultRule("p", "explode", nth=1)
    with pytest.raises(ValueError, match="trigger"):
        FaultRule("p", "drop")


def test_fault_plan_nth_and_every_triggers_are_exact():
    plan = FaultPlan([FaultRule("a", "drop", nth=3),
                      FaultRule("a", "delay", every=2, delay_s=0.0)])
    fired = [tuple(r.kind for r in plan.at("a")) for _ in range(6)]
    assert fired == [(), ("delay",), ("drop",), ("delay",), (),
                     ("delay",)]
    assert plan.hits("a") == 6
    assert [e.hit for e in plan.events if e.kind == "drop"] == [3]


def test_fault_plan_points_are_independent():
    plan = FaultPlan([FaultRule("a", "drop", nth=1)])
    assert plan.at("b") == []                # other points never trigger
    assert [r.kind for r in plan.at("a")] == ["drop"]
    assert plan.hits("a") == 1 and plan.hits("b") == 1


def test_fault_plan_count_caps_total_fires():
    plan = FaultPlan([FaultRule("a", "dup", every=1, count=2)])
    kinds = [len(plan.at("a")) for _ in range(5)]
    assert kinds == [1, 1, 0, 0, 0]


def test_fault_plan_prob_is_seed_deterministic():
    mk = lambda seed: FaultPlan([FaultRule("a", "drop", prob=0.5)],
                                seed=seed)
    run = lambda plan: [bool(plan.at("a")) for _ in range(64)]
    a, b = run(mk(7)), run(mk(7))
    assert a == b, "same seed must replay the same schedule"
    assert run(mk(8)) != a                   # and a different seed differs
    assert 10 < sum(a) < 54                  # actually probabilistic


def test_fault_plan_kill_requires_opt_in():
    plan = FaultPlan([FaultRule("a", "kill", nth=1)])   # allow_kill=False
    with pytest.raises(RuntimeError, match="allow_kill"):
        plan.at("a")


def test_fault_plan_audit_trail():
    plan = FaultPlan([FaultRule("x", "reset", nth=2)])
    plan.at("x")
    plan.at("x")
    assert plan.events == [FaultEvent("x", "reset", 2)]

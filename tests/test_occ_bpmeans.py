"""BP-means: serializability (App. B.2), representation quality, re-estimation."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import occ_bp_means, serial_bp_means, serial_bp_means_pass
from repro.core.bp_means import BPMeansTransaction, _reestimate
from repro.core.dp_means import thm31_permutation
from repro.data import bp_stick_breaking_data

LAM = 4.0


@pytest.mark.parametrize("pb", [32, 64])
def test_serializability_exact(pb):
    """App. B.2: the OCC run equals the serial pass on the Thm-3.1
    permutation, GIVEN the same initial pool.  The engine seeds init_mean
    from the first epoch's points (batching-independent initializer scope,
    DESIGN.md §11), so the serial pass is seeded with that same pool —
    serializability is a statement about the pass, not the init."""
    x, _, _ = bp_stick_breaking_data(256, seed=2)
    x = jnp.asarray(x)
    res = occ_bp_means(x, LAM, pb=pb, k_max=64, max_iters=1, init_mean=True)
    perm = thm31_permutation(res, x.shape[0])
    txn = BPMeansTransaction(LAM, 64, init_mean=True)
    pool_s, z_s = serial_bp_means_pass(x[perm], LAM, 64,
                                       pool=txn.init_pool(x[:pb]),
                                       z=txn.make_state(x))
    k = int(res.pool.count)
    assert int(pool_s.count) == k
    assert np.array_equal(np.asarray(z_s), np.asarray(res.z)[perm])
    pool_s = _reestimate(x[perm], z_s, pool_s)
    np.testing.assert_allclose(np.asarray(pool_s.centers[:k]),
                               np.asarray(res.pool.centers[:k]), atol=1e-4)


def test_rejections_bounded():
    x, _, _ = bp_stick_breaking_data(512, seed=3)
    res = occ_bp_means(jnp.asarray(x), LAM, pb=64, k_max=128, max_iters=1)
    m_n = int(res.stats.proposed.sum())
    k_n = int(res.stats.accepted.sum())
    assert m_n - k_n <= 64 * 4   # loose Pb-scale bound (paper Fig 3c)


def test_reconstruction_improves():
    x, ztrue, feats = bp_stick_breaking_data(256, seed=4)
    x = jnp.asarray(x)
    res = occ_bp_means(x, 2.0, pb=64, k_max=128, max_iters=3)
    zf = jnp.logical_and(res.z, res.pool.mask[None, :]).astype(jnp.float32)
    recon = zf @ res.pool.centers
    base = float(jnp.mean(jnp.sum(x * x, -1)))
    err = float(jnp.mean(jnp.sum((x - recon) ** 2, -1)))
    assert err < 0.5 * base


def test_matches_serial_quality():
    x, _, _ = bp_stick_breaking_data(256, seed=5)
    x = jnp.asarray(x)
    rs = serial_bp_means(x, LAM, k_max=64, max_iters=3)
    ro = occ_bp_means(x, LAM, pb=32, k_max=64, max_iters=3)
    assert float(ro.objective) <= 1.3 * float(rs.objective) + 1e-3


def test_multipass_stats_accumulate():
    """Every pass's validator stats are kept (one entry per epoch across
    all passes), matching the DP-means wrapper semantics."""
    x, _, _ = bp_stick_breaking_data(256, seed=4)
    x = jnp.asarray(x)
    t = 256 // 64
    r1 = occ_bp_means(x, 2.0, pb=64, k_max=128, max_iters=1)
    r3 = occ_bp_means(x, 2.0, pb=64, k_max=128, max_iters=3)
    assert r3.stats.proposed.shape == (t * r3.n_iters,)
    np.testing.assert_array_equal(np.asarray(r3.stats.proposed[:t]),
                                  np.asarray(r1.stats.proposed))
    if r3.n_iters > 1:
        assert int(r3.epoch_of.max()) == t * r3.n_iters - 1

"""Multi-device tests (subprocess with host-device emulation — conftest
deliberately leaves the main process at 1 device)."""
import json
import os
import subprocess
import sys

import pytest

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def _run(script: str, devices: int = 8, timeout: int = 600) -> str:
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={devices}"
    env["PYTHONPATH"] = os.path.join(REPO, "src")
    out = subprocess.run([sys.executable, "-c", script], env=env,
                         capture_output=True, text=True, timeout=timeout)
    assert out.returncode == 0, out.stderr[-3000:]
    return out.stdout


def test_occ_dpmeans_distributed_equals_local():
    """The mesh-sharded OCC run produces the same clustering as the
    single-device run — SPMD re-execution of the validator is exact."""
    out = _run("""
import jax, jax.numpy as jnp, numpy as np
from repro.core import occ_dp_means
from repro.data import dp_stick_breaking_data
x, _, _ = dp_stick_breaking_data(512, seed=1)
x = jnp.asarray(x)
from repro.launch.mesh import compat_mesh
mesh = compat_mesh((8,), ("data",))
r_local = occ_dp_means(x, 4.0, pb=64, k_max=128, max_iters=2)
r_dist = occ_dp_means(x, 4.0, pb=64, k_max=128, max_iters=2, mesh=mesh)
assert int(r_local.pool.count) == int(r_dist.pool.count)
assert np.array_equal(np.asarray(r_local.z), np.asarray(r_dist.z))
np.testing.assert_allclose(np.asarray(r_local.pool.centers),
                           np.asarray(r_dist.pool.centers), atol=1e-5)
print("DIST_OK", int(r_dist.pool.count))
""")
    assert "DIST_OK" in out


def test_cp_decode_equals_tp_decode():
    """Context-parallel (seq-sharded cache, psum-combined softmax) decode
    matches head-TP decode numerically."""
    out = _run("""
import jax, jax.numpy as jnp, numpy as np
from repro.configs import ARCHS, reduced
from repro.distributed.shardings import shard_ctx
from repro.models import build_model
cfg = reduced(ARCHS["granite-3-2b"]).replace(dtype="float32")
m = build_model(cfg)
from repro.launch.mesh import compat_mesh
mesh = compat_mesh((2, 4), ("data", "model"))
rng = np.random.default_rng(0)
B, CL = 4, 32
with shard_ctx(mesh), mesh:
    params = m.init(jax.random.key(0))
    caches = m.init_cache(B, CL)
    toks = jnp.asarray(rng.integers(0, cfg.vocab, (B, 1)), jnp.int32)
    pos = jnp.asarray(rng.integers(4, 8, (B,)), jnp.int32)
    lg_tp, c_tp = m.decode_step(params, caches, toks, pos, decode_mode="tp")
    lg_cp, c_cp = m.decode_step(params, caches, toks, pos, decode_mode="cp")
np.testing.assert_allclose(np.asarray(lg_tp), np.asarray(lg_cp), atol=2e-3)
for a, b in zip(jax.tree.leaves(c_tp), jax.tree.leaves(c_cp)):
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=1e-4)
print("CP_OK")
""")
    assert "CP_OK" in out


def test_sharded_train_step_matches_single_device():
    """pjit'd train step on a (2,2,2) mesh == single-device step."""
    out = _run("""
import jax, jax.numpy as jnp, numpy as np
from repro.configs import ARCHS, TrainConfig, reduced
from repro.distributed.shardings import shard_ctx
from repro.models import build_model
from repro.training.step import make_train_step, train_state_init
from repro.data.tokens import TokenPipeline
cfg = reduced(ARCHS["qwen3-4b"]).replace(dtype="float32")
m = build_model(cfg)
tcfg = TrainConfig()
pipe = TokenPipeline(cfg.vocab, 8, 16, seed=0)
batch = {k: jnp.asarray(v) for k, v in pipe.batch_at(0).items()}

state0 = train_state_init(m.init(jax.random.key(0)), tcfg)
s_ref, met_ref = make_train_step(m, tcfg)(state0, batch)

from repro.launch.mesh import compat_mesh
mesh = compat_mesh((2, 2, 2), ("pod", "data", "model"))
with shard_ctx(mesh), mesh:
    state1 = train_state_init(m.init(jax.random.key(0)), tcfg)
    s_sh, met_sh = jax.jit(make_train_step(m, tcfg))(state1, batch)
assert abs(float(met_ref["loss"]) - float(met_sh["loss"])) < 1e-4
for a, b in zip(jax.tree.leaves(s_ref.params), jax.tree.leaves(s_sh.params)):
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=2e-4)
print("TRAIN_SHARD_OK", float(met_sh["loss"]))
""")
    assert "TRAIN_SHARD_OK" in out


def test_compressed_psum_shard_map():
    """int8 error-feedback psum over a real mesh axis: exact integer
    reduction, residual bounded."""
    out = _run("""
import jax, jax.numpy as jnp, numpy as np
from jax.sharding import PartitionSpec as P
from repro.optim.compression import compressed_psum_with_feedback, ef_init
from repro.launch.mesh import compat_mesh
mesh = compat_mesh((4,), ("pod",))
rng = np.random.default_rng(0)
g_all = jnp.asarray(rng.normal(size=(4, 64)).astype(np.float32))
def body(g):
    grads = {"w": g[0]}
    ef = ef_init(grads)
    out, ef2 = compressed_psum_with_feedback(grads, ef, "pod")
    return out["w"], ef2.residual["w"]
from repro.distributed.shardings import compat_shard_map
summed, resid = compat_shard_map(body, mesh=mesh, in_specs=P("pod"),
                                 out_specs=(P(), P("pod")))(g_all)
true = np.asarray(g_all).sum(0)
err = np.abs(np.asarray(summed) - true).max()
amax = np.abs(np.asarray(g_all)).max()
assert err <= 4 * (amax / 127) + 1e-6, err
print("PSUM_OK", err)
""", devices=4)
    assert "PSUM_OK" in out


def test_elastic_remesh_restore(tmp_path):
    """Checkpoint on a (4,2) mesh, 'lose' devices, restore onto (2,2)."""
    out = _run(f"""
import jax, jax.numpy as jnp, numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P
from repro.checkpoint import CheckpointManager
from repro.distributed.elastic import plan_shrunk_mesh, build_mesh_from_plan
from repro.launch.mesh import compat_mesh
mesh = compat_mesh((4, 2), ("data", "model"))
w = jnp.arange(32, dtype=jnp.float32).reshape(8, 4)
sharded = jax.device_put(w, NamedSharding(mesh, P("data", "model")))
mgr = CheckpointManager({str(tmp_path)!r})
mgr.save(3, {{"w": sharded}})
plan = plan_shrunk_mesh(mesh, n_failed=3)   # 2 per rank -> lose 2 ranks
assert plan.new_shape["data"] == 2
new_mesh = build_mesh_from_plan(plan)
new_sh = {{"w": NamedSharding(new_mesh, P("data", "model"))}}
step, restored = mgr.restore({{"w": jax.eval_shape(lambda: w)}}, shardings=new_sh)
np.testing.assert_array_equal(np.asarray(restored["w"]), np.asarray(w))
assert restored["w"].sharding.mesh.shape["data"] == 2
print("ELASTIC_OK")
""", devices=8)
    assert "ELASTIC_OK" in out

"""Regression-gate math: rolling-tolerance tightening + history loading.

Pure-function tests for benchmarks/check_regress.py — the gate's policy
(when does history tighten the blanket 30% tolerance, and by how much)
must be pinned independently of any actual timing run.
"""
import json

import pytest

from benchmarks.check_regress import (KEY_METRICS, check, load_history,
                                      rolling_tolerance)

TOL = 0.30


def test_short_history_keeps_default():
    assert rolling_tolerance([], 1.0, TOL) == TOL
    assert rolling_tolerance([1.0, 1.01], 1.0, TOL) == TOL      # < min_points
    assert rolling_tolerance([1.0] * 3, 0.0, TOL) == TOL        # bad baseline


def test_tight_history_tightens_to_floor():
    # five essentially identical green runs: spread ~0 → the floor, never 0
    hist = [1.000, 1.001, 0.999, 1.002, 1.000]
    tol = rolling_tolerance(hist, 1.0, TOL)
    assert tol == pytest.approx(0.10)
    assert tol < TOL


def test_noisy_history_keeps_default_cap():
    # run-to-run spread worse than the default: the gate must NOT loosen
    hist = [0.5, 1.0, 1.5, 2.0, 0.8]
    assert rolling_tolerance(hist, 1.0, TOL) == TOL


def test_intermediate_spread_lands_between_floor_and_cap():
    hist = [1.00, 1.05, 0.95, 1.04, 0.97, 1.02, 1.05]
    tol = rolling_tolerance(hist, 1.0, TOL)
    assert 0.10 < tol < TOL


def test_single_outlier_does_not_widen():
    # MAD, not stdev: one wild historical run leaves the tolerance tight
    calm = [1.000, 1.001, 0.999, 1.002, 1.000, 1.001]
    spiked = calm + [3.0]
    assert (rolling_tolerance(spiked, 1.0, TOL)
            == pytest.approx(rolling_tolerance(calm, 1.0, TOL), rel=0.5))
    assert rolling_tolerance(spiked, 1.0, TOL) < TOL


def test_systematic_offset_reserved_before_noise():
    # history hovering at 1.2x baseline: the offset term must keep the
    # tolerance above the offset itself (a fresh 1.2x run is NORMAL here)
    hist = [1.20, 1.21, 1.19, 1.20, 1.22]
    tol = rolling_tolerance(hist, 1.0, TOL)
    assert tol >= 0.20


def _rec(norm):
    return {"bench": "regress_quick",
            "metrics": {k: 100.0 * v for k, v in norm.items()},
            "normalized": dict(norm)}


def test_load_history_skips_torn_and_foreign(tmp_path):
    good = {k: 1.0 for k in KEY_METRICS}
    (tmp_path / "BENCH_a.json").write_text(json.dumps(_rec(good)))
    (tmp_path / "BENCH_b.json").write_text('{"bench": "regress_q')  # torn
    (tmp_path / "BENCH_c.json").write_text(json.dumps({"bench": "other"}))
    (tmp_path / "notes.txt").write_text("not an artifact")
    hist = load_history(str(tmp_path))
    assert all(hist[k] == [1.0] for k in KEY_METRICS)
    assert load_history(str(tmp_path / "missing")) == {
        k: [] for k in KEY_METRICS}


def test_check_applies_per_metric_history(capsys):
    """End-to-end policy: a 15% slip passes the blanket 30% gate but FAILS
    once a tight history shrinks that metric's tolerance to the floor."""
    base = _rec({k: 1.0 for k in KEY_METRICS})
    fresh = _rec({k: (1.15 if k == "validator_pass_us" else 1.0)
                  for k in KEY_METRICS})
    assert check(base, fresh, TOL, history=None) == []
    hist = {k: [1.000, 1.001, 0.999, 1.002] for k in KEY_METRICS}
    failures = check(base, fresh, TOL, history=hist)
    assert len(failures) == 1 and "validator_pass_us" in failures[0]
    assert "10% tolerance" in failures[0]


def test_check_skips_metric_missing_from_baseline():
    base = _rec({k: 1.0 for k in KEY_METRICS})
    del base["normalized"]["recovery_replay_us"]
    fresh = _rec({k: 5.0 for k in KEY_METRICS})     # huge slip everywhere
    failures = check(base, fresh, TOL)
    assert not any("recovery_replay_us" in f for f in failures)
    assert len(failures) == len(KEY_METRICS) - 1

"""Streaming epoch-boundary carry: partial_fit is bit-identical to the
one-shot run for ANY batch length (ROADMAP item closed by the train/serve
PR — published snapshots must be batching-independent).

The engine holds the trailing `n mod pb` points in an explicit
partial-epoch carry; `flush()` commits them as the one-shot run's final
short epoch.  Concatenating every call's outputs + flush reproduces the
one-shot pass exactly: assignments, epoch partition, stats, pool bits.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (
    BPMeansTransaction, DPMeansTransaction, OCCEngine, OFLTransaction,
)
from repro.data import bp_stick_breaking_data, dp_stick_breaking_data

LAM = 4.0


def _x(n=512, seed=4, dim=8):
    x, _, _ = dp_stick_breaking_data(n, seed=seed, dim=dim)
    return jnp.asarray(x)


def _stream_all(eng, x, cuts):
    """Feed x split at `cuts`, return concatenated outputs incl. flush."""
    parts = [eng.partial_fit(xb) for xb in jnp.split(x, cuts)]
    fl = eng.flush()
    if fl is not None:
        parts.append(fl)
    cat = lambda get: np.concatenate([np.asarray(get(p)) for p in parts])
    return cat(lambda p: p.assign), cat(lambda p: p.epoch_of), \
        cat(lambda p: p.send)


CUTS = [
    [100, 137, 412],          # nothing aligned to pb=64
    [1],                      # single point first (carry-only call)
    [63, 64, 65],             # straddling one epoch boundary repeatedly
    [511],                    # all but the last point
    [128, 256, 384],          # perfectly aligned (carry never engages)
]


@pytest.mark.parametrize("cuts", CUTS)
def test_dp_stream_any_batching_bit_identical(cuts):
    x = _x()
    txn = DPMeansTransaction(LAM, k_max=128)
    one = OCCEngine(txn, pb=64).run(x)
    eng = OCCEngine(txn, pb=64)
    z, eo, send = _stream_all(eng, x, cuts)
    assert np.array_equal(z, np.asarray(one.assign))
    assert np.array_equal(eo, np.asarray(one.epoch_of))
    assert np.array_equal(send, np.asarray(one.send))
    assert np.array_equal(np.asarray(eng.stats.proposed),
                          np.asarray(one.stats.proposed))
    assert np.array_equal(np.asarray(eng.stats.accepted),
                          np.asarray(one.stats.accepted))
    np.testing.assert_array_equal(np.asarray(eng.pool.centers),
                                  np.asarray(one.pool.centers))
    assert int(eng.pool.count) == int(one.pool.count)
    assert eng.n_pending == 0 and eng.n_processed == 512
    assert eng.epochs_done == one.stats.proposed.shape[0]


@pytest.mark.parametrize("cuts", [[100, 137, 412], [63, 64, 65]])
def test_ofl_stream_any_batching_bit_identical(cuts):
    """OFL is the sharp case: counter-based uniforms + probabilistic sends
    mean ANY epoch-partition drift changes draws — bit-identity here proves
    the carry restores the exact one-shot partition."""
    x = _x(seed=5)
    key = jax.random.key(9)
    txn = OFLTransaction(LAM, 256, key)
    one = OCCEngine(txn, pb=64).run(x)
    eng = OCCEngine(txn, pb=64)
    z, eo, _ = _stream_all(eng, x, cuts)
    assert np.array_equal(z, np.asarray(one.assign))
    assert np.array_equal(eo, np.asarray(one.epoch_of))
    k = int(one.pool.count)
    assert int(eng.pool.count) == k
    np.testing.assert_array_equal(np.asarray(eng.pool.centers[:k]),
                                  np.asarray(one.pool.centers[:k]))


def test_bp_stream_any_batching_bit_identical():
    """BP-means carries per-point STATE (the (N, K_max) assignment rows)
    through the partial epoch, not just the points."""
    xb, _, _ = bp_stick_breaking_data(256, seed=2)
    xb = jnp.asarray(xb)
    txn = BPMeansTransaction(LAM, k_max=32, init_mean=False)
    one = OCCEngine(txn, pb=32).run(xb)
    eng = OCCEngine(txn, pb=32)
    z, eo, _ = _stream_all(eng, xb, [50, 81, 200])
    assert np.array_equal(z, np.asarray(one.assign))
    assert np.array_equal(eo, np.asarray(one.epoch_of))
    np.testing.assert_array_equal(np.asarray(eng.pool.centers),
                                  np.asarray(one.pool.centers))


@pytest.mark.parametrize("cuts", [[50, 81, 200], [1], [31, 32, 33], [255]])
def test_bp_stream_init_mean_bit_identical(cuts):
    """The ROADMAP divergence, closed: with init_mean=True the pool seeds
    from the FIRST EPOCH's mean in both modes — pool initialization is
    deferred until the first committed epoch, whose points are identical
    for any batching (the partial-epoch carry holds them) — so streams are
    bit-identical to one-shot with NO explicit seeding, even when the first
    batch is a single point."""
    xb, _, _ = bp_stick_breaking_data(256, seed=2)
    xb = jnp.asarray(xb)
    txn = BPMeansTransaction(LAM, k_max=32, init_mean=True)
    one = OCCEngine(txn, pb=32).run(xb)
    eng = OCCEngine(txn, pb=32)
    z, eo, _ = _stream_all(eng, xb, cuts)
    assert np.array_equal(z, np.asarray(one.assign))
    assert np.array_equal(eo, np.asarray(one.epoch_of))
    np.testing.assert_array_equal(np.asarray(eng.pool.centers),
                                  np.asarray(one.pool.centers))
    assert int(eng.pool.count) == int(one.pool.count)


def test_bp_stream_init_mean_short_stream_matches_one_shot():
    """Streams shorter than one epoch: flush() commits everything as the
    one-shot run's single short epoch, so the init-mean scope is the whole
    (short) dataset in both modes."""
    xb, _, _ = bp_stick_breaking_data(20, seed=3)
    xb = jnp.asarray(xb)
    txn = BPMeansTransaction(LAM, k_max=16, init_mean=True)
    one = OCCEngine(txn, pb=32).run(xb)
    eng = OCCEngine(txn, pb=32)
    parts = [eng.partial_fit(xb[:7]), eng.partial_fit(xb[7:])]
    assert all(p.assign.shape[0] == 0 for p in parts)   # all carried
    fl = eng.flush()
    assert np.array_equal(np.asarray(fl.assign), np.asarray(one.assign))
    np.testing.assert_array_equal(np.asarray(eng.pool.centers),
                                  np.asarray(one.pool.centers))


def test_bp_stream_with_seeded_pool():
    """partial_fit(pool=...) still seeds the stream with an explicit pool
    (e.g. a warm model) — first call only; matches a one-shot run seeded
    with the same pool."""
    xb, _, _ = bp_stick_breaking_data(256, seed=2)
    xb = jnp.asarray(xb)
    txn = BPMeansTransaction(LAM, k_max=32)
    seed_pool = txn.init_pool(xb)          # full-data mean (warm model)
    one = OCCEngine(txn, pb=32).run(xb, pool=seed_pool)
    eng = OCCEngine(txn, pb=32)
    parts = [eng.partial_fit(xb[:50], pool=seed_pool),
             eng.partial_fit(xb[50:200]), eng.partial_fit(xb[200:])]
    fl = eng.flush()
    parts += [fl] if fl is not None else []
    z = np.concatenate([np.asarray(p.assign) for p in parts])
    assert np.array_equal(z, np.asarray(one.assign))
    np.testing.assert_array_equal(np.asarray(eng.pool.centers),
                                  np.asarray(one.pool.centers))
    with pytest.raises(ValueError):
        eng.partial_fit(xb[:32], pool=txn.init_pool(xb))


def test_carry_only_call_returns_zero_point_result():
    x = _x()
    eng = OCCEngine(DPMeansTransaction(LAM, k_max=128), pb=64)
    res = eng.partial_fit(x[:10])
    assert res.assign.shape == (0,) and res.assign.dtype == jnp.int32
    assert res.send.shape == (0,) and res.epoch_of.shape == (0,)
    assert res.stats.proposed.shape == (0,)
    assert eng.n_pending == 10 and eng.n_processed == 0
    assert eng.n_seen == 10 and eng.epochs_done == 0
    # the zero-point result did not touch the pool
    assert int(res.pool.count) == 0
    # carried points commit (with correct global epoch ids) once it fills
    res2 = eng.partial_fit(x[10:74])
    assert res2.assign.shape == (64,)
    assert (np.asarray(res2.epoch_of) == 0).all()
    assert eng.n_pending == 10


def test_reset_stream_does_not_leak_pool_into_carry_results():
    """A carry-only call on a RESET stream must report the zero pre-commit
    pool, not the previous stream's trained pool (the zero-point template
    is cached per shape — it must never capture live state)."""
    x = _x()
    eng = OCCEngine(DPMeansTransaction(LAM, k_max=128), pb=64)
    eng.partial_fit(x[:64])                  # commit: pool is trained
    eng.partial_fit(x[64:66])                # carry-only: caches template
    assert int(eng.pool.count) > 0
    eng.reset_stream()
    res = eng.partial_fit(x[:2])             # carry-only on a FRESH stream
    assert int(res.pool.count) == 0
    assert not bool(res.pool.mask.any())
    # and once the fresh stream commits, results flow normally again
    res2 = eng.partial_fit(x[2:66])
    assert res2.assign.shape == (64,) and int(eng.pool.count) > 0


def test_flush_empty_and_reset_stream():
    x = _x()
    eng = OCCEngine(DPMeansTransaction(LAM, k_max=128), pb=64)
    assert eng.flush() is None               # nothing pending
    eng.partial_fit(x[:100])
    assert eng.n_pending == 36
    fl = eng.flush()
    assert fl is not None and fl.assign.shape == (36,)
    assert eng.flush() is None               # idempotent
    eng.reset_stream()
    assert (eng.n_seen, eng.n_pending, eng.epochs_done) == (0, 0, 0)
    assert eng.pool is None


def test_epoch_of_is_globally_numbered_across_calls():
    x = _x()
    eng = OCCEngine(DPMeansTransaction(LAM, k_max=128), pb=64)
    r1 = eng.partial_fit(x[:128])
    r2 = eng.partial_fit(x[128:256])
    assert np.array_equal(np.unique(np.asarray(r1.epoch_of)), [0, 1])
    assert np.array_equal(np.unique(np.asarray(r2.epoch_of)), [2, 3])


# -------------------------------------------------------- hypothesis layer

try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:
    HAVE_HYPOTHESIS = False

if HAVE_HYPOTHESIS:
    @settings(max_examples=15, deadline=None)
    @given(cuts=st.lists(st.integers(min_value=1, max_value=255),
                         min_size=1, max_size=5, unique=True).map(sorted))
    def test_hypothesis_any_partition_matches_one_shot(cuts):
        x = _x(256, seed=13)
        txn = DPMeansTransaction(LAM, k_max=64)
        one = OCCEngine(txn, pb=32).run(x)
        eng = OCCEngine(txn, pb=32)
        z, eo, _ = _stream_all(eng, x, cuts)
        assert np.array_equal(z, np.asarray(one.assign))
        assert np.array_equal(eo, np.asarray(one.epoch_of))
        assert int(eng.pool.count) == int(one.pool.count)
else:  # pragma: no cover - exercised only without hypothesis
    def test_hypothesis_layer_skipped():
        pytest.skip("hypothesis not installed; deterministic sweep still ran")

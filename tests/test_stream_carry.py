"""Streaming epoch-boundary carry: partial_fit is bit-identical to the
one-shot run for ANY batch length (ROADMAP item closed by the train/serve
PR — published snapshots must be batching-independent).

The engine holds the trailing `n mod pb` points in an explicit
partial-epoch carry; `flush()` commits them as the one-shot run's final
short epoch.  Concatenating every call's outputs + flush reproduces the
one-shot pass exactly: assignments, epoch partition, stats, pool bits.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (
    BPMeansTransaction, DPMeansTransaction, OCCEngine, OFLTransaction,
)
from repro.data import bp_stick_breaking_data, dp_stick_breaking_data

LAM = 4.0


def _x(n=512, seed=4, dim=8):
    x, _, _ = dp_stick_breaking_data(n, seed=seed, dim=dim)
    return jnp.asarray(x)


def _stream_all(eng, x, cuts):
    """Feed x split at `cuts`, return concatenated outputs incl. flush."""
    parts = [eng.partial_fit(xb) for xb in jnp.split(x, cuts)]
    fl = eng.flush()
    if fl is not None:
        parts.append(fl)
    cat = lambda get: np.concatenate([np.asarray(get(p)) for p in parts])
    return cat(lambda p: p.assign), cat(lambda p: p.epoch_of), \
        cat(lambda p: p.send)


CUTS = [
    [100, 137, 412],          # nothing aligned to pb=64
    [1],                      # single point first (carry-only call)
    [63, 64, 65],             # straddling one epoch boundary repeatedly
    [511],                    # all but the last point
    [128, 256, 384],          # perfectly aligned (carry never engages)
]


@pytest.mark.parametrize("cuts", CUTS)
def test_dp_stream_any_batching_bit_identical(cuts):
    x = _x()
    txn = DPMeansTransaction(LAM, k_max=128)
    one = OCCEngine(txn, pb=64).run(x)
    eng = OCCEngine(txn, pb=64)
    z, eo, send = _stream_all(eng, x, cuts)
    assert np.array_equal(z, np.asarray(one.assign))
    assert np.array_equal(eo, np.asarray(one.epoch_of))
    assert np.array_equal(send, np.asarray(one.send))
    assert np.array_equal(np.asarray(eng.stats.proposed),
                          np.asarray(one.stats.proposed))
    assert np.array_equal(np.asarray(eng.stats.accepted),
                          np.asarray(one.stats.accepted))
    np.testing.assert_array_equal(np.asarray(eng.pool.centers),
                                  np.asarray(one.pool.centers))
    assert int(eng.pool.count) == int(one.pool.count)
    assert eng.n_pending == 0 and eng.n_processed == 512
    assert eng.epochs_done == one.stats.proposed.shape[0]


@pytest.mark.parametrize("cuts", [[100, 137, 412], [63, 64, 65]])
def test_ofl_stream_any_batching_bit_identical(cuts):
    """OFL is the sharp case: counter-based uniforms + probabilistic sends
    mean ANY epoch-partition drift changes draws — bit-identity here proves
    the carry restores the exact one-shot partition."""
    x = _x(seed=5)
    key = jax.random.key(9)
    txn = OFLTransaction(LAM, 256, key)
    one = OCCEngine(txn, pb=64).run(x)
    eng = OCCEngine(txn, pb=64)
    z, eo, _ = _stream_all(eng, x, cuts)
    assert np.array_equal(z, np.asarray(one.assign))
    assert np.array_equal(eo, np.asarray(one.epoch_of))
    k = int(one.pool.count)
    assert int(eng.pool.count) == k
    np.testing.assert_array_equal(np.asarray(eng.pool.centers[:k]),
                                  np.asarray(one.pool.centers[:k]))


def test_bp_stream_any_batching_bit_identical():
    """BP-means carries per-point STATE (the (N, K_max) assignment rows)
    through the partial epoch, not just the points.  init_mean=False keeps
    init_pool data-independent — with init_mean the pool seeds from
    mean(first batch) vs mean(all x), the one documented way a stream can
    differ from one-shot (see the seeded-pool variant below)."""
    xb, _, _ = bp_stick_breaking_data(256, seed=2)
    xb = jnp.asarray(xb)
    txn = BPMeansTransaction(LAM, k_max=32, init_mean=False)
    one = OCCEngine(txn, pb=32).run(xb)
    eng = OCCEngine(txn, pb=32)
    z, eo, _ = _stream_all(eng, xb, [50, 81, 200])
    assert np.array_equal(z, np.asarray(one.assign))
    assert np.array_equal(eo, np.asarray(one.epoch_of))
    np.testing.assert_array_equal(np.asarray(eng.pool.centers),
                                  np.asarray(one.pool.centers))


def test_bp_stream_with_seeded_pool_matches_mean_init():
    """partial_fit(pool=...) seeds the stream with the one-shot run's
    mean-initialized pool, restoring bit-identity for init_mean=True."""
    xb, _, _ = bp_stick_breaking_data(256, seed=2)
    xb = jnp.asarray(xb)
    txn = BPMeansTransaction(LAM, k_max=32)
    one = OCCEngine(txn, pb=32).run(xb)
    eng = OCCEngine(txn, pb=32)
    parts = [eng.partial_fit(xb[:50], pool=txn.init_pool(xb)),
             eng.partial_fit(xb[50:200]), eng.partial_fit(xb[200:])]
    fl = eng.flush()
    parts += [fl] if fl is not None else []
    z = np.concatenate([np.asarray(p.assign) for p in parts])
    assert np.array_equal(z, np.asarray(one.assign))
    np.testing.assert_array_equal(np.asarray(eng.pool.centers),
                                  np.asarray(one.pool.centers))
    with pytest.raises(ValueError):
        eng.partial_fit(xb[:32], pool=txn.init_pool(xb))


def test_carry_only_call_returns_zero_point_result():
    x = _x()
    eng = OCCEngine(DPMeansTransaction(LAM, k_max=128), pb=64)
    res = eng.partial_fit(x[:10])
    assert res.assign.shape == (0,) and res.assign.dtype == jnp.int32
    assert res.send.shape == (0,) and res.epoch_of.shape == (0,)
    assert res.stats.proposed.shape == (0,)
    assert eng.n_pending == 10 and eng.n_processed == 0
    assert eng.n_seen == 10 and eng.epochs_done == 0
    # the zero-point result did not touch the pool
    assert int(res.pool.count) == 0
    # carried points commit (with correct global epoch ids) once it fills
    res2 = eng.partial_fit(x[10:74])
    assert res2.assign.shape == (64,)
    assert (np.asarray(res2.epoch_of) == 0).all()
    assert eng.n_pending == 10


def test_flush_empty_and_reset_stream():
    x = _x()
    eng = OCCEngine(DPMeansTransaction(LAM, k_max=128), pb=64)
    assert eng.flush() is None               # nothing pending
    eng.partial_fit(x[:100])
    assert eng.n_pending == 36
    fl = eng.flush()
    assert fl is not None and fl.assign.shape == (36,)
    assert eng.flush() is None               # idempotent
    eng.reset_stream()
    assert (eng.n_seen, eng.n_pending, eng.epochs_done) == (0, 0, 0)
    assert eng.pool is None


def test_epoch_of_is_globally_numbered_across_calls():
    x = _x()
    eng = OCCEngine(DPMeansTransaction(LAM, k_max=128), pb=64)
    r1 = eng.partial_fit(x[:128])
    r2 = eng.partial_fit(x[128:256])
    assert np.array_equal(np.unique(np.asarray(r1.epoch_of)), [0, 1])
    assert np.array_equal(np.unique(np.asarray(r2.epoch_of)), [2, 3])


# -------------------------------------------------------- hypothesis layer

try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:
    HAVE_HYPOTHESIS = False

if HAVE_HYPOTHESIS:
    @settings(max_examples=15, deadline=None)
    @given(cuts=st.lists(st.integers(min_value=1, max_value=255),
                         min_size=1, max_size=5, unique=True).map(sorted))
    def test_hypothesis_any_partition_matches_one_shot(cuts):
        x = _x(256, seed=13)
        txn = DPMeansTransaction(LAM, k_max=64)
        one = OCCEngine(txn, pb=32).run(x)
        eng = OCCEngine(txn, pb=32)
        z, eo, _ = _stream_all(eng, x, cuts)
        assert np.array_equal(z, np.asarray(one.assign))
        assert np.array_equal(eo, np.asarray(one.epoch_of))
        assert int(eng.pool.count) == int(one.pool.count)
else:  # pragma: no cover - exercised only without hypothesis
    def test_hypothesis_layer_skipped():
        pytest.skip("hypothesis not installed; deterministic sweep still ran")

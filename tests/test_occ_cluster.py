"""Multi-process OCC: host-driven pass parity + real-process e2e (§13).

Fast tests pin the keystone equivalence behind `launch/occ_cluster.py`:
`OCCEngine.run_from_proposals` — the host-driven epoch loop the cluster
master runs — is bit-identical to the fused single-jit `run()`: with the
local proposer, with a serial bootstrap prefix, with a sharded 2-worker
proposer (the in-process twin of the worker plane's reassembly), and on
the BP-means pytree path.  Slow tests spawn REAL worker/follower
processes over loopback sockets and audit cross-process bit-identity plus
both chaos paths (worker death mid-epoch, follower kill + replacement
snapshot bootstrap).
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (BPMeansTransaction, DPMeansTransaction, OCCEngine)
from repro.core import engine as engine_mod
from repro.data import bp_stick_breaking_data, dp_stick_breaking_data
from repro.launch.occ_cluster import ClusterConfig, run_cluster
from repro.serving.snapshot import SnapshotStore

LAM = 4.0


def _assert_bitwise(res, ref):
    """Full-pass bit-identity: pool, per-point outputs, and stats."""
    eq = lambda a, b: np.array_equal(np.asarray(a), np.asarray(b))
    assert eq(ref.pool.centers, res.pool.centers)
    assert int(ref.pool.count) == int(res.pool.count)
    assert eq(ref.pool.mask, res.pool.mask)
    for a, b in zip(jax.tree_util.tree_leaves(ref.assign),
                    jax.tree_util.tree_leaves(res.assign)):
        assert eq(a, b)
    assert eq(ref.send, res.send)
    assert eq(ref.epoch_of, res.epoch_of)
    assert eq(ref.stats.proposed, res.stats.proposed)
    assert eq(ref.stats.accepted, res.stats.accepted)
    assert eq(ref.stats.cap, res.stats.cap)


# ------------------------------------------------- host-driven pass parity

def test_run_from_proposals_matches_fused_run():
    """Ragged final epoch (488 % 61 != 0) — padding/valid handling must
    match the fused scan exactly, and the host loop costs one dispatch per
    epoch where run() costs one per pass."""
    x, _, _ = dp_stick_breaking_data(488, seed=11, dim=12)
    x = jnp.asarray(x)
    txn = DPMeansTransaction(LAM, k_max=99)
    eng = OCCEngine(txn, pb=61)
    res = eng.run_from_proposals(x)
    t_epochs = -(-488 // 61)
    assert eng.n_dispatches == t_epochs
    _assert_bitwise(res, OCCEngine(txn, pb=61).run(x))


def test_run_from_proposals_with_bootstrap_prefix():
    x, _, _ = dp_stick_breaking_data(256, seed=8)
    x = jnp.asarray(x)
    txn = DPMeansTransaction(LAM, k_max=64)
    res = OCCEngine(txn, pb=32).run_from_proposals(x, n_bootstrap=5)
    _assert_bitwise(res, OCCEngine(txn, pb=32).run(x, n_bootstrap=5))


def test_two_shard_proposer_matches_fused_run():
    """The cluster reassembly in miniature: each epoch's proposal block is
    produced by TWO shard-shaped jitted propose calls and concatenated in
    worker order — jit-to-jit slice exactness makes it bitwise equal."""
    x, _, _ = dp_stick_breaking_data(512, seed=5)
    x = jnp.asarray(x)
    txn = DPMeansTransaction(LAM, k_max=128)
    spb = 32

    def sharded(pool, x_e, state_e, valid_e, *, epoch, offset):
        parts = []
        for w in range(2):
            cut = slice(w * spb, (w + 1) * spb)
            out = engine_mod._propose_epoch_jit(
                txn, pool, x_e[cut], jax.tree.map(lambda s: s[cut], state_e))
            parts.append(jax.tree_util.tree_flatten(out))
        treedef = parts[0][1]
        cat = [jnp.concatenate([p[0][i] for p in parts], 0)
               for i in range(len(parts[0][0]))]
        send, payload, aux, safe = jax.tree_util.tree_unflatten(treedef, cat)
        return send, payload, aux, safe, valid_e

    res = OCCEngine(txn, pb=64).run_from_proposals(x, sharded)
    _assert_bitwise(res, OCCEngine(txn, pb=64).run(x))


def test_bp_means_host_driven_matches_fused():
    """The pytree-assign (Gram fast path) transaction through the host
    loop — (N, K) boolean assigns concatenate/unpad identically."""
    xb, _, _ = bp_stick_breaking_data(128, seed=2)
    xb = jnp.asarray(xb)
    txn = BPMeansTransaction(LAM, k_max=32)
    res = OCCEngine(txn, pb=32).run_from_proposals(xb)
    _assert_bitwise(res, OCCEngine(txn, pb=32).run(xb))


def test_run_from_proposals_refuses_adaptive_and_mesh():
    x, _, _ = dp_stick_breaking_data(64, seed=0)
    x = jnp.asarray(x)
    txn = DPMeansTransaction(LAM, k_max=32)
    with pytest.raises(ValueError, match="adaptive"):
        OCCEngine(txn, pb=32, validate_cap="adaptive").run_from_proposals(x)
    eng = OCCEngine(txn, pb=32)
    eng.mesh = object()      # any mesh: host loop can't shard inside jit
    with pytest.raises(ValueError, match="mesh"):
        eng.run_from_proposals(x)


def test_on_commit_publishes_every_epoch():
    """The per-epoch replication hook fires after each commit with the
    committed pool — publishing there yields one store version per epoch,
    the last one holding the final centers."""
    x, _, _ = dp_stick_breaking_data(256, seed=4)
    x = jnp.asarray(x)
    txn = DPMeansTransaction(LAM, k_max=64)
    store = SnapshotStore(capacity=16, delta=True, model="m")
    seen = []

    def on_commit(pool, epoch, t_epochs):
        seen.append((epoch, t_epochs, int(pool.count)))
        store.publish_pool(pool, epochs=epoch + 1)

    res = OCCEngine(txn, pb=32).run_from_proposals(x, on_commit=on_commit)
    assert [e for e, _, _ in seen] == list(range(8))
    assert all(t == 8 for _, t, _ in seen)
    counts = [c for _, _, c in seen]
    assert counts == sorted(counts)          # validator only appends
    assert counts[-1] == int(res.pool.count)
    assert store.versions() == list(range(1, 9))
    np.testing.assert_array_equal(
        np.asarray(store.latest().centers[:counts[-1]]),
        np.asarray(res.pool.centers[:counts[-1]]))


# ------------------------------------------------- real processes (slow)

QUICK = dict(n=1024, dim=8, pb=64, k_max=128, lam=3.0,
             n_workers=2, n_followers=1, quiet=True)


@pytest.mark.slow
def test_multiproc_e2e_bit_identical(tmp_path):
    """2 worker processes + follower processes over loopback: the full
    acceptance audit — bit-identity to the single-process pass, follower
    digests, late-joiner bootstrap, full version streams — plus the BENCH
    record the CI job consumes."""
    out = tmp_path / "BENCH_transport.json"
    rec = run_cluster(ClusterConfig(**QUICK, out_path=str(out)))
    assert all(rec["bit_identical"].values())
    assert rec["follower_digests_match"] and all(rec["follower_digests_match"])
    assert rec["late_joiners_bootstrapped"]
    assert rec["full_stream_versions_match"]
    assert rec["worker_deaths"] == {}
    assert rec["followers"] == 2             # initial + late joiner
    assert rec["epochs"] == 16 and rec["versions_published"] == 16
    assert rec["n_acks"] > 0 and rec["ack_p99_ms"] >= rec["ack_p50_ms"]
    assert rec["delta_bytes_per_publish"] > 0
    assert out.exists()


@pytest.mark.slow
def test_multiproc_worker_death_is_deterministic():
    """Worker 1 exits hard on STEP for epoch 3: the master must mask that
    shard from exactly epoch 3 on and land bit-identical to the in-process
    reference with the same masks — a pinned, reproducible outcome."""
    rec = run_cluster(ClusterConfig(**QUICK, die_worker=1, die_epoch=3))
    assert rec["worker_deaths"] == {1: 3}
    assert all(rec["bit_identical"].values())
    assert rec["follower_digests_match"] and all(rec["follower_digests_match"])


@pytest.mark.slow
def test_multiproc_follower_kill_replacement_bootstraps():
    """SIGKILL the only follower mid-publish: the primary keeps publishing
    (dead follower no longer holds the watermark), and the replacement
    resyncs via a SNAPSHOT bootstrap to the same bit-identical store."""
    rec = run_cluster(ClusterConfig(**QUICK, late_follower=False,
                                    kill_follower_at_epoch=4))
    assert rec["followers"] == 1             # the killed one wrote no report
    assert rec["n_bootstraps"] >= 1
    assert all(rec["bit_identical"].values())
    assert rec["follower_digests_match"] and all(rec["follower_digests_match"])
    assert rec["late_joiners_bootstrapped"]


@pytest.mark.slow
def test_ha_kill_master_promotes_resumes_bit_identical():
    """Acceptance (§14 tentpole): SIGKILL the master right after version 6
    is fully replicated.  The follower with the highest commit watermark
    is promoted with a fenced term, workers reconnect, the pass resumes
    from epoch 6 — and every per-epoch digest, every OCCStats triple, the
    final store and every surviving follower are bit-identical to an
    uninterrupted single-process run."""
    from repro.launch.ha_cluster import HAConfig, run_ha_cluster
    rec = run_ha_cluster(HAConfig(n=1024, dim=8, pb=64, k_max=128, lam=3.0,
                                  n_workers=2, n_nodes=3,
                                  kill_master_after_version=6, quiet=True))
    assert rec["promotions"] == 1 and rec["terms"] == [1, 2]
    assert rec["resume_epoch"] == 6          # == the acked kill version
    assert rec["master_node_final"] == 1     # watermark tie → lowest node id
    assert rec["epoch_digests_match"] and rec["epoch_stats_match"]
    assert rec["final_digest_match"]
    assert rec["follower_digests_match"] and all(rec["follower_digests_match"])
    assert rec["recomputed_overlap_epochs"] == []   # no epoch ran twice

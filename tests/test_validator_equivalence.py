"""The unified precomputed validator (DESIGN.md §11) vs the reference
implementations — for DP-means, OFL, and BP-means, across random epochs,
caps, pool occupancies, and the sent_overflow / pool-overflow paths.

Contracts enforced here:
  * DP-means / OFL payload scan is bit-identical to the legacy per-step
    D-dimensional recompute (`core/_reference.py`), including pool bits.
  * `scan_mode="logdepth"` is bit-identical to `scan_mode="serial"` for
    DP-means / OFL — everything, centers included (min/compare algebra
    never rounds).
  * BP-means Gram-carry validation is decision-identical to the
    D-dimensional refit reference — every discrete output (assignments,
    sends, slots, counts, stats, overflow) bit-equal — with appended
    centers equal up to float reassociation of the same exact algebra
    (§11), asserted at ulp-scale tolerance.
  * `validate_cap="adaptive"` commits results bit-identical to the
    unbounded master for all three transactions (the overflow-retry
    guarantee).

Two layers: a deterministic seeded sweep that always runs, and hypothesis
property variants (skipped when hypothesis is absent) exploring the same
space adversarially.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (
    BPMeansTransaction, DPMeansTransaction, OCCEngine, OFLTransaction,
    make_pool, nearest_center, precomputed_gather_validate,
)
from repro.core._reference import _reference_validate, reference_pass

try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:
    HAVE_HYPOTHESIS = False


def _seeded_pool(k_max, d, k0, rng):
    """A pool with k0 occupied slots (random occupancy ≤ k_max)."""
    pool = make_pool(k_max, d)
    if k0:
        centers = pool.centers.at[:k0].set(
            jnp.asarray(rng.normal(size=(k0, d)).astype(np.float32) * 2.0))
        pool = pool._replace(centers=centers,
                             mask=pool.mask.at[:k0].set(True),
                             count=jnp.asarray(k0, jnp.int32))
    return pool


def _problem(n, d, k_max, k0, seed):
    rng = np.random.default_rng(seed)
    x = jnp.asarray(rng.normal(size=(n, d)).astype(np.float32) * 2.0)
    return x, _seeded_pool(k_max, d, min(k0, k_max), rng)


def _assert_matches_reference(txn, x, pool, pb, cap, state=None,
                              scan_mode="serial"):
    """Engine (fast path) == legacy per-step reference, bit for bit."""
    fast = OCCEngine(txn, pb, validate_cap=cap,
                     scan_mode=scan_mode).run(x, pool=pool, state=state)
    rp, ra, rs, rst = reference_pass(txn, pool, x, state=state, pb=pb,
                                     cap=cap)
    np.testing.assert_array_equal(np.asarray(fast.assign), np.asarray(ra))
    np.testing.assert_array_equal(np.asarray(fast.send), np.asarray(rs))
    np.testing.assert_array_equal(np.asarray(fast.stats.proposed),
                                  np.asarray(rst.proposed))
    np.testing.assert_array_equal(np.asarray(fast.stats.accepted),
                                  np.asarray(rst.accepted))
    np.testing.assert_array_equal(np.asarray(fast.pool.centers),
                                  np.asarray(rp.centers))
    np.testing.assert_array_equal(np.asarray(fast.pool.mask),
                                  np.asarray(rp.mask))
    assert int(fast.pool.count) == int(rp.count)
    assert bool(fast.pool.overflow) == bool(rp.overflow)
    return fast


def _assert_scan_modes_identical(txn, x, pool, pb, cap):
    """logdepth == serial, bit for bit, everything."""
    serial = OCCEngine(txn, pb, validate_cap=cap).run(x, pool=pool)
    logd = OCCEngine(txn, pb, validate_cap=cap,
                     scan_mode="logdepth").run(x, pool=pool)
    for got, want in [(logd.assign, serial.assign), (logd.send, serial.send),
                      (logd.pool.centers, serial.pool.centers),
                      (logd.pool.mask, serial.pool.mask),
                      (logd.stats.proposed, serial.stats.proposed),
                      (logd.stats.accepted, serial.stats.accepted)]:
        np.testing.assert_array_equal(np.asarray(got), np.asarray(want))
    assert int(logd.pool.count) == int(serial.pool.count)
    assert bool(logd.pool.overflow) == bool(serial.pool.overflow)
    return serial


def _assert_bp_decision_identical(txn, x, pool, pb, cap):
    """BP Gram scan vs D-dim refit reference: every discrete output bit-
    identical; centers exact-algebra-equal (ulp-scale reassociation only)."""
    z0 = txn.make_state(x)
    fast = OCCEngine(txn, pb, validate_cap=cap).run(x, pool=pool, state=z0)
    rp, ra, rs, rst = reference_pass(txn, pool, x, state=z0, pb=pb, cap=cap)
    np.testing.assert_array_equal(np.asarray(fast.assign), np.asarray(ra))
    np.testing.assert_array_equal(np.asarray(fast.send), np.asarray(rs))
    np.testing.assert_array_equal(np.asarray(fast.stats.proposed),
                                  np.asarray(rst.proposed))
    np.testing.assert_array_equal(np.asarray(fast.stats.accepted),
                                  np.asarray(rst.accepted))
    np.testing.assert_array_equal(np.asarray(fast.pool.mask),
                                  np.asarray(rp.mask))
    assert int(fast.pool.count) == int(rp.count)
    assert bool(fast.pool.overflow) == bool(rp.overflow)
    scale = max(1.0, float(jnp.max(jnp.abs(rp.centers))))
    np.testing.assert_allclose(np.asarray(fast.pool.centers),
                               np.asarray(rp.centers), atol=1e-5 * scale)
    return fast


# ------------------------------------------------- deterministic seeded sweep

SWEEP = [
    # (n, d, k_max, k0, pb, lam, cap)
    (48, 3, 16, 0, 8, 2.0, None),       # cold pool, unbounded master
    (48, 3, 16, 5, 8, 2.0, 16),         # warm pool, roomy cap
    (96, 5, 64, 8, 16, 0.8, 4),         # small lam + tiny cap: sent_overflow
    (24, 2, 16, 2, 32, 4.0, 4),         # epoch wider than data
    (96, 5, 8, 0, 16, 0.5, None),       # pool-capacity overflow path
]


@pytest.mark.parametrize("n,d,k_max,k0,pb,lam,cap", SWEEP)
def test_dpmeans_fast_equals_reference_sweep(n, d, k_max, k0, pb, lam, cap):
    x, pool = _problem(n, d, k_max, k0, seed=n + k0)
    _assert_matches_reference(DPMeansTransaction(lam, k_max), x, pool, pb, cap)


@pytest.mark.parametrize("n,d,k_max,k0,pb,lam,cap", SWEEP)
def test_ofl_fast_equals_reference_sweep(n, d, k_max, k0, pb, lam, cap):
    x, pool = _problem(n, d, k_max, k0, seed=n + k0)
    txn = OFLTransaction(lam, k_max, jax.random.key(n))
    _assert_matches_reference(txn, x, pool, pb, cap)


@pytest.mark.parametrize("n,d,k_max,k0,pb,lam,cap", SWEEP)
def test_dpmeans_logdepth_equals_serial_sweep(n, d, k_max, k0, pb, lam, cap):
    x, pool = _problem(n, d, k_max, k0, seed=n + k0)
    _assert_scan_modes_identical(DPMeansTransaction(lam, k_max), x, pool,
                                 pb, cap)


@pytest.mark.parametrize("n,d,k_max,k0,pb,lam,cap", SWEEP)
def test_ofl_logdepth_equals_serial_sweep(n, d, k_max, k0, pb, lam, cap):
    x, pool = _problem(n, d, k_max, k0, seed=n + k0)
    txn = OFLTransaction(lam, k_max, jax.random.key(n))
    _assert_scan_modes_identical(txn, x, pool, pb, cap)


@pytest.mark.parametrize("n,d,k_max,k0,pb,lam,cap", SWEEP)
def test_bpmeans_gram_matches_refit_reference_sweep(n, d, k_max, k0, pb, lam,
                                                    cap):
    """The sweep's rows 3 and 5 drive sent_overflow and pool-capacity
    overflow through the Gram scan (small λ floods the validator)."""
    x, pool = _problem(n, d, k_max, k0, seed=n + k0)
    txn = BPMeansTransaction(lam, k_max, init_mean=False)
    _assert_bp_decision_identical(txn, x, pool, pb, cap)


@pytest.mark.parametrize("txn_name", ["dp", "ofl", "bp"])
def test_adaptive_cap_equals_full_cap(txn_name):
    """Adaptive committed results are bit-identical to the unbounded master
    for all three transactions — multi-pass so the Thm-3.3 estimate
    actually engages after the burn-in pass."""
    x, _ = _problem(256, 4, 128, 0, seed=17)
    if txn_name == "dp":
        txn = DPMeansTransaction(3.0, 128)
    elif txn_name == "ofl":
        txn = OFLTransaction(3.0, 128, jax.random.key(3))
    else:
        txn = BPMeansTransaction(3.0, 128, init_mean=False)
    state = txn.make_state(x)
    ea = OCCEngine(txn, pb=64, validate_cap="adaptive")
    ef = OCCEngine(txn, pb=64)
    ra, rf = ea.run(x, state=state), ef.run(x, state=state)
    for _ in range(2):          # warm passes: the shrunken cap is live now
        ra = ea.run(x, pool=ra.pool, state=state)
        rf = ef.run(x, pool=rf.pool, state=state)
    assert ea.cap_history[-1] is not None and ea.cap_history[-1] < 64, \
        f"adaptive cap never engaged: {ea.cap_history}"
    np.testing.assert_array_equal(np.asarray(ra.assign), np.asarray(rf.assign))
    np.testing.assert_array_equal(np.asarray(ra.pool.centers),
                                  np.asarray(rf.pool.centers))
    np.testing.assert_array_equal(np.asarray(ra.stats.proposed),
                                  np.asarray(rf.stats.proposed))
    assert int(ra.pool.count) == int(rf.pool.count)
    # the chosen cap is surfaced per epoch
    caps = np.asarray(ra.stats.cap)
    assert caps.shape == ra.stats.proposed.shape
    assert (caps >= np.asarray(ra.stats.proposed)).all()


def test_adaptive_cap_overflow_retry_is_lossless():
    """A stream whose conflict rate explodes after a quiet prefix overflows
    the shrunken window; the engine must re-dispatch at full width and
    commit results identical to the unbounded master."""
    rng = np.random.default_rng(11)
    quiet = rng.normal(size=(192, 4)).astype(np.float32) * 0.1
    burst = rng.normal(size=(64, 4)).astype(np.float32) * 50.0
    x = jnp.asarray(np.concatenate([quiet, burst]))
    txn = DPMeansTransaction(2.0, 256)
    ea = OCCEngine(txn, pb=64, validate_cap="adaptive")
    ef = OCCEngine(txn, pb=64)
    za, zf = [], []
    for lo in range(0, 256, 64):
        za.append(np.asarray(ea.partial_fit(x[lo:lo + 64]).assign))
        zf.append(np.asarray(ef.partial_fit(x[lo:lo + 64]).assign))
    assert ea.n_cap_retries >= 1, ea.cap_history
    np.testing.assert_array_equal(np.concatenate(za), np.concatenate(zf))
    np.testing.assert_array_equal(np.asarray(ea.pool.centers),
                                  np.asarray(ef.pool.centers))
    assert int(ea.pool.count) == int(ef.pool.count)


def test_unknown_knobs_raise():
    with pytest.raises(ValueError):
        OCCEngine(DPMeansTransaction(1.0, 8), 8, scan_mode="nope")
    with pytest.raises(ValueError):
        OCCEngine(DPMeansTransaction(1.0, 8), 8, validate_cap="nope")


def test_sent_overflow_bitidentical_slots():
    """Direct occ-level check: slots / outs / overflow from the fast path
    match the reference path through the bounded master, cap exceeded."""
    rng = np.random.default_rng(0)
    d, k_max, cap = 3, 16, 3
    pool = _seeded_pool(k_max, d, 2, rng)
    x = jnp.asarray(rng.normal(size=(10, d)).astype(np.float32) * 10.0)
    txn = DPMeansTransaction(1.0, k_max)
    send, payload, aux, _ = txn.propose(pool, x, ())
    count0 = pool.count

    accept = lambda p, v_j, a_j: txn.accept(p, v_j, a_j, count0)
    pl_, sl_, ol_, ovf_l = _reference_validate(pool, send, payload, accept,
                                               aux, cap=cap)
    for mode in ("serial", "logdepth"):
        pf_, sf_, of_, ovf_f = precomputed_gather_validate(
            pool, send, payload, aux, txn.precompute_accept, txn.accept_pre,
            cap=cap, scan_mode=mode)
        assert bool(ovf_l) and bool(ovf_f)
        np.testing.assert_array_equal(np.asarray(sl_), np.asarray(sf_))
        # outs only carry meaning for sent proposals (writeback masks them)
        s = np.asarray(send)
        np.testing.assert_array_equal(np.asarray(ol_)[s], np.asarray(of_)[s])
        np.testing.assert_array_equal(np.asarray(pl_.centers),
                                      np.asarray(pf_.centers))
        assert int(pl_.count) == int(pf_.count)


def test_fast_path_equals_full_recompute_reference():
    """Three-way: the precomputed path also matches the ORIGINAL
    full-recompute accept rule (nearest_center over the whole pool each
    scan step) — the pre-threading reference implementation."""
    rng = np.random.default_rng(3)
    d, k_max = 4, 32
    pool = _seeded_pool(k_max, d, 5, rng)
    x = jnp.asarray(rng.normal(size=(40, d)).astype(np.float32) * 2.0)
    lam2 = jnp.float32(2.0) ** 2
    txn = DPMeansTransaction(2.0, k_max)
    send, payload, aux, _ = txn.propose(pool, x, ())

    def full_recompute(p, x_j, a_j):
        d2, ref = nearest_center(p, x_j)
        return d2 > lam2, x_j, ref

    pr, sr, orr, _ = _reference_validate(pool, send, payload, full_recompute,
                                         aux=None, cap=None)
    pf, sf, off, _ = precomputed_gather_validate(
        pool, send, payload, aux, txn.precompute_accept, txn.accept_pre,
        cap=None)
    np.testing.assert_array_equal(np.asarray(sr), np.asarray(sf))
    s = np.asarray(send)
    np.testing.assert_array_equal(np.asarray(orr)[s], np.asarray(off)[s])
    np.testing.assert_array_equal(np.asarray(pr.centers), np.asarray(pf.centers))
    assert int(pr.count) == int(pf.count)


# ------------------------------------------------- hypothesis property layer

if HAVE_HYPOTHESIS:
    SET = dict(max_examples=10, deadline=None)

    @st.composite
    def validator_problem(draw):
        n = draw(st.sampled_from([24, 48, 96]))
        d = draw(st.sampled_from([2, 5]))
        pb = draw(st.sampled_from([8, 16, 32]))
        lam = draw(st.floats(0.5, 5.0))
        k_max = draw(st.sampled_from([16, 64]))
        k0 = draw(st.integers(0, 8))
        # cap=4 routinely exercises sent_overflow; None = unbounded master
        cap = draw(st.sampled_from([None, 4, 16]))
        seed = draw(st.integers(0, 2 ** 16))
        x, pool = _problem(n, d, k_max, k0, seed)
        return x, pool, pb, float(lam), k_max, cap, seed

    @given(validator_problem())
    @settings(**SET)
    def test_dpmeans_fast_equals_reference_property(prob):
        x, pool, pb, lam, k_max, cap, _ = prob
        _assert_matches_reference(DPMeansTransaction(lam, k_max), x, pool,
                                  pb, cap)

    @given(validator_problem())
    @settings(**SET)
    def test_ofl_fast_equals_reference_property(prob):
        x, pool, pb, lam, k_max, cap, seed = prob
        txn = OFLTransaction(lam, k_max, jax.random.key(seed))
        _assert_matches_reference(txn, x, pool, pb, cap)

    @given(validator_problem())
    @settings(**SET)
    def test_logdepth_equals_serial_property(prob):
        x, pool, pb, lam, k_max, cap, seed = prob
        _assert_scan_modes_identical(DPMeansTransaction(lam, k_max), x, pool,
                                     pb, cap)
        txn = OFLTransaction(lam, k_max, jax.random.key(seed))
        _assert_scan_modes_identical(txn, x, pool, pb, cap)

    @given(validator_problem())
    @settings(max_examples=8, deadline=None)
    def test_bpmeans_gram_matches_reference_property(prob):
        """The ISSUE's bit-identity layer: every discrete BP validation
        output equals the D-dim refit reference on adversarial problems —
        including sent_overflow (cap=4 draws) and pool-overflow (k0 ~ k_max
        with small λ) epochs."""
        x, pool, pb, lam, k_max, cap, _ = prob
        txn = BPMeansTransaction(lam, k_max, init_mean=False)
        _assert_bp_decision_identical(txn, x, pool, pb, cap)

    @given(st.sampled_from([0.5, 0.8]), st.integers(0, 2 ** 16))
    @settings(max_examples=4, deadline=None)
    def test_bpmeans_gram_overflow_property(lam, seed):
        """Dedicated overflow hammer: tiny pool + tiny cap + flooding λ."""
        x, pool = _problem(96, 5, 8, 0, seed)
        txn = BPMeansTransaction(lam, 8, init_mean=False)
        res = _assert_bp_decision_identical(txn, x, pool, 16, 4)
        assert bool(res.pool.overflow)
else:  # pragma: no cover - exercised only without hypothesis
    def test_hypothesis_layer_skipped():
        pytest.skip("hypothesis not installed; deterministic sweep still ran")

"""The precomputed (D-free) validator is bit-identical to the legacy
full-recompute validator — for DP-means, OFL, and BP-means, across random
epochs, caps, pool occupancies, and the sent_overflow path (DESIGN.md §9).

Two layers: a deterministic seeded sweep that always runs, and hypothesis
property variants (skipped when hypothesis is absent) exploring the same
space adversarially.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (
    BPMeansTransaction, DPMeansTransaction, OCCEngine, OFLTransaction,
    gather_validate, make_pool, nearest_center, precomputed_gather_validate,
    resolve_validate_mode,
)

try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:
    HAVE_HYPOTHESIS = False


def _seeded_pool(k_max, d, k0, rng):
    """A pool with k0 occupied slots (random occupancy ≤ k_max)."""
    pool = make_pool(k_max, d)
    if k0:
        centers = pool.centers.at[:k0].set(
            jnp.asarray(rng.normal(size=(k0, d)).astype(np.float32) * 2.0))
        pool = pool._replace(centers=centers,
                             mask=pool.mask.at[:k0].set(True),
                             count=jnp.asarray(k0, jnp.int32))
    return pool


def _problem(n, d, k_max, k0, seed):
    rng = np.random.default_rng(seed)
    x = jnp.asarray(rng.normal(size=(n, d)).astype(np.float32) * 2.0)
    return x, _seeded_pool(k_max, d, min(k0, k_max), rng)


def _assert_runs_identical(txn, x, pool, pb, cap):
    fast = OCCEngine(txn, pb, validate_cap=cap,
                     validate_mode="precomputed").run(x, pool=pool)
    legacy = OCCEngine(txn, pb, validate_cap=cap,
                       validate_mode="legacy").run(x, pool=pool)
    np.testing.assert_array_equal(np.asarray(fast.assign),
                                  np.asarray(legacy.assign))
    np.testing.assert_array_equal(np.asarray(fast.send),
                                  np.asarray(legacy.send))
    np.testing.assert_array_equal(np.asarray(fast.stats.proposed),
                                  np.asarray(legacy.stats.proposed))
    np.testing.assert_array_equal(np.asarray(fast.stats.accepted),
                                  np.asarray(legacy.stats.accepted))
    np.testing.assert_array_equal(np.asarray(fast.pool.centers),
                                  np.asarray(legacy.pool.centers))
    np.testing.assert_array_equal(np.asarray(fast.pool.mask),
                                  np.asarray(legacy.pool.mask))
    assert int(fast.pool.count) == int(legacy.pool.count)
    assert bool(fast.pool.overflow) == bool(legacy.pool.overflow)
    return fast


# ------------------------------------------------- deterministic seeded sweep

SWEEP = [
    # (n, d, k_max, k0, pb, lam, cap)
    (48, 3, 16, 0, 8, 2.0, None),       # cold pool, unbounded master
    (48, 3, 16, 5, 8, 2.0, 16),         # warm pool, roomy cap
    (96, 5, 64, 8, 16, 0.8, 4),         # small lam + tiny cap: sent_overflow
    (24, 2, 16, 2, 32, 4.0, 4),         # epoch wider than data
    (96, 5, 8, 0, 16, 0.5, None),       # pool-capacity overflow path
]


@pytest.mark.parametrize("n,d,k_max,k0,pb,lam,cap", SWEEP)
def test_dpmeans_fast_equals_legacy_sweep(n, d, k_max, k0, pb, lam, cap):
    x, pool = _problem(n, d, k_max, k0, seed=n + k0)
    _assert_runs_identical(DPMeansTransaction(lam, k_max), x, pool, pb, cap)


@pytest.mark.parametrize("n,d,k_max,k0,pb,lam,cap", SWEEP)
def test_ofl_fast_equals_legacy_sweep(n, d, k_max, k0, pb, lam, cap):
    x, pool = _problem(n, d, k_max, k0, seed=n + k0)
    txn = OFLTransaction(lam, k_max, jax.random.key(n))
    _assert_runs_identical(txn, x, pool, pb, cap)


@pytest.mark.parametrize("n,d,k_max,k0,pb,lam,cap", SWEEP[:3])
def test_bpmeans_auto_matches_legacy_sweep(n, d, k_max, k0, pb, lam, cap):
    """BP-means has no precomputed path (its append vector is the refit
    residual, not the payload): auto must resolve to legacy, and the
    auto-mode run must equal the forced-legacy run."""
    x, pool = _problem(n, d, k_max, k0, seed=n + k0)
    txn = BPMeansTransaction(lam, k_max, init_mean=False)
    assert resolve_validate_mode(txn, "auto") == "legacy"
    auto = OCCEngine(txn, pb, validate_cap=cap).run(x, pool=pool)
    legacy = OCCEngine(txn, pb, validate_cap=cap,
                       validate_mode="legacy").run(x, pool=pool)
    np.testing.assert_array_equal(np.asarray(auto.assign),
                                  np.asarray(legacy.assign))
    np.testing.assert_array_equal(np.asarray(auto.pool.centers),
                                  np.asarray(legacy.pool.centers))


def test_auto_resolves_fast_for_dp_and_ofl():
    assert resolve_validate_mode(DPMeansTransaction(1.0, 8)) == "precomputed"
    assert resolve_validate_mode(
        OFLTransaction(1.0, 8, jax.random.key(0))) == "precomputed"


def test_forcing_precomputed_on_bp_raises():
    txn = BPMeansTransaction(1.0, 8)
    with pytest.raises(ValueError):
        OCCEngine(txn, 8, validate_mode="precomputed")


def test_unknown_validate_mode_raises():
    with pytest.raises(ValueError):
        OCCEngine(DPMeansTransaction(1.0, 8), 8, validate_mode="nope")


def test_sent_overflow_bitidentical_slots():
    """Direct occ-level check: slots / outs / overflow from the fast path
    match the legacy path through the bounded master, cap exceeded."""
    rng = np.random.default_rng(0)
    d, k_max, cap = 3, 16, 3
    pool = _seeded_pool(k_max, d, 2, rng)
    x = jnp.asarray(rng.normal(size=(10, d)).astype(np.float32) * 10.0)
    txn = DPMeansTransaction(1.0, k_max)
    send, payload, aux, _ = txn.propose(pool, x, ())
    count0 = pool.count

    accept = lambda p, v_j, a_j: txn.accept(p, v_j, a_j, count0)
    pl_, sl_, ol_, ovf_l = gather_validate(pool, send, payload, accept, aux,
                                           cap=cap)
    pf_, sf_, of_, ovf_f = precomputed_gather_validate(
        pool, send, payload, aux, txn.precompute_accept, txn.accept_pre,
        cap=cap)
    assert bool(ovf_l) and bool(ovf_f)
    np.testing.assert_array_equal(np.asarray(sl_), np.asarray(sf_))
    # outs only carry meaning for sent proposals (writeback masks the rest)
    s = np.asarray(send)
    np.testing.assert_array_equal(np.asarray(ol_)[s], np.asarray(of_)[s])
    np.testing.assert_array_equal(np.asarray(pl_.centers), np.asarray(pf_.centers))
    assert int(pl_.count) == int(pf_.count)


def test_fast_path_equals_full_recompute_reference():
    """Three-way: the precomputed path also matches the ORIGINAL
    full-recompute accept rule (nearest_center over the whole pool each
    scan step) — the pre-threading reference implementation."""
    rng = np.random.default_rng(3)
    d, k_max = 4, 32
    pool = _seeded_pool(k_max, d, 5, rng)
    x = jnp.asarray(rng.normal(size=(40, d)).astype(np.float32) * 2.0)
    lam2 = jnp.float32(2.0) ** 2
    txn = DPMeansTransaction(2.0, k_max)
    send, payload, aux, _ = txn.propose(pool, x, ())

    def full_recompute(p, x_j, a_j):
        d2, ref = nearest_center(p, x_j)
        return d2 > lam2, x_j, ref

    pr, sr, orr, _ = gather_validate(pool, send, payload, full_recompute,
                                     aux=None, cap=None)
    pf, sf, off, _ = precomputed_gather_validate(
        pool, send, payload, aux, txn.precompute_accept, txn.accept_pre,
        cap=None)
    np.testing.assert_array_equal(np.asarray(sr), np.asarray(sf))
    s = np.asarray(send)
    np.testing.assert_array_equal(np.asarray(orr)[s], np.asarray(off)[s])
    np.testing.assert_array_equal(np.asarray(pr.centers), np.asarray(pf.centers))
    assert int(pr.count) == int(pf.count)


# ------------------------------------------------- hypothesis property layer

if HAVE_HYPOTHESIS:
    SET = dict(max_examples=10, deadline=None)

    @st.composite
    def validator_problem(draw):
        n = draw(st.sampled_from([24, 48, 96]))
        d = draw(st.sampled_from([2, 5]))
        pb = draw(st.sampled_from([8, 16, 32]))
        lam = draw(st.floats(0.5, 5.0))
        k_max = draw(st.sampled_from([16, 64]))
        k0 = draw(st.integers(0, 8))
        # cap=4 routinely exercises sent_overflow; None = unbounded master
        cap = draw(st.sampled_from([None, 4, 16]))
        seed = draw(st.integers(0, 2 ** 16))
        x, pool = _problem(n, d, k_max, k0, seed)
        return x, pool, pb, float(lam), k_max, cap, seed

    @given(validator_problem())
    @settings(**SET)
    def test_dpmeans_fast_equals_legacy_property(prob):
        x, pool, pb, lam, k_max, cap, _ = prob
        _assert_runs_identical(DPMeansTransaction(lam, k_max), x, pool, pb, cap)

    @given(validator_problem())
    @settings(**SET)
    def test_ofl_fast_equals_legacy_property(prob):
        x, pool, pb, lam, k_max, cap, seed = prob
        txn = OFLTransaction(lam, k_max, jax.random.key(seed))
        _assert_runs_identical(txn, x, pool, pb, cap)

    @given(validator_problem())
    @settings(max_examples=6, deadline=None)
    def test_bpmeans_auto_matches_legacy_property(prob):
        x, pool, pb, lam, k_max, cap, _ = prob
        txn = BPMeansTransaction(lam, k_max, init_mean=False)
        auto = OCCEngine(txn, pb, validate_cap=cap).run(x, pool=pool)
        legacy = OCCEngine(txn, pb, validate_cap=cap,
                           validate_mode="legacy").run(x, pool=pool)
        np.testing.assert_array_equal(np.asarray(auto.assign),
                                      np.asarray(legacy.assign))
        np.testing.assert_array_equal(np.asarray(auto.pool.centers),
                                      np.asarray(legacy.pool.centers))
else:  # pragma: no cover - exercised only without hypothesis
    def test_hypothesis_layer_skipped():
        pytest.skip("hypothesis not installed; deterministic sweep still ran")
